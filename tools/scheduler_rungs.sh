#!/usr/bin/env bash
# Regenerate the scheduler-gauntlet allocation audit: one quick Hyperband
# and one UCB-bandit campaign over the checkpointable registry subset,
# interleaved on ONE shared modeled clock, concatenated into a single TSV
# (one header). The model is fully deterministic, so the output is
# byte-stable across machines — CI diffs it against the committed fixture
# rust/tests/fixtures/scheduler_rungs.tsv, and the seal-baselines workflow
# regenerates that fixture with this same script. Keep the recipe here, in
# ONE place, so the gate and the sealer can never drift apart.
#
# Usage: tools/scheduler_rungs.sh [output.tsv]
set -euo pipefail

out="${1:-scheduler-rungs.tsv}"
tuners="spsa,random,nelder-mead,tpe"
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

cargo run --release -- tune --policy hyperband --tuners "$tuners" \
  --total-time 3000 --rungs-out "$tmp/hyperband.tsv"
cargo run --release -- tune --policy bandit --tuners "$tuners" \
  --total-time 3000 --rungs-out "$tmp/bandit.tsv"

{ cat "$tmp/hyperband.tsv"; tail -n +2 "$tmp/bandit.tsv"; } > "$out"
echo "wrote $(($(wc -l < "$out") - 1)) audit row(s) to $out"
