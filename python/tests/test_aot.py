"""AOT lowering tests: both exported computations lower to valid HLO text
with the shapes the rust loader expects (the ABI of the artifacts)."""

from compile import aot, model


def test_lower_all_produces_hlo_text():
    out = aot.lower_all()
    assert set(out) == {"whatif_batch", "spsa_step"}
    for name, text in out.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_whatif_entry_signature():
    text = aot.lower_all()["whatif_batch"]
    # entry layout: [256,11] x [11] x [10] -> ([256],)
    assert "f32[256,11]" in text
    assert "f32[256]" in text


def test_spsa_step_entry_signature():
    text = aot.lower_all()["spsa_step"]
    # theta[11], signs[8,11], ..., output packed [23]
    assert "f32[8,11]" in text
    assert f"f32[{2 * model.N + 1}]" in text


def test_metadata_matches_model():
    meta = aot.metadata()
    assert meta["batch"] == model.BATCH == 256
    assert meta["n_params"] == model.N == 11
    assert meta["n_perturbations"] == model.N_PERTURBATIONS == 8
    assert meta["spsa_step_output_len"] == 23
    assert len(meta["workload_features"]) == 11
