"""L1 correctness: Pallas kernel vs the pure-jnp oracle (`ref.cost_ref`).

The CORE correctness signal of the compile path: hypothesis sweeps random
parameter rows, workload mixes, batch sizes and both Hadoop versions, and
the kernel must match the reference to float32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import costmodel, ref

RNG = np.random.default_rng(0)


def cluster_features(is_v1=1.0):
    return np.array(
        [24, 3, 2, 120e6, 117e6, 2e8, 128 << 20, 1 << 30, 2, is_v1],
        dtype=np.float32,
    )


def workload_features(
    input_gb=8.0, sel_b=1.0, sel_r=1.0, comb=1.0, skew=1.1,
    map_ops=60.0, red_ops=50.0, cratio=0.4,
):
    return np.array(
        [input_gb * (1 << 30), 100.0, sel_b, sel_r, 100.0, comb, 1.0, skew,
         cratio, map_ops, red_ops],
        dtype=np.float32,
    )


def random_params(n_rows, rng=RNG):
    """Random Hadoop-space rows within the ParameterSpace ranges."""
    cols = [
        rng.uniform(50, 2000, n_rows),      # io.sort.mb
        rng.uniform(0.05, 0.95, n_rows),    # spill.percent
        rng.uniform(5, 500, n_rows),        # sort.factor
        rng.uniform(0.1, 0.95, n_rows),     # shuffle.input.buffer
        rng.uniform(0.1, 0.95, n_rows),     # shuffle.merge.percent
        rng.uniform(10, 10000, n_rows),     # inmem.merge.threshold
        rng.uniform(0.0, 0.8, n_rows),      # reduce.input.buffer
        rng.uniform(1, 100, n_rows),        # reduce.tasks
        rng.uniform(0.0, 1.0, n_rows),      # record% / slowstart
        rng.integers(0, 2, n_rows),         # compress / jvm (small)
        rng.integers(0, 2, n_rows),         # out compress / job.maps
    ]
    return np.stack(cols, axis=1).astype(np.float32)


def test_kernel_matches_ref_v1():
    p = random_params(256)
    w, c = workload_features(), cluster_features(1.0)
    got = np.asarray(costmodel.cost_pallas(p, w, c))
    want = np.asarray(ref.cost_ref(p, w, c))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-3)


def test_kernel_matches_ref_v2():
    p = random_params(256)
    p[:, 9] = RNG.uniform(1, 30, 256)   # jvm.numtasks
    p[:, 10] = RNG.uniform(2, 50, 256)  # job.maps
    w, c = workload_features(), cluster_features(0.0)
    got = np.asarray(costmodel.cost_pallas(p, w, c))
    want = np.asarray(ref.cost_ref(p, w, c))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-3)


@pytest.mark.parametrize("batch", [1, 7, 255, 256, 257, 1000])
def test_padding_handles_any_batch(batch):
    p = random_params(batch)
    w, c = workload_features(), cluster_features(1.0)
    got = np.asarray(costmodel.cost_pallas(p, w, c))
    assert got.shape == (batch,)
    want = np.asarray(ref.cost_ref(p, w, c))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-3)


@settings(max_examples=40, deadline=None)
@given(
    input_gb=st.floats(0.1, 128.0),
    sel_b=st.floats(0.01, 4.0),
    sel_r=st.floats(0.05, 16.0),
    comb=st.floats(0.05, 1.0),
    skew=st.floats(1.0, 5.0),
    map_ops=st.floats(10.0, 5000.0),
    red_ops=st.floats(10.0, 5000.0),
    cratio=st.floats(0.05, 1.0),
    is_v1=st.sampled_from([0.0, 1.0]),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref_hypothesis(
    input_gb, sel_b, sel_r, comb, skew, map_ops, red_ops, cratio, is_v1, seed
):
    rng = np.random.default_rng(seed)
    p = random_params(64, rng)
    w = workload_features(input_gb, sel_b, sel_r, comb, skew, map_ops,
                          red_ops, cratio)
    c = cluster_features(is_v1)
    got = np.asarray(costmodel.cost_pallas(p, w, c))
    want = np.asarray(ref.cost_ref(p, w, c))
    assert np.all(np.isfinite(got))
    assert np.all(got > 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_costs_positive_and_reducers_matter():
    # heavy-shuffle workload: the 1-reducer default must be far more
    # expensive than ~90 reducers (the headline mechanism).
    w, c = workload_features(input_gb=30.0), cluster_features(1.0)
    base = random_params(2)
    base[0, 7] = 1.0
    base[1, 7] = 90.0
    got = np.asarray(costmodel.cost_pallas(base, w, c))
    assert got[0] > 2.0 * got[1], got
