"""L2 tests: μ mapping semantics and the surrogate-SPSA step graph."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from .test_kernel import cluster_features, workload_features

# ParameterSpace v1 spec (min, width, is_int, is_bool) — mirrors
# rust/src/config/space.rs.
V1_SPEC = np.array(
    [
        # io.sort.mb, spill%, sort.factor, shuf.in%, shuf.merge%,
        # inmem.thresh, red.in%, reducers, record%, compress, out.compress
        [50, 0.05, 5, 0.1, 0.1, 10, 0.0, 1, 0.01, 0, 0],            # mins
        [1950, 0.90, 495, 0.85, 0.85, 9990, 0.8, 99, 0.49, 1, 1],   # widths
        [1, 0, 1, 0, 0, 1, 0, 1, 0, 0, 0],                          # is_int
        [0, 0, 0, 0, 0, 0, 0, 0, 0, 1, 1],                          # is_bool
    ],
    dtype=np.float32,
)


def test_mu_defaults():
    # default θ_A for v1 reproduces the Table-1 default values
    theta = np.array(
        [(100 - 50) / 1950, (0.08 - 0.05) / 0.9, (10 - 5) / 495,
         (0.7 - 0.1) / 0.85, (0.66 - 0.1) / 0.85, (1000 - 10) / 9990,
         0.0, 0.0, (0.05 - 0.01) / 0.49, 0.25, 0.25],
        dtype=np.float32,
    )
    v = np.asarray(model.mu(theta, V1_SPEC))
    assert v[0] == 100            # io.sort.mb
    assert abs(v[1] - 0.08) < 1e-6
    assert v[2] == 10             # sort.factor
    assert v[7] == 1              # reducers
    assert v[9] == 0 and v[10] == 0  # compression off


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0.0, 1.0), min_size=11, max_size=11))
def test_mu_in_range(theta):
    v = np.asarray(model.mu(np.array(theta, np.float32), V1_SPEC))
    mins, widths = V1_SPEC[0], V1_SPEC[1]
    assert np.all(v >= mins - 1e-5)
    assert np.all(v <= mins + widths + 1e-5)
    # integer params are integral
    for i in np.nonzero(V1_SPEC[2])[0]:
        assert v[i] == np.floor(v[i])
    # booleans are 0/1
    for i in np.nonzero(V1_SPEC[3])[0]:
        assert v[i] in (0.0, 1.0)


def spsa_inputs(seed=0):
    rng = np.random.default_rng(seed)
    theta = rng.uniform(0, 1, model.N).astype(np.float32)
    signs = rng.choice([-1.0, 1.0],
                       (model.N_PERTURBATIONS, model.N)).astype(np.float32)
    c = np.full(model.N, 0.05, np.float32)
    hyper = np.array([0.01, 0.15], np.float32)
    return theta, signs, c, workload_features(), cluster_features(1.0), \
        V1_SPEC, hyper


def unpack(out):
    out = np.asarray(out[0])
    n = model.N
    return out[:n], out[n], out[n + 1:]


def test_spsa_step_shapes_and_box():
    (out,) = (model.spsa_step(*spsa_inputs()),)
    theta_next, f0, ghat = unpack(out)
    assert theta_next.shape == (model.N,)
    assert ghat.shape == (model.N,)
    assert np.isfinite(f0) and f0 > 0
    assert np.all(theta_next >= 0.0) and np.all(theta_next <= 1.0)


def test_spsa_step_respects_max_step():
    theta, signs, c, w, cl, spec, _ = spsa_inputs(3)
    hyper = np.array([100.0, 0.05], np.float32)  # huge alpha, small clip
    (out,) = (model.spsa_step(theta, signs, c, w, cl, spec, hyper),)
    theta_next, _, _ = unpack(out)
    moved = np.abs(theta_next - np.clip(theta, 0, 1))
    assert np.all(moved <= 0.05 + 1e-6)


def test_spsa_step_descends_on_average():
    # Iterating the surrogate step from the default must reduce model cost.
    theta = np.array(
        [(100 - 50) / 1950, (0.08 - 0.05) / 0.9, (10 - 5) / 495,
         (0.7 - 0.1) / 0.85, (0.66 - 0.1) / 0.85, (1000 - 10) / 9990,
         0.0, 0.0, (0.05 - 0.01) / 0.49, 0.25, 0.25],
        dtype=np.float32,
    )
    rng = np.random.default_rng(7)
    _, _, c, w, cl, spec, hyper = spsa_inputs()
    f_first = None
    f_last = None
    for _ in range(40):
        signs = rng.choice(
            [-1.0, 1.0], (model.N_PERTURBATIONS, model.N)).astype(np.float32)
        (out,) = (model.spsa_step(theta, signs, c, w, cl, spec, hyper),)
        theta, f0, _ = unpack(out)
        if f_first is None:
            f_first = float(f0)
        f_last = float(f0)
    assert f_last < 0.7 * f_first, (f_first, f_last)
