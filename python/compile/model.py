"""L2 — JAX compute graphs built on the L1 Pallas cost-model kernel.

Two exported computations (AOT-lowered by `aot.py`, executed from rust via
PJRT — Python never runs on the request path):

* ``whatif_batch`` — what-if engine: [B, 11] Hadoop-space parameter rows →
  [B] predicted job times. Powers the Starfish-style CBO's RRS rounds.
* ``spsa_step`` — one surrogate-SPSA iteration evaluated entirely on the
  model: maps θ_A through μ, prices θ and K simultaneous perturbations with
  one batched kernel call, and returns the averaged gradient estimate plus
  the updated, projected θ (packed flat for a stable rust ABI).

Feature layouts are shared with rust (see `kernels/ref.py` docstring).
"""

import jax
import jax.numpy as jnp

from .kernels import costmodel, ref

# Shapes baked into the AOT artifacts (rust pads to these).
BATCH = 256
N_PERTURBATIONS = 8
N = ref.N_PARAMS

# Order of the workload feature vector (documentation of the ABI; the rust
# side builds it in WorkloadProfile::to_features).
WORKLOAD_FEATURES = (
    "input_bytes", "avg_input_record_bytes", "map_selectivity_bytes",
    "map_selectivity_records", "avg_map_record_bytes", "combiner_reduction",
    "reduce_selectivity_bytes", "partition_skew", "compress_ratio",
    "map_cpu_ops_per_record", "reduce_cpu_ops_per_record",
)


def whatif_batch(params, workload, cluster):
    """[B, 11] Hadoop rows → [B] seconds, through the Pallas kernel."""
    return (costmodel.cost_pallas(params, workload, cluster),)


def mu(theta, space_spec):
    """The paper's §5.1 mapping μ: θ_A ∈ [0,1]^n → Hadoop values.

    ``space_spec`` is a [4, n] matrix of rows (min, width, is_int, is_bool).
    Integer parameters are floored; booleans thresholded at 0.5.
    """
    mins, widths, is_int, is_bool = (space_spec[i] for i in range(4))
    t = jnp.clip(theta, 0.0, 1.0)
    v = mins + widths * t
    v = jnp.where(is_int > 0.5, jnp.floor(v), v)
    v = jnp.where(is_bool > 0.5, (t >= 0.5).astype(jnp.float32), v)
    return v


def spsa_step(theta, signs, c_scales, workload, cluster, space_spec, hyper):
    """One surrogate-SPSA iteration on the analytic model.

    Args:
      theta:      [n]   current iterate in [0,1]^n.
      signs:      [K,n] Rademacher ±1 perturbation directions.
      c_scales:   [n]   per-coordinate perturbation magnitudes c(i).
      workload:   [11]  workload features.
      cluster:    [10]  cluster features.
      space_spec: [4,n] μ-mapping spec (min, width, is_int, is_bool).
      hyper:      [2]   (alpha, max_step).

    Returns:
      One flat [2n+1] vector: (θ_next[n], f(θ)[1], ĝ[n]).
    """
    theta = jnp.clip(jnp.asarray(theta, jnp.float32), 0.0, 1.0)
    signs = jnp.asarray(signs, jnp.float32)
    alpha, max_step = hyper[0], hyper[1]

    # candidate points: θ plus K perturbations, padded to the kernel batch
    pert = jnp.clip(theta[None, :] + signs * c_scales[None, :], 0.0, 1.0)
    points = jnp.concatenate([theta[None, :], pert], axis=0)  # [K+1, n]
    rows = jax.vmap(lambda t: mu(t, space_spec))(points)
    costs = costmodel.cost_pallas(rows, workload, cluster)  # [K+1]

    f0 = costs[0]
    df = (costs[1:] - f0) / jnp.maximum(f0, 1e-9)  # [K], normalized
    # ĝ(i) = mean_k df_k / (s_ki · c_i)
    ghat = jnp.mean(df[:, None] / (signs * c_scales[None, :]), axis=0)

    step = jnp.clip(alpha * ghat, -max_step, max_step)
    theta_next = jnp.clip(theta - step, 0.0, 1.0)
    return (jnp.concatenate([theta_next, f0[None], ghat]),)


def example_args_whatif():
    """Example shapes for AOT lowering of whatif_batch."""
    return (
        jax.ShapeDtypeStruct((BATCH, N), jnp.float32),
        jax.ShapeDtypeStruct((ref.N_WORKLOAD_FEATURES,), jnp.float32),
        jax.ShapeDtypeStruct((ref.N_CLUSTER_FEATURES,), jnp.float32),
    )


def example_args_spsa():
    """Example shapes for AOT lowering of spsa_step."""
    return (
        jax.ShapeDtypeStruct((N,), jnp.float32),
        jax.ShapeDtypeStruct((N_PERTURBATIONS, N), jnp.float32),
        jax.ShapeDtypeStruct((N,), jnp.float32),
        jax.ShapeDtypeStruct((ref.N_WORKLOAD_FEATURES,), jnp.float32),
        jax.ShapeDtypeStruct((ref.N_CLUSTER_FEATURES,), jnp.float32),
        jax.ShapeDtypeStruct((4, N), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.float32),
    )
