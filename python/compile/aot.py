"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and gen_hlo.py there).

Run via ``make artifacts``:
    cd python && python -m compile.aot --out-dir ../artifacts

Outputs:
    artifacts/whatif_batch.hlo.txt  — [256,11]×[11]×[10] → ([256],)
    artifacts/spsa_step.hlo.txt     — surrogate-SPSA iteration → ([23],)
    artifacts/meta.json             — shape/ABI metadata for the rust loader
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    """Lower both exported computations; returns {name: hlo_text}."""
    out = {}
    out["whatif_batch"] = to_hlo_text(
        jax.jit(model.whatif_batch).lower(*model.example_args_whatif())
    )
    out["spsa_step"] = to_hlo_text(
        jax.jit(model.spsa_step).lower(*model.example_args_spsa())
    )
    return out


def metadata() -> dict:
    return {
        "batch": model.BATCH,
        "n_params": model.N,
        "n_perturbations": model.N_PERTURBATIONS,
        "n_workload_features": len(model.WORKLOAD_FEATURES),
        "n_cluster_features": 10,
        "workload_features": list(model.WORKLOAD_FEATURES),
        "spsa_step_output_len": 2 * model.N + 1,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, text in lower_all().items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(metadata(), f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
