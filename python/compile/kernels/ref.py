"""Pure-jnp reference implementation of the analytic MapReduce cost model.

This is the correctness oracle for the Pallas kernel
(`costmodel.py`) and the term-for-term mirror of the rust reference
(`rust/src/whatif/costmodel.rs`). All three implementations share:

* the parameter-row layout   (11 values, `ParameterSpace` order),
* the workload-feature layout (11 values, `WorkloadProfile::to_features`),
* the cluster-feature layout  (10 values, `ClusterFeatures::to_features`),
* the framework constants below.

Keep the math in the same order as the rust file — reviews diff them
side by side.
"""

import jax.numpy as jnp

# Framework constants (mirror rust/src/whatif/costmodel.rs).
JVM_START_S = 1.4
TASK_LAUNCH_S = 0.15
JOB_OVERHEAD_S = 8.0
SPILL_FILE_S = 0.006
FILE_OPEN_S = 0.003
SORT_OPS_PER_CMP = 12.0
COMBINE_OPS_PER_REC = 18.0
COMPRESS_OPS_PER_BYTE = 5.0
DECOMPRESS_OPS_PER_BYTE = 1.5
MERGE_OPS_PER_BYTE = 0.4
MERGE_STREAM_SWEET_SPOT = 48.0
MERGE_STREAM_PENALTY_DIV = 96.0
REDUCE_MEM_PRESSURE_COEFF = 0.6
FETCH_OVERLAP_EFF = 0.5

N_PARAMS = 11
N_WORKLOAD_FEATURES = 11
N_CLUSTER_FEATURES = 10


def cost_ref(params, workload, cluster):
    """Analytic job time for a batch of parameter rows.

    Args:
      params:   [B, 11] Hadoop-space parameter rows.
      workload: [11] workload features.
      cluster:  [10] cluster features.

    Returns:
      [B] predicted job execution time in seconds.
    """
    p = jnp.asarray(params, jnp.float32)
    w = jnp.asarray(workload, jnp.float32)
    c = jnp.asarray(cluster, jnp.float32)

    # ---- unpack parameter row (ParameterSpace order) ----------------------
    io_sort_mb = jnp.maximum(p[:, 0], 1.0)
    spill_pct = jnp.clip(p[:, 1], 0.01, 0.99)
    sort_factor = jnp.maximum(p[:, 2], 2.0)
    shuf_in_pct = jnp.clip(p[:, 3], 0.01, 0.99)
    shuf_merge_pct = jnp.clip(p[:, 4], 0.01, 0.99)
    inmem_thresh = jnp.maximum(p[:, 5], 2.0)
    red_in_pct = jnp.clip(p[:, 6], 0.0, 0.9)
    n_red = jnp.maximum(p[:, 7], 1.0)

    # ---- unpack workload / cluster features ---------------------------------
    (w_input, w_avg_in_rec, w_sel_b, w_sel_r, w_avg_map_rec, w_comb_red,
     w_red_sel, w_skew, w_cratio, w_map_ops, w_red_ops) = [w[i] for i in range(11)]
    (c_workers, c_mspn, c_rspn, c_disk, c_net, c_cpu, c_block, c_heap,
     c_repl, is_v1) = [c[i] for i in range(10)]

    # version-dependent parameter tail
    rec_pct = is_v1 * jnp.clip(p[:, 8], 0.01, 0.5) + (1.0 - is_v1) * 0.05
    compress_map = is_v1 * (p[:, 9] > 0.5).astype(jnp.float32)
    out_compress = is_v1 * (p[:, 10] > 0.5).astype(jnp.float32)
    slowstart = is_v1 * 0.05 + (1.0 - is_v1) * jnp.clip(p[:, 8], 0.0, 1.0)
    jvm_reuse = is_v1 + (1.0 - is_v1) * jnp.maximum(p[:, 9], 1.0)
    job_maps = is_v1 * 2.0 + (1.0 - is_v1) * jnp.maximum(p[:, 10], 2.0)

    has_comb = (w_comb_red < 0.999).astype(jnp.float32)

    # ---- layout -------------------------------------------------------------
    n_maps_nat = jnp.maximum(w_input / c_block, 1.0)
    n_maps = is_v1 * n_maps_nat + (1.0 - is_v1) * jnp.maximum(n_maps_nat, job_maps)
    split = w_input / n_maps
    map_slots = c_workers * c_mspn
    red_slots = c_workers * c_rspn
    map_waves = jnp.maximum(n_maps / map_slots, 1.0)
    red_waves = jnp.maximum(n_red / red_slots, 1.0)

    # blind spot 1 (see rust/src/whatif/costmodel.rs): uncontended bandwidth
    mdisk = c_disk
    cpu = c_cpu
    rdisk = c_disk
    rnet = c_net
    _ = (c_mspn, c_rspn)  # used only for slot counts above

    # ---- map task -----------------------------------------------------------
    read = split / mdisk
    recs = split / w_avg_in_rec
    map_cpu = recs * w_map_ops / cpu
    out_b = split * w_sel_b
    out_r = recs * w_sel_r

    buf = io_sort_mb * float(1 << 20)
    data_frac = is_v1 * (1.0 - rec_pct) + (1.0 - is_v1) * 0.95
    data_cap = jnp.maximum(buf * data_frac * spill_pct, 1.0)
    rec_cap_total = is_v1 * (buf * rec_pct / 16.0) + (1.0 - is_v1) * (buf / 16.0)
    rec_cap = jnp.maximum(rec_cap_total * spill_pct, 1.0)
    n_spills = jnp.maximum(jnp.maximum(out_b / data_cap, out_r / rec_cap), 1.0)

    # blind spot 2: constant combiner ratio (no spill dilution)
    r_eff = 1.0 - has_comb * (1.0 - w_comb_red)
    sort_cpu = out_r * jnp.log2(jnp.maximum(out_r / n_spills, 2.0)) * SORT_OPS_PER_CMP / cpu
    comb_cpu = has_comb * out_r * COMBINE_OPS_PER_REC / cpu
    surv_b = out_b * r_eff
    disk_b = surv_b * (compress_map * w_cratio + (1.0 - compress_map))
    comp_cpu = compress_map * surv_b * COMPRESS_OPS_PER_BYTE / cpu
    spill_io = disk_b / mdisk + n_spills * SPILL_FILE_S
    spill_side = sort_cpu + comb_cpu + comp_cpu + spill_io
    # blind spot 5: perfect map/spill overlap
    phase = jnp.maximum(map_cpu, spill_side)

    merge_gate = jnp.clip((n_spills - 1.0) / 0.5, 0.0, 1.0)
    passes = jnp.maximum(jnp.log(n_spills) / jnp.log(sort_factor), 1.0)
    streams = jnp.minimum(sort_factor, n_spills)
    # blind spot 4: seek-free merges
    merge = merge_gate * (
        passes * disk_b * 2.0 / mdisk
        + passes * surv_b * MERGE_OPS_PER_BYTE / cpu
        + (n_spills + passes * streams) * FILE_OPEN_S
    )

    setup = (JVM_START_S + (jvm_reuse - 1.0) * TASK_LAUNCH_S) / jvm_reuse
    map_task = setup + read + phase + merge
    map_total = map_waves * map_task

    # ---- reduce task (critical path = hot partition) --------------------------
    tot_raw = n_maps * surv_b
    # blind spot 3: uniform partitions (skew ignored)
    _ = w_skew
    hot_vol = tot_raw / n_red

    wire = hot_vol * (compress_map * w_cratio + (1.0 - compress_map))
    fetch = wire / rnet + compress_map * wire * DECOMPRESS_OPS_PER_BYTE / cpu

    buffer = c_heap * shuf_in_pct
    byte_trig = jnp.maximum(buffer * shuf_merge_pct, 1.0)
    segs = n_maps
    avg_seg = hot_vol / segs
    fits = (jnp.maximum(jnp.sign(byte_trig - hot_vol), 0.0)
            * jnp.maximum(jnp.sign(inmem_thresh - segs), 0.0)
            * jnp.maximum(jnp.sign(buffer - hot_vol), 0.0))
    segs_per_flush = jnp.minimum(inmem_thresh,
                                 jnp.maximum(byte_trig / jnp.maximum(avg_seg, 1.0), 1.0))
    n_flush = (1.0 - fits) * jnp.maximum(segs / segs_per_flush, 1.0)
    retained = c_heap * red_in_pct
    disk_bytes = (1.0 - fits) * jnp.maximum(hot_vol - retained, 0.0)

    extra_passes = jnp.maximum(
        jnp.log(jnp.maximum(n_flush, 1.0)) / jnp.log(sort_factor), 1.0) - 1.0
    rstreams = jnp.minimum(sort_factor, jnp.maximum(n_flush, 1.0))
    merge_gate_r = jnp.clip(n_flush, 0.0, 1.0)
    # blind spot 4 again: seek-free reduce-side merges
    merge_r = merge_gate_r * (
        disk_bytes / rdisk
        + n_flush * SPILL_FILE_S
        + hot_vol * MERGE_OPS_PER_BYTE / cpu
        + extra_passes * disk_bytes * 2.0 / rdisk
        + (n_flush + extra_passes * rstreams) * FILE_OPEN_S
        + disk_bytes / rdisk
    )

    red_recs = hot_vol / jnp.maximum(w_avg_map_rec, 1.0)
    # blind spot 6: no memory-pressure penalty
    red_cpu = red_recs * w_red_ops / cpu

    out_raw = hot_vol * w_red_sel
    out_b2 = out_raw * (out_compress * w_cratio + (1.0 - out_compress))
    comp_cpu2 = out_compress * out_raw * COMPRESS_OPS_PER_BYTE / cpu
    write = jnp.maximum(out_b2 / rdisk, out_b2 * (c_repl - 1.0) / rnet) + comp_cpu2

    red_task = setup + fetch + merge_r + red_cpu + write

    credit = jnp.minimum((1.0 - slowstart) * map_total * FETCH_OVERLAP_EFF, fetch * 0.5)

    return JOB_OVERHEAD_S + map_total + red_waves * red_task - credit
