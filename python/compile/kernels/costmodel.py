"""L1 — Pallas kernel for the batched MapReduce cost model.

The compute hot-spot of the what-if engine: evaluate the analytic cost
model for a tile of candidate configurations at once. The kernel is tiled
over the batch dimension with `BlockSpec((TILE, N_PARAMS))`; workload and
cluster feature vectors are broadcast to every tile.

TPU mapping (DESIGN.md §Hardware-Adaptation): the model is elementwise
over configurations — on a real TPU each (TILE, 11) tile streams
HBM→VMEM and the VPU evaluates all phases in registers; there is no
matmul so the MXU is idle by design. VMEM footprint per tile:
TILE×(11+1)×4 B ≈ 12 KiB at TILE=256 — far under the ~16 MiB budget, so
the schedule is bandwidth-bound and TILE can grow to 64k if needed.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Batch tile evaluated per kernel invocation.
TILE = 256


def _cost_kernel(params_ref, workload_ref, cluster_ref, out_ref):
    """Kernel body: one (TILE, 11) tile of parameter rows → (TILE,) times.

    Independent implementation of the model (same constants as `ref`, but
    written kernel-style with boolean masks instead of sign arithmetic) so
    the pytest ref-vs-kernel comparison is meaningful.
    """
    p = params_ref[...]
    w = workload_ref[...]
    c = cluster_ref[...]
    f32 = jnp.float32

    # parameter row
    io_sort_mb = jnp.maximum(p[:, 0], 1.0)
    spill_pct = jnp.clip(p[:, 1], 0.01, 0.99)
    sort_factor = jnp.maximum(p[:, 2], 2.0)
    shuf_in_pct = jnp.clip(p[:, 3], 0.01, 0.99)
    shuf_merge_pct = jnp.clip(p[:, 4], 0.01, 0.99)
    inmem_thresh = jnp.maximum(p[:, 5], 2.0)
    red_in_pct = jnp.clip(p[:, 6], 0.0, 0.9)
    n_red = jnp.maximum(p[:, 7], 1.0)

    w_input, w_avg_in_rec, w_sel_b, w_sel_r = w[0], w[1], w[2], w[3]
    w_avg_map_rec, w_comb_red, w_red_sel, w_skew = w[4], w[5], w[6], w[7]
    w_cratio, w_map_ops, w_red_ops = w[8], w[9], w[10]
    c_workers, c_mspn, c_rspn, c_disk, c_net = c[0], c[1], c[2], c[3], c[4]
    c_cpu, c_block, c_heap, c_repl, is_v1 = c[5], c[6], c[7], c[8], c[9]
    v1 = is_v1 > 0.5

    rec_pct = jnp.where(v1, jnp.clip(p[:, 8], 0.01, 0.5), 0.05)
    compress_map = jnp.where(v1, (p[:, 9] > 0.5).astype(f32), 0.0)
    out_compress = jnp.where(v1, (p[:, 10] > 0.5).astype(f32), 0.0)
    slowstart = jnp.where(v1, 0.05, jnp.clip(p[:, 8], 0.0, 1.0))
    jvm_reuse = jnp.where(v1, 1.0, jnp.maximum(p[:, 9], 1.0))
    job_maps = jnp.where(v1, 2.0, jnp.maximum(p[:, 10], 2.0))

    has_comb = (w_comb_red < 0.999).astype(f32)

    # layout
    n_maps_nat = jnp.maximum(w_input / c_block, 1.0)
    n_maps = jnp.where(v1, n_maps_nat, jnp.maximum(n_maps_nat, job_maps))
    split = w_input / n_maps
    map_waves = jnp.maximum(n_maps / (c_workers * c_mspn), 1.0)
    red_waves = jnp.maximum(n_red / (c_workers * c_rspn), 1.0)
    # blind spot 1: uncontended bandwidth (see ref.py / rust model)
    mdisk = c_disk
    rdisk = c_disk
    rnet = c_net
    cpu = c_cpu

    # map task
    read = split / mdisk
    recs = split / w_avg_in_rec
    map_cpu = recs * w_map_ops / cpu
    out_b = split * w_sel_b
    out_r = recs * w_sel_r

    buf = io_sort_mb * f32(1 << 20)
    data_frac = jnp.where(v1, 1.0 - rec_pct, 0.95)
    data_cap = jnp.maximum(buf * data_frac * spill_pct, 1.0)
    rec_cap_total = jnp.where(v1, buf * rec_pct / 16.0, buf / 16.0)
    rec_cap = jnp.maximum(rec_cap_total * spill_pct, 1.0)
    n_spills = jnp.maximum(jnp.maximum(out_b / data_cap, out_r / rec_cap), 1.0)

    # blind spot 2: constant combiner ratio
    r_eff = 1.0 - has_comb * (1.0 - w_comb_red)
    sort_cpu = out_r * jnp.log2(jnp.maximum(out_r / n_spills, 2.0)) \
        * ref.SORT_OPS_PER_CMP / cpu
    comb_cpu = has_comb * out_r * ref.COMBINE_OPS_PER_REC / cpu
    surv_b = out_b * r_eff
    disk_b = jnp.where(compress_map > 0.5, surv_b * w_cratio, surv_b)
    comp_cpu = compress_map * surv_b * ref.COMPRESS_OPS_PER_BYTE / cpu
    spill_io = disk_b / mdisk + n_spills * ref.SPILL_FILE_S
    spill_side = sort_cpu + comb_cpu + comp_cpu + spill_io
    # blind spot 5: perfect map/spill overlap
    phase = jnp.maximum(map_cpu, spill_side)

    merge_gate = jnp.clip((n_spills - 1.0) / 0.5, 0.0, 1.0)
    passes = jnp.maximum(jnp.log(n_spills) / jnp.log(sort_factor), 1.0)
    streams = jnp.minimum(sort_factor, n_spills)
    # blind spot 4: seek-free merges
    merge = merge_gate * (passes * disk_b * 2.0 / mdisk
                          + passes * surv_b * ref.MERGE_OPS_PER_BYTE / cpu
                          + (n_spills + passes * streams) * ref.FILE_OPEN_S)

    setup = (ref.JVM_START_S + (jvm_reuse - 1.0) * ref.TASK_LAUNCH_S) / jvm_reuse
    map_total = map_waves * (setup + read + phase + merge)

    # reduce task
    tot_raw = n_maps * surv_b
    # blind spot 3: uniform partitions
    _ = w_skew
    hot_vol = tot_raw / n_red

    wire = jnp.where(compress_map > 0.5, hot_vol * w_cratio, hot_vol)
    fetch = wire / rnet + compress_map * wire * ref.DECOMPRESS_OPS_PER_BYTE / cpu

    buffer = c_heap * shuf_in_pct
    byte_trig = jnp.maximum(buffer * shuf_merge_pct, 1.0)
    segs = n_maps
    avg_seg = hot_vol / segs
    fits = ((byte_trig >= hot_vol) & (inmem_thresh >= segs)
            & (buffer >= hot_vol)).astype(f32)
    segs_per_flush = jnp.minimum(
        inmem_thresh, jnp.maximum(byte_trig / jnp.maximum(avg_seg, 1.0), 1.0))
    n_flush = (1.0 - fits) * jnp.maximum(segs / segs_per_flush, 1.0)
    retained = c_heap * red_in_pct
    disk_bytes = (1.0 - fits) * jnp.maximum(hot_vol - retained, 0.0)

    extra_passes = jnp.maximum(
        jnp.log(jnp.maximum(n_flush, 1.0)) / jnp.log(sort_factor), 1.0) - 1.0
    rstreams = jnp.minimum(sort_factor, jnp.maximum(n_flush, 1.0))
    # blind spot 4 again: seek-free reduce-side merges
    merge_r = jnp.clip(n_flush, 0.0, 1.0) * (
        disk_bytes / rdisk
        + n_flush * ref.SPILL_FILE_S
        + hot_vol * ref.MERGE_OPS_PER_BYTE / cpu
        + extra_passes * disk_bytes * 2.0 / rdisk
        + (n_flush + extra_passes * rstreams) * ref.FILE_OPEN_S
        + disk_bytes / rdisk)

    red_recs = hot_vol / jnp.maximum(w_avg_map_rec, 1.0)
    # blind spot 6: no memory-pressure penalty
    red_cpu = red_recs * w_red_ops / cpu

    out_raw = hot_vol * w_red_sel
    out_b2 = jnp.where(out_compress > 0.5, out_raw * w_cratio, out_raw)
    comp_cpu2 = out_compress * out_raw * ref.COMPRESS_OPS_PER_BYTE / cpu
    write = jnp.maximum(out_b2 / rdisk, out_b2 * (c_repl - 1.0) / rnet) + comp_cpu2

    red_task = setup + fetch + merge_r + red_cpu + write
    credit = jnp.minimum((1.0 - slowstart) * map_total * ref.FETCH_OVERLAP_EFF,
                         fetch * 0.5)

    out_ref[...] = ref.JOB_OVERHEAD_S + map_total + red_waves * red_task - credit


@functools.partial(jax.jit, static_argnames=("tile",))
def cost_pallas(params, workload, cluster, tile=TILE):
    """Batched cost model via the Pallas kernel.

    Pads the batch to a multiple of `tile`, runs the tiled kernel, and
    slices the padding off.
    """
    params = jnp.asarray(params, jnp.float32)
    workload = jnp.asarray(workload, jnp.float32)
    cluster = jnp.asarray(cluster, jnp.float32)
    b = params.shape[0]
    padded = (b + tile - 1) // tile * tile
    if padded != b:
        pad = jnp.tile(params[:1], (padded - b, 1))
        params = jnp.concatenate([params, pad], axis=0)
    out = pl.pallas_call(
        _cost_kernel,
        grid=(padded // tile,),
        in_specs=[
            pl.BlockSpec((tile, ref.N_PARAMS), lambda i: (i, 0)),
            pl.BlockSpec((ref.N_WORKLOAD_FEATURES,), lambda i: (0,)),
            pl.BlockSpec((ref.N_CLUSTER_FEATURES,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=True,
    )(params, workload, cluster)
    return out[:b]
