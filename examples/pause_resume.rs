//! Pause/resume (paper §6.8 point 3): SPSA "can be halted at any parameter
//! configuration (e.g., need for executing a production job on the cluster)
//! and later resumed at the same parameter configuration".
//!
//! Runs 10 iterations, checkpoints the tuner state to JSON, "hands the
//! cluster back" for a production job, restores the state from disk and
//! finishes — verifying the resumed trajectory equals an uninterrupted run.
//!
//! ```bash
//! cargo run --release --example pause_resume
//! ```

use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::ParameterSpace;
use hadoop_spsa::sim::{simulate, SimOptions};
use hadoop_spsa::tuner::{Objective, SimObjective, Spsa, SpsaConfig, SpsaState};
use hadoop_spsa::util::json::Json;
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::util::units::fmt_secs;
use hadoop_spsa::workloads::Benchmark;

fn main() {
    let space = ParameterSpace::v1();
    let cluster = ClusterSpec::paper_cluster();
    let mut rng = Rng::seeded(1000);
    let w = Benchmark::InvertedIndex.paper_profile(&mut rng);
    let spsa = Spsa::for_space(SpsaConfig { seed: 21, ..Default::default() }, &space);
    let ckpt_path = std::env::temp_dir().join("hadoop-spsa-checkpoint.json");

    // --- phase 1: 10 iterations, then pause -----------------------------
    let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 99);
    let st = spsa.run_paused(&mut obj, SpsaState::fresh(space.default_theta()), 10);
    std::fs::write(&ckpt_path, st.to_json().to_pretty()).expect("write checkpoint");
    println!(
        "paused after {} iterations ({} observations so far); checkpoint → {}",
        st.iter,
        obj.evals(),
        ckpt_path.display()
    );

    // --- the cluster runs a production job meanwhile ---------------------
    let prod = simulate(
        &cluster,
        &space.default_config(),
        &w,
        &SimOptions { seed: 1234, noise: true, ..Default::default() },
    );
    println!("(production job ran for {})", fmt_secs(prod.exec_time_s));

    // --- phase 2: restore from disk and finish ---------------------------
    let loaded =
        Json::parse(&std::fs::read_to_string(&ckpt_path).expect("read checkpoint"))
            .expect("parse checkpoint");
    let restored = SpsaState::from_json(&loaded).expect("decode checkpoint");
    assert_eq!(restored.iter, st.iter);
    assert_eq!(restored.theta, st.theta);
    let resumed = spsa.run_from(&mut obj, restored, None);
    println!(
        "resumed and finished at iteration {} (stop: {:?})",
        resumed.iterations, resumed.stop
    );

    // --- verify: identical to an uninterrupted run on a fresh objective ---
    // (the per-iteration perturbation sequence is derived from the iteration
    // index, so a noise-free objective replays exactly; with the live noisy
    // objective the observation counter shifts, so we verify on noise-free)
    let mut obj_a = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 99).noise_free();
    let straight = spsa.run(&mut obj_a, space.default_theta());
    let mut obj_b = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 99).noise_free();
    let part1 = spsa.run_paused(&mut obj_b, SpsaState::fresh(space.default_theta()), 10);
    let part2 = spsa.run_from(&mut obj_b, part1, None);
    let max_diff = straight
        .final_theta
        .iter()
        .zip(&part2.final_theta)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("paused-vs-straight trajectory max |Δθ| = {max_diff:.2e} (noise-free check)");
    assert!(max_diff < 1e-9, "resume diverged from uninterrupted run");
    println!("pause/resume OK");
}
