//! Single-job anatomy: run one simulated MapReduce job and print the full
//! trace — phase breakdown, counters, locality, waves — for the default and
//! a hand-tuned configuration side by side. Demonstrates the substrate the
//! tuners optimize against.
//!
//! ```bash
//! cargo run --release --example cluster_trace [-- terasort]
//! ```

use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::ParameterSpace;
use hadoop_spsa::sim::{simulate, SimOptions};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::util::units::fmt_bytes;
use hadoop_spsa::workloads::Benchmark;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "terasort".into());
    let bench = Benchmark::from_name(&name).unwrap_or(Benchmark::Terasort);
    let space = ParameterSpace::v1();
    let cluster = ClusterSpec::paper_cluster();

    let mut rng = Rng::seeded(1000);
    let w = bench.paper_profile(&mut rng);
    println!(
        "== {bench} on the simulated 25-node cluster ==\n\
         input {}  ({} map tasks of {} each)\n",
        fmt_bytes(w.input_bytes),
        w.input_bytes.div_ceil(128 << 20),
        fmt_bytes(128 << 20),
    );

    let opts = SimOptions { seed: 7, noise: true, ..Default::default() };

    println!("--- default configuration ---");
    let r = simulate(&cluster, &space.default_config(), &w, &opts);
    print!("{}", r.report());

    println!("\n--- hand-tuned configuration ---");
    let mut tuned = space.default_config();
    tuned.io_sort_mb = 512;
    tuned.spill_percent = 0.6;
    tuned.sort_record_percent = 0.15;
    tuned.sort_factor = 64;
    tuned.reduce_tasks = 48;
    tuned.shuffle_input_buffer_percent = 0.8;
    tuned.compress_map_output = true;
    let r2 = simulate(&cluster, &tuned, &w, &opts);
    print!("{}", r2.report());

    println!(
        "\nspeedup: {:.1}× ({:.0}% decrease)",
        r.exec_time_s / r2.exec_time_s,
        100.0 * (r.exec_time_s - r2.exec_time_s) / r.exec_time_s
    );
}
