//! The batched objective layer end to end: a Terasort SPSA trial whose
//! per-iteration observations (f(θ_n) + grad_avg perturbation probes) fan
//! out across worker threads, verified bit-identical to the sequential
//! path and timed against it.
//!
//! Worker count: `HSPSA_WORKERS` env var, else all-but-one core.
//!
//! ```bash
//! cargo run --release --example batched_tuning
//! HSPSA_WORKERS=2 cargo run --release --example batched_tuning
//! ```

use std::time::Instant;

use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::ParameterSpace;
use hadoop_spsa::coordinator::resolve_workers;
use hadoop_spsa::tuner::{SimObjective, Spsa, SpsaConfig, TuningResult};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::util::units::fmt_secs;
use hadoop_spsa::workloads::Benchmark;

fn main() {
    let space = ParameterSpace::v1();
    let cluster = ClusterSpec::paper_cluster();
    let mut rng = Rng::seeded(1000);
    let w = Benchmark::Terasort.paper_profile(&mut rng);
    let workers = resolve_workers(None);

    let trial = |workers: usize| -> (TuningResult, f64) {
        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 42)
            .with_workers(workers);
        let spsa = Spsa::for_space(
            SpsaConfig { max_iters: 30, grad_avg: 4, seed: 7, ..Default::default() },
            &space,
        );
        let t0 = Instant::now();
        let res = spsa.run(&mut obj, space.default_theta());
        (res, t0.elapsed().as_secs_f64())
    };

    println!("30-iteration Terasort SPSA trial, grad_avg=4 (5 observations/iter)\n");
    let (seq, t_seq) = trial(1);
    println!("sequential (1 worker):   {t_seq:.2}s wall, best f = {}", fmt_secs(seq.best_f));
    let (par, t_par) = trial(workers);
    println!(
        "batched ({workers} workers):     {t_par:.2}s wall, best f = {}",
        fmt_secs(par.best_f)
    );

    // observation seeds are assigned before dispatch, so the parallel
    // trajectory is bit-for-bit the sequential one — not merely close
    assert_eq!(seq.final_theta, par.final_theta, "trajectories diverged");
    assert_eq!(seq.best_f, par.best_f);
    assert_eq!(seq.iterations, par.iterations);
    println!(
        "\ntrajectories identical across worker counts; speedup {:.2}x",
        t_seq / t_par
    );
}
