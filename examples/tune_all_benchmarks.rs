//! End-to-end driver (DESIGN.md deliverable): sweep the ENTIRE tuner
//! registry over every benchmark under one identical observation budget,
//! then regenerate every paper table and figure on the full pipeline —
//! real benchmark executions feed workload profiles, all tuners spend the
//! same metered budget against the simulated 25-node cluster, results land
//! in `results/` as markdown + CSV, and the headline numbers are printed
//! next to the paper's.
//!
//! ```bash
//! cargo run --release --example tune_all_benchmarks            # full
//! cargo run --release --example tune_all_benchmarks -- --quick # smoke
//! ```
//!
//! This is the run recorded in EXPERIMENTS.md.

use hadoop_spsa::config::HadoopVersion;
use hadoop_spsa::coordinator::{
    run_campaign, Algo, CampaignScheduler, ResultsDir, SchedulerPolicy, TrialSpec,
};
use hadoop_spsa::experiments::{self, ExpOptions};
use hadoop_spsa::util::table::Table;
use hadoop_spsa::workloads::Benchmark;

/// Registry sweep: every algorithm (all ten entries) × every benchmark,
/// one shared budget. This is the comparison the `Tuner`/`EvalBroker`
/// refactor makes native: best-found vs identical observation spend, no
/// per-algorithm glue — RDSA, Nelder–Mead and TPE joined without touching
/// this loop.
fn registry_sweep(opts: &ExpOptions) {
    let budget = opts.budget();
    let seed = opts.seeds()[0];
    let all = Benchmark::all();
    let benches: &[Benchmark] = if opts.quick { &[Benchmark::Terasort] } else { &all };

    let mut specs = Vec::new();
    for &bench in benches {
        for algo in Algo::all() {
            // PPABS tunes the v2 space (as in Fig. 9 / Table 2)
            let version =
                if algo == Algo::Ppabs { HadoopVersion::V2 } else { HadoopVersion::V1 };
            specs.push(TrialSpec::new(bench, version, algo, seed).with_budget(budget));
        }
    }
    let outcomes = run_campaign(specs);

    let mut header = vec!["Benchmark".to_string()];
    for algo in Algo::all() {
        header.push(algo.label().to_string());
    }
    let mut table = Table::new(&format!(
        "Registry sweep — % decrease vs default at {} shared observations",
        budget.max_obs
    ))
    .header(header);
    for &bench in benches {
        let mut row = vec![bench.label().to_string()];
        for algo in Algo::all() {
            let o = outcomes
                .iter()
                .find(|o| o.spec.benchmark == bench && o.spec.algo == algo)
                .expect("campaign covers the full matrix");
            assert!(o.observations <= budget.max_obs, "{} overspent", algo.label());
            row.push(format!("{:.0}% ({} obs)", o.pct_decrease(), o.observations));
        }
        table.row(row);
    }
    print!("{}", table.to_ascii());
    opts.persist("registry_sweep", &table);
}

/// Scheduler sweep: the whole registry on Terasort under ONE shared
/// modeled wall-clock budget, once per allocation policy. `Equal` is the
/// time-to-best comparison (walltime experiment's frame); the
/// `SuccessiveHalving` run shows culled tuners' unspent clock being
/// reinvested in the survivors — the campaign-level answer to "which
/// tuner deserves the cluster for the next hour?".
fn scheduler_sweep(opts: &ExpOptions) {
    let seed = opts.seeds()[0];
    // ~40 000 modeled seconds of shared clock (≈ 11 cluster-hours) split
    // across the ten registry tuners; quick mode halves it
    let total = if opts.quick { 20_000.0 } else { 40_000.0 };
    for policy in [SchedulerPolicy::Equal, SchedulerPolicy::SuccessiveHalving] {
        let outs = CampaignScheduler::new(Benchmark::Terasort, HadoopVersion::V1, seed, total)
            .with_policy(policy)
            .run();
        let mut table = Table::new(&format!(
            "Scheduler sweep — {policy:?}, Terasort, {total:.0} s shared model clock"
        ))
        .header(vec![
            "Tuner",
            "Allocated (s)",
            "Spent (s)",
            "Obs",
            "Time to best (s)",
            "Best observed f (s)",
            "Culled at rung",
        ]);
        for o in &outs {
            table.row(vec![
                o.algo.label().to_string(),
                format!("{:.0}", o.allocated_s),
                format!("{:.0}", o.elapsed_s),
                o.observations.to_string(),
                if o.observations > 0 { format!("{:.0}", o.time_to_best) } else { "-".into() },
                if o.best_f.is_finite() { format!("{:.0}", o.best_f) } else { "-".into() },
                o.culled_at_rung.map(|r| r.to_string()).unwrap_or_else(|| "survived".into()),
            ]);
        }
        print!("{}", table.to_ascii());
        let name = match policy {
            SchedulerPolicy::Equal => "scheduler_sweep_equal",
            SchedulerPolicy::SuccessiveHalving => "scheduler_sweep_halving",
        };
        opts.persist(name, &table);
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let out = ResultsDir::default_dir().expect("cannot create results/");
    let opts = ExpOptions { quick, out: Some(out) };
    let t0 = std::time::Instant::now();

    println!("=== Registry sweep: all tuners, one budget ===\n");
    registry_sweep(&opts);

    println!("\n=== Scheduler sweep: all tuners, one shared wall-clock budget ===\n");
    scheduler_sweep(&opts);

    println!("\n=== Walltime: time-to-best across the registry ===\n");
    println!("{}", experiments::walltime::run(&opts));

    println!("\n=== Table 1: tuned parameter values ===\n");
    println!("{}", experiments::table1::run(&opts));

    println!("=== Fig 6: best-so-far convergence, all registry tuners (Hadoop v1) ===\n");
    println!("{}", experiments::convergence::run(HadoopVersion::V1, &opts));

    println!("=== Fig 7: best-so-far convergence, all registry tuners (Hadoop v2) ===\n");
    println!("{}", experiments::convergence::run(HadoopVersion::V2, &opts));

    println!("=== Fig 8: Default vs Starfish vs SPSA (Hadoop v1) ===\n");
    println!("{}", experiments::comparison::run(HadoopVersion::V1, &opts));

    println!("=== Fig 9: Default vs SPSA vs PPABS (Hadoop v2) ===\n");
    println!("{}", experiments::comparison::run(HadoopVersion::V2, &opts));

    println!("=== Table 2: method comparison + overheads ===\n");
    println!("{}", experiments::table2::run(&opts));

    println!("=== Headline ===\n");
    let (_, report) = experiments::headline::compute(&opts);
    println!("{report}");

    println!(
        "\nall experiments regenerated in {:.1?}; tables under results/",
        t0.elapsed()
    );
}
