//! End-to-end driver (DESIGN.md deliverable): regenerate *every* paper
//! table and figure on the full pipeline — real benchmark executions feed
//! workload profiles, SPSA and all baselines tune against the simulated
//! 25-node cluster, results land in `results/` as markdown + CSV, and the
//! headline numbers are printed next to the paper's.
//!
//! ```bash
//! cargo run --release --example tune_all_benchmarks            # full
//! cargo run --release --example tune_all_benchmarks -- --quick # smoke
//! ```
//!
//! This is the run recorded in EXPERIMENTS.md.

use hadoop_spsa::config::HadoopVersion;
use hadoop_spsa::coordinator::ResultsDir;
use hadoop_spsa::experiments::{self, ExpOptions};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let out = ResultsDir::default_dir().expect("cannot create results/");
    let opts = ExpOptions { quick, out: Some(out) };
    let t0 = std::time::Instant::now();

    println!("=== Table 1: tuned parameter values ===\n");
    println!("{}", experiments::table1::run(&opts));

    println!("=== Fig 6: SPSA convergence (Hadoop v1) ===\n");
    println!("{}", experiments::convergence::run(HadoopVersion::V1, &opts));

    println!("=== Fig 7: SPSA convergence (Hadoop v2) ===\n");
    println!("{}", experiments::convergence::run(HadoopVersion::V2, &opts));

    println!("=== Fig 8: Default vs Starfish vs SPSA (Hadoop v1) ===\n");
    println!("{}", experiments::comparison::run(HadoopVersion::V1, &opts));

    println!("=== Fig 9: Default vs SPSA vs PPABS (Hadoop v2) ===\n");
    println!("{}", experiments::comparison::run(HadoopVersion::V2, &opts));

    println!("=== Table 2: method comparison + overheads ===\n");
    println!("{}", experiments::table2::run(&opts));

    println!("=== Headline ===\n");
    let (_, report) = experiments::headline::compute(&opts);
    println!("{report}");

    println!(
        "\nall experiments regenerated in {:.1?}; tables under results/",
        t0.elapsed()
    );
}
