//! SPSA tuning on a misbehaving cluster: 5 % task failures, two slow nodes
//! and speculative execution on — the scenario engine end to end.
//!
//! The paper's §4.2 argument is that SPSA works *because* it tolerates
//! noisy observations; fault injection is a harsher noise source than task
//! jitter, so this demo tunes under it and checks the tuned configuration
//! still beats the defaults evaluated under the same faults. The tail-aware
//! p95 objective is shown next to the plain one: under re-execution tails
//! the two can deploy different configurations.
//!
//! ```bash
//! cargo run --release --example fault_tuning
//! ```

use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::ParameterSpace;
use hadoop_spsa::coordinator::evaluate_theta;
use hadoop_spsa::sim::ScenarioSpec;
use hadoop_spsa::tuner::{Objective, SimObjective, Spsa, SpsaConfig};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::util::units::fmt_secs;
use hadoop_spsa::workloads::Benchmark;

fn main() {
    let space = ParameterSpace::v1();
    let cluster = ClusterSpec::paper_cluster();
    let mut rng = Rng::seeded(1000);
    let w = Benchmark::Terasort.paper_profile(&mut rng);

    let scenario = ScenarioSpec::default()
        .with_failures(0.05)
        .with_max_attempts(8)
        .with_slow_node(2, 0.6)
        .with_slow_node(5, 0.7)
        .with_speculation(true);
    println!(
        "scenario: 5% task failures, workers 2 @0.6x and 5 @0.7x, speculation on\n"
    );

    let tune = |obj: &mut SimObjective| {
        let spsa = Spsa::for_space(
            SpsaConfig { max_iters: 15, seed: 7, ..Default::default() },
            &space,
        );
        spsa.run(obj, space.default_theta())
    };

    // SPSA observing the faulty system
    let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 42)
        .with_scenario(scenario.clone());
    let res = tune(&mut obj);
    println!(
        "faulty-system SPSA: {} iterations, {} observations",
        res.iterations, res.observations
    );

    // the same budget on the failure-free cluster, for reference
    let mut clean_obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 42);
    let clean = tune(&mut clean_obj);

    // tail-aware variant: each observation is the p95 of 5 runs
    let mut tail_obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 42)
        .with_scenario(scenario.clone())
        .tail_p95(5);
    let tail = tune(&mut tail_obj);
    println!("tail-aware (p95 of 5) SPSA: {} simulated runs\n", tail_obj.evals());

    // evaluate everything under the scenario the cluster actually runs
    let eval = |theta: &[f64], seed: u64| {
        evaluate_theta(&space, &cluster, &w, theta, 5, seed, &scenario)
    };
    let (f_default, _) = eval(&space.default_theta(), 0xFA);
    let (f_tuned, sd) = eval(&res.best_theta, 0xFA);
    let (f_clean, _) = eval(&clean.best_theta, 0xFA);
    let (f_tail, _) = eval(&tail.best_theta, 0xFA);

    println!("execution time under the faulty cluster (mean of 5 runs):");
    println!("  default config:           {}", fmt_secs(f_default));
    println!("  tuned on faulty system:   {} (±{:.0}s)", fmt_secs(f_tuned), sd);
    println!("  tuned on clean system:    {}", fmt_secs(f_clean));
    println!("  tuned with p95 objective: {}", fmt_secs(f_tail));
    println!(
        "\ndecrease vs default: {:.0}% (faulty-tuned), {:.0}% (p95-tuned)",
        100.0 * (f_default - f_tuned) / f_default,
        100.0 * (f_default - f_tail) / f_default,
    );

    assert!(
        f_tuned < f_default,
        "tuning under faults failed to beat the default ({f_tuned} vs {f_default})"
    );
    println!("\nOK: SPSA converged despite fault injection");
}
