//! The AOT-compiled JAX/Pallas cost model on the rust hot path:
//!
//! 1. load `artifacts/*.hlo.txt` through PJRT (`make artifacts` first);
//! 2. cross-check the artifact against the independent rust what-if model;
//! 3. run a Starfish-style RRS optimization with the *artifact* as the
//!    what-if engine;
//! 4. run surrogate-SPSA entirely inside the compiled graph
//!    (`spsa_step.hlo.txt`) and deploy its answer on the simulator.
//!
//! ```bash
//! make artifacts && cargo run --release --example whatif_engine
//! ```

use hadoop_spsa::baselines::{rrs, CostEvaluator, RrsConfig};
use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::{HadoopVersion, ParameterSpace};
use hadoop_spsa::runtime::{ArtifactSpsaStep, ArtifactWhatIf, Runtime, ARTIFACT_K};
use hadoop_spsa::sim::{simulate, SimOptions};
use hadoop_spsa::tuner::Spsa;
use hadoop_spsa::util::error::Result;
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::util::units::fmt_secs;
use hadoop_spsa::whatif::{cost_for_theta, ClusterFeatures};
use hadoop_spsa::workloads::Benchmark;

fn main() -> Result<()> {
    if !Runtime::artifacts_present("artifacts") {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let rt = Runtime::default_dir()?;
    println!("PJRT platform: {}\n", rt.platform());

    let space = ParameterSpace::v1();
    let cluster_spec = ClusterSpec::paper_cluster();
    let features = ClusterFeatures::from_spec(&cluster_spec, HadoopVersion::V1);
    let mut rng = Rng::seeded(5);
    let w = Benchmark::Terasort.profile_scaled(512 << 10, 30 << 30, &mut rng);

    // --- 2. artifact vs rust cross-check -----------------------------------
    let mut artifact = ArtifactWhatIf::new(&rt, space.clone(), &w, &features)?;
    let thetas: Vec<Vec<f64>> = (0..512).map(|_| space.sample_uniform(&mut rng)).collect();
    let got = artifact.eval_batch(&thetas);
    let mut worst = 0.0f64;
    for (t, a) in thetas.iter().zip(&got) {
        let r = cost_for_theta(&space, t, &w, &features);
        worst = worst.max(((a - r) / r.max(1.0)).abs());
    }
    println!("artifact vs rust what-if: 512 random configs, max rel err {worst:.2e}");

    // --- 3. Starfish CBO with the artifact as what-if engine ----------------
    let res = rrs(&mut artifact, &RrsConfig::default());
    let sim_opts = SimOptions { seed: 3, noise: false, ..Default::default() };
    let f_default =
        simulate(&cluster_spec, &space.default_config(), &w, &sim_opts).exec_time_s;
    let f_rrs =
        simulate(&cluster_spec, &space.materialize(&res.best_theta), &w, &sim_opts).exec_time_s;
    println!(
        "RRS over artifact: {} model evals → config scores {} on the simulator \
         (default {})",
        res.evals,
        fmt_secs(f_rrs),
        fmt_secs(f_default),
    );

    // --- 4. surrogate SPSA inside the compiled graph ------------------------
    let stepper = ArtifactSpsaStep::new(&rt, &space, &w, &features)?;
    let c_scales = Spsa::scales_for(&space);
    let mut theta = space.default_theta();
    let mut f_first = None;
    let mut f_last = 0.0;
    for _ in 0..60 {
        let signs: Vec<Vec<f64>> = (0..ARTIFACT_K)
            .map(|_| (0..space.dim()).map(|_| rng.rademacher()).collect())
            .collect();
        let out = stepper.step(&theta, &signs, &c_scales, 0.01, 0.15)?;
        theta = out.theta_next;
        f_first.get_or_insert(out.f_theta);
        f_last = out.f_theta;
    }
    let f_sim =
        simulate(&cluster_spec, &space.materialize(&theta), &w, &sim_opts).exec_time_s;
    println!(
        "surrogate SPSA (60 compiled steps, K={ARTIFACT_K}): model {} → {}; deployed \
         config scores {} on the simulator",
        fmt_secs(f_first.unwrap()),
        fmt_secs(f_last),
        fmt_secs(f_sim),
    );
    println!(
        "\n(the gap between model score and simulator score IS the paper's §3.1 \
         argument for tuning on the real system)"
    );
    Ok(())
}
