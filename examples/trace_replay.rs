//! Trace replay: the tuner as deployed infrastructure.
//!
//! A day-in-the-life workload trace — a mixed sequence of jobs drawn from
//! the five benchmark families at varying input sizes — is replayed through
//! the simulated cluster three ways:
//!
//!   1. every job runs with Hadoop defaults;
//!   2. one *global* SPSA configuration (tuned once on Terasort) is reused
//!      for everything — the "one size fits all" trap;
//!   3. each job family gets its own SPSA-tuned configuration (the paper's
//!      deployment model: tune per application on a partial workload, then
//!      reuse).
//!
//! Reported: per-family and total makespan. Demonstrates why §6.4's
//! per-application tuning matters beyond single-job numbers.
//!
//! ```bash
//! cargo run --release --example trace_replay
//! ```

use std::collections::HashMap;

use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::ParameterSpace;
use hadoop_spsa::sim::{simulate, SimOptions};
use hadoop_spsa::tuner::{SimObjective, Spsa, SpsaConfig};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::util::table::Table;
use hadoop_spsa::util::units::fmt_secs;
use hadoop_spsa::workloads::Benchmark;

fn main() {
    let space = ParameterSpace::v1();
    let cluster = ClusterSpec::paper_cluster();
    let mut rng = Rng::seeded(2026);

    // ---- build the trace: 30 jobs, mixed families and sizes --------------
    let mut trace = Vec::new();
    for i in 0..30u64 {
        let bench = *rng.choose(&Benchmark::all());
        // each job's size varies around the family's partial workload
        let scale = rng.range_f64(0.25, 1.5);
        let bytes = ((bench.paper_partial_bytes() as f64 * scale) as u64).max(64 << 20);
        trace.push((i, bench, bytes));
    }

    // ---- profile each family once (as the paper's coordinator would) ------
    let mut profiles = HashMap::new();
    for b in Benchmark::all() {
        profiles.insert(b, b.profile_scaled(1 << 20, b.paper_partial_bytes(), &mut rng));
    }

    // ---- tune: per-family SPSA + one global config -------------------------
    let tune = |bench: Benchmark, seed: u64, rng: &mut Rng| -> Vec<f64> {
        let _ = rng;
        let w = profiles[&bench].clone();
        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w, seed);
        let spsa = Spsa::for_space(SpsaConfig { seed, ..Default::default() }, &space);
        spsa.run(&mut obj, space.default_theta()).best_theta
    };
    let mut per_family = HashMap::new();
    for b in Benchmark::all() {
        per_family.insert(b, tune(b, 42, &mut rng));
    }
    let global = tune(Benchmark::Terasort, 42, &mut rng);

    // ---- replay -----------------------------------------------------------
    let replay = |theta_for: &dyn Fn(Benchmark) -> Vec<f64>| -> (f64, HashMap<Benchmark, f64>) {
        let mut total = 0.0;
        let mut by_family: HashMap<Benchmark, f64> = HashMap::new();
        for &(job_id, bench, bytes) in &trace {
            let mut w = profiles[&bench].clone();
            w.input_bytes = bytes;
            let cfg = space.materialize(&theta_for(bench));
            let r = simulate(
                &cluster,
                &cfg,
                &w,
                &SimOptions { seed: 0xBEEF ^ job_id, noise: true, ..Default::default() },
            );
            total += r.exec_time_s;
            *by_family.entry(bench).or_default() += r.exec_time_s;
        }
        (total, by_family)
    };

    let default_theta = space.default_theta();
    let (t_default, f_default) = replay(&|_| default_theta.clone());
    let (t_global, f_global) = replay(&|_| global.clone());
    let (t_tuned, f_tuned) = replay(&|b| per_family[&b].clone());

    // ---- report -------------------------------------------------------------
    let mut table = Table::new("trace replay — 30-job mixed trace, sequential makespan")
        .header(vec![
            "job family",
            "jobs",
            "default",
            "one global config",
            "per-family SPSA",
        ]);
    for b in Benchmark::all() {
        let n = trace.iter().filter(|(_, x, _)| *x == b).count();
        table.row(vec![
            b.label().to_string(),
            n.to_string(),
            fmt_secs(*f_default.get(&b).unwrap_or(&0.0)),
            fmt_secs(*f_global.get(&b).unwrap_or(&0.0)),
            fmt_secs(*f_tuned.get(&b).unwrap_or(&0.0)),
        ]);
    }
    table.row(vec![
        "TOTAL".to_string(),
        trace.len().to_string(),
        fmt_secs(t_default),
        fmt_secs(t_global),
        fmt_secs(t_tuned),
    ]);
    print!("{}", table.to_ascii());
    println!(
        "\nper-family tuning cuts the trace makespan by {:.0}% vs default \
         and {:.0}% vs a single global configuration",
        100.0 * (t_default - t_tuned) / t_default,
        100.0 * (t_global - t_tuned) / t_global.max(1e-9),
    );
    assert!(t_tuned < t_default, "tuned trace should beat defaults");
}
