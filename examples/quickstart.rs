//! Quickstart: tune one benchmark with SPSA and print before/after.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! What happens:
//! 1. The Bigram benchmark is *really executed* on a sampled synthetic
//!    corpus to measure its data-flow profile.
//! 2. SPSA (paper Algorithm 1) tunes the 11 Hadoop v1 parameters against
//!    the simulated 25-node cluster, two observations per iteration.
//! 3. The tuned configuration is evaluated and printed next to the
//!    defaults.

use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::ParameterSpace;
use hadoop_spsa::coordinator::evaluate_theta;
use hadoop_spsa::tuner::{SimObjective, Spsa, SpsaConfig};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::util::table::Table;
use hadoop_spsa::util::units::{fmt_bytes, fmt_secs};
use hadoop_spsa::workloads::Benchmark;

fn main() {
    let bench = Benchmark::Bigram;
    let space = ParameterSpace::v1();
    let cluster = ClusterSpec::paper_cluster();

    // 1. profile by running the real job on sampled data
    let mut rng = Rng::seeded(1000);
    let w = bench.paper_profile(&mut rng);
    println!(
        "profiled {bench}: {} input, map selectivity {:.2} bytes/byte, \
         combiner keeps {:.0}% of records\n",
        fmt_bytes(w.input_bytes),
        w.map_selectivity_bytes,
        100.0 * w.combiner_reduction
    );

    // 2. tune with SPSA from the default configuration
    let mut objective = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 42);
    let spsa = Spsa::for_space(SpsaConfig::default(), &space);
    let res = spsa.run(&mut objective, space.default_theta());
    println!(
        "SPSA: {} iterations, {} live observations, stop: {:?}",
        res.iterations, res.observations, res.stop
    );

    // 3. evaluate tuned vs default (on the benign, failure-free cluster)
    let benign = hadoop_spsa::sim::ScenarioSpec::default();
    let (f_default, _) =
        evaluate_theta(&space, &cluster, &w, &space.default_theta(), 5, 7, &benign);
    let (f_tuned, sd) = evaluate_theta(&space, &cluster, &w, &res.best_theta, 5, 7, &benign);
    println!(
        "\ndefault: {}   tuned: {} (±{:.0}s)   decrease: {:.0}%\n",
        fmt_secs(f_default),
        fmt_secs(f_tuned),
        sd,
        100.0 * (f_default - f_tuned) / f_default
    );

    let vals = space.to_hadoop_values(&res.best_theta);
    let mut t = Table::new("tuned parameters").header(vec!["parameter", "default", "tuned"]);
    for (i, p) in space.params().iter().enumerate() {
        t.row(vec![p.name.to_string(), p.default_value().display(), vals[i].display()]);
    }
    print!("{}", t.to_ascii());
}
