//! Perf bench: full SPSA tuning campaigns per second (30 iterations,
//! 2-3 observations each) and the profile-measurement path.
use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::ParameterSpace;
use hadoop_spsa::tuner::{SimObjective, Spsa, SpsaConfig};
use hadoop_spsa::util::bench::{black_box, quick};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::workloads::Benchmark;

fn main() {
    let space = ParameterSpace::v1();
    let cluster = ClusterSpec::paper_cluster();
    let mut rng = Rng::seeded(1000);
    let w = Benchmark::Terasort.paper_profile(&mut rng);

    let mut seed = 0u64;
    quick("spsa/30-iter campaign (terasort)", || {
        seed += 1;
        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), seed)
            .with_workers(1);
        let spsa = Spsa::for_space(SpsaConfig { seed, ..Default::default() }, &space);
        black_box(spsa.run(&mut obj, space.default_theta()));
    });

    // same campaign with per-iteration observations fanned across cores
    // (see perf_batch.rs for the dedicated speedup bench)
    let mut seed = 0u64;
    quick("spsa/30-iter campaign (batched objective)", || {
        seed += 1;
        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), seed);
        let spsa = Spsa::for_space(SpsaConfig { seed, ..Default::default() }, &space);
        black_box(spsa.run(&mut obj, space.default_theta()));
    });

    let mut s = 0u64;
    quick("profile/grep 2MB real execution", || {
        s += 1;
        let mut r = Rng::seeded(s);
        black_box(Benchmark::Grep.profile_scaled(2 << 20, 22 << 30, &mut r));
    });
}
