//! Bench: regenerate Fig 8 (Default vs Starfish vs SPSA, Hadoop v1).
use hadoop_spsa::config::HadoopVersion;
use hadoop_spsa::experiments::{comparison, ExpOptions};
use hadoop_spsa::util::bench::quick;

fn main() {
    let mut last = String::new();
    quick("fig8 campaign (quick)", || {
        last = comparison::run(HadoopVersion::V1, &ExpOptions::quick());
    });
    println!("\n{last}");
}
