//! Bench: regenerate the headline claim (66% vs default / 45% vs prior).
use hadoop_spsa::experiments::{headline, ExpOptions};
use hadoop_spsa::util::bench::bench;

fn main() {
    let mut last = String::new();
    bench("headline campaign (quick)", 0, 2, 0.0, || {
        let (_, report) = headline::compute(&ExpOptions::quick());
        last = report;
    });
    println!("\n{last}");
}
