//! Perf bench: the DES hot path — simulated job runs per second across
//! benchmark profiles and configurations. Target (DESIGN.md §8): ≥ 2,000
//! partial-workload runs/s.
use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::ParameterSpace;
use hadoop_spsa::sim::{simulate, SimOptions};
use hadoop_spsa::util::bench::{black_box, quick};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::workloads::Benchmark;

fn main() {
    let cluster = ClusterSpec::paper_cluster();
    let space = ParameterSpace::v1();
    let mut rng = Rng::seeded(1000);
    for bench in Benchmark::all() {
        let w = bench.paper_profile(&mut rng);
        let default = space.default_config();
        let mut seed = 0u64;
        quick(&format!("simulate/{}", bench.label()), || {
            seed += 1;
            black_box(simulate(
                &cluster,
                &default,
                &w,
                &SimOptions { seed, noise: true, ..Default::default() },
            ));
        });
    }
    // tuned configuration (more reducers = more events)
    let w = Benchmark::Terasort.paper_profile(&mut rng);
    let mut tuned = space.default_config();
    tuned.reduce_tasks = 95;
    tuned.io_sort_mb = 500;
    let mut seed = 0u64;
    quick("simulate/Terasort-95reducers", || {
        seed += 1;
        black_box(simulate(
            &cluster,
            &tuned,
            &w,
            &SimOptions { seed, noise: true, ..Default::default() },
        ));
    });
}
