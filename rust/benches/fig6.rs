//! Bench: regenerate Fig 6 (SPSA convergence, Hadoop v1) and time it.
use hadoop_spsa::config::HadoopVersion;
use hadoop_spsa::experiments::{convergence, ExpOptions};
use hadoop_spsa::util::bench::quick;

fn main() {
    let mut last = String::new();
    quick("fig6 campaign (quick)", || {
        last = convergence::run(HadoopVersion::V1, &ExpOptions::quick());
    });
    println!("\n{last}");
}
