//! Perf bench for the batched objective layer: one 30-iteration Terasort
//! SPSA trial with grad_avg=4 (1 + 4 observations per iteration), run
//! with a sequential objective vs. the parallel fan-out, plus the raw
//! `simulate_batch` path. On a ≥4-core machine the parallel trial should
//! be ≥2× faster wall-clock while producing the bit-identical trajectory
//! (seeds are assigned before dispatch).

use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::ParameterSpace;
use hadoop_spsa::coordinator::default_workers;
use hadoop_spsa::sim::{simulate_batch, SimJob, SimOptions};
use hadoop_spsa::tuner::{SimObjective, Spsa, SpsaConfig};
use hadoop_spsa::util::bench::{black_box, quick};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::workloads::Benchmark;

fn main() {
    let space = ParameterSpace::v1();
    let cluster = ClusterSpec::paper_cluster();
    let mut rng = Rng::seeded(1000);
    let w = Benchmark::Terasort.paper_profile(&mut rng);
    let workers = default_workers();
    println!("parallel worker count: {workers}\n");

    let trial = |workers: usize, seed: u64| {
        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), seed)
            .with_workers(workers);
        let spsa = Spsa::for_space(
            SpsaConfig { max_iters: 30, grad_avg: 4, seed, ..Default::default() },
            &space,
        );
        spsa.run(&mut obj, space.default_theta())
    };

    // sanity: identical trajectories before timing anything
    let a = trial(1, 7);
    let b = trial(workers, 7);
    assert_eq!(a.final_theta, b.final_theta, "parallel trajectory diverged");

    let mut seed = 0u64;
    let seq = quick("spsa/30-iter grad_avg=4 trial (1 worker)", || {
        seed += 1;
        black_box(trial(1, seed));
    });
    let mut seed = 0u64;
    let par = quick("spsa/30-iter grad_avg=4 trial (parallel)", || {
        seed += 1;
        black_box(trial(workers, seed));
    });
    println!(
        "\nintra-trial speedup: {:.2}x with {} workers",
        seq.mean_ns / par.mean_ns,
        workers
    );

    // raw batched-simulation path (campaign::evaluate_theta's substrate)
    let jobs = |n: u64| -> Vec<SimJob> {
        (0..n)
            .map(|i| SimJob {
                config: space.default_config(),
                opts: SimOptions { seed: i + 1, noise: true, ..Default::default() },
            })
            .collect()
    };
    let seq = quick("simulate_batch/8 runs (1 worker)", || {
        black_box(simulate_batch(&cluster, jobs(8), &w, 1));
    });
    let par = quick("simulate_batch/8 runs (parallel)", || {
        black_box(simulate_batch(&cluster, jobs(8), &w, workers));
    });
    println!(
        "\nsimulate_batch speedup: {:.2}x with {} workers",
        seq.mean_ns / par.mean_ns,
        workers
    );
}
