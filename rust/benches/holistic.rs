//! Bench: holistic (Hadoop+OS) tuning comparison (paper §7 future work).
use hadoop_spsa::experiments::{holistic, ExpOptions};
use hadoop_spsa::util::bench::bench;

fn main() {
    let mut last = String::new();
    bench("holistic campaign (quick)", 0, 2, 0.0, || {
        last = holistic::run(&ExpOptions::quick());
    });
    println!("\n{last}");
}
