//! Bench: regenerate Fig 7 (SPSA convergence, Hadoop v2) and time it.
use hadoop_spsa::config::HadoopVersion;
use hadoop_spsa::experiments::{convergence, ExpOptions};
use hadoop_spsa::util::bench::quick;

fn main() {
    let mut last = String::new();
    quick("fig7 campaign (quick)", || {
        last = convergence::run(HadoopVersion::V2, &ExpOptions::quick());
    });
    println!("\n{last}");
}
