//! Perf bench: the PJRT artifact hot path — batched what-if evaluations
//! per second (configs/s) and compiled surrogate-SPSA steps per second.
//! Target (DESIGN.md §8): ≥ 1e5 configs/s through the batch artifact.

// SKIP notice prints to stderr so piped bench output stays parseable
#![allow(clippy::print_stderr)]

use hadoop_spsa::baselines::CostEvaluator;
use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::{HadoopVersion, ParameterSpace};
use hadoop_spsa::runtime::{ArtifactSpsaStep, ArtifactWhatIf, Runtime, ARTIFACT_BATCH, ARTIFACT_K};
use hadoop_spsa::tuner::Spsa;
use hadoop_spsa::util::bench::{black_box, quick};
use hadoop_spsa::util::rng::Rng;
use hadoop_spsa::whatif::{cost_model_batch, ClusterFeatures};
use hadoop_spsa::workloads::Benchmark;

fn main() {
    if !Runtime::artifacts_present("artifacts") {
        eprintln!("SKIP perf_runtime: run `make artifacts` first");
        return;
    }
    let rt = Runtime::default_dir().expect("PJRT client");
    let space = ParameterSpace::v1();
    let features = ClusterFeatures::from_spec(&ClusterSpec::paper_cluster(), HadoopVersion::V1);
    let mut rng = Rng::seeded(9);
    let w = Benchmark::Terasort.profile_scaled(256 << 10, 30 << 30, &mut rng);

    let thetas: Vec<Vec<f64>> =
        (0..ARTIFACT_BATCH).map(|_| space.sample_uniform(&mut rng)).collect();
    let rows: Vec<Vec<f64>> = thetas
        .iter()
        .map(|t| space.to_hadoop_values(t).iter().map(|v| v.as_f64()).collect())
        .collect();

    let mut artifact = ArtifactWhatIf::new(&rt, space.clone(), &w, &features).unwrap();
    let r = quick("artifact whatif_batch (256 configs)", || {
        black_box(artifact.eval_batch(&thetas));
    });
    println!("  → {:.0} configs/s through PJRT", 256.0 * r.per_sec());

    let r2 = quick("rust whatif batch (256 configs)", || {
        black_box(cost_model_batch(&rows, &w, &features));
    });
    println!("  → {:.0} configs/s in pure rust", 256.0 * r2.per_sec());

    let stepper = ArtifactSpsaStep::new(&rt, &space, &w, &features).unwrap();
    let c = Spsa::scales_for(&space);
    let theta = space.default_theta();
    let signs: Vec<Vec<f64>> = (0..ARTIFACT_K)
        .map(|_| (0..space.dim()).map(|_| rng.rademacher()).collect())
        .collect();
    quick("artifact spsa_step (K=8)", || {
        black_box(stepper.step(&theta, &signs, &c, 0.01, 0.15).unwrap());
    });
}
