//! Bench: SPSA design-choice ablation table (DESIGN.md §5 extension).
use hadoop_spsa::experiments::{ablation, ExpOptions};
use hadoop_spsa::util::bench::bench;

fn main() {
    let mut last = String::new();
    bench("ablation campaign (quick)", 0, 2, 0.0, || {
        last = ablation::run(&ExpOptions::quick());
    });
    println!("\n{last}");
}
