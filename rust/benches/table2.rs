//! Bench: regenerate Table 2 (method comparison + measured overheads).
use hadoop_spsa::experiments::{table2, ExpOptions};
use hadoop_spsa::util::bench::quick;

fn main() {
    let mut last = String::new();
    quick("table2 campaign (quick)", || {
        last = table2::run(&ExpOptions::quick());
    });
    println!("\n{last}");
}
