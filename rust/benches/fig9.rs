//! Bench: regenerate Fig 9 (Default vs SPSA vs PPABS, Hadoop v2).
use hadoop_spsa::config::HadoopVersion;
use hadoop_spsa::experiments::{comparison, ExpOptions};
use hadoop_spsa::util::bench::quick;

fn main() {
    let mut last = String::new();
    quick("fig9 campaign (quick)", || {
        last = comparison::run(HadoopVersion::V2, &ExpOptions::quick());
    });
    println!("\n{last}");
}
