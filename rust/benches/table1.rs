//! Bench: regenerate paper Table 1 (quick mode) and time the campaign.
use hadoop_spsa::experiments::{table1, ExpOptions};
use hadoop_spsa::util::bench::quick;

fn main() {
    let mut last = String::new();
    quick("table1 campaign (quick)", || {
        last = table1::run(&ExpOptions::quick());
    });
    println!("\n{last}");
}
