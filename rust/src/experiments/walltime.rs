//! Wall-clock experiment: **time-to-best** across the whole registry —
//! the comparison frame of the successor literature (Tuneful, Fekry et
//! al. 2020; Bao et al. 2018), where the currency is modeled wall-clock,
//! not observations. The paper's economy claim (§6.6: SPSA needs 2–3
//! observations per iteration) is re-examined in the currency an operator
//! pays in: random search batches 64 probes into ONE parallel wave that
//! costs barely more clock than SPSA's 3-probe wave, so obs-frugality and
//! time-frugality rank the registry differently.
//!
//! Every tuner runs through a [`CampaignScheduler`] (policy `Equal`) with
//! the same per-tuner slice of modeled clock — sized in multiples of the
//! benchmark's own default-configuration duration, so the slice means the
//! same thing for a 100 s job and a 3000 s one. Outputs:
//!
//! * one dense CSV per registry tuner (`walltime_<name>`): rows are a
//!   uniform grid of modeled seconds, one column per benchmark with the
//!   best-so-far f at that time — blank before the tuner's first wave
//!   lands, forward-filled across cache hits, [`charge`] gaps and after
//!   the run stops (like the Fig-6/7 obs-indexed curves);
//! * `walltime_summary`: per tuner × benchmark, **obs-to-best AND
//!   time-to-best** next to the spend on both axes and the verified
//!   decrease vs default;
//! * `walltime_scheduler`: a `SuccessiveHalving` run on the first
//!   benchmark — allocations, cull rungs, and where the reclaimed time
//!   went;
//! * `walltime_brackets`: a `Hyperband` run over the checkpointable
//!   subset with **per-bracket columns** — each bracket's cumulative
//!   grant, charged model time, observations and standing per tuner
//!   (culled tuners re-enter the next bracket from their checkpoints);
//! * `walltime_bandit`: the UCB bandit's slice-by-slice grant sequence
//!   on the same subset.
//!
//! [`charge`]: crate::tuner::EvalBroker::charge

use crate::cluster::ClusterSpec;
use crate::config::HadoopVersion;
use crate::coordinator::{
    evaluate_theta, profile_for, Algo, CampaignScheduler, SchedulerOutcome, SchedulerPolicy,
};
use crate::sim::{simulate, ScenarioSpec, SimOptions};
use crate::tuner::{live_best, EvalRecord, DEFAULT_DISPATCH_OVERHEAD_S};
use crate::util::table::Table;
use crate::workloads::Benchmark;

use super::common::ExpOptions;

/// Rows of each dense per-tuner curve CSV.
const GRID_POINTS: usize = 120;

/// Per-tuner clock, in default-duration waves (one wave ≈ one
/// default-config run + dispatch overhead).
fn waves(opts: &ExpOptions) -> f64 {
    if opts.quick {
        12.0
    } else {
        32.0
    }
}

/// Noise-free default-config execution time of a benchmark — the unit
/// the time budgets are sized in (the scheduler tests reuse it so their
/// budgets and this experiment's can never drift apart).
pub(crate) fn calib_s(bench: Benchmark, version: HadoopVersion) -> f64 {
    let space = crate::config::ParameterSpace::for_version(version);
    let w = profile_for(bench, 1000);
    simulate(
        &ClusterSpec::paper_cluster(),
        &space.default_config(),
        &w,
        &SimOptions { seed: 1, noise: false, scenario: ScenarioSpec::default() },
    )
    .exec_time_s
}

/// Dense best-so-far series over a modeled-seconds `grid`: entry `k` is
/// the best f observed by time `grid[k]`. Times before the first record
/// stay +∞ (rendered blank); between and after records the previous best
/// carries forward — cache hits, charge gaps and post-stop times are all
/// forward-filled. Relies on the trace's `model_time` being
/// non-decreasing (batch members share their wave's completion time).
pub fn best_so_far_by_time(trace: &[EvalRecord], grid: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(grid.len());
    let (mut best, mut i) = (f64::INFINITY, 0);
    for &t in grid {
        while i < trace.len() && trace[i].model_time <= t {
            best = best.min(trace[i].f);
            i += 1;
        }
        out.push(best);
    }
    out
}

pub fn run(opts: &ExpOptions) -> String {
    let version = HadoopVersion::V1;
    let seed = opts.seeds()[0];
    let all = Benchmark::all();
    // quick mode keeps CI fast with a representative pair (like fig6/7)
    let benches: &[Benchmark] = if opts.quick { &all[..2] } else { &all };
    let n_tuners = Algo::all().len() as f64;

    // one Equal-policy scheduler per benchmark: the whole registry under
    // one shared clock, per-tuner slice = waves × (default duration + Δ)
    let mut campaigns: Vec<(Benchmark, f64, Vec<SchedulerOutcome>)> = Vec::new();
    for &bench in benches {
        let per_tuner = waves(opts) * (calib_s(bench, version) + DEFAULT_DISPATCH_OVERHEAD_S);
        let outs = CampaignScheduler::new(bench, version, seed, per_tuner * n_tuners).run();
        campaigns.push((bench, per_tuner, outs));
    }

    let mut report = format!(
        "== walltime — best-so-far vs modeled seconds, all registry tuners, Hadoop {} \
         ({} default-duration waves of clock per tuner) ==\n",
        version,
        waves(opts)
    );

    // per-tuner dense curve CSV: one column per benchmark, a shared
    // modeled-seconds axis spanning the largest per-benchmark slice
    let t_max = campaigns.iter().map(|(_, per, _)| *per).fold(0.0_f64, f64::max);
    let grid: Vec<f64> =
        (1..=GRID_POINTS).map(|k| t_max * k as f64 / GRID_POINTS as f64).collect();
    for (ai, algo) in Algo::all().into_iter().enumerate() {
        let curves: Vec<Vec<f64>> = campaigns
            .iter()
            .map(|(_, _, outs)| best_so_far_by_time(&outs[ai].trace, &grid))
            .collect();
        let mut table = Table::new(&format!(
            "walltime — {} best-so-far f (seconds) vs modeled seconds, Hadoop {}",
            algo.label(),
            version
        ))
        .header({
            let mut h = vec!["model_seconds".to_string()];
            h.extend(benches.iter().map(|b| b.label().to_string()));
            h
        });
        for (k, &t) in grid.iter().enumerate() {
            let mut row = vec![format!("{t:.1}")];
            for c in &curves {
                row.push(if c[k].is_finite() { format!("{:.3}", c[k]) } else { String::new() });
            }
            table.row(row);
        }
        opts.persist(&format!("walltime_{}", algo.name()), &table);
    }

    // summary: spend and first-hit on BOTH axes, plus verified quality.
    // "Best observed" counts every trace record (cache replays included);
    // the live columns restrict to ObsSource::Live — fresh measurements
    // under THIS run's noise stream — so a noise-frozen store/cache replay
    // can never masquerade as a verified result (satellite bugfix).
    let mut summary = Table::new(&format!(
        "walltime summary — obs-to-best and time-to-best per tuner, Hadoop {version}"
    ))
    .header(vec![
        "Tuner",
        "Benchmark",
        "Obs spent",
        "Model time spent (s)",
        "Obs to best",
        "Time to best (s)",
        "Best observed f (s)",
        "Obs to live best",
        "Live best f (s)",
        "Result vs default",
    ]);
    for (bench, _, outs) in &campaigns {
        let space = crate::config::ParameterSpace::for_version(version);
        let cluster = ClusterSpec::paper_cluster();
        let w = profile_for(*bench, 1000);
        let (default_mean, _) = evaluate_theta(
            &space,
            &cluster,
            &w,
            &space.default_theta(),
            5,
            seed ^ 0xE7A1,
            &ScenarioSpec::default(),
        );
        for o in outs {
            let (tuned_mean, _) = evaluate_theta(
                &space,
                &cluster,
                &w,
                &o.best_theta,
                5,
                seed ^ 0xE7A1,
                &ScenarioSpec::default(),
            );
            let live = live_best(&o.trace);
            summary.row(vec![
                o.algo.label().to_string(),
                bench.label().to_string(),
                o.observations.to_string(),
                format!("{:.0}", o.elapsed_s),
                if o.observations > 0 { o.obs_to_best.to_string() } else { "-".into() },
                if o.observations > 0 { format!("{:.0}", o.time_to_best) } else { "-".into() },
                if o.best_f.is_finite() { format!("{:.0}", o.best_f) } else { "-".into() },
                live.map(|r| r.obs.to_string()).unwrap_or_else(|| "-".into()),
                live.map(|r| format!("{:.0}", r.f)).unwrap_or_else(|| "-".into()),
                format!("-{:.0}%", 100.0 * (default_mean - tuned_mean) / default_mean),
            ]);
        }
    }
    report.push_str(&summary.to_ascii());
    opts.persist("walltime_summary", &summary);

    // SuccessiveHalving demonstration on the first benchmark: same total
    // clock, rung-by-rung culling with reinvested remainders
    let (bench0, per_tuner0, _) = &campaigns[0];
    let sha = CampaignScheduler::new(*bench0, version, seed, per_tuner0 * n_tuners)
        .with_policy(SchedulerPolicy::SuccessiveHalving)
        .run();
    let mut sha_table = Table::new(&format!(
        "walltime scheduler — SuccessiveHalving on {}, total clock {:.0} s",
        bench0.label(),
        per_tuner0 * n_tuners
    ))
    .header(vec![
        "Tuner",
        "Allocated (s)",
        "Spent (s)",
        "Charged (s)",
        "Obs",
        "Culled at rung",
        "Best observed f (s)",
    ]);
    for o in &sha {
        sha_table.row(vec![
            o.algo.label().to_string(),
            format!("{:.0}", o.allocated_s),
            format!("{:.0}", o.elapsed_s),
            format!("{:.0}", o.charged_s),
            o.observations.to_string(),
            o.culled_at_rung.map(|r| r.to_string()).unwrap_or_else(|| "survived".into()),
            if o.best_f.is_finite() { format!("{:.0}", o.best_f) } else { "-".into() },
        ]);
    }
    report.push('\n');
    report.push_str(&sha_table.to_ascii());
    opts.persist("walltime_scheduler", &sha_table);

    // Hyperband on the checkpointable subset: per-bracket columns — each
    // bracket's cumulative grant/charge/obs and standing per tuner, built
    // from the scheduler's allocation audit trail (culled tuners are
    // revived and *extended from their checkpoints* at the next bracket)
    let hb_algos = vec![Algo::Spsa, Algo::Random, Algo::NelderMead, Algo::Tpe];
    let hb = CampaignScheduler::new(*bench0, version, seed, per_tuner0 * hb_algos.len() as f64)
        .with_algos(hb_algos.clone())
        .with_policy(SchedulerPolicy::Hyperband);
    let (_, hb_events) = hb.run_with_events();
    let mut hb_table = Table::new(&format!(
        "walltime scheduler — Hyperband brackets on {}, total clock {:.0} s",
        bench0.label(),
        per_tuner0 * hb_algos.len() as f64
    ))
    .header(vec![
        "Bracket",
        "Tuner",
        "Allocated (s)",
        "Charged (s)",
        "Obs",
        "Best observed f (s)",
        "Standing",
    ]);
    let max_bracket = hb_events.iter().map(|e| e.bracket).max().unwrap_or(0);
    for bracket in 0..=max_bracket {
        for &algo in &hb_algos {
            // the tuner's last audit row of this bracket is its standing
            let Some(last) = hb_events
                .iter()
                .filter(|e| e.bracket == bracket && e.algo == algo)
                .next_back()
            else {
                continue;
            };
            hb_table.row(vec![
                bracket.to_string(),
                algo.label().to_string(),
                format!("{:.0}", last.allocated_s),
                format!("{:.0}", last.charged_s),
                last.observations.to_string(),
                if last.best_f.is_finite() { format!("{:.0}", last.best_f) } else { "-".into() },
                last.action.name().to_string(),
            ]);
        }
    }
    report.push('\n');
    report.push_str(&hb_table.to_ascii());
    opts.persist("walltime_brackets", &hb_table);

    // UCB bandit on the same subset: the slice-by-slice grant sequence
    let bd = CampaignScheduler::new(*bench0, version, seed, per_tuner0 * hb_algos.len() as f64)
        .with_algos(hb_algos.clone())
        .with_policy(SchedulerPolicy::Bandit);
    let (_, bd_events) = bd.run_with_events();
    let mut bd_table = Table::new(&format!(
        "walltime scheduler — UCB bandit slices on {}, total clock {:.0} s",
        bench0.label(),
        per_tuner0 * hb_algos.len() as f64
    ))
    .header(vec![
        "Slice",
        "Tuner",
        "Action",
        "Allocated (s)",
        "Charged (s)",
        "Obs",
        "Best observed f (s)",
    ]);
    for e in &bd_events {
        bd_table.row(vec![
            e.rung.to_string(),
            e.algo.label().to_string(),
            e.action.name().to_string(),
            format!("{:.0}", e.allocated_s),
            format!("{:.0}", e.charged_s),
            e.observations.to_string(),
            if e.best_f.is_finite() { format!("{:.0}", e.best_f) } else { "-".into() },
        ]);
    }
    report.push('\n');
    report.push_str(&bd_table.to_ascii());
    opts.persist("walltime_bandit", &bd_table);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ResultsDir;
    use crate::tuner::ObsSource;

    #[test]
    fn best_so_far_by_time_is_dense_and_forward_filled() {
        let rec = |obs: u64, t: f64, f: f64, cached: bool| EvalRecord {
            obs,
            model_time: t,
            theta: vec![0.5],
            f,
            cached,
            source: if cached { ObsSource::Memo } else { ObsSource::Live },
        };
        // a 2-point first wave landing at t=10, a cache hit at the same
        // elapsed time, then a charge gap until a wave at t=30
        let trace = vec![
            rec(2, 10.0, 12.0, false),
            rec(2, 10.0, 9.0, false),
            rec(2, 10.0, 11.0, true),
            rec(7, 30.0, 8.0, false),
        ];
        let grid = vec![5.0, 10.0, 20.0, 30.0, 40.0];
        let c = best_so_far_by_time(&trace, &grid);
        assert!(c[0].is_infinite(), "before the first wave lands: blank");
        assert_eq!(&c[1..], &[9.0, 9.0, 8.0, 8.0], "forward-filled between/after waves");
        assert!(best_so_far_by_time(&[], &grid).iter().all(|v| v.is_infinite()));
    }

    #[test]
    fn best_so_far_by_time_shrugs_off_nan_and_inf_observations() {
        // `f64::min` keeps the non-NaN operand: a NaN observation (e.g. a
        // poisoned score) must not stick as the best or blank the curve.
        let rec = |t: f64, f: f64| EvalRecord {
            obs: 1,
            model_time: t,
            theta: vec![0.5],
            f,
            cached: false,
            source: ObsSource::Live,
        };
        let trace =
            vec![rec(10.0, f64::NAN), rec(20.0, 9.0), rec(30.0, f64::NAN), rec(40.0, 7.0)];
        let c = best_so_far_by_time(&trace, &[10.0, 20.0, 30.0, 40.0]);
        assert!(c[0].is_infinite() && !c[0].is_nan(), "NaN-only prefix stays +inf");
        assert_eq!(&c[1..], &[9.0, 9.0, 7.0]);
    }

    #[test]
    fn walltime_quick_emits_a_curve_per_registry_tuner_and_a_two_axis_summary() {
        let dir = std::env::temp_dir().join(format!("hspsa-walltime-{}", std::process::id()));
        let opts = ExpOptions {
            quick: true,
            out: Some(ResultsDir::new(&dir).expect("results dir")),
        };
        let report = run(&opts);

        for algo in Algo::all() {
            let path = dir.join(format!("walltime_{}.csv", algo.name()));
            assert!(path.exists(), "missing walltime CSV for {}", algo.label());
            let csv = std::fs::read_to_string(&path).unwrap();
            assert_eq!(
                csv.lines().count(),
                GRID_POINTS + 1,
                "{} curve is not dense",
                algo.label()
            );
        }
        let summary = std::fs::read_to_string(dir.join("walltime_summary.csv")).unwrap();
        assert!(summary.contains("Obs to best"), "summary lost the obs-to-best column");
        assert!(summary.contains("Time to best"), "summary lost the time-to-best column");
        // regression (noise-frozen bugfix): the live-verified best must be
        // reported alongside the raw best-observed column
        assert!(summary.contains("Obs to live best"), "summary lost the live-obs column");
        assert!(summary.contains("Live best f (s)"), "summary lost the live-best column");
        assert!(dir.join("walltime_scheduler.csv").exists());
        let sched = std::fs::read_to_string(dir.join("walltime_scheduler.csv")).unwrap();
        assert!(sched.contains("Charged (s)"), "scheduler table lost the charged column");
        // per-bracket columns: the Hyperband table reports every bracket
        // from 0 to its maximum, and the bandit table logs its slices
        let brackets = std::fs::read_to_string(dir.join("walltime_brackets.csv")).unwrap();
        assert!(brackets.contains("Bracket"), "brackets table lost its bracket column");
        assert!(
            brackets.lines().skip(1).any(|l| l.starts_with("0,")),
            "brackets table has no bracket-0 rows"
        );
        let bandit = std::fs::read_to_string(dir.join("walltime_bandit.csv")).unwrap();
        assert!(bandit.contains("Slice"), "bandit table lost its slice column");
        assert!(bandit.lines().count() > 1, "bandit table has no slice rows");

        // the report carries both frames for every tuner
        for algo in Algo::all() {
            assert!(report.contains(algo.label()), "summary missing {}", algo.label());
        }
        // under one shared clock the 64-probe wave must buy random search
        // more observations than SPSA's 3-probe wave
        let obs_of = |name: &str| -> u64 {
            summary
                .lines()
                .find(|l| l.starts_with(&format!("{name},")))
                .unwrap_or_else(|| panic!("{name} missing from summary CSV"))
                .split(',')
                .nth(2)
                .unwrap()
                .parse()
                .unwrap()
        };
        assert!(
            obs_of("Random") > obs_of("SPSA"),
            "wall-clock frame lost: random {} obs vs spsa {}",
            obs_of("Random"),
            obs_of("SPSA")
        );
        std::fs::remove_dir_all(dir).ok();
    }
}
