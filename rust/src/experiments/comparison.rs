//! Fig. 8 / Fig. 9 regeneration: per-benchmark execution-time comparison of
//! tuning methods.
//!
//! * Fig. 8 (Hadoop v1): Default vs Starfish vs SPSA.
//! * Fig. 9 (Hadoop v2): Default vs SPSA vs PPABS.
//!
//! Expected shape (paper §6.7): SPSA ≤ Starfish ≤ Default and
//! SPSA ≤ PPABS ≤ Default on every benchmark, with the largest margins on
//! the shuffle-heavy jobs.

use crate::config::HadoopVersion;
use crate::coordinator::Algo;
use crate::util::table::{bar_chart, Table};
use crate::workloads::Benchmark;

use super::common::{campaign_for, fmt_pct, fmt_s, mean_decrease, mean_time, ExpOptions};

pub fn run(version: HadoopVersion, opts: &ExpOptions) -> String {
    let (fig, algos): (&str, Vec<Algo>) = match version {
        HadoopVersion::V1 => ("fig8", vec![Algo::Default, Algo::Starfish, Algo::Spsa]),
        HadoopVersion::V2 => ("fig9", vec![Algo::Default, Algo::Spsa, Algo::Ppabs]),
    };
    let outcomes = campaign_for(&algos, version, opts);

    let mut header = vec!["Benchmark".to_string()];
    for a in &algos {
        header.push(format!("{} (s)", a.label()));
    }
    for a in &algos[1..] {
        header.push(format!("{} vs default", a.label()));
    }
    let mut table = Table::new(&format!(
        "{} — execution time by tuning method, Hadoop {}",
        fig.to_uppercase(),
        version
    ))
    .header(header);

    let mut report = String::new();
    for bench in Benchmark::all() {
        let mut row = vec![bench.label().to_string()];
        for a in &algos {
            row.push(fmt_s(mean_time(&outcomes, bench, *a)));
        }
        for a in &algos[1..] {
            row.push(fmt_pct(mean_decrease(&outcomes, bench, *a)));
        }
        table.row(row);

        let entries: Vec<(String, f64)> = algos
            .iter()
            .map(|a| (a.label().to_string(), mean_time(&outcomes, bench, *a)))
            .collect();
        report.push_str(&bar_chart(&format!("{} — {}", fig.to_uppercase(), bench), &entries, 50));
        report.push('\n');
    }
    report.push_str(&table.to_ascii());
    opts.persist(fig, &table);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_ordering_holds_on_terasort() {
        let opts = ExpOptions::quick();
        let outcomes = campaign_for(
            &[Algo::Default, Algo::Starfish, Algo::Spsa],
            HadoopVersion::V1,
            &opts,
        );
        let d = mean_time(&outcomes, Benchmark::Terasort, Algo::Default);
        let s = mean_time(&outcomes, Benchmark::Terasort, Algo::Starfish);
        let p = mean_time(&outcomes, Benchmark::Terasort, Algo::Spsa);
        assert!(s < d, "starfish {s} vs default {d}");
        assert!(p < d * 0.6, "spsa {p} vs default {d}");
    }
}
