//! Multi-tenancy experiment: does the cross-campaign observation store
//! actually amortize? For every benchmark × representative tuner, a
//! fresh [`TuningService`] admits a **cold** request (empty store) and
//! then a **warm** one (different tenant, different seed, same
//! workload). The warm trial inherits the cold trial's live
//! observations as free noise-frozen store records, so the claim under
//! test is Tuneful's: the warm tenant reaches the cold run's best f
//! with strictly fewer live observations.
//!
//! Output (`tenancy_summary`): per benchmark × tuner, the cold run's
//! obs-to-best next to the warm run's live observations spent to reach
//! that same f, plus seeded-record/store-hit counts and the warm run's
//! own live-verified best — the noise-frozen replay and the fresh
//! measurement are never conflated (satellite bugfix).

use crate::config::HadoopVersion;
use crate::coordinator::{Algo, ServiceOutcome, TrialSpec, TuningRequest, TuningService};
use crate::tuner::{Budget, EvalRecord};
use crate::util::table::Table;
use crate::workloads::Benchmark;

use super::common::ExpOptions;

/// Representative tuners: the paper's contribution (cache policy `Off`),
/// a hill climber and TPE (both `Quantized`, so they exercise the store
/// tier on lookups too).
const TUNERS: [Algo; 3] = [Algo::Spsa, Algo::HillClimb, Algo::Tpe];

const COLD_SEED: u64 = 11;
const WARM_SEED: u64 = 23;

/// Live observations spent when the trace's best-so-far (any source —
/// store seeds replay at obs 0) first reaches `target`. `None` if never.
pub fn obs_to_reach(trace: &[EvalRecord], target: f64) -> Option<u64> {
    let mut best = f64::INFINITY;
    for r in trace {
        if !r.f.is_nan() && r.f < best {
            best = r.f;
        }
        if best <= target {
            return Some(r.obs);
        }
    }
    None
}

/// One cold/warm pair on a fresh service.
pub struct TenancyRow {
    pub benchmark: Benchmark,
    pub algo: Algo,
    pub cold: ServiceOutcome,
    pub warm: ServiceOutcome,
    /// Live obs the warm run spent to reach the cold run's best f
    /// (`None`: never reached it).
    pub warm_obs_to_cold_best: Option<u64>,
}

/// Run the cold/warm pair for one benchmark × tuner on a fresh service.
pub fn run_pair(bench: Benchmark, algo: Algo, budget: Budget) -> TenancyRow {
    let mut svc = TuningService::new();
    let req = |tenant: &str, seed: u64| TuningRequest {
        tenant: tenant.into(),
        spec: TrialSpec::new(bench, HadoopVersion::V1, algo, seed).with_budget(budget),
    };
    let cold = svc.submit(&req("cold-tenant", COLD_SEED));
    let warm = svc.submit(&req("warm-tenant", WARM_SEED));
    let warm_obs_to_cold_best = if cold.live_best_f.is_finite() {
        obs_to_reach(&warm.outcome.eval_trace, cold.live_best_f)
    } else {
        None
    };
    TenancyRow { benchmark: bench, algo, cold, warm, warm_obs_to_cold_best }
}

pub fn run(opts: &ExpOptions) -> String {
    let all = Benchmark::all();
    let benches: &[Benchmark] = if opts.quick { &all[..2] } else { &all };

    let mut table = Table::new(
        "tenancy — cold vs warm obs-to-best per tuner (warm tenant seeded from the \
         cold tenant's campaign via the observation store)",
    )
    .header(vec![
        "Benchmark",
        "Tuner",
        "Cold obs",
        "Cold obs to best",
        "Cold best f (s)",
        "Warm obs",
        "Warm obs to cold best",
        "Warm seeded records",
        "Warm store hits",
        "Warm live best f (s)",
        "Warm deploy noise-frozen",
    ]);
    let mut rows = Vec::new();
    for &bench in benches {
        for algo in TUNERS {
            rows.push(run_pair(bench, algo, opts.budget()));
        }
    }
    let mut amortized = 0usize;
    let mut pairs = 0usize;
    for r in &rows {
        let cold_live = r.cold.live_obs_to_best;
        table.row(vec![
            r.benchmark.label().to_string(),
            r.algo.label().to_string(),
            r.cold.outcome.observations.to_string(),
            cold_live.to_string(),
            if r.cold.live_best_f.is_finite() {
                format!("{:.0}", r.cold.live_best_f)
            } else {
                "-".into()
            },
            r.warm.outcome.observations.to_string(),
            r.warm_obs_to_cold_best.map(|o| o.to_string()).unwrap_or_else(|| "-".into()),
            r.warm.seeded_records.to_string(),
            r.warm.outcome.store_hits.to_string(),
            if r.warm.live_best_f.is_finite() {
                format!("{:.0}", r.warm.live_best_f)
            } else {
                "-".into()
            },
            if r.warm.outcome.noise_frozen { "yes".into() } else { "no".to_string() },
        ]);
        pairs += 1;
        if let Some(w) = r.warm_obs_to_cold_best {
            if w < cold_live {
                amortized += 1;
            }
        }
    }

    let mut report = String::from(
        "== tenancy — cross-campaign amortization: warm tenants reuse cold tenants' \
         observations ==\n",
    );
    report.push_str(&table.to_ascii());
    report.push_str(&format!(
        "\namortized (warm reached cold best with strictly fewer live obs): {amortized}/{pairs} pairs\n",
    ));
    opts.persist("tenancy_summary", &table);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_to_reach_walks_best_so_far() {
        let rec = |obs: u64, f: f64| EvalRecord {
            obs,
            model_time: 0.0,
            theta: vec![0.5],
            f,
            cached: false,
            source: crate::tuner::ObsSource::Live,
        };
        let trace = vec![rec(0, 10.0), rec(3, f64::NAN), rec(3, 8.0), rec(6, 9.0)];
        assert_eq!(obs_to_reach(&trace, 10.0), Some(0));
        assert_eq!(obs_to_reach(&trace, 8.5), Some(3), "NaN never counts as progress");
        assert_eq!(obs_to_reach(&trace, 1.0), None);
    }

    #[test]
    fn warm_tenant_amortizes_on_every_quick_pair() {
        // The acceptance claim, on the quick benchmark slice: the warm
        // tenant starts from the cold tenant's incumbent (free store
        // seeds at obs 0), so it reaches the cold best with strictly
        // fewer live observations than the cold run spent.
        let opts = ExpOptions::quick();
        let all = Benchmark::all();
        for &bench in &all[..2] {
            for algo in TUNERS {
                let r = run_pair(bench, algo, opts.budget());
                assert!(!r.cold.warm_started, "{bench:?}/{algo:?}: first request is cold");
                assert!(r.warm.warm_started, "{bench:?}/{algo:?}: repeat workload must match");
                assert!(r.warm.seeded_records > 0, "{bench:?}/{algo:?}: no records seeded");
                assert!(r.warm.outcome.store_hits > 0, "{bench:?}/{algo:?}: no store hits");
                let w = r
                    .warm_obs_to_cold_best
                    .unwrap_or_else(|| panic!("{bench:?}/{algo:?}: warm never reached cold best"));
                assert!(
                    w < r.cold.live_obs_to_best,
                    "{bench:?}/{algo:?}: warm spent {w} live obs vs cold {}",
                    r.cold.live_obs_to_best
                );
            }
        }
    }

    #[test]
    fn tenancy_quick_report_counts_amortized_pairs() {
        let report = run(&ExpOptions::quick());
        assert!(report.contains("Warm obs to cold best"));
        assert!(report.contains("amortized"), "report lost the amortization tally");
    }
}
