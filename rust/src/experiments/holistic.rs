//! Holistic tuning (paper §7 future work): extend SPSA's search space with
//! OS-layer parameters (readahead, TCP rmem, dirty ratio) and compare
//! against framework-only tuning at the same iteration budget.
//!
//! Key property: the *what-if model cannot see the OS layer* — only a
//! direct-feedback tuner like SPSA can exploit it, which is the paper's
//! closing argument for the approach.

use crate::cluster::ClusterSpec;
use crate::config::{HadoopVersion, ParameterSpace};
use crate::coordinator::evaluate_theta;
use crate::tuner::registry::SpsaTuner;
use crate::tuner::{Budget, EvalBroker, SimObjective, Tuner};
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::util::table::Table;
use crate::workloads::Benchmark;

use super::common::ExpOptions;

/// Tune `bench` over `space` with the registry SPSA tuner under an
/// `iters`-iteration-equivalent observation budget; return the deployed
/// configuration's mean execution time.
fn tune(space: &ParameterSpace, bench: Benchmark, iters: u64, seed: u64) -> f64 {
    let cluster = ClusterSpec::paper_cluster();
    let mut rng = Rng::seeded(1000);
    let w = bench.paper_profile(&mut rng);
    let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), seed);
    let mut broker = EvalBroker::new(&mut obj, Budget::obs(3 * iters));
    let out = SpsaTuner::paper().tune(&mut broker, space, seed);
    let (t, _) = evaluate_theta(
        space,
        &cluster,
        &w,
        &out.best_theta,
        5,
        seed ^ 0xC0,
        &crate::sim::ScenarioSpec::default(),
    );
    t
}

pub fn run(opts: &ExpOptions) -> String {
    let seeds = opts.seeds();
    let iters = opts.iters() + 10; // 3 extra dims → slightly longer budget
    let mut table = Table::new(
        "Holistic tuning — SPSA over Hadoop-only (11 params) vs Hadoop+OS (14 params)",
    )
    .header(vec![
        "Benchmark",
        "default (s)",
        "Hadoop-only SPSA (s)",
        "Hadoop+OS SPSA (s)",
        "extra gain",
    ]);

    let mut report = String::new();
    for bench in [Benchmark::Terasort, Benchmark::Bigram, Benchmark::InvertedIndex] {
        let base_space = ParameterSpace::for_version(HadoopVersion::V1);
        let ext_space = ParameterSpace::extended(HadoopVersion::V1);
        let cluster = ClusterSpec::paper_cluster();
        let mut rng = Rng::seeded(1000);
        let w = bench.paper_profile(&mut rng);
        let (f_default, _) = evaluate_theta(
            &base_space,
            &cluster,
            &w,
            &base_space.default_theta(),
            5,
            9,
            &crate::sim::ScenarioSpec::default(),
        );

        let f_base = mean(
            &seeds.iter().map(|&s| tune(&base_space, bench, iters, s)).collect::<Vec<_>>(),
        );
        let f_ext = mean(
            &seeds.iter().map(|&s| tune(&ext_space, bench, iters, s)).collect::<Vec<_>>(),
        );
        let extra = 100.0 * (f_base - f_ext) / f_base;
        table.row(vec![
            bench.label().to_string(),
            format!("{f_default:.0}"),
            format!("{f_base:.0}"),
            format!("{f_ext:.0}"),
            format!("{extra:+.0}%"),
        ]);
    }
    report.push_str(&table.to_ascii());
    opts.persist("holistic", &table);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extended_space_tunes_at_least_as_well() {
        // At an adequate budget, adding OS knobs should not hurt (and the
        // OS landscape offers some headroom: readahead boost + TCP window).
        let base = ParameterSpace::for_version(HadoopVersion::V1);
        let ext = ParameterSpace::extended(HadoopVersion::V1);
        assert_eq!(ext.dim(), base.dim() + 3);
        let f_base = tune(&base, Benchmark::Bigram, 30, 5);
        let f_ext = tune(&ext, Benchmark::Bigram, 40, 5);
        assert!(
            f_ext < f_base * 1.15,
            "holistic tuning regressed badly: {f_ext} vs {f_base}"
        );
    }

    #[test]
    fn os_defaults_are_noop() {
        // The extended space at default θ produces exactly the same config
        // behaviour as the base space (OS defaults = stock Linux).
        let base = ParameterSpace::for_version(HadoopVersion::V1);
        let ext = ParameterSpace::extended(HadoopVersion::V1);
        let cb = base.materialize(&base.default_theta());
        let ce = ext.materialize(&ext.default_theta());
        assert_eq!(cb.os, ce.os);
        assert_eq!(cb.io_sort_mb, ce.io_sort_mb);
    }
}
