//! Table 1 regeneration: default parameter values vs. the values SPSA
//! converges to, per benchmark, for Hadoop v1 and v2.
//!
//! Expected shape vs. the paper: SPSA lands on *large* reducer counts for
//! shuffle-heavy jobs (Terasort/Inverted-Index), grows io.sort.mb for
//! spill-bound jobs, and leaves Grep close to defaults — the qualitative
//! pattern of the paper's Table 1 (exact values differ; the landscape is a
//! simulator and SPSA is stochastic).

use crate::config::{HadoopVersion, ParameterSpace};
use crate::coordinator::{run_campaign, Algo, TrialSpec};
use crate::util::table::Table;
use crate::workloads::Benchmark;

use super::common::ExpOptions;

pub fn run(opts: &ExpOptions) -> String {
    let seed = opts.seeds()[0];
    let mut specs = Vec::new();
    for version in [HadoopVersion::V1, HadoopVersion::V2] {
        for bench in Benchmark::all() {
            specs.push(
                TrialSpec::new(bench, version, Algo::Spsa, seed).with_budget(opts.budget()),
            );
        }
    }
    let outcomes = run_campaign(specs);

    let mut report = String::new();
    for version in [HadoopVersion::V1, HadoopVersion::V2] {
        let space = ParameterSpace::for_version(version);
        let mut header: Vec<String> = vec!["Parameter".into(), "Default".into()];
        for b in Benchmark::all() {
            header.push(b.label().to_string());
        }
        let mut table = Table::new(&format!(
            "Table 1 — SPSA-tuned parameter values (Hadoop {version})"
        ))
        .header(header);

        let tuned: Vec<Vec<crate::config::ParamValue>> = Benchmark::all()
            .iter()
            .map(|b| {
                let o = outcomes
                    .iter()
                    .find(|o| o.spec.benchmark == *b && o.spec.version == version)
                    .expect("missing outcome");
                space.to_hadoop_values(&o.tuned_theta)
            })
            .collect();

        for (i, p) in space.params().iter().enumerate() {
            let mut row = vec![p.name.to_string(), p.default_value().display()];
            for t in &tuned {
                row.push(t[i].display());
            }
            table.row(row);
        }
        report.push_str(&table.to_ascii());
        report.push('\n');
        opts.persist(&format!("table1_{}", if version == HadoopVersion::V1 { "v1" } else { "v2" }), &table);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_table1_has_both_versions() {
        let report = run(&ExpOptions::quick());
        assert!(report.contains("Hadoop v1.0.3"));
        assert!(report.contains("Hadoop v2.6.3"));
        assert!(report.contains("io.sort.mb"));
        assert!(report.contains("mapreduce.job.jvm.numtasks"));
    }
}
