//! Table 2 regeneration: qualitative method comparison, extended with the
//! *measured* overheads the paper's §6.8(6) reports anecdotally (Starfish
//! profiled Word Co-occurrence for 4 h 38 m; SPSA has no profiling phase).
//!
//! Every algorithm of the registry runs through the same budget-metered
//! `EvalBroker`, so the "live-system runs" column is the paper's
//! observation-economy argument measured under one identical budget.

use crate::config::HadoopVersion;
use crate::coordinator::{run_trial, Algo, TrialSpec};
use crate::util::table::Table;
use crate::util::units::fmt_secs;
use crate::workloads::Benchmark;

use super::common::ExpOptions;

pub fn run(opts: &ExpOptions) -> String {
    // Qualitative matrix (the paper's Table 2 verbatim; ✓ = method is free
    // of the limitation / has the property).
    let mut qual = Table::new("Table 2 — qualitative comparison (paper layout)").header(vec![
        "Method",
        "No math model needed",
        "Dimension free",
        "Captures param dependency",
        "Optimizes on real system",
        "No profiling overhead",
    ]);
    qual.row(vec!["Starfish", "x", "x", "x", "x", "x"]);
    qual.row(vec!["PPABS", "x", "x", "x", "x", "x"]);
    qual.row(vec!["SPSA", "ok", "ok", "ok", "ok", "ok"]);

    // Measured overheads on the paper's §6.8 example (Word Co-occurrence):
    // the ENTIRE registry — all ten algorithms — under ONE identical
    // observation budget.
    let bench = Benchmark::WordCooccurrence;
    let seed = opts.seeds()[0];
    let budget = opts.budget();
    let mut quant = Table::new(&format!(
        "Table 2 (extended) — measured tuning overheads, Word Co-occurrence, \
         budget {} observations",
        budget.max_obs
    ))
    .header(vec![
        "Method",
        "Profiling time (sim)",
        "Live obs / budget",
        "Model wall-clock (sim)",
        "Model evals",
        "Result vs default",
    ]);
    for algo in Algo::all() {
        let version =
            if algo == Algo::Ppabs { HadoopVersion::V2 } else { HadoopVersion::V1 };
        let spec =
            TrialSpec::new(bench, version, algo, seed).with_budget(budget);
        let o = run_trial(&spec);
        assert!(
            o.observations <= budget.max_obs,
            "{} overspent the shared budget",
            algo.label()
        );
        quant.row(vec![
            algo.label().to_string(),
            if o.profiling_overhead_s > 0.0 {
                fmt_secs(o.profiling_overhead_s)
            } else {
                "none".to_string()
            },
            format!("{}/{}", o.observations, budget.max_obs),
            // the third budget axis, threaded through every trial: what
            // the same observation budget costs in modeled wall-clock
            if o.elapsed_model_s > 0.0 { fmt_secs(o.elapsed_model_s) } else { "none".into() },
            o.model_evals.to_string(),
            format!("-{:.0}%", o.pct_decrease()),
        ]);
    }

    let report = format!("{}\n{}", qual.to_ascii(), quant.to_ascii());
    opts.persist("table2_qualitative", &qual);
    opts.persist("table2_overheads", &quant);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_reports_overheads_for_the_whole_registry() {
        let report = run(&ExpOptions::quick());
        for algo in Algo::all() {
            assert!(report.contains(algo.label()), "missing {}", algo.label());
        }
        assert_eq!(
            report.matches("\nRDSA").count() + report.matches("\nTPE").count(),
            2,
            "the grown registry rows must be present exactly once each"
        );
        assert!(report.contains("none")); // SPSA has no profiling phase
        assert!(report.contains("/60"), "budget column missing (quick = 60 obs)");
        assert!(
            report.contains("Model wall-clock"),
            "the wall-clock axis is missing from the overhead table"
        );
    }
}
