//! Table 2 regeneration: qualitative method comparison, extended with the
//! *measured* overheads the paper's §6.8(6) reports anecdotally (Starfish
//! profiled Word Co-occurrence for 4 h 38 m; SPSA has no profiling phase).

use crate::config::HadoopVersion;
use crate::coordinator::{run_trial, Algo, TrialSpec};
use crate::util::table::Table;
use crate::util::units::fmt_secs;
use crate::workloads::Benchmark;

use super::common::ExpOptions;

pub fn run(opts: &ExpOptions) -> String {
    // Qualitative matrix (the paper's Table 2 verbatim; ✓ = method is free
    // of the limitation / has the property).
    let mut qual = Table::new("Table 2 — qualitative comparison (paper layout)").header(vec![
        "Method",
        "No math model needed",
        "Dimension free",
        "Captures param dependency",
        "Optimizes on real system",
        "No profiling overhead",
    ]);
    qual.row(vec!["Starfish", "x", "x", "x", "x", "x"]);
    qual.row(vec!["PPABS", "x", "x", "x", "x", "x"]);
    qual.row(vec!["SPSA", "ok", "ok", "ok", "ok", "ok"]);

    // Measured overheads on the paper's §6.8 example (Word Co-occurrence).
    let bench = Benchmark::WordCooccurrence;
    let seed = opts.seeds()[0];
    let mut quant = Table::new(
        "Table 2 (extended) — measured tuning overheads, Word Co-occurrence, Hadoop v1",
    )
    .header(vec![
        "Method",
        "Profiling time (sim)",
        "Live-system runs",
        "Model evals",
        "Result vs default",
    ]);
    for algo in [Algo::Starfish, Algo::Ppabs, Algo::Spsa] {
        let version =
            if algo == Algo::Ppabs { HadoopVersion::V2 } else { HadoopVersion::V1 };
        let mut spec = TrialSpec::new(bench, version, algo, seed);
        spec.iters = opts.iters();
        let o = run_trial(&spec);
        quant.row(vec![
            algo.label().to_string(),
            if o.profiling_overhead_s > 0.0 {
                fmt_secs(o.profiling_overhead_s)
            } else {
                "none".to_string()
            },
            o.observations.to_string(),
            o.model_evals.to_string(),
            format!("-{:.0}%", o.pct_decrease()),
        ]);
    }

    let report = format!("{}\n{}", qual.to_ascii(), quant.to_ascii());
    opts.persist("table2_qualitative", &qual);
    opts.persist("table2_overheads", &quant);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_reports_overheads() {
        let report = run(&ExpOptions::quick());
        assert!(report.contains("Starfish"));
        assert!(report.contains("SPSA"));
        assert!(report.contains("none")); // SPSA has no profiling phase
    }
}
