//! Headline-claim regeneration (paper §1.1 / §7): "66 % decrease in
//! execution time vs. default on average, and 45 % vs. prior methods".
//!
//! Aggregates the Fig-8 (v1: SPSA vs Starfish) and Fig-9 (v2: SPSA vs
//! PPABS) campaigns into the two averages the abstract quotes.

use crate::config::HadoopVersion;
use crate::coordinator::Algo;
use crate::util::stats::mean;
use crate::util::table::Table;
use crate::workloads::Benchmark;

use super::common::{campaign_for, mean_time, ExpOptions};

pub struct Headline {
    /// Mean % decrease of SPSA vs default across benchmarks and versions.
    pub vs_default_pct: f64,
    /// Mean % decrease of SPSA vs the prior method (Starfish on v1, PPABS
    /// on v2) across benchmarks.
    pub vs_prior_pct: f64,
}

pub fn compute(opts: &ExpOptions) -> (Headline, String) {
    let v1 = campaign_for(&[Algo::Default, Algo::Starfish, Algo::Spsa], HadoopVersion::V1, opts);
    let v2 = campaign_for(&[Algo::Default, Algo::Ppabs, Algo::Spsa], HadoopVersion::V2, opts);

    let mut vs_default = Vec::new();
    let mut vs_prior = Vec::new();
    let mut table = Table::new("Headline — SPSA vs default and vs prior methods").header(vec![
        "Benchmark",
        "Version",
        "Default (s)",
        "Prior (s)",
        "SPSA (s)",
        "vs default",
        "vs prior",
    ]);

    for (outcomes, version, prior) in
        [(&v1, HadoopVersion::V1, Algo::Starfish), (&v2, HadoopVersion::V2, Algo::Ppabs)]
    {
        for bench in Benchmark::all() {
            let d = mean_time(outcomes, bench, Algo::Default);
            let p = mean_time(outcomes, bench, prior);
            let s = mean_time(outcomes, bench, Algo::Spsa);
            let dd = 100.0 * (d - s) / d;
            let dp = 100.0 * (p - s) / p;
            vs_default.push(dd);
            vs_prior.push(dp);
            table.row(vec![
                bench.label().to_string(),
                version.label().to_string(),
                format!("{d:.0}"),
                format!("{p:.0}"),
                format!("{s:.0}"),
                format!("{dd:.0}%"),
                format!("{dp:.0}%"),
            ]);
        }
    }

    let headline =
        Headline { vs_default_pct: mean(&vs_default), vs_prior_pct: mean(&vs_prior) };
    let mut report = table.to_ascii();
    report.push_str(&format!(
        "\npaper:    66% mean decrease vs default, 45% vs prior methods\n\
         measured: {:.0}% mean decrease vs default, {:.0}% vs prior methods\n",
        headline.vs_default_pct, headline.vs_prior_pct
    ));
    opts.persist("headline", &table);
    opts.persist_text("headline.txt", &report);
    (headline, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_direction_matches_paper() {
        let (h, report) = compute(&ExpOptions::quick());
        assert!(
            h.vs_default_pct > 40.0,
            "vs default only {:.0}%\n{report}",
            h.vs_default_pct
        );
        assert!(h.vs_prior_pct > 0.0, "vs prior {:.0}%\n{report}", h.vs_prior_pct);
    }
}
