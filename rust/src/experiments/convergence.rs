//! Fig. 6 / Fig. 7 regeneration, trace-driven: best-so-far execution time
//! versus *live observations spent* for EVERY registry tuner under one
//! shared budget, on Hadoop v1 (Fig. 6) and v2 (Fig. 7).
//!
//! The paper plots SPSA's per-iteration f(θ_n); the broker refactor gives
//! every algorithm a uniform [`EvalRecord`] trace, so the figures now
//! compare all ten tuners in the paper's own currency — observations, not
//! iterations (§6.6's economy argument made visual). The "jumps" §6.7
//! discusses survive: a noisy-gradient step can worsen f(θ_n), but the
//! *best-so-far* curve shows how quickly each tuner banks a deployable
//! configuration.
//!
//! Outputs, per figure:
//! * one CSV per registry tuner (`fig6_convergence_<name>`): rows are
//!   observation counts 1..budget, one column per benchmark with the
//!   best-so-far f after that many observations (blank before a tuner's
//!   first dispatched batch lands, after it stopped, and everywhere for
//!   tuners that never observe live, like `default`);
//! * a Table-1-style summary (`fig6_convergence_summary`): % decrease vs
//!   the default configuration and observations spent, per tuner ×
//!   benchmark.

use crate::config::HadoopVersion;
use crate::coordinator::{run_campaign, Algo, TrialOutcome, TrialSpec};
use crate::tuner::EvalRecord;
use crate::util::table::{curve, Table};
use crate::workloads::Benchmark;

use super::common::ExpOptions;

/// Dense best-so-far series indexed by live-observation count: element
/// `k` is the best f observed once `k+1` observations were spent. Counts
/// between trace records (e.g. external [`EvalBroker::charge`]s) carry
/// the previous best forward; counts before the first record stay +∞
/// (rendered blank). Empty for tuners that never observe live.
///
/// [`EvalBroker::charge`]: crate::tuner::EvalBroker::charge
pub fn best_so_far_by_obs(trace: &[EvalRecord]) -> Vec<f64> {
    let Some(last) = trace.last() else { return Vec::new() };
    let mut out = vec![f64::INFINITY; last.obs as usize];
    let mut best = f64::INFINITY;
    for r in trace {
        best = best.min(r.f);
        if r.obs >= 1 {
            let i = (r.obs - 1) as usize;
            out[i] = out[i].min(best);
        }
    }
    let mut prev = f64::INFINITY;
    for v in out.iter_mut() {
        if v.is_finite() {
            prev = *v;
        } else {
            *v = prev;
        }
    }
    out
}

fn outcome_for<'a>(
    outcomes: &'a [TrialOutcome],
    bench: Benchmark,
    algo: Algo,
) -> &'a TrialOutcome {
    outcomes
        .iter()
        .find(|o| o.spec.benchmark == bench && o.spec.algo == algo)
        .expect("campaign covers the full tuner × benchmark matrix")
}

pub fn run(version: HadoopVersion, opts: &ExpOptions) -> String {
    let fig = if version == HadoopVersion::V1 { "fig6" } else { "fig7" };
    let seed = opts.seeds()[0];
    let budget = opts.budget();
    let all = Benchmark::all();
    // quick mode keeps the suite fast with a representative pair
    let benches: &[Benchmark] = if opts.quick { &all[..2] } else { &all };

    let specs: Vec<TrialSpec> = Algo::all()
        .into_iter()
        .flat_map(|algo| {
            benches
                .iter()
                .map(move |&b| TrialSpec::new(b, version, algo, seed).with_budget(budget))
        })
        .collect();
    let outcomes = run_campaign(specs);

    let mut report = format!(
        "== {} — best-so-far vs observations, all registry tuners, Hadoop {} \
         (shared budget {} obs) ==\n",
        fig.to_uppercase(),
        version,
        budget.max_obs
    );

    // Per-tuner convergence CSV + a terminal sparkline on the first
    // benchmark (Terasort) so the figure is visible in the run log.
    for algo in Algo::all() {
        let curves: Vec<Vec<f64>> = benches
            .iter()
            .map(|&b| best_so_far_by_obs(&outcome_for(&outcomes, b, algo).eval_trace))
            .collect();
        let mut table = Table::new(&format!(
            "{} — {} best-so-far f (seconds) vs live observations, Hadoop {}",
            fig.to_uppercase(),
            algo.label(),
            version
        ))
        .header({
            let mut h = vec!["obs".to_string()];
            h.extend(benches.iter().map(|b| b.label().to_string()));
            h
        });
        let len = curves.iter().map(Vec::len).max().unwrap_or(0);
        for k in 0..len {
            let mut row = vec![(k + 1).to_string()];
            for c in &curves {
                row.push(match c.get(k) {
                    Some(v) if v.is_finite() => format!("{v:.3}"),
                    _ => String::new(),
                });
            }
            table.row(row);
        }
        opts.persist(&format!("{fig}_convergence_{}", algo.name()), &table);

        // A multi-point first dispatch (SPSA's iteration batch, the
        // simplex init, TPE's startup) records every point at the
        // post-batch obs count, so the curve's leading entries are +∞
        // until that batch lands — render from the first finite value.
        let lead = &curves[0];
        let Some(si) = lead.iter().position(|v| v.is_finite()) else {
            report.push_str(&format!(
                "{:<16} no live observations (deploys from defaults or a model)\n\n",
                algo.label()
            ));
            continue;
        };
        let shown = &lead[si..];
        let (first, last) = (shown[0], *shown.last().expect("non-empty by position"));
        report.push_str(&curve(
            &format!("{} on {} ({} obs)", algo.label(), benches[0], lead.len()),
            shown,
            6,
        ));
        report.push_str(&format!(
            "  start {first:.0}s → best {last:.0}s ({:.0}% decrease)\n\n",
            100.0 * (first - last) / first.max(1e-9)
        ));
    }

    // Table-1-style summary: verified tuned-vs-default decrease + spend.
    let mut summary = Table::new(&format!(
        "{} summary — % decrease vs default (obs spent), Hadoop {}, budget {}",
        fig.to_uppercase(),
        version,
        budget.max_obs
    ))
    .header({
        let mut h = vec!["Tuner".to_string()];
        h.extend(benches.iter().map(|b| b.label().to_string()));
        h
    });
    for algo in Algo::all() {
        let mut row = vec![algo.label().to_string()];
        for &b in benches {
            let o = outcome_for(&outcomes, b, algo);
            assert!(
                o.observations <= budget.max_obs,
                "{} overspent the shared budget",
                algo.label()
            );
            row.push(format!("{:.0}% ({} obs)", o.pct_decrease(), o.observations));
        }
        summary.row(row);
    }
    report.push_str(&summary.to_ascii());
    opts.persist(&format!("{fig}_convergence_summary"), &summary);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ResultsDir;
    use crate::tuner::ObsSource;

    #[test]
    fn best_so_far_is_monotone_and_dense() {
        let rec = |obs: u64, f: f64, cached: bool| EvalRecord {
            obs,
            model_time: obs as f64, // shape irrelevant to the obs-indexed curve
            theta: vec![0.5],
            f,
            cached,
            source: if cached { ObsSource::Memo } else { ObsSource::Live },
        };
        // live, live, cache hit (same obs), then a charge gap to obs 6
        let trace = vec![
            rec(1, 10.0, false),
            rec(2, 12.0, false),
            rec(2, 8.0, true),
            rec(6, 9.0, false),
        ];
        let c = best_so_far_by_obs(&trace);
        assert_eq!(c, vec![10.0, 8.0, 8.0, 8.0, 8.0, 8.0]);
        assert!(best_so_far_by_obs(&[]).is_empty());

        // a 3-point first dispatch: every record carries the post-batch
        // count, so counts before the batch lands stay +∞ (blank)
        let batch = vec![rec(3, 7.0, false), rec(3, 5.0, false), rec(3, 6.0, false)];
        let c = best_so_far_by_obs(&batch);
        assert!(c[0].is_infinite() && c[1].is_infinite());
        assert_eq!(c[2], 5.0);
    }

    #[test]
    fn best_so_far_by_obs_shrugs_off_nan_and_inf_observations() {
        // `f64::min` keeps the non-NaN operand, so a poisoned observation
        // (NaN score from a degenerate config) must neither stick as the
        // best nor blank later entries.
        let rec = |obs: u64, f: f64| EvalRecord {
            obs,
            model_time: obs as f64,
            theta: vec![0.5],
            f,
            cached: false,
            source: ObsSource::Live,
        };
        let trace = vec![
            rec(1, f64::NAN),
            rec(2, f64::INFINITY),
            rec(3, 9.0),
            rec(4, f64::NAN),
            rec(5, 7.0),
        ];
        let c = best_so_far_by_obs(&trace);
        // before any finite observation the curve stays +inf, never NaN
        assert!(c[0].is_infinite() && !c[0].is_nan());
        assert!(c[1].is_infinite());
        assert_eq!(&c[2..], &[9.0, 9.0, 7.0]);
    }

    #[test]
    fn fig6_emits_a_curve_per_registry_tuner_and_spsa_converges() {
        let dir = std::env::temp_dir().join(format!("hspsa-fig6-{}", std::process::id()));
        let opts =
            ExpOptions { quick: true, out: Some(ResultsDir::new(&dir).expect("results dir")) };
        let report = run(HadoopVersion::V1, &opts);

        // one best-so-far CSV per registry tuner, plus the summary
        for algo in Algo::all() {
            let path = dir.join(format!("fig6_convergence_{}.csv", algo.name()));
            assert!(path.exists(), "missing convergence CSV for {}", algo.label());
        }
        assert!(dir.join("fig6_convergence_summary.csv").exists());

        // SPSA on Terasort must still show the paper's large decrease
        let spsa_line = report
            .lines()
            .skip_while(|l| !l.contains("SPSA on Terasort"))
            .find(|l| l.contains("decrease"))
            .expect("SPSA Terasort decrease line");
        let pct: f64 = spsa_line
            .split('(')
            .nth(1)
            .unwrap()
            .split('%')
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert!(pct > 30.0, "SPSA Terasort only {pct}% in fig6");

        // every tuner appears in the summary table
        for algo in Algo::all() {
            assert!(report.contains(algo.label()), "summary missing {}", algo.label());
        }
        std::fs::remove_dir_all(dir).ok();
    }
}
