//! Fig. 6 / Fig. 7 regeneration: SPSA convergence — job execution time
//! f(θ_n) per iteration for each benchmark, on Hadoop v1 (Fig. 6) and v2
//! (Fig. 7). The "jumps in the plots" the paper's §6.7 discusses come from
//! the noisy gradient estimate; they must be visible here too.

use crate::config::HadoopVersion;
use crate::coordinator::{run_campaign, Algo, TrialSpec};
use crate::util::table::{curve, Table};
use crate::workloads::Benchmark;

use super::common::ExpOptions;

pub fn run(version: HadoopVersion, opts: &ExpOptions) -> String {
    let fig = if version == HadoopVersion::V1 { "fig6" } else { "fig7" };
    let seed = opts.seeds()[0];
    let specs: Vec<TrialSpec> = Benchmark::all()
        .iter()
        .map(|b| TrialSpec::new(*b, version, Algo::Spsa, seed).with_budget(opts.budget()))
        .collect();
    let outcomes = run_campaign(specs);

    let mut report = format!(
        "== {} — SPSA convergence on Hadoop {} ==\n",
        fig.to_uppercase(),
        version
    );
    let mut table = Table::new(&format!(
        "{} — f(θ_n) per SPSA iteration (seconds), Hadoop {}",
        fig.to_uppercase(),
        version
    ))
    .header({
        let mut h = vec!["iter".to_string()];
        h.extend(Benchmark::all().iter().map(|b| b.label().to_string()));
        h
    });

    let iters = outcomes.iter().map(|o| o.history.len()).max().unwrap_or(0);
    for i in 0..iters {
        let mut row = vec![i.to_string()];
        for o in &outcomes {
            row.push(
                o.history
                    .get(i)
                    .map(|r| format!("{:.0}", r.f_theta))
                    .unwrap_or_default(),
            );
        }
        table.row(row);
    }

    for o in &outcomes {
        let values: Vec<f64> = o.history.iter().map(|r| r.f_theta).collect();
        report.push_str(&curve(
            &format!("{} ({} iters, 2 obs/iter)", o.spec.benchmark, o.history.len()),
            &values,
            8,
        ));
        let first = values.first().copied().unwrap_or(0.0);
        let last = values.last().copied().unwrap_or(0.0);
        report.push_str(&format!(
            "  start {first:.0}s → end {last:.0}s ({:.0}% decrease)\n\n",
            100.0 * (first - last) / first.max(1e-9)
        ));
    }
    report.push_str(&table.to_ascii());
    opts.persist(fig, &table);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_converges_downward_for_terasort() {
        let report = run(HadoopVersion::V1, &ExpOptions::quick());
        assert!(report.contains("Terasort"));
        assert!(report.contains("2 obs/iter"));
        // terasort must show a large decrease
        let tera_line = report
            .lines()
            .skip_while(|l| !l.contains("Terasort"))
            .find(|l| l.contains("decrease"))
            .expect("terasort decrease line");
        let pct: f64 = tera_line
            .split('(')
            .nth(1)
            .unwrap()
            .split('%')
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert!(pct > 30.0, "terasort only {pct}% in fig6");
    }
}
