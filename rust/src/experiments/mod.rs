//! Experiment regeneration: one module per table/figure of the paper's
//! evaluation (DESIGN.md §5 experiment index). Each produces terminal
//! output and, in full mode, persists markdown/CSV into `results/`.

pub mod ablation;
pub mod common;
pub mod comparison;
pub mod convergence;
pub mod headline;
pub mod holistic;
pub mod perf;
pub mod robustness;
pub mod table1;
pub mod table2;
pub mod tenancy;
pub mod walltime;

pub use common::ExpOptions;
