//! Ablations over SPSA's design choices (paper §6.5 discussion):
//!
//! * gradient estimator: one-sided (paper) vs two-sided vs one-measurement
//!   ("it has been shown that standard two function measurement form … is
//!   more efficient … than the one evaluation variant");
//! * gradient averaging: 1 / 2 / 4 estimates per iteration (the paper cites
//!   [28] for averaging under high noise);
//! * step clip `max_step` (the stability guard, DESIGN.md).
//!
//! Each cell reports the deployed configuration's mean execution time at an
//! *equal live-observation budget*. The budget is not hand-translated into
//! per-variant iteration counts any more: every cell runs through an
//! [`EvalBroker`] with the same `Budget`, and the broker stops each
//! estimator after however many whole iterations it can afford — cheaper
//! estimators simply get more of them.

use crate::cluster::ClusterSpec;
use crate::config::ParameterSpace;
use crate::coordinator::evaluate_theta;
use crate::tuner::{Budget, EvalBroker, SimObjective, Spsa, SpsaConfig, SpsaVariant};
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::util::table::Table;
use crate::workloads::Benchmark;

use super::common::ExpOptions;

/// Observation budget per tuning run (comparable to the paper's 40–60).
const BUDGET: u64 = 90;

fn run_cell(cfg: SpsaConfig, seeds: &[u64]) -> (f64, f64, f64) {
    let space = ParameterSpace::v1();
    let cluster = ClusterSpec::paper_cluster();
    let mut rng = Rng::seeded(1000);
    let w = Benchmark::Terasort.paper_profile(&mut rng);
    let mut times = Vec::new();
    let mut obs = Vec::new();
    let mut iters = Vec::new();
    for &seed in seeds {
        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), seed);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(BUDGET));
        let spsa = Spsa::for_space(SpsaConfig { seed, ..cfg.clone() }, &space);
        let res = spsa.run_broker(&mut broker, space.default_theta());
        assert!(broker.evals_used() <= BUDGET);
        let (t, _) = evaluate_theta(
            &space,
            &cluster,
            &w,
            &res.best_theta,
            5,
            seed ^ 0xAB,
            &crate::sim::ScenarioSpec::default(),
        );
        times.push(t);
        obs.push(broker.evals_used() as f64);
        iters.push(res.iterations as f64);
    }
    (mean(&times), mean(&obs), mean(&iters))
}

pub fn run(opts: &ExpOptions) -> String {
    let seeds = opts.seeds();
    let mut table = Table::new(
        "Ablation — SPSA design choices on Terasort v1 (equal observation budget)",
    )
    .header(vec!["variant", "grad_avg", "max_step", "mean iters", "mean obs", "tuned time (s)"]);

    // the broker's budget governs iteration counts: max_iters stays
    // unbounded and each estimator spends the same 90 observations
    let base = SpsaConfig {
        grad_tol: 0.0,
        patience: u64::MAX,
        max_iters: u64::MAX,
        ..Default::default()
    };

    let cells: Vec<(&str, SpsaConfig)> = vec![
        (
            "one-sided (paper)",
            SpsaConfig { variant: SpsaVariant::OneSided, grad_avg: 2, ..base.clone() },
        ),
        (
            "two-sided",
            SpsaConfig { variant: SpsaVariant::TwoSided, grad_avg: 1, ..base.clone() },
        ),
        (
            "one-measurement",
            SpsaConfig { variant: SpsaVariant::OneMeasurement, grad_avg: 1, ..base.clone() },
        ),
        (
            "one-sided, no averaging",
            SpsaConfig { variant: SpsaVariant::OneSided, grad_avg: 1, ..base.clone() },
        ),
        (
            "one-sided, heavy averaging",
            SpsaConfig { variant: SpsaVariant::OneSided, grad_avg: 4, ..base.clone() },
        ),
        (
            "RDSA (gaussian directions)",
            SpsaConfig { variant: SpsaVariant::Rdsa, grad_avg: 2, ..base.clone() },
        ),
        (
            "small step clip (0.05)",
            SpsaConfig {
                variant: SpsaVariant::OneSided,
                grad_avg: 2,
                max_step: 0.05,
                ..base.clone()
            },
        ),
        (
            "large step clip (0.4)",
            SpsaConfig {
                variant: SpsaVariant::OneSided,
                grad_avg: 2,
                max_step: 0.4,
                ..base.clone()
            },
        ),
    ];

    for (label, cfg) in cells {
        let (t, obs, iters) = run_cell(cfg.clone(), &seeds);
        table.row(vec![
            label.to_string(),
            cfg.grad_avg.to_string(),
            format!("{}", cfg.max_step),
            format!("{iters:.0}"),
            format!("{obs:.0}"),
            format!("{t:.0}"),
        ]);
    }

    let report = table.to_ascii();
    opts.persist("ablation", &table);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_produces_all_cells() {
        let report = run(&ExpOptions::quick());
        assert!(report.contains("one-sided (paper)"));
        assert!(report.contains("one-measurement"));
        assert!(report.contains("large step clip"));
        assert!(report.lines().filter(|l| l.contains("0.")).count() >= 5);
    }

    #[test]
    fn cheaper_estimators_get_more_iterations_at_equal_budget() {
        // one-measurement costs 2 obs/iter vs one-sided+avg2's 3: the
        // broker must grant it 45 iterations to the paper variant's 30.
        let base = SpsaConfig {
            grad_tol: 0.0,
            patience: u64::MAX,
            max_iters: u64::MAX,
            ..Default::default()
        };
        let (_, obs_paper, iters_paper) = run_cell(
            SpsaConfig { variant: SpsaVariant::OneSided, grad_avg: 2, ..base.clone() },
            &[11],
        );
        let (_, obs_one, iters_one) = run_cell(
            SpsaConfig { variant: SpsaVariant::OneMeasurement, grad_avg: 1, ..base },
            &[11],
        );
        assert_eq!(obs_paper, 90.0);
        assert_eq!(obs_one, 90.0);
        assert_eq!(iters_paper, 30.0);
        assert_eq!(iters_one, 45.0);
    }
}
