//! Shared experiment infrastructure: options, campaign helpers and
//! formatting used by every table/figure regeneration module.

use crate::config::HadoopVersion;
use crate::coordinator::{run_campaign, Algo, ResultsDir, TrialOutcome, TrialSpec};
use crate::tuner::Budget;
use crate::util::stats::mean;
use crate::workloads::Benchmark;

/// Experiment options.
pub struct ExpOptions {
    /// Quick mode: fewer seeds/iterations — used by `cargo bench` smoke
    /// passes; full mode regenerates the EXPERIMENTS.md numbers.
    pub quick: bool,
    /// Where to persist tables (None = stdout only).
    pub out: Option<ResultsDir>,
}

impl ExpOptions {
    pub fn quick() -> Self {
        ExpOptions { quick: true, out: None }
    }

    pub fn full_to(dir: ResultsDir) -> Self {
        ExpOptions { quick: false, out: Some(dir) }
    }

    pub fn seeds(&self) -> Vec<u64> {
        if self.quick {
            vec![11]
        } else {
            vec![11, 23, 37]
        }
    }

    pub fn iters(&self) -> u64 {
        if self.quick {
            20
        } else {
            30
        }
    }

    /// The shared live-observation budget of every trial: 3 observations
    /// per SPSA iteration (paper estimator + gradient averaging), so all
    /// algorithms of a comparison spend the same currency.
    pub fn budget(&self) -> Budget {
        Budget::obs(3 * self.iters())
    }

    /// Persist a table if an output directory is configured.
    #[allow(clippy::print_stderr)] // best-effort persistence: warn, don't fail the run
    pub fn persist(&self, name: &str, table: &crate::util::table::Table) {
        if let Some(dir) = &self.out {
            if let Err(e) = dir.write_table(name, table) {
                eprintln!("warning: failed to write {name}: {e}");
            }
        }
    }

    #[allow(clippy::print_stderr)] // best-effort persistence: warn, don't fail the run
    pub fn persist_text(&self, name: &str, text: &str) {
        if let Some(dir) = &self.out {
            if let Err(e) = dir.write_text(name, text) {
                eprintln!("warning: failed to write {name}: {e}");
            }
        }
    }
}

/// Run `algo` on every benchmark for one Hadoop version across the option
/// seeds; returns outcomes grouped by benchmark (mean-aggregated helper
/// below).
pub fn campaign_for(
    algos: &[Algo],
    version: HadoopVersion,
    opts: &ExpOptions,
) -> Vec<TrialOutcome> {
    let mut specs = Vec::new();
    for &algo in algos {
        for bench in Benchmark::all() {
            for &seed in &opts.seeds() {
                specs.push(
                    TrialSpec::new(bench, version, algo, seed).with_budget(opts.budget()),
                );
            }
        }
    }
    run_campaign(specs)
}

/// Mean tuned execution time for (benchmark, algo) across seeds.
pub fn mean_time(outcomes: &[TrialOutcome], bench: Benchmark, algo: Algo) -> f64 {
    let xs: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.spec.benchmark == bench && o.spec.algo == algo)
        .map(|o| o.tuned_mean_s)
        .collect();
    mean(&xs)
}

/// Mean % decrease vs default for (benchmark, algo).
pub fn mean_decrease(outcomes: &[TrialOutcome], bench: Benchmark, algo: Algo) -> f64 {
    let xs: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.spec.benchmark == bench && o.spec.algo == algo)
        .map(|o| o.pct_decrease())
        .collect();
    mean(&xs)
}

pub fn fmt_s(x: f64) -> String {
    format!("{x:.0}")
}

pub fn fmt_pct(x: f64) -> String {
    format!("{x:.0}%")
}
