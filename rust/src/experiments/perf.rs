//! `repro bench` — the simulator perf-measurement layer (ROADMAP item 1's
//! missing baseline): run the hot-path workloads from `benches/perf_sim.rs`
//! / `perf_batch.rs` under the micro-bench harness, reduce each case to
//! median ns/run, ns/event and events/sec (plus allocation metering when
//! the counting allocator is installed — binary only, see `util::alloc`),
//! and diff against the committed `BENCH_sim.json` trajectory point with a
//! deliberately generous gate.
//!
//! Numbers are machine-dependent; the gate guards against order-of-
//! magnitude regressions (an accidental clone in the event loop, a
//! per-event allocation), not single-digit percent drift. The committed
//! baseline is regenerated with `repro bench --update-baseline` on the CI
//! runner class, never on a laptop.

use crate::cluster::ClusterSpec;
use crate::config::ParameterSpace;
use crate::coordinator::profile_for;
use crate::sim::{
    simulate_batch, simulate_with_buffers, ScenarioSpec, SimBuffers, SimCounters, SimJob,
    SimOptions,
};
use crate::tuner::{Budget, CachePolicy, EvalBroker, SimObjective};
use crate::util::alloc;
use crate::util::bench::{bench, black_box};
use crate::util::json::Json;
use crate::workloads::Benchmark;

/// Regression gate: ns/event may grow at most this factor over baseline.
pub const NS_PER_EVENT_FACTOR: f64 = 4.0;
/// Allocation gate: allocs/run ≤ factor × baseline + slack.
pub const ALLOCS_FACTOR: f64 = 1.25;
pub const ALLOCS_SLACK: f64 = 512.0;
/// Peak-live-bytes gate: ≤ factor × baseline + slack.
pub const PEAK_FACTOR: f64 = 1.5;
pub const PEAK_SLACK: f64 = 65536.0;

/// One measured benchmark × scenario point.
#[derive(Clone, Debug, PartialEq)]
pub struct CaseResult {
    pub name: String,
    /// Events dispatched by one run — deterministic (fixed seed), so the
    /// committed value doubles as a cheap physics cross-check.
    pub events_per_run: u64,
    pub median_ns_per_run: f64,
    pub ns_per_event: f64,
    pub events_per_sec: f64,
    /// Allocator calls per run; `None` when the counting allocator is not
    /// installed (library/test builds).
    pub allocs_per_run: Option<f64>,
    /// Process-wide live-heap high-water mark after this case ran. The
    /// counter is monotone, so the value folds in every earlier case —
    /// comparable across runs because case order is fixed.
    pub peak_live_bytes: Option<f64>,
    /// Cost-model evaluations per (steady-state) run — after the warm-up
    /// run, so warm-cache cases report their warm number. Deterministic;
    /// informational, never gated.
    pub cost_evals_per_run: u64,
    /// Warm-cache lookups served per (steady-state) run. Informational.
    pub warm_hits_per_run: u64,
}

/// The per-run meter sample a bench case's closure reports: the event
/// count (ns/event denominator) plus the costing meters.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunMeter {
    pub events: u64,
    pub cost_evals: u64,
    pub warm_hits: u64,
}

impl RunMeter {
    pub fn of(c: &SimCounters) -> Self {
        RunMeter { events: c.events, cost_evals: c.cost_evals, warm_hits: c.warm_hits }
    }

    /// Fold another run into this sample (multi-run cases: batch waves).
    pub fn add(&mut self, c: &SimCounters) {
        self.events += c.events;
        self.cost_evals += c.cost_evals;
        self.warm_hits += c.warm_hits;
    }
}

/// The fail5 tier of the golden matrix (kept in sync with
/// `rust/tests/golden_traces.rs`): failures + two slow nodes + one mid-job
/// crash + speculation.
fn faulty_scenario() -> ScenarioSpec {
    ScenarioSpec::default()
        .with_failures(0.05)
        .with_max_attempts(8)
        .with_slow_node(2, 0.6)
        .with_slow_node(5, 0.7)
        .with_crash(240.0, 1)
        .with_speculation(true)
}

/// Measure one case. `run` executes the workload once and returns its
/// [`RunMeter`]. The first call is a discarded warm-up; the second call's
/// meter is the reference, so cases sharing a buffer pool report their
/// steady-state (warm) costing meters. The event count is deterministic
/// and identical across runs either way.
fn measure<F: FnMut() -> RunMeter>(name: &str, quick: bool, mut run: F) -> CaseResult {
    black_box(run());
    let meter = run();
    let events_per_run = meter.events;
    // allocation metering over a fixed window, separate from the timed
    // loop so the snapshot reads don't sit on the timed path
    let alloc_runs: u64 = if quick { 3 } else { 10 };
    let before = alloc::snapshot();
    for _ in 0..alloc_runs {
        black_box(run());
    }
    let after = alloc::snapshot();
    let metered = alloc::metering_available();
    let allocs_per_run = if metered {
        Some((after.total_allocs.saturating_sub(before.total_allocs)) as f64 / alloc_runs as f64)
    } else {
        None
    };
    let (warmup, min_iters, min_time_s) = if quick { (1, 5, 0.05) } else { (2, 20, 0.5) };
    let r = bench(name, warmup, min_iters, min_time_s, || {
        black_box(run());
    });
    let ev = events_per_run.max(1) as f64;
    CaseResult {
        name: name.to_string(),
        events_per_run,
        median_ns_per_run: r.median_ns,
        ns_per_event: r.median_ns / ev,
        events_per_sec: ev * 1e9 / r.median_ns.max(1e-9),
        allocs_per_run,
        peak_live_bytes: if metered { Some(after.peak_live_bytes as f64) } else { None },
        cost_evals_per_run: meter.cost_evals,
        warm_hits_per_run: meter.warm_hits,
    }
}

/// Run the full case matrix: 5 paper benchmarks × {benign, fail5}, the
/// tuned Terasort-95reducers profile, and a sequential 8-job
/// `simulate_batch` wave (the buffer-reuse path). Case order is fixed —
/// the peak-live metric depends on it.
pub fn run_all(quick: bool) -> Vec<CaseResult> {
    let cluster = ClusterSpec::paper_cluster();
    let space = ParameterSpace::v1();
    let config = space.default_config();
    let mut out = Vec::new();
    let mut bufs = SimBuffers::new();
    for b in Benchmark::all() {
        let w = profile_for(b, 1000);
        for (stag, scenario) in [("benign", ScenarioSpec::default()), ("fail5", faulty_scenario())]
        {
            let opts = SimOptions { seed: 42, noise: true, scenario };
            let name = format!("sim/{}/{stag}", b.label().replace(' ', "_"));
            out.push(measure(&name, quick, || {
                RunMeter::of(
                    &simulate_with_buffers(&cluster, &config, &w, &opts, &mut bufs).counters,
                )
            }));
        }
    }
    // tuned configuration (more reducers = more events), as in perf_sim.rs
    let w = profile_for(Benchmark::Terasort, 1000);
    let mut tuned = space.default_config();
    tuned.reduce_tasks = 95;
    tuned.io_sort_mb = 500;
    let opts = SimOptions { seed: 42, noise: true, ..Default::default() };
    out.push(measure("sim/Terasort-95reducers/benign", quick, || {
        RunMeter::of(&simulate_with_buffers(&cluster, &tuned, &w, &opts, &mut bufs).counters)
    }));
    // sequential batch wave: one buffer pool amortized across 8 jobs
    let jobs: Vec<SimJob> = (0..8)
        .map(|i| SimJob {
            config: config.clone(),
            opts: SimOptions { seed: i + 1, noise: true, ..Default::default() },
        })
        .collect();
    out.push(measure("batch/Terasort-8jobs/seq", quick, || {
        let mut m = RunMeter::default();
        for r in simulate_batch(&cluster, jobs.clone(), &w, 1) {
            m.add(&r.counters);
        }
        m
    }));
    // Level-1 showcase: a benign homogeneous run priced entirely through
    // the per-run cost tables. Own pool so its meters aren't colored by
    // the mixed traffic above; the alloc meter verifies the launch paths
    // stay allocation-free (no per-launch TaskRates).
    let mut hom_bufs = SimBuffers::new();
    let opts = SimOptions { seed: 42, noise: true, ..Default::default() };
    out.push(measure("sim/homogeneous-costing/benign", quick, || {
        RunMeter::of(&simulate_with_buffers(&cluster, &config, &w, &opts, &mut hom_bufs).counters)
    }));
    // Level-2 showcase: a percentile wave (same θ/profile/cluster, seeds
    // varied) through one pool. After the cold first run every wave is a
    // warm benign twin, so the steady-state meter reports warm_hits > 0
    // and far fewer cost_evals than the cold homogeneous case above.
    let mut wave_bufs = SimBuffers::new();
    out.push(measure("warm/Terasort-percentile-wave", quick, || {
        let mut m = RunMeter::default();
        for k in 0..4 {
            let opts = SimOptions { seed: 4242 + k, noise: true, ..Default::default() };
            m.add(&simulate_with_buffers(&cluster, &config, &w, &opts, &mut wave_bufs).counters);
        }
        m
    }));
    // Contended wave: one 12-probe wave through the broker's slot-charging
    // path (3 slots → 4 sub-waves of duration maxima), the scheduler's
    // cost-model hot loop. The broker hides the per-job SimCounters, so
    // the meter counts dispatched observations and ns/event here reads as
    // ns/observation. Fresh objective + broker per run keeps the
    // positional obs seeds — and therefore the charge — bit-identical
    // across iterations.
    let wave: Vec<Vec<f64>> = (0..12)
        .map(|i| vec![(i as f64 + 0.5) / 12.0; space.dim()])
        .collect();
    out.push(measure("broker/Terasort-contended-wave/3slots", quick, || {
        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 777);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(64))
            .with_cache(CachePolicy::Off)
            .with_slots(3);
        let fs = broker.try_eval_batch(&wave);
        black_box(broker.elapsed_model_time());
        RunMeter { events: fs.len() as u64, cost_evals: 0, warm_hits: 0 }
    }));
    out
}

fn opt_num(v: Option<f64>) -> Json {
    v.map_or(Json::Null, Json::Num)
}

/// Serialize results in the committed `BENCH_sim.json` shape.
pub fn to_json(cases: &[CaseResult], quick: bool) -> Json {
    let mut root = Json::obj();
    root.set("generated_by", Json::Str("repro bench".into()))
        .set("quick", Json::Bool(quick))
        .set(
            "note",
            Json::Str(
                "Simulator perf trajectory point. Machine-dependent medians; the CI gate \
                 is deliberately generous (see README, Performance & benchmarking). \
                 Regenerate on the CI runner class with `repro bench --update-baseline`."
                    .into(),
            ),
        );
    let mut arr = Vec::new();
    for c in cases {
        let mut j = Json::obj();
        j.set("name", Json::Str(c.name.clone()))
            .set("events_per_run", Json::Num(c.events_per_run as f64))
            .set("median_ns_per_run", Json::Num(c.median_ns_per_run))
            .set("ns_per_event", Json::Num(c.ns_per_event))
            .set("events_per_sec", Json::Num(c.events_per_sec))
            .set("allocs_per_run", opt_num(c.allocs_per_run))
            .set("peak_live_bytes", opt_num(c.peak_live_bytes))
            .set("cost_evals_per_run", Json::Num(c.cost_evals_per_run as f64))
            .set("warm_hits_per_run", Json::Num(c.warm_hits_per_run as f64));
        arr.push(j);
    }
    root.set("cases", Json::Arr(arr));
    root
}

/// Extract the case list from a parsed baseline document. Unknown shapes
/// degrade to an empty list (→ advisory mode), never an error: the first
/// committed baseline intentionally has no cases until CI seals real
/// numbers.
pub fn parse_cases(doc: &Json) -> Vec<CaseResult> {
    let mut out = Vec::new();
    let Some(cases) = doc.get("cases").and_then(Json::as_arr) else {
        return out;
    };
    for c in cases {
        let Some(name) = c.get("name").and_then(Json::as_str) else {
            continue;
        };
        let num = |k: &str| c.get(k).and_then(Json::as_f64);
        out.push(CaseResult {
            name: name.to_string(),
            events_per_run: num("events_per_run").unwrap_or(0.0) as u64,
            median_ns_per_run: num("median_ns_per_run").unwrap_or(0.0),
            ns_per_event: num("ns_per_event").unwrap_or(0.0),
            events_per_sec: num("events_per_sec").unwrap_or(0.0),
            allocs_per_run: num("allocs_per_run"),
            peak_live_bytes: num("peak_live_bytes"),
            cost_evals_per_run: num("cost_evals_per_run").unwrap_or(0.0) as u64,
            warm_hits_per_run: num("warm_hits_per_run").unwrap_or(0.0) as u64,
        });
    }
    out
}

/// Baseline case names no longer present in the current case list —
/// advisory, so a renamed or removed case can't silently rot in
/// `BENCH_sim.json` while `check` skips it. Reseal the baseline with
/// `repro bench --update-baseline` to clear them.
pub fn stale_cases(current: &[CaseResult], baseline: &[CaseResult]) -> Vec<String> {
    baseline
        .iter()
        .filter(|b| !current.iter().any(|c| c.name == b.name))
        .map(|b| b.name.clone())
        .collect()
}

/// Diff fresh results against a baseline. Returns one human-readable
/// violation per breached gate; cases absent from the baseline (or metrics
/// recorded as null on either side) are advisory and produce nothing.
pub fn check(current: &[CaseResult], baseline: &[CaseResult]) -> Vec<String> {
    let mut violations = Vec::new();
    for cur in current {
        let Some(base) = baseline.iter().find(|b| b.name == cur.name) else {
            continue;
        };
        if base.ns_per_event > 0.0 && cur.ns_per_event > base.ns_per_event * NS_PER_EVENT_FACTOR {
            violations.push(format!(
                "{}: ns/event {:.1} exceeds {NS_PER_EVENT_FACTOR}x baseline {:.1}",
                cur.name, cur.ns_per_event, base.ns_per_event
            ));
        }
        if let (Some(c), Some(b)) = (cur.allocs_per_run, base.allocs_per_run) {
            if c > b * ALLOCS_FACTOR + ALLOCS_SLACK {
                violations.push(format!(
                    "{}: allocs/run {c:.0} exceeds {ALLOCS_FACTOR}x baseline {b:.0} + {ALLOCS_SLACK:.0}",
                    cur.name
                ));
            }
        }
        if let (Some(c), Some(b)) = (cur.peak_live_bytes, base.peak_live_bytes) {
            if c > b * PEAK_FACTOR + PEAK_SLACK {
                violations.push(format!(
                    "{}: peak live bytes {c:.0} exceeds {PEAK_FACTOR}x baseline {b:.0} + {PEAK_SLACK:.0}",
                    cur.name
                ));
            }
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case(name: &str, ns_per_event: f64, allocs: Option<f64>, peak: Option<f64>) -> CaseResult {
        CaseResult {
            name: name.to_string(),
            events_per_run: 1000,
            median_ns_per_run: ns_per_event * 1000.0,
            ns_per_event,
            events_per_sec: 1e9 / ns_per_event,
            allocs_per_run: allocs,
            peak_live_bytes: peak,
            cost_evals_per_run: 0,
            warm_hits_per_run: 0,
        }
    }

    #[test]
    fn json_roundtrip_preserves_cases() {
        let mut cases =
            vec![case("sim/Terasort/benign", 120.0, Some(40.0), Some(1e6)), case("x", 5.0, None, None)];
        cases[0].cost_evals_per_run = 77;
        cases[0].warm_hits_per_run = 3;
        let doc = to_json(&cases, true);
        let parsed = Json::parse(&doc.to_pretty()).expect("own output parses");
        assert_eq!(parse_cases(&parsed), cases);
        assert_eq!(parsed.get("quick").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn empty_or_alien_baseline_is_advisory() {
        let cur = vec![case("a", 100.0, Some(10.0), Some(1e6))];
        assert!(check(&cur, &[]).is_empty());
        assert!(check(&cur, &[case("other", 1.0, None, None)]).is_empty());
        let doc = Json::parse("{\"note\": \"no cases yet\"}").expect("valid json");
        assert!(parse_cases(&doc).is_empty());
    }

    #[test]
    fn gates_trip_on_order_of_magnitude_regressions() {
        let base = vec![case("a", 100.0, Some(100.0), Some(1e6))];
        // within the generous envelope: 2x time, +25% allocs, +50% peak
        let ok = vec![case("a", 200.0, Some(125.0), Some(1.5e6))];
        assert!(check(&ok, &base).is_empty());
        let slow = vec![case("a", 500.0, Some(100.0), Some(1e6))];
        assert_eq!(check(&slow, &base).len(), 1);
        let leaky = vec![case("a", 100.0, Some(5000.0), Some(1e8))];
        assert_eq!(check(&leaky, &base).len(), 2);
    }

    #[test]
    fn null_metrics_skip_their_gates() {
        let base = vec![case("a", 100.0, None, None)];
        let cur = vec![case("a", 150.0, Some(1e9), Some(1e12))];
        assert!(check(&cur, &base).is_empty(), "null baseline metrics must not gate");
    }

    #[test]
    fn measure_reports_consistent_event_arithmetic() {
        let mut n = 0u64;
        let r = measure("test/noop", true, || {
            n += 1;
            black_box(n);
            RunMeter { events: 2000, cost_evals: 150, warm_hits: 7 }
        });
        assert_eq!(r.events_per_run, 2000);
        assert_eq!(r.cost_evals_per_run, 150);
        assert_eq!(r.warm_hits_per_run, 7);
        assert!(r.ns_per_event >= 0.0);
        assert!((r.ns_per_event - r.median_ns_per_run / 2000.0).abs() < 1e-9);
        // library/test builds have no counting allocator installed
        assert_eq!(r.allocs_per_run, None);
        assert_eq!(r.peak_live_bytes, None);
    }

    #[test]
    fn stale_baseline_cases_are_flagged_not_ignored() {
        let cur = vec![case("a", 100.0, None, None)];
        let base =
            vec![case("a", 100.0, None, None), case("sim/renamed/benign", 1.0, None, None)];
        assert_eq!(stale_cases(&cur, &base), vec!["sim/renamed/benign".to_string()]);
        // stale entries are advisory: they never become gate violations
        assert!(check(&cur, &base).is_empty());
        assert!(stale_cases(&base, &base).is_empty());
    }

    #[test]
    fn empty_baseline_never_hard_gates() {
        // The first committed BENCH_sim.json carries no cases until CI
        // seals real numbers; an empty baseline must produce neither
        // violations nor stale flags, so the gate cannot trip vacuously.
        let doc = Json::parse("{\"cases\": []}").expect("valid json");
        let base = parse_cases(&doc);
        assert!(base.is_empty());
        let cur = vec![case("a", 100.0, Some(10.0), Some(1e6))];
        assert!(check(&cur, &base).is_empty());
        assert!(stale_cases(&cur, &base).is_empty());
    }
}
