//! Robustness experiment: tuner quality when the execution substrate
//! misbehaves — the scenario-engine counterpart of Fig. 8/Table 2.
//!
//! Every tuner runs at failure tiers 0 / 5 / 15 % (each non-zero tier adds
//! two slow nodes and speculative execution — the heterogeneous fleet of
//! the acceptance scenario). Live-system tuners (SPSA, random search)
//! observe the faulty system directly; model-based tuners (Starfish, PPABS)
//! profile as usual and have their configurations *evaluated* under the
//! faults. The paper's §4.2 argument predicts SPSA degrades gracefully: the
//! extra noise from re-execution is exactly what the SPSA iterates already
//! filter.

use crate::config::HadoopVersion;
use crate::coordinator::{run_campaign, Algo, TrialOutcome, TrialSpec};
use crate::sim::ScenarioSpec;
use crate::util::table::Table;
use crate::workloads::Benchmark;

use super::common::ExpOptions;

/// The failure tiers of the full robustness table.
pub const FAILURE_RATES: [f64; 3] = [0.0, 0.05, 0.15];

/// The scenario of one tier: task failures at `rate`, two slow nodes
/// (workers 2 @ 0.6× and 5 @ 0.7×) and speculative execution on. Rate 0 is
/// the benign cluster every other experiment uses.
pub fn tier_scenario(rate: f64) -> ScenarioSpec {
    if rate <= 0.0 {
        return ScenarioSpec::default();
    }
    ScenarioSpec::default()
        .with_failures(rate)
        .with_max_attempts(8)
        .with_slow_node(2, 0.6)
        .with_slow_node(5, 0.7)
        .with_speculation(true)
}

fn find<'a>(
    outcomes: &'a [TrialOutcome],
    bench: Benchmark,
    algo: Algo,
    rate: f64,
) -> Option<&'a TrialOutcome> {
    outcomes.iter().find(|o| {
        o.spec.benchmark == bench
            && o.spec.algo == algo
            && (o.spec.scenario.task_failure_p - rate).abs() < 1e-9
    })
}

pub fn run(opts: &ExpOptions) -> String {
    // Full mode compares the ENTIRE registry — all ten algorithms under
    // one identical observation budget per tier; quick keeps the two
    // cheapest live tuners so the smoke pass stays fast.
    let algos: Vec<Algo> =
        if opts.quick { vec![Algo::Spsa, Algo::Random] } else { Algo::all().to_vec() };
    let rates: Vec<f64> = if opts.quick { vec![0.0, 0.05] } else { FAILURE_RATES.to_vec() };
    let seed = opts.seeds()[0];
    let budget = opts.budget();

    let mut specs = Vec::new();
    for &rate in &rates {
        for &algo in &algos {
            for bench in Benchmark::all() {
                // PPABS tunes the v2 space (as in Fig. 9 / Table 2).
                let version =
                    if algo == Algo::Ppabs { HadoopVersion::V2 } else { HadoopVersion::V1 };
                specs.push(
                    TrialSpec::new(bench, version, algo, seed)
                        .with_scenario(tier_scenario(rate))
                        .with_budget(budget),
                );
            }
        }
    }
    let outcomes = run_campaign(specs);
    for o in &outcomes {
        assert!(
            o.observations <= budget.max_obs,
            "{} overspent the shared budget under faults",
            o.spec.algo.label()
        );
    }

    // Table-1-style matrix: % decrease vs the (same-scenario) default,
    // one column per tuner × failure tier.
    let mut header = vec!["Benchmark".to_string()];
    for &rate in &rates {
        for a in &algos {
            header.push(format!("{} @{:.0}%", a.label(), rate * 100.0));
        }
    }
    let mut table =
        Table::new("Robustness — % decrease vs default under fault injection").header(header);
    for bench in Benchmark::all() {
        let mut row = vec![bench.label().to_string()];
        for &rate in &rates {
            for &algo in &algos {
                row.push(match find(&outcomes, bench, algo, rate) {
                    Some(o) => format!("{:.0}%", o.pct_decrease()),
                    None => "-".to_string(),
                });
            }
        }
        table.row(row);
    }

    // The wall-clock axis under faults: retries and re-execution make
    // every observation longer, so the SAME observation budget costs more
    // modeled tuning time at higher failure tiers — the hidden price of
    // tuning on a flaky cluster, averaged across benchmarks per tuner.
    let mut clock =
        Table::new("Robustness — modeled tuning wall-clock (s) per failure tier").header({
            let mut h = vec!["Tuner".to_string()];
            h.extend(rates.iter().map(|r| format!("@{:.0}%", r * 100.0)));
            h
        });
    for &algo in &algos {
        let mut row = vec![algo.label().to_string()];
        for &rate in &rates {
            let xs: Vec<f64> = outcomes
                .iter()
                .filter(|o| {
                    o.spec.algo == algo
                        && (o.spec.scenario.task_failure_p - rate).abs() < 1e-9
                })
                .map(|o| o.elapsed_model_s)
                .collect();
            row.push(format!("{:.0}", crate::util::stats::mean(&xs)));
        }
        clock.row(row);
    }

    // Convergence-under-faults summary (the acceptance criterion): SPSA's
    // tuned objective at the 5 % tier vs its failure-free tuned value.
    let mut report = String::new();
    let mut within = 0;
    let mut judged = 0;
    report.push_str("SPSA tuned objective: 5%-failure tier vs failure-free\n");
    for bench in Benchmark::all() {
        let (Some(faulty), Some(clean)) = (
            find(&outcomes, bench, Algo::Spsa, 0.05),
            find(&outcomes, bench, Algo::Spsa, 0.0),
        ) else {
            continue;
        };
        let ratio = faulty.tuned_mean_s / clean.tuned_mean_s;
        judged += 1;
        if ratio <= 1.10 {
            within += 1;
        }
        report.push_str(&format!(
            "  {:<20} {:>7.0}s vs {:>7.0}s  ratio {:.2}{}\n",
            bench.label(),
            faulty.tuned_mean_s,
            clean.tuned_mean_s,
            ratio,
            if ratio <= 1.10 { "  (within 10%)" } else { "" },
        ));
    }
    report.push_str(&format!(
        "{within}/{judged} benchmarks within 10% of the failure-free tuned value\n\n"
    ));
    report.push_str(&table.to_ascii());
    report.push('\n');
    report.push_str(&clock.to_ascii());
    opts.persist("robustness", &table);
    opts.persist("robustness_walltime", &clock);
    opts.persist_text("robustness_convergence", &report);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_scenarios_shape() {
        assert!(tier_scenario(0.0).is_benign());
        let t = tier_scenario(0.05);
        assert_eq!(t.task_failure_p, 0.05);
        assert_eq!(t.slow_nodes.len(), 2);
        assert!(t.speculative_maps && t.speculative_reduces);
    }

    #[test]
    fn robustness_quick_report_shape() {
        let report = run(&ExpOptions::quick());
        assert!(report.contains("SPSA"), "missing SPSA column");
        assert!(report.contains("@5%"), "missing 5% failure tier");
        assert!(report.contains("ratio"), "missing convergence summary");
        assert!(
            report.contains("modeled tuning wall-clock"),
            "missing the wall-clock-per-tier table"
        );
    }
}
