//! The AOT cost-model artifact as a [`CostEvaluator`]: Starfish's what-if
//! engine served by the compiled JAX/Pallas graph through PJRT — this is
//! the L1/L2 compute on the L3 hot path.

use crate::util::error::Result;

use crate::baselines::CostEvaluator;
use crate::config::ParameterSpace;
use crate::whatif::ClusterFeatures;
use crate::workloads::WorkloadProfile;

use super::client::{LoadedComputation, Runtime};

/// Batch size baked into the artifact (`python/compile/model.py::BATCH`).
pub const ARTIFACT_BATCH: usize = 256;
/// Perturbations baked into the SPSA-step artifact.
pub const ARTIFACT_K: usize = 8;
const N: usize = 11;

/// What-if engine backed by the `whatif_batch` artifact.
pub struct ArtifactWhatIf {
    comp: LoadedComputation,
    pub space: ParameterSpace,
    workload_features: Vec<f32>,
    cluster_features: Vec<f32>,
    evals: u64,
}

impl ArtifactWhatIf {
    pub fn new(
        runtime: &Runtime,
        space: ParameterSpace,
        workload: &WorkloadProfile,
        cluster: &ClusterFeatures,
    ) -> Result<Self> {
        Ok(ArtifactWhatIf {
            comp: runtime.load("whatif_batch")?,
            space,
            workload_features: workload.to_features(),
            cluster_features: cluster.to_features(),
            evals: 0,
        })
    }

    /// Evaluate a batch of Hadoop-space rows (padded internally to the
    /// artifact batch size).
    pub fn eval_rows(&mut self, rows: &[Vec<f32>]) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(ARTIFACT_BATCH) {
            let mut flat = vec![0f32; ARTIFACT_BATCH * N];
            for (i, row) in chunk.iter().enumerate() {
                assert_eq!(row.len(), N);
                flat[i * N..(i + 1) * N].copy_from_slice(row);
            }
            // pad with copies of the first row (cost discarded)
            for i in chunk.len()..ARTIFACT_BATCH {
                let src: Vec<f32> = flat[..N].to_vec();
                flat[i * N..(i + 1) * N].copy_from_slice(&src);
            }
            let res = self.comp.run_f32(&[
                (&flat, &[ARTIFACT_BATCH as i64, N as i64]),
                (&self.workload_features, &[11]),
                (&self.cluster_features, &[10]),
            ])?;
            out.extend(res[..chunk.len()].iter().map(|&x| x as f64));
        }
        self.evals += rows.len() as u64;
        Ok(out)
    }
}

impl CostEvaluator for ArtifactWhatIf {
    fn dim(&self) -> usize {
        self.space.dim()
    }

    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        let rows: Vec<Vec<f32>> =
            thetas.iter().map(|t| self.space.to_feature_row(t)).collect();
        self.eval_rows(&rows)
            .expect("artifact execution failed on the hot path")
    }

    fn model_evals(&self) -> u64 {
        self.evals
    }
}

/// Surrogate-SPSA step served by the `spsa_step` artifact.
pub struct ArtifactSpsaStep {
    comp: LoadedComputation,
    space_spec: Vec<f32>,
    workload_features: Vec<f32>,
    cluster_features: Vec<f32>,
}

/// Decoded output of one surrogate step.
#[derive(Clone, Debug)]
pub struct SpsaStepOut {
    pub theta_next: Vec<f64>,
    pub f_theta: f64,
    pub ghat: Vec<f64>,
}

impl ArtifactSpsaStep {
    pub fn new(
        runtime: &Runtime,
        space: &ParameterSpace,
        workload: &WorkloadProfile,
        cluster: &ClusterFeatures,
    ) -> Result<Self> {
        // [4, n] spec rows: min, width, is_int, is_bool
        let mut spec = Vec::with_capacity(4 * N);
        for p in space.params() {
            spec.push(p.min as f32);
        }
        for p in space.params() {
            spec.push(p.width() as f32);
        }
        for p in space.params() {
            spec.push((p.kind == crate::config::ParamKind::Int) as u8 as f32);
        }
        for p in space.params() {
            spec.push((p.kind == crate::config::ParamKind::Bool) as u8 as f32);
        }
        Ok(ArtifactSpsaStep {
            comp: runtime.load("spsa_step")?,
            space_spec: spec,
            workload_features: workload.to_features(),
            cluster_features: cluster.to_features(),
        })
    }

    /// One iteration: θ, K sign rows, c scales, (α, max_step) → decoded out.
    pub fn step(
        &self,
        theta: &[f64],
        signs: &[Vec<f64>],
        c_scales: &[f64],
        alpha: f64,
        max_step: f64,
    ) -> Result<SpsaStepOut> {
        assert_eq!(theta.len(), N);
        assert_eq!(signs.len(), ARTIFACT_K);
        let theta32: Vec<f32> = theta.iter().map(|&x| x as f32).collect();
        let mut signs32 = Vec::with_capacity(ARTIFACT_K * N);
        for row in signs {
            assert_eq!(row.len(), N);
            signs32.extend(row.iter().map(|&x| x as f32));
        }
        let c32: Vec<f32> = c_scales.iter().map(|&x| x as f32).collect();
        let hyper = [alpha as f32, max_step as f32];
        let out = self.comp.run_f32(&[
            (&theta32, &[N as i64]),
            (&signs32, &[ARTIFACT_K as i64, N as i64]),
            (&c32, &[N as i64]),
            (&self.workload_features, &[11]),
            (&self.cluster_features, &[10]),
            (&self.space_spec, &[4, N as i64]),
            (&hyper, &[2]),
        ])?;
        assert_eq!(out.len(), 2 * N + 1, "spsa_step output length");
        Ok(SpsaStepOut {
            theta_next: out[..N].iter().map(|&x| x as f64).collect(),
            f_theta: out[N] as f64,
            ghat: out[N + 1..].iter().map(|&x| x as f64).collect(),
        })
    }
}

// Execution-level tests live in rust/tests/integration_runtime.rs.
