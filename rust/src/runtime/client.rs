//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client — the rust half of the AOT bridge (see `python/compile/aot.py`
//! and /opt/xla-example/load_hlo for the reference wiring).
//!
//! One [`Runtime`] owns the PJRT client; each artifact compiles once into a
//! [`LoadedComputation`] that the hot path executes repeatedly.
//!
//! **Feature gating.** The real implementation needs the `xla` crate,
//! which is not vendored in the offline build; it compiles only with
//! `--features pjrt`. The default build ships an API-compatible stub whose
//! constructor reports the runtime as unavailable — callers already probe
//! [`Runtime::artifacts_present`] first (the artifacts can only have been
//! produced in an environment that also provides PJRT), so the offline
//! path degrades to "skipped" everywhere.

use std::path::Path;

use crate::util::error::Result;

/// Default artifact directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// True if the artifact files exist (lets tests skip gracefully when
/// `make artifacts` has not run).
fn artifacts_present_in(dir: &Path) -> bool {
    dir.join("whatif_batch.hlo.txt").exists() && dir.join("spsa_step.hlo.txt").exists()
}

#[cfg(feature = "pjrt")]
mod imp {
    use std::path::{Path, PathBuf};

    use crate::util::error::{Context, Error, Result};

    /// Owns the PJRT CPU client.
    pub struct Runtime {
        client: xla::PjRtClient,
        artifact_dir: PathBuf,
    }

    /// A compiled executable plus its entry metadata.
    pub struct LoadedComputation {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Runtime {
        /// Create a CPU PJRT client rooted at `artifact_dir`.
        pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile `<name>.hlo.txt` from the artifact directory.
        pub fn load(&self, name: &str) -> Result<LoadedComputation> {
            let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::msg("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            Ok(LoadedComputation { exe, name: name.to_string() })
        }
    }

    impl LoadedComputation {
        /// Execute with f32 tensor inputs given as (data, dims) pairs;
        /// returns the flattened f32 contents of the first tuple element.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let lit = xla::Literal::vec1(data);
                let lit = if dims.len() == 1 && dims[0] as usize == data.len() {
                    lit
                } else {
                    lit.reshape(dims)
                        .with_context(|| format!("reshape to {dims:?}"))?
                };
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {}", self.name))?;
            let out = result
                .first()
                .and_then(|d| d.first())
                .ok_or_else(|| Error::msg(format!("no output buffer from {}", self.name)))?
                .to_literal_sync()
                .context("syncing output literal")?;
            // aot.py lowers with return_tuple=True: outputs are 1-tuples
            let inner = out.to_tuple1().context("unwrapping output tuple")?;
            inner.to_vec::<f32>().context("reading f32 output")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::{Path, PathBuf};

    use crate::util::error::{Error, Result};

    const UNAVAILABLE: &str = "PJRT runtime unavailable: built without the `pjrt` \
         feature (the `xla` crate is not vendored in the offline build)";

    /// Stub standing in for the PJRT client (see module docs).
    pub struct Runtime {
        #[allow(dead_code)]
        artifact_dir: PathBuf,
    }

    /// Stub compiled-executable handle; cannot be constructed without the
    /// `pjrt` feature (its only producer, `Runtime::load`, needs a
    /// `Runtime`, and `Runtime::new` always errors here).
    pub struct LoadedComputation {
        pub name: String,
    }

    impl Runtime {
        pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
            let _ = artifact_dir.as_ref();
            Err(Error::msg(UNAVAILABLE))
        }

        pub fn platform(&self) -> String {
            "unavailable (no pjrt feature)".to_string()
        }

        pub fn load(&self, _name: &str) -> Result<LoadedComputation> {
            Err(Error::msg(UNAVAILABLE))
        }
    }

    impl LoadedComputation {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            Err(Error::msg(UNAVAILABLE))
        }
    }
}

pub use imp::{LoadedComputation, Runtime};

impl Runtime {
    /// Create with the default `artifacts/` directory.
    pub fn default_dir() -> Result<Runtime> {
        Self::new(DEFAULT_ARTIFACT_DIR)
    }

    /// True if the artifact files exist (lets tests skip gracefully when
    /// `make artifacts` has not run).
    pub fn artifacts_present(dir: impl AsRef<Path>) -> bool {
        artifacts_present_in(dir.as_ref())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_present_detects_missing() {
        assert!(!Runtime::artifacts_present("/nonexistent"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::default_dir().unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }

    // Full load/execute coverage lives in rust/tests/integration_runtime.rs
    // (needs `make artifacts` and `--features pjrt`).
}
