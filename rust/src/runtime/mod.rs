//! PJRT runtime bridge: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and serves them
//! on the rust hot path. Python never runs at request time.

pub mod client;
pub mod whatif_artifact;

pub use client::{LoadedComputation, Runtime, DEFAULT_ARTIFACT_DIR};
pub use whatif_artifact::{
    ArtifactSpsaStep, ArtifactWhatIf, SpsaStepOut, ARTIFACT_BATCH, ARTIFACT_K,
};
