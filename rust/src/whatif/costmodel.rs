//! Analytic MapReduce cost model — the "what-if engine" of the
//! Starfish-style baseline and the reference semantics for the AOT-compiled
//! JAX/Pallas artifact (`python/compile/model.py` mirrors these formulas
//! term for term; `tests/integration_runtime.rs` asserts the two agree).
//!
//! The model is a *smooth* (ceil-free, branch-light) approximation of the
//! discrete-event simulator with the blind spots documented for
//! Starfish-class cost models — this approximation gap is exactly the
//! model-vs-reality gap the paper argues makes model-based tuners
//! (Starfish, PPABS) underperform direct-feedback SPSA (§3.1):
//!
//! 1. **Uncontended bandwidth** — prices IO at the node's full disk/NIC
//!    rate; the real cluster shares them across concurrent task slots.
//! 2. **Constant combiner ratio** — uses the profiled reduction verbatim;
//!    really the combiner dilutes as spills shrink (fewer duplicate keys
//!    per spill).
//! 3. **Uniform partitions** — ignores key skew; the real job's tail is
//!    its hottest reducer.
//! 4. **Free merge fan-in** — ignores the seek penalty of wide merges.
//! 5. **Perfect map/spill overlap** — ignores the map blocking when the
//!    buffer fills at high spill thresholds.
//! 6. **No memory pressure** — ignores the reduce-function slowdown when
//!    reduce.input.buffer.percent retains map outputs in the heap.
//!
//! Plus no locality misses, no queueing jitter, no noise, real-valued
//! wave counts.
//!
//! All three feature layouts are fixed and shared with the Python side:
//! * `params`   — 11 Hadoop values in [`crate::config::ParameterSpace`] order;
//! * `workload` — 11 features from [`crate::workloads::WorkloadProfile::to_features`];
//! * `cluster`  — 10 features from [`ClusterFeatures::to_features`].

use crate::cluster::ClusterSpec;
use crate::config::{HadoopVersion, ParameterSpace};
use crate::workloads::WorkloadProfile;

/// Framework constants (mirror `sim::constants`; duplicated into
/// `python/compile/model.py` — keep all three in sync).
pub const JVM_START_S: f64 = 1.4;
pub const TASK_LAUNCH_S: f64 = 0.15;
pub const JOB_OVERHEAD_S: f64 = 8.0; // setup + cleanup
pub const SPILL_FILE_S: f64 = 0.006;
pub const FILE_OPEN_S: f64 = 0.003;
pub const SORT_OPS_PER_CMP: f64 = 12.0;
pub const COMBINE_OPS_PER_REC: f64 = 18.0;
pub const COMPRESS_OPS_PER_BYTE: f64 = 5.0;
pub const DECOMPRESS_OPS_PER_BYTE: f64 = 1.5;
pub const MERGE_OPS_PER_BYTE: f64 = 0.4;
pub const MERGE_STREAM_SWEET_SPOT: f64 = 48.0;
pub const MERGE_STREAM_PENALTY_DIV: f64 = 96.0;
pub const REDUCE_MEM_PRESSURE_COEFF: f64 = 0.6;
pub const FETCH_OVERLAP_EFF: f64 = 0.5;

/// Number of cluster features in the shared layout.
pub const N_CLUSTER_FEATURES: usize = 10;

/// Cluster-side inputs of the cost model.
#[derive(Clone, Debug)]
pub struct ClusterFeatures {
    pub workers: f64,
    pub map_slots_per_node: f64,
    pub reduce_slots_per_node: f64,
    pub disk_bw: f64,
    pub net_bw: f64,
    pub cpu_ops_per_sec: f64,
    pub block_size: f64,
    pub heap_bytes: f64,
    pub replication: f64,
    /// 1.0 for Hadoop v1 semantics, 0.0 for v2.
    pub is_v1: f64,
}

impl ClusterFeatures {
    pub fn from_spec(spec: &ClusterSpec, version: HadoopVersion) -> Self {
        ClusterFeatures {
            workers: spec.workers() as f64,
            map_slots_per_node: spec.map_slots_per_node as f64,
            reduce_slots_per_node: spec.reduce_slots_per_node as f64,
            disk_bw: spec.node.disk_bw,
            net_bw: spec.node.net_bw,
            cpu_ops_per_sec: spec.node.cpu_ops_per_sec,
            block_size: (128u64 << 20) as f64,
            heap_bytes: (1u64 << 30) as f64,
            replication: 2.0,
            is_v1: if version == HadoopVersion::V1 { 1.0 } else { 0.0 },
        }
    }

    pub fn to_features(&self) -> Vec<f32> {
        vec![
            self.workers as f32,
            self.map_slots_per_node as f32,
            self.reduce_slots_per_node as f32,
            self.disk_bw as f32,
            self.net_bw as f32,
            self.cpu_ops_per_sec as f32,
            self.block_size as f32,
            self.heap_bytes as f32,
            self.replication as f32,
            self.is_v1 as f32,
        ]
    }
}

#[inline]
fn smooth_max(a: f64, b: f64) -> f64 {
    a.max(b)
}

/// Evaluate the analytic job time (seconds) for one parameter row.
///
/// `params` is the 11-value Hadoop-space row (ParameterSpace order),
/// `w` the workload features, `c` the cluster features.
pub fn cost_model(params: &[f64], w: &WorkloadProfile, c: &ClusterFeatures) -> f64 {
    assert_eq!(params.len(), 11);
    // ---- unpack parameter row (ParameterSpace order) ----------------------
    let io_sort_mb = params[0].max(1.0);
    let spill_pct = params[1].clamp(0.01, 0.99);
    let sort_factor = params[2].max(2.0);
    let shuf_in_pct = params[3].clamp(0.01, 0.99);
    let shuf_merge_pct = params[4].clamp(0.01, 0.99);
    let inmem_thresh = params[5].max(2.0);
    let red_in_pct = params[6].clamp(0.0, 0.9);
    let n_red = params[7].max(1.0);
    let is_v1 = c.is_v1;
    // version-dependent tail: v1 = [record%, compress_map, out_compress];
    // v2 = [slowstart, jvm_numtasks, job_maps]
    let rec_pct = is_v1 * params[8].clamp(0.01, 0.5) + (1.0 - is_v1) * 0.05;
    let compress_map = is_v1 * (params[9] > 0.5) as u8 as f64;
    let out_compress = is_v1 * (params[10] > 0.5) as u8 as f64;
    let slowstart = is_v1 * 0.05 + (1.0 - is_v1) * params[8].clamp(0.0, 1.0);
    let jvm_reuse = is_v1 + (1.0 - is_v1) * params[9].max(1.0);
    let job_maps = is_v1 * 2.0 + (1.0 - is_v1) * params[10].max(2.0);

    let has_comb = (w.combiner_reduction < 0.999) as u8 as f64;

    // ---- layout -------------------------------------------------------------
    let input = w.input_bytes as f64;
    let n_maps_nat = smooth_max(input / c.block_size, 1.0);
    let n_maps = is_v1 * n_maps_nat + (1.0 - is_v1) * smooth_max(n_maps_nat, job_maps);
    let split = input / n_maps;
    let map_slots = c.workers * c.map_slots_per_node;
    let red_slots = c.workers * c.reduce_slots_per_node;
    let map_waves = smooth_max(n_maps / map_slots, 1.0);
    let red_waves = smooth_max(n_red / red_slots, 1.0);

    // blind spot 1: the model assumes each task enjoys the node's full
    // disk/NIC bandwidth (the real cluster divides it across busy slots)
    let mdisk = c.disk_bw;
    let cpu = c.cpu_ops_per_sec;
    let rdisk = c.disk_bw;
    let rnet = c.net_bw;

    // ---- map task -----------------------------------------------------------
    let read = split / mdisk;
    let recs = split / w.avg_input_record_bytes;
    let map_cpu = recs * w.map_cpu_ops_per_record / cpu;
    let out_b = split * w.map_selectivity_bytes;
    let out_r = recs * w.map_selectivity_records;

    let buf = io_sort_mb * (1u64 << 20) as f64;
    let data_frac = is_v1 * (1.0 - rec_pct) + (1.0 - is_v1) * 0.95;
    let data_cap = (buf * data_frac * spill_pct).max(1.0);
    let rec_cap_total = is_v1 * (buf * rec_pct / 16.0) + (1.0 - is_v1) * (buf / 16.0);
    let rec_cap = (rec_cap_total * spill_pct).max(1.0);
    let n_spills = smooth_max(smooth_max(out_b / data_cap, out_r / rec_cap), 1.0);

    // blind spot 2: profiled combiner ratio applied verbatim (no spill
    // dilution)
    let r_eff = 1.0 - has_comb * (1.0 - w.combiner_reduction);
    let sort_cpu = out_r * (out_r / n_spills).max(2.0).log2() * SORT_OPS_PER_CMP / cpu;
    let comb_cpu = has_comb * out_r * COMBINE_OPS_PER_REC / cpu;
    let surv_b = out_b * r_eff;
    let disk_b = surv_b * (compress_map * w.compress_ratio + (1.0 - compress_map));
    let comp_cpu = compress_map * surv_b * COMPRESS_OPS_PER_BYTE / cpu;
    let spill_io = disk_b / mdisk + n_spills * SPILL_FILE_S;
    let spill_side = sort_cpu + comb_cpu + comp_cpu + spill_io;
    // blind spot 5: perfect map/spill pipeline overlap assumed
    let phase = map_cpu.max(spill_side);

    // merge (active when n_spills > 1; smooth gate)
    let merge_gate = ((n_spills - 1.0) / 0.5).clamp(0.0, 1.0);
    let passes = smooth_max(n_spills.ln() / sort_factor.ln(), 1.0);
    let streams = sort_factor.min(n_spills);
    // blind spot 4: merge fan-in priced seek-free
    let merge = merge_gate
        * (passes * disk_b * 2.0 / mdisk
            + passes * surv_b * MERGE_OPS_PER_BYTE / cpu
            + (n_spills + passes * streams) * FILE_OPEN_S);

    let setup = (JVM_START_S + (jvm_reuse - 1.0) * TASK_LAUNCH_S) / jvm_reuse;
    let map_task = setup + read + phase + merge;
    let map_total = map_waves * map_task;

    // ---- reduce task (critical path = hot partition) --------------------------
    let tot_raw = n_maps * surv_b;
    // blind spot 3: partitions assumed uniform (key skew ignored)
    let hot_vol = tot_raw / n_red;

    let wire = hot_vol * (compress_map * w.compress_ratio + (1.0 - compress_map));
    let fetch = wire / rnet + compress_map * wire * DECOMPRESS_OPS_PER_BYTE / cpu;

    let buffer = c.heap_bytes * shuf_in_pct;
    let byte_trig = (buffer * shuf_merge_pct).max(1.0);
    let segs = n_maps;
    let avg_seg = hot_vol / segs;
    let fits = ((byte_trig - hot_vol).signum().max(0.0))
        * ((inmem_thresh - segs).signum().max(0.0))
        * ((buffer - hot_vol).signum().max(0.0));
    let segs_per_flush = inmem_thresh.min((byte_trig / avg_seg.max(1.0)).max(1.0));
    let n_flush = (1.0 - fits) * smooth_max(segs / segs_per_flush, 1.0);
    let retained = c.heap_bytes * red_in_pct;
    let disk_bytes = (1.0 - fits) * (hot_vol - retained).max(0.0);

    let extra_passes = (n_flush.max(1.0).ln() / sort_factor.ln()).max(1.0) - 1.0;
    let rstreams = sort_factor.min(n_flush.max(1.0));
    let merge_gate_r = (n_flush / 1.0).clamp(0.0, 1.0);
    // blind spot 4 again: reduce-side merges priced seek-free
    let merge_r = merge_gate_r
        * (disk_bytes / rdisk
            + n_flush * SPILL_FILE_S
            + hot_vol * MERGE_OPS_PER_BYTE / cpu
            + extra_passes * disk_bytes * 2.0 / rdisk
            + (n_flush + extra_passes * rstreams) * FILE_OPEN_S
            + disk_bytes / rdisk);

    let red_recs = hot_vol / w.avg_map_record_bytes.max(1.0);
    // blind spot 6: no reduce-side memory-pressure penalty
    let red_cpu = red_recs * w.reduce_cpu_ops_per_record / cpu;

    let out_raw = hot_vol * w.reduce_selectivity_bytes;
    let out_b2 = out_raw * (out_compress * w.compress_ratio + (1.0 - out_compress));
    let comp_cpu2 = out_compress * out_raw * COMPRESS_OPS_PER_BYTE / cpu;
    let write = (out_b2 / rdisk).max(out_b2 * (c.replication - 1.0) / rnet) + comp_cpu2;

    let red_task = setup + fetch + merge_r + red_cpu + write;

    // slowstart overlap credit: the first reduce wave fetches during the map
    // phase from the slowstart point, at reduced efficiency.
    let credit = ((1.0 - slowstart) * map_total * FETCH_OVERLAP_EFF).min(fetch * 0.5);

    JOB_OVERHEAD_S + map_total + red_waves * red_task - credit
}

/// Evaluate a batch of parameter rows (the artifact's native shape).
pub fn cost_model_batch(rows: &[Vec<f64>], w: &WorkloadProfile, c: &ClusterFeatures) -> Vec<f64> {
    rows.iter().map(|r| cost_model(r, w, c)).collect()
}

/// Convenience: evaluate a θ_A point through a parameter space.
pub fn cost_for_theta(
    space: &ParameterSpace,
    theta: &[f64],
    w: &WorkloadProfile,
    c: &ClusterFeatures,
) -> f64 {
    // the model sees only the 11 framework knobs — the OS-extension tail
    // (if any) is below its modelling boundary (paper §7)
    let row: Vec<f64> = space
        .to_hadoop_values(theta)
        .iter()
        .take(crate::config::N_PARAMS)
        .map(|v| v.as_f64())
        .collect();
    cost_model(&row, w, c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ParameterSpace;

    fn wl() -> WorkloadProfile {
        WorkloadProfile {
            name: "tera".into(),
            input_bytes: 30 << 30,
            avg_input_record_bytes: 100.0,
            map_selectivity_bytes: 1.0,
            map_selectivity_records: 1.0,
            avg_map_record_bytes: 100.0,
            combiner_reduction: 1.0,
            has_combiner: false,
            reduce_selectivity_bytes: 1.0,
            partition_skew: 1.1,
            compress_ratio: 0.4,
            map_cpu_ops_per_record: 60.0,
            reduce_cpu_ops_per_record: 50.0,
        }
    }

    fn features(version: HadoopVersion) -> ClusterFeatures {
        ClusterFeatures::from_spec(&ClusterSpec::paper_cluster(), version)
    }

    fn row_for(space: &ParameterSpace, theta: &[f64]) -> Vec<f64> {
        space.to_hadoop_values(theta).iter().map(|v| v.as_f64()).collect()
    }

    #[test]
    fn default_config_is_expensive() {
        let space = ParameterSpace::v1();
        let c = features(HadoopVersion::V1);
        let t = cost_model(&row_for(&space, &space.default_theta()), &wl(), &c);
        assert!(t > 300.0, "default cost {t}");
        assert!(t.is_finite());
    }

    #[test]
    fn more_reducers_cheaper_for_heavy_shuffle() {
        let space = ParameterSpace::v1();
        let c = features(HadoopVersion::V1);
        let mut theta = space.default_theta();
        let base = cost_for_theta(&space, &theta, &wl(), &c);
        theta[7] = 0.9; // ~90 reducers
        let many = cost_for_theta(&space, &theta, &wl(), &c);
        assert!(many < base * 0.5, "many {many} base {base}");
    }

    #[test]
    fn bigger_sort_buffer_cheaper_map_phase() {
        let space = ParameterSpace::v1();
        let c = features(HadoopVersion::V1);
        let mut theta = space.default_theta();
        theta[7] = 0.5;
        let small = cost_for_theta(&space, &theta, &wl(), &c);
        theta[0] = 0.3; // 635 MB buffer
        theta[1] = 0.7; // sane spill threshold
        theta[8] = 0.3;
        let big = cost_for_theta(&space, &theta, &wl(), &c);
        assert!(big < small, "big {big} small {small}");
    }

    #[test]
    fn tracks_simulator_ordering() {
        // The what-if model need not match the DES in absolute terms, but
        // it must rank clearly-better configurations above clearly-worse
        // ones (otherwise Starfish could not optimize at all).
        use crate::sim::{simulate, SimOptions};
        let space = ParameterSpace::v1();
        let c = features(HadoopVersion::V1);
        let cluster = ClusterSpec::paper_cluster();
        let w = wl();
        let opts = SimOptions { seed: 9, noise: false, ..Default::default() };

        let mut bad = space.default_theta();
        bad[7] = 0.0; // 1 reducer
        let mut good = space.default_theta();
        good[0] = 0.25;
        good[1] = 0.6;
        good[7] = 0.9;
        good[8] = 0.3;

        let model_bad = cost_for_theta(&space, &bad, &w, &c);
        let model_good = cost_for_theta(&space, &good, &w, &c);
        let sim_bad = simulate(&cluster, &space.materialize(&bad), &w, &opts).exec_time_s;
        let sim_good = simulate(&cluster, &space.materialize(&good), &w, &opts).exec_time_s;
        assert!(model_good < model_bad);
        assert!(sim_good < sim_bad);
        // and the model is within a factor-3 band of the DES on both
        for (m, s) in [(model_bad, sim_bad), (model_good, sim_good)] {
            let ratio = m / s;
            assert!(ratio > 0.2 && ratio < 5.0, "model {m} sim {s}");
        }
    }

    #[test]
    fn v2_params_take_effect() {
        let space = ParameterSpace::v2();
        let c = features(HadoopVersion::V2);
        let mut theta = space.default_theta();
        theta[7] = 0.5;
        let fresh = cost_for_theta(&space, &theta, &wl(), &c);
        theta[9] = 1.0; // jvm reuse 30
        let reused = cost_for_theta(&space, &theta, &wl(), &c);
        assert!(reused < fresh);
    }

    #[test]
    fn batch_matches_scalar() {
        let space = ParameterSpace::v1();
        let c = features(HadoopVersion::V1);
        let rows: Vec<Vec<f64>> = (0..8)
            .map(|i| {
                let t: Vec<f64> = (0..11).map(|j| ((i * 11 + j) as f64 * 0.083) % 1.0).collect();
                row_for(&space, &t)
            })
            .collect();
        let batch = cost_model_batch(&rows, &wl(), &c);
        for (r, b) in rows.iter().zip(&batch) {
            assert_eq!(cost_model(r, &wl(), &c), *b);
        }
    }

    #[test]
    fn cluster_features_layout() {
        let c = features(HadoopVersion::V1);
        let f = c.to_features();
        assert_eq!(f.len(), N_CLUSTER_FEATURES);
        assert_eq!(f[0], 24.0);
        assert_eq!(f[9], 1.0);
    }
}
