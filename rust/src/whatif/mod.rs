//! Analytic "what-if" cost model: rust reference implementation of the
//! AOT-compiled JAX/Pallas cost model (see `python/compile/model.py`).
//! Powers the Starfish-style baseline and cross-checks artifact numerics.

pub mod costmodel;

pub use costmodel::{
    cost_for_theta, cost_model, cost_model_batch, ClusterFeatures, N_CLUSTER_FEATURES,
};
