//! Line/token-level Rust lexer for the lint engine.
//!
//! Deliberately **not** a parser (`syn` is unavailable in the offline
//! build, DESIGN.md §7): the rules only need an identifier/punctuation
//! stream with line numbers, with comments, string literals and char
//! literals stripped so that `HashMap` inside a doc comment or a format
//! string can never trigger a finding. Comments are captured separately —
//! in-source suppressions (`// lint:allow(rule): why`) live there.

/// Token class. The rules mostly match on [`Token::text`]; the kind
/// disambiguates lifetimes from char literals and numbers from idents.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Number,
    Punct,
    Lifetime,
}

/// One code token with its 1-indexed source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

/// Lexer output: the code-token stream plus every `//` comment (line
/// comments and doc comments), keyed by line, for suppression parsing.
#[derive(Debug, Default)]
pub struct LexOutput {
    pub tokens: Vec<Token>,
    /// `(line, comment text without the leading slashes)`.
    pub comments: Vec<(usize, String)>,
}

/// Multi-character punctuation kept as one token; everything the rules
/// match on sequences of (`::` paths, `->` return types, `=>` arms).
const MULTI_PUNCT: [&str; 3] = ["::", "->", "=>"];

struct Cursor<'a> {
    src: &'a [u8],
    pos: usize,
    line: usize,
}

impl<'a> Cursor<'a> {
    fn peek(&self, ahead: usize) -> Option<u8> {
        self.src.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.src.get(self.pos).copied();
        if let Some(c) = b {
            self.pos += 1;
            if c == b'\n' {
                self.line += 1;
            }
        }
        b
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_' || b >= 0x80
}

fn is_ident_continue(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

/// Tokenize `src`. Never fails: unterminated strings/comments consume to
/// end of input (the lint engine must degrade gracefully on any file the
/// compiler itself would reject — it runs pre-build in CI).
pub fn lex(src: &str) -> LexOutput {
    let mut cur = Cursor { src: src.as_bytes(), pos: 0, line: 1 };
    let mut out = LexOutput::default();

    while let Some(b) = cur.peek(0) {
        match b {
            b' ' | b'\t' | b'\r' | b'\n' => {
                cur.bump();
            }
            b'/' if cur.peek(1) == Some(b'/') => lex_line_comment(&mut cur, &mut out),
            b'/' if cur.peek(1) == Some(b'*') => lex_block_comment(&mut cur),
            b'"' => lex_string(&mut cur),
            b'\'' => lex_quote(&mut cur, &mut out),
            b'r' | b'b' if raw_or_byte_string_ahead(&cur) => lex_prefixed_string(&mut cur),
            _ if is_ident_start(b) => lex_ident(&mut cur, &mut out),
            _ if b.is_ascii_digit() => lex_number(&mut cur, &mut out),
            _ => lex_punct(&mut cur, &mut out),
        }
    }
    out
}

fn lex_line_comment(cur: &mut Cursor, out: &mut LexOutput) {
    let line = cur.line;
    let start = cur.pos;
    while let Some(b) = cur.peek(0) {
        if b == b'\n' {
            break;
        }
        cur.bump();
    }
    let text = String::from_utf8_lossy(&cur.src[start..cur.pos])
        .trim_start_matches('/')
        .trim()
        .to_string();
    out.comments.push((line, text));
}

fn lex_block_comment(cur: &mut Cursor) {
    // consume "/*", then run to the matching "*/" (block comments nest)
    cur.bump();
    cur.bump();
    let mut depth = 1usize;
    while depth > 0 {
        match (cur.peek(0), cur.peek(1)) {
            (Some(b'/'), Some(b'*')) => {
                depth += 1;
                cur.bump();
                cur.bump();
            }
            (Some(b'*'), Some(b'/')) => {
                depth -= 1;
                cur.bump();
                cur.bump();
            }
            (Some(_), _) => {
                cur.bump();
            }
            (None, _) => break,
        }
    }
}

fn lex_string(cur: &mut Cursor) {
    cur.bump(); // opening quote
    while let Some(b) = cur.bump() {
        match b {
            b'\\' => {
                cur.bump(); // escaped char (incl. \")
            }
            b'"' => break,
            _ => {}
        }
    }
}

/// True when the cursor sits on `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#` or
/// `b'…'` — prefixed literals that must not lex as identifiers.
fn raw_or_byte_string_ahead(cur: &Cursor) -> bool {
    let mut i = 1; // past the r/b
    if cur.peek(0) == Some(b'b') && cur.peek(1) == Some(b'r') {
        i = 2;
    }
    if cur.peek(0) == Some(b'b') && cur.peek(1) == Some(b'\'') {
        return true; // byte char b'x'
    }
    let mut j = i;
    while cur.peek(j) == Some(b'#') {
        j += 1;
    }
    cur.peek(j) == Some(b'"')
}

fn lex_prefixed_string(cur: &mut Cursor) {
    // r / b / br prefix
    if cur.peek(0) == Some(b'b') && cur.peek(1) == Some(b'\'') {
        cur.bump(); // b
        cur.bump(); // opening '
        while let Some(b) = cur.bump() {
            match b {
                b'\\' => {
                    cur.bump();
                }
                b'\'' => break,
                _ => {}
            }
        }
        return;
    }
    cur.bump();
    if cur.peek(0) == Some(b'r') {
        cur.bump();
    }
    let mut hashes = 0usize;
    while cur.peek(0) == Some(b'#') {
        hashes += 1;
        cur.bump();
    }
    cur.bump(); // opening quote
    if hashes == 0 {
        // plain (byte) string: honors escapes
        while let Some(b) = cur.bump() {
            match b {
                b'\\' => {
                    cur.bump();
                }
                b'"' => break,
                _ => {}
            }
        }
        return;
    }
    // raw string: ends at `"` followed by `hashes` hash marks
    while let Some(b) = cur.bump() {
        if b == b'"' {
            let mut k = 0;
            while k < hashes && cur.peek(k) == Some(b'#') {
                k += 1;
            }
            if k == hashes {
                for _ in 0..hashes {
                    cur.bump();
                }
                break;
            }
        }
    }
}

/// `'` starts either a char literal (`'a'`, `'\n'`) or a lifetime (`'a`).
fn lex_quote(cur: &mut Cursor, out: &mut LexOutput) {
    let line = cur.line;
    let next = cur.peek(1);
    let is_char_literal = match next {
        Some(b'\\') => true,
        Some(c) if is_ident_start(c) || c.is_ascii_digit() => cur.peek(2) == Some(b'\''),
        Some(_) => true, // '(' ')' etc. are single-char literals
        None => false,
    };
    if is_char_literal {
        cur.bump(); // '
        while let Some(b) = cur.bump() {
            match b {
                b'\\' => {
                    cur.bump();
                }
                b'\'' => break,
                _ => {}
            }
        }
    } else {
        // lifetime: consume 'ident
        cur.bump();
        let start = cur.pos;
        while let Some(b) = cur.peek(0) {
            if !is_ident_continue(b) {
                break;
            }
            cur.bump();
        }
        out.tokens.push(Token {
            kind: TokKind::Lifetime,
            text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
            line,
        });
    }
}

fn lex_ident(cur: &mut Cursor, out: &mut LexOutput) {
    let line = cur.line;
    let start = cur.pos;
    while let Some(b) = cur.peek(0) {
        if !is_ident_continue(b) {
            break;
        }
        cur.bump();
    }
    out.tokens.push(Token {
        kind: TokKind::Ident,
        text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
        line,
    });
}

fn lex_number(cur: &mut Cursor, out: &mut LexOutput) {
    let line = cur.line;
    let start = cur.pos;
    while let Some(b) = cur.peek(0) {
        if is_ident_continue(b) {
            cur.bump();
        } else if b == b'.'
            && cur.peek(1).map(|c| c.is_ascii_digit()).unwrap_or(false)
            && !cur.src[start..cur.pos].contains(&b'.')
        {
            cur.bump(); // the one decimal point of 1.25 (never 0..n)
        } else {
            break;
        }
    }
    out.tokens.push(Token {
        kind: TokKind::Number,
        text: String::from_utf8_lossy(&cur.src[start..cur.pos]).into_owned(),
        line,
    });
}

fn lex_punct(cur: &mut Cursor, out: &mut LexOutput) {
    let line = cur.line;
    for mp in MULTI_PUNCT {
        let bytes = mp.as_bytes();
        if cur.peek(0) == Some(bytes[0]) && cur.peek(1) == Some(bytes[1]) {
            cur.bump();
            cur.bump();
            out.tokens.push(Token { kind: TokKind::Punct, text: mp.to_string(), line });
            return;
        }
    }
    if let Some(b) = cur.bump() {
        out.tokens.push(Token {
            kind: TokKind::Punct,
            text: (b as char).to_string(),
            line,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).tokens.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_paths_and_lines() {
        let out = lex("use std::collections::HashMap;\nlet x = 1;");
        let toks = &out.tokens;
        assert_eq!(toks[0].text, "use");
        assert!(toks.iter().any(|t| t.text == "HashMap" && t.line == 1));
        assert!(toks.iter().any(|t| t.text == "x" && t.line == 2));
        assert!(toks.iter().any(|t| t.text == "::" && t.kind == TokKind::Punct));
    }

    #[test]
    fn comments_are_stripped_and_captured() {
        let out = lex("// HashMap in a comment\nlet a = 1; // trailing note");
        assert!(!out.tokens.iter().any(|t| t.text == "HashMap"));
        assert_eq!(out.comments.len(), 2);
        assert_eq!(out.comments[0], (1, "HashMap in a comment".to_string()));
        assert_eq!(out.comments[1], (2, "trailing note".to_string()));
    }

    #[test]
    fn block_comments_nest_and_strings_hide_tokens() {
        let src = "/* outer /* HashMap */ still */ let s = \"Instant::now\";";
        let t = texts(src);
        assert!(!t.contains(&"HashMap".to_string()));
        assert!(!t.contains(&"Instant".to_string()));
        assert!(t.contains(&"s".to_string()));
    }

    #[test]
    fn raw_strings_and_escapes() {
        let t = texts(r##"let a = r#"HashMap " quote"# ; let b = "esc \" HashMap";"##);
        assert!(!t.contains(&"HashMap".to_string()));
        assert_eq!(t.iter().filter(|x| *x == ";").count(), 2);
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let out = lex("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        let lifetimes: Vec<_> =
            out.tokens.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        assert!(lifetimes.iter().all(|t| t.text == "a"));
        // the char literals produced no ident tokens
        let stray_char_ident =
            out.tokens.iter().any(|t| t.text == "x" && t.kind == TokKind::Ident && t.line != 1);
        assert!(!stray_char_ident);
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let t = texts("let k = b\"HashMap\"; let c = b'h'; let r = br#\"SystemTime\"#;");
        assert!(!t.contains(&"HashMap".to_string()));
        assert!(!t.contains(&"SystemTime".to_string()));
        assert_eq!(t.iter().filter(|x| *x == "=").count(), 3);
    }

    #[test]
    fn numbers_including_hex_and_ranges() {
        let out = lex("let a = 0x9E37_79B9; for i in 0..n { let f = 1.25; }");
        assert!(out
            .tokens
            .iter()
            .any(|t| t.kind == TokKind::Number && t.text == "0x9E37_79B9"));
        assert!(out.tokens.iter().any(|t| t.kind == TokKind::Number && t.text == "1.25"));
        // 0..n lexes as number, punct, punct, ident — not one blob
        assert!(out.tokens.iter().any(|t| t.text == "0"));
        assert!(out.tokens.iter().any(|t| t.text == "n"));
    }

    #[test]
    fn multi_punct_coalesced() {
        let t = texts("fn f() -> Rng { a::b => c }");
        assert!(t.contains(&"->".to_string()));
        assert!(t.contains(&"::".to_string()));
        assert!(t.contains(&"=>".to_string()));
    }

    #[test]
    fn unterminated_string_degrades_gracefully() {
        let out = lex("let s = \"never closed");
        assert!(out.tokens.iter().any(|t| t.text == "s"));
    }
}
