//! Per-file source model the rules run against: the token stream plus
//! structural context recovered from it — which lines are test code
//! (`#[cfg(test)]` / `#[test]` regions), which function encloses a line,
//! and the in-source suppressions (`// lint:allow(rule): why`).

use super::lexer::{lex, TokKind, Token};

/// An in-source suppression comment:
/// `// lint:allow(rule-a, rule-b): justification`.
///
/// It silences matching findings on its own line (trailing comment) and
/// on the line directly below (comment-above style). A suppression with
/// an empty justification silences nothing — the `suppression` rule
/// reports it instead.
#[derive(Clone, Debug)]
pub struct Suppression {
    pub line: usize,
    pub rules: Vec<String>,
    pub justification: String,
}

/// One lexed + structurally annotated source file.
pub struct SourceFile {
    /// Path relative to the lint root, forward slashes (`tuner/broker.rs`).
    pub rel_path: String,
    /// Raw source lines (1-indexed access via [`SourceFile::line_text`]).
    pub lines: Vec<String>,
    /// Code tokens (comments/strings stripped).
    pub tokens: Vec<Token>,
    pub suppressions: Vec<Suppression>,
    /// `test_lines[i]` — line `i + 1` is inside a test item's braces.
    test_lines: Vec<bool>,
    /// `(fn name, first line, last line)`, innermost-last for nested fns.
    fn_ranges: Vec<(String, usize, usize)>,
}

impl SourceFile {
    pub fn parse(rel_path: &str, content: &str) -> SourceFile {
        let out = lex(content);
        let lines: Vec<String> = content.lines().map(|l| l.to_string()).collect();
        let n_lines = lines.len();
        let (test_ranges, fn_ranges) = scan_structure(&out.tokens);
        let mut test_lines = vec![false; n_lines];
        for (a, b) in test_ranges {
            for flag in test_lines.iter_mut().take(b.min(n_lines)).skip(a.saturating_sub(1)) {
                *flag = true;
            }
        }
        let suppressions = out
            .comments
            .iter()
            .filter_map(|(line, text)| parse_suppression(*line, text))
            .collect();
        SourceFile {
            rel_path: rel_path.to_string(),
            lines,
            tokens: out.tokens,
            suppressions,
            test_lines,
            fn_ranges,
        }
    }

    /// Trimmed text of a 1-indexed line (empty for out-of-range).
    pub fn line_text(&self, line: usize) -> &str {
        line.checked_sub(1)
            .and_then(|i| self.lines.get(i))
            .map(|s| s.trim())
            .unwrap_or("")
    }

    /// True when the 1-indexed line sits inside `#[cfg(test)]` / `#[test]`
    /// braces — rules skip test code.
    pub fn is_test_line(&self, line: usize) -> bool {
        line.checked_sub(1)
            .and_then(|i| self.test_lines.get(i))
            .copied()
            .unwrap_or(false)
    }

    /// True when this file defines a `fn` with the given name (body
    /// present). Lets rules tell a locally-defined method (`Parser::
    /// expect`, which returns `Result`) from the panicking `Option::expect`.
    pub fn defines_fn(&self, name: &str) -> bool {
        self.fn_ranges.iter().any(|(n, _, _)| n == name)
    }

    /// Name of the innermost function whose body spans `line`.
    pub fn enclosing_fn(&self, line: usize) -> Option<&str> {
        self.fn_ranges
            .iter()
            .filter(|(_, a, b)| (*a..=*b).contains(&line))
            .min_by_key(|(_, a, b)| b - a)
            .map(|(name, _, _)| name.as_str())
    }

    /// True when `path` (forward-slash, root-relative) starts with any of
    /// the given directory prefixes.
    pub fn in_scope(&self, prefixes: &[&str]) -> bool {
        prefixes.iter().any(|p| self.rel_path.starts_with(p))
    }
}

/// Recover test regions and fn body ranges from the token stream with a
/// brace-depth walk — no AST, but exact enough for line attribution.
#[allow(clippy::type_complexity)]
fn scan_structure(
    tokens: &[Token],
) -> (Vec<(usize, usize)>, Vec<(String, usize, usize)>) {
    let mut depth = 0usize;
    let mut test_ranges: Vec<(usize, usize)> = Vec::new();
    let mut test_stack: Vec<(usize, usize)> = Vec::new(); // (depth, open line)
    let mut fn_ranges: Vec<(String, usize, usize)> = Vec::new();
    let mut fn_stack: Vec<(String, usize, usize)> = Vec::new(); // (name, depth, open line)

    // a seen test attribute waits for the item's opening brace; `;` at
    // zero paren/bracket nesting cancels it (`#[cfg(test)] mod t;`)
    let mut pending_test = false;
    let mut pending_fn: Option<String> = None;
    let mut guard = 0isize; // ( [ nesting since the pending attr / fn kw

    let mut i = 0usize;
    while i < tokens.len() {
        let t = &tokens[i];
        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "#") if matches!(tokens.get(i + 1), Some(n) if n.text == "[") => {
                // scan the attribute to its matching `]`
                let mut j = i + 2;
                let mut bdepth = 1usize;
                let mut idents: Vec<&str> = Vec::new();
                while j < tokens.len() && bdepth > 0 {
                    match tokens[j].text.as_str() {
                        "[" => bdepth += 1,
                        "]" => bdepth -= 1,
                        _ => {
                            if tokens[j].kind == TokKind::Ident {
                                idents.push(&tokens[j].text);
                            }
                        }
                    }
                    j += 1;
                }
                if is_test_attr(&idents) {
                    pending_test = true;
                    guard = 0;
                }
                i = j;
                continue;
            }
            (TokKind::Ident, "fn") => {
                if let Some(next) = tokens.get(i + 1) {
                    if next.kind == TokKind::Ident {
                        pending_fn = Some(next.text.clone());
                        guard = 0;
                    }
                }
            }
            (TokKind::Punct, "(") | (TokKind::Punct, "[") => guard += 1,
            (TokKind::Punct, ")") | (TokKind::Punct, "]") => guard -= 1,
            (TokKind::Punct, ";") if guard <= 0 => {
                // item ended without a body: attr / fn decl consumed
                pending_test = false;
                pending_fn = None;
            }
            (TokKind::Punct, "{") => {
                depth += 1;
                if pending_test && guard <= 0 {
                    test_stack.push((depth, t.line));
                    pending_test = false;
                }
                if let Some(name) = pending_fn.take() {
                    if guard <= 0 {
                        fn_stack.push((name, depth, t.line));
                    }
                }
            }
            (TokKind::Punct, "}") => {
                if let Some(&(d, open)) = test_stack.last() {
                    if d == depth {
                        test_stack.pop();
                        test_ranges.push((open, t.line));
                    }
                }
                if let Some((_, d, _)) = fn_stack.last() {
                    if *d == depth {
                        if let Some((name, _, open)) = fn_stack.pop() {
                            fn_ranges.push((name, open, t.line));
                        }
                    }
                }
                depth = depth.saturating_sub(1);
            }
            _ => {}
        }
        i += 1;
    }
    // unterminated regions (lexer degrades gracefully) close at EOF
    let eof = tokens.last().map(|t| t.line).unwrap_or(0);
    for (_, open) in test_stack {
        test_ranges.push((open, eof));
    }
    for (name, _, open) in fn_stack {
        fn_ranges.push((name, open, eof));
    }
    (test_ranges, fn_ranges)
}

/// `#[test]`, `#[tokio::test]`-style, or `#[cfg(test)]` — but never
/// `#[cfg(not(test))]`.
fn is_test_attr(idents: &[&str]) -> bool {
    let has_test = idents.iter().any(|s| *s == "test");
    let has_not = idents.iter().any(|s| *s == "not");
    has_test && !has_not
}

/// Parse `lint:allow(rule-a, rule-b): justification` out of a comment.
/// The directive must open the comment — prose *mentioning* `lint:allow`
/// mid-sentence (docs like these) is not a suppression.
fn parse_suppression(line: usize, comment: &str) -> Option<Suppression> {
    let rest = comment.strip_prefix("lint:allow(")?;
    let close = rest.find(')')?;
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    let after = rest[close + 1..].trim_start();
    let justification = after.strip_prefix(':').map(|j| j.trim().to_string()).unwrap_or_default();
    Some(Suppression { line, rules, justification })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = r#"
use std::collections::BTreeMap;

pub fn outer(x: [u8; 4]) -> u32 {
    helper(x[0])
}

fn helper(v: u8) -> u32 {
    v as u32
}

#[cfg(test)]
mod tests {
    #[test]
    fn inner_test() {
        let m = std::collections::HashMap::<u32, u32>::new();
        assert!(m.is_empty());
    }
}
"#;

    #[test]
    fn test_regions_cover_cfg_test_mod() {
        let f = SourceFile::parse("x/y.rs", SRC);
        assert!(!f.is_test_line(4), "outer fn is not test code");
        assert!(!f.is_test_line(8));
        assert!(f.is_test_line(16), "HashMap line inside mod tests");
        assert!(f.is_test_line(17));
    }

    #[test]
    fn fn_ranges_track_enclosing_function() {
        let f = SourceFile::parse("x/y.rs", SRC);
        assert_eq!(f.enclosing_fn(5), Some("outer"));
        assert_eq!(f.enclosing_fn(9), Some("helper"));
        assert_eq!(f.enclosing_fn(2), None);
        // `[u8; 4]` in the signature must not cancel fn tracking
        assert_eq!(f.enclosing_fn(16), Some("inner_test"));
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nmod real {\n    fn f() {}\n}\n";
        let f = SourceFile::parse("a.rs", src);
        assert!(!f.is_test_line(3));
    }

    #[test]
    fn semicolon_cancels_pending_attr() {
        let src = "#[cfg(test)]\nmod tests;\nfn live() {\n    work();\n}\n";
        let f = SourceFile::parse("a.rs", src);
        assert!(!f.is_test_line(4), "mod tests; must not swallow the next item");
    }

    #[test]
    fn suppressions_parse_with_and_without_justification() {
        let src = "// lint:allow(unordered-map): keyed lookups only, never iterated\n\
                   let a = 1;\n\
                   let b = 2; // lint:allow(wall-clock, env-read)\n";
        let f = SourceFile::parse("a.rs", src);
        assert_eq!(f.suppressions.len(), 2);
        assert_eq!(f.suppressions[0].line, 1);
        assert_eq!(f.suppressions[0].rules, vec!["unordered-map"]);
        assert!(!f.suppressions[0].justification.is_empty());
        assert_eq!(f.suppressions[1].line, 3);
        assert_eq!(f.suppressions[1].rules.len(), 2);
        assert!(f.suppressions[1].justification.is_empty());
    }

    #[test]
    fn scope_prefix_match() {
        let f = SourceFile::parse("tuner/broker.rs", "fn x() {}\n");
        assert!(f.in_scope(&["tuner/", "sim/"]));
        assert!(!f.in_scope(&["coordinator/"]));
    }
}
