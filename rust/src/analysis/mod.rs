//! In-repo determinism & metering lint engine (`repro lint`).
//!
//! A zero-dependency static-analysis pass over `rust/src/**` that enforces
//! the repo's reproducibility contract *structurally* — the invariants the
//! golden traces, 1-vs-N-worker bit-equality tests and resume-by-replay
//! machinery already check dynamically:
//!
//! * `unordered-map` / `wall-clock` / `env-read` — determinism: no
//!   HashMap/HashSet iteration, host clocks, or ad-hoc environment reads
//!   in replayed code paths;
//! * `seed-discipline` — all RNG streams keyed through `util::rng`;
//! * `unmetered-eval` — live observations only through the `EvalBroker`;
//! * `panic-hygiene` — no unwrap/expect/panic! in non-test library code;
//! * `suppression` — every `lint:allow` carries a justification.
//!
//! The pipeline: [`lexer`] strips comments/strings and tokenizes,
//! [`source`] recovers structure (test regions, enclosing fns,
//! suppressions), [`rules`] runs the registry, [`baseline`] diffs the
//! findings against the committed ledger, and [`report`] renders the
//! table/JSON the CLI and CI consume.

pub mod baseline;
pub mod lexer;
pub mod report;
pub mod rules;
pub mod source;

use std::fs;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};

use source::SourceFile;

/// One lint finding at a concrete source location.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    /// Stable rule id from the [`rules`] registry.
    pub rule: &'static str,
    /// Path relative to the lint root, forward slashes.
    pub file: String,
    /// 1-indexed line.
    pub line: usize,
    /// Trimmed source text of the line — the baseline matching key.
    pub text: String,
    pub message: String,
}

impl Finding {
    pub fn new(rule: &'static str, file: &SourceFile, line: usize, message: String) -> Finding {
        Finding {
            rule,
            file: file.rel_path.clone(),
            line,
            text: file.line_text(line).to_string(),
            message,
        }
    }
}

/// Outcome of linting a source tree.
pub struct LintReport {
    /// Unsuppressed findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Findings silenced by a justified in-source `lint:allow`.
    pub suppressed: usize,
    pub files_scanned: usize,
}

/// Lint every `.rs` file under `root`. The walk is sorted so the report
/// is byte-identical across filesystems.
pub fn lint_tree(root: &Path) -> Result<LintReport> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)
        .with_context(|| format!("walking lint root {}", root.display()))?;
    files.sort();

    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    for path in &files {
        let content = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = rel_path(root, path);
        let (mut file_findings, file_suppressed) = lint_source(&rel, &content);
        findings.append(&mut file_findings);
        suppressed += file_suppressed;
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(LintReport { findings, suppressed, files_scanned: files.len() })
}

/// Lint a single in-memory source file: run every registered rule, then
/// drop findings covered by a justified suppression on the finding's own
/// line or the line above. Returns (kept findings, suppressed count).
pub fn lint_source(rel_path: &str, content: &str) -> (Vec<Finding>, usize) {
    let file = SourceFile::parse(rel_path, content);
    let mut raw = Vec::new();
    for rule in rules::all() {
        (rule.check)(&file, &mut raw);
    }
    let mut kept = Vec::new();
    let mut suppressed = 0usize;
    for f in raw {
        let silenced = f.rule != rules::SUPPRESSION
            && file.suppressions.iter().any(|s| {
                !s.justification.is_empty()
                    && (s.line == f.line || s.line + 1 == f.line)
                    && s.rules.iter().any(|r| r == f.rule)
            });
        if silenced {
            suppressed += 1;
        } else {
            kept.push(f);
        }
    }
    (kept, suppressed)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in
        fs::read_dir(dir).with_context(|| format!("reading dir {}", dir.display()))?
    {
        let path = entry.context("bad dir entry")?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suppression_silences_same_line_and_line_below() {
        let src = "\
// lint:allow(unordered-map): keyed lookups only, never iterated
use std::collections::HashMap;
fn f(m: &HashMap<u32, u32>) -> u32 { // lint:allow(unordered-map): keyed lookup
    *m.get(&0).unwrap_or(&0)
}
";
        let (findings, suppressed) = lint_source("tuner/x.rs", src);
        assert_eq!(findings, vec![], "both HashMap sites are suppressed");
        assert_eq!(suppressed, 2);
    }

    #[test]
    fn unjustified_suppression_silences_nothing_and_is_reported() {
        let src = "// lint:allow(unordered-map)\nuse std::collections::HashMap;\n";
        let (findings, suppressed) = lint_source("tuner/x.rs", src);
        assert_eq!(suppressed, 0);
        let rules: Vec<&str> = findings.iter().map(|f| f.rule).collect();
        assert!(rules.contains(&"unordered-map"), "finding survives: {rules:?}");
        assert!(rules.contains(&"suppression"), "empty justification reported: {rules:?}");
    }

    #[test]
    fn suppression_only_covers_named_rules() {
        let src = "// lint:allow(wall-clock): wrong rule named\nuse std::collections::HashMap;\n";
        let (findings, _) = lint_source("tuner/x.rs", src);
        assert!(findings.iter().any(|f| f.rule == "unordered-map"));
    }

    #[test]
    fn findings_sorted_and_text_keyed() {
        let src = "fn f() {\n    let a = o.unwrap();\n    let t = Instant::now();\n}\n";
        let (findings, _) = lint_source("sim/x.rs", src);
        assert_eq!(findings.len(), 2);
        assert!(findings[0].line <= findings[1].line);
        assert_eq!(findings[0].text, "let a = o.unwrap();");
    }
}
