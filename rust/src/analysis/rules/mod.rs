//! Rule registry: every lint the engine enforces, with its identity,
//! one-line summary, and the default justification `--update-baseline`
//! stamps on grandfathered findings.
//!
//! Adding a rule is one module + one [`RuleInfo`] entry here; the CLI,
//! reporter, baseline differ, suppression matcher and CI gate pick it up
//! with no further wiring.

pub mod determinism;
pub mod metering;
pub mod panic_hygiene;
pub mod seed;

use super::source::SourceFile;
use super::Finding;

/// A registered lint rule.
pub struct RuleInfo {
    /// Stable rule id — the name used in `lint:allow(<rule>)`, baseline
    /// entries and JSON output. Kebab-case, never renamed.
    pub name: &'static str,
    pub summary: &'static str,
    /// Justification stamped on entries `--update-baseline` grandfathers.
    pub baseline_justification: &'static str,
    pub check: fn(&SourceFile, &mut Vec<Finding>),
}

/// The engine's rule set, in reporting order.
pub fn all() -> &'static [RuleInfo] {
    &[
        RuleInfo {
            name: determinism::UNORDERED_MAP,
            summary: "HashMap/HashSet in determinism-scoped code (sim, tuner, \
                      coordinator, baselines) — iteration order corrupts replay; \
                      use BTreeMap/BTreeSet or a sorted drain",
            baseline_justification: "grandfathered at lint introduction; audit \
                                     confirmed keyed lookups only",
            check: determinism::check_unordered_map,
        },
        RuleInfo {
            name: determinism::WALL_CLOCK,
            summary: "wall-clock source (Instant::now, SystemTime) in \
                      determinism-scoped code — modeled time must come from \
                      the simulator",
            baseline_justification: "grandfathered at lint introduction; \
                                     reporting-only measurement",
            check: determinism::check_wall_clock,
        },
        RuleInfo {
            name: determinism::ENV_READ,
            summary: "process-environment read in determinism-scoped code \
                      outside the sanctioned coordinator::pool::env_workers",
            baseline_justification: "grandfathered at lint introduction",
            check: determinism::check_env_read,
        },
        RuleInfo {
            name: seed::SEED_DISCIPLINE,
            summary: "RNG construction bypassing util::rng keyed streams \
                      (foreign RNGs, hand-built generator state)",
            baseline_justification: "grandfathered at lint introduction",
            check: seed::check_seed_discipline,
        },
        RuleInfo {
            name: metering::UNMETERED_EVAL,
            summary: "direct Objective::eval/eval_batch outside tuner/broker.rs \
                      — every live observation must be budget-metered",
            baseline_justification: "grandfathered at lint introduction; \
                                     model-side evaluation, no live observation",
            check: metering::check_unmetered_eval,
        },
        RuleInfo {
            name: panic_hygiene::PANIC_HYGIENE,
            summary: "unwrap/expect/panic! in non-test library code",
            baseline_justification: "grandfathered at lint introduction; panic \
                                     guards an internal invariant — burn down \
                                     over time",
            check: panic_hygiene::check_panic_hygiene,
        },
        RuleInfo {
            name: SUPPRESSION,
            summary: "lint:allow without a justification — every suppression \
                      must say why",
            baseline_justification: "never baseline this rule: write the \
                                     justification instead",
            check: check_suppression_justification,
        },
    ]
}

/// Look a rule up by name.
pub fn find(name: &str) -> Option<&'static RuleInfo> {
    all().iter().find(|r| r.name == name)
}

/// Rule id: a `lint:allow` comment whose justification is missing/empty.
pub const SUPPRESSION: &str = "suppression";

fn check_suppression_justification(file: &SourceFile, out: &mut Vec<Finding>) {
    for s in &file.suppressions {
        if s.justification.is_empty() {
            out.push(Finding::new(
                SUPPRESSION,
                file,
                s.line,
                format!(
                    "lint:allow({}) has no justification — write \
                     `lint:allow(<rule>): <why>`; an unjustified allow \
                     suppresses nothing",
                    s.rules.join(", ")
                ),
            ));
        }
        for r in &s.rules {
            if find(r).is_none() {
                out.push(Finding::new(
                    SUPPRESSION,
                    file,
                    s.line,
                    format!("lint:allow names unknown rule '{r}' (see `repro lint --help`)"),
                ));
            }
        }
    }
}
