//! Seed-stream discipline: every random stream in the repo must be a
//! `util::rng` keyed stream (`Rng::seeded(seed)`, `rng.fork(tag)`). The
//! replay contract — bit-exact trajectories at any worker count — only
//! holds while generator *state* is constructed in exactly one place;
//! a hand-built generator or a foreign RNG crate reintroduces per-process
//! entropy the golden traces cannot see.
//!
//! Deriving a *seed* by mixing (`seed ^ round.wrapping_mul(GOLDEN)`) and
//! passing it to `Rng::seeded` is the sanctioned keyed-stream pattern and
//! is not flagged.

use crate::analysis::source::SourceFile;
use crate::analysis::Finding;

pub const SEED_DISCIPLINE: &str = "seed-discipline";

/// The one module allowed to build generator state.
const SANCTIONED_FILE: &str = "util/rng.rs";

/// Foreign / entropy-seeded RNG surfaces (the `rand` crate family).
const FOREIGN_RNG: &[&str] =
    &["thread_rng", "from_entropy", "seed_from_u64", "StdRng", "SmallRng", "ThreadRng"];

/// Tokens that may legitimately precede `Rng {` without it being a struct
/// literal (type positions, impl headers, trait objects, patterns).
const NON_LITERAL_PREFIX: &[&str] =
    &["->", "impl", "for", "mut", ":", "&", "dyn", "<", "as", "enum", "struct"];

pub fn check_seed_discipline(file: &SourceFile, out: &mut Vec<Finding>) {
    if file.rel_path == SANCTIONED_FILE {
        return;
    }
    for (i, t) in file.tokens.iter().enumerate() {
        if file.is_test_line(t.line) {
            continue;
        }
        if FOREIGN_RNG.contains(&t.text.as_str()) {
            out.push(Finding::new(
                SEED_DISCIPLINE,
                file,
                t.line,
                format!(
                    "{} constructs an RNG outside util::rng: streams must be \
                     keyed via Rng::seeded(seed)/rng.fork(tag) so every draw \
                     is replayable",
                    t.text
                ),
            ));
            continue;
        }
        // `Rng { … }` struct literal: builds generator state by hand,
        // bypassing the SplitMix64 seed expansion of Rng::seeded.
        if t.text == "Rng"
            && matches!(file.tokens.get(i + 1), Some(n) if n.text == "{")
            && !matches!(
                i.checked_sub(1).and_then(|p| file.tokens.get(p)),
                Some(prev) if NON_LITERAL_PREFIX.contains(&prev.text.as_str())
            )
        {
            out.push(Finding::new(
                SEED_DISCIPLINE,
                file,
                t.line,
                "hand-built Rng state outside util::rng: construct streams \
                 with Rng::seeded(seed) (SplitMix64 expansion) or fork an \
                 existing stream"
                    .to_string(),
            ));
        }
    }
}
