//! Panic hygiene: `unwrap`/`expect`/`panic!` in non-test library code.
//! A panic in a worker thread poisons the whole campaign (`run_parallel`
//! joins workers and re-panics); library paths should return
//! `util::error::Result` and let the CLI layer decide. Existing sites are
//! grandfathered into the committed baseline and burned down over time —
//! this rule's job is to stop NEW ones from landing unexamined.

use crate::analysis::source::SourceFile;
use crate::analysis::Finding;

pub const PANIC_HYGIENE: &str = "panic-hygiene";

/// Methods that panic on None/Err. Exact idents — `unwrap_or`,
/// `unwrap_or_else`, `expect_err` etc. are distinct tokens and never fire.
const PANICKING_METHODS: &[&str] = &["unwrap", "expect"];

/// Macros that unconditionally panic.
const PANICKING_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

pub fn check_panic_hygiene(file: &SourceFile, out: &mut Vec<Finding>) {
    // A file that defines its own `fn expect` / `fn unwrap` (util/json.rs's
    // parser does — it returns Result) is calling that method, not the
    // panicking Option/Result one; skip the name file-wide.
    let local: Vec<&str> =
        PANICKING_METHODS.iter().copied().filter(|m| file.defines_fn(m)).collect();
    for (i, t) in file.tokens.iter().enumerate() {
        if file.is_test_line(t.line) {
            continue;
        }
        let prev = i.checked_sub(1).and_then(|p| file.tokens.get(p));
        let next = file.tokens.get(i + 1);
        let is_panicking_method = PANICKING_METHODS.contains(&t.text.as_str())
            && !local.contains(&t.text.as_str())
            && matches!(prev, Some(p) if p.text == ".")
            && matches!(next, Some(n) if n.text == "(");
        let is_panicking_macro = PANICKING_MACROS.contains(&t.text.as_str())
            && matches!(next, Some(n) if n.text == "!");
        if is_panicking_method {
            out.push(Finding::new(
                PANIC_HYGIENE,
                file,
                t.line,
                format!(
                    ".{}() in non-test library code: prefer util::error::Result \
                     (+ Context) so callers choose the failure mode",
                    t.text
                ),
            ));
        } else if is_panicking_macro {
            out.push(Finding::new(
                PANIC_HYGIENE,
                file,
                t.line,
                format!(
                    "{}! in non-test library code: prefer util::error::Result \
                     (+ Context) so callers choose the failure mode",
                    t.text
                ),
            ));
        }
    }
}
