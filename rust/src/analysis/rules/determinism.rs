//! Determinism lints: the invariants every golden trace, 1-vs-N-worker
//! bit-equality test and resume-by-replay rung extension rest on. Scoped
//! to the modules whose behavior feeds replayed trajectories — `sim`,
//! `tuner`, `coordinator`, `baselines`; test code is exempt everywhere.

use crate::analysis::source::SourceFile;
use crate::analysis::Finding;

pub const UNORDERED_MAP: &str = "unordered-map";
pub const WALL_CLOCK: &str = "wall-clock";
pub const ENV_READ: &str = "env-read";

/// Directories (relative to the lint root) whose code feeds deterministic
/// replay. A HashMap iteration or wall-clock read anywhere here can change
/// observation streams between runs.
pub const DETERMINISM_SCOPE: &[&str] = &["sim/", "tuner/", "coordinator/", "baselines/"];

/// `(file, enclosing fn)` locations sanctioned to read the process
/// environment: the single env knob the repo exposes.
const ENV_SANCTIONED: &[(&str, &str)] = &[("coordinator/pool.rs", "env_workers")];

/// Unordered `std::collections` types whose iteration order is
/// seed-for-seed nondeterministic (SipHash keyed per process).
const UNORDERED_TYPES: &[&str] = &["HashMap", "HashSet"];

/// Identifiers that read real time. (`thread_rng` — entropy rather than
/// time — is the seed-discipline rule's, and that one is repo-wide.)
const CLOCK_IDENTS: &[&str] = &["Instant", "SystemTime"];

pub fn check_unordered_map(file: &SourceFile, out: &mut Vec<Finding>) {
    if !file.in_scope(DETERMINISM_SCOPE) {
        return;
    }
    for t in &file.tokens {
        if file.is_test_line(t.line) {
            continue;
        }
        if UNORDERED_TYPES.contains(&t.text.as_str()) {
            out.push(Finding::new(
                UNORDERED_MAP,
                file,
                t.line,
                format!(
                    "{} in determinism-scoped code: iteration order varies per \
                     process and corrupts replay — use BTreeMap/BTreeSet or \
                     drain through a sorted Vec",
                    t.text
                ),
            ));
        }
    }
}

pub fn check_wall_clock(file: &SourceFile, out: &mut Vec<Finding>) {
    if !file.in_scope(DETERMINISM_SCOPE) {
        return;
    }
    for t in &file.tokens {
        if file.is_test_line(t.line) {
            continue;
        }
        if CLOCK_IDENTS.contains(&t.text.as_str()) {
            out.push(Finding::new(
                WALL_CLOCK,
                file,
                t.line,
                format!(
                    "{} reads host wall-clock/entropy in determinism-scoped \
                     code: modeled time must come from the simulator, noise \
                     from util::rng seeded streams",
                    t.text
                ),
            ));
        }
    }
}

pub fn check_env_read(file: &SourceFile, out: &mut Vec<Finding>) {
    if !file.in_scope(DETERMINISM_SCOPE) {
        return;
    }
    for (i, t) in file.tokens.iter().enumerate() {
        if file.is_test_line(t.line) {
            continue;
        }
        // `env` used as a module path: std::env::var, env::args, …
        let is_env_path = t.text == "env"
            && matches!(file.tokens.get(i + 1), Some(n) if n.text == "::");
        if !is_env_path {
            continue;
        }
        let sanctioned = ENV_SANCTIONED.iter().any(|(f, func)| {
            file.rel_path == *f && file.enclosing_fn(t.line) == Some(func)
        });
        if sanctioned {
            continue;
        }
        out.push(Finding::new(
            ENV_READ,
            file,
            t.line,
            "process-environment access in determinism-scoped code: the one \
             sanctioned env knob is coordinator::pool::env_workers \
             (HSPSA_WORKERS) — route through it or hoist the read to the CLI \
             layer"
                .to_string(),
        ));
    }
}
