//! Metering discipline: every live observation must flow through the
//! budget-metered `EvalBroker` (`tuner/broker.rs`). A direct
//! `Objective::eval` / `eval_batch` call anywhere else spends an
//! observation the budget never sees — the cross-tuner comparisons (one
//! shared observation currency, paper §6.6) silently stop being fair.

use crate::analysis::source::SourceFile;
use crate::analysis::Finding;

pub const UNMETERED_EVAL: &str = "unmetered-eval";

/// Directories whose code participates in budgeted tuning runs.
const METERING_SCOPE: &[&str] = &["tuner/", "baselines/", "coordinator/", "experiments/"];

/// Files sanctioned to call eval/eval_batch directly:
/// * `tuner/broker.rs` — the meter itself;
/// * `tuner/objective.rs` — the trait, its blanket impls and adapters;
/// * `baselines/evaluator.rs` — the CostEvaluator adapter layer over
///   what-if models and broker-backed objectives.
const SANCTIONED_FILES: &[&str] =
    &["tuner/broker.rs", "tuner/objective.rs", "baselines/evaluator.rs"];

const EVAL_METHODS: &[&str] = &["eval", "eval_batch"];

pub fn check_unmetered_eval(file: &SourceFile, out: &mut Vec<Finding>) {
    if !file.in_scope(METERING_SCOPE) || SANCTIONED_FILES.contains(&file.rel_path.as_str()) {
        return;
    }
    for (i, t) in file.tokens.iter().enumerate() {
        if file.is_test_line(t.line) {
            continue;
        }
        // match `.eval(` / `.eval_batch(` — method calls only, so idents
        // like `fn eval_batch` in trait impls or `try_eval` (the broker's
        // own metered surface) never fire
        let prev = i.checked_sub(1).and_then(|p| file.tokens.get(p));
        let is_method_call = EVAL_METHODS.contains(&t.text.as_str())
            && matches!(prev, Some(p) if p.text == ".")
            && matches!(file.tokens.get(i + 1), Some(n) if n.text == "(");
        if is_method_call {
            out.push(Finding::new(
                UNMETERED_EVAL,
                file,
                t.line,
                format!(
                    ".{}() bypasses the EvalBroker: live observations must be \
                     served by broker.try_eval/try_eval_batch so the budget \
                     meters them",
                    t.text
                ),
            ));
        }
    }
}
