//! Findings baseline: the committed ledger of known findings that lets
//! the CI gate ratchet ("no NEW findings") without demanding a big-bang
//! cleanup. Entries are keyed by `(rule, file, trimmed line text)` with a
//! count, so the match survives line-number drift from unrelated edits;
//! every entry carries a justification, and an entry without one is
//! itself a gating condition.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};
use crate::util::json::Json;

use super::{rules, Finding, LintReport};

/// One baselined finding group.
#[derive(Clone, Debug, PartialEq)]
pub struct BaselineEntry {
    pub rule: String,
    pub file: String,
    /// Trimmed source-line text at the finding site (drift-resilient key).
    pub text: String,
    /// How many findings with this (rule, file, text) key are accepted.
    pub count: usize,
    pub justification: String,
}

/// A parsed baseline file.
#[derive(Clone, Debug, Default)]
pub struct Baseline {
    pub entries: Vec<BaselineEntry>,
}

/// Result of diffing current findings against a [`Baseline`].
#[derive(Debug, Default)]
pub struct DiffOutcome {
    /// Findings not covered by the baseline — these gate.
    pub new: Vec<Finding>,
    /// Baseline entries (or surplus counts) no current finding matches:
    /// the finding was fixed; prune with `--update-baseline`. Advisory.
    pub stale: Vec<BaselineEntry>,
    /// Baseline entries with an empty justification — these gate too.
    pub unjustified: Vec<BaselineEntry>,
    /// Number of current findings absorbed by the baseline.
    pub baselined: usize,
}

impl DiffOutcome {
    /// True when the gate passes: nothing new, nothing unjustified.
    pub fn clean(&self) -> bool {
        self.new.is_empty() && self.unjustified.is_empty()
    }
}

impl Baseline {
    pub fn parse(src: &str) -> Result<Baseline> {
        let root = Json::parse(src).map_err(|e| Error::msg(format!("baseline JSON: {e}")))?;
        let arr = root
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| Error::msg("baseline JSON: missing 'entries' array"))?;
        let mut entries = Vec::with_capacity(arr.len());
        for (i, item) in arr.iter().enumerate() {
            let field = |k: &str| -> Result<String> {
                item.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| Error::msg(format!("baseline entry {i}: missing '{k}'")))
            };
            let rule = field("rule")?;
            if rules::find(&rule).is_none() {
                return Err(Error::msg(format!("baseline entry {i}: unknown rule '{rule}'")));
            }
            entries.push(BaselineEntry {
                rule,
                file: field("file")?,
                text: field("text")?,
                count: item.get("count").and_then(|v| v.as_f64()).unwrap_or(1.0) as usize,
                justification: item
                    .get("justification")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
            });
        }
        Ok(Baseline { entries })
    }

    pub fn to_json(&self) -> Json {
        let mut entries: Vec<Json> = Vec::with_capacity(self.entries.len());
        for e in &self.entries {
            let mut o = Json::obj();
            o.set("rule", Json::Str(e.rule.clone()))
                .set("file", Json::Str(e.file.clone()))
                .set("text", Json::Str(e.text.clone()))
                .set("count", Json::Num(e.count as f64))
                .set("justification", Json::Str(e.justification.clone()));
            entries.push(o);
        }
        let mut root = Json::obj();
        root.set("version", Json::Num(1.0)).set("entries", Json::Arr(entries));
        root
    }

    /// Build a baseline that accepts exactly the given findings, stamping
    /// each rule's default justification. When `prev` is supplied, hand
    /// written justifications for keys that survive are preserved.
    pub fn from_findings(findings: &[Finding], prev: Option<&Baseline>) -> Baseline {
        let mut grouped: BTreeMap<(String, String, String), usize> = BTreeMap::new();
        for f in findings {
            *grouped.entry((f.rule.to_string(), f.file.clone(), f.text.clone())).or_insert(0) += 1;
        }
        let entries = grouped
            .into_iter()
            .map(|((rule, file, text), count)| {
                let kept = prev.and_then(|b| {
                    b.entries
                        .iter()
                        .find(|e| e.rule == rule && e.file == file && e.text == text)
                        .filter(|e| !e.justification.is_empty())
                        .map(|e| e.justification.clone())
                });
                let justification = kept.unwrap_or_else(|| {
                    rules::find(&rule)
                        .map(|r| r.baseline_justification.to_string())
                        .unwrap_or_default()
                });
                BaselineEntry { rule, file, text, count, justification }
            })
            .collect();
        Baseline { entries }
    }

    /// Diff current findings against this baseline. Matching is by
    /// `(rule, file, text)` with counts: up to `count` findings per key are
    /// absorbed; the excess is new; unconsumed baseline capacity is stale.
    pub fn diff(&self, report: &LintReport) -> DiffOutcome {
        let mut budget: BTreeMap<(&str, &str, &str), usize> = BTreeMap::new();
        for e in &self.entries {
            *budget.entry((&e.rule, &e.file, &e.text)).or_insert(0) += e.count;
        }
        let mut out = DiffOutcome::default();
        for f in &report.findings {
            let key = (f.rule, f.file.as_str(), f.text.as_str());
            match budget.get_mut(&key) {
                Some(n) if *n > 0 => {
                    *n -= 1;
                    out.baselined += 1;
                }
                _ => out.new.push(f.clone()),
            }
        }
        for e in &self.entries {
            if e.justification.is_empty() {
                out.unjustified.push(e.clone());
            }
            let left = budget
                .get(&(e.rule.as_str(), e.file.as_str(), e.text.as_str()))
                .copied()
                .unwrap_or(0);
            if left > 0 {
                // report the residual once, on the first entry for the key
                let mut stale = e.clone();
                stale.count = left;
                out.stale.push(stale);
                if let Some(n) =
                    budget.get_mut(&(e.rule.as_str(), e.file.as_str(), e.text.as_str()))
                {
                    *n = 0;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::source::SourceFile;
    use super::*;

    fn finding(rule: &'static str, file: &str, line: usize, src: &str) -> Finding {
        let sf = SourceFile::parse(file, src);
        Finding::new(rule, &sf, line, "msg".to_string())
    }

    fn report(findings: Vec<Finding>) -> LintReport {
        LintReport { findings, suppressed: 0, files_scanned: 1 }
    }

    #[test]
    fn round_trips_through_json() {
        let f = finding("panic-hygiene", "tuner/x.rs", 1, "let a = o.unwrap();\n");
        let b = Baseline::from_findings(&[f.clone(), f], None);
        let parsed = Baseline::parse(&b.to_json().to_pretty()).expect("parse");
        assert_eq!(parsed.entries, b.entries);
        assert_eq!(parsed.entries[0].count, 2);
        assert!(!parsed.entries[0].justification.is_empty());
    }

    #[test]
    fn diff_splits_new_baselined_stale() {
        let known = finding("panic-hygiene", "tuner/x.rs", 1, "let a = o.unwrap();\n");
        let baseline = Baseline::from_findings(&[known.clone(), known.clone()], None);
        // one matching finding (one stale surplus), one brand new
        let fresh = finding("wall-clock", "sim/t.rs", 1, "let t = Instant::now();\n");
        let d = baseline.diff(&report(vec![known, fresh]));
        assert_eq!(d.baselined, 1);
        assert_eq!(d.new.len(), 1);
        assert_eq!(d.new[0].rule, "wall-clock");
        assert_eq!(d.stale.len(), 1);
        assert_eq!(d.stale[0].count, 1);
        assert!(!d.clean());
    }

    #[test]
    fn matching_survives_line_drift() {
        let src_before = "let a = o.unwrap();\n";
        let src_after = "// new comment pushed everything down\n\nlet a = o.unwrap();\n";
        let baseline =
            Baseline::from_findings(&[finding("panic-hygiene", "tuner/x.rs", 1, src_before)], None);
        let moved = finding("panic-hygiene", "tuner/x.rs", 3, src_after);
        let d = baseline.diff(&report(vec![moved]));
        assert!(d.clean(), "same (rule,file,text) at a new line must still match");
        assert_eq!(d.baselined, 1);
    }

    #[test]
    fn unjustified_entries_gate() {
        let f = finding("panic-hygiene", "tuner/x.rs", 1, "o.unwrap();\n");
        let mut b = Baseline::from_findings(&[f.clone()], None);
        b.entries[0].justification.clear();
        let d = b.diff(&report(vec![f]));
        assert_eq!(d.new.len(), 0);
        assert_eq!(d.unjustified.len(), 1);
        assert!(!d.clean());
    }

    #[test]
    fn update_preserves_hand_written_justifications() {
        let f = finding("panic-hygiene", "tuner/x.rs", 1, "o.unwrap();\n");
        let mut prev = Baseline::from_findings(&[f.clone()], None);
        prev.entries[0].justification = "reviewed: invariant held by construction".to_string();
        let next = Baseline::from_findings(&[f], Some(&prev));
        assert_eq!(next.entries[0].justification, "reviewed: invariant held by construction");
    }

    #[test]
    fn parse_rejects_unknown_rules() {
        let src = r#"{"entries": [{"rule": "no-such-rule", "file": "a.rs", "text": "x"}]}"#;
        assert!(Baseline::parse(src).is_err());
    }
}
