//! Finding reporters: a human table and stable machine-readable JSON.
//! The JSON is what CI diffs and uploads — orderings are fully
//! deterministic (findings sorted by file, line, rule; objects serialize
//! with sorted keys via `util::json`).

use crate::util::json::Json;
use crate::util::table::Table;

use super::baseline::DiffOutcome;
use super::{Finding, LintReport};

/// One JSON object per finding.
fn finding_json(f: &Finding) -> Json {
    let mut o = Json::obj();
    o.set("rule", Json::Str(f.rule.to_string()))
        .set("file", Json::Str(f.file.clone()))
        .set("line", Json::Num(f.line as f64))
        .set("text", Json::Str(f.text.clone()))
        .set("message", Json::Str(f.message.clone()));
    o
}

/// The machine-readable report CI gates on: every finding, the
/// baseline-diff split, and a summary block. `diff` is the outcome
/// against the committed baseline (`None` when run with no baseline —
/// then every finding counts as new).
pub fn to_json(report: &LintReport, diff: Option<&DiffOutcome>) -> Json {
    let mut root = Json::obj();
    root.set("version", Json::Num(1.0));
    root.set("files_scanned", Json::Num(report.files_scanned as f64));

    let new: Vec<&Finding> = match diff {
        Some(d) => d.new.iter().collect(),
        None => report.findings.iter().collect(),
    };
    root.set("new", Json::Arr(new.iter().map(|f| finding_json(f)).collect()));
    root.set(
        "findings",
        Json::Arr(report.findings.iter().map(finding_json).collect()),
    );
    if let Some(d) = diff {
        root.set(
            "stale_baseline",
            Json::Arr(
                d.stale
                    .iter()
                    .map(|e| {
                        let mut o = Json::obj();
                        o.set("rule", Json::Str(e.rule.clone()))
                            .set("file", Json::Str(e.file.clone()))
                            .set("text", Json::Str(e.text.clone()))
                            .set("count", Json::Num(e.count as f64));
                        o
                    })
                    .collect(),
            ),
        );
        root.set(
            "unjustified_baseline",
            Json::Arr(
                d.unjustified
                    .iter()
                    .map(|e| {
                        let mut o = Json::obj();
                        o.set("rule", Json::Str(e.rule.clone()))
                            .set("file", Json::Str(e.file.clone()))
                            .set("text", Json::Str(e.text.clone()));
                        o
                    })
                    .collect(),
            ),
        );
    }

    let mut summary = Json::obj();
    summary
        .set("total", Json::Num(report.findings.len() as f64))
        .set("new", Json::Num(new.len() as f64))
        .set(
            "baselined",
            Json::Num(diff.map(|d| d.baselined).unwrap_or(0) as f64),
        )
        .set("suppressed", Json::Num(report.suppressed as f64))
        .set(
            "stale_baseline",
            Json::Num(diff.map(|d| d.stale.len()).unwrap_or(0) as f64),
        );
    root.set("summary", summary);
    root
}

/// Human-readable table of the findings that matter (new ones), plus a
/// one-line summary of everything else.
pub fn to_table(report: &LintReport, diff: Option<&DiffOutcome>) -> String {
    let new: Vec<&Finding> = match diff {
        Some(d) => d.new.iter().collect(),
        None => report.findings.iter().collect(),
    };
    let mut out = String::new();
    if new.is_empty() {
        out.push_str("repro lint: clean");
    } else {
        let mut t = Table::new("repro lint findings").header(vec!["rule", "location", "finding"]);
        for f in &new {
            t.row(vec![
                f.rule.to_string(),
                format!("{}:{}", f.file, f.line),
                f.message.clone(),
            ]);
        }
        out.push_str(&t.to_ascii());
    }
    out.push_str(&format!(
        "\n{} file(s) scanned; {} finding(s): {} new, {} baselined, {} suppressed in-source",
        report.files_scanned,
        report.findings.len(),
        new.len(),
        diff.map(|d| d.baselined).unwrap_or(0),
        report.suppressed,
    ));
    if let Some(d) = diff {
        if !d.stale.is_empty() {
            out.push_str(&format!(
                "\nstale baseline entries (fixed findings — prune with --update-baseline): {}",
                d.stale.len()
            ));
        }
        if !d.unjustified.is_empty() {
            out.push_str(&format!(
                "\nbaseline entries without a justification (gating): {}",
                d.unjustified.len()
            ));
        }
    }
    out.push('\n');
    out
}
