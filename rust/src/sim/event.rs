//! Discrete-event calendar: deterministic event queues keyed by
//! `(time, sequence)` — the sequence number breaks same-time ties in
//! insertion order so simulation results are bit-reproducible for a given
//! seed regardless of queue internals.
//!
//! Two interchangeable implementations sit behind [`EventQueue`]:
//!
//! * [`CalendarQueue`] (the default) — a bucketed calendar queue tuned for
//!   the simulator's near-monotone schedule horizon: events are binned
//!   into a power-of-two ring of *day* buckets and pop sweeps days from
//!   the current clock, so the common case touches one small unsorted
//!   bucket instead of rebalancing a heap. See `sim/README.md` for the
//!   invariants.
//! * [`HeapQueue`] — the original `BinaryHeap` implementation, kept as the
//!   reference oracle. The `heap-queue` cargo feature makes it the build
//!   default; `EventQueue::with_kind` selects it at runtime (golden-trace
//!   equality tests run both and demand bit-identical digests).
//!
//! Both queues share one contract: `pop` yields the queued event with the
//! smallest `(time, seq)`; `schedule` clamps past times to `now`; the
//! clock is the time of the last popped event.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in seconds.
pub type SimTime = f64;

/// Which implementation an [`EventQueue`] dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueKind {
    /// Bucketed calendar queue (the production default).
    Calendar,
    /// The original binary-heap queue (reference oracle).
    Heap,
}

impl QueueKind {
    /// The build default: [`QueueKind::Calendar`], unless the `heap-queue`
    /// cargo feature pins the legacy binary heap.
    pub fn default_kind() -> QueueKind {
        if cfg!(feature = "heap-queue") {
            QueueKind::Heap
        } else {
            QueueKind::Calendar
        }
    }
}

// ---------------------------------------------------------------------------
// HeapQueue: the original BinaryHeap implementation
// ---------------------------------------------------------------------------

struct Entry<T> {
    time: SimTime,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap; ties broken by insertion order.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-heap event queue (the pre-calendar implementation).
pub struct HeapQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
    now: SimTime,
}

impl<T> HeapQueue<T> {
    pub fn new() -> Self {
        HeapQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Current simulation clock (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `payload` at absolute time `at` (clamped to now).
    pub fn schedule(&mut self, at: SimTime, payload: T) {
        let at = if at < self.now { self.now } else { at };
        debug_assert!(at.is_finite(), "scheduling at non-finite time");
        self.heap.push(Entry { time: at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` after a delay from the current clock.
    pub fn schedule_in(&mut self, delay: SimTime, payload: T) {
        let now = self.now;
        self.schedule(now + delay.max(0.0), payload);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, T)> {
        self.heap.pop().map(|e| {
            self.now = e.time;
            (e.time, e.payload)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Empty the queue and rewind the clock/sequence to zero, keeping the
    /// heap's backing allocation for the next run.
    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.now = 0.0;
    }
}

impl<T> Default for HeapQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// CalendarQueue: bucketed days, unsorted buckets, min-scan pop
// ---------------------------------------------------------------------------

/// Ring size the calendar starts with (power of two).
const INITIAL_BUCKETS: usize = 16;
/// Day-width clamp: keeps `time / width` well inside f64's exact-integer
/// range for any simulated horizon, and bounds how many ring cycles a
/// clustered schedule can span.
const MIN_WIDTH: SimTime = 1e-6;
const MAX_WIDTH: SimTime = 1e9;

struct CalEntry<T> {
    time: SimTime,
    seq: u64,
    payload: T,
}

/// Bucketed calendar event queue.
///
/// Invariants (see `sim/README.md`):
/// * every queued time is ≥ `now` (schedule clamps), so the next event's
///   *day* `floor(time / width)` is ≥ the clock's day — pop sweeps days
///   upward from the clock and the first day holding an event holds the
///   global minimum;
/// * all events of one day land in exactly one bucket (`day mod ring`),
///   so one unsorted-bucket min-scan per day suffices;
/// * pop order is a pure function of the queued `(time, seq)` pairs —
///   never of ring geometry — so resizes and buffer reuse cannot perturb
///   simulation physics.
pub struct CalendarQueue<T> {
    /// Power-of-two ring of unsorted day buckets.
    buckets: Vec<Vec<CalEntry<T>>>,
    /// Width of one day in simulated seconds.
    width: SimTime,
    len: usize,
    seq: u64,
    now: SimTime,
}

impl<T> CalendarQueue<T> {
    pub fn new() -> Self {
        let mut buckets = Vec::with_capacity(INITIAL_BUCKETS);
        buckets.resize_with(INITIAL_BUCKETS, Vec::new);
        CalendarQueue { buckets, width: 1.0, len: 0, seq: 0, now: 0.0 }
    }

    /// Current simulation clock (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    fn day_of(&self, t: SimTime) -> u64 {
        (t / self.width) as u64
    }

    fn bucket_of(&self, t: SimTime) -> usize {
        (self.day_of(t) & (self.buckets.len() as u64 - 1)) as usize
    }

    /// Schedule `payload` at absolute time `at` (clamped to now).
    pub fn schedule(&mut self, at: SimTime, payload: T) {
        let at = if at < self.now { self.now } else { at };
        debug_assert!(at.is_finite(), "scheduling at non-finite time");
        if self.len + 1 > 2 * self.buckets.len() {
            self.grow();
        }
        let b = self.bucket_of(at);
        self.buckets[b].push(CalEntry { time: at, seq: self.seq, payload });
        self.seq += 1;
        self.len += 1;
    }

    /// Schedule `payload` after a delay from the current clock.
    pub fn schedule_in(&mut self, delay: SimTime, payload: T) {
        let now = self.now;
        self.schedule(now + delay.max(0.0), payload);
    }

    /// Double the ring and re-derive the day width from the queued time
    /// span. Deterministic and content-only: geometry is a pure function
    /// of what is queued, never of wall clock or capacity history (and
    /// pop order does not depend on geometry at all).
    fn grow(&mut self) {
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for bucket in &self.buckets {
            for e in bucket {
                lo = lo.min(e.time);
                hi = hi.max(e.time);
            }
        }
        let span = (hi - lo).max(0.0);
        self.width = (span / self.len.max(1) as f64).clamp(MIN_WIDTH, MAX_WIDTH);
        let nb = self.buckets.len() * 2;
        let mut old = std::mem::take(&mut self.buckets);
        self.buckets = Vec::with_capacity(nb);
        self.buckets.resize_with(nb, Vec::new);
        for bucket in &mut old {
            for e in bucket.drain(..) {
                let b = self.bucket_of(e.time);
                self.buckets[b].push(e);
            }
        }
    }

    /// `(bucket, index)` of the minimum `(time, seq)` entry across the
    /// whole ring — the sparse-tail fallback when no event lives within
    /// one ring cycle of days from the clock.
    fn global_min(&self) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize, SimTime, u64)> = None;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (i, e) in bucket.iter().enumerate() {
                let better = match best {
                    None => true,
                    Some((_, _, bt, bs)) => e.time < bt || (e.time == bt && e.seq < bs),
                };
                if better {
                    best = Some((b, i, e.time, e.seq));
                }
            }
        }
        best.map(|(b, i, _, _)| (b, i))
    }

    /// Pop the next event, advancing the clock.
    ///
    /// Sweeps days upward from the clock's day: since every queued time is
    /// ≥ `now` and a day's events live in exactly one bucket, the first
    /// day holding an event holds the global `(time, seq)` minimum. After
    /// one full ring cycle of empty days, falls back to a global min-scan.
    pub fn pop(&mut self) -> Option<(SimTime, T)> {
        if self.len == 0 {
            return None;
        }
        let nb = self.buckets.len() as u64;
        let start_day = self.day_of(self.now);
        let mut found: Option<(usize, usize)> = None;
        for step in 0..nb {
            let day = start_day.wrapping_add(step);
            let b = (day & (nb - 1)) as usize;
            let mut best: Option<(usize, SimTime, u64)> = None;
            for (i, e) in self.buckets[b].iter().enumerate() {
                if self.day_of(e.time) != day {
                    continue; // a different ring cycle of this bucket
                }
                let better = match best {
                    None => true,
                    Some((_, bt, bs)) => e.time < bt || (e.time == bt && e.seq < bs),
                };
                if better {
                    best = Some((i, e.time, e.seq));
                }
            }
            if let Some((i, _, _)) = best {
                found = Some((b, i));
                break;
            }
        }
        let (b, i) = match found {
            Some(x) => x,
            None => self.global_min()?, // unreachable None: len > 0
        };
        let e = self.buckets[b].swap_remove(i);
        self.len -= 1;
        self.now = e.time;
        Some((e.time, e.payload))
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Empty the queue and rewind the clock/sequence to zero, keeping the
    /// ring's backing allocations (and its adapted geometry — harmless,
    /// since pop order never depends on geometry) for the next run.
    pub fn clear(&mut self) {
        for bucket in &mut self.buckets {
            bucket.clear();
        }
        self.len = 0;
        self.seq = 0;
        self.now = 0.0;
    }
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// EventQueue: the dispatching facade the simulator uses
// ---------------------------------------------------------------------------

enum QueueImpl<T> {
    Calendar(CalendarQueue<T>),
    Heap(HeapQueue<T>),
}

/// Deterministic event queue — a thin facade over [`CalendarQueue`] /
/// [`HeapQueue`] selected by [`QueueKind`].
pub struct EventQueue<T> {
    q: QueueImpl<T>,
}

impl<T> EventQueue<T> {
    /// The build-default implementation (see [`QueueKind::default_kind`]).
    pub fn new() -> Self {
        Self::with_kind(QueueKind::default_kind())
    }

    pub fn with_kind(kind: QueueKind) -> Self {
        let q = match kind {
            QueueKind::Calendar => QueueImpl::Calendar(CalendarQueue::new()),
            QueueKind::Heap => QueueImpl::Heap(HeapQueue::new()),
        };
        EventQueue { q }
    }

    pub fn kind(&self) -> QueueKind {
        match &self.q {
            QueueImpl::Calendar(_) => QueueKind::Calendar,
            QueueImpl::Heap(_) => QueueKind::Heap,
        }
    }

    /// Reset to an empty queue at clock zero for `kind`, reusing the
    /// current backing storage when the kind is unchanged (the per-run
    /// buffer-reuse path).
    pub fn reset(&mut self, kind: QueueKind) {
        if self.kind() == kind {
            match &mut self.q {
                QueueImpl::Calendar(q) => q.clear(),
                QueueImpl::Heap(q) => q.clear(),
            }
        } else {
            *self = Self::with_kind(kind);
        }
    }

    /// Current simulation clock (time of the last popped event).
    pub fn now(&self) -> SimTime {
        match &self.q {
            QueueImpl::Calendar(q) => q.now(),
            QueueImpl::Heap(q) => q.now(),
        }
    }

    /// Schedule `payload` at absolute time `at` (clamped to now).
    pub fn schedule(&mut self, at: SimTime, payload: T) {
        match &mut self.q {
            QueueImpl::Calendar(q) => q.schedule(at, payload),
            QueueImpl::Heap(q) => q.schedule(at, payload),
        }
    }

    /// Schedule `payload` after a delay from the current clock.
    pub fn schedule_in(&mut self, delay: SimTime, payload: T) {
        match &mut self.q {
            QueueImpl::Calendar(q) => q.schedule_in(delay, payload),
            QueueImpl::Heap(q) => q.schedule_in(delay, payload),
        }
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, T)> {
        match &mut self.q {
            QueueImpl::Calendar(q) => q.pop(),
            QueueImpl::Heap(q) => q.pop(),
        }
    }

    pub fn is_empty(&self) -> bool {
        match &self.q {
            QueueImpl::Calendar(q) => q.is_empty(),
            QueueImpl::Heap(q) => q.is_empty(),
        }
    }

    pub fn len(&self) -> usize {
        match &self.q {
            QueueImpl::Calendar(q) => q.len(),
            QueueImpl::Heap(q) => q.len(),
        }
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_that, forall};

    const KINDS: [QueueKind; 2] = [QueueKind::Calendar, QueueKind::Heap];

    #[test]
    fn pops_in_time_order() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.schedule(3.0, "c");
            q.schedule(1.0, "a");
            q.schedule(2.0, "b");
            let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
            assert_eq!(order, vec!["a", "b", "c"], "{kind:?}");
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.schedule(1.0, 1);
            q.schedule(1.0, 2);
            q.schedule(1.0, 3);
            let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
            assert_eq!(order, vec![1, 2, 3], "{kind:?}");
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.schedule(5.0, ());
            q.schedule(2.0, ());
            let (t1, _) = q.pop().unwrap();
            let (t2, _) = q.pop().unwrap();
            assert!(t1 <= t2, "{kind:?}");
            assert_eq!(q.now(), 5.0, "{kind:?}");
        }
    }

    #[test]
    fn past_events_clamp_to_now() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.schedule(10.0, "late");
            q.pop();
            q.schedule(3.0, "early"); // in the past — clamped
            let (t, _) = q.pop().unwrap();
            assert_eq!(t, 10.0, "{kind:?}");
        }
    }

    #[test]
    fn schedule_in_is_relative() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.schedule(4.0, "x");
            q.pop();
            q.schedule_in(2.5, "y");
            let (t, _) = q.pop().unwrap();
            assert!((t - 6.5).abs() < 1e-12, "{kind:?}");
        }
    }

    #[test]
    fn calendar_fifo_ties_hold_across_bucket_wraps() {
        // Initial geometry: 16 buckets × width 1.0 — times 0.5 and 16.5
        // share bucket 0 across a full ring wrap. FIFO `seq` tie-breaks
        // must hold within each day, and the near day must drain first.
        let mut q = CalendarQueue::new();
        q.schedule(16.5, "far-1");
        q.schedule(0.5, "near-1");
        q.schedule(0.5, "near-2");
        q.schedule(16.5, "far-2");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["near-1", "near-2", "far-1", "far-2"]);
    }

    #[test]
    fn calendar_sparse_far_future_uses_global_fallback() {
        // One event many ring cycles past the clock: the day sweep finds
        // nothing within one cycle and the global min-scan must take over.
        let mut q = CalendarQueue::new();
        q.schedule(1.0e7, "far");
        q.schedule(1.0e7, "far-2"); // FIFO holds on the fallback path too
        assert_eq!(q.pop().map(|(_, p)| p), Some("far"));
        assert_eq!(q.pop().map(|(_, p)| p), Some("far-2"));
        assert!(q.is_empty());
    }

    #[test]
    fn calendar_grow_preserves_pop_order() {
        // 100 events force two ring doublings mid-stream; order must stay
        // a pure function of (time, seq).
        let mut cal = CalendarQueue::new();
        let mut heap = HeapQueue::new();
        for i in 0..100u64 {
            // times collide in pairs to exercise ties while growing
            let t = ((i / 2) * 7 % 50) as f64 * 3.5;
            cal.schedule(t, i);
            heap.schedule(t, i);
        }
        assert_eq!(cal.len(), 100);
        for _ in 0..100 {
            assert_eq!(cal.pop(), heap.pop());
        }
        assert!(cal.pop().is_none() && heap.pop().is_none());
    }

    #[test]
    fn reset_reuses_storage_and_rewinds_the_clock() {
        for kind in KINDS {
            let mut q = EventQueue::with_kind(kind);
            q.schedule(9.0, 1);
            q.pop();
            q.schedule(11.0, 2);
            q.reset(kind);
            assert!(q.is_empty());
            assert_eq!(q.now(), 0.0);
            assert_eq!(q.kind(), kind);
            // a fresh schedule starts the sequence again at zero: ties
            // behave exactly as on a brand-new queue
            q.schedule(1.0, 10);
            q.schedule(1.0, 20);
            assert_eq!(q.pop(), Some((1.0, 10)));
            assert_eq!(q.pop(), Some((1.0, 20)));
        }
        // switching kinds rebuilds the backing store
        let mut q: EventQueue<u8> = EventQueue::with_kind(QueueKind::Heap);
        q.reset(QueueKind::Calendar);
        assert_eq!(q.kind(), QueueKind::Calendar);
    }

    #[test]
    fn calendar_matches_heap_on_random_interleaved_schedules() {
        // The pop-order-equivalence property the golden digests rely on:
        // any interleaving of schedules and pops — same-time ties, past
        // clamps, wide day jumps (bucket wraps), grow mid-stream — yields
        // the identical (time, payload) stream from both implementations.
        forall("calendar ≡ heap pop order", 200, |g| {
            let mut cal = CalendarQueue::new();
            let mut heap = HeapQueue::new();
            let ops = g.usize_in(1, 120);
            let mut next_id = 0u64;
            for _ in 0..ops {
                if g.bool() || cal.is_empty() {
                    // cluster times so ties actually occur, with rare
                    // far-future jumps to force ring wraps
                    let base = g.f64_in(0.0, 40.0).floor();
                    let t = if g.u64_in(0, 9) == 0 { base * 1000.0 } else { base };
                    cal.schedule(t, next_id);
                    heap.schedule(t, next_id);
                    next_id += 1;
                } else {
                    let (a, b) = (cal.pop(), heap.pop());
                    assert_that(a == b, format!("mid-stream pop diverged: {a:?} vs {b:?}"))?;
                }
            }
            loop {
                let (a, b) = (cal.pop(), heap.pop());
                assert_that(a == b, format!("drain pop diverged: {a:?} vs {b:?}"))?;
                if a.is_none() {
                    break;
                }
            }
            assert_that(cal.now() == heap.now(), "clocks diverged")
        });
    }
}
