//! Discrete-event calendar: a min-heap of (time, sequence, payload) events.
//!
//! The sequence number breaks ties deterministically so simulation results
//! are bit-reproducible for a given seed regardless of heap internals.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in seconds.
pub type SimTime = f64;

struct Entry<T> {
    time: SimTime,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap; ties broken by insertion order.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic event queue.
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
    now: SimTime,
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Current simulation clock (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `payload` at absolute time `at` (clamped to now).
    pub fn schedule(&mut self, at: SimTime, payload: T) {
        let at = if at < self.now { self.now } else { at };
        debug_assert!(at.is_finite(), "scheduling at non-finite time");
        self.heap.push(Entry { time: at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` after a delay from the current clock.
    pub fn schedule_in(&mut self, delay: SimTime, payload: T) {
        let now = self.now;
        self.schedule(now + delay.max(0.0), payload);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, T)> {
        self.heap.pop().map(|e| {
            self.now = e.time;
            (e.time, e.payload)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.schedule(2.0, ());
        let (t1, _) = q.pop().unwrap();
        let (t2, _) = q.pop().unwrap();
        assert!(t1 <= t2);
        assert_eq!(q.now(), 5.0);
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut q = EventQueue::new();
        q.schedule(10.0, "late");
        q.pop();
        q.schedule(3.0, "early"); // in the past — clamped
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 10.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(4.0, "x");
        q.pop();
        q.schedule_in(2.5, "y");
        let (t, _) = q.pop().unwrap();
        assert!((t - 6.5).abs() < 1e-12);
    }
}
