//! Slab-style attempt arena and the fixed-capacity running-attempt set —
//! the allocation-free bookkeeping behind the simulator's hot path.
//!
//! [`Arena`] is an append-only id-indexed store: ids are dense `usize`s in
//! launch order, lookups are plain indexing, and `clear` rewinds length
//! while keeping capacity for the next run (the `SimBuffers` reuse path).
//! Nothing in the API requires `T: Clone` — event handling borrows records
//! in place (see the non-`Clone` payload test below, the contract ISSUE 7
//! pins).
//!
//! [`RunningSet`] holds the live attempt ids of one task. The scheduler
//! launches at most an original plus one speculative backup per task
//! (`backups > 0` guards a second), so two inline slots suffice — a `Copy`
//! value replacing the former per-task `Vec<usize>`.

/// Append-only slab keyed by dense insertion-order ids.
pub struct Arena<T> {
    items: Vec<T>,
}

impl<T> Arena<T> {
    pub fn new() -> Self {
        Arena { items: Vec::new() }
    }

    /// Insert `item`, returning its id (== insertion count so far).
    pub fn push(&mut self, item: T) -> usize {
        self.items.push(item);
        self.items.len() - 1
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Drop all items, keeping the backing capacity for reuse.
    pub fn clear(&mut self) {
        self.items.clear();
    }
}

impl<T> Default for Arena<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> std::ops::Index<usize> for Arena<T> {
    type Output = T;
    fn index(&self, id: usize) -> &T {
        &self.items[id]
    }
}

impl<T> std::ops::IndexMut<usize> for Arena<T> {
    fn index_mut(&mut self, id: usize) -> &mut T {
        &mut self.items[id]
    }
}

/// Live attempt ids of one task: the original and at most one speculative
/// backup. `Copy`, so task state moves without heap traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RunningSet {
    ids: [usize; 2],
    len: u8,
}

impl RunningSet {
    /// Add an id. The scheduler's `backups > 0` guard makes a third live
    /// attempt unreachable; a debug build asserts it, a release build
    /// drops the overflow.
    pub fn push(&mut self, id: usize) {
        debug_assert!(self.len < 2, "a task runs at most an original and one backup");
        if (self.len as usize) < 2 {
            self.ids[self.len as usize] = id;
            self.len += 1;
        }
    }

    /// Remove `id` if present, preserving the order of the remainder.
    pub fn remove(&mut self, id: usize) {
        if self.len >= 1 && self.ids[0] == id {
            self.ids[0] = self.ids[1];
            self.len -= 1;
        } else if self.len == 2 && self.ids[1] == id {
            self.len -= 1;
        }
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The live ids, oldest first.
    pub fn as_slice(&self) -> &[usize] {
        &self.ids[..self.len as usize]
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deliberately NOT `Clone`: stable Rust cannot write a negative
    /// trait bound, so compiling the arena (and the simulator's event
    /// handling) against a clone-less payload *is* the proof that the
    /// hot path no longer clones attempt records.
    struct NoClone {
        x: u64,
    }

    #[test]
    fn arena_works_without_clone() {
        let mut a: Arena<NoClone> = Arena::new();
        assert!(a.is_empty());
        let i0 = a.push(NoClone { x: 10 });
        let i1 = a.push(NoClone { x: 20 });
        assert_eq!((i0, i1), (0, 1));
        assert_eq!(a.len(), 2);
        a[i0].x += 1;
        assert_eq!(a[i0].x, 11);
        assert_eq!(a[i1].x, 20);
        a.clear();
        assert!(a.is_empty());
        // ids restart densely after a clear (per-run reuse semantics)
        assert_eq!(a.push(NoClone { x: 30 }), 0);
    }

    #[test]
    fn running_set_push_remove_preserves_order() {
        let mut s = RunningSet::default();
        assert!(s.is_empty());
        s.push(7);
        s.push(9);
        assert_eq!(s.as_slice(), &[7, 9]);
        s.remove(7);
        assert_eq!(s.as_slice(), &[9]);
        s.remove(42); // absent id: no-op
        assert_eq!(s.as_slice(), &[9]);
        s.remove(9);
        assert!(s.is_empty());
        // removing the newer of two keeps the older in place
        s.push(1);
        s.push(2);
        s.remove(2);
        assert_eq!(s.as_slice(), &[1]);
        s.clear();
        assert!(s.is_empty());
    }

    #[test]
    fn running_set_take_resets_to_empty() {
        let mut s = RunningSet::default();
        s.push(3);
        s.push(4);
        let taken = std::mem::take(&mut s);
        assert_eq!(taken.as_slice(), &[3, 4]);
        assert!(s.is_empty());
    }
}
