//! Calibration constants of the simulator's cost model. These are the
//! "physics" of the simulated Hadoop installation: fixed framework costs a
//! real deployment would exhibit but the paper does not tune.

/// Seconds to cold-start a task JVM (v1 always pays this; v2 amortizes it
/// over `jvm.numtasks` tasks).
pub const JVM_START_S: f64 = 1.4;

/// Residual per-task scheduling/launch overhead when a JVM is reused.
pub const TASK_LAUNCH_S: f64 = 0.15;

/// Fixed job setup (split computation, staging) + cleanup time in seconds.
pub const JOB_SETUP_S: f64 = 5.0;
pub const JOB_CLEANUP_S: f64 = 3.0;

/// Per-spill-file constant cost (file create + fsync + seek), seconds.
pub const SPILL_FILE_S: f64 = 0.006;

/// Per-file open cost during merges, seconds.
pub const FILE_OPEN_S: f64 = 0.003;

/// CPU ops per record-comparison in the sort (k·log₂k model).
pub const SORT_OPS_PER_CMP: f64 = 12.0;

/// CPU ops per record for one combiner application.
pub const COMBINE_OPS_PER_REC: f64 = 18.0;

/// CPU ops per byte for zlib-class compression / decompression.
pub const COMPRESS_OPS_PER_BYTE: f64 = 5.0;
pub const DECOMPRESS_OPS_PER_BYTE: f64 = 1.5;

/// CPU ops per byte for the merge copy path.
pub const MERGE_OPS_PER_BYTE: f64 = 0.4;

/// Number of concurrently-merged streams a disk handles before seek
/// thrashing degrades throughput.
pub const MERGE_STREAM_SWEET_SPOT: f64 = 48.0;

/// Throughput degradation slope beyond the sweet spot: rate divisor grows
/// by (streams - sweet)/this.
pub const MERGE_STREAM_PENALTY_DIV: f64 = 96.0;

/// Fraction of a reduce task's shuffle that can start only after the last
/// map wave produces its output (the non-overlappable tail).
pub const SHUFFLE_TAIL_FRACTION: f64 = 0.5;

/// Reduce-function memory-pressure penalty coefficient: reduce CPU is
/// multiplied by (1 + coeff · riB²) where riB = reduce.input.buffer.percent.
/// Retaining map outputs in the heap squeezes the reduce function.
pub const REDUCE_MEM_PRESSURE_COEFF: f64 = 0.6;

/// Lognormal sigma for per-task multiplicative noise — the run-to-run
/// variance SPSA must filter (paper §4.2).
pub const TASK_NOISE_SIGMA: f64 = 0.10;

/// Straggler probability and slowdown factor.
pub const STRAGGLER_P: f64 = 0.015;
pub const STRAGGLER_FACTOR: f64 = 2.2;

/// Delay between a slot going idle with no pending work and the scheduler
/// launching a speculative backup copy (the JobTracker's speculation lag).
pub const SPECULATIVE_DELAY_S: f64 = 1.0;

/// Only speculate on attempts with at least this much expected remaining
/// run time — backing up a nearly-done task is pure waste.
pub const SPECULATIVE_MIN_REMAINING_S: f64 = 5.0;

/// Objective penalty multiplier for a failed job (a task exhausted
/// `max.attempts`, or node losses made the job unplaceable): the tuner
/// must see failed configurations as far worse than any completed run.
pub const FAILED_JOB_PENALTY: f64 = 10.0;

/// Failed-job score for counter-based metrics (spilled records, shuffled
/// bytes, …). Those counters commit on success only, so an early abort
/// drives them toward zero and no extrapolation can recover the full-job
/// scale from the run itself; a sentinel far above any physical counter
/// value keeps job-killing configurations unattractive.
pub const FAILED_METRIC_SENTINEL: f64 = 1e30;
