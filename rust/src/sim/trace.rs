//! Simulation outputs: per-phase time breakdown, data-flow counters and the
//! job-level result — the "job history" a real Hadoop run would leave behind
//! (and what profiling-based baselines like Starfish consume).

use crate::util::units::{fmt_bytes, fmt_secs};

/// Aggregate time spent in each pipeline phase, summed over tasks.
/// (Wall-clock job time is shorter because tasks run in parallel.)
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub task_setup: f64,
    pub map_read: f64,
    pub map_cpu: f64,
    /// Spill-side work on the map: sort + combine + compress + write.
    pub map_spill: f64,
    pub map_merge: f64,
    pub shuffle: f64,
    pub reduce_merge: f64,
    pub reduce_cpu: f64,
    pub output_write: f64,
    /// Work thrown away by the scenario engine: failed attempts up to their
    /// failure point, and speculative/node-loss kills up to the kill.
    pub wasted: f64,
}

impl PhaseBreakdown {
    pub fn total(&self) -> f64 {
        self.task_setup
            + self.map_read
            + self.map_cpu
            + self.map_spill
            + self.map_merge
            + self.shuffle
            + self.reduce_merge
            + self.reduce_cpu
            + self.output_write
            + self.wasted
    }

    pub fn add(&mut self, other: &PhaseBreakdown) {
        self.task_setup += other.task_setup;
        self.map_read += other.map_read;
        self.map_cpu += other.map_cpu;
        self.map_spill += other.map_spill;
        self.map_merge += other.map_merge;
        self.shuffle += other.shuffle;
        self.reduce_merge += other.reduce_merge;
        self.reduce_cpu += other.reduce_cpu;
        self.output_write += other.output_write;
        self.wasted += other.wasted;
    }

    /// (label, seconds) rows for display, largest first.
    pub fn rows(&self) -> Vec<(&'static str, f64)> {
        let mut v = vec![
            ("task setup", self.task_setup),
            ("map read", self.map_read),
            ("map cpu", self.map_cpu),
            ("map spill (sort+combine+write)", self.map_spill),
            ("map merge", self.map_merge),
            ("shuffle", self.shuffle),
            ("reduce merge", self.reduce_merge),
            ("reduce cpu", self.reduce_cpu),
            ("output write", self.output_write),
            ("wasted (failed/killed attempts)", self.wasted),
        ];
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v
    }
}

/// Data-flow counters, mirroring Hadoop's job counters.
#[derive(Clone, Debug, Default)]
pub struct SimCounters {
    pub n_maps: u64,
    pub n_reduces: u64,
    pub map_waves: u64,
    pub reduce_waves: u64,
    /// Total spill files written by all map tasks.
    pub spilled_files: u64,
    /// Records written to spill files (Hadoop's "Spilled Records").
    pub spilled_records: u64,
    pub map_output_bytes: u64,
    /// Bytes moved map→reduce over the network.
    pub shuffled_bytes: u64,
    /// Reduce-side bytes written to disk before the reduce function.
    pub reduce_spilled_bytes: u64,
    pub output_bytes: u64,
    /// Map tasks that read their split from a local replica.
    pub data_local_maps: u64,

    // -- scenario-engine counters (all zero on a benign run) ---------------
    /// Map attempts launched (originals + retries + speculative copies).
    pub map_attempts: u64,
    /// Reduce attempts launched.
    pub reduce_attempts: u64,
    /// Map tasks that completed successfully (== n_maps unless the job
    /// failed; each split is processed exactly once).
    pub map_successes: u64,
    /// Reduce tasks that completed successfully.
    pub reduce_successes: u64,
    /// Map attempts that failed mid-run (fault injection).
    pub map_failures: u64,
    /// Reduce attempts that failed mid-run.
    pub reduce_failures: u64,
    /// The most failed attempts accumulated by any single task — never
    /// exceeds the scenario's `max_attempts`.
    pub max_task_failures: u64,
    /// Speculative backup copies launched.
    pub speculative_launches: u64,
    /// Backup copies that finished before their original.
    pub speculative_wins: u64,
    /// Attempts killed (losing speculation copies + node-loss victims).
    pub killed_attempts: u64,
    /// Workers permanently lost to scheduled crashes.
    pub nodes_lost: u64,

    // -- metering (not physics) --------------------------------------------
    /// Discrete events dispatched by the simulator's main loop. Perf
    /// metering for `repro bench` (ns/event denominators), not a modeled
    /// quantity — deliberately excluded from golden-trace digests.
    pub events: u64,
    /// Cost-model evaluations (`map_task_cost`/`reduce_task_cost` calls)
    /// this run actually performed. In `CostMode::Direct` this equals
    /// `map_attempts + reduce_attempts`; in `Table` mode memo hits make
    /// it (much) smaller. Metering only — excluded from golden digests
    /// AND from `SimCounters` equality, since table-vs-direct and
    /// warm-vs-cold runs legitimately differ here while their physics
    /// compare equal.
    pub cost_evals: u64,
    /// Lookups served from warm state inherited from a previous run in
    /// the same buffer pool (memoized costs + the attempt-0 noise
    /// prefix). Metering only — excluded from digests and equality like
    /// `cost_evals`.
    pub warm_hits: u64,
}

/// Equality covers physics plus the deterministic `events` meter, and
/// deliberately EXCLUDES `cost_evals`/`warm_hits`: the costing fast
/// path changes how many evaluations a run performs without changing
/// what the job does, and the table≡direct / warm≡cold equivalence
/// tests assert `counters ==` across exactly that difference.
impl PartialEq for SimCounters {
    fn eq(&self, o: &Self) -> bool {
        self.n_maps == o.n_maps
            && self.n_reduces == o.n_reduces
            && self.map_waves == o.map_waves
            && self.reduce_waves == o.reduce_waves
            && self.spilled_files == o.spilled_files
            && self.spilled_records == o.spilled_records
            && self.map_output_bytes == o.map_output_bytes
            && self.shuffled_bytes == o.shuffled_bytes
            && self.reduce_spilled_bytes == o.reduce_spilled_bytes
            && self.output_bytes == o.output_bytes
            && self.data_local_maps == o.data_local_maps
            && self.map_attempts == o.map_attempts
            && self.reduce_attempts == o.reduce_attempts
            && self.map_successes == o.map_successes
            && self.reduce_successes == o.reduce_successes
            && self.map_failures == o.map_failures
            && self.reduce_failures == o.reduce_failures
            && self.max_task_failures == o.max_task_failures
            && self.speculative_launches == o.speculative_launches
            && self.speculative_wins == o.speculative_wins
            && self.killed_attempts == o.killed_attempts
            && self.nodes_lost == o.nodes_lost
            && self.events == o.events
    }
}

/// Result of one simulated job execution.
#[derive(Clone, Debug)]
pub struct JobRunResult {
    /// The objective f(θ): wall-clock job execution time in seconds.
    pub exec_time_s: f64,
    pub phases: PhaseBreakdown,
    pub counters: SimCounters,
    /// Time the last map task finished (start of the reduce-only tail).
    pub maps_done_s: f64,
    /// True when the job did not complete: a task exhausted the scenario's
    /// `max_attempts`, or node losses left work unplaceable. The objective
    /// layer penalizes failed runs.
    pub job_failed: bool,
}

impl JobRunResult {
    /// Fraction of the job's tasks that completed successfully, in
    /// (0, 1]. Used to extrapolate an aborted run's truncated makespan to
    /// a full-job estimate: an early abort stops the clock long before a
    /// completed run would, so the raw `exec_time_s` of a failed job says
    /// nothing about how expensive finishing would have been.
    pub fn progress(&self) -> f64 {
        let done = self.counters.map_successes + self.counters.reduce_successes;
        let total = self.counters.n_maps + self.counters.n_reduces;
        (((done + 1) as f64) / ((total + 1) as f64)).clamp(1e-3, 1.0)
    }

    /// Human-readable run report (used by `repro run` and cluster_trace).
    pub fn report(&self) -> String {
        let c = &self.counters;
        let mut s = String::new();
        s.push_str(&format!("job time: {}\n", fmt_secs(self.exec_time_s)));
        if self.job_failed {
            s.push_str("JOB FAILED (max.attempts exhausted or cluster lost)\n");
        }
        s.push_str(&format!(
            "maps: {} ({} waves, {} data-local)   reduces: {} ({} waves)\n",
            c.n_maps, c.map_waves, c.data_local_maps, c.n_reduces, c.reduce_waves
        ));
        if c.map_failures + c.reduce_failures + c.speculative_launches + c.nodes_lost > 0 {
            s.push_str(&format!(
                "scenario: {} map / {} reduce attempt failures   {} speculative \
                 ({} won)   {} killed   {} nodes lost\n",
                c.map_failures,
                c.reduce_failures,
                c.speculative_launches,
                c.speculative_wins,
                c.killed_attempts,
                c.nodes_lost,
            ));
        }
        s.push_str(&format!(
            "map output: {}   shuffled: {}   spill files: {}   spilled records: {}\n",
            fmt_bytes(c.map_output_bytes),
            fmt_bytes(c.shuffled_bytes),
            c.spilled_files,
            c.spilled_records,
        ));
        s.push_str(&format!(
            "reduce-side spill: {}   output: {}\n",
            fmt_bytes(c.reduce_spilled_bytes),
            fmt_bytes(c.output_bytes)
        ));
        s.push_str("phase breakdown (task-seconds):\n");
        for (label, secs) in self.phases.rows() {
            if secs > 0.0 {
                s.push_str(&format!("  {:<32} {}\n", label, fmt_secs(secs)));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_and_add() {
        let mut a = PhaseBreakdown { map_cpu: 1.0, shuffle: 2.0, ..Default::default() };
        let b = PhaseBreakdown { map_cpu: 0.5, output_write: 1.5, ..Default::default() };
        a.add(&b);
        assert!((a.total() - 5.0).abs() < 1e-12);
        assert!((a.map_cpu - 1.5).abs() < 1e-12);
    }

    #[test]
    fn rows_sorted_descending() {
        let p = PhaseBreakdown { map_cpu: 1.0, shuffle: 5.0, map_read: 3.0, ..Default::default() };
        let rows = p.rows();
        assert_eq!(rows[0].0, "shuffle");
        assert!(rows.windows(2).all(|w| w[0].1 >= w[1].1));
    }

    #[test]
    fn report_mentions_key_counters() {
        let r = JobRunResult {
            exec_time_s: 123.0,
            phases: PhaseBreakdown::default(),
            counters: SimCounters { n_maps: 10, n_reduces: 4, ..Default::default() },
            maps_done_s: 100.0,
            job_failed: false,
        };
        let rep = r.report();
        assert!(rep.contains("maps: 10"));
        assert!(rep.contains("reduces: 4"));
        assert!(!rep.contains("scenario:"), "benign run must not print scenario line");
    }

    #[test]
    fn report_surfaces_scenario_outcomes() {
        let r = JobRunResult {
            exec_time_s: 99.0,
            phases: PhaseBreakdown { wasted: 12.0, ..Default::default() },
            counters: SimCounters {
                n_maps: 8,
                n_reduces: 2,
                map_failures: 3,
                speculative_launches: 2,
                speculative_wins: 1,
                nodes_lost: 1,
                ..Default::default()
            },
            maps_done_s: 50.0,
            job_failed: true,
        };
        let rep = r.report();
        assert!(rep.contains("JOB FAILED"));
        assert!(rep.contains("3 map"));
        assert!(rep.contains("1 nodes lost"));
        assert!(rep.contains("wasted"));
    }

    #[test]
    fn counters_equality_ignores_costing_meters_but_not_events() {
        let a = SimCounters { n_maps: 4, events: 100, cost_evals: 36, warm_hits: 0, ..Default::default() };
        // Same physics + events, different costing meters: equal (the
        // table≡direct and warm≡cold tests rely on this).
        let b = SimCounters { cost_evals: 5, warm_hits: 31, ..a.clone() };
        assert_eq!(a, b);
        // events stays inside equality — it is deterministic physics-adjacent
        // metering that queue implementations must agree on.
        let c = SimCounters { events: 101, ..a.clone() };
        assert_ne!(a, c);
        let d = SimCounters { n_maps: 5, ..a.clone() };
        assert_ne!(a, d);
    }
}
