//! Map-task cost model: the paper's §2.3.1 data path priced in seconds.
//!
//! read → map function → circular buffer → {sort, combine, compress, spill}
//! cycles → multi-pass merge of spill files. Pure function of
//! (config, workload, split size, effective bandwidths) so it is testable in
//! isolation; the scheduler supplies contention-adjusted bandwidths.

use super::constants::*;
use crate::config::HadoopConfig;
use crate::workloads::WorkloadProfile;

/// Effective resource rates seen by one task (after contention sharing).
#[derive(Clone, Copy, Debug)]
pub struct TaskRates {
    pub disk_bw: f64,
    pub net_bw: f64,
    pub cpu_ops_per_sec: f64,
}

/// Cost breakdown of one map task. `Copy` (all-scalar) so the costing
/// memo in `sim::cost` can store and serve it by value.
#[derive(Clone, Copy, Debug, Default)]
pub struct MapTaskCost {
    pub read_s: f64,
    pub map_cpu_s: f64,
    /// Spill-side work: sort + combine + compress + spill writes.
    pub spill_s: f64,
    pub merge_s: f64,
    /// Map phase wall time accounting for map/spill overlap (excludes read
    /// and merge).
    pub overlapped_phase_s: f64,
    pub n_spills: u64,
    pub spilled_records: u64,
    /// Bytes of final map output on disk (post combine, post compression).
    pub output_bytes: u64,
    /// Post-combiner output bytes *before* compression — the logical volume
    /// reducers must process.
    pub output_bytes_raw: u64,
    /// Map output records after the (per-spill) combiner.
    pub output_records: u64,
}

/// Size of one map task's output (data only, no timing): used by the
/// scheduler to know total shuffle volume before reducers launch.
#[derive(Clone, Copy, Debug, Default)]
pub struct MapOutputSize {
    /// Post-combiner, pre-compression bytes.
    pub raw_bytes: f64,
    /// On-disk / on-wire bytes (compressed if configured).
    pub wire_bytes: f64,
    pub records: f64,
    pub n_spills: u64,
}

/// Compute a map task's output volume for a split — pure data-flow, no
/// resource rates involved.
pub fn map_output_for_split(
    config: &HadoopConfig,
    w: &WorkloadProfile,
    split_bytes: u64,
) -> MapOutputSize {
    let records = split_bytes as f64 / w.avg_input_record_bytes;
    let out_bytes = split_bytes as f64 * w.map_selectivity_bytes;
    let out_records = records * w.map_selectivity_records;
    if out_bytes <= 0.0 {
        return MapOutputSize::default();
    }
    let n_spills = spill_count(config, out_bytes, out_records);
    let r_eff = if w.has_combiner {
        effective_combiner_reduction(w.combiner_reduction, n_spills)
    } else {
        1.0
    };
    let raw = out_bytes * r_eff;
    let wire = if config.compress_map_output { raw * w.compress_ratio } else { raw };
    MapOutputSize { raw_bytes: raw, wire_bytes: wire, records: out_records * r_eff, n_spills }
}

impl MapTaskCost {
    /// Total task wall time excluding setup.
    pub fn wall_s(&self) -> f64 {
        self.read_s + self.overlapped_phase_s + self.merge_s
    }
}

/// Effective combiner survival ratio when the map output is cut into
/// `n_spills` pieces: a combiner over many small spills sees fewer duplicate
/// keys, so its measured whole-output reduction `r` degrades toward 1.
pub fn effective_combiner_reduction(r: f64, n_spills: u64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&r));
    if n_spills <= 1 {
        return r;
    }
    let dilution = 1.0 + (n_spills as f64).ln();
    1.0 - (1.0 - r) / dilution
}

/// Number of spills produced by one map task (paper §2.3.1: triggered by
/// either the data threshold or — v1 — the record-metadata threshold).
pub fn spill_count(config: &HadoopConfig, out_bytes: f64, out_records: f64) -> u64 {
    if out_bytes <= 0.0 {
        return 0;
    }
    let data_trigger = (config.sort_buffer_data_bytes() as f64 * config.spill_percent).max(1.0);
    let record_trigger =
        (config.sort_buffer_record_capacity() as f64 * config.spill_percent).max(1.0);
    let by_data = (out_bytes / data_trigger).ceil() as u64;
    let by_records = (out_records / record_trigger).ceil() as u64;
    by_data.max(by_records).max(1)
}

/// Overlap efficiency between the map function and the spill thread.
/// A low spill threshold starts spilling early (good overlap); a high
/// threshold means the buffer is nearly full when spilling starts and the
/// map blocks (paper §2.3.1: "If any time the buffer becomes full, the Map
/// task is blocked till spill finishes").
pub fn spill_overlap_efficiency(spill_percent: f64) -> f64 {
    ((1.0 - spill_percent) * 1.6).clamp(0.05, 1.0)
}

/// Price one map task processing `split_bytes` of input.
pub fn map_task_cost(
    config: &HadoopConfig,
    w: &WorkloadProfile,
    split_bytes: u64,
    local_read: bool,
    rates: &TaskRates,
) -> MapTaskCost {
    let mut c = MapTaskCost::default();
    let cpu = rates.cpu_ops_per_sec;

    // ---- read (OS layer: readahead boosts sequential reads; the TCP
    // window caps remote-flow bandwidth) --------------------------------
    let read_bw = if local_read {
        rates.disk_bw * config.os.readahead_boost()
    } else {
        rates.net_bw.min(config.os.net_window_bw())
    };
    c.read_s = split_bytes as f64 / read_bw.max(1.0);

    // ---- map function ------------------------------------------------------
    let records = split_bytes as f64 / w.avg_input_record_bytes;
    c.map_cpu_s = records * w.map_cpu_ops_per_record / cpu;

    let out_bytes = split_bytes as f64 * w.map_selectivity_bytes;
    let out_records = records * w.map_selectivity_records;
    if out_bytes <= 0.0 {
        // map-only-style task with no output (degenerate; still valid)
        c.overlapped_phase_s = c.map_cpu_s;
        return c;
    }

    // ---- spill cycles ------------------------------------------------------
    let size = map_output_for_split(config, w, split_bytes);
    let n_spills = size.n_spills;
    c.n_spills = n_spills;
    // Hadoop's "Spilled Records" counter: every record written to local
    // disk, including re-writes by multi-pass merges.
    c.spilled_records = out_records as u64;

    // sort: records · log2(records-per-spill) comparisons
    let per_spill_records = (out_records / n_spills as f64).max(2.0);
    let sort_cpu_s = out_records * per_spill_records.log2() * SORT_OPS_PER_CMP / cpu;

    // combiner (per spill)
    let combine_cpu_s = if w.has_combiner { out_records * COMBINE_OPS_PER_REC / cpu } else { 0.0 };
    let surviving_bytes = size.raw_bytes;
    let surviving_records = size.records;

    // compression of spill output
    let disk_bytes = size.wire_bytes;
    let compress_cpu_s = if config.compress_map_output {
        surviving_bytes * COMPRESS_OPS_PER_BYTE / cpu
    } else {
        0.0
    };

    let spill_io_s = disk_bytes / rates.disk_bw.max(1.0)
        + n_spills as f64 * SPILL_FILE_S * config.os.spill_overhead_factor();
    c.spill_s = sort_cpu_s + combine_cpu_s + compress_cpu_s + spill_io_s;

    // ---- overlap of map-side and spill-side work ---------------------------
    let overlap = spill_overlap_efficiency(config.spill_percent);
    let a = c.map_cpu_s;
    let b = c.spill_s;
    c.overlapped_phase_s = a.max(b) + (1.0 - overlap) * a.min(b);

    // ---- merge spills into the final map output ----------------------------
    if n_spills > 1 {
        let factor = config.sort_factor.max(2) as f64;
        let passes = ((n_spills as f64).ln() / factor.ln()).ceil().max(1.0);
        // each pass reads + writes the full surviving output
        let streams = factor.min(n_spills as f64);
        let seek_divisor = 1.0 + ((streams - MERGE_STREAM_SWEET_SPOT).max(0.0)) / MERGE_STREAM_PENALTY_DIV;
        let merge_rate = rates.disk_bw.max(1.0) / seek_divisor;
        let merge_io_s = passes * disk_bytes * 2.0 / merge_rate;
        let merge_cpu_s = passes * surviving_bytes * MERGE_OPS_PER_BYTE / cpu;
        let open_s = (n_spills as f64 + passes * streams) * FILE_OPEN_S;
        c.merge_s = merge_io_s + merge_cpu_s + open_s;
        // merge passes re-write every surviving record
        c.spilled_records += (surviving_records * passes) as u64;
    }

    c.output_bytes = disk_bytes as u64;
    c.output_bytes_raw = surviving_bytes as u64;
    c.output_records = surviving_records as u64;
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParameterSpace;

    fn rates() -> TaskRates {
        TaskRates { disk_bw: 40e6, net_bw: 40e6, cpu_ops_per_sec: 2e8 }
    }

    fn terasort_like() -> WorkloadProfile {
        WorkloadProfile {
            name: "tera".into(),
            input_bytes: 30 << 30,
            avg_input_record_bytes: 100.0,
            map_selectivity_bytes: 1.0,
            map_selectivity_records: 1.0,
            avg_map_record_bytes: 100.0,
            combiner_reduction: 1.0,
            has_combiner: false,
            reduce_selectivity_bytes: 1.0,
            partition_skew: 1.1,
            compress_ratio: 0.4,
            map_cpu_ops_per_record: 60.0,
            reduce_cpu_ops_per_record: 50.0,
        }
    }

    fn grep_like() -> WorkloadProfile {
        WorkloadProfile {
            name: "grep".into(),
            input_bytes: 22 << 30,
            avg_input_record_bytes: 80.0,
            map_selectivity_bytes: 0.05,
            map_selectivity_records: 0.3,
            avg_map_record_bytes: 14.0,
            combiner_reduction: 0.3,
            has_combiner: true,
            reduce_selectivity_bytes: 0.5,
            partition_skew: 1.5,
            compress_ratio: 0.35,
            map_cpu_ops_per_record: 2600.0,
            reduce_cpu_ops_per_record: 120.0,
        }
    }

    #[test]
    fn default_config_spills_a_lot() {
        // paper Table 1 defaults: io.sort.mb=100, spill%=0.08 ⇒ a 128 MB
        // terasort split spills many times.
        let cfg = ParameterSpace::v1().default_config();
        let c = map_task_cost(&cfg, &terasort_like(), 128 << 20, true, &rates());
        assert!(c.n_spills > 10, "spills {}", c.n_spills);
        assert!(c.merge_s > 0.0);
    }

    #[test]
    fn bigger_buffer_fewer_spills() {
        let mut cfg = ParameterSpace::v1().default_config();
        let base = map_task_cost(&cfg, &terasort_like(), 128 << 20, true, &rates());
        cfg.io_sort_mb = 1024;
        cfg.spill_percent = 0.8;
        cfg.sort_record_percent = 0.2;
        let tuned = map_task_cost(&cfg, &terasort_like(), 128 << 20, true, &rates());
        assert!(tuned.n_spills < base.n_spills);
        assert!(tuned.wall_s() < base.wall_s(), "tuned {} base {}", tuned.wall_s(), base.wall_s());
    }

    #[test]
    fn spill_count_record_trigger_dominates_small_records() {
        // tiny records: the v1 record-metadata limit binds before the data
        // limit — the cross-parameter interaction the paper highlights.
        let mut cfg = ParameterSpace::v1().default_config();
        cfg.io_sort_mb = 100;
        cfg.sort_record_percent = 0.01; // tiny accounting space
        cfg.spill_percent = 0.8;
        let by_both = spill_count(&cfg, 10e6, 1_000_000.0);
        cfg.sort_record_percent = 0.4;
        let relaxed = spill_count(&cfg, 10e6, 1_000_000.0);
        assert!(by_both > relaxed);
    }

    #[test]
    fn compression_cuts_spill_io_but_costs_cpu() {
        let mut cfg = ParameterSpace::v1().default_config();
        cfg.io_sort_mb = 512;
        cfg.spill_percent = 0.8;
        let plain = map_task_cost(&cfg, &terasort_like(), 128 << 20, true, &rates());
        cfg.compress_map_output = true;
        let compressed = map_task_cost(&cfg, &terasort_like(), 128 << 20, true, &rates());
        assert!(compressed.output_bytes < plain.output_bytes);
    }

    #[test]
    fn remote_read_slower_than_local() {
        let cfg = ParameterSpace::v1().default_config();
        let slow_net = TaskRates { disk_bw: 80e6, net_bw: 20e6, cpu_ops_per_sec: 2e8 };
        let local = map_task_cost(&cfg, &grep_like(), 128 << 20, true, &slow_net);
        let remote = map_task_cost(&cfg, &grep_like(), 128 << 20, false, &slow_net);
        assert!(remote.read_s > local.read_s * 3.0);
    }

    #[test]
    fn combiner_dilution_monotone() {
        let r = 0.3;
        let mut last = effective_combiner_reduction(r, 1);
        assert!((last - r).abs() < 1e-12);
        for n in [2, 4, 16, 64, 1024] {
            let e = effective_combiner_reduction(r, n);
            assert!(e >= last, "not monotone at {n}");
            assert!(e <= 1.0);
            last = e;
        }
    }

    #[test]
    fn overlap_better_at_low_threshold() {
        assert!(spill_overlap_efficiency(0.1) > spill_overlap_efficiency(0.9));
        assert!(spill_overlap_efficiency(0.05) <= 1.0);
        assert!(spill_overlap_efficiency(0.95) >= 0.05);
    }

    #[test]
    fn grep_output_is_small() {
        let cfg = ParameterSpace::v1().default_config();
        let c = map_task_cost(&cfg, &grep_like(), 128 << 20, true, &rates());
        assert!(c.output_bytes < (128 << 20) / 10);
        // CPU-bound: map cpu dominates spill-side work
        assert!(c.map_cpu_s > c.spill_s);
    }

    #[test]
    fn huge_sort_factor_pays_seek_penalty() {
        let mut cfg = ParameterSpace::v1().default_config();
        cfg.io_sort_mb = 60;
        cfg.spill_percent = 0.1; // many spills
        cfg.sort_factor = 16;
        let modest = map_task_cost(&cfg, &terasort_like(), 128 << 20, true, &rates());
        cfg.sort_factor = 500;
        let huge = map_task_cost(&cfg, &terasort_like(), 128 << 20, true, &rates());
        // 500-way merge does one pass but thrashes; 16-way does more passes.
        // Neither dominates universally — just check both priced sanely.
        assert!(modest.merge_s > 0.0 && huge.merge_s > 0.0);
        assert!(huge.merge_s != modest.merge_s);
    }
}
