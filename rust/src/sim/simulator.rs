//! The discrete-event MapReduce job simulator — the "real system" whose
//! execution time is the objective `f(θ)` that SPSA observes.
//!
//! Slot-level event-driven scheduling over the cluster model: map tasks are
//! placed locality-first on free map slots; reducers launch once all maps
//! finish, with a shuffle-overlap credit earned from the slowstart point
//! onward (paper §2.3.2); every task's duration is priced by the
//! [`super::map_task`]/[`super::reduce_task`] cost models under per-node
//! resource contention, multiplied by seeded stochastic noise (lognormal +
//! stragglers) — the run-to-run randomness SPSA's iterates must filter
//! (paper §4.2, Fig. 4).
//!
//! **Scenario engine.** A [`ScenarioSpec`] in [`SimOptions`] turns the
//! benign cluster into a misbehaving one: task attempts fail mid-run and
//! retry up to `max.attempts` (job-kill beyond), nodes crash on a schedule
//! (slots die, lost splits re-queue locality-first), speculative backup
//! copies race slow originals with copy-kill semantics, and per-node speed
//! factors model heterogeneous fleets. Every stochastic decision is keyed
//! by `(seed, kind, task, attempt)` — see [`super::scenario`] — so runs
//! stay bit-reproducible and order-independent, and compose with
//! [`super::batch`] at any worker count.

use crate::cluster::{ClusterSpec, HdfsFile, Namenode, Resource, ResourceTracker};
use crate::config::{HadoopConfig, HadoopVersion};
use crate::util::rng::Rng;
use crate::workloads::WorkloadProfile;
// (placement uses a sequential seeded Rng at init; task noise and scenario
// fates come from keyed per-attempt streams in `scenario::attempt_rng`)

use super::arena::{Arena, RunningSet};
use super::constants::*;
use super::cost::{CostMode, WarmCache};
use super::event::{EventQueue, QueueKind};
use super::map_task::{map_output_for_split, map_task_cost, MapTaskCost, TaskRates};
use super::reduce_task::{reduce_task_cost, ReduceTaskCost};
use super::scenario::{self, ScenarioSpec, TaskKind};
use super::trace::{JobRunResult, PhaseBreakdown, SimCounters};

/// Simulation options.
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// RNG seed: placement, noise and scenario fates are deterministic per
    /// seed.
    pub seed: u64,
    /// Disable for the noise-free objective (landscape dumps, tests);
    /// SPSA observes the noisy system, as on a real cluster.
    pub noise: bool,
    /// Execution-substrate regime: task failures, node crashes, per-node
    /// speed factors, speculative execution. The default is the benign
    /// failure-free homogeneous cluster.
    pub scenario: ScenarioSpec,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { seed: 1, noise: true, scenario: ScenarioSpec::default() }
    }
}

/// Fraction of an early reducer's fetch window usable while maps still run
/// (the network is shared with map-side traffic during the overlap).
const FETCH_OVERLAP_EFF: f64 = 0.5;

#[derive(Clone, Copy, Debug)]
enum Event {
    /// Fill all map slots at job start.
    InitialFill,
    /// A task attempt ran to completion (ignored if the attempt was killed).
    TaskDone { attempt: usize },
    /// A task attempt died mid-run (fault injection).
    TaskFailed { attempt: usize },
    /// A scheduled permanent node loss (index into the crash schedule).
    NodeDown { crash: usize },
    /// A free slot looks for a straggling task to back up.
    SpeculativeLaunch { kind: TaskKind },
}

struct Slot {
    node: u32,
    tasks_run: u64,
    busy: bool,
    dead: bool,
}

/// Scheduler-side state of one task (map or reduce).
#[derive(Clone, Default)]
struct TaskState {
    completed: bool,
    /// Failed attempts so far — the `max.attempts` budget.
    failed_attempts: u64,
    /// Attempts ever launched (ordinal for keyed noise/fate derivation).
    attempts_launched: u64,
    /// Live attempt ids (original and at most one speculative copy).
    running: RunningSet,
    /// Speculative copies ever launched (at most one per task).
    backups: u64,
}

/// Counter deltas an attempt commits if (and only if) it succeeds.
#[derive(Clone, Copy, Default)]
struct AttemptCounters {
    data_local: bool,
    spilled_files: u64,
    spilled_records: u64,
    map_output_bytes: u64,
    shuffled_bytes: u64,
    reduce_spilled_bytes: u64,
    output_bytes: u64,
}

/// One in-flight (or finished) task attempt. Deliberately **not**
/// `Clone`: records live in the attempt [`Arena`] and every event handler
/// borrows them in place — termination paths copy out the small
/// [`Retired`] summary instead of the whole record (phase breakdown
/// included), which was the hot path's top allocation source.
struct AttemptInfo {
    kind: TaskKind,
    task: usize,
    slot: usize,
    node: u32,
    alive: bool,
    speculative: bool,
    holds_net: bool,
    start_s: f64,
    /// Scheduled wall end: completion or mid-run failure time.
    end_s: f64,
    /// Phase contribution, committed on success only.
    phases: PhaseBreakdown,
    /// Counter contribution, committed on success only.
    counters: AttemptCounters,
}

/// The slice of an attempt record the termination paths read after
/// retirement — a `Copy` summary, so no full-struct clone leaves the
/// arena.
#[derive(Clone, Copy)]
struct Retired {
    kind: TaskKind,
    task: usize,
    slot: usize,
    speculative: bool,
    start_s: f64,
}

fn kind_index(kind: TaskKind) -> usize {
    match kind {
        TaskKind::Map => 0,
        TaskKind::Reduce => 1,
    }
}

/// Reusable per-run allocation pool for the simulator: every growable
/// scheduler structure a run needs, handed back when the run finishes so
/// the next run starts from warmed capacity instead of a fresh heap. One
/// pool serves a whole `simulate_batch` wave (per worker), so a 64-probe
/// wave allocates its scheduler state once, not 64×.
///
/// Fields are private: a pool is only ever filled and cleared by the
/// simulator. `Namenode`/`HdfsFile`/`ResourceTracker` state is still
/// rebuilt per run (block placement is seed-dependent); the pool covers
/// the scheduler's hot structures. Reuse is physics-free — a run's result
/// is bit-identical whether its pool is fresh or warmed (see the
/// buffer-independence tests).
#[derive(Default)]
pub struct SimBuffers {
    q: EventQueue<Event>,
    node_pending: Vec<Vec<usize>>,
    pending_maps: Vec<usize>,
    map_assigned: Vec<bool>,
    pending_reduces: Vec<usize>,
    map_tasks: Vec<TaskState>,
    red_tasks: Vec<TaskState>,
    attempts: Arena<AttemptInfo>,
    node_dead: Vec<bool>,
    map_slots: Vec<Slot>,
    reduce_slots: Vec<Slot>,
    /// Scratch id list for crash/abort victim sweeps.
    scratch: Vec<usize>,
    /// Cross-run warm state for the costing fast path (cost tables +
    /// attempt-0 noise prefix — see `sim::cost`). Unlike the other pool
    /// fields its *contents* deliberately survive between runs; it is
    /// still physics-free, because a memo hit returns the pure cost
    /// functions' own earlier output (table ≡ direct is property- and
    /// golden-tested).
    warm: WarmCache,
}

impl SimBuffers {
    pub fn new() -> Self {
        Self::default()
    }
}

struct Sim<'a> {
    config: &'a HadoopConfig,
    w: &'a WorkloadProfile,
    opts: &'a SimOptions,
    /// How attempts are priced: memoized cost tables (`Table`) or a
    /// fresh cost-model evaluation per launch (`Direct`).
    cost_mode: CostMode,
    /// Cost tables + attempt-0 noise prefix (used in `Table` mode only;
    /// handed back through `SimBuffers` so the next run can inherit it).
    warm: WarmCache,

    q: EventQueue<Event>,
    tracker: ResourceTracker,
    phases: PhaseBreakdown,
    counters: SimCounters,

    file: HdfsFile,
    namenode: Namenode,
    map_slots: Vec<Slot>,
    reduce_slots: Vec<Slot>,
    /// Per-node queues of pending tasks with a local replica (locality-first
    /// dispatch in O(1) amortized instead of an O(pending) scan — §Perf).
    node_pending: Vec<Vec<usize>>,
    /// Global FIFO of pending map tasks (fallback for remote dispatch).
    pending_maps: Vec<usize>,
    /// Next unscanned index into `pending_maps`.
    pending_cursor: usize,
    /// Task assignment flags (a task may sit in several queues).
    map_assigned: Vec<bool>,
    maps_launched: u64,
    pending_reduces: Vec<usize>,

    /// Scheduler state per map / reduce task.
    map_tasks: Vec<TaskState>,
    red_tasks: Vec<TaskState>,
    /// Registry of every attempt ever launched (slab arena, id = launch
    /// order).
    attempts: Arena<AttemptInfo>,
    node_dead: Vec<bool>,
    /// Scratch id list for crash/abort victim sweeps (no per-event Vec).
    scratch: Vec<usize>,
    /// InitialFill has fired (guards crash handlers scheduled before
    /// JOB_SETUP_S from launching the map wave early).
    job_started: bool,
    reduce_phase_started: bool,
    /// A SpeculativeLaunch event is already queued for [map, reduce].
    spec_scheduled: [bool; 2],
    /// A task exhausted `max.attempts` — the job is killed.
    aborted: bool,

    n_maps: u64,
    n_reduces: u64,
    total_shuffle_raw: f64,

    maps_completed: u64,
    reduces_completed: u64,
    maps_done_s: f64,
    slowstart_cross_s: Option<f64>,
    last_reduce_done_s: f64,
}

impl<'a> Sim<'a> {
    fn new(
        cluster: &'a ClusterSpec,
        config: &'a HadoopConfig,
        w: &'a WorkloadProfile,
        opts: &'a SimOptions,
        kind: QueueKind,
        cost_mode: CostMode,
        bufs: SimBuffers,
    ) -> Self {
        // Move the pooled buffers in, reset them, and refill — `run`
        // hands them back. Capacity survives; contents never do (except
        // the warm cost cache, whose reuse is physics-free by
        // construction), so a warmed pool and a fresh one are
        // indistinguishable to physics.
        let SimBuffers {
            mut q,
            mut node_pending,
            mut pending_maps,
            mut map_assigned,
            mut pending_reduces,
            mut map_tasks,
            mut red_tasks,
            mut attempts,
            mut node_dead,
            mut map_slots,
            mut reduce_slots,
            mut scratch,
            mut warm,
        } = bufs;
        q.reset(kind);
        attempts.clear();
        scratch.clear();

        let mut rng = Rng::seeded(opts.seed);
        let mut namenode = Namenode::new(cluster.workers(), config.dfs_replication as u32);

        // input layout: v2 honours the job.maps hint (only increases splits)
        let block = config.dfs_block_size.max(1 << 20);
        let natural_splits = w.input_bytes.div_ceil(block).max(1);
        let n_maps = match config.version {
            HadoopVersion::V1 => natural_splits,
            HadoopVersion::V2 => natural_splits.max(config.job_maps),
        };
        let split_bytes = w.input_bytes.div_ceil(n_maps).max(1);
        let file = namenode.create_file(&w.name, w.input_bytes, split_bytes, &mut rng);
        let n_maps = file.blocks.len() as u64;

        // total shuffle volume (pre-compression) is known analytically;
        // in Table mode the per-split raw bytes come from (and seed) the
        // warm cache's split classes — bit-identical, same values summed
        // in the same order
        let table = matches!(cost_mode, CostMode::Table);
        let total_shuffle_raw: f64 = if table {
            warm.begin_run(cluster, config, w, &opts.scenario);
            warm.assign_splits(config, w, file.blocks.iter().map(|b| b.size))
        } else {
            file.blocks
                .iter()
                .map(|b| map_output_for_split(config, w, b.size).raw_bytes)
                .sum()
        };

        // Interleave slots across nodes (slot k of every node, then slot
        // k+1, …) so partially-filled waves spread over the whole cluster —
        // matching how a real scheduler balances task placement.
        map_slots.clear();
        for s in 0..cluster.map_slots_per_node {
            for node in 0..cluster.workers() {
                let _ = s;
                map_slots.push(Slot { node, tasks_run: 0, busy: false, dead: false });
            }
        }
        reduce_slots.clear();
        for s in 0..cluster.reduce_slots_per_node {
            for node in 0..cluster.workers() {
                let _ = s;
                reduce_slots.push(Slot { node, tasks_run: 0, busy: false, dead: false });
            }
        }

        let n_reduces = config.reduce_tasks.max(1);

        // Attempt-0 noise prefix: every task's first attempt draws its
        // noise exactly once per run anyway, so prefilling is free on a
        // cold run — and a warm run with the same seed (scenario twins)
        // inherits the whole prefix instead of redrawing it. Noise is
        // keyed (seed, kind, task, attempt), so the factors are
        // independent of scenario and scheduling order.
        if table && opts.noise {
            let seed = opts.seed;
            warm.ensure_noise_prefix(seed, n_maps as usize, n_reduces as usize, |map, task| {
                let kind = if map { TaskKind::Map } else { TaskKind::Reduce };
                raw_noise_factor(seed, kind, task, 0)
            });
        }

        let mut counters = SimCounters::default();
        counters.n_maps = n_maps;
        counters.n_reduces = n_reduces;
        counters.map_waves = n_maps.div_ceil(cluster.total_map_slots() as u64);
        counters.reduce_waves = n_reduces.div_ceil(cluster.total_reduce_slots() as u64);

        // per-node locality queues (inner capacity survives reuse)
        for v in &mut node_pending {
            v.clear();
        }
        node_pending.resize_with(cluster.workers() as usize, Vec::new);
        for (t, block) in file.blocks.iter().enumerate() {
            for &r in &block.replicas {
                node_pending[r as usize].push(t);
            }
        }

        pending_maps.clear();
        pending_maps.extend(0..n_maps as usize);
        map_assigned.clear();
        map_assigned.resize(n_maps as usize, false);
        pending_reduces.clear();
        pending_reduces.extend(0..n_reduces as usize);
        map_tasks.clear();
        map_tasks.resize(n_maps as usize, TaskState::default());
        red_tasks.clear();
        red_tasks.resize(n_reduces as usize, TaskState::default());
        node_dead.clear();
        node_dead.resize(cluster.workers() as usize, false);

        Sim {
            config,
            w,
            opts,
            cost_mode,
            warm,
            q,
            tracker: ResourceTracker::new(cluster),
            phases: PhaseBreakdown::default(),
            counters,
            node_pending,
            pending_maps,
            pending_cursor: 0,
            map_assigned,
            maps_launched: 0,
            pending_reduces,
            map_tasks,
            red_tasks,
            attempts,
            node_dead,
            scratch,
            job_started: false,
            reduce_phase_started: false,
            spec_scheduled: [false; 2],
            aborted: false,
            file,
            namenode,
            map_slots,
            reduce_slots,
            n_maps,
            n_reduces,
            total_shuffle_raw,
            maps_completed: 0,
            reduces_completed: 0,
            maps_done_s: 0.0,
            slowstart_cross_s: None,
            last_reduce_done_s: 0.0,
        }
    }

    /// Per-attempt multiplicative duration noise, keyed by
    /// `(seed, kind, task, attempt)` so it is independent of scheduling
    /// order and identical between benign and scenario runs. Attempt-0
    /// factors are served from the warm prefix in `Table` mode — the
    /// prefix stores [`raw_noise_factor`]'s own output, so the fast path
    /// is bit-identical to redrawing.
    fn noise_factor_for(&mut self, kind: TaskKind, task: usize, attempt: u64) -> f64 {
        if !self.opts.noise {
            return 1.0;
        }
        if attempt == 0 && matches!(self.cost_mode, CostMode::Table) {
            if let Some((m, inherited)) = self.warm.noise0(matches!(kind, TaskKind::Map), task) {
                if inherited {
                    self.counters.warm_hits += 1;
                }
                return m;
            }
        }
        raw_noise_factor(self.opts.seed, kind, task, attempt)
    }

    /// Contention-adjusted resource rates on `node`, scaled by the
    /// scenario's per-node speed factor (heterogeneous fleets).
    fn rates_for(&self, node: u32) -> TaskRates {
        let speed = self.opts.scenario.speed_of(node);
        TaskRates {
            disk_bw: self.tracker.disk_bw(node) * speed,
            net_bw: self.tracker.net_bw(node) * speed,
            cpu_ops_per_sec: self.tracker.cpu_rate(node) * speed,
        }
    }

    /// Price one map attempt. In `Table` mode the cost is served from
    /// the memo keyed by node class × split class × locality × the
    /// post-acquire contention triple — every input `map_task_cost`
    /// reads is either in that key or pinned by the warm signature, so a
    /// hit is bit-identical to evaluating. Key overflow (pathological
    /// class counts / user counts) falls back to direct evaluation.
    fn map_cost(&mut self, node: u32, task: usize, split: u64, local: bool) -> MapTaskCost {
        if matches!(self.cost_mode, CostMode::Table) {
            let cpu = self.tracker.users(node, Resource::Cpu);
            let disk = self.tracker.users(node, Resource::Disk);
            let net = self.tracker.users(node, Resource::Net);
            if let Some(key) = self.warm.map_key(node, task, local, cpu, disk, net) {
                if let Some((cost, inherited)) = self.warm.lookup_map(key) {
                    if inherited {
                        self.counters.warm_hits += 1;
                    }
                    return cost;
                }
                self.counters.cost_evals += 1;
                let cost = map_task_cost(self.config, self.w, split, local, &self.rates_for(node));
                self.warm.insert_map(key, cost);
                return cost;
            }
        }
        self.counters.cost_evals += 1;
        map_task_cost(self.config, self.w, split, local, &self.rates_for(node))
    }

    /// Price one reduce attempt (see [`Sim::map_cost`]). The volume
    /// class (hot partition vs uniform rest) stands in for the exact
    /// volume in the key — the class↔volume mapping is a function of
    /// (config, workload) only, which the warm signature pins.
    fn reduce_cost(&mut self, node: u32, task: usize, vol: f64) -> ReduceTaskCost {
        if matches!(self.cost_mode, CostMode::Table) {
            let cpu = self.tracker.users(node, Resource::Cpu);
            let disk = self.tracker.users(node, Resource::Disk);
            let net = self.tracker.users(node, Resource::Net);
            let vol_class = if self.n_reduces > 1 && task > 0 { 1 } else { 0 };
            if let Some(key) = self.warm.red_key(node, vol_class, cpu, disk, net) {
                if let Some((cost, inherited)) = self.warm.lookup_red(key) {
                    if inherited {
                        self.counters.warm_hits += 1;
                    }
                    return cost;
                }
                self.counters.cost_evals += 1;
                let cost = reduce_task_cost(
                    self.config,
                    self.w,
                    vol as u64,
                    self.n_maps,
                    &self.rates_for(node),
                );
                self.warm.insert_red(key, cost);
                return cost;
            }
        }
        self.counters.cost_evals += 1;
        reduce_task_cost(self.config, self.w, vol as u64, self.n_maps, &self.rates_for(node))
    }

    fn setup_time(slot: &mut Slot, reuse: u64) -> f64 {
        let t = if slot.tasks_run % reuse.max(1) == 0 { JVM_START_S } else { TASK_LAUNCH_S };
        slot.tasks_run += 1;
        t
    }

    /// Per-reducer shuffle volume with the measured partition skew:
    /// reducer 0 is the hot partition.
    fn reduce_volume(&self, task: usize) -> f64 {
        let total = self.total_shuffle_raw;
        if self.n_reduces == 1 {
            return total;
        }
        let mean = total / self.n_reduces as f64;
        let hot = (self.w.partition_skew.max(1.0) * mean).min(total);
        if task == 0 {
            hot
        } else {
            (total - hot) / (self.n_reduces - 1) as f64
        }
    }

    /// Pick the next map task for a node: data-local if its queue has one,
    /// else the oldest unassigned task.
    fn next_map_task(&mut self, node: u32) -> Option<usize> {
        if self.maps_launched >= self.n_maps {
            return None;
        }
        // local queue first
        while let Some(t) = self.node_pending[node as usize].pop() {
            if !self.map_assigned[t] {
                self.map_assigned[t] = true;
                self.maps_launched += 1;
                return Some(t);
            }
        }
        // global FIFO fallback
        while self.pending_cursor < self.pending_maps.len() {
            let t = self.pending_maps[self.pending_cursor];
            self.pending_cursor += 1;
            if !self.map_assigned[t] {
                self.map_assigned[t] = true;
                self.maps_launched += 1;
                return Some(t);
            }
        }
        None
    }

    /// Launch one map attempt of `task` on `slot_idx` (original, retry or
    /// speculative copy).
    fn launch_map_on(&mut self, slot_idx: usize, task: usize, speculative: bool) {
        let node = self.map_slots[slot_idx].node;
        let local = self.namenode.is_local(&self.file.blocks[task], node);
        self.tracker.acquire(node, Resource::Cpu);
        self.tracker.acquire(node, Resource::Disk);
        if !local {
            self.tracker.acquire(node, Resource::Net);
        }
        let split = self.file.blocks[task].size;
        let cost = self.map_cost(node, task, split, local);
        let reuse = self.config.effective_jvm_reuse();
        let setup = Self::setup_time(&mut self.map_slots[slot_idx], reuse);
        let ord = self.map_tasks[task].attempts_launched;
        self.map_tasks[task].attempts_launched += 1;
        let m = self.noise_factor_for(TaskKind::Map, task, ord);

        let phases = PhaseBreakdown {
            task_setup: setup,
            map_read: cost.read_s * m,
            map_cpu: cost.map_cpu_s * m,
            map_spill: cost.spill_s * m,
            map_merge: cost.merge_s * m,
            ..Default::default()
        };
        let counters = AttemptCounters {
            data_local: local,
            spilled_files: cost.n_spills,
            spilled_records: cost.spilled_records,
            map_output_bytes: cost.output_bytes,
            ..Default::default()
        };

        let now = self.q.now();
        let work = cost.wall_s() * m;
        let fate =
            self.opts.scenario.attempt_fate(self.opts.seed, TaskKind::Map, task as u64, ord);
        let end = now + setup + work * fate.unwrap_or(1.0);
        let id = self.attempts.push(AttemptInfo {
            kind: TaskKind::Map,
            task,
            slot: slot_idx,
            node,
            alive: true,
            speculative,
            holds_net: !local,
            start_s: now,
            end_s: end,
            phases,
            counters,
        });
        self.map_slots[slot_idx].busy = true;
        self.map_tasks[task].running.push(id);
        if speculative {
            self.map_tasks[task].backups += 1;
            self.counters.speculative_launches += 1;
        }
        self.counters.map_attempts += 1;
        let ev = if fate.is_some() {
            Event::TaskFailed { attempt: id }
        } else {
            Event::TaskDone { attempt: id }
        };
        self.q.schedule(end, ev);
    }

    /// Launch one reduce attempt of `task` on `slot_idx`.
    fn launch_reduce_on(&mut self, slot_idx: usize, task: usize, speculative: bool) {
        let node = self.reduce_slots[slot_idx].node;
        // First-wave shuffle credit belongs only to a task's FIRST attempt
        // on a virgin slot — the one that really fetched during the map
        // phase. Retries and speculative copies launch later and must
        // re-fetch everything, even when they land on an unused slot.
        let first_wave = self.reduce_slots[slot_idx].tasks_run == 0
            && self.red_tasks[task].attempts_launched == 0
            && !speculative;
        self.tracker.acquire(node, Resource::Cpu);
        self.tracker.acquire(node, Resource::Disk);
        self.tracker.acquire(node, Resource::Net);
        let vol = self.reduce_volume(task);
        let cost = self.reduce_cost(node, task, vol);
        let reuse = self.config.effective_jvm_reuse();
        let setup = Self::setup_time(&mut self.reduce_slots[slot_idx], reuse);
        let ord = self.red_tasks[task].attempts_launched;
        self.red_tasks[task].attempts_launched += 1;
        let m = self.noise_factor_for(TaskKind::Reduce, task, ord);

        // Shuffle-overlap credit: a first-wave reducer has been fetching
        // since the slowstart point, at reduced efficiency (shared with map
        // traffic). At least the non-overlappable tail remains.
        let mut shuffle_s = cost.shuffle_s * m;
        if first_wave {
            if let Some(cross) = self.slowstart_cross_s {
                let window = (self.maps_done_s - cross).max(0.0) * FETCH_OVERLAP_EFF;
                shuffle_s = (shuffle_s - window).max(cost.shuffle_s * m * SHUFFLE_TAIL_FRACTION);
            }
        }
        let work = shuffle_s + (cost.merge_s + cost.reduce_cpu_s + cost.write_s) * m;

        let phases = PhaseBreakdown {
            task_setup: setup,
            shuffle: shuffle_s,
            reduce_merge: cost.merge_s * m,
            reduce_cpu: cost.reduce_cpu_s * m,
            output_write: cost.write_s * m,
            ..Default::default()
        };
        let shuffled = if self.config.compress_map_output {
            (vol * self.w.compress_ratio) as u64
        } else {
            vol as u64
        };
        let counters = AttemptCounters {
            shuffled_bytes: shuffled,
            reduce_spilled_bytes: cost.spilled_bytes,
            output_bytes: cost.output_bytes,
            ..Default::default()
        };

        let now = self.q.now();
        let fate =
            self.opts.scenario.attempt_fate(self.opts.seed, TaskKind::Reduce, task as u64, ord);
        let end = now + setup + work * fate.unwrap_or(1.0);
        let id = self.attempts.push(AttemptInfo {
            kind: TaskKind::Reduce,
            task,
            slot: slot_idx,
            node,
            alive: true,
            speculative,
            holds_net: true,
            start_s: now,
            end_s: end,
            phases,
            counters,
        });
        self.reduce_slots[slot_idx].busy = true;
        self.red_tasks[task].running.push(id);
        if speculative {
            self.red_tasks[task].backups += 1;
            self.counters.speculative_launches += 1;
        }
        self.counters.reduce_attempts += 1;
        let ev = if fate.is_some() {
            Event::TaskFailed { attempt: id }
        } else {
            Event::TaskDone { attempt: id }
        };
        self.q.schedule(end, ev);
    }

    /// Fill every idle live map slot with pending work; slots left idle ask
    /// for a speculative pass.
    fn fill_map_slots(&mut self) {
        if !self.job_started {
            return;
        }
        let mut want_spec = false;
        for i in 0..self.map_slots.len() {
            if self.map_slots[i].busy || self.map_slots[i].dead {
                continue;
            }
            let node = self.map_slots[i].node;
            match self.next_map_task(node) {
                Some(task) => self.launch_map_on(i, task, false),
                None => want_spec = true,
            }
        }
        if want_spec {
            self.maybe_schedule_speculation(TaskKind::Map);
        }
    }

    /// Fill every idle live reduce slot once the reduce phase has begun.
    fn fill_reduce_slots(&mut self) {
        if !self.reduce_phase_started {
            return;
        }
        let mut want_spec = false;
        for i in 0..self.reduce_slots.len() {
            if self.reduce_slots[i].busy || self.reduce_slots[i].dead {
                continue;
            }
            if self.pending_reduces.is_empty() {
                want_spec = true;
                break;
            }
            let task = self.pending_reduces.remove(0);
            self.launch_reduce_on(i, task, false);
        }
        if want_spec {
            self.maybe_schedule_speculation(TaskKind::Reduce);
        }
    }

    /// The straggler most worth backing up: the running original with the
    /// latest expected finish, no backup yet, and enough remaining time.
    fn spec_candidate(&self, kind: TaskKind, now: f64) -> Option<(usize, usize)> {
        let tasks = match kind {
            TaskKind::Map => &self.map_tasks,
            TaskKind::Reduce => &self.red_tasks,
        };
        let mut best: Option<(usize, usize, f64)> = None;
        for (t, ts) in tasks.iter().enumerate() {
            if ts.completed || ts.backups > 0 || ts.running.len() != 1 {
                continue;
            }
            let id = ts.running.as_slice()[0];
            let a = &self.attempts[id];
            if a.speculative || a.end_s - now < SPECULATIVE_MIN_REMAINING_S {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, _, e)) => a.end_s > e,
            };
            if better {
                best = Some((t, id, a.end_s));
            }
        }
        best.map(|(t, id, _)| (t, id))
    }

    /// An idle live slot of the kind, preferring a different node than the
    /// original attempt runs on.
    fn pick_idle_slot(&self, kind: TaskKind, avoid_node: Option<u32>) -> Option<usize> {
        let slots = match kind {
            TaskKind::Map => &self.map_slots,
            TaskKind::Reduce => &self.reduce_slots,
        };
        if let Some(avoid) = avoid_node {
            if let Some(i) = slots.iter().position(|s| !s.busy && !s.dead && s.node != avoid) {
                return Some(i);
            }
        }
        slots.iter().position(|s| !s.busy && !s.dead)
    }

    /// Schedule a speculative pass after the JobTracker's lag, if
    /// speculation is on, none is queued yet, and a candidate exists.
    fn maybe_schedule_speculation(&mut self, kind: TaskKind) {
        if !self.opts.scenario.speculative(kind) {
            return;
        }
        let ki = kind_index(kind);
        if self.spec_scheduled[ki] {
            return;
        }
        let fire_at = self.q.now() + SPECULATIVE_DELAY_S;
        if self.spec_candidate(kind, fire_at).is_none() {
            return;
        }
        self.spec_scheduled[ki] = true;
        self.q.schedule_in(SPECULATIVE_DELAY_S, Event::SpeculativeLaunch { kind });
    }

    /// Launch backup copies of the slowest running originals onto idle
    /// slots until either runs out.
    fn run_speculation(&mut self, kind: TaskKind, now: f64) {
        if !self.opts.scenario.speculative(kind) {
            return;
        }
        loop {
            let Some((task, orig)) = self.spec_candidate(kind, now) else {
                return;
            };
            let orig_node = self.attempts[orig].node;
            let Some(slot) = self.pick_idle_slot(kind, Some(orig_node)) else {
                return;
            };
            match kind {
                TaskKind::Map => self.launch_map_on(slot, task, true),
                TaskKind::Reduce => self.launch_reduce_on(slot, task, true),
            }
        }
    }

    /// Shared teardown of every attempt-termination path (success, failure,
    /// kill): mark the attempt dead, give back its tracker resources and
    /// free its slot. Returns the [`Retired`] summary — the handful of
    /// fields the callers' path-specific accounting reads — while the full
    /// record stays put in the arena, borrowed, never cloned. Callers must
    /// check `alive` first.
    fn retire_attempt(&mut self, id: usize) -> Retired {
        debug_assert!(self.attempts[id].alive, "retiring a dead attempt");
        let a = &mut self.attempts[id];
        a.alive = false;
        let (node, holds_net) = (a.node, a.holds_net);
        let r = Retired {
            kind: a.kind,
            task: a.task,
            slot: a.slot,
            speculative: a.speculative,
            start_s: a.start_s,
        };
        self.tracker.release(node, Resource::Cpu);
        self.tracker.release(node, Resource::Disk);
        if holds_net {
            self.tracker.release(node, Resource::Net);
        }
        match r.kind {
            TaskKind::Map => self.map_slots[r.slot].busy = false,
            TaskKind::Reduce => self.reduce_slots[r.slot].busy = false,
        }
        r
    }

    /// Kill a live attempt (losing speculation copy or node-loss victim):
    /// elapsed work is wasted and the attempt's future Done/Failed event
    /// becomes a no-op.
    fn kill_attempt(&mut self, id: usize, t: f64) {
        if !self.attempts[id].alive {
            return;
        }
        let a = self.retire_attempt(id);
        self.phases.wasted += (t - a.start_s).max(0.0);
        self.counters.killed_attempts += 1;
    }

    fn on_task_done(&mut self, attempt: usize, t: f64) {
        if !self.attempts[attempt].alive {
            return; // orphaned event of a killed attempt
        }
        let a = self.retire_attempt(attempt);
        // The first finisher commits; racing copies are killed on the spot.
        let siblings = match a.kind {
            TaskKind::Map => std::mem::take(&mut self.map_tasks[a.task].running),
            TaskKind::Reduce => std::mem::take(&mut self.red_tasks[a.task].running),
        };
        for &sib in siblings.as_slice() {
            if sib != attempt {
                self.kill_attempt(sib, t);
            }
        }
        match a.kind {
            TaskKind::Map => self.map_tasks[a.task].completed = true,
            TaskKind::Reduce => self.red_tasks[a.task].completed = true,
        }
        if a.speculative {
            self.counters.speculative_wins += 1;
        }
        // Commit the successful attempt's work straight from the arena:
        // `phases`/`counters` and `attempts` are disjoint fields, so the
        // record is borrowed in place (no `Clone` on `AttemptInfo`).
        self.phases.add(&self.attempts[attempt].phases);
        let c = self.attempts[attempt].counters;
        match a.kind {
            TaskKind::Map => {
                self.counters.data_local_maps += c.data_local as u64;
                self.counters.spilled_files += c.spilled_files;
                self.counters.spilled_records += c.spilled_records;
                self.counters.map_output_bytes += c.map_output_bytes;
                self.counters.map_successes += 1;
                self.maps_completed += 1;
                self.maps_done_s = t;
                let slowstart = self.config.effective_slowstart();
                if self.slowstart_cross_s.is_none()
                    && self.maps_completed as f64 / self.n_maps as f64 >= slowstart
                {
                    self.slowstart_cross_s = Some(t);
                }
                self.fill_map_slots();
                if self.maps_completed == self.n_maps {
                    if self.slowstart_cross_s.is_none() {
                        self.slowstart_cross_s = Some(t);
                    }
                    // launch the first reduce wave
                    self.reduce_phase_started = true;
                    self.fill_reduce_slots();
                }
            }
            TaskKind::Reduce => {
                self.counters.shuffled_bytes += c.shuffled_bytes;
                self.counters.reduce_spilled_bytes += c.reduce_spilled_bytes;
                self.counters.output_bytes += c.output_bytes;
                self.counters.reduce_successes += 1;
                self.reduces_completed += 1;
                self.last_reduce_done_s = t;
                self.fill_reduce_slots();
            }
        }
    }

    fn on_task_failed(&mut self, attempt: usize, t: f64) {
        if !self.attempts[attempt].alive {
            return; // killed before the failure fired
        }
        let a = self.retire_attempt(attempt);
        self.phases.wasted += (t - a.start_s).max(0.0);
        let (failures, orphaned) = {
            let ts = match a.kind {
                TaskKind::Map => &mut self.map_tasks[a.task],
                TaskKind::Reduce => &mut self.red_tasks[a.task],
            };
            ts.running.remove(attempt);
            ts.failed_attempts += 1;
            (ts.failed_attempts, !ts.completed && ts.running.is_empty())
        };
        match a.kind {
            TaskKind::Map => self.counters.map_failures += 1,
            TaskKind::Reduce => self.counters.reduce_failures += 1,
        }
        self.counters.max_task_failures = self.counters.max_task_failures.max(failures);
        if failures >= self.opts.scenario.max_attempts {
            // Hadoop kills the job once one task exhausts its attempts.
            self.aborted = true;
            return;
        }
        if orphaned {
            // Retry on the slot that just freed: deterministic, and the
            // extra work lands on the same chain the healthy run used.
            match a.kind {
                TaskKind::Map => self.launch_map_on(a.slot, a.task, false),
                TaskKind::Reduce => self.launch_reduce_on(a.slot, a.task, false),
            }
        } else {
            // A live copy keeps running; reuse the freed slot elsewhere.
            match a.kind {
                TaskKind::Map => self.fill_map_slots(),
                TaskKind::Reduce => self.fill_reduce_slots(),
            }
        }
    }

    fn on_node_down(&mut self, crash: usize, t: f64) {
        let node = self.opts.scenario.node_crashes[crash].node;
        if (node as usize) >= self.node_dead.len() || self.node_dead[node as usize] {
            return;
        }
        self.node_dead[node as usize] = true;
        self.counters.nodes_lost += 1;
        for s in self.map_slots.iter_mut().chain(self.reduce_slots.iter_mut()) {
            if s.node == node {
                s.dead = true;
            }
        }
        let mut victims = std::mem::take(&mut self.scratch);
        victims.clear();
        victims.extend(
            (0..self.attempts.len())
                .filter(|&i| self.attempts[i].alive && self.attempts[i].node == node),
        );
        for &id in &victims {
            self.kill_attempt(id, t);
            let (kind, task) = (self.attempts[id].kind, self.attempts[id].task);
            let orphaned = {
                let ts = match kind {
                    TaskKind::Map => &mut self.map_tasks[task],
                    TaskKind::Reduce => &mut self.red_tasks[task],
                };
                ts.running.remove(id);
                !ts.completed && ts.running.is_empty()
            };
            if orphaned {
                match kind {
                    TaskKind::Map => {
                        // Re-queue the lost split, locality-first on the
                        // surviving replica holders. `file` and
                        // `node_pending` are disjoint fields, so the
                        // replica list is walked in place, not cloned.
                        self.map_assigned[task] = false;
                        self.maps_launched = self.maps_launched.saturating_sub(1);
                        let (file, node_pending, node_dead) =
                            (&self.file, &mut self.node_pending, &self.node_dead);
                        for &r in &file.blocks[task].replicas {
                            if !node_dead[r as usize] {
                                node_pending[r as usize].push(task);
                            }
                        }
                        self.pending_maps.push(task);
                    }
                    TaskKind::Reduce => self.pending_reduces.push(task),
                }
            }
        }
        self.scratch = victims;
        self.fill_map_slots();
        self.fill_reduce_slots();
    }

    fn run(mut self) -> (JobRunResult, SimBuffers) {
        let crash_schedule: Vec<(usize, f64)> = self
            .opts
            .scenario
            .node_crashes
            .iter()
            .enumerate()
            .filter(|(_, c)| (c.node as usize) < self.node_dead.len())
            .map(|(i, c)| (i, c.at_s))
            .collect();
        for (i, at) in crash_schedule {
            self.q.schedule(at, Event::NodeDown { crash: i });
        }
        self.q.schedule(JOB_SETUP_S, Event::InitialFill);

        while let Some((t, ev)) = self.q.pop() {
            self.counters.events += 1;
            match ev {
                Event::InitialFill => {
                    self.job_started = true;
                    self.fill_map_slots();
                    // degenerate: a job with zero map output still runs
                    if self.n_maps == 0 {
                        self.maps_done_s = t;
                        self.reduce_phase_started = true;
                        self.fill_reduce_slots();
                    }
                }
                Event::TaskDone { attempt } => self.on_task_done(attempt, t),
                Event::TaskFailed { attempt } => self.on_task_failed(attempt, t),
                Event::NodeDown { crash } => self.on_node_down(crash, t),
                Event::SpeculativeLaunch { kind } => {
                    self.spec_scheduled[kind_index(kind)] = false;
                    self.run_speculation(kind, t);
                }
            }
            if self.aborted {
                break;
            }
        }

        if self.aborted {
            // The job kill terminates every in-flight attempt; charge their
            // partial work as waste exactly like any other kill, so the
            // failed run's phase breakdown stays consistent.
            let now = self.q.now();
            let mut live = std::mem::take(&mut self.scratch);
            live.clear();
            live.extend((0..self.attempts.len()).filter(|&i| self.attempts[i].alive));
            for &id in &live {
                self.kill_attempt(id, now);
            }
            self.scratch = live;
        }

        let complete =
            self.maps_completed == self.n_maps && self.reduces_completed == self.n_reduces;
        let job_failed = self.aborted || !complete;
        let end = if complete {
            self.last_reduce_done_s.max(self.maps_done_s)
        } else {
            self.q.now().max(self.maps_done_s)
        };
        let result = JobRunResult {
            exec_time_s: end + JOB_CLEANUP_S,
            phases: self.phases,
            counters: self.counters,
            maps_done_s: self.maps_done_s,
            job_failed,
        };
        let bufs = SimBuffers {
            q: self.q,
            node_pending: self.node_pending,
            pending_maps: self.pending_maps,
            map_assigned: self.map_assigned,
            pending_reduces: self.pending_reduces,
            map_tasks: self.map_tasks,
            red_tasks: self.red_tasks,
            attempts: self.attempts,
            node_dead: self.node_dead,
            map_slots: self.map_slots,
            reduce_slots: self.reduce_slots,
            scratch: self.scratch,
            warm: self.warm,
        };
        (result, bufs)
    }
}

/// Draw the `(seed, kind, task, attempt)`-keyed noise multiplier
/// (lognormal × occasional straggler). The warm cache's attempt-0
/// prefix stores exactly these values — any fallback to this function
/// is therefore bit-identical to a prefix hit.
fn raw_noise_factor(seed: u64, kind: TaskKind, task: usize, attempt: u64) -> f64 {
    let mut rng = scenario::attempt_rng(seed, scenario::NOISE_SALT, kind, task as u64, attempt);
    let mut m = rng.lognormal_unit_mean(TASK_NOISE_SIGMA);
    if rng.bernoulli(STRAGGLER_P) {
        m *= STRAGGLER_FACTOR;
    }
    m
}

/// Simulate one job execution; returns wall-clock time and full trace.
pub fn simulate(
    cluster: &ClusterSpec,
    config: &HadoopConfig,
    w: &WorkloadProfile,
    opts: &SimOptions,
) -> JobRunResult {
    let mut bufs = SimBuffers::new();
    simulate_with_buffers(cluster, config, w, opts, &mut bufs)
}

/// [`simulate`] reusing the caller's buffer pool: run N+1 inherits run
/// N's capacity. Results are bit-identical to fresh buffers — pooling is
/// an allocation optimization, never a physics input.
pub fn simulate_with_buffers(
    cluster: &ClusterSpec,
    config: &HadoopConfig,
    w: &WorkloadProfile,
    opts: &SimOptions,
    bufs: &mut SimBuffers,
) -> JobRunResult {
    run_with(cluster, config, w, opts, QueueKind::default_kind(), CostMode::default_mode(), bufs)
}

/// [`simulate`] on an explicitly chosen event-queue implementation — the
/// hook the golden-trace equality tests use to prove the calendar queue
/// and the legacy heap produce bit-identical physics.
pub fn simulate_with_queue(
    cluster: &ClusterSpec,
    config: &HadoopConfig,
    w: &WorkloadProfile,
    opts: &SimOptions,
    kind: QueueKind,
) -> JobRunResult {
    let mut bufs = SimBuffers::new();
    run_with(cluster, config, w, opts, kind, CostMode::default_mode(), &mut bufs)
}

/// [`simulate`] on an explicitly chosen costing mode, reusing the
/// caller's buffer pool — the hook the equality tests use to prove the
/// memoized cost tables (cold and warm) and the direct per-launch
/// costing fallback produce bit-identical physics. A fresh pool makes
/// `Table` mode cold; reusing one across runs exercises the warm cache.
pub fn simulate_with_cost_mode(
    cluster: &ClusterSpec,
    config: &HadoopConfig,
    w: &WorkloadProfile,
    opts: &SimOptions,
    mode: CostMode,
    bufs: &mut SimBuffers,
) -> JobRunResult {
    run_with(cluster, config, w, opts, QueueKind::default_kind(), mode, bufs)
}

fn run_with(
    cluster: &ClusterSpec,
    config: &HadoopConfig,
    w: &WorkloadProfile,
    opts: &SimOptions,
    kind: QueueKind,
    mode: CostMode,
    bufs: &mut SimBuffers,
) -> JobRunResult {
    let taken = std::mem::take(bufs);
    let (result, returned) = Sim::new(cluster, config, w, opts, kind, mode, taken).run();
    *bufs = returned;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParameterSpace;

    fn workload() -> WorkloadProfile {
        WorkloadProfile {
            name: "tera-test".into(),
            input_bytes: 4 << 30,
            avg_input_record_bytes: 100.0,
            map_selectivity_bytes: 1.0,
            map_selectivity_records: 1.0,
            avg_map_record_bytes: 100.0,
            combiner_reduction: 1.0,
            has_combiner: false,
            reduce_selectivity_bytes: 1.0,
            partition_skew: 1.1,
            compress_ratio: 0.4,
            map_cpu_ops_per_record: 60.0,
            reduce_cpu_ops_per_record: 50.0,
        }
    }

    fn o(seed: u64, noise: bool) -> SimOptions {
        SimOptions { seed, noise, ..Default::default() }
    }

    #[test]
    fn runs_to_completion() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let r = simulate(&cluster, &cfg, &workload(), &SimOptions::default());
        assert!(r.exec_time_s.is_finite());
        assert!(r.exec_time_s > JOB_SETUP_S);
        assert_eq!(r.counters.n_maps, 32); // 4 GB / 128 MB
        assert_eq!(r.counters.n_reduces, 1);
        assert!(!r.job_failed);
        // benign runs register one attempt per task, nothing scenario-ish
        assert_eq!(r.counters.map_attempts, 32);
        assert_eq!(r.counters.map_successes, 32);
        assert_eq!(r.counters.reduce_successes, 1);
        assert_eq!(r.counters.map_failures + r.counters.killed_attempts, 0);
        assert_eq!(r.phases.wasted, 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let a = simulate(&cluster, &cfg, &workload(), &o(7, true));
        let b = simulate(&cluster, &cfg, &workload(), &o(7, true));
        assert_eq!(a.exec_time_s, b.exec_time_s);
        assert_eq!(a.counters, b.counters);
    }

    #[test]
    fn noise_changes_between_seeds() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let a = simulate(&cluster, &cfg, &workload(), &o(1, true));
        let b = simulate(&cluster, &cfg, &workload(), &o(2, true));
        assert_ne!(a.exec_time_s, b.exec_time_s);
        let ratio = a.exec_time_s / b.exec_time_s;
        assert!(ratio > 0.5 && ratio < 2.0, "ratio {ratio}");
    }

    #[test]
    fn more_reducers_help_shuffle_heavy_job() {
        let cluster = ClusterSpec::paper_cluster();
        let mut cfg = ParameterSpace::v1().default_config();
        let opts = o(3, false);
        let single = simulate(&cluster, &cfg, &workload(), &opts);
        cfg.reduce_tasks = 48;
        let many = simulate(&cluster, &cfg, &workload(), &opts);
        assert!(
            many.exec_time_s < single.exec_time_s * 0.6,
            "48 reducers {} vs 1 reducer {}",
            many.exec_time_s,
            single.exec_time_s
        );
    }

    #[test]
    fn maps_finish_before_job_ends() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let r = simulate(&cluster, &cfg, &workload(), &o(5, false));
        assert!(r.maps_done_s < r.exec_time_s);
        assert!(r.counters.data_local_maps > r.counters.n_maps / 2);
    }

    #[test]
    fn wave_counts() {
        let cluster = ClusterSpec::paper_cluster();
        let mut cfg = ParameterSpace::v1().default_config();
        cfg.reduce_tasks = 100;
        let r = simulate(&cluster, &cfg, &workload(), &o(5, false));
        assert_eq!(r.counters.map_waves, 1); // 32 maps on 72 slots
        assert_eq!(r.counters.reduce_waves, 3); // 100 on 48 slots
    }

    #[test]
    fn v2_job_maps_hint_increases_splits() {
        let cluster = ClusterSpec::paper_cluster();
        let mut cfg = ParameterSpace::v2().default_config();
        let mut small = workload();
        small.input_bytes = 256 << 20; // 2 natural splits
        cfg.job_maps = 16;
        let r = simulate(&cluster, &cfg, &small, &o(1, false));
        assert_eq!(r.counters.n_maps, 16);
    }

    #[test]
    fn v2_jvm_reuse_cuts_setup_time() {
        let cluster = ClusterSpec::paper_cluster();
        let mut cfg = ParameterSpace::v2().default_config();
        let mut wl = workload();
        wl.input_bytes = 40 << 30; // many waves
        let opts = o(2, false);
        let fresh = simulate(&cluster, &cfg, &wl, &opts);
        cfg.jvm_numtasks = 20;
        let reused = simulate(&cluster, &cfg, &wl, &opts);
        assert!(reused.phases.task_setup < fresh.phases.task_setup);
    }

    #[test]
    fn early_slowstart_overlaps_shuffle() {
        let cluster = ClusterSpec::paper_cluster();
        let mut cfg = ParameterSpace::v2().default_config();
        cfg.reduce_tasks = 24;
        let mut wl = workload();
        wl.input_bytes = 20 << 30;
        let opts = o(4, false);
        cfg.slowstart = 0.05;
        let early = simulate(&cluster, &cfg, &wl, &opts);
        cfg.slowstart = 1.0;
        let late = simulate(&cluster, &cfg, &wl, &opts);
        assert!(
            early.exec_time_s < late.exec_time_s,
            "early {} late {}",
            early.exec_time_s,
            late.exec_time_s
        );
    }

    #[test]
    fn tuned_config_beats_default_substantially() {
        // The headline mechanism: a hand-tuned configuration must
        // dramatically beat Table-1 defaults on a terasort-like job,
        // otherwise the optimization landscape is too flat for the paper's
        // 60 %+ gains to be reproducible.
        let cluster = ClusterSpec::paper_cluster();
        let space = ParameterSpace::v1();
        let default = space.default_config();
        let mut tuned = default.clone();
        tuned.io_sort_mb = 400;
        tuned.spill_percent = 0.6;
        tuned.sort_record_percent = 0.15;
        tuned.sort_factor = 64;
        tuned.reduce_tasks = 90;
        tuned.shuffle_input_buffer_percent = 0.8;
        tuned.compress_map_output = true;
        let mut wl = workload();
        wl.input_bytes = 30 << 30; // the paper's terasort partial workload
        let opts = o(11, false);
        let d = simulate(&cluster, &default, &wl, &opts);
        let t = simulate(&cluster, &tuned, &wl, &opts);
        let gain = 1.0 - t.exec_time_s / d.exec_time_s;
        assert!(
            gain > 0.4,
            "gain only {:.1}% ({} -> {})",
            gain * 100.0,
            d.exec_time_s,
            t.exec_time_s
        );
    }

    // -- scenario engine ---------------------------------------------------

    #[test]
    fn failure_injection_retries_every_split_to_success() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        // max_attempts far above what p=0.2 can exhaust: P(one task fails
        // 10 times) = 0.2^10 ≈ 1e-7, so the job always completes.
        let scenario = ScenarioSpec::default().with_failures(0.2).with_max_attempts(10);
        let mut total_failures = 0;
        for seed in 1..=8 {
            let opts = SimOptions { seed, noise: true, scenario: scenario.clone() };
            let r = simulate(&cluster, &cfg, &workload(), &opts);
            assert!(!r.job_failed, "seed {seed} failed the job");
            assert_eq!(r.counters.map_successes, r.counters.n_maps);
            assert_eq!(r.counters.reduce_successes, r.counters.n_reduces);
            assert!(r.counters.map_attempts >= r.counters.n_maps);
            assert!(r.counters.max_task_failures < 10);
            total_failures += r.counters.map_failures + r.counters.reduce_failures;
            if r.counters.map_failures + r.counters.reduce_failures > 0 {
                assert!(r.phases.wasted > 0.0, "failed attempts must waste work");
            }
        }
        // 8 seeds × 33 attempts × p=0.2: zero failures overall is impossible
        assert!(total_failures > 0, "no failures injected across 8 seeds");
    }

    #[test]
    fn failure_counters_conserve_data_flow() {
        // Byte/record counters come from successful attempts only, so a
        // faulty run moves exactly the data of its benign twin.
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let benign = simulate(&cluster, &cfg, &workload(), &o(17, true));
        let scenario = ScenarioSpec::default().with_failures(0.25).with_max_attempts(12);
        let faulty = simulate(
            &cluster,
            &cfg,
            &workload(),
            &SimOptions { seed: 17, noise: true, scenario },
        );
        assert!(!faulty.job_failed);
        let (b, f) = (&benign.counters, &faulty.counters);
        assert_eq!(b.map_output_bytes, f.map_output_bytes);
        assert_eq!(b.shuffled_bytes, f.shuffled_bytes);
        assert_eq!(b.output_bytes, f.output_bytes);
        assert_eq!(b.spilled_records, f.spilled_records);
        assert_eq!(b.spilled_files, f.spilled_files);
        assert_eq!(b.reduce_spilled_bytes, f.reduce_spilled_bytes);
    }

    #[test]
    fn job_fails_when_attempts_exhausted() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let scenario = ScenarioSpec::default().with_failures(1.0).with_max_attempts(2);
        let r = simulate(
            &cluster,
            &cfg,
            &workload(),
            &SimOptions { seed: 1, noise: true, scenario },
        );
        assert!(r.job_failed);
        assert_eq!(r.counters.max_task_failures, 2);
        assert_eq!(r.counters.map_successes, 0);
        assert!(r.exec_time_s.is_finite() && r.exec_time_s > 0.0);
        // the job kill terminates the other in-flight attempts and charges
        // their partial work as waste (32 maps were running at abort time)
        assert!(r.counters.killed_attempts > 0, "abort left live attempts unaccounted");
        assert!(r.phases.wasted > 0.0);
    }

    #[test]
    fn node_crash_requeues_lost_splits() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let benign = simulate(&cluster, &cfg, &workload(), &o(9, false));
        // crash one node mid-map-phase: its running work re-queues and the
        // job still processes every split exactly once
        let scenario = ScenarioSpec::default().with_crash(JOB_SETUP_S + 10.0, 3);
        let r = simulate(
            &cluster,
            &cfg,
            &workload(),
            &SimOptions { seed: 9, noise: false, scenario },
        );
        assert!(!r.job_failed);
        assert_eq!(r.counters.nodes_lost, 1);
        assert_eq!(r.counters.map_successes, r.counters.n_maps);
        assert_eq!(r.counters.reduce_successes, r.counters.n_reduces);
        // losing capacity + re-running work cannot beat the healthy cluster
        // by more than scheduling-anomaly jitter
        assert!(
            r.exec_time_s > benign.exec_time_s * 0.95,
            "crash run {} vs benign {}",
            r.exec_time_s,
            benign.exec_time_s
        );
    }

    #[test]
    fn crash_before_job_setup_does_not_start_the_job_early() {
        // A NodeDown event popped before InitialFill must not launch the
        // map wave at crash time: with a tiny single-split job whose task
        // never touches the crashed node, the run is identical to benign.
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let mut tiny = workload();
        tiny.input_bytes = 1 << 20; // one split, well under one block
        let benign = simulate(&cluster, &cfg, &tiny, &o(6, false));
        let scenario = ScenarioSpec::default().with_crash(0.5, 3);
        let crashed = simulate(
            &cluster,
            &cfg,
            &tiny,
            &SimOptions { seed: 6, noise: false, scenario },
        );
        assert!(!crashed.job_failed);
        assert_eq!(crashed.counters.nodes_lost, 1);
        assert_eq!(
            crashed.exec_time_s, benign.exec_time_s,
            "crash before job setup changed the schedule"
        );
    }

    #[test]
    fn losing_every_node_fails_the_job() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let mut scenario = ScenarioSpec::default();
        for node in 0..cluster.workers() {
            scenario = scenario.with_crash(JOB_SETUP_S + 5.0, node);
        }
        let r = simulate(
            &cluster,
            &cfg,
            &workload(),
            &SimOptions { seed: 2, noise: false, scenario },
        );
        assert!(r.job_failed);
        assert_eq!(r.counters.nodes_lost as u32, cluster.workers());
        assert!(r.exec_time_s.is_finite());
    }

    #[test]
    fn slow_nodes_stretch_the_makespan() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let benign = simulate(&cluster, &cfg, &workload(), &o(4, false));
        let scenario = ScenarioSpec::default()
            .with_slow_node(0, 0.5)
            .with_slow_node(1, 0.5)
            .with_slow_node(2, 0.5)
            .with_slow_node(3, 0.5)
            .with_slow_node(4, 0.5);
        let slow = simulate(
            &cluster,
            &cfg,
            &workload(),
            &SimOptions { seed: 4, noise: false, scenario },
        );
        assert!(
            slow.exec_time_s > benign.exec_time_s * 1.02,
            "slow fleet {} vs homogeneous {}",
            slow.exec_time_s,
            benign.exec_time_s
        );
    }

    #[test]
    fn cluster_node_overrides_slow_the_job() {
        // Heterogeneity through ClusterSpec hardware overrides (not just
        // scenario speed factors): slower disks/CPU on five workers.
        use crate::cluster::NodeSpec;
        let cfg = ParameterSpace::v1().default_config();
        let homo = ClusterSpec::paper_cluster();
        let benign = simulate(&homo, &cfg, &workload(), &o(6, false));
        let old_gen = NodeSpec {
            cpu_ops_per_sec: 1.0e8,
            disk_bw: 60.0e6,
            ..NodeSpec::default()
        };
        let mut hetero = ClusterSpec::paper_cluster();
        for node in 0..5 {
            hetero = hetero.with_node_override(node, old_gen.clone());
        }
        let slow = simulate(&hetero, &cfg, &workload(), &o(6, false));
        assert!(
            slow.exec_time_s > benign.exec_time_s * 1.02,
            "hetero {} vs homo {}",
            slow.exec_time_s,
            benign.exec_time_s
        );
    }

    #[test]
    fn speculation_rescues_straggler_nodes() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        // Nodes 0 and 1 run at quarter speed. The first slot-fill row places
        // one map on every node, so the stragglers always carry work.
        let hetero = ScenarioSpec::default().with_slow_node(0, 0.25).with_slow_node(1, 0.25);
        let no_spec = simulate(
            &cluster,
            &cfg,
            &workload(),
            &SimOptions { seed: 8, noise: false, scenario: hetero.clone() },
        );
        let with_spec = simulate(
            &cluster,
            &cfg,
            &workload(),
            &SimOptions { seed: 8, noise: false, scenario: hetero.with_speculation(true) },
        );
        assert!(!with_spec.job_failed);
        assert!(with_spec.counters.speculative_launches > 0, "no backups launched");
        assert!(with_spec.counters.speculative_wins > 0, "no backup won its race");
        // every win kills the losing original
        assert!(with_spec.counters.killed_attempts >= with_spec.counters.speculative_wins);
        assert_eq!(with_spec.counters.map_successes, with_spec.counters.n_maps);
        assert!(
            with_spec.exec_time_s < no_spec.exec_time_s * 0.9,
            "speculation {} vs none {}",
            with_spec.exec_time_s,
            no_spec.exec_time_s
        );
        assert!(with_spec.phases.wasted > 0.0, "killed copies must show as waste");
    }

    #[test]
    fn scenario_runs_are_deterministic() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let scenario = ScenarioSpec::default()
            .with_failures(0.15)
            .with_max_attempts(10)
            .with_crash(60.0, 2)
            .with_slow_node(5, 0.5)
            .with_speculation(true);
        let opts = SimOptions { seed: 23, noise: true, scenario };
        let a = simulate(&cluster, &cfg, &workload(), &opts);
        let b = simulate(&cluster, &cfg, &workload(), &opts);
        assert_eq!(a.exec_time_s, b.exec_time_s);
        assert_eq!(a.counters, b.counters);
        assert_eq!(a.phases, b.phases);
        assert_eq!(a.job_failed, b.job_failed);
    }

    // -- fast path: arena, buffer reuse, queue equivalence -----------------

    fn busy_scenario() -> ScenarioSpec {
        ScenarioSpec::default()
            .with_failures(0.15)
            .with_max_attempts(10)
            .with_crash(60.0, 2)
            .with_slow_node(5, 0.5)
            .with_speculation(true)
    }

    #[test]
    fn event_handling_does_not_require_clone_on_attempts() {
        // `AttemptInfo` deliberately has no `Clone` impl (stable Rust
        // cannot state a negative bound, so compiling this file *is* the
        // proof — see also `arena::tests::arena_works_without_clone`).
        // Runtime leg: a scenario run that exercises every termination
        // path (done / failed / killed / crash victims / speculative
        // races) over a *reused* arena matches the fresh-arena run bit
        // for bit.
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let opts = SimOptions { seed: 23, noise: true, scenario: busy_scenario() };
        let fresh = simulate(&cluster, &cfg, &workload(), &opts);
        assert!(fresh.counters.killed_attempts > 0 || fresh.counters.map_failures > 0);
        let mut bufs = SimBuffers::new();
        let first = simulate_with_buffers(&cluster, &cfg, &workload(), &opts, &mut bufs);
        let reused = simulate_with_buffers(&cluster, &cfg, &workload(), &opts, &mut bufs);
        for r in [&first, &reused] {
            assert_eq!(r.exec_time_s, fresh.exec_time_s);
            assert_eq!(r.counters, fresh.counters);
            assert_eq!(r.phases, fresh.phases);
        }
    }

    #[test]
    fn buffer_reuse_is_independent_of_the_previous_run() {
        // A fail-heavy job leaves the pool full of dead slots, retry
        // counters and a populated arena; the benign job that follows in
        // the same pool must match its standalone fresh-buffer twin.
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let faulty_opts =
            SimOptions { seed: 40, noise: true, scenario: busy_scenario() };
        let benign_opts = o(41, true);
        let mut bufs = SimBuffers::new();
        let faulty = simulate_with_buffers(&cluster, &cfg, &workload(), &faulty_opts, &mut bufs);
        let benign = simulate_with_buffers(&cluster, &cfg, &workload(), &benign_opts, &mut bufs);
        let solo_faulty = simulate(&cluster, &cfg, &workload(), &faulty_opts);
        let solo_benign = simulate(&cluster, &cfg, &workload(), &benign_opts);
        assert_eq!(faulty.exec_time_s, solo_faulty.exec_time_s);
        assert_eq!(faulty.counters, solo_faulty.counters);
        assert_eq!(benign.exec_time_s, solo_benign.exec_time_s);
        assert_eq!(benign.counters, solo_benign.counters);
        assert_eq!(benign.phases, solo_benign.phases);
        // the benign run really saw none of the faulty run's state
        assert_eq!(benign.counters.killed_attempts + benign.counters.map_failures, 0);
    }

    #[test]
    fn calendar_and_heap_queue_runs_are_bit_identical() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        for opts in [o(7, true), SimOptions { seed: 23, noise: true, scenario: busy_scenario() }]
        {
            let cal = simulate_with_queue(&cluster, &cfg, &workload(), &opts, QueueKind::Calendar);
            let heap = simulate_with_queue(&cluster, &cfg, &workload(), &opts, QueueKind::Heap);
            assert_eq!(cal.exec_time_s, heap.exec_time_s);
            assert_eq!(cal.counters, heap.counters);
            assert_eq!(cal.phases, heap.phases);
            assert_eq!(cal.job_failed, heap.job_failed);
        }
    }

    #[test]
    fn table_and_direct_costing_runs_are_bit_identical() {
        // The costing analogue of the queue test: memoized cost tables
        // (cold and warm) against the direct per-launch fallback, under
        // both a benign and a busy scenario.
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        for opts in [o(7, true), SimOptions { seed: 23, noise: true, scenario: busy_scenario() }]
        {
            let mut pool = SimBuffers::new();
            let cold =
                simulate_with_cost_mode(&cluster, &cfg, &workload(), &opts, CostMode::Table, &mut pool);
            let warm =
                simulate_with_cost_mode(&cluster, &cfg, &workload(), &opts, CostMode::Table, &mut pool);
            let direct = simulate_with_cost_mode(
                &cluster,
                &cfg,
                &workload(),
                &opts,
                CostMode::Direct,
                &mut SimBuffers::new(),
            );
            for r in [&cold, &warm] {
                assert_eq!(r.exec_time_s, direct.exec_time_s);
                assert_eq!(r.counters, direct.counters);
                assert_eq!(r.phases, direct.phases);
                assert_eq!(r.job_failed, direct.job_failed);
            }
            // Direct mode evaluates every attempt; the table collapses a
            // homogeneous run to a handful of distinct keys.
            assert_eq!(
                direct.counters.cost_evals,
                direct.counters.map_attempts + direct.counters.reduce_attempts
            );
            assert!(cold.counters.cost_evals < direct.counters.cost_evals);
        }
    }

    #[test]
    fn warm_twin_reuses_cost_tables_and_noise_prefix() {
        // The acceptance shape: a benign run followed by its faulty twin
        // (same seed, same config/workload) in one pool. The twin must
        // (a) be bit-identical to a cold standalone run, and (b) show
        // warm hits and fewer cost evaluations than that cold run.
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let benign = o(42, true);
        let faulty = SimOptions { seed: 42, noise: true, scenario: busy_scenario() };
        let mut pool = SimBuffers::new();
        let first =
            simulate_with_cost_mode(&cluster, &cfg, &workload(), &benign, CostMode::Table, &mut pool);
        let twin =
            simulate_with_cost_mode(&cluster, &cfg, &workload(), &faulty, CostMode::Table, &mut pool);
        let cold = simulate_with_cost_mode(
            &cluster,
            &cfg,
            &workload(),
            &faulty,
            CostMode::Table,
            &mut SimBuffers::new(),
        );
        assert_eq!(twin.exec_time_s, cold.exec_time_s);
        assert_eq!(twin.counters, cold.counters);
        assert_eq!(twin.phases, cold.phases);
        assert_eq!(twin.job_failed, cold.job_failed);
        assert_eq!(first.counters.warm_hits, 0, "first run in a fresh pool is cold");
        assert!(twin.counters.warm_hits > 0, "twin never hit the warm cache");
        assert!(
            twin.counters.cost_evals < cold.counters.cost_evals,
            "warm twin must evaluate fewer costs than cold ({} vs {})",
            twin.counters.cost_evals,
            cold.counters.cost_evals
        );
    }

    #[test]
    fn events_counter_meters_dispatches() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let r = simulate(&cluster, &cfg, &workload(), &SimOptions::default());
        // at least InitialFill + one Done event per task attempt
        assert!(
            r.counters.events > r.counters.map_attempts + r.counters.reduce_attempts,
            "events={}",
            r.counters.events
        );
        let again = simulate(&cluster, &cfg, &workload(), &SimOptions::default());
        assert_eq!(r.counters.events, again.counters.events, "event count must be deterministic");
    }
}
