//! The discrete-event MapReduce job simulator — the "real system" whose
//! execution time is the objective `f(θ)` that SPSA observes.
//!
//! Slot-level event-driven scheduling over the cluster model: map tasks are
//! placed locality-first on free map slots; reducers launch once all maps
//! finish, with a shuffle-overlap credit earned from the slowstart point
//! onward (paper §2.3.2); every task's duration is priced by the
//! [`super::map_task`]/[`super::reduce_task`] cost models under per-node
//! resource contention, multiplied by seeded stochastic noise (lognormal +
//! stragglers) — the run-to-run randomness SPSA's iterates must filter
//! (paper §4.2, Fig. 4).

use crate::cluster::{ClusterSpec, HdfsFile, Namenode, Resource, ResourceTracker};
use crate::config::{HadoopConfig, HadoopVersion};
use crate::util::rng::Rng;
use crate::workloads::WorkloadProfile;

use super::constants::*;
use super::event::EventQueue;
use super::map_task::{map_output_for_split, map_task_cost, TaskRates};
use super::reduce_task::reduce_task_cost;
use super::trace::{JobRunResult, PhaseBreakdown, SimCounters};

/// Simulation options.
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// RNG seed: placement and noise are deterministic per seed.
    pub seed: u64,
    /// Disable for the noise-free objective (landscape dumps, tests);
    /// SPSA observes the noisy system, as on a real cluster.
    pub noise: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { seed: 1, noise: true }
    }
}

/// Fraction of an early reducer's fetch window usable while maps still run
/// (the network is shared with map-side traffic during the overlap).
const FETCH_OVERLAP_EFF: f64 = 0.5;

#[derive(Clone, Copy, Debug)]
enum Event {
    /// Fill all map slots at job start.
    InitialFill,
    MapDone { slot: usize, task: usize },
    ReduceDone { slot: usize },
}

struct Slot {
    node: u32,
    tasks_run: u64,
}

struct Sim<'a> {
    config: &'a HadoopConfig,
    w: &'a WorkloadProfile,
    opts: &'a SimOptions,

    q: EventQueue<Event>,
    tracker: ResourceTracker,
    rng: Rng,
    phases: PhaseBreakdown,
    counters: SimCounters,

    file: HdfsFile,
    namenode: Namenode,
    map_slots: Vec<Slot>,
    reduce_slots: Vec<Slot>,
    /// Per-node queues of pending tasks with a local replica (locality-first
    /// dispatch in O(1) amortized instead of an O(pending) scan — §Perf).
    node_pending: Vec<Vec<usize>>,
    /// Global FIFO of pending map tasks (fallback for remote dispatch).
    pending_maps: Vec<usize>,
    /// Next unscanned index into `pending_maps`.
    pending_cursor: usize,
    /// Task assignment flags (a task may sit in several queues).
    map_assigned: Vec<bool>,
    maps_launched: u64,
    pending_reduces: Vec<usize>,
    map_task_local: Vec<bool>,

    n_maps: u64,
    n_reduces: u64,
    total_shuffle_raw: f64,

    maps_completed: u64,
    maps_done_s: f64,
    slowstart_cross_s: Option<f64>,
    last_reduce_done_s: f64,
}

impl<'a> Sim<'a> {
    fn new(
        cluster: &'a ClusterSpec,
        config: &'a HadoopConfig,
        w: &'a WorkloadProfile,
        opts: &'a SimOptions,
    ) -> Self {
        let mut rng = Rng::seeded(opts.seed);
        let mut namenode = Namenode::new(cluster.workers(), config.dfs_replication as u32);

        // input layout: v2 honours the job.maps hint (only increases splits)
        let block = config.dfs_block_size.max(1 << 20);
        let natural_splits = w.input_bytes.div_ceil(block).max(1);
        let n_maps = match config.version {
            HadoopVersion::V1 => natural_splits,
            HadoopVersion::V2 => natural_splits.max(config.job_maps),
        };
        let split_bytes = w.input_bytes.div_ceil(n_maps).max(1);
        let file = namenode.create_file(&w.name, w.input_bytes, split_bytes, &mut rng);
        let n_maps = file.blocks.len() as u64;

        // total shuffle volume (pre-compression) is known analytically
        let total_shuffle_raw: f64 = file
            .blocks
            .iter()
            .map(|b| map_output_for_split(config, w, b.size).raw_bytes)
            .sum();

        // Interleave slots across nodes (slot k of every node, then slot
        // k+1, …) so partially-filled waves spread over the whole cluster —
        // matching how a real scheduler balances task placement.
        let mut map_slots = Vec::new();
        for s in 0..cluster.map_slots_per_node {
            for node in 0..cluster.workers() {
                let _ = s;
                map_slots.push(Slot { node, tasks_run: 0 });
            }
        }
        let mut reduce_slots = Vec::new();
        for s in 0..cluster.reduce_slots_per_node {
            for node in 0..cluster.workers() {
                let _ = s;
                reduce_slots.push(Slot { node, tasks_run: 0 });
            }
        }

        let n_reduces = config.reduce_tasks.max(1);
        let mut counters = SimCounters::default();
        counters.n_maps = n_maps;
        counters.n_reduces = n_reduces;
        counters.map_waves = n_maps.div_ceil(cluster.total_map_slots() as u64);
        counters.reduce_waves = n_reduces.div_ceil(cluster.total_reduce_slots() as u64);

        // per-node locality queues
        let mut node_pending: Vec<Vec<usize>> = vec![Vec::new(); cluster.workers() as usize];
        for (t, block) in file.blocks.iter().enumerate() {
            for &r in &block.replicas {
                node_pending[r as usize].push(t);
            }
        }

        Sim {
            config,
            w,
            opts,
            q: EventQueue::new(),
            tracker: ResourceTracker::new(cluster),
            rng,
            phases: PhaseBreakdown::default(),
            counters,
            node_pending,
            pending_maps: (0..n_maps as usize).collect(),
            pending_cursor: 0,
            map_assigned: vec![false; n_maps as usize],
            maps_launched: 0,
            pending_reduces: (0..n_reduces as usize).collect(),
            map_task_local: vec![false; n_maps as usize],
            file,
            namenode,
            map_slots,
            reduce_slots,
            n_maps,
            n_reduces,
            total_shuffle_raw,
            maps_completed: 0,
            maps_done_s: 0.0,
            slowstart_cross_s: None,
            last_reduce_done_s: 0.0,
        }
    }

    fn noise_factor(&mut self) -> f64 {
        if !self.opts.noise {
            return 1.0;
        }
        let mut m = self.rng.lognormal_unit_mean(TASK_NOISE_SIGMA);
        if self.rng.bernoulli(STRAGGLER_P) {
            m *= STRAGGLER_FACTOR;
        }
        m
    }

    fn setup_time(slot: &mut Slot, reuse: u64) -> f64 {
        let t = if slot.tasks_run % reuse.max(1) == 0 { JVM_START_S } else { TASK_LAUNCH_S };
        slot.tasks_run += 1;
        t
    }

    /// Per-reducer shuffle volume with the measured partition skew:
    /// reducer 0 is the hot partition.
    fn reduce_volume(&self, task: usize) -> f64 {
        let total = self.total_shuffle_raw;
        if self.n_reduces == 1 {
            return total;
        }
        let mean = total / self.n_reduces as f64;
        let hot = (self.w.partition_skew.max(1.0) * mean).min(total);
        if task == 0 {
            hot
        } else {
            (total - hot) / (self.n_reduces - 1) as f64
        }
    }

    /// Pick the next map task for a node: data-local if its queue has one,
    /// else the oldest unassigned task.
    fn next_map_task(&mut self, node: u32) -> Option<usize> {
        if self.maps_launched >= self.n_maps {
            return None;
        }
        // local queue first
        while let Some(t) = self.node_pending[node as usize].pop() {
            if !self.map_assigned[t] {
                self.map_assigned[t] = true;
                self.maps_launched += 1;
                return Some(t);
            }
        }
        // global FIFO fallback
        while self.pending_cursor < self.pending_maps.len() {
            let t = self.pending_maps[self.pending_cursor];
            self.pending_cursor += 1;
            if !self.map_assigned[t] {
                self.map_assigned[t] = true;
                self.maps_launched += 1;
                return Some(t);
            }
        }
        None
    }

    fn launch_map(&mut self, slot_idx: usize) -> bool {
        let node = self.map_slots[slot_idx].node;
        let Some(task) = self.next_map_task(node) else {
            return false;
        };
        let local = self.namenode.is_local(&self.file.blocks[task], node);
        self.map_task_local[task] = local;
        if local {
            self.counters.data_local_maps += 1;
        }

        self.tracker.acquire(node, Resource::Cpu);
        self.tracker.acquire(node, Resource::Disk);
        if !local {
            self.tracker.acquire(node, Resource::Net);
        }
        let rates = TaskRates {
            disk_bw: self.tracker.disk_bw(node),
            net_bw: self.tracker.net_bw(node),
            cpu_ops_per_sec: self.tracker.cpu_rate(node),
        };
        let split = self.file.blocks[task].size;
        let cost = map_task_cost(self.config, self.w, split, local, &rates);
        let setup =
            Self::setup_time(&mut self.map_slots[slot_idx], self.config.effective_jvm_reuse());
        let m = self.noise_factor();
        let wall = setup + cost.wall_s() * m;

        self.phases.task_setup += setup;
        self.phases.map_read += cost.read_s * m;
        self.phases.map_cpu += cost.map_cpu_s * m;
        self.phases.map_spill += cost.spill_s * m;
        self.phases.map_merge += cost.merge_s * m;
        self.counters.spilled_files += cost.n_spills;
        self.counters.spilled_records += cost.spilled_records;
        self.counters.map_output_bytes += cost.output_bytes;

        self.q.schedule_in(wall, Event::MapDone { slot: slot_idx, task });
        true
    }

    fn launch_reduce(&mut self, slot_idx: usize) -> bool {
        if self.pending_reduces.is_empty() {
            return false;
        }
        let task = self.pending_reduces.remove(0);
        let node = self.reduce_slots[slot_idx].node;
        let first_wave = self.reduce_slots[slot_idx].tasks_run == 0;

        self.tracker.acquire(node, Resource::Cpu);
        self.tracker.acquire(node, Resource::Disk);
        self.tracker.acquire(node, Resource::Net);
        let rates = TaskRates {
            disk_bw: self.tracker.disk_bw(node),
            net_bw: self.tracker.net_bw(node),
            cpu_ops_per_sec: self.tracker.cpu_rate(node),
        };
        let vol = self.reduce_volume(task);
        let cost = reduce_task_cost(self.config, self.w, vol as u64, self.n_maps, &rates);
        let setup =
            Self::setup_time(&mut self.reduce_slots[slot_idx], self.config.effective_jvm_reuse());
        let m = self.noise_factor();

        // Shuffle-overlap credit: a first-wave reducer has been fetching
        // since the slowstart point, at reduced efficiency (shared with map
        // traffic). At least the non-overlappable tail remains.
        let mut shuffle_s = cost.shuffle_s * m;
        if first_wave {
            if let Some(cross) = self.slowstart_cross_s {
                let window = (self.maps_done_s - cross).max(0.0) * FETCH_OVERLAP_EFF;
                shuffle_s = (shuffle_s - window).max(cost.shuffle_s * m * SHUFFLE_TAIL_FRACTION);
            }
        }
        let wall = setup + shuffle_s + (cost.merge_s + cost.reduce_cpu_s + cost.write_s) * m;

        self.phases.task_setup += setup;
        self.phases.shuffle += shuffle_s;
        self.phases.reduce_merge += cost.merge_s * m;
        self.phases.reduce_cpu += cost.reduce_cpu_s * m;
        self.phases.output_write += cost.write_s * m;
        self.counters.shuffled_bytes += if self.config.compress_map_output {
            (vol * self.w.compress_ratio) as u64
        } else {
            vol as u64
        };
        self.counters.reduce_spilled_bytes += cost.spilled_bytes;
        self.counters.output_bytes += cost.output_bytes;

        self.q.schedule_in(wall, Event::ReduceDone { slot: slot_idx });
        true
    }

    fn run(mut self) -> JobRunResult {
        self.q.schedule(JOB_SETUP_S, Event::InitialFill);
        let slowstart = self.config.effective_slowstart();

        while let Some((t, ev)) = self.q.pop() {
            match ev {
                Event::InitialFill => {
                    for i in 0..self.map_slots.len() {
                        if !self.launch_map(i) {
                            break;
                        }
                    }
                    // degenerate: a job with zero map output still runs
                    if self.n_maps == 0 {
                        self.maps_done_s = t;
                    }
                }
                Event::MapDone { slot, task } => {
                    self.maps_completed += 1;
                    self.maps_done_s = t;
                    let node = self.map_slots[slot].node;
                    self.tracker.release(node, Resource::Cpu);
                    self.tracker.release(node, Resource::Disk);
                    if !self.map_task_local[task] {
                        self.tracker.release(node, Resource::Net);
                    }
                    if self.slowstart_cross_s.is_none()
                        && self.maps_completed as f64 / self.n_maps as f64 >= slowstart
                    {
                        self.slowstart_cross_s = Some(t);
                    }
                    self.launch_map(slot);
                    if self.maps_completed == self.n_maps {
                        if self.slowstart_cross_s.is_none() {
                            self.slowstart_cross_s = Some(t);
                        }
                        // launch the first reduce wave
                        for i in 0..self.reduce_slots.len() {
                            if !self.launch_reduce(i) {
                                break;
                            }
                        }
                    }
                }
                Event::ReduceDone { slot } => {
                    self.last_reduce_done_s = t;
                    let node = self.reduce_slots[slot].node;
                    self.tracker.release(node, Resource::Cpu);
                    self.tracker.release(node, Resource::Disk);
                    self.tracker.release(node, Resource::Net);
                    self.launch_reduce(slot);
                }
            }
        }

        let exec = self.last_reduce_done_s.max(self.maps_done_s) + JOB_CLEANUP_S;
        JobRunResult {
            exec_time_s: exec,
            phases: self.phases,
            counters: self.counters,
            maps_done_s: self.maps_done_s,
        }
    }
}

/// Simulate one job execution; returns wall-clock time and full trace.
pub fn simulate(
    cluster: &ClusterSpec,
    config: &HadoopConfig,
    w: &WorkloadProfile,
    opts: &SimOptions,
) -> JobRunResult {
    Sim::new(cluster, config, w, opts).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParameterSpace;

    fn workload() -> WorkloadProfile {
        WorkloadProfile {
            name: "tera-test".into(),
            input_bytes: 4 << 30,
            avg_input_record_bytes: 100.0,
            map_selectivity_bytes: 1.0,
            map_selectivity_records: 1.0,
            avg_map_record_bytes: 100.0,
            combiner_reduction: 1.0,
            has_combiner: false,
            reduce_selectivity_bytes: 1.0,
            partition_skew: 1.1,
            compress_ratio: 0.4,
            map_cpu_ops_per_record: 60.0,
            reduce_cpu_ops_per_record: 50.0,
        }
    }

    #[test]
    fn runs_to_completion() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let r = simulate(&cluster, &cfg, &workload(), &SimOptions::default());
        assert!(r.exec_time_s.is_finite());
        assert!(r.exec_time_s > JOB_SETUP_S);
        assert_eq!(r.counters.n_maps, 32); // 4 GB / 128 MB
        assert_eq!(r.counters.n_reduces, 1);
    }

    #[test]
    fn deterministic_per_seed() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let a = simulate(&cluster, &cfg, &workload(), &SimOptions { seed: 7, noise: true });
        let b = simulate(&cluster, &cfg, &workload(), &SimOptions { seed: 7, noise: true });
        assert_eq!(a.exec_time_s, b.exec_time_s);
        assert_eq!(a.counters, b.counters);
    }

    #[test]
    fn noise_changes_between_seeds() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let a = simulate(&cluster, &cfg, &workload(), &SimOptions { seed: 1, noise: true });
        let b = simulate(&cluster, &cfg, &workload(), &SimOptions { seed: 2, noise: true });
        assert_ne!(a.exec_time_s, b.exec_time_s);
        let ratio = a.exec_time_s / b.exec_time_s;
        assert!(ratio > 0.5 && ratio < 2.0, "ratio {ratio}");
    }

    #[test]
    fn more_reducers_help_shuffle_heavy_job() {
        let cluster = ClusterSpec::paper_cluster();
        let mut cfg = ParameterSpace::v1().default_config();
        let opts = SimOptions { seed: 3, noise: false };
        let single = simulate(&cluster, &cfg, &workload(), &opts);
        cfg.reduce_tasks = 48;
        let many = simulate(&cluster, &cfg, &workload(), &opts);
        assert!(
            many.exec_time_s < single.exec_time_s * 0.6,
            "48 reducers {} vs 1 reducer {}",
            many.exec_time_s,
            single.exec_time_s
        );
    }

    #[test]
    fn maps_finish_before_job_ends() {
        let cluster = ClusterSpec::paper_cluster();
        let cfg = ParameterSpace::v1().default_config();
        let r = simulate(&cluster, &cfg, &workload(), &SimOptions { seed: 5, noise: false });
        assert!(r.maps_done_s < r.exec_time_s);
        assert!(r.counters.data_local_maps > r.counters.n_maps / 2);
    }

    #[test]
    fn wave_counts() {
        let cluster = ClusterSpec::paper_cluster();
        let mut cfg = ParameterSpace::v1().default_config();
        cfg.reduce_tasks = 100;
        let r = simulate(&cluster, &cfg, &workload(), &SimOptions { seed: 5, noise: false });
        assert_eq!(r.counters.map_waves, 1); // 32 maps on 72 slots
        assert_eq!(r.counters.reduce_waves, 3); // 100 on 48 slots
    }

    #[test]
    fn v2_job_maps_hint_increases_splits() {
        let cluster = ClusterSpec::paper_cluster();
        let mut cfg = ParameterSpace::v2().default_config();
        let mut small = workload();
        small.input_bytes = 256 << 20; // 2 natural splits
        cfg.job_maps = 16;
        let r = simulate(&cluster, &cfg, &small, &SimOptions { seed: 1, noise: false });
        assert_eq!(r.counters.n_maps, 16);
    }

    #[test]
    fn v2_jvm_reuse_cuts_setup_time() {
        let cluster = ClusterSpec::paper_cluster();
        let mut cfg = ParameterSpace::v2().default_config();
        let mut wl = workload();
        wl.input_bytes = 40 << 30; // many waves
        let opts = SimOptions { seed: 2, noise: false };
        let fresh = simulate(&cluster, &cfg, &wl, &opts);
        cfg.jvm_numtasks = 20;
        let reused = simulate(&cluster, &cfg, &wl, &opts);
        assert!(reused.phases.task_setup < fresh.phases.task_setup);
    }

    #[test]
    fn early_slowstart_overlaps_shuffle() {
        let cluster = ClusterSpec::paper_cluster();
        let mut cfg = ParameterSpace::v2().default_config();
        cfg.reduce_tasks = 24;
        let mut wl = workload();
        wl.input_bytes = 20 << 30;
        let opts = SimOptions { seed: 4, noise: false };
        cfg.slowstart = 0.05;
        let early = simulate(&cluster, &cfg, &wl, &opts);
        cfg.slowstart = 1.0;
        let late = simulate(&cluster, &cfg, &wl, &opts);
        assert!(
            early.exec_time_s < late.exec_time_s,
            "early {} late {}",
            early.exec_time_s,
            late.exec_time_s
        );
    }

    #[test]
    fn tuned_config_beats_default_substantially() {
        // The headline mechanism: a hand-tuned configuration must
        // dramatically beat Table-1 defaults on a terasort-like job,
        // otherwise the optimization landscape is too flat for the paper's
        // 60 %+ gains to be reproducible.
        let cluster = ClusterSpec::paper_cluster();
        let space = ParameterSpace::v1();
        let default = space.default_config();
        let mut tuned = default.clone();
        tuned.io_sort_mb = 400;
        tuned.spill_percent = 0.6;
        tuned.sort_record_percent = 0.15;
        tuned.sort_factor = 64;
        tuned.reduce_tasks = 90;
        tuned.shuffle_input_buffer_percent = 0.8;
        tuned.compress_map_output = true;
        let mut wl = workload();
        wl.input_bytes = 30 << 30; // the paper's terasort partial workload
        let opts = SimOptions { seed: 11, noise: false };
        let d = simulate(&cluster, &default, &wl, &opts);
        let t = simulate(&cluster, &tuned, &wl, &opts);
        let gain = 1.0 - t.exec_time_s / d.exec_time_s;
        assert!(gain > 0.4, "gain only {:.1}% ({} -> {})", gain * 100.0, d.exec_time_s, t.exec_time_s);
    }
}
