//! Two-level costing fast path: per-run cost tables + a cross-run warm
//! cache.
//!
//! `map_task_cost` / `reduce_task_cost` are pure functions of
//! `(config, workload, split-or-volume, locality, rates)`, and `rates`
//! itself is a pure function of `(node spec, scenario speed, exact
//! contention triple)`. That makes every attempt price memoizable with
//! a key that captures *all* of those inputs:
//!
//! * **Level 1 (per run)** — `launch_map_on` / `launch_reduce_on` look
//!   costs up in a table keyed by deduplicated node class × split (or
//!   reduce-volume) class × locality × the post-acquire
//!   `(cpu, disk, net)` user counts. On a homogeneous cluster the node
//!   column collapses to one class and a benign run prices a handful of
//!   distinct keys instead of one evaluation per attempt.
//! * **Level 2 (across runs)** — the table lives in [`WarmCache`]
//!   inside `SimBuffers`, so consecutive runs that share
//!   `(config, workload)` — scenario twins, percentile-wave seeds,
//!   repeated SPSA observations at one θ — inherit the previous run's
//!   entries. The attempt-0 noise prefix is additionally reusable when
//!   the *seed* also matches (benign/faulty twins): noise is keyed
//!   `(seed, kind, task, attempt)` (order-independent since PR 2), so
//!   attempt-0 factors are identical across scenario variants.
//!
//! Bit-invisibility is by construction: a memo hit returns the pure
//! cost function's own earlier output, every physics input is either in
//! the key (node spec bits, speed bits, split size, locality, exact
//! user counts) or pinned by the warm signature (config + workload),
//! and anything schedule-dependent — per-attempt noise multipliers,
//! fault fates, JVM setup, the first-wave shuffle-overlap credit — is
//! applied *outside* the cached value. The `direct-cost` cargo feature
//! (mirroring `heap-queue`) keeps the table-free path as the default,
//! and both paths stay compiled and cross-tested either way.

use super::map_task::{map_output_for_split, MapTaskCost};
use super::reduce_task::ReduceTaskCost;
use super::scenario::ScenarioSpec;
use crate::cluster::ClusterSpec;
use crate::config::{HadoopConfig, HadoopVersion};
use crate::workloads::WorkloadProfile;

/// How the simulator prices task attempts. Mirrors `QueueKind`: the
/// production default is the fast path, the alternative stays compiled
/// as an escape hatch and cross-check target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostMode {
    /// Memoized per-run cost tables + cross-run warm cache (default).
    Table,
    /// Evaluate the cost model on every attempt launch (legacy path;
    /// default only under the `direct-cost` cargo feature).
    Direct,
}

impl CostMode {
    /// The build's default costing mode: `Table` unless the
    /// `direct-cost` feature flips the default back to `Direct`.
    pub fn default_mode() -> CostMode {
        if cfg!(feature = "direct-cost") {
            CostMode::Direct
        } else {
            CostMode::Table
        }
    }
}

/// Field widths of the packed memo key. Out-of-range components (a
/// pathological cluster with >1024 distinct node classes, or >8191
/// concurrent users of one resource) fall back to direct evaluation for
/// that lookup — correctness never depends on the key fitting.
const CLASS_BITS: u32 = 10;
const USER_BITS: u32 = 13;
const MAX_CLASSES: usize = 1 << CLASS_BITS;
/// Per-run class assignment marker for "doesn't fit in the key".
const UNCLASSIFIED: u16 = u16::MAX;

/// A deduplicated node equivalence class: everything `rates_for` reads
/// besides the contention triple. Two nodes in the same class produce
/// bit-identical `TaskRates` for equal user counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct NodeClass {
    cpu_ops_bits: u64,
    cores: u32,
    disk_bw_bits: u64,
    net_bw_bits: u64,
    memory: u64,
    speed_bits: u64,
}

/// Cross-run warm state for the costing fast path. Lives inside
/// `SimBuffers`; unlike the other pool fields its *contents* survive
/// between runs on purpose.
///
/// Validity is self-enforcing: memo entries depend on `(config,
/// workload)` — pinned by [`WarmCache::begin_run`]'s signature check,
/// which resets everything on mismatch — plus inputs that are part of
/// the key itself (node-spec/speed bits via the append-only class list,
/// exact split sizes, locality, exact user counts). Cluster topology
/// and scenario therefore do NOT need to be in the signature: a changed
/// node spec or speed simply lands in a different (possibly new) class.
/// The attempt-0 noise prefix is keyed by seed separately.
#[derive(Clone, Debug, Default)]
pub struct WarmCache {
    /// Signature of (config, workload) the cached state is valid for.
    /// Empty = cold.
    sig: Vec<u64>,
    /// Append-only node class list; memo keys index into it, so classes
    /// are never removed or reordered within a signature epoch.
    classes: Vec<NodeClass>,
    /// Per-run: class index of each worker node (rebuilt every run).
    node_class: Vec<u16>,
    /// Append-only deduplicated split sizes (≤ 2 distinct in practice:
    /// full blocks + one remainder).
    split_sizes: Vec<u64>,
    /// Memoized `map_output_for_split(..).raw_bytes` per split class.
    split_raw: Vec<f64>,
    /// Per-run: split class of each map task (rebuilt every run).
    split_class: Vec<u16>,
    /// Memoized map costs, linear-scan by packed key (a `Vec` both for
    /// determinism-lint hygiene and because the key population is tiny).
    memo_map: Vec<(u64, MapTaskCost)>,
    /// Memoized reduce costs.
    memo_red: Vec<(u64, ReduceTaskCost)>,
    /// `memo_map.len()` at run start — entries below it were inherited
    /// from a previous run, and serving them counts as a warm hit.
    inherited_map: usize,
    /// `memo_red.len()` at run start.
    inherited_red: usize,
    /// Seed the attempt-0 noise prefix below was computed for.
    noise_seed: Option<u64>,
    /// Whether the current run inherited the prefix (same seed + same
    /// signature as the previous run) rather than recomputing it.
    noise_inherited: bool,
    /// Attempt-0 noise multiplier per map task.
    noise0_map: Vec<f64>,
    /// Attempt-0 noise multiplier per reduce task.
    noise0_red: Vec<f64>,
}

impl WarmCache {
    /// Start a run: validate or reset the cache against `(config, w)`,
    /// mark the inherited memo prefix, and assign every worker its node
    /// class under this run's scenario speeds.
    pub(crate) fn begin_run(
        &mut self,
        cluster: &ClusterSpec,
        config: &HadoopConfig,
        w: &WorkloadProfile,
        scenario: &ScenarioSpec,
    ) {
        let sig = signature(config, w);
        if sig != self.sig {
            self.sig = sig;
            self.classes.clear();
            self.split_sizes.clear();
            self.split_raw.clear();
            self.memo_map.clear();
            self.memo_red.clear();
            self.noise_seed = None;
            self.noise0_map.clear();
            self.noise0_red.clear();
        }
        self.inherited_map = self.memo_map.len();
        self.inherited_red = self.memo_red.len();
        self.node_class.clear();
        for node in 0..cluster.workers() {
            let spec = cluster.node_spec(node);
            let key = NodeClass {
                cpu_ops_bits: spec.cpu_ops_per_sec.to_bits(),
                cores: spec.cores,
                disk_bw_bits: spec.disk_bw.to_bits(),
                net_bw_bits: spec.net_bw.to_bits(),
                memory: spec.memory,
                speed_bits: scenario.speed_of(node).to_bits(),
            };
            let idx = match self.classes.iter().position(|c| *c == key) {
                Some(i) => i as u16,
                None if self.classes.len() < MAX_CLASSES => {
                    self.classes.push(key);
                    (self.classes.len() - 1) as u16
                }
                None => UNCLASSIFIED,
            };
            self.node_class.push(idx);
        }
    }

    /// Assign each split its class (memoizing the per-class map-output
    /// raw bytes) and return the total shuffle raw bytes — bit-identical
    /// to summing `map_output_for_split(..).raw_bytes` per block in the
    /// same order, because each class's value IS that function's output.
    pub(crate) fn assign_splits(
        &mut self,
        config: &HadoopConfig,
        w: &WorkloadProfile,
        sizes: impl Iterator<Item = u64>,
    ) -> f64 {
        self.split_class.clear();
        let mut total = 0.0;
        for size in sizes {
            match self.split_sizes.iter().position(|&s| s == size) {
                Some(i) => {
                    total += self.split_raw[i];
                    self.split_class.push(i as u16);
                }
                None if self.split_sizes.len() < MAX_CLASSES => {
                    let raw = map_output_for_split(config, w, size).raw_bytes;
                    self.split_sizes.push(size);
                    self.split_raw.push(raw);
                    total += raw;
                    self.split_class.push((self.split_sizes.len() - 1) as u16);
                }
                None => {
                    total += map_output_for_split(config, w, size).raw_bytes;
                    self.split_class.push(UNCLASSIFIED);
                }
            }
        }
        total
    }

    /// (Re)compute or inherit the attempt-0 noise prefix for `seed`.
    /// Inheriting is sound because noise is keyed `(seed, kind, task,
    /// attempt)` — scenario variants with the same seed draw identical
    /// attempt-0 factors.
    pub(crate) fn ensure_noise_prefix<F: Fn(bool, usize) -> f64>(
        &mut self,
        seed: u64,
        n_maps: usize,
        n_reduces: usize,
        raw_factor_for_map: F,
    ) {
        if self.noise_seed == Some(seed)
            && self.noise0_map.len() == n_maps
            && self.noise0_red.len() == n_reduces
        {
            self.noise_inherited = true;
            return;
        }
        self.noise_inherited = false;
        self.noise_seed = Some(seed);
        self.noise0_map.clear();
        self.noise0_map
            .extend((0..n_maps).map(|t| raw_factor_for_map(true, t)));
        self.noise0_red.clear();
        self.noise0_red
            .extend((0..n_reduces).map(|t| raw_factor_for_map(false, t)));
    }

    /// Serve an attempt-0 noise factor from the prefix, with a flag
    /// saying whether the prefix was inherited from a previous run.
    /// `None` (task outside the prefix) falls back to direct
    /// computation, which is bit-identical by construction.
    pub(crate) fn noise0(&self, map: bool, task: usize) -> Option<(f64, bool)> {
        let arr = if map { &self.noise0_map } else { &self.noise0_red };
        arr.get(task).map(|&m| (m, self.noise_inherited))
    }

    /// Packed memo key for a map attempt, or `None` when any component
    /// overflows its field (→ caller evaluates directly).
    pub(crate) fn map_key(
        &self,
        node: u32,
        task: usize,
        local: bool,
        cpu_users: u32,
        disk_users: u32,
        net_users: u32,
    ) -> Option<u64> {
        let nc = *self.node_class.get(node as usize)?;
        let sc = *self.split_class.get(task)?;
        pack_key(nc, sc, local, cpu_users, disk_users, net_users)
    }

    /// Packed memo key for a reduce attempt. `vol_class` is 0 for the
    /// hot (skewed) partition and 1 for the uniform rest — the
    /// class↔volume mapping is pinned by the signature (volumes derive
    /// from config + workload only).
    pub(crate) fn red_key(
        &self,
        node: u32,
        vol_class: u16,
        cpu_users: u32,
        disk_users: u32,
        net_users: u32,
    ) -> Option<u64> {
        let nc = *self.node_class.get(node as usize)?;
        pack_key(nc, vol_class, false, cpu_users, disk_users, net_users)
    }

    /// Look up a memoized map cost; the flag is true when the entry was
    /// inherited from a previous run (a warm hit).
    pub(crate) fn lookup_map(&self, key: u64) -> Option<(MapTaskCost, bool)> {
        self.memo_map
            .iter()
            .position(|(k, _)| *k == key)
            .map(|i| (self.memo_map[i].1, i < self.inherited_map))
    }

    pub(crate) fn insert_map(&mut self, key: u64, cost: MapTaskCost) {
        self.memo_map.push((key, cost));
    }

    pub(crate) fn lookup_red(&self, key: u64) -> Option<(ReduceTaskCost, bool)> {
        self.memo_red
            .iter()
            .position(|(k, _)| *k == key)
            .map(|i| (self.memo_red[i].1, i < self.inherited_red))
    }

    pub(crate) fn insert_red(&mut self, key: u64, cost: ReduceTaskCost) {
        self.memo_red.push((key, cost));
    }
}

/// Pack a memo key. Layout (low → high bits): cpu users (13), disk
/// users (13), net users (13), locality flag (1), split/volume class
/// (10), node class (10) — 60 bits, injective over in-range components.
fn pack_key(
    node_class: u16,
    item_class: u16,
    local: bool,
    cpu_users: u32,
    disk_users: u32,
    net_users: u32,
) -> Option<u64> {
    if node_class as usize >= MAX_CLASSES
        || item_class as usize >= MAX_CLASSES
        || cpu_users >= 1 << USER_BITS
        || disk_users >= 1 << USER_BITS
        || net_users >= 1 << USER_BITS
    {
        return None;
    }
    Some(
        cpu_users as u64
            | (disk_users as u64) << USER_BITS
            | (net_users as u64) << (2 * USER_BITS)
            | (local as u64) << (3 * USER_BITS)
            | (item_class as u64) << (3 * USER_BITS + 1)
            | (node_class as u64) << (3 * USER_BITS + 1 + CLASS_BITS),
    )
}

fn push_f(sig: &mut Vec<u64>, x: f64) {
    sig.push(x.to_bits());
}

/// Injective fixed-layout encoding of everything the cost functions
/// read besides the per-key inputs: the full `HadoopConfig` and
/// `WorkloadProfile`. Seed and scenario are deliberately absent — the
/// noise prefix is seed-keyed separately, and scenario speeds live
/// inside the node-class keys, which is what makes cross-scenario and
/// cross-seed reuse possible at all.
fn signature(config: &HadoopConfig, w: &WorkloadProfile) -> Vec<u64> {
    let mut s = Vec::with_capacity(40 + w.name.len() / 8);
    s.push(match config.version {
        HadoopVersion::V1 => 1,
        HadoopVersion::V2 => 2,
    });
    s.push(config.io_sort_mb);
    push_f(&mut s, config.spill_percent);
    s.push(config.sort_factor);
    push_f(&mut s, config.shuffle_input_buffer_percent);
    push_f(&mut s, config.shuffle_merge_percent);
    s.push(config.inmem_merge_threshold);
    push_f(&mut s, config.reduce_input_buffer_percent);
    s.push(config.reduce_tasks);
    push_f(&mut s, config.sort_record_percent);
    s.push(config.compress_map_output as u64);
    s.push(config.output_compress as u64);
    push_f(&mut s, config.slowstart);
    s.push(config.jvm_numtasks);
    s.push(config.job_maps);
    s.push(config.dfs_block_size);
    s.push(config.reduce_task_heap);
    s.push(config.dfs_replication);
    s.push(config.os.readahead_kb);
    s.push(config.os.net_rmem_kb);
    push_f(&mut s, config.os.dirty_ratio);
    // Workload: length-prefixed name (keeps the encoding injective),
    // then every numeric field in declaration order.
    s.push(w.name.len() as u64);
    for chunk in w.name.as_bytes().chunks(8) {
        let mut word = 0u64;
        for (i, &b) in chunk.iter().enumerate() {
            word |= (b as u64) << (8 * i);
        }
        s.push(word);
    }
    s.push(w.input_bytes);
    push_f(&mut s, w.avg_input_record_bytes);
    push_f(&mut s, w.map_selectivity_bytes);
    push_f(&mut s, w.map_selectivity_records);
    push_f(&mut s, w.avg_map_record_bytes);
    push_f(&mut s, w.combiner_reduction);
    s.push(w.has_combiner as u64);
    push_f(&mut s, w.reduce_selectivity_bytes);
    push_f(&mut s, w.partition_skew);
    push_f(&mut s, w.compress_ratio);
    push_f(&mut s, w.map_cpu_ops_per_record);
    push_f(&mut s, w.reduce_cpu_ops_per_record);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParameterSpace;
    use crate::coordinator::profile_for;
    use crate::workloads::Benchmark;

    fn setup() -> (ClusterSpec, HadoopConfig, WorkloadProfile) {
        (
            ClusterSpec::paper_cluster(),
            ParameterSpace::v1().default_config(),
            profile_for(Benchmark::Terasort, 1000),
        )
    }

    #[test]
    fn signature_tracks_config_and_workload() {
        let (_, config, w) = setup();
        assert_eq!(signature(&config, &w), signature(&config, &w));
        let mut c2 = config.clone();
        c2.io_sort_mb += 1;
        assert_ne!(signature(&config, &w), signature(&c2, &w));
        let mut w2 = w.clone();
        w2.partition_skew += 0.5;
        assert_ne!(signature(&config, &w), signature(&config, &w2));
        let mut w3 = w.clone();
        w3.name.push('x');
        assert_ne!(signature(&config, &w), signature(&config, &w3));
    }

    #[test]
    fn homogeneous_cluster_collapses_to_one_node_class() {
        let (cluster, config, w) = setup();
        let mut warm = WarmCache::default();
        warm.begin_run(&cluster, &config, &w, &ScenarioSpec::default());
        assert_eq!(warm.classes.len(), 1);
        assert_eq!(warm.node_class.len(), cluster.workers() as usize);
        assert!(warm.node_class.iter().all(|&c| c == 0));
        // A slowed node is a different class; everyone else keeps class 0.
        let slow = ScenarioSpec::default().with_slow_node(3, 0.5);
        warm.begin_run(&cluster, &config, &w, &slow);
        assert_eq!(warm.classes.len(), 2);
        assert_eq!(warm.node_class[3], 1);
        assert_eq!(warm.node_class[0], 0);
    }

    #[test]
    fn pack_key_rejects_out_of_range_components() {
        assert!(pack_key(0, 0, true, 1, 1, 1).is_some());
        assert!(pack_key(UNCLASSIFIED, 0, true, 1, 1, 1).is_none());
        assert!(pack_key(0, UNCLASSIFIED, false, 1, 1, 1).is_none());
        assert!(pack_key(0, 0, false, 1 << USER_BITS, 1, 1).is_none());
        // Injective over distinct in-range components.
        let a = pack_key(1, 2, true, 3, 4, 5).unwrap();
        let b = pack_key(1, 2, false, 3, 4, 5).unwrap();
        let c = pack_key(2, 1, true, 3, 4, 5).unwrap();
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn assign_splits_matches_direct_total_and_dedups() {
        let (_, config, w) = setup();
        let mut warm = WarmCache::default();
        let sizes = [128u64 << 20, 128 << 20, 128 << 20, 44 << 20];
        let total = warm.assign_splits(&config, &w, sizes.iter().copied());
        let direct: f64 = sizes
            .iter()
            .map(|&s| map_output_for_split(&config, &w, s).raw_bytes)
            .sum();
        assert_eq!(total.to_bits(), direct.to_bits());
        assert_eq!(warm.split_sizes.len(), 2);
        assert_eq!(warm.split_class, vec![0, 0, 0, 1]);
    }

    #[test]
    fn memo_entries_inherited_across_runs_count_as_warm() {
        let (cluster, config, w) = setup();
        let mut warm = WarmCache::default();
        warm.begin_run(&cluster, &config, &w, &ScenarioSpec::default());
        let key = warm.map_key(0, 0, true, 1, 1, 0);
        // No splits assigned yet → task 0 has no class.
        assert!(key.is_none());
        let _ = warm.assign_splits(&config, &w, [128u64 << 20].iter().copied());
        let key = warm.map_key(0, 0, true, 1, 1, 0).unwrap();
        assert!(warm.lookup_map(key).is_none());
        warm.insert_map(key, MapTaskCost::default());
        // Same run: a hit, but not inherited.
        assert_eq!(warm.lookup_map(key).map(|(_, inh)| inh), Some(false));
        // Next run, same signature: the entry is inherited.
        warm.begin_run(&cluster, &config, &w, &ScenarioSpec::default());
        let _ = warm.assign_splits(&config, &w, [128u64 << 20].iter().copied());
        assert_eq!(warm.lookup_map(key).map(|(_, inh)| inh), Some(true));
        // A signature change resets the memo entirely.
        let mut c2 = config.clone();
        c2.reduce_tasks += 1;
        warm.begin_run(&cluster, &c2, &w, &ScenarioSpec::default());
        let _ = warm.assign_splits(&c2, &w, [128u64 << 20].iter().copied());
        let key2 = warm.map_key(0, 0, true, 1, 1, 0).unwrap();
        assert!(warm.lookup_map(key2).is_none());
    }

    #[test]
    fn noise_prefix_inherits_only_on_matching_seed() {
        let mut warm = WarmCache::default();
        let fake = |map: bool, task: usize| if map { task as f64 } else { -(task as f64) };
        warm.ensure_noise_prefix(7, 3, 2, fake);
        assert!(!warm.noise_inherited);
        assert_eq!(warm.noise0(true, 2), Some((2.0, false)));
        assert_eq!(warm.noise0(false, 1), Some((-1.0, false)));
        assert_eq!(warm.noise0(true, 3), None);
        // Same seed + same shape → inherited, values untouched.
        warm.ensure_noise_prefix(7, 3, 2, |_, _| f64::NAN);
        assert!(warm.noise_inherited);
        assert_eq!(warm.noise0(true, 2), Some((2.0, true)));
        // Different seed → recomputed.
        warm.ensure_noise_prefix(8, 3, 2, fake);
        assert!(!warm.noise_inherited);
    }
}
