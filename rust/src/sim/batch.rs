//! Batched simulation entry point: fan a set of independent job runs
//! (distinct configurations and/or seeds against one cluster + workload)
//! across the coordinator thread pool. Each run's outcome is a pure
//! function of its `(config, SimOptions)` pair, so results are identical
//! for any worker count — parallelism is purely a wall-clock optimization.

use std::sync::Arc;

use crate::cluster::ClusterSpec;
use crate::config::HadoopConfig;
use crate::coordinator::pool::{resolve_workers, run_parallel};
use crate::workloads::WorkloadProfile;

use super::simulator::{simulate_with_buffers, SimBuffers, SimOptions};
use super::trace::JobRunResult;

/// One entry of a simulation batch.
#[derive(Clone, Debug)]
pub struct SimJob {
    pub config: HadoopConfig,
    pub opts: SimOptions,
}

/// Simulate every job in `jobs` on `workers` threads (1 = sequential, in
/// order); results come back in job order. Determinism: element `i` equals
/// `simulate(cluster, &jobs[i].config, w, &jobs[i].opts)` exactly,
/// independent of `workers` and scheduling — seeds travel with the jobs,
/// not with the threads.
///
/// Allocation: each lane (the sequential path, or one worker chunk) runs
/// its whole share of the batch through a single [`SimBuffers`] pool, so a
/// 64-probe wave performs one warm-up allocation per lane rather than 64
/// full simulator builds. Safe because `Sim::new` fully re-initializes
/// every buffer from the job's own spec — run N's state cannot leak into
/// run N+1 (see `buffer_reuse_matches_fresh_buffers` below).
pub fn simulate_batch(
    cluster: &ClusterSpec,
    jobs: Vec<SimJob>,
    w: &WorkloadProfile,
    workers: usize,
) -> Vec<JobRunResult> {
    let mut bufs = SimBuffers::new();
    simulate_batch_with_buffers(cluster, jobs, w, workers, &mut bufs)
}

/// [`simulate_batch`] threading the caller's buffer pool through the
/// sequential path, so the pool's warm cost cache (`sim::cost`) carries
/// across consecutive batches — `SimObjective`'s percentile waves re-run
/// the same (config, workload) with only seeds varied, exactly the warm
/// case. Parallel chunks still get their own pools (pools never cross
/// threads); since pooling and warm reuse are bit-invisible, results
/// stay independent of the worker count either way.
pub fn simulate_batch_with_buffers(
    cluster: &ClusterSpec,
    jobs: Vec<SimJob>,
    w: &WorkloadProfile,
    workers: usize,
    bufs: &mut SimBuffers,
) -> Vec<JobRunResult> {
    if workers <= 1 || jobs.len() <= 1 {
        return jobs
            .into_iter()
            .map(|j| simulate_with_buffers(cluster, &j.config, w, &j.opts, bufs))
            .collect();
    }
    let cluster = Arc::new(cluster.clone());
    let w = Arc::new(w.clone());
    // Chunk the job list so each worker thunk reuses one buffer pool
    // across its whole slice; flattening chunk results in order preserves
    // the job-order contract.
    let n = jobs.len();
    let per = n.div_ceil(workers.min(n));
    let mut chunks: Vec<Vec<SimJob>> = Vec::new();
    let mut jobs = jobs;
    while jobs.len() > per {
        let tail = jobs.split_off(per);
        chunks.push(std::mem::replace(&mut jobs, tail));
    }
    chunks.push(jobs);
    let thunks: Vec<Box<dyn FnOnce() -> Vec<JobRunResult> + Send>> = chunks
        .into_iter()
        .map(|chunk| {
            let cluster = Arc::clone(&cluster);
            let w = Arc::clone(&w);
            Box::new(move || {
                let mut bufs = SimBuffers::new();
                chunk
                    .into_iter()
                    .map(|j| simulate_with_buffers(&cluster, &j.config, &w, &j.opts, &mut bufs))
                    .collect()
            }) as _
        })
        .collect();
    run_parallel(thunks, workers).into_iter().flatten().collect()
}

/// `simulate_batch` with the worker count resolved from the environment
/// (`HSPSA_WORKERS`, else all-but-one core; see `coordinator::pool`).
pub fn simulate_batch_auto(
    cluster: &ClusterSpec,
    jobs: Vec<SimJob>,
    w: &WorkloadProfile,
) -> Vec<JobRunResult> {
    simulate_batch(cluster, jobs, w, resolve_workers(None))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParameterSpace;
    use crate::util::rng::Rng;
    use crate::workloads::Benchmark;

    #[test]
    fn batch_matches_sequential_for_any_worker_count() {
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut rng = Rng::seeded(2);
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut rng);
        let jobs: Vec<SimJob> = (0..6)
            .map(|i| SimJob {
                config: space.default_config(),
                opts: SimOptions { seed: 100 + i, noise: true, ..Default::default() },
            })
            .collect();
        let seq = simulate_batch(&cluster, jobs.clone(), &w, 1);
        let par = simulate_batch(&cluster, jobs.clone(), &w, 4);
        assert_eq!(seq.len(), 6);
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.exec_time_s, b.exec_time_s);
            assert_eq!(a.counters, b.counters);
        }
        // distinct seeds must really differ (noise on)
        assert_ne!(seq[0].exec_time_s, seq[1].exec_time_s);
    }

    #[test]
    fn scenario_batch_is_bit_identical_at_any_worker_count() {
        // Scenario fates are keyed per (seed, task, attempt), so a faulty
        // heterogeneous batch stays a pure function of its job list — the
        // PR-1 determinism contract extends to the scenario engine.
        use crate::sim::ScenarioSpec;
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut rng = Rng::seeded(3);
        let w = Benchmark::Terasort.profile_scaled(200_000, 2 << 30, &mut rng);
        let scenario = ScenarioSpec::default()
            .with_failures(0.15)
            .with_max_attempts(10)
            .with_crash(90.0, 1)
            .with_slow_node(4, 0.5)
            .with_speculation(true);
        let jobs: Vec<SimJob> = (0..6)
            .map(|i| SimJob {
                config: space.default_config(),
                opts: SimOptions { seed: 500 + i, noise: true, scenario: scenario.clone() },
            })
            .collect();
        let seq = simulate_batch(&cluster, jobs.clone(), &w, 1);
        let par = simulate_batch(&cluster, jobs, &w, 4);
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.exec_time_s, b.exec_time_s);
            assert_eq!(a.counters, b.counters);
            assert_eq!(a.phases, b.phases);
            assert_eq!(a.job_failed, b.job_failed);
        }
    }

    #[test]
    fn buffer_reuse_matches_fresh_buffers() {
        // Run N must be independent of run N-1 sharing its buffer pool: a
        // fail-heavy job (dead nodes, killed attempts, retry counters, a
        // populated arena) precedes a benign job in the same sequential
        // lane, and each batch element must equal its standalone
        // fresh-buffer `simulate` twin bit for bit.
        use crate::sim::simulator::simulate;
        use crate::sim::ScenarioSpec;
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut rng = Rng::seeded(5);
        let w = Benchmark::Wordcount.profile_scaled(200_000, 1 << 30, &mut rng);
        let faulty = ScenarioSpec::default()
            .with_failures(0.2)
            .with_max_attempts(10)
            .with_crash(60.0, 2)
            .with_slow_node(3, 0.4)
            .with_speculation(true);
        let jobs: Vec<SimJob> = vec![
            SimJob {
                config: space.default_config(),
                opts: SimOptions { seed: 900, noise: true, scenario: faulty },
            },
            SimJob {
                config: space.default_config(),
                opts: SimOptions { seed: 901, noise: true, ..Default::default() },
            },
        ];
        let batch = simulate_batch(&cluster, jobs.clone(), &w, 1);
        assert!(
            batch[0].counters.killed_attempts + batch[0].counters.map_failures > 0,
            "first job must actually dirty the pool"
        );
        for (got, job) in batch.iter().zip(&jobs) {
            let solo = simulate(&cluster, &job.config, &w, &job.opts);
            assert_eq!(got.exec_time_s, solo.exec_time_s);
            assert_eq!(got.counters, solo.counters);
            assert_eq!(got.phases, solo.phases);
            assert_eq!(got.job_failed, solo.job_failed);
        }
        // no scenario state bled into the benign second run
        assert_eq!(batch[1].counters.killed_attempts + batch[1].counters.map_failures, 0);
    }

    #[test]
    fn caller_pool_batches_are_warm_and_bit_identical() {
        // A same-(config, workload) seed wave through one caller-owned
        // pool — the SimObjective percentile shape — must (a) actually
        // engage the warm cost cache after the first run, and (b) stay
        // bit-identical to fresh-pool batches at any worker count.
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut rng = Rng::seeded(7);
        let w = Benchmark::Terasort.profile_scaled(200_000, 1 << 30, &mut rng);
        let jobs: Vec<SimJob> = (0..5)
            .map(|i| SimJob {
                config: space.default_config(),
                opts: SimOptions { seed: 300 + i, noise: true, ..Default::default() },
            })
            .collect();
        let mut bufs = SimBuffers::new();
        let warm = simulate_batch_with_buffers(&cluster, jobs.clone(), &w, 1, &mut bufs);
        let fresh = simulate_batch(&cluster, jobs.clone(), &w, 1);
        let par = simulate_batch(&cluster, jobs, &w, 3);
        for ((a, b), c) in warm.iter().zip(&fresh).zip(&par) {
            assert_eq!(a.exec_time_s, b.exec_time_s);
            assert_eq!(a.counters, b.counters);
            assert_eq!(a.phases, b.phases);
            assert_eq!(b.counters, c.counters);
            assert_eq!(b.exec_time_s, c.exec_time_s);
        }
        // Cost tables are seed-independent (block layout is not a
        // function of the RNG), so runs 2.. of the wave serve warm hits
        // and evaluate (far) fewer costs than the cold first run.
        if matches!(crate::sim::CostMode::default_mode(), crate::sim::CostMode::Table) {
            assert_eq!(warm[0].counters.warm_hits, 0);
            assert!(warm[1].counters.warm_hits > 0, "wave run 2 never hit the warm cache");
            assert!(warm[1].counters.cost_evals < warm[0].counters.cost_evals);
        }
    }
}
