//! Discrete-event simulator of a Hadoop MapReduce cluster — the substrate
//! standing in for the paper's 25-node testbed (DESIGN.md §1).
//!
//! `simulate(cluster, config, workload, opts)` plays one job through the
//! full §2.3 data path and returns the wall-clock execution time (the SPSA
//! objective) plus a phase/counter trace.

pub mod arena;
pub mod batch;
pub mod constants;
pub mod cost;
pub mod event;
pub mod map_task;
pub mod reduce_task;
pub mod scenario;
pub mod simulator;
pub mod trace;

pub use arena::{Arena, RunningSet};
pub use batch::{simulate_batch, simulate_batch_auto, simulate_batch_with_buffers, SimJob};
pub use cost::{CostMode, WarmCache};
pub use event::{CalendarQueue, EventQueue, HeapQueue, QueueKind, SimTime};
pub use map_task::{map_output_for_split, map_task_cost, MapTaskCost, TaskRates};
pub use reduce_task::{reduce_task_cost, ReduceTaskCost};
pub use scenario::{NodeCrash, NodeSlowdown, ScenarioSpec, TaskKind};
pub use simulator::{
    simulate, simulate_with_buffers, simulate_with_cost_mode, simulate_with_queue, SimBuffers,
    SimOptions,
};
pub use trace::{JobRunResult, PhaseBreakdown, SimCounters};
