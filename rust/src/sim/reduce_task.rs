//! Reduce-task cost model: the paper's §2.3.2 data path priced in seconds.
//!
//! shuffle fetch → in-memory segment buffer → {threshold,percent}-triggered
//! in-memory merges → on-disk merge passes → reduce function → HDFS write.
//! Pure function of (config, workload, per-reducer volume, bandwidths).

use super::constants::*;
use super::map_task::TaskRates;
use crate::config::HadoopConfig;
use crate::workloads::WorkloadProfile;

/// Cost breakdown of one reduce task. `Copy` (all-scalar) so the
/// costing memo in `sim::cost` can store and serve it by value.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReduceTaskCost {
    /// Network fetch time for this reducer's partition.
    pub shuffle_s: f64,
    /// In-memory + on-disk merge work before the reduce function.
    pub merge_s: f64,
    pub reduce_cpu_s: f64,
    pub write_s: f64,
    /// Bytes spilled to the reducer's local disk during shuffle/merge.
    pub spilled_bytes: u64,
    /// Final output bytes written to HDFS.
    pub output_bytes: u64,
}

impl ReduceTaskCost {
    pub fn wall_s(&self) -> f64 {
        self.shuffle_s + self.merge_s + self.reduce_cpu_s + self.write_s
    }
}

/// How many in-memory merge flushes the shuffle performs, and how much data
/// reaches disk. Returns (n_flushes, disk_bytes).
///
/// Segments arrive into a buffer of `shuffle.input.buffer.percent × heap`;
/// a flush fires when accumulated segments exceed `inmem.merge.threshold`
/// count or `shuffle.merge.percent` of the buffer (paper §2.3.2). After the
/// shuffle, `reduce.input.buffer.percent × heap` bytes may stay in memory.
pub fn inmem_merge_plan(
    config: &HadoopConfig,
    volume_bytes: f64,
    n_segments: f64,
) -> (u64, f64) {
    if volume_bytes <= 0.0 || n_segments <= 0.0 {
        return (0, 0.0);
    }
    let buffer = config.shuffle_buffer_bytes() as f64;
    let byte_trigger = (buffer * config.shuffle_merge_percent).max(1.0);
    let seg_trigger = config.inmem_merge_threshold as f64;

    // Everything fits and never crosses a trigger: no disk at all.
    let retained = config.reduce_task_heap as f64 * config.reduce_input_buffer_percent;
    if volume_bytes <= byte_trigger && n_segments <= seg_trigger && volume_bytes <= buffer {
        return (0, 0.0);
    }

    let avg_segment = volume_bytes / n_segments;
    let segs_per_flush_by_bytes = (byte_trigger / avg_segment.max(1.0)).max(1.0);
    let segs_per_flush = seg_trigger.min(segs_per_flush_by_bytes).max(1.0);
    let n_flushes = (n_segments / segs_per_flush).ceil().max(1.0);
    // reduce.input.buffer.percent lets the tail stay in memory
    let disk_bytes = (volume_bytes - retained).max(0.0);
    (n_flushes as u64, disk_bytes)
}

/// Price one reduce task fetching `volume_bytes` (post-combiner map output,
/// compressed on the wire if map compression is on) from `n_maps` mappers.
pub fn reduce_task_cost(
    config: &HadoopConfig,
    w: &WorkloadProfile,
    volume_bytes: u64,
    n_maps: u64,
    rates: &TaskRates,
) -> ReduceTaskCost {
    let mut c = ReduceTaskCost::default();
    let cpu = rates.cpu_ops_per_sec;
    let vol = volume_bytes as f64;
    if vol <= 0.0 {
        return c;
    }

    // Wire volume: map outputs travel compressed if map compression is on.
    let wire_bytes = if config.compress_map_output { vol * w.compress_ratio } else { vol };

    // ---- shuffle fetch (TCP window caps per-flow bandwidth) ---------------
    let fetch_s = wire_bytes / rates.net_bw.min(config.os.net_window_bw()).max(1.0);
    let decompress_s = if config.compress_map_output {
        wire_bytes * DECOMPRESS_OPS_PER_BYTE / cpu
    } else {
        0.0
    };
    c.shuffle_s = fetch_s + decompress_s;

    // ---- in-memory merge flushes -------------------------------------------
    let (n_flushes, disk_bytes) = inmem_merge_plan(config, vol, n_maps as f64);
    c.spilled_bytes = disk_bytes as u64;
    let mut merge_s = 0.0;
    if n_flushes > 0 {
        // each flush sorts/merges its segments and writes to disk
        let write_io = disk_bytes / rates.disk_bw.max(1.0);
        let flush_overhead =
            n_flushes as f64 * SPILL_FILE_S * config.os.spill_overhead_factor();
        let merge_cpu = vol * MERGE_OPS_PER_BYTE / cpu;
        merge_s += write_io + flush_overhead + merge_cpu;

        // ---- on-disk merge passes ------------------------------------------
        // n_flushes files on disk; the final merge streams into the reduce,
        // so only passes beyond the first re-read/re-write data.
        let factor = config.sort_factor.max(2) as f64;
        let extra_passes = ((n_flushes as f64).ln() / factor.ln()).ceil().max(1.0) - 1.0;
        if extra_passes > 0.0 && disk_bytes > 0.0 {
            let streams = factor.min(n_flushes as f64);
            let seek_divisor =
                1.0 + ((streams - MERGE_STREAM_SWEET_SPOT).max(0.0)) / MERGE_STREAM_PENALTY_DIV;
            merge_s += extra_passes * disk_bytes * 2.0 / (rates.disk_bw.max(1.0) / seek_divisor);
            merge_s += (n_flushes as f64 + extra_passes * streams) * FILE_OPEN_S;
        }
        // final read of on-disk data into the reduce function
        merge_s += disk_bytes / rates.disk_bw.max(1.0);
    }
    c.merge_s = merge_s;

    // ---- reduce function -----------------------------------------------------
    let records = vol / w.avg_map_record_bytes.max(1.0);
    // retaining map outputs in the heap pressures the reduce function
    let mem_pressure =
        1.0 + REDUCE_MEM_PRESSURE_COEFF * config.reduce_input_buffer_percent.powi(2);
    c.reduce_cpu_s = records * w.reduce_cpu_ops_per_record * mem_pressure / cpu;

    // ---- output write (HDFS, pipelined replication) --------------------------
    let mut out_bytes = vol * w.reduce_selectivity_bytes;
    let mut compress_cpu = 0.0;
    if config.output_compress {
        compress_cpu = out_bytes * COMPRESS_OPS_PER_BYTE / cpu;
        out_bytes *= w.compress_ratio;
    }
    c.output_bytes = out_bytes as u64;
    let local_write = out_bytes / rates.disk_bw.max(1.0);
    let replica_send = out_bytes * (config.dfs_replication.saturating_sub(1)) as f64
        / rates.net_bw.max(1.0);
    // pipeline: local write and replica transfer overlap
    c.write_s = local_write.max(replica_send) + compress_cpu;

    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParameterSpace;

    fn rates() -> TaskRates {
        TaskRates { disk_bw: 60e6, net_bw: 60e6, cpu_ops_per_sec: 2e8 }
    }

    fn wl() -> WorkloadProfile {
        WorkloadProfile {
            name: "t".into(),
            input_bytes: 1 << 30,
            avg_input_record_bytes: 100.0,
            map_selectivity_bytes: 1.0,
            map_selectivity_records: 1.0,
            avg_map_record_bytes: 100.0,
            combiner_reduction: 1.0,
            has_combiner: false,
            reduce_selectivity_bytes: 1.0,
            partition_skew: 1.0,
            compress_ratio: 0.4,
            map_cpu_ops_per_record: 60.0,
            reduce_cpu_ops_per_record: 200.0,
        }
    }

    #[test]
    fn small_volume_stays_in_memory() {
        let mut cfg = ParameterSpace::v1().default_config();
        cfg.shuffle_input_buffer_percent = 0.7; // 716 MB buffer
        cfg.shuffle_merge_percent = 0.9;
        cfg.inmem_merge_threshold = 10_000;
        let (flushes, disk) = inmem_merge_plan(&cfg, 100e6, 50.0);
        assert_eq!(flushes, 0);
        assert_eq!(disk, 0.0);
    }

    #[test]
    fn low_threshold_forces_many_flushes() {
        let mut cfg = ParameterSpace::v1().default_config();
        cfg.inmem_merge_threshold = 10;
        let (flushes_low, _) = inmem_merge_plan(&cfg, 2e9, 500.0);
        cfg.inmem_merge_threshold = 400;
        let (flushes_high, _) = inmem_merge_plan(&cfg, 2e9, 500.0);
        assert!(flushes_low > flushes_high);
    }

    #[test]
    fn retained_memory_cuts_disk_bytes() {
        let mut cfg = ParameterSpace::v1().default_config();
        cfg.reduce_input_buffer_percent = 0.0;
        let (_, disk0) = inmem_merge_plan(&cfg, 2e9, 500.0);
        cfg.reduce_input_buffer_percent = 0.5;
        let (_, disk1) = inmem_merge_plan(&cfg, 2e9, 500.0);
        assert!(disk1 < disk0);
        assert!((disk0 - disk1 - 0.5 * (1u64 << 30) as f64).abs() < 1.0);
    }

    #[test]
    fn map_compression_cuts_wire_time() {
        let mut cfg = ParameterSpace::v1().default_config();
        let plain = reduce_task_cost(&cfg, &wl(), 1 << 30, 100, &rates());
        cfg.compress_map_output = true;
        let comp = reduce_task_cost(&cfg, &wl(), 1 << 30, 100, &rates());
        assert!(comp.shuffle_s < plain.shuffle_s);
    }

    #[test]
    fn output_compress_trades_write_for_cpu() {
        let mut cfg = ParameterSpace::v1().default_config();
        let plain = reduce_task_cost(&cfg, &wl(), 1 << 30, 100, &rates());
        cfg.output_compress = true;
        let comp = reduce_task_cost(&cfg, &wl(), 1 << 30, 100, &rates());
        assert!(comp.output_bytes < plain.output_bytes);
    }

    #[test]
    fn mem_pressure_penalizes_reduce_cpu() {
        let mut cfg = ParameterSpace::v1().default_config();
        cfg.reduce_input_buffer_percent = 0.0;
        let lean = reduce_task_cost(&cfg, &wl(), 1 << 28, 100, &rates());
        cfg.reduce_input_buffer_percent = 0.8;
        let fat = reduce_task_cost(&cfg, &wl(), 1 << 28, 100, &rates());
        assert!(fat.reduce_cpu_s > lean.reduce_cpu_s);
    }

    #[test]
    fn zero_volume_is_free() {
        let cfg = ParameterSpace::v1().default_config();
        let c = reduce_task_cost(&cfg, &wl(), 0, 100, &rates());
        assert_eq!(c.wall_s(), 0.0);
    }

    #[test]
    fn bigger_shuffle_buffer_less_disk() {
        let mut cfg = ParameterSpace::v1().default_config();
        cfg.inmem_merge_threshold = 10_000;
        cfg.shuffle_merge_percent = 0.9;
        cfg.shuffle_input_buffer_percent = 0.1;
        let small = reduce_task_cost(&cfg, &wl(), 600 << 20, 200, &rates());
        cfg.shuffle_input_buffer_percent = 0.9;
        let big = reduce_task_cost(&cfg, &wl(), 600 << 20, 200, &rates());
        assert!(big.merge_s <= small.merge_s);
    }
}
