//! Fault-injection & heterogeneity scenarios — the misbehaving execution
//! substrates a tuner must stay robust against.
//!
//! The paper's central claim is that SPSA tunes Hadoop by observing a
//! *noisy* system (§4.2, Fig. 4). On a real cluster that noise is not just
//! task-duration jitter: tasks fail and re-execute (`mapred.*.max.attempts`),
//! whole nodes drop out mid-job, speculative backup copies race the
//! originals (`mapred.map./reduce.tasks.speculative.execution`), and
//! heterogeneous fleets mix fast and slow machines. A [`ScenarioSpec`]
//! describes one such regime and rides inside
//! [`super::simulator::SimOptions`] into the event loop, which reacts with
//! `TaskFailed` / `NodeDown` / `SpeculativeLaunch` events.
//!
//! **Determinism.** Every scenario decision (does attempt k of task t fail,
//! and when?) and every task-noise draw is keyed by
//! `(seed, kind, task, attempt)` rather than drawn from a sequential
//! stream. Two consequences: a simulation is a pure function of
//! `(cluster, config, workload, SimOptions)` regardless of event ordering,
//! so scenarios compose with [`super::batch`] and the batched objective
//! layer at any worker count; and the attempt-0 noise of every task is
//! *identical* between a scenario run and its benign twin, so injected
//! faults add work on top of the same baseline instead of reshuffling it.

use crate::util::rng::Rng;

/// Which side of the job an attempt belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Map,
    Reduce,
}

impl TaskKind {
    fn tag(self) -> u64 {
        match self {
            TaskKind::Map => 0x4D41_5054,    // "MAPT"
            TaskKind::Reduce => 0x5245_4454, // "REDT"
        }
    }
}

/// A scheduled permanent node loss (the machine never comes back; its
/// slots are removed and its running attempts are killed and re-queued).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeCrash {
    /// Simulation time in seconds at which the node goes down.
    pub at_s: f64,
    /// Worker (DataNode) index.
    pub node: u32,
}

/// A heterogeneous-fleet entry: one worker running at a fraction of
/// nominal speed (all three resource rates — CPU, disk, NIC — scale).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeSlowdown {
    /// Worker (DataNode) index.
    pub node: u32,
    /// Relative speed in (0, ∞): 1.0 = nominal, 0.5 = half-speed straggler
    /// node, 2.0 = an upgraded machine.
    pub speed: f64,
}

/// One execution-substrate regime: failure injection, node-crash schedule,
/// per-node speed factors and speculative execution. `Default` is the
/// benign scenario PRs 0–1 simulated (no failures, homogeneous, no
/// speculation) — it reproduces the pre-scenario simulator exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    /// Probability that any single task attempt fails partway through
    /// (applies independently to every map/reduce attempt, speculative
    /// copies included).
    pub task_failure_p: f64,
    /// `mapred.map./reduce.max.attempts`: when one task accumulates this
    /// many *failed* attempts the job is declared failed (Hadoop's
    /// job-kill semantics). Kills from speculation or node loss do not
    /// count, matching Hadoop's failed-vs-killed distinction.
    pub max_attempts: u64,
    /// Permanent node losses on a schedule.
    pub node_crashes: Vec<NodeCrash>,
    /// Heterogeneous per-node speed factors.
    pub slow_nodes: Vec<NodeSlowdown>,
    /// `mapred.map.tasks.speculative.execution`: back up slow map tasks.
    pub speculative_maps: bool,
    /// `mapred.reduce.tasks.speculative.execution`: back up slow reducers.
    pub speculative_reduces: bool,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            task_failure_p: 0.0,
            max_attempts: 4,
            node_crashes: Vec::new(),
            slow_nodes: Vec::new(),
            speculative_maps: false,
            speculative_reduces: false,
        }
    }
}

/// Salt for the per-attempt task-duration noise stream.
pub(crate) const NOISE_SALT: u64 = 0x6E6F_6973_655F_7331;
/// Salt for the per-attempt failure-fate stream (independent of noise).
pub(crate) const FAULT_SALT: u64 = 0x6661_756C_745F_7332;

/// Derive the independent RNG of one `(seed, salt, kind, task, attempt)`
/// tuple. Keyed derivation (instead of one sequential stream) is what makes
/// scenarios order-independent and benign/faulty runs share their attempt-0
/// noise — see the module docs.
pub(crate) fn attempt_rng(seed: u64, salt: u64, kind: TaskKind, task: u64, attempt: u64) -> Rng {
    let mut x = seed ^ salt;
    x = x.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ kind.tag().wrapping_mul(0xD1B5_4A32_D192_ED03);
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB)
        ^ task.wrapping_add(1).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = x.wrapping_mul(0x2545_F491_4F6C_DD1D) ^ attempt.wrapping_add(1);
    Rng::seeded(x)
}

impl ScenarioSpec {
    /// The benign scenario (alias of `Default`).
    pub fn benign() -> Self {
        ScenarioSpec::default()
    }

    /// No faults, homogeneous fleet, speculation off?
    pub fn is_benign(&self) -> bool {
        self.task_failure_p <= 0.0
            && self.node_crashes.is_empty()
            && self.slow_nodes.is_empty()
            && !self.speculative_maps
            && !self.speculative_reduces
    }

    /// Builder: per-attempt failure probability.
    pub fn with_failures(mut self, p: f64) -> Self {
        self.task_failure_p = p.clamp(0.0, 1.0);
        self
    }

    /// Builder: `max.attempts` ceiling (≥ 1).
    pub fn with_max_attempts(mut self, n: u64) -> Self {
        self.max_attempts = n.max(1);
        self
    }

    /// Builder: schedule a permanent node loss.
    pub fn with_crash(mut self, at_s: f64, node: u32) -> Self {
        self.node_crashes.push(NodeCrash { at_s: at_s.max(0.0), node });
        self
    }

    /// Builder: mark one worker as running at `speed` × nominal.
    pub fn with_slow_node(mut self, node: u32, speed: f64) -> Self {
        self.slow_nodes.push(NodeSlowdown { node, speed: speed.max(1e-3) });
        self
    }

    /// Builder: toggle speculative execution for both task kinds.
    pub fn with_speculation(mut self, on: bool) -> Self {
        self.speculative_maps = on;
        self.speculative_reduces = on;
        self
    }

    /// Is speculation enabled for this task kind?
    pub fn speculative(&self, kind: TaskKind) -> bool {
        match kind {
            TaskKind::Map => self.speculative_maps,
            TaskKind::Reduce => self.speculative_reduces,
        }
    }

    /// Relative speed of a worker (1.0 unless listed in `slow_nodes`; the
    /// last entry wins if a node is listed twice).
    pub fn speed_of(&self, node: u32) -> f64 {
        self.slow_nodes
            .iter()
            .rev()
            .find(|s| s.node == node)
            .map(|s| s.speed)
            .unwrap_or(1.0)
    }

    /// The fate of attempt `attempt` of task `task`: `None` = runs to
    /// completion; `Some(frac)` = dies after `frac` of its would-be work
    /// time. Pure function of `(seed, kind, task, attempt)`.
    pub fn attempt_fate(&self, seed: u64, kind: TaskKind, task: u64, attempt: u64) -> Option<f64> {
        if self.task_failure_p <= 0.0 {
            return None;
        }
        let mut rng = attempt_rng(seed, FAULT_SALT, kind, task, attempt);
        if rng.bernoulli(self.task_failure_p) {
            // Die strictly inside the run: at least a sliver of work is
            // wasted, and the attempt never outlives its healthy twin.
            Some(rng.range_f64(0.05, 0.95))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_benign() {
        let s = ScenarioSpec::default();
        assert!(s.is_benign());
        assert_eq!(s.max_attempts, 4);
        assert_eq!(s.attempt_fate(1, TaskKind::Map, 0, 0), None);
    }

    #[test]
    fn builders_compose() {
        let s = ScenarioSpec::default()
            .with_failures(0.1)
            .with_max_attempts(3)
            .with_crash(100.0, 2)
            .with_slow_node(5, 0.5)
            .with_speculation(true);
        assert!(!s.is_benign());
        assert_eq!(s.node_crashes, vec![NodeCrash { at_s: 100.0, node: 2 }]);
        assert_eq!(s.speed_of(5), 0.5);
        assert_eq!(s.speed_of(4), 1.0);
        assert!(s.speculative(TaskKind::Map) && s.speculative(TaskKind::Reduce));
    }

    #[test]
    fn failure_p_clamped() {
        assert_eq!(ScenarioSpec::default().with_failures(7.0).task_failure_p, 1.0);
        assert_eq!(ScenarioSpec::default().with_failures(-1.0).task_failure_p, 0.0);
    }

    #[test]
    fn fate_is_deterministic_and_keyed() {
        let s = ScenarioSpec::default().with_failures(0.5);
        for task in 0..50u64 {
            for attempt in 0..3u64 {
                let a = s.attempt_fate(9, TaskKind::Map, task, attempt);
                let b = s.attempt_fate(9, TaskKind::Map, task, attempt);
                assert_eq!(a, b, "fate not deterministic");
                if let Some(frac) = a {
                    assert!((0.05..0.95).contains(&frac));
                }
            }
        }
    }

    #[test]
    fn fate_rate_tracks_p() {
        let s = ScenarioSpec::default().with_failures(0.2);
        let n = 5000u64;
        let fails = (0..n)
            .filter(|&t| s.attempt_fate(3, TaskKind::Reduce, t, 0).is_some())
            .count();
        let rate = fails as f64 / n as f64;
        assert!((rate - 0.2).abs() < 0.03, "rate {rate}");
    }

    #[test]
    fn kinds_and_attempts_get_independent_fates() {
        let s = ScenarioSpec::default().with_failures(0.5);
        let map_fates: Vec<bool> =
            (0..64).map(|t| s.attempt_fate(1, TaskKind::Map, t, 0).is_some()).collect();
        let red_fates: Vec<bool> =
            (0..64).map(|t| s.attempt_fate(1, TaskKind::Reduce, t, 0).is_some()).collect();
        let retry_fates: Vec<bool> =
            (0..64).map(|t| s.attempt_fate(1, TaskKind::Map, t, 1).is_some()).collect();
        assert_ne!(map_fates, red_fates);
        assert_ne!(map_fates, retry_fates);
    }

    #[test]
    fn attempt_rng_streams_differ() {
        let mut a = attempt_rng(1, NOISE_SALT, TaskKind::Map, 0, 0);
        let mut b = attempt_rng(1, NOISE_SALT, TaskKind::Map, 1, 0);
        let mut c = attempt_rng(1, FAULT_SALT, TaskKind::Map, 0, 0);
        let same_ab = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same_ab < 4);
        let mut a2 = attempt_rng(1, NOISE_SALT, TaskKind::Map, 0, 0);
        let same_ac = (0..64).filter(|_| a2.next_u64() == c.next_u64()).count();
        assert!(same_ac < 4);
    }
}
