//! Nelder–Mead downhill simplex over the normalized parameter space — a
//! classic derivative-free baseline for the registry: where SPSA spends a
//! dimension-independent 2–3 observations per iteration on a gradient
//! *estimate*, the simplex pays n+1 observations just to get started and
//! then 1–2 per reflect/expand/contract step, with an n-observation bill
//! for every shrink.
//!
//! Broker integration:
//! * the n+1-vertex initial simplex and every shrink step are dispatched
//!   as ONE `try_eval_batch` call, so the independent probes fan across
//!   the worker pool (and the values stay bit-identical to a sequential
//!   loop at any worker count — the broker's ordered-dispatch contract);
//! * the search is budget-truncation-safe: any `None`/short batch from
//!   the broker is a graceful stop and the best vertex *observed so far*
//!   (not the best simplex vertex) is returned;
//! * iterates are projected onto [0,1]^n by coordinate clamping — the
//!   same Γ every other tuner uses.
//!
//! The method is deterministic given θ₀ (no RNG), so the registry seed
//! only reaches the objective's noise, never the search itself.

use crate::config::ParameterSpace;
use crate::util::json::Json;

use super::broker::EvalBroker;
use super::registry::{decode_checkpoint, encode_checkpoint, TuneOutcome, Tuner};

/// Standard Nelder–Mead coefficients plus the simplex construction step.
#[derive(Clone, Debug)]
pub struct NelderMeadConfig {
    /// Initial simplex edge per coordinate (algorithm space).
    pub step: f64,
    /// Reflection coefficient (> 0).
    pub alpha: f64,
    /// Expansion coefficient (> 1).
    pub gamma: f64,
    /// Contraction coefficient (0 < rho ≤ 0.5).
    pub rho: f64,
    /// Shrink coefficient (0 < sigma < 1).
    pub sigma: f64,
    /// Stop when the simplex f-spread falls below this relative tolerance.
    pub tol: f64,
    /// Iteration cap for unlimited brokers (a budgeted broker stops the
    /// loop by exhaustion first).
    pub max_iters: u64,
}

impl Default for NelderMeadConfig {
    fn default() -> Self {
        NelderMeadConfig {
            step: 0.15,
            alpha: 1.0,
            gamma: 2.0,
            rho: 0.5,
            sigma: 0.5,
            tol: 1e-4,
            max_iters: 200,
        }
    }
}

/// Nelder–Mead behind the [`Tuner`] interface.
pub struct NelderMeadTuner {
    pub config: NelderMeadConfig,
}

impl NelderMeadTuner {
    pub fn new() -> NelderMeadTuner {
        NelderMeadTuner { config: NelderMeadConfig::default() }
    }
}

impl Default for NelderMeadTuner {
    fn default() -> Self {
        Self::new()
    }
}

fn clamp_unit(theta: &mut [f64]) {
    for t in theta.iter_mut() {
        *t = t.clamp(0.0, 1.0);
    }
}

/// Best-so-far tracker over every (θ, f) the search observes.
struct Best {
    theta: Vec<f64>,
    f: f64,
}

impl Best {
    fn seen(&mut self, theta: &[f64], f: f64) {
        if f < self.f {
            self.f = f;
            self.theta = theta.to_vec();
        }
    }
}

/// Where inside an iteration a checkpointed run stopped. Every stop sits
/// immediately BEFORE a broker dispatch, so resuming re-issues exactly the
/// evaluation the straight run would have issued next — same observation
/// index, same wave grid.
#[derive(Clone, Debug, PartialEq)]
enum NmPhase {
    /// At an iteration boundary (or before the initial simplex batch when
    /// the simplex is still empty).
    Start,
    /// Reflected point observed and better than the incumbent best; the
    /// expansion probe is the next dispatch.
    Expand { xr: Vec<f64>, fr: f64 },
    /// Reflected point observed and not good enough; the contraction
    /// probe is the next dispatch. `xc` is recomputed from the (unchanged)
    /// simplex, so only `fr` needs to survive the checkpoint.
    Contract { fr: f64 },
    /// Contraction rejected; the n-point shrink batch is the next dispatch.
    Shrink,
}

/// Serializable Nelder–Mead resume state: the simplex (empty until the
/// initial batch lands), the iteration counter, the best-so-far tracker,
/// and the intra-iteration phase. Geometry (centroid, reflect/contract
/// points, shrink targets) is deterministic from the simplex and is
/// recomputed on resume rather than stored.
#[derive(Clone, Debug, PartialEq)]
pub struct NmState {
    simplex: Vec<(Vec<f64>, f64)>,
    iters: u64,
    best_theta: Vec<f64>,
    best_f: f64,
    phase: NmPhase,
}

impl NmState {
    fn fresh(theta0: Vec<f64>) -> NmState {
        NmState {
            simplex: Vec::new(),
            iters: 0,
            best_theta: theta0,
            best_f: f64::INFINITY,
            phase: NmPhase::Start,
        }
    }

    /// Finite-safe f encoding: the virgin state carries best_f = +inf,
    /// which JSON spells `null`.
    fn f_to_json(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }

    fn f_from_json(j: Option<&Json>) -> f64 {
        j.and_then(|v| v.as_f64()).unwrap_or(f64::INFINITY)
    }

    pub fn to_json(&self) -> Json {
        let simplex = Json::Arr(
            self.simplex
                .iter()
                .map(|(v, f)| {
                    Json::obj()
                        .set("theta", Json::from_f64_slice(v))
                        .set("f", Self::f_to_json(*f))
                })
                .collect(),
        );
        let mut js = Json::obj()
            .set("simplex", simplex)
            .set("iters", Json::Num(self.iters as f64))
            .set("best_theta", Json::from_f64_slice(&self.best_theta))
            .set("best_f", Self::f_to_json(self.best_f));
        js = match &self.phase {
            NmPhase::Start => js.set("phase", Json::Str("start".into())),
            NmPhase::Expand { xr, fr } => js
                .set("phase", Json::Str("expand".into()))
                .set("xr", Json::from_f64_slice(xr))
                .set("fr", Self::f_to_json(*fr)),
            NmPhase::Contract { fr } => {
                js.set("phase", Json::Str("contract".into())).set("fr", Self::f_to_json(*fr))
            }
            NmPhase::Shrink => js.set("phase", Json::Str("shrink".into())),
        };
        js
    }

    pub fn from_json(js: &Json) -> Result<NmState, String> {
        let simplex = js
            .get("simplex")
            .and_then(|v| v.as_arr())
            .ok_or("missing simplex")?
            .iter()
            .map(|entry| {
                let theta = entry
                    .get("theta")
                    .and_then(|v| v.to_f64_vec())
                    .ok_or("simplex vertex missing theta")?;
                let f = Self::f_from_json(entry.get("f"));
                Ok((theta, f))
            })
            .collect::<Result<Vec<_>, String>>()?;
        let iters = js.get("iters").and_then(|v| v.as_f64()).ok_or("missing iters")? as u64;
        let best_theta =
            js.get("best_theta").and_then(|v| v.to_f64_vec()).ok_or("missing best_theta")?;
        let best_f = Self::f_from_json(js.get("best_f"));
        let phase = match js.get("phase").and_then(|v| v.as_str()).ok_or("missing phase")? {
            "start" => NmPhase::Start,
            "expand" => NmPhase::Expand {
                xr: js.get("xr").and_then(|v| v.to_f64_vec()).ok_or("expand missing xr")?,
                fr: Self::f_from_json(js.get("fr")),
            },
            "contract" => NmPhase::Contract { fr: Self::f_from_json(js.get("fr")) },
            "shrink" => NmPhase::Shrink,
            other => return Err(format!("unknown phase {other:?}")),
        };
        Ok(NmState { simplex, iters, best_theta, best_f, phase })
    }
}

/// The point `centroid + coef·(centroid − worst)` clamped to [0,1]^n,
/// where the centroid spans all vertices but the worst. Arithmetic order
/// matches `tune` exactly so both paths agree bit for bit.
fn nm_along(simplex: &[(Vec<f64>, f64)], coef: f64) -> Vec<f64> {
    let n = simplex.len() - 1;
    let dim = simplex[0].0.len();
    let mut centroid = vec![0.0; dim];
    for (v, _) in &simplex[..n] {
        for (c, x) in centroid.iter_mut().zip(v) {
            *c += x / n as f64;
        }
    }
    let mut v: Vec<f64> =
        centroid.iter().zip(&simplex[n].0).map(|(c, w)| c + coef * (c - w)).collect();
    clamp_unit(&mut v);
    v
}

impl NelderMeadTuner {
    /// Checkpoint-grade search loop: identical moves to `tune`, but every
    /// broker dispatch is guarded by a whole-step affordability check
    /// (`remaining() ≥ step size`) instead of dispatching a truncatable
    /// prefix. A failed guard checkpoints at the current [`NmPhase`] and
    /// returns `done = false`; tolerance collapse and the iteration cap
    /// return `done = true` (the search is finished for good). Because
    /// every segment stops on the same whole-step grid, a split run's
    /// dispatch sequence — and therefore its wave grid and modeled time —
    /// is bit-identical to the uninterrupted run's.
    fn run_resumable(
        &self,
        broker: &mut EvalBroker,
        space: &ParameterSpace,
        mut st: NmState,
    ) -> (NmState, bool) {
        let cfg = &self.config;
        let n = space.dim();

        if st.simplex.is_empty() {
            // initial simplex: all-or-nothing (the plain path's truncated
            // prefix cannot be resumed without re-observing it)
            if broker.remaining() < n as u64 + 1 {
                return (st, false);
            }
            let x0 = st.best_theta.clone();
            let mut points: Vec<Vec<f64>> = Vec::with_capacity(n + 1);
            points.push(x0.clone());
            for i in 0..n {
                let mut v = x0.clone();
                v[i] = if v[i] + cfg.step <= 1.0 { v[i] + cfg.step } else { v[i] - cfg.step };
                clamp_unit(&mut v);
                points.push(v);
            }
            let fs = broker.try_eval_batch(&points);
            debug_assert_eq!(fs.len(), points.len(), "guarded init batch must serve whole");
            let mut best = Best { theta: st.best_theta.clone(), f: st.best_f };
            for (p, &f) in points.iter().zip(&fs) {
                best.seen(p, f);
            }
            st.best_theta = best.theta;
            st.best_f = best.f;
            st.simplex = points.into_iter().zip(fs).collect();
        }

        let done = loop {
            match st.phase.clone() {
                NmPhase::Start => {
                    if st.iters >= cfg.max_iters {
                        break true;
                    }
                    st.simplex.sort_by(|a, b| a.1.total_cmp(&b.1));
                    let (fb, fw) = (st.simplex[0].1, st.simplex[n].1);
                    if fw - fb <= cfg.tol * fb.abs().max(1e-9) {
                        break true;
                    }
                    if broker.remaining() < 1 {
                        break false;
                    }
                    st.iters += 1;
                    let xr = nm_along(&st.simplex, cfg.alpha);
                    let fr = broker.try_eval(&xr).expect("guarded reflect eval");
                    if fr < st.best_f {
                        st.best_f = fr;
                        st.best_theta = xr.clone();
                    }
                    let f_second_worst = st.simplex[n - 1].1;
                    if fr < fb {
                        st.phase = NmPhase::Expand { xr, fr };
                    } else if fr < f_second_worst {
                        st.simplex[n] = (xr, fr);
                    } else {
                        st.phase = NmPhase::Contract { fr };
                    }
                }
                NmPhase::Expand { xr, fr } => {
                    if broker.remaining() < 1 {
                        break false;
                    }
                    let xe = nm_along(&st.simplex, cfg.alpha * cfg.gamma);
                    let fe = broker.try_eval(&xe).expect("guarded expand eval");
                    if fe < st.best_f {
                        st.best_f = fe;
                        st.best_theta = xe.clone();
                    }
                    st.simplex[n] = if fe < fr { (xe, fe) } else { (xr, fr) };
                    st.phase = NmPhase::Start;
                }
                NmPhase::Contract { fr } => {
                    if broker.remaining() < 1 {
                        break false;
                    }
                    let fw = st.simplex[n].1;
                    let xc = if fr < fw {
                        nm_along(&st.simplex, cfg.alpha * cfg.rho)
                    } else {
                        nm_along(&st.simplex, -cfg.rho)
                    };
                    let fc = broker.try_eval(&xc).expect("guarded contract eval");
                    if fc < st.best_f {
                        st.best_f = fc;
                        st.best_theta = xc.clone();
                    }
                    if fc < fr.min(fw) {
                        st.simplex[n] = (xc, fc);
                        st.phase = NmPhase::Start;
                    } else {
                        st.phase = NmPhase::Shrink;
                    }
                }
                NmPhase::Shrink => {
                    if broker.remaining() < n as u64 {
                        break false;
                    }
                    let targets: Vec<Vec<f64>> = st.simplex[1..]
                        .iter()
                        .map(|(v, _)| {
                            let mut s: Vec<f64> = st.simplex[0]
                                .0
                                .iter()
                                .zip(v)
                                .map(|(b, x)| b + cfg.sigma * (x - b))
                                .collect();
                            clamp_unit(&mut s);
                            s
                        })
                        .collect();
                    let fs = broker.try_eval_batch(&targets);
                    debug_assert_eq!(fs.len(), targets.len(), "guarded shrink batch must serve whole");
                    for (i, (t, f)) in targets.into_iter().zip(fs).enumerate() {
                        if f < st.best_f {
                            st.best_f = f;
                            st.best_theta = t.clone();
                        }
                        st.simplex[i + 1] = (t, f);
                    }
                    st.phase = NmPhase::Start;
                }
            }
        };
        (st, done)
    }
}

impl Tuner for NelderMeadTuner {
    fn name(&self) -> &'static str {
        "nelder-mead"
    }

    // default cache policy (Quantized): a contracting simplex revisits
    // quantized cells near its optimum — those replays are free

    fn tune(&self, broker: &mut EvalBroker, space: &ParameterSpace, _seed: u64) -> TuneOutcome {
        let cfg = &self.config;
        let n = space.dim();
        let x0 = space.default_theta();
        let mut best = Best { theta: x0.clone(), f: f64::INFINITY };

        // Initial simplex: θ₀ plus one vertex per coordinate, stepped away
        // from the nearer box wall so every vertex is distinct — one batch.
        let mut points: Vec<Vec<f64>> = Vec::with_capacity(n + 1);
        points.push(x0.clone());
        for i in 0..n {
            let mut v = x0.clone();
            v[i] = if v[i] + cfg.step <= 1.0 { v[i] + cfg.step } else { v[i] - cfg.step };
            clamp_unit(&mut v);
            points.push(v);
        }
        let fs = broker.try_eval_batch(&points);
        for (p, &f) in points.iter().zip(&fs) {
            best.seen(p, f);
        }
        if fs.len() < points.len() {
            // budget could not even afford the initial simplex
            return TuneOutcome {
                best_theta: best.theta,
                best_f: best.f,
                history: Vec::new(),
                model_evals: 0,
                profiling_overhead_s: 0.0,
                noise_frozen: false,
            };
        }
        let mut simplex: Vec<(Vec<f64>, f64)> = points.into_iter().zip(fs).collect();

        let mut iters = 0;
        while iters < cfg.max_iters && !broker.exhausted() {
            iters += 1;
            // order best → worst (stable: ties keep insertion order)
            // total_cmp: a NaN vertex sorts worst and gets replaced first
            // (Equal-on-NaN left it stuck wherever it sat)
            simplex.sort_by(|a, b| a.1.total_cmp(&b.1));
            let (fb, fw) = (simplex[0].1, simplex[n].1);
            if fw - fb <= cfg.tol * fb.abs().max(1e-9) {
                break; // simplex collapsed onto (noise around) one value
            }
            let f_second_worst = simplex[n - 1].1;

            // centroid of all vertices but the worst
            let mut centroid = vec![0.0; n];
            for (v, _) in &simplex[..n] {
                for (c, x) in centroid.iter_mut().zip(v) {
                    *c += x / n as f64;
                }
            }
            let along = |coef: f64| -> Vec<f64> {
                let mut v: Vec<f64> = centroid
                    .iter()
                    .zip(&simplex[n].0)
                    .map(|(c, w)| c + coef * (c - w))
                    .collect();
                clamp_unit(&mut v);
                v
            };

            // reflect
            let xr = along(cfg.alpha);
            let Some(fr) = broker.try_eval(&xr) else { break };
            best.seen(&xr, fr);

            if fr < fb {
                // expand
                let xe = along(cfg.alpha * cfg.gamma);
                let Some(fe) = broker.try_eval(&xe) else {
                    simplex[n] = (xr, fr);
                    break;
                };
                best.seen(&xe, fe);
                simplex[n] = if fe < fr { (xe, fe) } else { (xr, fr) };
            } else if fr < f_second_worst {
                simplex[n] = (xr, fr);
            } else {
                // contract toward the better of the reflected/worst point
                let xc = if fr < fw { along(cfg.alpha * cfg.rho) } else { along(-cfg.rho) };
                let Some(fc) = broker.try_eval(&xc) else { break };
                best.seen(&xc, fc);
                if fc < fr.min(fw) {
                    simplex[n] = (xc, fc);
                } else {
                    // shrink every non-best vertex toward the best — the
                    // n new vertices are independent probes: one batch
                    let targets: Vec<Vec<f64>> = simplex[1..]
                        .iter()
                        .map(|(v, _)| {
                            let mut s: Vec<f64> = simplex[0]
                                .0
                                .iter()
                                .zip(v)
                                .map(|(b, x)| b + cfg.sigma * (x - b))
                                .collect();
                            clamp_unit(&mut s);
                            s
                        })
                        .collect();
                    let fs = broker.try_eval_batch(&targets);
                    let truncated = fs.len() < targets.len();
                    for (i, (t, f)) in targets.into_iter().zip(fs).enumerate() {
                        best.seen(&t, f);
                        simplex[i + 1] = (t, f);
                    }
                    if truncated {
                        break; // mid-shrink exhaustion: keep best-so-far
                    }
                }
            }
        }

        TuneOutcome {
            best_theta: best.theta,
            best_f: best.f,
            history: Vec::new(),
            model_evals: 0,
            profiling_overhead_s: 0.0,
            noise_frozen: false,
        }
    }

    fn checkpointable(&self) -> bool {
        true
    }

    fn tune_resumable(
        &self,
        broker: &mut EvalBroker,
        space: &ParameterSpace,
        _seed: u64,
        resume: Option<&[u8]>,
    ) -> (TuneOutcome, Option<Vec<u8>>) {
        let st = match resume {
            Some(bytes) => {
                let js = decode_checkpoint(self.name(), bytes)
                    .unwrap_or_else(|e| panic!("{}: bad checkpoint: {e}", self.name()));
                NmState::from_json(&js)
                    .unwrap_or_else(|e| panic!("{}: bad checkpoint state: {e}", self.name()))
            }
            None => NmState::fresh(space.default_theta()),
        };
        let (st, done) = self.run_resumable(broker, space, st);
        let out = TuneOutcome {
            best_theta: st.best_theta.clone(),
            best_f: st.best_f,
            history: Vec::new(),
            model_evals: 0,
            profiling_overhead_s: 0.0,
            noise_frozen: false,
        };
        let ck = if done { None } else { Some(encode_checkpoint(self.name(), st.to_json())) };
        (out, ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::broker::{Budget, CachePolicy, EvalBroker};
    use crate::tuner::objective::{Objective, QuadraticObjective, SimObjective};

    #[test]
    fn descends_noise_free_quadratic() {
        let space = ParameterSpace::v1();
        let target: Vec<f64> = (0..space.dim()).map(|i| 0.3 + 0.04 * i as f64).collect();
        let mut obj = QuadraticObjective::new(target.clone(), 0.0, 1);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(600));
        let out = NelderMeadTuner::new().tune(&mut broker, &space, 1);
        assert!(out.best_f < 1.15, "best f {} (noise-free minimum 1.0)", out.best_f);
        let err: f64 = out
            .best_theta
            .iter()
            .zip(&target)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / target.len() as f64;
        assert!(err < 0.15, "mean abs error {err}: {:?}", out.best_theta);
    }

    #[test]
    fn budget_truncation_is_graceful_even_mid_simplex() {
        let space = ParameterSpace::v1();
        // budget smaller than the n+1 initial simplex (12 points for v1)
        let mut obj = QuadraticObjective::new(vec![0.5; space.dim()], 0.02, 3);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(5));
        let out = NelderMeadTuner::new().tune(&mut broker, &space, 3);
        assert_eq!(broker.evals_used(), 5, "must spend exactly the affordable prefix");
        assert!(out.best_f.is_finite(), "partial result must carry best-so-far");
        assert_eq!(out.best_theta.len(), space.dim());
    }

    #[test]
    fn never_overspends_and_tracks_broker_best() {
        let space = ParameterSpace::v1();
        for budget in [13, 25, 60] {
            let mut obj = QuadraticObjective::new(vec![0.4; space.dim()], 0.05, 7);
            let mut broker = EvalBroker::new(&mut obj, Budget::obs(budget));
            let out = NelderMeadTuner::new().tune(&mut broker, &space, 7);
            assert!(broker.evals_used() <= budget);
            let (_, bf) = broker.best().expect("at least one observation");
            assert_eq!(out.best_f, bf, "tuner best must equal broker best (budget {budget})");
        }
    }

    #[test]
    fn shrink_batches_reproduce_sequential_values_at_any_worker_count() {
        // The whole search (init batch + shrink batches included) must
        // trace identically through a parallel SimObjective.
        use crate::cluster::ClusterSpec;
        use crate::workloads::Benchmark;
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = crate::util::rng::Rng::seeded(21);
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let run_with = |workers: usize| {
            let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 23)
                .with_workers(workers);
            let mut broker =
                EvalBroker::new(&mut obj, Budget::obs(50)).with_cache(CachePolicy::Quantized);
            let out = NelderMeadTuner::new().tune(&mut broker, &space, 23);
            (out.best_theta, out.best_f, broker.evals_used())
        };
        assert_eq!(run_with(1), run_with(4));
    }

    #[test]
    fn resumable_split_matches_straight_run_at_any_cut() {
        // Checkpoint/resume at assorted budgets — including cuts that land
        // mid-iteration (after the reflect, before the expand/contract) —
        // must reproduce the straight run bit for bit, spending only the
        // incremental observations and charging prior waves exactly once.
        use crate::cluster::ClusterSpec;
        use crate::workloads::Benchmark;
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = crate::util::rng::Rng::seeded(31);
        let w = Benchmark::Wordcount.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let tuner = NelderMeadTuner::new();
        const FULL: u64 = 60;

        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 41);
        let mut broker =
            EvalBroker::new(&mut obj, Budget::obs(FULL)).with_cache(CachePolicy::Off);
        let (full, _ck) = tuner.tune_resumable(&mut broker, &space, 41, None);
        let full_evals = broker.evals_used();
        let full_elapsed = broker.elapsed_model_time();

        for cut in [13u64, 20, 25, 31] {
            let mut obj_a = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 41);
            let mut broker_a =
                EvalBroker::new(&mut obj_a, Budget::obs(cut)).with_cache(CachePolicy::Off);
            let (_seg1, ck1) = tuner.tune_resumable(&mut broker_a, &space, 41, None);
            let ck1 = ck1.expect("cut {cut} exhausts the budget mid-search");
            let (obs1, batches1, elapsed1) =
                (broker_a.evals_used(), broker_a.batches_used(), broker_a.elapsed_model_time());
            assert!(obs1 <= cut, "whole-step guards never overspend");

            // round-trip the checkpoint through its JSON text form
            let js = crate::tuner::registry::decode_checkpoint("nelder-mead", &ck1).unwrap();
            let reencoded =
                crate::tuner::registry::encode_checkpoint("nelder-mead", js);

            let mut obj_b = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 41);
            assert!(obj_b.advance_evals(obs1));
            let mut broker_b = EvalBroker::new(&mut obj_b, Budget::obs(FULL))
                .with_cache(CachePolicy::Off)
                .with_prior_spend(obs1, batches1, elapsed1);
            let (seg2, _ck2) = tuner.tune_resumable(&mut broker_b, &space, 41, Some(&reencoded));

            assert_eq!(seg2.best_theta, full.best_theta, "cut {cut}");
            assert_eq!(seg2.best_f, full.best_f, "cut {cut}");
            assert_eq!(broker_b.evals_used(), full_evals, "cut {cut}");
            // evals_used == full_evals with prior_spend(obs1) preloaded
            // means segment 2 issued exactly full_evals − obs1 live
            // observations: O(increment), no prefix replay.
            assert_eq!(
                broker_b.elapsed_model_time(),
                full_elapsed,
                "cut {cut}: prior waves charged once, not replayed"
            );
        }
    }

    #[test]
    fn nm_state_json_round_trips_every_phase() {
        let simplex = vec![(vec![0.1, 0.2], 3.5), (vec![0.3, 0.4], 4.5), (vec![0.5, 0.6], 5.5)];
        for phase in [
            NmPhase::Start,
            NmPhase::Expand { xr: vec![0.7, 0.8], fr: 2.25 },
            NmPhase::Contract { fr: 6.125 },
            NmPhase::Shrink,
        ] {
            let st = NmState {
                simplex: simplex.clone(),
                iters: 9,
                best_theta: vec![0.1, 0.2],
                best_f: 3.5,
                phase,
            };
            let text = st.to_json().to_string();
            let back = NmState::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, st);
        }
        // the virgin state's infinite best_f survives the null spelling
        let virgin = NmState::fresh(vec![0.5; 3]);
        let back =
            NmState::from_json(&Json::parse(&virgin.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, virgin);
    }

    #[test]
    fn iterate_cap_stops_unlimited_brokers() {
        let space = ParameterSpace::v1();
        let mut obj = QuadraticObjective::new(vec![0.5; space.dim()], 0.1, 11);
        let mut broker = EvalBroker::new(&mut obj, Budget::unlimited());
        let tuner = NelderMeadTuner {
            config: NelderMeadConfig { max_iters: 40, tol: 0.0, ..Default::default() },
        };
        let out = tuner.tune(&mut broker, &space, 11);
        assert!(out.best_f.is_finite());
        // init (n+1) + ≤ 2 evals/iter + occasional n-point shrinks
        assert!(obj.evals() < 40 * (space.dim() as u64 + 2) + 20, "{} evals", obj.evals());
    }
}
