//! Nelder–Mead downhill simplex over the normalized parameter space — a
//! classic derivative-free baseline for the registry: where SPSA spends a
//! dimension-independent 2–3 observations per iteration on a gradient
//! *estimate*, the simplex pays n+1 observations just to get started and
//! then 1–2 per reflect/expand/contract step, with an n-observation bill
//! for every shrink.
//!
//! Broker integration:
//! * the n+1-vertex initial simplex and every shrink step are dispatched
//!   as ONE `try_eval_batch` call, so the independent probes fan across
//!   the worker pool (and the values stay bit-identical to a sequential
//!   loop at any worker count — the broker's ordered-dispatch contract);
//! * the search is budget-truncation-safe: any `None`/short batch from
//!   the broker is a graceful stop and the best vertex *observed so far*
//!   (not the best simplex vertex) is returned;
//! * iterates are projected onto [0,1]^n by coordinate clamping — the
//!   same Γ every other tuner uses.
//!
//! The method is deterministic given θ₀ (no RNG), so the registry seed
//! only reaches the objective's noise, never the search itself.

use crate::config::ParameterSpace;

use super::broker::EvalBroker;
use super::registry::{TuneOutcome, Tuner};

/// Standard Nelder–Mead coefficients plus the simplex construction step.
#[derive(Clone, Debug)]
pub struct NelderMeadConfig {
    /// Initial simplex edge per coordinate (algorithm space).
    pub step: f64,
    /// Reflection coefficient (> 0).
    pub alpha: f64,
    /// Expansion coefficient (> 1).
    pub gamma: f64,
    /// Contraction coefficient (0 < rho ≤ 0.5).
    pub rho: f64,
    /// Shrink coefficient (0 < sigma < 1).
    pub sigma: f64,
    /// Stop when the simplex f-spread falls below this relative tolerance.
    pub tol: f64,
    /// Iteration cap for unlimited brokers (a budgeted broker stops the
    /// loop by exhaustion first).
    pub max_iters: u64,
}

impl Default for NelderMeadConfig {
    fn default() -> Self {
        NelderMeadConfig {
            step: 0.15,
            alpha: 1.0,
            gamma: 2.0,
            rho: 0.5,
            sigma: 0.5,
            tol: 1e-4,
            max_iters: 200,
        }
    }
}

/// Nelder–Mead behind the [`Tuner`] interface.
pub struct NelderMeadTuner {
    pub config: NelderMeadConfig,
}

impl NelderMeadTuner {
    pub fn new() -> NelderMeadTuner {
        NelderMeadTuner { config: NelderMeadConfig::default() }
    }
}

impl Default for NelderMeadTuner {
    fn default() -> Self {
        Self::new()
    }
}

fn clamp_unit(theta: &mut [f64]) {
    for t in theta.iter_mut() {
        *t = t.clamp(0.0, 1.0);
    }
}

/// Best-so-far tracker over every (θ, f) the search observes.
struct Best {
    theta: Vec<f64>,
    f: f64,
}

impl Best {
    fn seen(&mut self, theta: &[f64], f: f64) {
        if f < self.f {
            self.f = f;
            self.theta = theta.to_vec();
        }
    }
}

impl Tuner for NelderMeadTuner {
    fn name(&self) -> &'static str {
        "nelder-mead"
    }

    // default cache policy (Quantized): a contracting simplex revisits
    // quantized cells near its optimum — those replays are free

    fn tune(&self, broker: &mut EvalBroker, space: &ParameterSpace, _seed: u64) -> TuneOutcome {
        let cfg = &self.config;
        let n = space.dim();
        let x0 = space.default_theta();
        let mut best = Best { theta: x0.clone(), f: f64::INFINITY };

        // Initial simplex: θ₀ plus one vertex per coordinate, stepped away
        // from the nearer box wall so every vertex is distinct — one batch.
        let mut points: Vec<Vec<f64>> = Vec::with_capacity(n + 1);
        points.push(x0.clone());
        for i in 0..n {
            let mut v = x0.clone();
            v[i] = if v[i] + cfg.step <= 1.0 { v[i] + cfg.step } else { v[i] - cfg.step };
            clamp_unit(&mut v);
            points.push(v);
        }
        let fs = broker.try_eval_batch(&points);
        for (p, &f) in points.iter().zip(&fs) {
            best.seen(p, f);
        }
        if fs.len() < points.len() {
            // budget could not even afford the initial simplex
            return TuneOutcome {
                best_theta: best.theta,
                best_f: best.f,
                history: Vec::new(),
                model_evals: 0,
                profiling_overhead_s: 0.0,
            };
        }
        let mut simplex: Vec<(Vec<f64>, f64)> = points.into_iter().zip(fs).collect();

        let mut iters = 0;
        while iters < cfg.max_iters && !broker.exhausted() {
            iters += 1;
            // order best → worst (stable: ties keep insertion order)
            // total_cmp: a NaN vertex sorts worst and gets replaced first
            // (Equal-on-NaN left it stuck wherever it sat)
            simplex.sort_by(|a, b| a.1.total_cmp(&b.1));
            let (fb, fw) = (simplex[0].1, simplex[n].1);
            if fw - fb <= cfg.tol * fb.abs().max(1e-9) {
                break; // simplex collapsed onto (noise around) one value
            }
            let f_second_worst = simplex[n - 1].1;

            // centroid of all vertices but the worst
            let mut centroid = vec![0.0; n];
            for (v, _) in &simplex[..n] {
                for (c, x) in centroid.iter_mut().zip(v) {
                    *c += x / n as f64;
                }
            }
            let along = |coef: f64| -> Vec<f64> {
                let mut v: Vec<f64> = centroid
                    .iter()
                    .zip(&simplex[n].0)
                    .map(|(c, w)| c + coef * (c - w))
                    .collect();
                clamp_unit(&mut v);
                v
            };

            // reflect
            let xr = along(cfg.alpha);
            let Some(fr) = broker.try_eval(&xr) else { break };
            best.seen(&xr, fr);

            if fr < fb {
                // expand
                let xe = along(cfg.alpha * cfg.gamma);
                let Some(fe) = broker.try_eval(&xe) else {
                    simplex[n] = (xr, fr);
                    break;
                };
                best.seen(&xe, fe);
                simplex[n] = if fe < fr { (xe, fe) } else { (xr, fr) };
            } else if fr < f_second_worst {
                simplex[n] = (xr, fr);
            } else {
                // contract toward the better of the reflected/worst point
                let xc = if fr < fw { along(cfg.alpha * cfg.rho) } else { along(-cfg.rho) };
                let Some(fc) = broker.try_eval(&xc) else { break };
                best.seen(&xc, fc);
                if fc < fr.min(fw) {
                    simplex[n] = (xc, fc);
                } else {
                    // shrink every non-best vertex toward the best — the
                    // n new vertices are independent probes: one batch
                    let targets: Vec<Vec<f64>> = simplex[1..]
                        .iter()
                        .map(|(v, _)| {
                            let mut s: Vec<f64> = simplex[0]
                                .0
                                .iter()
                                .zip(v)
                                .map(|(b, x)| b + cfg.sigma * (x - b))
                                .collect();
                            clamp_unit(&mut s);
                            s
                        })
                        .collect();
                    let fs = broker.try_eval_batch(&targets);
                    let truncated = fs.len() < targets.len();
                    for (i, (t, f)) in targets.into_iter().zip(fs).enumerate() {
                        best.seen(&t, f);
                        simplex[i + 1] = (t, f);
                    }
                    if truncated {
                        break; // mid-shrink exhaustion: keep best-so-far
                    }
                }
            }
        }

        TuneOutcome {
            best_theta: best.theta,
            best_f: best.f,
            history: Vec::new(),
            model_evals: 0,
            profiling_overhead_s: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::broker::{Budget, CachePolicy, EvalBroker};
    use crate::tuner::objective::{Objective, QuadraticObjective, SimObjective};

    #[test]
    fn descends_noise_free_quadratic() {
        let space = ParameterSpace::v1();
        let target: Vec<f64> = (0..space.dim()).map(|i| 0.3 + 0.04 * i as f64).collect();
        let mut obj = QuadraticObjective::new(target.clone(), 0.0, 1);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(600));
        let out = NelderMeadTuner::new().tune(&mut broker, &space, 1);
        assert!(out.best_f < 1.15, "best f {} (noise-free minimum 1.0)", out.best_f);
        let err: f64 = out
            .best_theta
            .iter()
            .zip(&target)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / target.len() as f64;
        assert!(err < 0.15, "mean abs error {err}: {:?}", out.best_theta);
    }

    #[test]
    fn budget_truncation_is_graceful_even_mid_simplex() {
        let space = ParameterSpace::v1();
        // budget smaller than the n+1 initial simplex (12 points for v1)
        let mut obj = QuadraticObjective::new(vec![0.5; space.dim()], 0.02, 3);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(5));
        let out = NelderMeadTuner::new().tune(&mut broker, &space, 3);
        assert_eq!(broker.evals_used(), 5, "must spend exactly the affordable prefix");
        assert!(out.best_f.is_finite(), "partial result must carry best-so-far");
        assert_eq!(out.best_theta.len(), space.dim());
    }

    #[test]
    fn never_overspends_and_tracks_broker_best() {
        let space = ParameterSpace::v1();
        for budget in [13, 25, 60] {
            let mut obj = QuadraticObjective::new(vec![0.4; space.dim()], 0.05, 7);
            let mut broker = EvalBroker::new(&mut obj, Budget::obs(budget));
            let out = NelderMeadTuner::new().tune(&mut broker, &space, 7);
            assert!(broker.evals_used() <= budget);
            let (_, bf) = broker.best().expect("at least one observation");
            assert_eq!(out.best_f, bf, "tuner best must equal broker best (budget {budget})");
        }
    }

    #[test]
    fn shrink_batches_reproduce_sequential_values_at_any_worker_count() {
        // The whole search (init batch + shrink batches included) must
        // trace identically through a parallel SimObjective.
        use crate::cluster::ClusterSpec;
        use crate::workloads::Benchmark;
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = crate::util::rng::Rng::seeded(21);
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let run_with = |workers: usize| {
            let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 23)
                .with_workers(workers);
            let mut broker =
                EvalBroker::new(&mut obj, Budget::obs(50)).with_cache(CachePolicy::Quantized);
            let out = NelderMeadTuner::new().tune(&mut broker, &space, 23);
            (out.best_theta, out.best_f, broker.evals_used())
        };
        assert_eq!(run_with(1), run_with(4));
    }

    #[test]
    fn iterate_cap_stops_unlimited_brokers() {
        let space = ParameterSpace::v1();
        let mut obj = QuadraticObjective::new(vec![0.5; space.dim()], 0.1, 11);
        let mut broker = EvalBroker::new(&mut obj, Budget::unlimited());
        let tuner = NelderMeadTuner {
            config: NelderMeadConfig { max_iters: 40, tol: 0.0, ..Default::default() },
        };
        let out = tuner.tune(&mut broker, &space, 11);
        assert!(out.best_f.is_finite());
        // init (n+1) + ≤ 2 evals/iter + occasional n-point shrinks
        assert!(obj.evals() < 40 * (space.dim() as u64 + 2) + 20, "{} evals", obj.evals());
    }
}
