//! TPE-style Bayesian optimization (Bergstra et al., NIPS'11 — the
//! estimator family behind AutoTune/Tuneful-class Spark/Hadoop tuners):
//! instead of modelling f(θ) directly, model *where good configurations
//! live*. The observation history splits at the γ-quantile into a "good"
//! set L and a "bad" set G; per-coordinate Parzen (kernel-density)
//! estimators l(θ) and g(θ) are fitted over the two sets; candidates are
//! sampled from l and ranked by the density ratio l(θ)/g(θ) — the
//! expected-improvement-optimal acquisition under the TPE factorization.
//!
//! Everything runs on the repo's own substrate — `util::rng` gaussians,
//! no external crates — and the history IS the broker's
//! [`EvalRecord`](super::broker::EvalRecord) trace: the model consumes
//! exactly what the budget meter recorded, so cache replays and every
//! live probe feed the density split for free.
//!
//! Broker integration:
//! * each round proposes a *batch* of candidates not yet observed
//!   (deduplicated against the trace at the broker's cache quantum) and
//!   dispatches them through one `try_eval_batch` — independent probes
//!   fan across the worker pool, values bit-identical at any worker
//!   count;
//! * proposals are capped to `remaining()`, so exhaustion truncates
//!   between rounds and the best observed θ is returned (graceful stop);
//! * the first observation is always the default configuration (the
//!   anchor every other tuner starts from), then uniform startup draws
//!   until the split has enough mass.

use crate::config::ParameterSpace;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::broker::EvalBroker;
use super::registry::{decode_checkpoint, encode_checkpoint, TuneOutcome, Tuner};

/// TPE hyper-parameters.
#[derive(Clone, Debug)]
pub struct TpeConfig {
    /// Fraction of the history forming the "good" quantile L (0 < γ < 1).
    pub gamma: f64,
    /// Uniform-random observations before the density model kicks in
    /// (counting the default-θ anchor).
    pub n_startup: u64,
    /// Candidates sampled from l(θ) and scored per proposal round.
    pub n_candidates: usize,
    /// Highest-ranked uncached candidates evaluated per round (one
    /// `try_eval_batch` dispatch ≈ one parallel wave).
    pub batch: usize,
    /// Kernel bandwidth floor in normalized coordinates (keeps the
    /// estimator exploratory once the good set concentrates).
    pub bandwidth_floor: f64,
    /// Proposal-round cap for unlimited brokers (a budgeted broker stops
    /// the loop by exhaustion first).
    pub max_rounds: u64,
}

impl Default for TpeConfig {
    fn default() -> Self {
        TpeConfig {
            gamma: 0.25,
            n_startup: 10,
            n_candidates: 24,
            batch: 8,
            bandwidth_floor: 0.03,
            max_rounds: 64,
        }
    }
}

/// TPE behind the [`Tuner`] interface.
pub struct TpeTuner {
    pub config: TpeConfig,
}

impl TpeTuner {
    pub fn new() -> TpeTuner {
        TpeTuner { config: TpeConfig::default() }
    }
}

impl Default for TpeTuner {
    fn default() -> Self {
        Self::new()
    }
}

/// One-dimensional Parzen estimator: a uniform prior on [0,1] mixed with
/// a truncation-unnormalized gaussian kernel per observation. Both l and
/// g share the truncation bias, so the *ratio* stays a useful ranking.
struct Parzen1d {
    centers: Vec<f64>,
    sigma: f64,
}

impl Parzen1d {
    /// Fit over the given coordinate values with a Scott-style bandwidth
    /// (std · m^(−1/5)), floored so a collapsed set keeps exploring.
    fn fit(values: Vec<f64>, floor: f64) -> Parzen1d {
        let m = values.len().max(1) as f64;
        let mean = values.iter().sum::<f64>() / m;
        let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / m;
        let sigma = (var.sqrt() * m.powf(-0.2)).max(floor);
        Parzen1d { centers: values, sigma }
    }

    /// Density at x: (uniform prior + Σ kernels) / (m + 1).
    fn density(&self, x: f64) -> f64 {
        let norm = 1.0 / (self.sigma * (2.0 * std::f64::consts::PI).sqrt());
        let mut acc = 1.0; // the uniform prior's density on [0,1]
        for c in &self.centers {
            let z = (x - c) / self.sigma;
            acc += norm * (-0.5 * z * z).exp();
        }
        acc / (self.centers.len() as f64 + 1.0)
    }

    /// Sample: pick the prior or one kernel uniformly, then draw from it.
    fn sample(&self, rng: &mut Rng) -> f64 {
        let k = rng.below(self.centers.len() as u64 + 1) as usize;
        if k == self.centers.len() {
            rng.f64()
        } else {
            (self.centers[k] + self.sigma * rng.gaussian()).clamp(0.0, 1.0)
        }
    }
}

/// Quantize θ for duplicate detection (the broker's cache quantum).
fn quant_key(theta: &[f64], quantum: f64) -> Vec<i64> {
    theta.iter().map(|t| (t / quantum).round() as i64).collect()
}

/// Serializable TPE resume state. TPE's model is its observation history,
/// and a resumed broker's trace only covers the new segment — so the
/// checkpoint carries the full (θ, f) prefix in observation order; a
/// resumed run models over `observed ++ trace`, which is exactly the
/// straight run's trace at the same point.
#[derive(Clone, Debug, PartialEq)]
pub struct TpeState {
    /// Next proposal round to run (round RNGs are keyed by this index, so
    /// the pending round replays identically after a resume).
    round: u64,
    /// Every (θ, f) observed by prior segments, in observation order.
    observed: Vec<(Vec<f64>, f64)>,
    best_theta: Vec<f64>,
    best_f: f64,
}

impl TpeState {
    fn fresh(theta0: Vec<f64>) -> TpeState {
        TpeState { round: 0, observed: Vec::new(), best_theta: theta0, best_f: f64::INFINITY }
    }

    fn f_to_json(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null // the virgin state's +inf best_f
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("round", Json::Num(self.round as f64))
            .set(
                "observed",
                Json::Arr(
                    self.observed
                        .iter()
                        .map(|(t, f)| {
                            Json::obj()
                                .set("theta", Json::from_f64_slice(t))
                                .set("f", Self::f_to_json(*f))
                        })
                        .collect(),
                ),
            )
            .set("best_theta", Json::from_f64_slice(&self.best_theta))
            .set("best_f", Self::f_to_json(self.best_f))
    }

    pub fn from_json(js: &Json) -> Result<TpeState, String> {
        let round = js.get("round").and_then(|v| v.as_f64()).ok_or("missing round")? as u64;
        let observed = js
            .get("observed")
            .and_then(|v| v.as_arr())
            .ok_or("missing observed")?
            .iter()
            .map(|entry| {
                let theta = entry
                    .get("theta")
                    .and_then(|v| v.to_f64_vec())
                    .ok_or("observation missing theta")?;
                let f = entry.get("f").and_then(|v| v.as_f64()).unwrap_or(f64::INFINITY);
                Ok((theta, f))
            })
            .collect::<Result<Vec<_>, String>>()?;
        let best_theta =
            js.get("best_theta").and_then(|v| v.to_f64_vec()).ok_or("missing best_theta")?;
        let best_f = js.get("best_f").and_then(|v| v.as_f64()).unwrap_or(f64::INFINITY);
        Ok(TpeState { round, observed, best_theta, best_f })
    }
}

impl Tuner for TpeTuner {
    fn name(&self) -> &'static str {
        "tpe"
    }

    // default cache policy (Quantized): the model can re-propose a near
    // -duplicate under noise — the broker replays it for free

    fn tune(&self, broker: &mut EvalBroker, space: &ParameterSpace, seed: u64) -> TuneOutcome {
        let cfg = &self.config;
        let n = space.dim();
        // dedupe at the broker's own cache quantum, so "already observed"
        // here and "memo hit" there agree cell-for-cell
        let quantum = broker.quantization();
        let mut best_theta = space.default_theta();
        let mut best_f = f64::INFINITY;

        for round in 0..cfg.max_rounds {
            if broker.exhausted() {
                break;
            }
            // per-round RNG keyed like SPSA's per-iteration streams:
            // deterministic regardless of worker count or cache replays
            let mut rng = Rng::seeded(seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x7BE5);

            let trace = broker.trace();
            let observed: Vec<(Vec<f64>, f64)> =
                trace.iter().map(|r| (r.theta.clone(), r.f)).collect();
            let mut seen: std::collections::BTreeSet<Vec<i64>> =
                observed.iter().map(|(t, _)| quant_key(t, quantum)).collect();

            // the quantile split needs at least one point on each side, so
            // the model never engages before two observations exist
            let proposals: Vec<Vec<f64>> = if (observed.len() as u64) < cfg.n_startup.max(2) {
                // startup: the default-θ anchor first, then uniform draws
                let want = (cfg.n_startup.max(2) - observed.len() as u64).min(broker.remaining());
                let mut pts = Vec::with_capacity(want as usize);
                if observed.is_empty() && want > 0 {
                    pts.push(space.default_theta());
                }
                while (pts.len() as u64) < want {
                    pts.push((0..n).map(|_| rng.f64()).collect());
                }
                pts
            } else {
                // good/bad quantile split over the broker trace
                let mut sorted = observed;
                // total_cmp: NaN observations sort to the bad tail instead
                // of comparing Equal and drifting into the good split
                sorted.sort_by(|a, b| a.1.total_cmp(&b.1));
                let n_good = ((cfg.gamma * sorted.len() as f64).ceil() as usize)
                    .clamp(1, sorted.len() - 1);
                let (good, bad) = sorted.split_at(n_good);

                // per-coordinate density estimators for L (good) and G (bad)
                let fit = |set: &[(Vec<f64>, f64)]| -> Vec<Parzen1d> {
                    (0..n)
                        .map(|d| {
                            Parzen1d::fit(
                                set.iter().map(|(t, _)| t[d]).collect(),
                                cfg.bandwidth_floor,
                            )
                        })
                        .collect()
                };
                let l = fit(good);
                let g = fit(bad);

                // sample candidates from l, rank by Σ_d log l_d − log g_d
                let mut scored: Vec<(f64, Vec<f64>)> = (0..cfg.n_candidates)
                    .map(|_| {
                        let cand: Vec<f64> = l.iter().map(|p| p.sample(&mut rng)).collect();
                        let score: f64 = cand
                            .iter()
                            .enumerate()
                            .map(|(d, &x)| {
                                l[d].density(x).max(1e-300).ln()
                                    - g[d].density(x).max(1e-300).ln()
                            })
                            .sum();
                        (score, cand)
                    })
                    .collect();
                // stable sort: ties keep draw order → deterministic
                scored.sort_by(|a, b| b.0.total_cmp(&a.0));

                // batch-propose the top *uncached* candidates
                let cap = (cfg.batch as u64).min(broker.remaining()) as usize;
                let mut pts = Vec::with_capacity(cap);
                for (_, cand) in scored {
                    if pts.len() >= cap {
                        break;
                    }
                    if seen.insert(quant_key(&cand, quantum)) {
                        pts.push(cand);
                    }
                }
                pts
            };

            if proposals.is_empty() {
                break; // every candidate already observed: model has converged
            }
            let fs = broker.try_eval_batch(&proposals);
            for (t, &f) in proposals.iter().zip(&fs) {
                if f < best_f {
                    best_f = f;
                    best_theta = t.clone();
                }
            }
            if fs.len() < proposals.len() {
                break; // budget exhausted mid-batch: keep best-so-far
            }
        }

        TuneOutcome {
            best_theta,
            best_f,
            history: Vec::new(),
            model_evals: 0,
            profiling_overhead_s: 0.0,
            noise_frozen: false,
        }
    }

    fn checkpointable(&self) -> bool {
        true
    }

    fn tune_resumable(
        &self,
        broker: &mut EvalBroker,
        space: &ParameterSpace,
        seed: u64,
        resume: Option<&[u8]>,
    ) -> (TuneOutcome, Option<Vec<u8>>) {
        let st = match resume {
            Some(bytes) => {
                let js = decode_checkpoint(self.name(), bytes)
                    .unwrap_or_else(|e| panic!("{}: bad checkpoint: {e}", self.name()));
                TpeState::from_json(&js)
                    .unwrap_or_else(|e| panic!("{}: bad checkpoint state: {e}", self.name()))
            }
            None => TpeState::fresh(space.default_theta()),
        };
        let (st, done) = self.run_resumable(broker, space, seed, st);
        let out = TuneOutcome {
            best_theta: st.best_theta.clone(),
            best_f: st.best_f,
            history: Vec::new(),
            model_evals: 0,
            profiling_overhead_s: 0.0,
            noise_frozen: false,
        };
        let ck = if done { None } else { Some(encode_checkpoint(self.name(), st.to_json())) };
        (out, ck)
    }
}

impl TpeTuner {
    /// Checkpoint-grade proposal loop: the same model and per-round RNG
    /// streams as `tune`, but rounds are all-or-nothing — a round whose
    /// proposal batch exceeds `remaining()` checkpoints BEFORE dispatching
    /// (round index pending), so a resume recomputes that round from the
    /// identical history and identical round-keyed RNG and dispatches the
    /// identical batch. Split runs therefore share the straight run's
    /// dispatch sequence, wave grid, and modeled time bit for bit.
    /// Convergence (no fresh candidates) and the round cap are terminal.
    fn run_resumable(
        &self,
        broker: &mut EvalBroker,
        space: &ParameterSpace,
        seed: u64,
        mut st: TpeState,
    ) -> (TpeState, bool) {
        let cfg = &self.config;
        let n = space.dim();
        let quantum = broker.quantization();

        let mut done = true;
        let mut round = st.round;
        while round < cfg.max_rounds {
            // full history: prior segments' prefix + this segment's trace
            let observed: Vec<(Vec<f64>, f64)> = st
                .observed
                .iter()
                .cloned()
                .chain(broker.trace().iter().map(|r| (r.theta.clone(), r.f)))
                .collect();
            let mut rng = Rng::seeded(seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x7BE5);
            let mut seen: std::collections::BTreeSet<Vec<i64>> =
                observed.iter().map(|(t, _)| quant_key(t, quantum)).collect();

            let proposals: Vec<Vec<f64>> = if (observed.len() as u64) < cfg.n_startup.max(2) {
                // startup round, whole (never capped to remaining())
                let want = cfg.n_startup.max(2) - observed.len() as u64;
                let mut pts = Vec::with_capacity(want as usize);
                if observed.is_empty() {
                    pts.push(space.default_theta());
                }
                while (pts.len() as u64) < want {
                    pts.push((0..n).map(|_| rng.f64()).collect());
                }
                pts
            } else {
                let mut sorted = observed;
                sorted.sort_by(|a, b| a.1.total_cmp(&b.1));
                let n_good = ((cfg.gamma * sorted.len() as f64).ceil() as usize)
                    .clamp(1, sorted.len() - 1);
                let (good, bad) = sorted.split_at(n_good);
                let fit = |set: &[(Vec<f64>, f64)]| -> Vec<Parzen1d> {
                    (0..n)
                        .map(|d| {
                            Parzen1d::fit(
                                set.iter().map(|(t, _)| t[d]).collect(),
                                cfg.bandwidth_floor,
                            )
                        })
                        .collect()
                };
                let l = fit(good);
                let g = fit(bad);
                let mut scored: Vec<(f64, Vec<f64>)> = (0..cfg.n_candidates)
                    .map(|_| {
                        let cand: Vec<f64> = l.iter().map(|p| p.sample(&mut rng)).collect();
                        let score: f64 = cand
                            .iter()
                            .enumerate()
                            .map(|(d, &x)| {
                                l[d].density(x).max(1e-300).ln()
                                    - g[d].density(x).max(1e-300).ln()
                            })
                            .sum();
                        (score, cand)
                    })
                    .collect();
                scored.sort_by(|a, b| b.0.total_cmp(&a.0));
                let mut pts = Vec::with_capacity(cfg.batch);
                for (_, cand) in scored {
                    if pts.len() >= cfg.batch {
                        break;
                    }
                    if seen.insert(quant_key(&cand, quantum)) {
                        pts.push(cand);
                    }
                }
                pts
            };

            if proposals.is_empty() {
                break; // converged: every candidate already observed
            }
            if (proposals.len() as u64) > broker.remaining() {
                done = false; // checkpoint with this round still pending
                break;
            }
            let fs = broker.try_eval_batch(&proposals);
            debug_assert_eq!(fs.len(), proposals.len(), "guarded round must serve whole");
            for (t, &f) in proposals.iter().zip(&fs) {
                if f < st.best_f {
                    st.best_f = f;
                    st.best_theta = t.clone();
                }
            }
            round += 1;
        }
        st.round = round;
        st.observed.extend(broker.trace().iter().map(|r| (r.theta.clone(), r.f)));
        (st, done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::broker::{Budget, CachePolicy, EvalBroker};
    use crate::tuner::objective::{QuadraticObjective, SimObjective};

    fn run_quad(budget: u64, seed: u64, noise: f64) -> (TuneOutcome, u64) {
        let space = ParameterSpace::v1();
        let target: Vec<f64> = (0..space.dim()).map(|i| 0.25 + 0.05 * i as f64).collect();
        let mut obj = QuadraticObjective::new(target, noise, seed);
        let mut broker =
            EvalBroker::new(&mut obj, Budget::obs(budget)).with_cache(CachePolicy::Quantized);
        let out = TpeTuner::new().tune(&mut broker, &space, seed);
        (out, broker.evals_used())
    }

    #[test]
    fn beats_its_own_random_startup() {
        // After the model kicks in, the best found must improve on the
        // best of the 10 startup observations alone.
        let (full, used_full) = run_quad(120, 5, 0.01);
        let (startup_only, _) = run_quad(10, 5, 0.01);
        assert!(used_full <= 120);
        assert!(
            full.best_f < startup_only.best_f,
            "model phase added nothing: {} vs {}",
            full.best_f,
            startup_only.best_f
        );
    }

    #[test]
    fn spends_at_most_the_budget_and_stops_gracefully() {
        for budget in [3, 10, 11, 37] {
            let (out, used) = run_quad(budget, 9, 0.05);
            assert!(used <= budget, "budget {budget}: used {used}");
            assert!(out.best_f.is_finite());
            assert_eq!(out.best_theta.len(), ParameterSpace::v1().dim());
        }
    }

    #[test]
    fn deterministic_per_seed_and_worker_count() {
        use crate::cluster::ClusterSpec;
        use crate::workloads::Benchmark;
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = crate::util::rng::Rng::seeded(31);
        let w = Benchmark::Bigram.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let run_with = |workers: usize| {
            let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 37)
                .with_workers(workers);
            let mut broker =
                EvalBroker::new(&mut obj, Budget::obs(40)).with_cache(CachePolicy::Quantized);
            let out = TpeTuner::new().tune(&mut broker, &space, 11);
            (out.best_theta, out.best_f, broker.evals_used())
        };
        assert_eq!(run_with(1), run_with(4));
    }

    #[test]
    fn model_rounds_propose_only_unobserved_candidates() {
        // With the quantized cache ON, a correct dedupe means no cache
        // hits: every dispatched proposal is a new θ cell.
        let space = ParameterSpace::v1();
        let target: Vec<f64> = (0..space.dim()).map(|_| 0.5).collect();
        let mut obj = QuadraticObjective::new(target, 0.02, 13);
        let mut broker =
            EvalBroker::new(&mut obj, Budget::obs(60)).with_cache(CachePolicy::Quantized);
        TpeTuner::new().tune(&mut broker, &space, 13);
        assert_eq!(broker.cache_hits(), 0, "TPE proposed an already-observed θ");
    }

    #[test]
    fn unlimited_broker_stops_at_the_round_cap() {
        let space = ParameterSpace::v1();
        let mut obj = QuadraticObjective::new(vec![0.5; space.dim()], 0.05, 3);
        let mut broker = EvalBroker::new(&mut obj, Budget::unlimited());
        let tuner = TpeTuner { config: TpeConfig { max_rounds: 6, ..Default::default() } };
        let out = tuner.tune(&mut broker, &space, 3);
        assert!(out.best_f.is_finite());
        // startup round (10) + ≤ 5 model rounds × batch 8
        assert!(broker.evals_used() <= 10 + 5 * 8, "{} evals", broker.evals_used());
    }

    #[test]
    fn resumable_split_matches_straight_run_at_any_cut() {
        // Cuts below the startup batch (7), exactly at it (10), and on
        // model-round boundaries (18, 26): a checkpointed split must
        // reproduce the straight run bit for bit — same best, same eval
        // count, same modeled time — spending only the increment.
        use crate::cluster::ClusterSpec;
        use crate::tuner::objective::Objective;
        use crate::workloads::Benchmark;
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = crate::util::rng::Rng::seeded(47);
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let tuner = TpeTuner::new();
        const FULL: u64 = 50;

        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 53);
        let mut broker =
            EvalBroker::new(&mut obj, Budget::obs(FULL)).with_cache(CachePolicy::Off);
        let (full, _ck) = tuner.tune_resumable(&mut broker, &space, 53, None);
        let full_evals = broker.evals_used();
        let full_elapsed = broker.elapsed_model_time();

        for cut in [7u64, 10, 18, 26] {
            let mut obj_a = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 53);
            let mut broker_a =
                EvalBroker::new(&mut obj_a, Budget::obs(cut)).with_cache(CachePolicy::Off);
            let (_seg1, ck1) = tuner.tune_resumable(&mut broker_a, &space, 53, None);
            let ck1 = ck1.expect("segment 1 must stop on budget, not converge");
            let (obs1, batches1, elapsed1) =
                (broker_a.evals_used(), broker_a.batches_used(), broker_a.elapsed_model_time());
            assert!(obs1 <= cut, "whole-round guard never overspends");

            let js = crate::tuner::registry::decode_checkpoint("tpe", &ck1).unwrap();
            let reencoded = crate::tuner::registry::encode_checkpoint("tpe", js);

            let mut obj_b = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 53);
            assert!(obj_b.advance_evals(obs1));
            let mut broker_b = EvalBroker::new(&mut obj_b, Budget::obs(FULL))
                .with_cache(CachePolicy::Off)
                .with_prior_spend(obs1, batches1, elapsed1);
            let (seg2, _ck2) = tuner.tune_resumable(&mut broker_b, &space, 53, Some(&reencoded));

            assert_eq!(seg2.best_theta, full.best_theta, "cut {cut}");
            assert_eq!(seg2.best_f, full.best_f, "cut {cut}");
            assert_eq!(broker_b.evals_used(), full_evals, "cut {cut}");
            assert_eq!(
                broker_b.elapsed_model_time(),
                full_elapsed,
                "cut {cut}: prior waves charged once, not replayed"
            );
        }
    }

    #[test]
    fn tpe_state_json_round_trips() {
        let st = TpeState {
            round: 3,
            observed: vec![(vec![0.25, 0.5], 12.5), (vec![0.75, 0.125], 11.25)],
            best_theta: vec![0.75, 0.125],
            best_f: 11.25,
        };
        let text = st.to_json().to_string();
        let back = TpeState::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, st);
        let virgin = TpeState::fresh(vec![0.5; 4]);
        let back =
            TpeState::from_json(&Json::parse(&virgin.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, virgin);
    }

    #[test]
    fn parzen_density_integrates_sanely_and_sampling_stays_in_box() {
        let p = Parzen1d::fit(vec![0.2, 0.25, 0.8], 0.03);
        // grid-integrate the density over [0,1]: the truncation bias makes
        // it < 1 but it must stay in the right ballpark
        let steps = 2000;
        let mass: f64 =
            (0..steps).map(|i| p.density((i as f64 + 0.5) / steps as f64) / steps as f64).sum();
        assert!(mass > 0.7 && mass < 1.05, "mass {mass}");
        let mut rng = Rng::seeded(7);
        for _ in 0..500 {
            let x = p.sample(&mut rng);
            assert!((0.0..=1.0).contains(&x));
        }
    }
}
