//! Simultaneous Perturbation Stochastic Approximation — the paper's
//! Algorithm 1, with the §5 Hadoop-specific adaptations:
//!
//! * θ_A ∈ [0,1]^n with the projection Γ clipping coordinates (§5.1);
//! * Bernoulli ±1 perturbations Δ (Example 2), coordinate-scaled so integer
//!   Hadoop parameters move by ≥ 1 per perturbation (§5.2);
//! * constant step size α (§5.2), two observations per iteration;
//! * optional gradient averaging over several Δs (§6.5, citing [28]) and a
//!   one-measurement variant (§6.5);
//! * pause/resume via JSON checkpoints (§6.8 point 3);
//! * termination on negligible gradient change or max iterations (§6.5).
//!
//! **Stability guard (documented deviation).** The observed objective is
//! normalized by the *current* observation (f/f(θₙ), so relative
//! sensitivity — and hence step size — is preserved as the objective drops
//! by orders of magnitude), and per-coordinate steps are clipped to
//! `max_step` per iteration. The paper's raw update
//! θ(i) − α·(f(θ+δΔ)−f(θ))/(δΔ(i)) has magnitude α·Δf·(θᴴmax−θᴴmin) which
//! for wide integer ranges (e.g. inmem.merge.threshold, width 9990) exceeds
//! the whole [0,1] box for any measurable Δf; unclipped it degenerates to
//! boundary bang-bang. The clip preserves the gradient's *sign and relative
//! magnitude* across coordinates — exactly the cross-parameter information
//! SPSA is prized for — while keeping iterates inside the region the paper's
//! own plots show (smooth descent with noise jumps, Fig. 6/7).

use crate::config::ParameterSpace;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::objective::Objective;

/// Which gradient estimator to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpsaVariant {
    /// Paper's estimator (eq. 3): (f(θ+δΔ) − f(θ)) / δΔ(i) — 2 obs/iter.
    OneSided,
    /// Classical Spall: (f(θ+δΔ) − f(θ−δΔ)) / 2δΔ(i) — 2 obs/iter.
    TwoSided,
    /// One-measurement form (§6.5): f(θ+δΔ)/δΔ(i) — 1 obs/iter, noisier.
    OneMeasurement,
    /// Random-directions SA (paper §7 future work, citing Prashanth et
    /// al. [26]): gaussian direction d, ĝ(i) = d(i)·(f(θ+cd) − f(θ))/c.
    Rdsa,
}

/// SPSA hyper-parameters.
#[derive(Clone, Debug)]
pub struct SpsaConfig {
    /// Maximum iterations (paper: convergence within 20–30).
    pub max_iters: u64,
    /// Constant step size α (paper §5.2: 0.01).
    pub alpha: f64,
    /// Per-coordinate per-iteration step clip (stability guard).
    pub max_step: f64,
    /// Gradient estimates averaged per iteration (paper §6.5; 1 = off).
    pub grad_avg: u64,
    pub variant: SpsaVariant,
    /// Stop when the relative change of the gradient-estimate norm stays
    /// below this for `patience` consecutive iterations.
    pub grad_tol: f64,
    pub patience: u64,
    /// RNG seed for the perturbation sequence.
    pub seed: u64,
}

impl Default for SpsaConfig {
    fn default() -> Self {
        SpsaConfig {
            max_iters: 30,
            alpha: 0.01,
            max_step: 0.15,
            grad_avg: 2,
            variant: SpsaVariant::OneSided,
            grad_tol: 0.02,
            patience: 4,
            seed: 7,
        }
    }
}

/// One iteration's record (feeds the Fig-6/7 convergence curves).
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub iter: u64,
    /// Observation at θ_n (un-normalized seconds).
    pub f_theta: f64,
    /// Observation at the perturbed point.
    pub f_pert: f64,
    /// ∞-norm of the (normalized) gradient estimate.
    pub grad_norm: f64,
    pub theta: Vec<f64>,
}

/// Resumable tuner state — serializable for pause/resume (paper §6.8).
#[derive(Clone, Debug)]
pub struct SpsaState {
    pub theta: Vec<f64>,
    pub iter: u64,
    /// Normalization constant: the first observation f(θ₀).
    pub f0: Option<f64>,
    pub prev_grad_norm: Option<f64>,
    pub calm_iters: u64,
    pub best_theta: Vec<f64>,
    pub best_f: f64,
    pub history: Vec<IterRecord>,
}

impl SpsaState {
    pub fn fresh(theta0: Vec<f64>) -> Self {
        SpsaState {
            best_theta: theta0.clone(),
            theta: theta0,
            iter: 0,
            f0: None,
            prev_grad_norm: None,
            calm_iters: 0,
            best_f: f64::INFINITY,
            history: Vec::new(),
        }
    }

    /// Serialize for checkpointing.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("theta", Json::from_f64_slice(&self.theta))
            .set("iter", Json::Num(self.iter as f64))
            .set(
                "f0",
                self.f0.map(Json::Num).unwrap_or(Json::Null),
            )
            .set(
                "prev_grad_norm",
                self.prev_grad_norm.map(Json::Num).unwrap_or(Json::Null),
            )
            .set("calm_iters", Json::Num(self.calm_iters as f64))
            .set("best_theta", Json::from_f64_slice(&self.best_theta))
            .set("best_f", Json::Num(self.best_f))
            .set(
                "history",
                Json::Arr(
                    self.history
                        .iter()
                        .map(|r| {
                            let mut o = Json::obj();
                            o.set("iter", Json::Num(r.iter as f64))
                                .set("f_theta", Json::Num(r.f_theta))
                                .set("f_pert", Json::Num(r.f_pert))
                                .set("grad_norm", Json::Num(r.grad_norm))
                                .set("theta", Json::from_f64_slice(&r.theta));
                            o
                        })
                        .collect(),
                ),
            );
        j
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let theta = j
            .get("theta")
            .and_then(|x| x.to_f64_vec())
            .ok_or("missing theta")?;
        let best_theta = j
            .get("best_theta")
            .and_then(|x| x.to_f64_vec())
            .ok_or("missing best_theta")?;
        let history = j
            .get("history")
            .and_then(|h| h.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|r| {
                        Some(IterRecord {
                            iter: r.get("iter")?.as_f64()? as u64,
                            f_theta: r.get("f_theta")?.as_f64()?,
                            f_pert: r.get("f_pert")?.as_f64()?,
                            grad_norm: r.get("grad_norm")?.as_f64()?,
                            theta: r.get("theta")?.to_f64_vec()?,
                        })
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(SpsaState {
            theta,
            iter: j.get("iter").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
            f0: j.get("f0").and_then(|x| x.as_f64()),
            prev_grad_norm: j.get("prev_grad_norm").and_then(|x| x.as_f64()),
            calm_iters: j.get("calm_iters").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
            best_theta,
            best_f: j.get("best_f").and_then(|x| x.as_f64()).unwrap_or(f64::INFINITY),
            history,
        })
    }
}

/// Why the run stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    MaxIters,
    GradientCalm,
    Paused,
    /// The broker's observation budget cannot afford another iteration
    /// (graceful stop with the best-so-far partial result).
    BudgetExhausted,
}

/// Result of a tuning run.
#[derive(Clone, Debug)]
pub struct TuningResult {
    /// θ_{N+1} — the paper's returned iterate.
    pub final_theta: Vec<f64>,
    /// Best observed iterate (practical deployments keep this).
    pub best_theta: Vec<f64>,
    pub best_f: f64,
    pub stop: StopReason,
    pub iterations: u64,
    pub observations: u64,
    pub history: Vec<IterRecord>,
}

/// The SPSA tuner.
pub struct Spsa {
    pub config: SpsaConfig,
    /// Per-coordinate perturbation magnitude c(i) in algorithm space.
    pub c: Vec<f64>,
}

impl Spsa {
    /// Perturbation scales for a Hadoop parameter space: the paper's
    /// δΔ(i) = 1/(max−min), clamped into [0.05, 0.25] so real-valued
    /// coordinates (width < 1) stay inside the unit box, very wide integer
    /// ranges still move ≥ 1 Hadoop unit, and narrow-impact coordinates
    /// probe far enough to rise above the run-to-run noise floor.
    pub fn scales_for(space: &ParameterSpace) -> Vec<f64> {
        space
            .params()
            .iter()
            .map(|p| (1.0 / p.width().max(1e-9)).clamp(0.05, 0.25))
            .collect()
    }

    pub fn new(config: SpsaConfig, c: Vec<f64>) -> Self {
        assert!(!c.is_empty());
        Spsa { config, c }
    }

    pub fn for_space(config: SpsaConfig, space: &ParameterSpace) -> Self {
        Self::new(config, Self::scales_for(space))
    }

    /// Live observations one iteration consumes: f(θ_n) plus the
    /// perturbation probes of every gradient-averaging round.
    pub fn obs_per_iter(&self) -> u64 {
        let rounds = self.config.grad_avg.max(1);
        match self.config.variant {
            SpsaVariant::TwoSided => 1 + 2 * rounds,
            _ => 1 + rounds,
        }
    }

    /// Run from a fresh state at θ₀.
    pub fn run(&self, objective: &mut dyn Objective, theta0: Vec<f64>) -> TuningResult {
        let state = SpsaState::fresh(theta0);
        self.run_from(objective, state, None)
    }

    /// Run against a budget-metered [`EvalBroker`](super::broker::EvalBroker):
    /// before each iteration the remaining budget is checked against
    /// [`Spsa::obs_per_iter`], so the run stops gracefully (best-so-far
    /// kept) instead of overdrawing — [`StopReason::BudgetExhausted`].
    /// One iteration at a time through `run_state` keeps the trajectory
    /// bit-identical to an uninterrupted `run` (per-iteration seeding, the
    /// pause/resume property), and the broker's pass-through batching
    /// keeps it bit-identical at any worker count.
    pub fn run_broker(
        &self,
        broker: &mut super::broker::EvalBroker,
        theta0: Vec<f64>,
    ) -> TuningResult {
        self.run_broker_from(broker, SpsaState::fresh(theta0)).0
    }

    /// [`Spsa::run_broker`] from an explicit (possibly checkpointed) state,
    /// returning the post-run state alongside the result so the caller can
    /// checkpoint it. Because the loop only ever stops at iteration
    /// boundaries and each iteration reseeds from `state.iter`, resuming
    /// the returned state against a broker carrying the prior spend (and an
    /// objective fast-forwarded past the prior observations) continues
    /// bit-identically to an uninterrupted run.
    pub fn run_broker_from(
        &self,
        broker: &mut super::broker::EvalBroker,
        mut state: SpsaState,
    ) -> (TuningResult, SpsaState) {
        let per_iter = self.obs_per_iter();
        let start_evals = broker.evals_used();
        let stop = loop {
            if state.iter >= self.config.max_iters {
                break StopReason::MaxIters;
            }
            if broker.remaining() < per_iter {
                break StopReason::BudgetExhausted;
            }
            match self.run_state(broker, &mut state, Some(1)) {
                StopReason::Paused => continue,
                other => break other,
            }
        };
        let result = TuningResult {
            final_theta: state.theta.clone(),
            best_theta: state.best_theta.clone(),
            best_f: state.best_f,
            stop,
            iterations: state.iter,
            // delta, not lifetime total: a reused broker carries prior spend
            observations: broker.evals_used() - start_evals,
            history: state.history.clone(),
        };
        (result, state)
    }

    /// Run (or resume) from an explicit state; `pause_after` optionally
    /// stops after that many *additional* iterations (pause/resume demo).
    pub fn run_from(
        &self,
        objective: &mut dyn Objective,
        mut state: SpsaState,
        pause_after: Option<u64>,
    ) -> TuningResult {
        let stop = self.run_state(objective, &mut state, pause_after);
        TuningResult {
            final_theta: state.theta.clone(),
            best_theta: state.best_theta.clone(),
            best_f: state.best_f,
            stop,
            iterations: state.iter,
            observations: objective.evals(),
            history: state.history,
        }
    }

    /// Run with pause support, returning the checkpointable state instead
    /// of a final result (used by the pause/resume example). The returned
    /// state is the loop's own — including `f0`, `prev_grad_norm` and
    /// `calm_iters`, so a resumed run keeps its termination context (a
    /// prior version hand-reassembled the state from the result and
    /// silently dropped those fields, making calm stopping fire later
    /// after a resume than in an uninterrupted run).
    pub fn run_paused(
        &self,
        objective: &mut dyn Objective,
        mut state: SpsaState,
        iters: u64,
    ) -> SpsaState {
        self.run_state(objective, &mut state, Some(iters));
        state
    }

    /// The iteration loop, advancing `state` in place — the single source
    /// of truth for `run`/`run_from`/`run_paused`. Each iteration gathers
    /// f(θ_n) plus every perturbation probe into ONE `eval_batch` call:
    /// the observations are independent simulations, so a parallel
    /// objective ([`super::objective::SimObjective`]) fans them across
    /// worker threads. Perturbations are drawn *before* dispatch and the
    /// batch contract guarantees sequential-identical values, so the
    /// trajectory is bit-for-bit the same at any worker count.
    pub fn run_state(
        &self,
        objective: &mut dyn Objective,
        state: &mut SpsaState,
        pause_after: Option<u64>,
    ) -> StopReason {
        let n = objective.dim();
        assert_eq!(self.c.len(), n, "perturbation scale dimension mismatch");
        let cfg = &self.config;
        let start_iter = state.iter;
        let rounds = cfg.grad_avg.max(1);

        while state.iter < cfg.max_iters {
            if let Some(p) = pause_after {
                if state.iter - start_iter >= p {
                    return StopReason::Paused;
                }
            }
            // Deterministic per-iteration RNG ⇒ checkpoint/resume replays
            // the same perturbation sequence.
            let mut rng = Rng::seeded(cfg.seed ^ (state.iter.wrapping_mul(0x9E37_79B9_7F4A_7C15)));

            // Draw every round's perturbation, then batch θ_n plus all
            // probe points into one objective call (2 obs/iter for the
            // paper's estimator; grad_avg rounds ride the same batch).
            let mut points: Vec<Vec<f64>> = Vec::with_capacity(1 + 2 * rounds as usize);
            points.push(state.theta.clone());
            let mut draws: Vec<(Vec<f64>, Option<Vec<f64>>)> = Vec::with_capacity(rounds as usize);
            for _ in 0..rounds {
                let signs: Vec<f64> = (0..n).map(|_| rng.rademacher()).collect();
                let plus = |deltas: &[f64], sign: f64| -> Vec<f64> {
                    state
                        .theta
                        .iter()
                        .zip(deltas)
                        .zip(&self.c)
                        .map(|((t, d), c)| (t + sign * d * c).clamp(0.0, 1.0))
                        .collect()
                };
                match cfg.variant {
                    SpsaVariant::OneSided | SpsaVariant::OneMeasurement => {
                        points.push(plus(&signs, 1.0));
                        draws.push((signs, None));
                    }
                    SpsaVariant::TwoSided => {
                        points.push(plus(&signs, 1.0));
                        points.push(plus(&signs, -1.0));
                        draws.push((signs, None));
                    }
                    SpsaVariant::Rdsa => {
                        // gaussian direction instead of Bernoulli signs
                        // (signs stay drawn so the RNG stream matches the
                        // historical per-iteration sequence)
                        let dirs: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
                        points.push(plus(&dirs, 1.0));
                        draws.push((signs, Some(dirs)));
                    }
                }
            }

            // lint:allow(unmetered-eval): registry runs reach this loop via run_broker, which passes the metered EvalBroker through the Objective facade
            let fs = objective.eval_batch(&points);
            debug_assert_eq!(fs.len(), points.len());

            // f(θ_n)
            let f_theta = fs[0];
            let f0 = *state.f0.get_or_insert(f_theta.max(1e-9));
            // Adaptive normalization: gradients are scaled by the *current*
            // observation, so the relative sensitivity (and hence step
            // size) stays constant as the objective shrinks by orders of
            // magnitude during descent. (f0 remains the one-measurement
            // variant's denominator, which has no current observation.)
            let f_norm = f_theta.max(1e-9);
            if f_theta < state.best_f {
                state.best_f = f_theta;
                state.best_theta = state.theta.clone();
            }

            // averaged gradient estimate (cfg.grad_avg independent Δs)
            let mut grad = vec![0.0; n];
            let mut f_pert_last = f_theta;
            let mut idx = 1;
            for (signs, dirs) in &draws {
                match cfg.variant {
                    SpsaVariant::OneSided => {
                        let f_pert = fs[idx];
                        idx += 1;
                        f_pert_last = f_pert;
                        let df = (f_pert - f_theta) / f_norm;
                        for i in 0..n {
                            grad[i] += df / (signs[i] * self.c[i]);
                        }
                    }
                    SpsaVariant::TwoSided => {
                        let (f_plus, f_minus) = (fs[idx], fs[idx + 1]);
                        idx += 2;
                        f_pert_last = f_plus;
                        let df = (f_plus - f_minus) / (2.0 * f_norm);
                        for i in 0..n {
                            grad[i] += df / (signs[i] * self.c[i]);
                        }
                    }
                    SpsaVariant::OneMeasurement => {
                        let f_pert = fs[idx];
                        idx += 1;
                        f_pert_last = f_pert;
                        let fv = f_pert / f0;
                        for i in 0..n {
                            grad[i] += fv / (signs[i] * self.c[i]);
                        }
                    }
                    SpsaVariant::Rdsa => {
                        let dirs = dirs.as_ref().expect("RDSA round carries directions");
                        let f_pert = fs[idx];
                        idx += 1;
                        f_pert_last = f_pert;
                        let df = (f_pert - f_theta) / f_norm;
                        for i in 0..n {
                            grad[i] += dirs[i] * df / self.c[i];
                        }
                    }
                }
            }
            let avg = rounds as f64;
            for g in grad.iter_mut() {
                *g /= avg;
            }

            // Γ(θ − α·ĝ) with the per-coordinate step clip
            for i in 0..n {
                let step = (cfg.alpha * grad[i]).clamp(-cfg.max_step, cfg.max_step);
                state.theta[i] = (state.theta[i] - step).clamp(0.0, 1.0);
            }

            let grad_norm = grad.iter().fold(0.0f64, |m, g| m.max(g.abs()));
            state.history.push(IterRecord {
                iter: state.iter,
                f_theta,
                f_pert: f_pert_last,
                grad_norm,
                theta: state.theta.clone(),
            });

            // termination: negligible change in the gradient estimate
            if let Some(prev) = state.prev_grad_norm {
                let rel = (grad_norm - prev).abs() / prev.max(1e-9);
                if rel < cfg.grad_tol {
                    state.calm_iters += 1;
                } else {
                    state.calm_iters = 0;
                }
            }
            state.prev_grad_norm = Some(grad_norm);
            state.iter += 1;

            if state.calm_iters >= cfg.patience {
                return StopReason::GradientCalm;
            }
        }
        StopReason::MaxIters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::objective::QuadraticObjective;

    fn quad_spsa(seed: u64) -> Spsa {
        Spsa::new(
            SpsaConfig {
                max_iters: 150,
                alpha: 0.05,
                max_step: 0.08,
                grad_avg: 2,
                grad_tol: 0.0, // disable calm stopping for the descent tests
                patience: u64::MAX,
                seed,
                variant: SpsaVariant::OneSided,
            },
            vec![0.05; 4],
        )
    }

    #[test]
    fn descends_noisy_quadratic() {
        let target = vec![0.25, 0.75, 0.5, 0.9];
        let mut obj = QuadraticObjective::new(target.clone(), 0.02, 3);
        let res = quad_spsa(1).run(&mut obj, vec![0.5; 4]);
        let err: f64 = res
            .final_theta
            .iter()
            .zip(&target)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / 4.0;
        assert!(err < 0.15, "mean abs error {err}, theta {:?}", res.final_theta);
    }

    #[test]
    fn two_obs_per_iteration_one_sided() {
        let mut obj = QuadraticObjective::new(vec![0.5; 4], 0.0, 1);
        let spsa = quad_spsa(2);
        let res = spsa.run(&mut obj, vec![0.2; 4]);
        // one-sided with grad_avg=2: 1 + 2 observations per iteration
        assert_eq!(res.observations, res.iterations * 3);
    }

    #[test]
    fn one_measurement_variant_uses_fewer_obs() {
        let mut cfg = quad_spsa(3).config;
        cfg.variant = SpsaVariant::OneMeasurement;
        cfg.grad_avg = 1;
        let spsa = Spsa::new(cfg, vec![0.05; 4]);
        let mut obj = QuadraticObjective::new(vec![0.5; 4], 0.0, 1);
        let res = spsa.run(&mut obj, vec![0.2; 4]);
        assert_eq!(res.observations, res.iterations * 2); // f(θ) + 1 pert
    }

    #[test]
    fn projection_keeps_unit_box() {
        let mut obj = QuadraticObjective::new(vec![0.0, 1.0, 0.0, 1.0], 0.1, 5);
        let res = quad_spsa(4).run(&mut obj, vec![0.5; 4]);
        for r in &res.history {
            assert!(r.theta.iter().all(|t| (0.0..=1.0).contains(t)));
        }
    }

    #[test]
    fn history_records_every_iteration() {
        let mut obj = QuadraticObjective::new(vec![0.5; 4], 0.01, 6);
        let res = quad_spsa(5).run(&mut obj, vec![0.1; 4]);
        assert_eq!(res.history.len() as u64, res.iterations);
        assert_eq!(res.history[0].iter, 0);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mut obj = QuadraticObjective::new(vec![0.5; 4], 0.01, 7);
        let spsa = quad_spsa(6);
        let st = spsa.run_paused(&mut obj, SpsaState::fresh(vec![0.2; 4]), 10);
        let json = st.to_json();
        let back = SpsaState::from_json(&json).unwrap();
        assert_eq!(back.theta, st.theta);
        assert_eq!(back.iter, st.iter);
        assert_eq!(back.history.len(), st.history.len());
        assert_eq!(back.best_theta, st.best_theta);
    }

    #[test]
    fn pause_resume_matches_straight_run() {
        // identical perturbation sequence per iteration index ⇒ pausing and
        // resuming yields the same trajectory as an uninterrupted run on a
        // noise-free objective.
        let spsa = Spsa::new(
            SpsaConfig {
                max_iters: 20,
                grad_tol: 0.0,
                patience: u64::MAX,
                ..quad_spsa(9).config
            },
            vec![0.05; 4],
        );
        let mut obj1 = QuadraticObjective::new(vec![0.6; 4], 0.0, 1);
        let full = spsa.run(&mut obj1, vec![0.2; 4]);

        let mut obj2 = QuadraticObjective::new(vec![0.6; 4], 0.0, 1);
        let st = spsa.run_paused(&mut obj2, SpsaState::fresh(vec![0.2; 4]), 8);
        let resumed = spsa.run_from(&mut obj2, st, None);
        for (a, b) in full.final_theta.iter().zip(&resumed.final_theta) {
            assert!((a - b).abs() < 1e-9, "{:?} vs {:?}", full.final_theta, resumed.final_theta);
        }
    }

    #[test]
    fn pause_resume_preserves_termination_context() {
        // Calm stopping ENABLED: pausing and resuming (through a JSON
        // checkpoint, like the real flow) must stop at the same iteration
        // with the same θ as an uninterrupted run. The old run_paused
        // dropped prev_grad_norm/calm_iters, stopping late after resume.
        let spsa = Spsa::new(
            SpsaConfig {
                max_iters: 500,
                grad_tol: 0.5,
                patience: 3,
                ..quad_spsa(10).config
            },
            vec![0.05; 4],
        );
        let mut obj1 = QuadraticObjective::new(vec![0.5; 4], 0.0, 2);
        let full = spsa.run(&mut obj1, vec![0.5; 4]);
        assert_eq!(full.stop, StopReason::GradientCalm);
        assert!(full.iterations > 2, "need a stop later than the pause point");

        let mut obj2 = QuadraticObjective::new(vec![0.5; 4], 0.0, 2);
        let st = spsa.run_paused(&mut obj2, SpsaState::fresh(vec![0.5; 4]), 2);
        assert_eq!(st.iter, 2);
        assert!(st.prev_grad_norm.is_some(), "checkpoint lost prev_grad_norm");
        let st = SpsaState::from_json(&st.to_json()).unwrap();
        let resumed = spsa.run_from(&mut obj2, st, None);
        assert_eq!(resumed.stop, StopReason::GradientCalm);
        assert_eq!(
            resumed.iterations, full.iterations,
            "resume lost its calm-stopping context"
        );
        assert_eq!(resumed.final_theta, full.final_theta);
    }

    #[test]
    fn run_paused_state_matches_midpoint_of_straight_run() {
        // the paused state is the loop's own state: f0 and the
        // termination fields survive, not just θ/iter/history
        let spsa = quad_spsa(12);
        let mut obj = QuadraticObjective::new(vec![0.4; 4], 0.0, 3);
        let st = spsa.run_paused(&mut obj, SpsaState::fresh(vec![0.2; 4]), 5);
        assert_eq!(st.iter, 5);
        assert_eq!(st.history.len(), 5);
        assert!(st.f0.is_some());
        assert!(st.prev_grad_norm.is_some());
        assert_eq!(
            st.prev_grad_norm.unwrap(),
            st.history.last().unwrap().grad_norm
        );
    }

    #[test]
    fn batched_objective_reproduces_sequential_trajectory() {
        // SPSA through a parallel SimObjective (per-iteration probes
        // fanned across threads) must trace exactly the trajectory of the
        // 1-worker objective on a noise-free system.
        use crate::cluster::ClusterSpec;
        use crate::tuner::objective::SimObjective;
        use crate::workloads::Benchmark;

        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = crate::util::rng::Rng::seeded(4);
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let spsa = Spsa::for_space(
            SpsaConfig { max_iters: 6, grad_avg: 4, seed: 3, ..Default::default() },
            &space,
        );

        let run_with = |workers: usize| {
            let mut obj =
                SimObjective::new(space.clone(), cluster.clone(), w.clone(), 9)
                    .noise_free()
                    .with_workers(workers);
            spsa.run(&mut obj, space.default_theta())
        };
        let seq = run_with(1);
        let par = run_with(4);
        assert_eq!(seq.iterations, par.iterations);
        assert_eq!(seq.final_theta, par.final_theta);
        for (a, b) in seq.history.iter().zip(&par.history) {
            assert_eq!(a.f_theta, b.f_theta);
            assert_eq!(a.grad_norm, b.grad_norm);
            assert_eq!(a.theta, b.theta);
        }
    }

    #[test]
    fn batched_trajectory_matches_sequential_under_scenario() {
        // The PR-1 determinism contract extends to the scenario engine:
        // with failures, a slow node and speculation all on (and noise ON —
        // scenario fates and noise are keyed per attempt, not per stream),
        // SPSA through the parallel objective traces exactly the 1-worker
        // trajectory.
        use crate::cluster::ClusterSpec;
        use crate::sim::ScenarioSpec;
        use crate::tuner::objective::SimObjective;
        use crate::workloads::Benchmark;

        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = crate::util::rng::Rng::seeded(6);
        let w = Benchmark::Terasort.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let scenario = ScenarioSpec::default()
            .with_failures(0.15)
            .with_max_attempts(10)
            .with_slow_node(1, 0.5)
            .with_speculation(true);
        let spsa = Spsa::for_space(
            SpsaConfig { max_iters: 5, grad_avg: 3, seed: 4, ..Default::default() },
            &space,
        );

        let run_with = |workers: usize| {
            let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 13)
                .with_scenario(scenario.clone())
                .with_workers(workers);
            spsa.run(&mut obj, space.default_theta())
        };
        let seq = run_with(1);
        let par = run_with(4);
        assert_eq!(seq.iterations, par.iterations);
        assert_eq!(seq.final_theta, par.final_theta);
        for (a, b) in seq.history.iter().zip(&par.history) {
            assert_eq!(a.f_theta, b.f_theta);
            assert_eq!(a.grad_norm, b.grad_norm);
            assert_eq!(a.theta, b.theta);
        }
    }

    #[test]
    fn broker_run_matches_direct_run_bit_exactly() {
        // An unlimited, cache-off broker is a transparent proxy: the SPSA
        // trajectory through it replays the direct run bit for bit (the
        // pre-refactor golden-trajectory contract).
        use crate::tuner::broker::{Budget, EvalBroker};
        let spsa = quad_spsa(21);
        let mut obj1 = QuadraticObjective::new(vec![0.3, 0.8, 0.5, 0.2], 0.02, 7);
        let direct = spsa.run(&mut obj1, vec![0.5; 4]);
        let mut obj2 = QuadraticObjective::new(vec![0.3, 0.8, 0.5, 0.2], 0.02, 7);
        let mut broker = EvalBroker::new(&mut obj2, Budget::unlimited());
        let brokered = spsa.run_broker(&mut broker, vec![0.5; 4]);
        assert_eq!(direct.iterations, brokered.iterations);
        assert_eq!(direct.final_theta, brokered.final_theta);
        assert_eq!(direct.best_f, brokered.best_f);
        assert_eq!(direct.observations, brokered.observations);
        for (a, b) in direct.history.iter().zip(&brokered.history) {
            assert_eq!(a.f_theta, b.f_theta);
            assert_eq!(a.grad_norm, b.grad_norm);
            assert_eq!(a.theta, b.theta);
        }
    }

    #[test]
    fn broker_budget_stops_gracefully_with_best_so_far() {
        use crate::tuner::broker::{Budget, EvalBroker};
        let spsa = quad_spsa(22); // grad_avg 2, one-sided → 3 obs/iter
        assert_eq!(spsa.obs_per_iter(), 3);
        let mut obj = QuadraticObjective::new(vec![0.5; 4], 0.01, 3);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(10));
        let res = spsa.run_broker(&mut broker, vec![0.1; 4]);
        assert_eq!(res.stop, StopReason::BudgetExhausted);
        assert_eq!(res.iterations, 3, "10 obs afford exactly 3 iterations of 3");
        assert_eq!(res.observations, 9);
        assert!(broker.remaining() == 1 && !broker.exhausted());
        assert!(res.best_f.is_finite(), "partial result must carry best-so-far");
        assert_eq!(res.history.len(), 3);
    }

    #[test]
    fn broker_batched_trajectory_matches_sequential() {
        // The PR-1 determinism contract survives the broker layer: SPSA
        // through a metered broker over the parallel SimObjective traces
        // exactly the 1-worker trajectory.
        use crate::cluster::ClusterSpec;
        use crate::tuner::broker::{Budget, EvalBroker};
        use crate::tuner::objective::SimObjective;
        use crate::workloads::Benchmark;

        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = crate::util::rng::Rng::seeded(8);
        let w = Benchmark::Bigram.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let spsa = Spsa::for_space(
            SpsaConfig { max_iters: 5, grad_avg: 3, seed: 6, ..Default::default() },
            &space,
        );

        let run_with = |workers: usize| {
            let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 17)
                .with_workers(workers);
            let mut broker = EvalBroker::new(&mut obj, Budget::obs(18));
            spsa.run_broker(&mut broker, space.default_theta())
        };
        let seq = run_with(1);
        let par = run_with(4);
        // 18 observations afford 4 of the 5 iterations (4 obs each)
        assert_eq!(seq.stop, StopReason::BudgetExhausted);
        assert_eq!(seq.iterations, 4);
        assert_eq!(seq.iterations, par.iterations);
        assert_eq!(seq.final_theta, par.final_theta);
        assert_eq!(seq.observations, par.observations);
        for (a, b) in seq.history.iter().zip(&par.history) {
            assert_eq!(a.f_theta, b.f_theta);
            assert_eq!(a.grad_norm, b.grad_norm);
            assert_eq!(a.theta, b.theta);
        }
    }

    #[test]
    fn broker_resume_from_checkpoint_matches_straight_run() {
        // The scheduler's rung-extension contract: run to a smaller budget,
        // checkpoint the state, then resume against a broker carrying the
        // prior spend and an objective fast-forwarded past the observations
        // already consumed — bit-identical to one uninterrupted run at the
        // larger budget, spending only the incremental observations.
        use crate::tuner::broker::{Budget, EvalBroker};
        use crate::tuner::objective::Objective;
        let spsa = quad_spsa(25); // 3 obs/iter
        let target = vec![0.3, 0.8, 0.5, 0.2];

        let mut obj_full = QuadraticObjective::new(target.clone(), 0.05, 9);
        let mut full_broker = EvalBroker::new(&mut obj_full, Budget::obs(30));
        let full = spsa.run_broker(&mut full_broker, vec![0.5; 4]);
        assert_eq!(full.iterations, 10);

        let mut obj_a = QuadraticObjective::new(target.clone(), 0.05, 9);
        let mut broker_a = EvalBroker::new(&mut obj_a, Budget::obs(12));
        let (seg1, st) = spsa.run_broker_from(&mut broker_a, SpsaState::fresh(vec![0.5; 4]));
        assert_eq!(seg1.stop, StopReason::BudgetExhausted);
        assert_eq!(seg1.iterations, 4);
        let (obs1, batches1, elapsed1) =
            (broker_a.evals_used(), broker_a.batches_used(), broker_a.elapsed_model_time());

        // JSON round-trip, like the real checkpoint channel
        let st = SpsaState::from_json(&st.to_json()).unwrap();
        let mut obj_b = QuadraticObjective::new(target, 0.05, 9);
        assert!(obj_b.advance_evals(obs1));
        let mut broker_b = EvalBroker::new(&mut obj_b, Budget::obs(30))
            .with_prior_spend(obs1, batches1, elapsed1);
        let (seg2, _) = spsa.run_broker_from(&mut broker_b, st);

        assert_eq!(seg2.iterations, full.iterations);
        assert_eq!(seg2.final_theta, full.final_theta);
        assert_eq!(seg2.best_theta, full.best_theta);
        assert_eq!(seg2.best_f.to_bits(), full.best_f.to_bits());
        assert_eq!(
            seg2.observations,
            full.observations - seg1.observations,
            "extension must spend only the increment"
        );
        assert_eq!(seg2.history.len(), full.history.len());
        for (a, b) in seg2.history.iter().zip(&full.history) {
            assert_eq!(a.f_theta.to_bits(), b.f_theta.to_bits());
            assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits());
            assert_eq!(a.theta, b.theta);
        }
        assert_eq!(broker_b.evals_used(), full_broker.evals_used());
        assert_eq!(
            broker_b.elapsed_model_time().to_bits(),
            full_broker.elapsed_model_time().to_bits(),
            "prior waves must be charged once, not replayed"
        );
    }

    #[test]
    fn rdsa_variant_descends() {
        let mut cfg = quad_spsa(11).config;
        cfg.variant = SpsaVariant::Rdsa;
        let spsa = Spsa::new(cfg, vec![0.05; 4]);
        let target = vec![0.3, 0.7, 0.4, 0.6];
        let mut obj = QuadraticObjective::new(target.clone(), 0.02, 5);
        let res = spsa.run(&mut obj, vec![0.5; 4]);
        let err: f64 = res
            .final_theta
            .iter()
            .zip(&target)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / 4.0;
        assert!(err < 0.2, "RDSA error {err}: {:?}", res.final_theta);
    }

    #[test]
    fn calm_gradient_stops_early() {
        let spsa = Spsa::new(
            SpsaConfig {
                max_iters: 500,
                grad_tol: 0.5,
                patience: 3,
                ..quad_spsa(10).config
            },
            vec![0.05; 4],
        );
        let mut obj = QuadraticObjective::new(vec![0.5; 4], 0.0, 2);
        let res = spsa.run(&mut obj, vec![0.5; 4]); // start at optimum
        assert_eq!(res.stop, StopReason::GradientCalm);
        assert!(res.iterations < 500);
    }

    #[test]
    fn scales_respect_integer_movement() {
        let space = ParameterSpace::v1();
        let c = Spsa::scales_for(&space);
        for (ci, p) in c.iter().zip(space.params()) {
            assert!(*ci >= 0.02 && *ci <= 0.25, "{}: {}", p.name, ci);
            if p.width() >= 5.0 {
                // moving by c in algo space moves ≥ 1 Hadoop unit
                assert!(ci * p.width() >= 1.0 - 1e-9, "{}", p.name);
            }
        }
    }
}
