//! The unified tuner interface: every algorithm of the paper's comparison
//! (§6.6) — and any future one — is a [`Tuner`] that observes the live
//! system only through a budget-metered [`EvalBroker`], so cross-algorithm
//! comparisons are apples-to-apples by construction: one observation
//! budget, one eval accounting, one convergence trace.
//!
//! Adding a tuner: implement [`Tuner`] and append a [`TunerEntry`] to
//! [`TUNERS`] — `repro list`, `registry::create` and every broker-driven
//! caller pick it up immediately. To also join the enum-driven campaign
//! and experiment matrices (`repro tune`, table2, robustness), add the
//! matching `coordinator::Algo` variant — three one-line match arms in
//! its `all`/`name`/`label`; that enum stays as a deliberate thin compat
//! shim for code that pattern-matches on algorithms.

use crate::baselines::{
    hill_climb, random_search, starfish_tune, training_corpus, CostObjective,
    HillClimbConfig, Ppabs, RrsConfig, RustWhatIf,
};
use crate::cluster::ClusterSpec;
use crate::config::{HadoopVersion, ParameterSpace};
use crate::util::rng::Rng;
use crate::whatif::ClusterFeatures;
use crate::workloads::WorkloadProfile;

use super::broker::{CachePolicy, EvalBroker};
use super::nelder_mead::NelderMeadTuner;
use super::rdsa::RdsaTuner;
use super::spsa::{IterRecord, Spsa, SpsaConfig};
use super::tpe::TpeTuner;

/// Measurement error of a single-shot job profile (lognormal sigma applied
/// to each data-flow feature). Profiling-based tuners see the workload
/// through this lens; SPSA never needs a profile.
pub const PROFILE_NOISE_SIGMA: f64 = 0.35;

/// Everything a tuner may need besides the broker: what job runs on what
/// cluster. The broker's objective observes the same pair, so model-based
/// tuners derive their what-if features from here.
#[derive(Clone)]
pub struct TunerContext {
    pub version: HadoopVersion,
    pub cluster: ClusterSpec,
    pub workload: WorkloadProfile,
}

impl TunerContext {
    pub fn features(&self) -> ClusterFeatures {
        ClusterFeatures::from_spec(&self.cluster, self.version)
    }
}

/// What a tuning run hands back. Live-observation accounting lives in the
/// broker (`evals_used`, trace, best-so-far); this carries the deployed
/// configuration plus the tuner-private extras.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    /// Configuration to deploy (algorithm space).
    pub best_theta: Vec<f64>,
    /// The tuner's own estimate of f at `best_theta` — observed for
    /// live-system tuners, model-predicted for CBO tuners, `INFINITY`
    /// when nothing was evaluated.
    pub best_f: f64,
    /// Per-iteration records (SPSA-family; empty otherwise — the broker
    /// trace is the uniform history).
    pub history: Vec<IterRecord>,
    /// What-if model evaluations (model-based tuners only).
    pub model_evals: u64,
    /// Simulated seconds spent profiling (Starfish/PPABS; 0 for SPSA).
    pub profiling_overhead_s: f64,
    /// `true` when `best_f` replays a value observed in an *earlier*
    /// campaign (served by the cross-campaign store, [`ObsSource::Store`])
    /// and never re-measured live in this run — the deployment is
    /// noise-frozen and its reported f is not a fresh measurement.
    /// Always `false` for a tuner's own result; the service layer sets it
    /// when a warm-started incumbent beats everything the tuner found.
    ///
    /// [`ObsSource::Store`]: crate::tuner::broker::ObsSource
    pub noise_frozen: bool,
}

impl TuneOutcome {
    fn deploy(best_theta: Vec<f64>, best_f: f64) -> TuneOutcome {
        TuneOutcome {
            best_theta,
            best_f,
            history: Vec::new(),
            model_evals: 0,
            profiling_overhead_s: 0.0,
            noise_frozen: false,
        }
    }
}

/// A tuning algorithm behind the one metered evaluation path.
pub trait Tuner {
    /// Canonical registry name (`TunerEntry::name`).
    fn name(&self) -> &'static str;

    /// Cache policy the broker should run with. Default: memoize —
    /// revisit-heavy searches stop paying for repeat simulations. The
    /// SPSA family overrides to `Off`: a cache hit skips the objective's
    /// next seed, and SPSA's golden trajectories must replay bit-exactly.
    fn cache_policy(&self) -> CachePolicy {
        CachePolicy::Quantized
    }

    /// Tune within the broker's budget; exhausting it is a graceful stop
    /// (return the best configuration found so far).
    fn tune(&self, broker: &mut EvalBroker, space: &ParameterSpace, seed: u64) -> TuneOutcome;

    /// Whether this tuner supports the checkpoint channel: pausing at a
    /// budget boundary and continuing in a later [`Tuner::tune_resumable`]
    /// call, bit-identically to one uninterrupted run.
    fn checkpointable(&self) -> bool {
        false
    }

    /// Tune with checkpoint support. `resume` is a blob a previous call of
    /// the SAME tuner returned; the caller must also hand back a broker
    /// preloaded with the prior segment's spend
    /// ([`EvalBroker::with_prior_spend`]) and an objective fast-forwarded
    /// past the observations that segment consumed
    /// ([`Objective::advance_evals`]) — then the continued run is
    /// bit-identical to an uninterrupted run at the combined budget, and
    /// spends only the incremental observations (O(increment) extension,
    /// vs resume-by-replay's O(cumulative)).
    ///
    /// Returns the outcome plus the checkpoint to continue from; `None`
    /// means the tuner finished for good (or does not checkpoint — the
    /// default falls back to a plain [`Tuner::tune`], which callers extend
    /// by deterministic replay instead).
    ///
    /// Checkpointable tuners should run with [`CachePolicy::Off`]: the
    /// memo cache is broker-local state that no checkpoint carries, so a
    /// resumed segment would miss hits the uninterrupted run gets.
    ///
    /// [`Objective::advance_evals`]: super::objective::Objective::advance_evals
    fn tune_resumable(
        &self,
        broker: &mut EvalBroker,
        space: &ParameterSpace,
        seed: u64,
        resume: Option<&[u8]>,
    ) -> (TuneOutcome, Option<Vec<u8>>) {
        debug_assert!(
            resume.is_none(),
            "{}: checkpoint handed to a non-checkpointable tuner",
            self.name()
        );
        (self.tune(broker, space, seed), None)
    }
}

/// Wrap a tuner's serialized state in a tagged envelope, so a blob resumed
/// by the wrong tuner fails loudly instead of silently misparsing.
pub fn encode_checkpoint(tuner: &str, state: crate::util::json::Json) -> Vec<u8> {
    use crate::util::json::Json;
    let mut j = Json::obj();
    j.set("tuner", Json::Str(tuner.to_string())).set("state", state);
    j.to_string().into_bytes()
}

/// Unwrap an [`encode_checkpoint`] envelope, checking the tuner tag.
pub fn decode_checkpoint(
    tuner: &str,
    bytes: &[u8],
) -> Result<crate::util::json::Json, String> {
    use crate::util::json::Json;
    let s = std::str::from_utf8(bytes).map_err(|e| format!("checkpoint not UTF-8: {e}"))?;
    let j = Json::parse(s)?;
    let tag = j.get("tuner").and_then(|t| t.as_str()).ok_or("checkpoint missing tuner tag")?;
    if tag != tuner {
        return Err(format!("checkpoint belongs to tuner '{tag}', not '{tuner}'"));
    }
    j.get("state").cloned().ok_or_else(|| "checkpoint missing state".to_string())
}

// ---------------------------------------------------------------------------
// implementations
// ---------------------------------------------------------------------------

/// No tuning: Hadoop defaults (the paper's baseline row).
pub struct DefaultTuner;

impl Tuner for DefaultTuner {
    fn name(&self) -> &'static str {
        "default"
    }

    fn cache_policy(&self) -> CachePolicy {
        CachePolicy::Off
    }

    fn tune(&self, _broker: &mut EvalBroker, space: &ParameterSpace, _seed: u64) -> TuneOutcome {
        TuneOutcome::deploy(space.default_theta(), f64::INFINITY)
    }
}

/// The paper's contribution (Algorithm 1) on the live system.
pub struct SpsaTuner {
    pub config: SpsaConfig,
}

impl SpsaTuner {
    /// The paper's hyper-parameters (§5.2 / §6.5).
    pub fn paper() -> SpsaTuner {
        SpsaTuner { config: SpsaConfig::default() }
    }
}

impl SpsaTuner {
    /// Budget planning shared by the plain and resumable paths: size
    /// `max_iters` so the run spends the whole *remaining* budget (on top
    /// of the `start_iter` iterations a resumed state already holds)
    /// unless the gradient calms first. The config's own max_iters only
    /// caps unlimited-budget runs.
    fn plan(&self, spsa: &mut Spsa, broker: &EvalBroker, start_iter: u64) {
        if broker.budget().max_obs != u64::MAX {
            spsa.config.max_iters =
                start_iter + (broker.remaining() / spsa.obs_per_iter()).max(1);
        } else if !broker.budget().is_unlimited() {
            // batch/model-time-limited with unlimited observations: no
            // whole-iteration plan exists up front — iterate until the
            // broker truncates (`run_broker` stops the moment the next
            // iteration is unaffordable) or the gradient calms
            spsa.config.max_iters = u64::MAX;
        }
    }
}

impl Tuner for SpsaTuner {
    fn name(&self) -> &'static str {
        "spsa"
    }

    fn cache_policy(&self) -> CachePolicy {
        CachePolicy::Off
    }

    fn tune(&self, broker: &mut EvalBroker, space: &ParameterSpace, seed: u64) -> TuneOutcome {
        let mut spsa = Spsa::for_space(SpsaConfig { seed, ..self.config.clone() }, space);
        self.plan(&mut spsa, broker, 0);
        let res = spsa.run_broker(broker, space.default_theta());
        TuneOutcome {
            // Deploy the best configuration observed during learning: the
            // coordinator has every iterate's measured time at hand, and
            // the final iterate still carries the last noisy step.
            best_theta: res.best_theta,
            best_f: res.best_f,
            history: res.history,
            model_evals: 0,
            profiling_overhead_s: 0.0,
            noise_frozen: false,
        }
    }

    fn checkpointable(&self) -> bool {
        true
    }

    fn tune_resumable(
        &self,
        broker: &mut EvalBroker,
        space: &ParameterSpace,
        seed: u64,
        resume: Option<&[u8]>,
    ) -> (TuneOutcome, Option<Vec<u8>>) {
        use super::spsa::SpsaState;
        let mut spsa = Spsa::for_space(SpsaConfig { seed, ..self.config.clone() }, space);
        let state = match resume {
            Some(bytes) => {
                let j = decode_checkpoint(self.name(), bytes)
                    .unwrap_or_else(|e| panic!("{}: bad checkpoint: {e}", self.name()));
                SpsaState::from_json(&j)
                    .unwrap_or_else(|e| panic!("{}: bad checkpoint state: {e}", self.name()))
            }
            None => SpsaState::fresh(space.default_theta()),
        };
        // per-iteration reseeding keys off state.iter, so planning from the
        // resumed iteration reproduces the uninterrupted run's horizon
        self.plan(&mut spsa, broker, state.iter);
        let (res, state) = spsa.run_broker_from(broker, state);
        // GradientCalm is a terminal stop: an uninterrupted run at any
        // larger budget ends at the same iterate, so there is nothing to
        // resume — signal "finished for good" instead of a checkpoint.
        let checkpoint = match res.stop {
            super::spsa::StopReason::GradientCalm => None,
            _ => Some(encode_checkpoint(self.name(), state.to_json())),
        };
        let outcome = TuneOutcome {
            best_theta: res.best_theta,
            best_f: res.best_f,
            history: res.history,
            model_evals: 0,
            profiling_overhead_s: 0.0,
            noise_frozen: false,
        };
        (outcome, checkpoint)
    }
}

/// SPSA iterating on the analytic what-if surface instead of the live
/// system (extension; the artifact-backed variant lives in
/// `examples/whatif_engine.rs`). Model observations are free, so it runs
/// 4× the live-budget-equivalent iterations and consumes 0 live
/// observations.
pub struct SurrogateSpsaTuner {
    pub config: SpsaConfig,
    workload: WorkloadProfile,
    features: ClusterFeatures,
}

impl SurrogateSpsaTuner {
    pub fn new(ctx: &TunerContext) -> SurrogateSpsaTuner {
        SurrogateSpsaTuner {
            config: SpsaConfig::default(),
            workload: ctx.workload.clone(),
            features: ctx.features(),
        }
    }
}

impl Tuner for SurrogateSpsaTuner {
    fn name(&self) -> &'static str {
        "spsa-surrogate"
    }

    fn cache_policy(&self) -> CachePolicy {
        CachePolicy::Off
    }

    fn tune(&self, broker: &mut EvalBroker, space: &ParameterSpace, seed: u64) -> TuneOutcome {
        let mut evaluator =
            RustWhatIf::new(space.clone(), self.workload.clone(), self.features.clone());
        let mut spsa = Spsa::for_space(SpsaConfig { seed, ..self.config.clone() }, space);
        if broker.budget().max_obs != u64::MAX {
            spsa.config.max_iters =
                (broker.remaining() / spsa.obs_per_iter()).max(1).saturating_mul(4);
        }
        let mut obj = CostObjective::new(&mut evaluator);
        let res = spsa.run(&mut obj, space.default_theta());
        TuneOutcome {
            best_theta: res.best_theta,
            best_f: res.best_f,
            history: res.history,
            model_evals: res.observations,
            profiling_overhead_s: 0.0,
            noise_frozen: false,
        }
    }
}

/// Starfish: one metered profiling run → noisy single-shot profile →
/// what-if model → RRS (paper §3, §6.8(4)).
pub struct StarfishTuner {
    pub rrs: RrsConfig,
    workload: WorkloadProfile,
    features: ClusterFeatures,
}

impl StarfishTuner {
    pub fn new(ctx: &TunerContext) -> StarfishTuner {
        StarfishTuner {
            rrs: RrsConfig::default(),
            workload: ctx.workload.clone(),
            features: ctx.features(),
        }
    }
}

impl Tuner for StarfishTuner {
    fn name(&self) -> &'static str {
        "starfish"
    }

    fn tune(&self, broker: &mut EvalBroker, space: &ParameterSpace, seed: u64) -> TuneOutcome {
        // Starfish characterizes the job from ONE instrumented run: its
        // what-if engine sees a single-shot noisy profile.
        let mut prof_rng = Rng::seeded(seed ^ 0x5F15);
        let noisy_w = self.workload.with_measurement_noise(&mut prof_rng, PROFILE_NOISE_SIGMA);
        let mut evaluator = RustWhatIf::new(space.clone(), noisy_w, self.features.clone());
        let res = starfish_tune(
            space,
            broker,
            &mut evaluator,
            &RrsConfig { seed, ..self.rrs.clone() },
        );
        TuneOutcome {
            best_theta: res.best_theta,
            best_f: res.model_cost,
            history: Vec::new(),
            model_evals: res.model_evals,
            profiling_overhead_s: res.profiling_overhead_s,
            noise_frozen: false,
        }
    }
}

/// PPABS: profile a training corpus (metered via [`EvalBroker::charge`] —
/// the corpus jobs are *other* workloads, simulated inside `Ppabs::train`),
/// cluster signatures, anneal one configuration per cluster, then assign
/// the target job to its nearest cluster.
pub struct PpabsTuner {
    pub k: usize,
    cluster: ClusterSpec,
    workload: WorkloadProfile,
}

impl PpabsTuner {
    pub fn new(ctx: &TunerContext) -> PpabsTuner {
        PpabsTuner { k: 4, cluster: ctx.cluster.clone(), workload: ctx.workload.clone() }
    }
}

impl Tuner for PpabsTuner {
    fn name(&self) -> &'static str {
        "ppabs"
    }

    fn tune(&self, broker: &mut EvalBroker, space: &ParameterSpace, seed: u64) -> TuneOutcome {
        let mut prof_rng = Rng::seeded(seed ^ 0x99AB);
        let corpus: Vec<WorkloadProfile> = training_corpus(2000)
            .iter()
            .map(|c| c.with_measurement_noise(&mut prof_rng, PROFILE_NOISE_SIGMA))
            .collect();
        // meter the corpus profiling against the shared live budget; a
        // too-small budget shrinks the corpus (graceful degradation). The
        // grant must precede training (it sizes the corpus), so the runs'
        // wall-clock is priced afterwards, once it has been measured.
        let granted = broker.charge(corpus.len() as u64, 0.0) as usize;
        if granted == 0 {
            return TuneOutcome::deploy(space.default_theta(), f64::INFINITY);
        }
        let ppabs = Ppabs::train(space, &self.cluster, &corpus[..granted], self.k, seed);
        broker.charge(0, ppabs.profiling_overhead_s);
        TuneOutcome {
            best_theta: ppabs.configure(&self.workload),
            best_f: f64::INFINITY, // assigns a cluster config, never observes it
            history: Vec::new(),
            model_evals: ppabs.model_evals,
            profiling_overhead_s: ppabs.profiling_overhead_s,
            noise_frozen: false,
        }
    }
}

/// MROnline-style hill climbing on the live system.
pub struct HillClimbTuner {
    pub config: HillClimbConfig,
}

impl Tuner for HillClimbTuner {
    fn name(&self) -> &'static str {
        "hillclimb"
    }

    fn tune(&self, broker: &mut EvalBroker, space: &ParameterSpace, seed: u64) -> TuneOutcome {
        let res = hill_climb(
            broker,
            space.default_theta(),
            &HillClimbConfig { seed, ..self.config.clone() },
        );
        TuneOutcome::deploy(res.best_theta, res.best_f)
    }
}

/// Random search on the live system (ablation anchor).
pub struct RandomTuner;

impl Tuner for RandomTuner {
    fn name(&self) -> &'static str {
        "random"
    }

    fn cache_policy(&self) -> CachePolicy {
        // a memo hit would silently skip the objective's next seed (the
        // cache can never help a uniform sampler anyway) — keep the
        // documented bit-exact seed-stream contract of random_search
        CachePolicy::Off
    }

    fn tune(&self, broker: &mut EvalBroker, space: &ParameterSpace, seed: u64) -> TuneOutcome {
        let res = random_search(broker, space.default_theta(), seed);
        TuneOutcome::deploy(res.best_theta, res.best_f)
    }

    fn checkpointable(&self) -> bool {
        true
    }

    fn tune_resumable(
        &self,
        broker: &mut EvalBroker,
        space: &ParameterSpace,
        seed: u64,
        resume: Option<&[u8]>,
    ) -> (TuneOutcome, Option<Vec<u8>>) {
        use crate::baselines::{random_search_resumable, RandomSearchState};
        let state = resume.map(|bytes| {
            let j = decode_checkpoint(self.name(), bytes)
                .unwrap_or_else(|e| panic!("{}: bad checkpoint: {e}", self.name()));
            RandomSearchState::from_json(&j)
                .unwrap_or_else(|e| panic!("{}: bad checkpoint state: {e}", self.name()))
        });
        let (res, state) = random_search_resumable(broker, space.default_theta(), seed, state);
        let checkpoint = state.map(|st| encode_checkpoint(self.name(), st.to_json()));
        (TuneOutcome::deploy(res.best_theta, res.best_f), checkpoint)
    }
}

// ---------------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------------

/// One registered tuner: canonical name, accepted aliases (matched
/// case-insensitively, input trimmed), a one-liner for `repro list`, and
/// the factory.
pub struct TunerEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub summary: &'static str,
    pub make: fn(&TunerContext) -> Box<dyn Tuner>,
}

/// Every tuner the repro knows. Append here to register a new one.
pub static TUNERS: &[TunerEntry] = &[
    TunerEntry {
        name: "default",
        aliases: &["none", "baseline"],
        summary: "no tuning: Hadoop default configuration",
        make: |_| Box::new(DefaultTuner),
    },
    TunerEntry {
        name: "spsa",
        aliases: &[],
        summary: "the paper's noisy-gradient tuner on the live system (Algorithm 1)",
        make: |_| Box::new(SpsaTuner::paper()),
    },
    TunerEntry {
        name: "spsa-surrogate",
        aliases: &["surrogate", "spsasurrogate"],
        summary: "SPSA iterating on the analytic what-if model, 0 live observations",
        make: |ctx| Box::new(SurrogateSpsaTuner::new(ctx)),
    },
    TunerEntry {
        name: "starfish",
        aliases: &[],
        summary: "profile once, then RRS over the what-if cost model (CIDR'11)",
        make: |ctx| Box::new(StarfishTuner::new(ctx)),
    },
    TunerEntry {
        name: "ppabs",
        aliases: &[],
        summary: "corpus profiling + signature clustering + SA on a reduced space (HiPC'13)",
        make: |ctx| Box::new(PpabsTuner::new(ctx)),
    },
    TunerEntry {
        name: "hillclimb",
        aliases: &["hill", "hill-climb", "mronline"],
        summary: "MROnline-style one-parameter-at-a-time search on the live system (HPDC'14)",
        make: |_| Box::new(HillClimbTuner { config: HillClimbConfig::default() }),
    },
    TunerEntry {
        name: "random",
        aliases: &["randomsearch", "random-search"],
        summary: "uniform random search on the live system (ablation anchor)",
        make: |_| Box::new(RandomTuner),
    },
    TunerEntry {
        name: "rdsa",
        aliases: &["random-directions", "rd-sa"],
        summary: "paper §7 random-direction noisy gradient: gaussian d, SPSA gain schedule",
        make: |_| Box::new(RdsaTuner::paper()),
    },
    TunerEntry {
        name: "nelder-mead",
        aliases: &["neldermead", "nm", "simplex"],
        summary: "downhill simplex on the live system; init + shrink steps batch-dispatched",
        make: |_| Box::new(NelderMeadTuner::new()),
    },
    TunerEntry {
        name: "tpe",
        aliases: &["bayesopt", "tpe-bo"],
        summary: "TPE Bayesian optimization over the broker trace (density-ratio ranking)",
        make: |_| Box::new(TpeTuner::new()),
    },
];

/// Look a tuner up by name or alias (trimmed, case-insensitive).
pub fn find(name: &str) -> Option<&'static TunerEntry> {
    let t = name.trim().to_ascii_lowercase();
    TUNERS.iter().find(|e| {
        e.name == t || e.aliases.iter().any(|a| *a == t)
    })
}

/// Instantiate a tuner for a (workload, cluster, version) context.
pub fn create(name: &str, ctx: &TunerContext) -> Option<Box<dyn Tuner>> {
    find(name).map(|e| (e.make)(ctx))
}

/// Canonical names, registry order.
pub fn names() -> Vec<&'static str> {
    TUNERS.iter().map(|e| e.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::broker::{Budget, EvalBroker};
    use crate::tuner::SimObjective;
    use crate::workloads::Benchmark;

    fn ctx() -> TunerContext {
        let mut rng = Rng::seeded(1);
        TunerContext {
            version: HadoopVersion::V1,
            cluster: ClusterSpec::paper_cluster(),
            workload: Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut rng),
        }
    }

    #[test]
    fn registry_names_are_unique_and_resolve() {
        let names = names();
        for (i, n) in names.iter().enumerate() {
            assert!(!names[i + 1..].contains(n), "duplicate registry name {n}");
            assert_eq!(find(n).unwrap().name, *n);
        }
    }

    #[test]
    fn find_is_case_insensitive_and_trims() {
        assert_eq!(find("  SPSA ").unwrap().name, "spsa");
        assert_eq!(find("Hill-Climb").unwrap().name, "hillclimb");
        assert_eq!(find("MROnline").unwrap().name, "hillclimb");
        assert_eq!(find("SURROGATE").unwrap().name, "spsa-surrogate");
        assert_eq!(find("RDSA").unwrap().name, "rdsa");
        assert_eq!(find("NelderMead").unwrap().name, "nelder-mead");
        assert_eq!(find("Simplex").unwrap().name, "nelder-mead");
        assert_eq!(find("BayesOpt").unwrap().name, "tpe");
        assert!(find("bogus").is_none());
    }

    #[test]
    fn registry_matches_the_committed_name_fixture() {
        // One source of truth for "what tuners exist": CI diffs
        // `repro list --names` against rust/tests/fixtures/registry_names.txt,
        // and this test enforces the same fixture locally — growing the
        // registry without updating the fixture fails here first, and the
        // fix is a one-line fixture edit, not a YAML change.
        let fixture = include_str!("../../tests/fixtures/registry_names.txt");
        let want: Vec<&str> =
            fixture.lines().map(str::trim).filter(|l| !l.is_empty()).collect();
        assert_eq!(
            names(),
            want,
            "rust/tests/fixtures/registry_names.txt is out of date with TUNERS"
        );
    }

    #[test]
    fn created_tuner_reports_its_registry_name() {
        let c = ctx();
        for e in TUNERS {
            let t = create(e.name, &c).unwrap();
            assert_eq!(t.name(), e.name, "factory/name mismatch for {}", e.name);
        }
    }

    #[test]
    fn every_tuner_respects_one_shared_budget() {
        // The acceptance contract in miniature: each registry tuner runs
        // against the same live objective under the same budget and never
        // overspends; live-system tuners must consume something.
        let c = ctx();
        let space = ParameterSpace::for_version(c.version);
        const BUDGET: u64 = 30;
        for e in TUNERS {
            let tuner = create(e.name, &c).unwrap();
            let mut obj =
                SimObjective::new(space.clone(), c.cluster.clone(), c.workload.clone(), 7);
            let mut broker = EvalBroker::new(&mut obj, Budget::obs(BUDGET))
                .with_cache(tuner.cache_policy());
            let out = tuner.tune(&mut broker, &space, 7);
            assert!(
                broker.evals_used() <= BUDGET,
                "{} overspent: {} > {BUDGET}",
                e.name,
                broker.evals_used()
            );
            assert_eq!(out.best_theta.len(), space.dim(), "{}", e.name);
            match e.name {
                "default" | "spsa-surrogate" => assert_eq!(broker.evals_used(), 0),
                "starfish" => assert_eq!(broker.evals_used(), 1),
                "random" => assert_eq!(broker.evals_used(), BUDGET),
                _ => assert!(broker.evals_used() > 0, "{} never observed", e.name),
            }
        }
    }

    #[test]
    fn spsa_tuner_iterates_under_a_pure_time_budget() {
        // Unlimited observations, finite model time: the planner cannot
        // precompute whole iterations, so the broker's time axis must be
        // what stops the run — gracefully, on an iteration boundary.
        let c = ctx();
        let space = ParameterSpace::for_version(c.version);
        let calib = {
            use crate::tuner::Objective;
            let mut o = SimObjective::new(
                space.clone(),
                c.cluster.clone(),
                c.workload.clone(),
                3,
            )
            .noise_free();
            o.eval(&space.default_theta())
        };
        let tuner = SpsaTuner::paper(); // 3 obs/iter
        let mut obj =
            SimObjective::new(space.clone(), c.cluster.clone(), c.workload.clone(), 3);
        let cap = calib * 10.0;
        let mut broker =
            EvalBroker::new(&mut obj, Budget::unlimited().with_model_time(cap));
        let out = tuner.tune(&mut broker, &space, 3);
        assert!(broker.evals_used() > 0, "time budget afforded nothing");
        assert_eq!(broker.evals_used() % 3, 0, "must stop on an iteration boundary");
        assert!(
            broker.elapsed_model_time() <= cap + broker.max_batch_cost(),
            "time overshoot beyond one wave: {} > {} + {}",
            broker.elapsed_model_time(),
            cap,
            broker.max_batch_cost()
        );
        assert!(!out.history.is_empty());
    }

    #[test]
    fn spsa_tuner_spends_budget_in_whole_iterations() {
        let c = ctx();
        let space = ParameterSpace::for_version(c.version);
        let tuner = SpsaTuner::paper(); // grad_avg 2 → 3 obs/iter
        let mut obj =
            SimObjective::new(space.clone(), c.cluster.clone(), c.workload.clone(), 3);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(31));
        let out = tuner.tune(&mut broker, &space, 3);
        assert!(broker.evals_used() <= 30, "3-obs iterations can't spend 31");
        assert_eq!(broker.evals_used() % 3, 0);
        assert_eq!(out.history.len() as u64 * 3, broker.evals_used());
    }
}
