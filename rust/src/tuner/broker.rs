//! `EvalBroker` — the single metered evaluation path every tuner observes
//! the live system through. The paper's headline claim is *economy of
//! observations* (SPSA needs 2 per iteration, §6.6); the broker makes that
//! the native currency of the comparison by wrapping any [`Objective`] with
//!
//! * a hard observation/batch budget ([`Budget`]): exceeding it is a
//!   graceful stop — the tuner keeps its best-so-far partial result;
//! * a memoization cache keyed by quantized θ ([`CachePolicy::Quantized`]),
//!   so revisit-heavy tuners (hill climbing, annealing-style proposals)
//!   stop paying for repeat simulations — cache hits cost no budget and
//!   never reach the underlying objective;
//! * batched dispatch: uncached points of a batch go to the objective in
//!   one [`Objective::eval_batch`] call, in their original order, so the
//!   pre-assigned seed streams of `SimObjective` fan across
//!   `coordinator::pool` workers and stay bit-identical to the sequential
//!   loop at any worker count (the PR 1 contract);
//! * a uniform eval-trace ([`EvalRecord`]) and best-so-far tracking, so
//!   every tuner gets a convergence history for free.
//!
//! **Cache caveat (continuous-θ tuners).** A cache hit replays a past
//! observation instead of consuming the objective's next seed, so the
//! observation stream is no longer bit-identical to an uncached run, and
//! quantization (default 1e-6 per coordinate) aliases points closer than
//! the quantum. Tuners whose trajectories must replay exactly — the SPSA
//! family — declare [`CachePolicy::Off`] via `Tuner::cache_policy`.

use std::collections::HashMap;

use super::objective::Objective;

/// Hard evaluation budget of one tuning run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Budget {
    /// Maximum live observations (cache hits are free).
    pub max_obs: u64,
    /// Maximum dispatch rounds (each underlying `eval_batch` call is one
    /// round — a wall-clock proxy: one round ≈ one parallel wave).
    pub max_batches: u64,
}

impl Budget {
    /// Observation budget with unlimited batches — the common case.
    pub fn obs(max_obs: u64) -> Budget {
        Budget { max_obs, max_batches: u64::MAX }
    }

    /// No limits (compat path for callers that meter elsewhere).
    pub fn unlimited() -> Budget {
        Budget::obs(u64::MAX)
    }

    /// Builder: additionally cap dispatch rounds.
    pub fn with_batches(mut self, max_batches: u64) -> Budget {
        self.max_batches = max_batches;
        self
    }
}

/// Whether the broker may serve repeat θs from memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CachePolicy {
    /// Every observation reaches the objective — bit-exact seed streams.
    Off,
    /// Memoize by quantized θ; revisits are free (and noise-frozen).
    Quantized,
}

/// One observed point of the uniform convergence trace.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    /// Live observations consumed *after* this record (cache hits repeat
    /// the previous count).
    pub obs: u64,
    pub theta: Vec<f64>,
    pub f: f64,
    pub cached: bool,
}

/// Budget-metered, memoizing, trace-keeping wrapper around an objective.
pub struct EvalBroker<'a> {
    objective: &'a mut dyn Objective,
    budget: Budget,
    policy: CachePolicy,
    /// Cache quantization step per coordinate (θ ∈ [0,1]).
    quant: f64,
    memo: HashMap<Vec<i64>, f64>,
    evals_used: u64,
    batches_used: u64,
    cache_hits: u64,
    trace: Vec<EvalRecord>,
    best: Option<(Vec<f64>, f64)>,
}

impl<'a> EvalBroker<'a> {
    /// Wrap `objective`. The cache starts [`CachePolicy::Off`] — the safe,
    /// bit-exact default; registry-driven runs apply the tuner's declared
    /// policy.
    pub fn new(objective: &'a mut dyn Objective, budget: Budget) -> Self {
        EvalBroker {
            objective,
            budget,
            policy: CachePolicy::Off,
            quant: 1e-6,
            memo: HashMap::new(),
            evals_used: 0,
            batches_used: 0,
            cache_hits: 0,
            trace: Vec::new(),
            best: None,
        }
    }

    pub fn with_cache(mut self, policy: CachePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Cache quantization step (only meaningful with `Quantized`).
    pub fn with_quantization(mut self, quant: f64) -> Self {
        assert!(quant > 0.0, "quantization step must be positive");
        self.quant = quant;
        self
    }

    /// The cache quantization step in effect (θ-cell size of `Quantized`).
    /// Tuners that deduplicate their own proposals against the memo (TPE)
    /// read this so their notion of "already observed" matches the cache's.
    pub fn quantization(&self) -> f64 {
        self.quant
    }

    /// Observations still affordable (0 once either budget axis is spent).
    pub fn remaining(&self) -> u64 {
        if self.batches_used >= self.budget.max_batches {
            return 0;
        }
        self.budget.max_obs.saturating_sub(self.evals_used)
    }

    pub fn exhausted(&self) -> bool {
        self.remaining() == 0
    }

    /// Live observations consumed so far (incl. [`EvalBroker::charge`]).
    pub fn evals_used(&self) -> u64 {
        self.evals_used
    }

    pub fn batches_used(&self) -> u64 {
        self.batches_used
    }

    pub fn cache_hits(&self) -> u64 {
        self.cache_hits
    }

    pub fn budget(&self) -> Budget {
        self.budget
    }

    pub fn dim(&self) -> usize {
        self.objective.dim()
    }

    /// Best observed point so far: (θ, f).
    pub fn best(&self) -> Option<(&[f64], f64)> {
        self.best.as_ref().map(|(t, f)| (t.as_slice(), *f))
    }

    /// The uniform convergence trace (every served observation, in order).
    pub fn trace(&self) -> &[EvalRecord] {
        &self.trace
    }

    pub fn take_trace(&mut self) -> Vec<EvalRecord> {
        std::mem::take(&mut self.trace)
    }

    /// Account `n` live runs performed *outside* this broker's objective
    /// against the budget (e.g. PPABS profiling its training corpus, which
    /// runs other workloads). Returns how many were granted; the caller
    /// must scale its external work down to the grant.
    pub fn charge(&mut self, n: u64) -> u64 {
        let granted = n.min(self.remaining());
        self.evals_used += granted;
        granted
    }

    fn key(&self, theta: &[f64]) -> Vec<i64> {
        theta.iter().map(|t| (t / self.quant).round() as i64).collect()
    }

    /// One observation. `None` once the budget is exhausted — the caller's
    /// graceful-stop signal (return best-so-far).
    pub fn try_eval(&mut self, theta: &[f64]) -> Option<f64> {
        self.try_eval_batch(std::slice::from_ref(&theta.to_vec())).first().copied()
    }

    /// Observe a batch of points. Serves each point in order — from the
    /// cache when allowed, else from the objective — and **truncates at
    /// the first point the budget cannot afford**: the returned vector may
    /// be shorter than `thetas` (empty when exhausted up front). All
    /// uncached points go to the objective in ONE `eval_batch` call, in
    /// their original relative order, so per-observation seed derivation
    /// matches the plain sequential loop exactly.
    pub fn try_eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        // Plan: which points are served from memory, which dispatch, and
        // where the budget truncates the batch.
        enum Source {
            Memo(f64),
            /// Index into the dispatch vector (also covers duplicates of a
            /// not-yet-dispatched point within the same batch).
            Dispatch(usize),
        }
        let mut plan: Vec<Source> = Vec::with_capacity(thetas.len());
        let mut dispatch: Vec<Vec<f64>> = Vec::new();
        let mut pending: HashMap<Vec<i64>, usize> = HashMap::new();
        let affordable = self.remaining();
        for theta in thetas {
            let use_cache = self.policy == CachePolicy::Quantized;
            let k = if use_cache { self.key(theta) } else { Vec::new() };
            if use_cache {
                if let Some(&f) = self.memo.get(&k) {
                    plan.push(Source::Memo(f));
                    continue;
                }
                if let Some(&i) = pending.get(&k) {
                    plan.push(Source::Dispatch(i));
                    continue;
                }
            }
            if (dispatch.len() as u64) >= affordable {
                break; // budget exhausted: truncate here
            }
            if use_cache {
                pending.insert(k, dispatch.len());
            }
            plan.push(Source::Dispatch(dispatch.len()));
            dispatch.push(theta.clone());
        }

        let values: Vec<f64> = if dispatch.is_empty() {
            Vec::new()
        } else {
            self.batches_used += 1;
            self.evals_used += dispatch.len() as u64;
            self.objective.eval_batch(&dispatch)
        };
        debug_assert_eq!(values.len(), dispatch.len());
        if self.policy == CachePolicy::Quantized {
            for (theta, &f) in dispatch.iter().zip(&values) {
                self.memo.insert(self.key(theta), f);
            }
        }

        let mut out = Vec::with_capacity(plan.len());
        let mut dispatched_seen = vec![false; dispatch.len()];
        for (src, theta) in plan.iter().zip(thetas) {
            let (f, cached) = match src {
                Source::Memo(f) => (*f, true),
                Source::Dispatch(i) => {
                    let first = !dispatched_seen[*i];
                    dispatched_seen[*i] = true;
                    (values[*i], !first)
                }
            };
            if cached {
                self.cache_hits += 1;
            }
            self.trace.push(EvalRecord {
                obs: self.evals_used,
                theta: theta.clone(),
                f,
                cached,
            });
            let better = match &self.best {
                Some((_, bf)) => f < *bf,
                None => true,
            };
            if better {
                self.best = Some((theta.clone(), f));
            }
            out.push(f);
        }
        out
    }
}

/// The broker as an [`Objective`], so batch-driven tuners (`Spsa::run_state`)
/// plug in unchanged. This facade has no graceful-stop channel: callers
/// must check [`EvalBroker::remaining`] before each request (as
/// `Spsa::run_broker` does) — an over-budget request here is a caller bug
/// and panics rather than fabricating an observation.
impl Objective for EvalBroker<'_> {
    fn dim(&self) -> usize {
        self.objective.dim()
    }

    fn eval(&mut self, theta: &[f64]) -> f64 {
        self.try_eval(theta)
            .expect("EvalBroker budget exhausted — check remaining() before eval")
    }

    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        let out = self.try_eval_batch(thetas);
        assert_eq!(
            out.len(),
            thetas.len(),
            "EvalBroker budget exhausted mid-batch — check remaining() before eval_batch"
        );
        out
    }

    fn evals(&self) -> u64 {
        self.evals_used
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::objective::QuadraticObjective;

    fn quad() -> QuadraticObjective {
        QuadraticObjective::new(vec![0.3, 0.7], 0.05, 9)
    }

    #[test]
    fn meters_observations_and_batches() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10));
        assert_eq!(b.remaining(), 10);
        let f = b.try_eval(&[0.5, 0.5]).unwrap();
        assert!(f.is_finite());
        assert_eq!(b.evals_used(), 1);
        assert_eq!(b.batches_used(), 1);
        let fs = b.try_eval_batch(&[vec![0.1, 0.1], vec![0.9, 0.9]]);
        assert_eq!(fs.len(), 2);
        assert_eq!(b.evals_used(), 3);
        assert_eq!(b.batches_used(), 2);
        assert_eq!(b.remaining(), 7);
        assert_eq!(b.trace().len(), 3);
    }

    #[test]
    fn budget_exhaustion_truncates_gracefully() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(3));
        let pts: Vec<Vec<f64>> = (0..5).map(|i| vec![0.1 * i as f64, 0.2]).collect();
        let fs = b.try_eval_batch(&pts);
        assert_eq!(fs.len(), 3, "batch must truncate at the budget");
        assert_eq!(b.evals_used(), 3);
        assert!(b.exhausted());
        assert!(b.try_eval(&[0.5, 0.5]).is_none());
        assert!(b.try_eval_batch(&pts).is_empty());
        // best-so-far survives exhaustion — the partial result
        let (bt, bf) = b.best().expect("best-so-far");
        assert_eq!(bt.len(), 2);
        assert!(bf.is_finite());
        assert_eq!(obj.evals(), 3, "objective saw exactly the budget");
    }

    #[test]
    fn batch_budget_axis_stops_dispatch() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(100).with_batches(2));
        assert!(b.try_eval(&[0.1, 0.1]).is_some());
        assert!(b.try_eval(&[0.2, 0.2]).is_some());
        assert_eq!(b.batches_used(), 2);
        assert_eq!(b.remaining(), 0, "batch budget spent");
        assert!(b.try_eval(&[0.3, 0.3]).is_none());
    }

    #[test]
    fn cache_hit_is_free_and_objective_counter_does_not_grow() {
        let mut obj = quad();
        let mut b =
            EvalBroker::new(&mut obj, Budget::obs(10)).with_cache(CachePolicy::Quantized);
        let theta = [0.25, 0.75];
        let a = b.try_eval(&theta).unwrap();
        let evals_after_first = b.evals_used();
        let c = b.try_eval(&theta).unwrap();
        assert_eq!(a, c, "cache must replay the recorded observation");
        assert_eq!(b.evals_used(), evals_after_first, "cache hit charged the budget");
        assert_eq!(b.cache_hits(), 1);
        assert_eq!(obj.evals(), 1, "Objective::evals() grew on a repeated θ");
    }

    #[test]
    fn cache_off_pays_every_time() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10));
        let theta = [0.25, 0.75];
        b.try_eval(&theta).unwrap();
        b.try_eval(&theta).unwrap();
        assert_eq!(b.evals_used(), 2);
        assert_eq!(b.cache_hits(), 0);
        assert_eq!(obj.evals(), 2);
    }

    #[test]
    fn within_batch_duplicates_dispatch_once_under_cache() {
        let mut obj = quad();
        let mut b =
            EvalBroker::new(&mut obj, Budget::obs(10)).with_cache(CachePolicy::Quantized);
        let fs = b.try_eval_batch(&[vec![0.4, 0.4], vec![0.4, 0.4], vec![0.6, 0.6]]);
        assert_eq!(fs.len(), 3);
        assert_eq!(fs[0], fs[1]);
        assert_eq!(b.evals_used(), 2, "duplicate θ in one batch must dispatch once");
        assert_eq!(obj.evals(), 2);
    }

    #[test]
    fn quantization_aliases_nearby_points() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10))
            .with_cache(CachePolicy::Quantized)
            .with_quantization(0.1);
        let a = b.try_eval(&[0.50, 0.50]).unwrap();
        let c = b.try_eval(&[0.52, 0.48]).unwrap(); // same 0.1-cell
        assert_eq!(a, c);
        assert_eq!(b.evals_used(), 1);
    }

    #[test]
    fn charge_meters_external_runs() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10));
        assert_eq!(b.charge(4), 4);
        assert_eq!(b.evals_used(), 4);
        assert_eq!(b.charge(20), 6, "grant clips to the remaining budget");
        assert!(b.exhausted());
        assert_eq!(obj.evals(), 0, "charge must not touch the objective");
    }

    #[test]
    fn trace_and_best_track_observations() {
        let mut obj = QuadraticObjective::new(vec![0.5, 0.5], 0.0, 1);
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10));
        b.try_eval(&[0.9, 0.9]).unwrap();
        b.try_eval(&[0.5, 0.5]).unwrap();
        b.try_eval(&[0.8, 0.8]).unwrap();
        let (bt, bf) = b.best().unwrap();
        assert_eq!(bt, &[0.5, 0.5]);
        assert!((bf - 1.0).abs() < 1e-9, "noise-free minimum is 1.0");
        assert_eq!(b.trace().len(), 3);
        assert_eq!(b.trace()[2].obs, 3);
        assert!(!b.trace()[2].cached);
    }

    #[test]
    fn objective_facade_passes_through_unlimited() {
        // Through the Objective facade with cache off, the broker is a
        // transparent proxy: same values, same counter.
        let thetas: Vec<Vec<f64>> = vec![vec![0.2, 0.2], vec![0.7, 0.1], vec![0.5, 0.9]];
        let mut plain = quad();
        let want = plain.eval_batch(&thetas);
        let mut wrapped_inner = quad();
        let mut b = EvalBroker::new(&mut wrapped_inner, Budget::unlimited());
        let got = Objective::eval_batch(&mut b, &thetas);
        assert_eq!(got, want);
        assert_eq!(Objective::evals(&b), 3);
    }

    #[test]
    #[should_panic(expected = "budget exhausted")]
    fn objective_facade_panics_when_overdrawn() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(1));
        Objective::eval(&mut b, &[0.5, 0.5]);
        Objective::eval(&mut b, &[0.6, 0.6]); // caller bug: no remaining() check
    }
}
