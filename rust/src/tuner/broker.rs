//! `EvalBroker` — the single metered evaluation path every tuner observes
//! the live system through. The paper's headline claim is *economy of
//! observations* (SPSA needs 2 per iteration, §6.6); the broker makes that
//! the native currency of the comparison by wrapping any [`Objective`] with
//!
//! * a hard observation/batch budget ([`Budget`]): exceeding it is a
//!   graceful stop — the tuner keeps its best-so-far partial result;
//! * a memoization cache keyed by quantized θ ([`CachePolicy::Quantized`]),
//!   so revisit-heavy tuners (hill climbing, annealing-style proposals)
//!   stop paying for repeat simulations — cache hits cost no budget and
//!   never reach the underlying objective;
//! * batched dispatch: uncached points of a batch go to the objective in
//!   one [`Objective::eval_batch`] call, in their original order, so the
//!   pre-assigned seed streams of `SimObjective` fan across
//!   `coordinator::pool` workers and stay bit-identical to the sequential
//!   loop at any worker count (the PR 1 contract);
//! * a uniform eval-trace ([`EvalRecord`]) and best-so-far tracking, so
//!   every tuner gets a convergence history for free;
//! * a **modeled wall-clock cost model**: observations are not the
//!   currency a cluster operator pays in — wall-clock is, and a tuner
//!   that batches 64 probes per wave finishes the wave in (almost) the
//!   same time as one that batches 3. Every dispatched batch is charged
//!   `max(member simulated durations) + dispatch_overhead` — the max,
//!   not the sum, because the batch members run as one parallel wave —
//!   accumulated into [`EvalBroker::elapsed_model_time`] and capped by
//!   the third budget axis, [`Budget::max_model_time`].
//!
//! **Time-axis truncation semantics.** The time axis is checked *before*
//! a dispatch, never mid-wave: once `elapsed_model_time` reaches
//! `max_model_time` the broker serves nothing further (`remaining() == 0`,
//! `try_eval*` truncate/return `None` — the same graceful stop as the
//! observation axes), but the wave that crossed the line is charged in
//! full. `elapsed_model_time` therefore never exceeds `max_model_time`
//! by more than one batch's cost ([`EvalBroker::max_batch_cost`]), and
//! the cost model only *meters* — it never perturbs dispatch order,
//! batch composition or observation seeds, so metered trajectories stay
//! bit-identical to unmetered ones up to the truncation point.
//!
//! **Cache caveat (continuous-θ tuners).** A cache hit replays a past
//! observation instead of consuming the objective's next seed, so the
//! observation stream is no longer bit-identical to an uncached run, and
//! quantization (default 1e-6 per coordinate) aliases points closer than
//! the quantum. Tuners whose trajectories must replay exactly — the SPSA
//! family — declare [`CachePolicy::Off`] via `Tuner::cache_policy`.

use std::collections::BTreeMap;

use super::objective::Objective;

/// Hard evaluation budget of one tuning run: three independently
/// exhaustible axes (observations, dispatch rounds, modeled wall-clock),
/// each with the same graceful-truncation semantics — whichever runs out
/// first stops the run, and the tuner keeps its best-so-far.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Budget {
    /// Maximum live observations (cache hits are free).
    pub max_obs: u64,
    /// Maximum dispatch rounds (each underlying `eval_batch` call is one
    /// round — a coarse wall-clock proxy: one round ≈ one parallel wave).
    pub max_batches: u64,
    /// Maximum modeled wall-clock, in simulated seconds
    /// ([`EvalBroker::elapsed_model_time`]); `f64::INFINITY` = uncapped.
    /// Checked before each dispatch, so a run may overshoot by at most
    /// one batch's cost (see the module docs).
    pub max_model_time: f64,
}

impl Budget {
    /// Observation budget with unlimited batches — the common case.
    pub fn obs(max_obs: u64) -> Budget {
        Budget { max_obs, max_batches: u64::MAX, max_model_time: f64::INFINITY }
    }

    /// No limits (compat path for callers that meter elsewhere).
    pub fn unlimited() -> Budget {
        Budget::obs(u64::MAX)
    }

    /// Builder: additionally cap dispatch rounds.
    pub fn with_batches(mut self, max_batches: u64) -> Budget {
        self.max_batches = max_batches;
        self
    }

    /// Builder: additionally cap modeled wall-clock (simulated seconds).
    pub fn with_model_time(mut self, max_model_time: f64) -> Budget {
        assert!(max_model_time >= 0.0, "model-time budget must be non-negative");
        self.max_model_time = max_model_time;
        self
    }

    /// True when no axis constrains anything — the signal for tuners with
    /// no intrinsic stopping rule (random search) to apply their own cap.
    pub fn is_unlimited(&self) -> bool {
        self.max_obs == u64::MAX
            && self.max_batches == u64::MAX
            && self.max_model_time.is_infinite()
    }
}

/// Which budget axis stopped a run. Axes are checked in one documented,
/// fixed order — **observations, then batches, then model time** — so an
/// exactly-simultaneous exhaustion of several axes reports
/// deterministically (observations win, then batches). Pinned by test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BudgetAxis {
    Observations,
    Batches,
    ModelTime,
}

/// Modeled per-wave dispatch overhead in simulated seconds: job
/// submission, container scheduling and tear-down latency, charged once
/// per dispatched batch regardless of its size — the term that makes a
/// 3-probe wave and a 64-probe wave cost (almost) the same wall-clock.
pub const DEFAULT_DISPATCH_OVERHEAD_S: f64 = 5.0;

/// Whether the broker may serve repeat θs from memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CachePolicy {
    /// Every observation reaches the objective — bit-exact seed streams.
    Off,
    /// Memoize by quantized θ; revisits are free (and noise-frozen).
    Quantized,
}

/// Where an observation's value came from — the provenance axis of the
/// trace. `Live` values were measured by the objective during this run;
/// everything else replays a number observed earlier (same trial for
/// `Memo`, a previous campaign for `Store`) under a *different* noise
/// stream, i.e. the value is **noise-frozen** at its original draw.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ObsSource {
    /// Dispatched to the objective in this run.
    Live,
    /// Served from this trial's quantized memo cache (or a within-batch
    /// duplicate of a point dispatched in the same wave).
    Memo,
    /// Served from a cross-campaign [`ObservationStore`] tier — observed
    /// in an earlier campaign, possibly at a nearby (store-quantized) θ.
    ///
    /// [`ObservationStore`]: crate::coordinator::ObservationStore
    Store,
}

/// One observed point of the uniform convergence trace.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    /// Live observations consumed *after* this record (cache hits repeat
    /// the previous count).
    pub obs: u64,
    /// Modeled wall-clock elapsed *after* this record, in simulated
    /// seconds. Members of one dispatched batch all carry the post-wave
    /// time (they finish together when the wave finishes); cache hits
    /// repeat the previous elapsed time — replays are free in time too.
    pub model_time: f64,
    pub theta: Vec<f64>,
    pub f: f64,
    /// `true` iff the value was served from memory (`source != Live`).
    pub cached: bool,
    /// Provenance of the value (live / memo replay / store replay).
    pub source: ObsSource,
}

/// The record where the best **live-measured** f was *first* achieved:
/// store- and memo-served replays are skipped, so the result is the
/// first live-verified best — the honest counterpart of a best-so-far
/// that warm-start seeding can otherwise set at obs 0 for free. NaN
/// observations are ignored; `None` when the trace has no live record
/// with a non-NaN f.
pub fn live_best(trace: &[EvalRecord]) -> Option<&EvalRecord> {
    let mut best: Option<&EvalRecord> = None;
    for r in trace {
        if r.source != ObsSource::Live || r.f.is_nan() {
            continue;
        }
        // strict `<` keeps the FIRST record achieving the best f
        match best {
            Some(b) if r.f >= b.f => {}
            _ => best = Some(r),
        }
    }
    best
}

/// Budget-metered, memoizing, trace-keeping wrapper around an objective.
pub struct EvalBroker<'a> {
    objective: &'a mut dyn Objective,
    budget: Budget,
    policy: CachePolicy,
    /// Cache quantization step per coordinate (θ ∈ [0,1]).
    quant: f64,
    memo: BTreeMap<Vec<i64>, f64>,
    evals_used: u64,
    batches_used: u64,
    cache_hits: u64,
    /// Modeled per-wave dispatch overhead (see [`DEFAULT_DISPATCH_OVERHEAD_S`]).
    dispatch_overhead_s: f64,
    /// Modeled cluster probe slots: how many batch members can run
    /// concurrently. 0 (the default) = unlimited — the flat
    /// `max(durations)` charging every pre-contention test pins. With
    /// m > 0 a k-probe wave runs in ⌈k/m⌉ sub-waves of at most m probes
    /// each (dispatch order), and the wave is charged the SUM of the
    /// sub-wave maxima plus ONE dispatch overhead.
    slots: usize,
    /// Modeled wall-clock spent so far (simulated seconds).
    elapsed_model_time: f64,
    /// Costliest single wave charged so far — the bound on how far the
    /// time axis can overshoot (see the module docs).
    max_batch_cost: f64,
    trace: Vec<EvalRecord>,
    best: Option<(Vec<f64>, f64)>,
    /// Provenance of the current `best` (meaningless while `best` is None).
    best_source: ObsSource,
    /// Best point among **live** observations only — what the trial
    /// actually measured this run, never a noise-frozen replay.
    best_live: Option<(Vec<f64>, f64)>,
    /// Cross-campaign store tier: consulted on memo miss, keyed by the
    /// (coarser) `store_quant` θ-cell. Populated by
    /// [`EvalBroker::with_store_tier`]; empty outside a service context.
    store: BTreeMap<Vec<i64>, f64>,
    store_quant: f64,
    store_hits: u64,
}

impl<'a> EvalBroker<'a> {
    /// Wrap `objective`. The cache starts [`CachePolicy::Off`] — the safe,
    /// bit-exact default; registry-driven runs apply the tuner's declared
    /// policy.
    pub fn new(objective: &'a mut dyn Objective, budget: Budget) -> Self {
        EvalBroker {
            objective,
            budget,
            policy: CachePolicy::Off,
            quant: 1e-6,
            memo: BTreeMap::new(),
            evals_used: 0,
            batches_used: 0,
            cache_hits: 0,
            dispatch_overhead_s: DEFAULT_DISPATCH_OVERHEAD_S,
            slots: 0,
            elapsed_model_time: 0.0,
            max_batch_cost: 0.0,
            trace: Vec::new(),
            best: None,
            best_source: ObsSource::Live,
            best_live: None,
            store: BTreeMap::new(),
            store_quant: 1e-6,
            store_hits: 0,
        }
    }

    pub fn with_cache(mut self, policy: CachePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Cache quantization step (only meaningful with `Quantized`).
    pub fn with_quantization(mut self, quant: f64) -> Self {
        assert!(quant > 0.0, "quantization step must be positive");
        self.quant = quant;
        self
    }

    /// The cache quantization step in effect (θ-cell size of `Quantized`).
    /// Tuners that deduplicate their own proposals against the memo (TPE)
    /// read this so their notion of "already observed" matches the cache's.
    pub fn quantization(&self) -> f64 {
        self.quant
    }

    /// Modeled per-wave dispatch overhead, in simulated seconds.
    pub fn with_dispatch_overhead(mut self, seconds: f64) -> Self {
        assert!(seconds >= 0.0, "dispatch overhead must be non-negative");
        self.dispatch_overhead_s = seconds;
        self
    }

    /// Model slot contention: the cluster can run at most `slots` probes
    /// of one wave concurrently, so a k-probe wave is charged in ⌈k/m⌉
    /// sub-waves (the sum of per-group-of-m duration maxima, dispatch
    /// order, plus one overhead) instead of one flat max. `slots == 0`
    /// restores the uncontended default. Charging only — dispatch order,
    /// batch composition and observation seeds are untouched, so metered
    /// values stay bit-identical to the flat model's.
    pub fn with_slots(mut self, slots: usize) -> Self {
        self.slots = slots;
        self
    }

    /// Probe-slot count in effect (0 = uncontended flat charging).
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Preload spend recorded by an earlier (checkpointed) segment of the
    /// same logical run, so budget checks, `remaining()`-derived chunk
    /// sizes and `EvalRecord` obs/model-time stamps continue exactly where
    /// the interrupted run stopped. The budget axes still cap the TOTAL —
    /// prior plus new spend.
    pub fn with_prior_spend(mut self, obs: u64, batches: u64, elapsed_s: f64) -> Self {
        assert!(elapsed_s >= 0.0, "prior elapsed time must be non-negative");
        self.evals_used = obs;
        self.batches_used = batches;
        self.elapsed_model_time = elapsed_s;
        self
    }

    /// Attach a cross-campaign store tier: `(θ, f)` pairs observed by
    /// earlier campaigns, keyed by the (typically much coarser) store
    /// quantum. Consulted on memo miss for [`CachePolicy::Quantized`]
    /// tuners; hits are free in observations AND model time, recorded
    /// with [`ObsSource::Store`] — i.e. noise-frozen. The tier is
    /// deliberately **inert under [`CachePolicy::Off`]**: SPSA-family
    /// tuners keep their bit-exact seed streams, and warm-start for them
    /// goes through [`EvalBroker::ingest`] instead. First entry per cell
    /// wins (replay-stable, like the memo).
    pub fn with_store_tier(mut self, quant: f64, entries: &[(Vec<f64>, f64)]) -> Self {
        assert!(quant > 0.0, "store quantization step must be positive");
        self.store_quant = quant;
        for (theta, f) in entries {
            let k: Vec<i64> = theta.iter().map(|t| (t / quant).round() as i64).collect();
            self.store.entry(k).or_insert(*f);
        }
        self
    }

    /// Seed the trace with one observation served by the cross-campaign
    /// store *before* the tuner runs: a free [`ObsSource::Store`] record
    /// (no observation, no model time) that participates in best-so-far
    /// tracking. This is how a matched prior campaign's incumbent reaches
    /// a warm-started trial for **every** cache policy — under
    /// [`CachePolicy::Off`] the tuner itself never sees the value, so its
    /// seed stream stays bit-exact, but the trial's best already starts
    /// at the incumbent. Under `Quantized` the value also lands in the
    /// memo, so the tuner revisiting the incumbent θ gets a free hit.
    pub fn ingest(&mut self, theta: &[f64], f: f64) {
        if self.policy == CachePolicy::Quantized {
            let k = self.key(theta);
            self.memo.entry(k).or_insert(f);
        }
        self.store_hits += 1;
        self.trace.push(EvalRecord {
            obs: self.evals_used,
            model_time: self.elapsed_model_time,
            theta: theta.to_vec(),
            f,
            cached: true,
            source: ObsSource::Store,
        });
        self.note_best(theta, f, ObsSource::Store);
    }

    /// Why the budget is spent, or `None` while every axis has room.
    /// Axes are checked in the documented fixed order of [`BudgetAxis`]:
    /// observations, then batches, then model time — an
    /// exactly-simultaneous exhaustion reports the earlier axis.
    pub fn stop_reason(&self) -> Option<BudgetAxis> {
        if self.evals_used >= self.budget.max_obs {
            return Some(BudgetAxis::Observations);
        }
        if self.batches_used >= self.budget.max_batches {
            return Some(BudgetAxis::Batches);
        }
        if self.elapsed_model_time >= self.budget.max_model_time {
            return Some(BudgetAxis::ModelTime);
        }
        None
    }

    /// Observations still affordable (0 once any budget axis is spent).
    pub fn remaining(&self) -> u64 {
        match self.stop_reason() {
            Some(_) => 0,
            None => self.budget.max_obs.saturating_sub(self.evals_used),
        }
    }

    pub fn exhausted(&self) -> bool {
        self.remaining() == 0
    }

    /// Live observations consumed so far (incl. [`EvalBroker::charge`]).
    pub fn evals_used(&self) -> u64 {
        self.evals_used
    }

    pub fn batches_used(&self) -> u64 {
        self.batches_used
    }

    pub fn cache_hits(&self) -> u64 {
        self.cache_hits
    }

    /// Observations served by the cross-campaign store tier (lookup hits
    /// plus [`EvalBroker::ingest`]ed seeds). Disjoint from
    /// [`EvalBroker::cache_hits`], which counts same-trial memo replays.
    pub fn store_hits(&self) -> u64 {
        self.store_hits
    }

    /// Modeled wall-clock spent so far, in simulated seconds: per
    /// dispatched wave, the max of its members' simulated durations plus
    /// the dispatch overhead, plus any [`EvalBroker::charge`]d external
    /// time. Cache hits cost nothing.
    pub fn elapsed_model_time(&self) -> f64 {
        self.elapsed_model_time
    }

    /// Cost of the most expensive single wave charged so far — the bound
    /// on the time axis's possible overshoot past `max_model_time`.
    pub fn max_batch_cost(&self) -> f64 {
        self.max_batch_cost
    }

    pub fn budget(&self) -> Budget {
        self.budget
    }

    pub fn dim(&self) -> usize {
        self.objective.dim()
    }

    /// Best observed point so far: (θ, f).
    pub fn best(&self) -> Option<(&[f64], f64)> {
        self.best.as_ref().map(|(t, f)| (t.as_slice(), *f))
    }

    /// `true` iff the current best-so-far was served by the store tier —
    /// i.e. its f was measured in an *earlier* campaign under a different
    /// noise stream and was never beaten (or matched) by a live
    /// observation in this run. A deployment of this best is noise-frozen.
    pub fn best_noise_frozen(&self) -> bool {
        self.best.is_some() && self.best_source == ObsSource::Store
    }

    /// Best point among **live** observations only (θ, f): the strongest
    /// claim this run actually verified by measurement. `None` until the
    /// first live observation lands.
    pub fn best_live(&self) -> Option<(&[f64], f64)> {
        self.best_live.as_ref().map(|(t, f)| (t.as_slice(), *f))
    }

    /// Shared best-so-far update. NaN-hygiene: `f < bf` is already false
    /// for NaN candidates, but the first observation lands via the None
    /// arm — a NaN there would poison best-so-far for the whole trial.
    fn note_best(&mut self, theta: &[f64], f: f64, source: ObsSource) {
        let better = match &self.best {
            Some((_, bf)) => f < *bf,
            None => !f.is_nan(),
        };
        if better {
            self.best = Some((theta.to_vec(), f));
            self.best_source = source;
        }
        if source == ObsSource::Live {
            let better_live = match &self.best_live {
                Some((_, bf)) => f < *bf,
                None => !f.is_nan(),
            };
            if better_live {
                self.best_live = Some((theta.to_vec(), f));
            }
        }
    }

    /// The uniform convergence trace (every served observation, in order).
    pub fn trace(&self) -> &[EvalRecord] {
        &self.trace
    }

    pub fn take_trace(&mut self) -> Vec<EvalRecord> {
        std::mem::take(&mut self.trace)
    }

    /// Account `n` live runs performed *outside* this broker's objective
    /// against the budget (e.g. PPABS profiling its training corpus, which
    /// runs other workloads), plus their modeled wall-clock `duration_s`
    /// (e.g. the summed simulated seconds of the profiling runs — external
    /// profiling is priced in the same currency as dispatched waves).
    /// Returns how many runs were granted; the caller must scale its
    /// external work down to the grant, and the charged time scales with
    /// it (`duration_s · granted/n`). With `n == 0` the full `duration_s`
    /// is charged as pure wall-clock — the pattern for pricing profiling
    /// time that is only measurable *after* the observation grant. Like a
    /// dispatched wave, a time charge may overshoot `max_model_time`;
    /// every later request then sees `remaining() == 0`.
    pub fn charge(&mut self, n: u64, duration_s: f64) -> u64 {
        let granted = n.min(self.remaining());
        self.evals_used += granted;
        let charged_s = if n == 0 { duration_s } else { duration_s * granted as f64 / n as f64 };
        self.elapsed_model_time += charged_s;
        if charged_s > 0.0 {
            // an external profiling block counts as one wave for the
            // overshoot bound: elapsed ≤ max_model_time + max_batch_cost
            // holds for charge-metered tuners (PPABS) too
            self.max_batch_cost = self.max_batch_cost.max(charged_s);
        }
        granted
    }

    fn key(&self, theta: &[f64]) -> Vec<i64> {
        theta.iter().map(|t| (t / self.quant).round() as i64).collect()
    }

    /// One observation. `None` once the budget is exhausted — the caller's
    /// graceful-stop signal (return best-so-far).
    pub fn try_eval(&mut self, theta: &[f64]) -> Option<f64> {
        self.try_eval_batch(std::slice::from_ref(&theta.to_vec())).first().copied()
    }

    /// Observe a batch of points. Serves each point in order — from the
    /// cache when allowed, else from the objective — and **truncates at
    /// the first point the budget cannot afford**: the returned vector may
    /// be shorter than `thetas` (empty when exhausted up front). All
    /// uncached points go to the objective in ONE `eval_batch` call, in
    /// their original relative order, so per-observation seed derivation
    /// matches the plain sequential loop exactly.
    pub fn try_eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        // Plan: which points are served from memory, which dispatch, and
        // where the budget truncates the batch.
        enum Source {
            Memo(f64),
            /// Served by the cross-campaign store tier (noise-frozen).
            Store(f64),
            /// Index into the dispatch vector (also covers duplicates of a
            /// not-yet-dispatched point within the same batch).
            Dispatch(usize),
        }
        let mut plan: Vec<Source> = Vec::with_capacity(thetas.len());
        let mut dispatch: Vec<Vec<f64>> = Vec::new();
        let mut pending: BTreeMap<Vec<i64>, usize> = BTreeMap::new();
        let affordable = self.remaining();
        for theta in thetas {
            let use_cache = self.policy == CachePolicy::Quantized;
            let k = if use_cache { self.key(theta) } else { Vec::new() };
            if use_cache {
                if let Some(&f) = self.memo.get(&k) {
                    plan.push(Source::Memo(f));
                    continue;
                }
                if let Some(&i) = pending.get(&k) {
                    plan.push(Source::Dispatch(i));
                    continue;
                }
                // memo miss → the (coarser-celled) cross-campaign tier
                if !self.store.is_empty() {
                    let sk: Vec<i64> = theta
                        .iter()
                        .map(|t| (t / self.store_quant).round() as i64)
                        .collect();
                    if let Some(&f) = self.store.get(&sk) {
                        plan.push(Source::Store(f));
                        continue;
                    }
                }
            }
            if (dispatch.len() as u64) >= affordable {
                break; // budget exhausted: truncate here
            }
            if use_cache {
                pending.insert(k, dispatch.len());
            }
            plan.push(Source::Dispatch(dispatch.len()));
            dispatch.push(theta.clone());
        }

        let values: Vec<f64> = if dispatch.is_empty() {
            Vec::new()
        } else {
            self.batches_used += 1;
            self.evals_used += dispatch.len() as u64;
            let vs = self.objective.eval_batch(&dispatch);
            // Wall-clock cost of the wave: its members run in parallel, so
            // the wave takes as long as its slowest member (max, NOT sum —
            // the parallelism contract), plus the per-dispatch overhead.
            // Objectives that know their runs' simulated durations report
            // them; for the rest the observation value is the documented
            // proxy (exact for the ExecTime metric).
            let durations = match self.objective.last_durations() {
                Some(d) if d.len() == vs.len() => d,
                _ => vs.clone(),
            };
            // With m > 0 slots, the k probes run in ⌈k/m⌉ sub-waves of at
            // most m each (dispatch order): the wave takes the SUM of the
            // sub-wave maxima. m == 0 (or m ≥ k) degenerates to the flat
            // max — one sub-wave. One overhead either way: it models job
            // submission latency, paid once per dispatched batch.
            let runtime: f64 = if self.slots == 0 {
                durations.iter().cloned().fold(0.0_f64, f64::max)
            } else {
                durations
                    .chunks(self.slots)
                    .map(|sub| sub.iter().cloned().fold(0.0_f64, f64::max))
                    .sum()
            };
            let wave_cost = runtime + self.dispatch_overhead_s;
            self.elapsed_model_time += wave_cost;
            self.max_batch_cost = self.max_batch_cost.max(wave_cost);
            vs
        };
        debug_assert_eq!(values.len(), dispatch.len());
        if self.policy == CachePolicy::Quantized {
            for (theta, &f) in dispatch.iter().zip(&values) {
                self.memo.insert(self.key(theta), f);
            }
        }

        let mut out = Vec::with_capacity(plan.len());
        let mut dispatched_seen = vec![false; dispatch.len()];
        for (src, theta) in plan.iter().zip(thetas) {
            let (f, source) = match src {
                Source::Memo(f) => (*f, ObsSource::Memo),
                Source::Store(f) => (*f, ObsSource::Store),
                Source::Dispatch(i) => {
                    let first = !dispatched_seen[*i];
                    dispatched_seen[*i] = true;
                    (values[*i], if first { ObsSource::Live } else { ObsSource::Memo })
                }
            };
            let cached = source != ObsSource::Live;
            match source {
                ObsSource::Memo => self.cache_hits += 1,
                ObsSource::Store => self.store_hits += 1,
                ObsSource::Live => {}
            }
            self.trace.push(EvalRecord {
                obs: self.evals_used,
                model_time: self.elapsed_model_time,
                theta: theta.clone(),
                f,
                cached,
                source,
            });
            self.note_best(theta, f, source);
            out.push(f);
        }
        out
    }
}

/// The broker as an [`Objective`], so batch-driven tuners (`Spsa::run_state`)
/// plug in unchanged. This facade has no graceful-stop channel: callers
/// must check [`EvalBroker::remaining`] before each request (as
/// `Spsa::run_broker` does) — an over-budget request here is a caller bug
/// and panics rather than fabricating an observation.
impl Objective for EvalBroker<'_> {
    fn dim(&self) -> usize {
        self.objective.dim()
    }

    fn eval(&mut self, theta: &[f64]) -> f64 {
        self.try_eval(theta)
            .expect("EvalBroker budget exhausted — check remaining() before eval")
    }

    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        let out = self.try_eval_batch(thetas);
        assert_eq!(
            out.len(),
            thetas.len(),
            "EvalBroker budget exhausted mid-batch — check remaining() before eval_batch"
        );
        out
    }

    fn evals(&self) -> u64 {
        self.evals_used
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::objective::QuadraticObjective;

    fn quad() -> QuadraticObjective {
        QuadraticObjective::new(vec![0.3, 0.7], 0.05, 9)
    }

    /// Pathological objective: first observation NaN, second +inf, then
    /// finite — the NaN-hygiene probe for best-so-far tracking.
    struct NanThenFinite {
        evals: u64,
    }

    impl Objective for NanThenFinite {
        fn dim(&self) -> usize {
            1
        }

        fn eval(&mut self, _theta: &[f64]) -> f64 {
            self.evals += 1;
            match self.evals {
                1 => f64::NAN,
                2 => f64::INFINITY,
                _ => 5.0,
            }
        }

        fn evals(&self) -> u64 {
            self.evals
        }
    }

    #[test]
    fn nan_first_observation_does_not_poison_best_so_far() {
        let mut obj = NanThenFinite { evals: 0 };
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10));
        assert!(b.try_eval(&[0.1]).is_some());
        assert!(b.best().is_none(), "a NaN observation must not become the best");
        assert!(b.try_eval(&[0.2]).is_some());
        // +inf is comparable — a legitimate (terrible) best
        assert_eq!(b.best().map(|(_, f)| f), Some(f64::INFINITY));
        assert!(b.try_eval(&[0.3]).is_some());
        let (_, bf) = b.best().expect("finite best");
        assert_eq!(bf, 5.0);
        assert!(!bf.is_nan());
    }

    #[test]
    fn meters_observations_and_batches() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10));
        assert_eq!(b.remaining(), 10);
        let f = b.try_eval(&[0.5, 0.5]).unwrap();
        assert!(f.is_finite());
        assert_eq!(b.evals_used(), 1);
        assert_eq!(b.batches_used(), 1);
        let fs = b.try_eval_batch(&[vec![0.1, 0.1], vec![0.9, 0.9]]);
        assert_eq!(fs.len(), 2);
        assert_eq!(b.evals_used(), 3);
        assert_eq!(b.batches_used(), 2);
        assert_eq!(b.remaining(), 7);
        assert_eq!(b.trace().len(), 3);
    }

    #[test]
    fn budget_exhaustion_truncates_gracefully() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(3));
        let pts: Vec<Vec<f64>> = (0..5).map(|i| vec![0.1 * i as f64, 0.2]).collect();
        let fs = b.try_eval_batch(&pts);
        assert_eq!(fs.len(), 3, "batch must truncate at the budget");
        assert_eq!(b.evals_used(), 3);
        assert!(b.exhausted());
        assert!(b.try_eval(&[0.5, 0.5]).is_none());
        assert!(b.try_eval_batch(&pts).is_empty());
        // best-so-far survives exhaustion — the partial result
        let (bt, bf) = b.best().expect("best-so-far");
        assert_eq!(bt.len(), 2);
        assert!(bf.is_finite());
        assert_eq!(obj.evals(), 3, "objective saw exactly the budget");
    }

    #[test]
    fn batch_budget_axis_stops_dispatch() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(100).with_batches(2));
        assert!(b.try_eval(&[0.1, 0.1]).is_some());
        assert!(b.try_eval(&[0.2, 0.2]).is_some());
        assert_eq!(b.batches_used(), 2);
        assert_eq!(b.remaining(), 0, "batch budget spent");
        assert!(b.try_eval(&[0.3, 0.3]).is_none());
    }

    #[test]
    fn memo_values_independent_of_population_order() {
        // The memo/pending maps are BTreeMaps (`repro lint`'s
        // unordered-map rule): whatever order keys were inserted in, a
        // revisited θ must replay the exact value it was first observed
        // at, and identically-fed brokers must expose identical traces.
        let pts: Vec<Vec<f64>> = (0..6).map(|i| vec![0.1 * i as f64, 0.05 * i as f64]).collect();
        let mut fwd_obj = quad();
        let mut fwd =
            EvalBroker::new(&mut fwd_obj, Budget::obs(100)).with_cache(CachePolicy::Quantized);
        let fwd_vals = fwd.try_eval_batch(&pts);

        let mut rev_pts = pts.clone();
        rev_pts.reverse();
        let mut rev_obj = quad();
        let mut rev =
            EvalBroker::new(&mut rev_obj, Budget::obs(100)).with_cache(CachePolicy::Quantized);
        let rev_vals = rev.try_eval_batch(&rev_pts);

        // each broker replays its own first-observed value for every θ —
        // the cache key lookup is exact whatever order keys went in
        // (observed *values* differ between the runs: the noise stream is
        // positional by design)
        for (p, want) in pts.iter().zip(&fwd_vals) {
            assert_eq!(fwd.try_eval(p), Some(*want));
        }
        for (p, want) in rev_pts.iter().zip(&rev_vals) {
            assert_eq!(rev.try_eval(p), Some(*want));
        }
        assert_eq!(fwd.evals_used(), rev.evals_used(), "replays are cache hits on both");

        // and two identically-fed brokers produce bit-identical traces
        let mut twin_obj = quad();
        let mut twin =
            EvalBroker::new(&mut twin_obj, Budget::obs(100)).with_cache(CachePolicy::Quantized);
        let twin_vals = twin.try_eval_batch(&pts);
        assert_eq!(fwd_vals, twin_vals);
    }

    #[test]
    fn cache_hit_is_free_and_objective_counter_does_not_grow() {
        let mut obj = quad();
        let mut b =
            EvalBroker::new(&mut obj, Budget::obs(10)).with_cache(CachePolicy::Quantized);
        let theta = [0.25, 0.75];
        let a = b.try_eval(&theta).unwrap();
        let evals_after_first = b.evals_used();
        let c = b.try_eval(&theta).unwrap();
        assert_eq!(a, c, "cache must replay the recorded observation");
        assert_eq!(b.evals_used(), evals_after_first, "cache hit charged the budget");
        assert_eq!(b.cache_hits(), 1);
        assert_eq!(obj.evals(), 1, "Objective::evals() grew on a repeated θ");
    }

    #[test]
    fn cache_off_pays_every_time() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10));
        let theta = [0.25, 0.75];
        b.try_eval(&theta).unwrap();
        b.try_eval(&theta).unwrap();
        assert_eq!(b.evals_used(), 2);
        assert_eq!(b.cache_hits(), 0);
        assert_eq!(obj.evals(), 2);
    }

    #[test]
    fn within_batch_duplicates_dispatch_once_under_cache() {
        let mut obj = quad();
        let mut b =
            EvalBroker::new(&mut obj, Budget::obs(10)).with_cache(CachePolicy::Quantized);
        let fs = b.try_eval_batch(&[vec![0.4, 0.4], vec![0.4, 0.4], vec![0.6, 0.6]]);
        assert_eq!(fs.len(), 3);
        assert_eq!(fs[0], fs[1]);
        assert_eq!(b.evals_used(), 2, "duplicate θ in one batch must dispatch once");
        assert_eq!(obj.evals(), 2);
    }

    #[test]
    fn quantization_aliases_nearby_points() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10))
            .with_cache(CachePolicy::Quantized)
            .with_quantization(0.1);
        let a = b.try_eval(&[0.50, 0.50]).unwrap();
        let c = b.try_eval(&[0.52, 0.48]).unwrap(); // same 0.1-cell
        assert_eq!(a, c);
        assert_eq!(b.evals_used(), 1);
    }

    #[test]
    fn charge_meters_external_runs() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10));
        assert_eq!(b.charge(4, 100.0), 4);
        assert_eq!(b.evals_used(), 4);
        assert_eq!(b.elapsed_model_time(), 100.0);
        assert_eq!(b.charge(20, 200.0), 6, "grant clips to the remaining budget");
        assert!(b.exhausted());
        assert_eq!(
            b.elapsed_model_time(),
            100.0 + 200.0 * 6.0 / 20.0,
            "charged time scales with the clipped grant"
        );
        assert_eq!(obj.evals(), 0, "charge must not touch the objective");
        // n == 0: price pure wall-clock (post-grant profiling measurement)
        let before = b.elapsed_model_time();
        assert_eq!(b.charge(0, 37.5), 0);
        assert_eq!(b.elapsed_model_time(), before + 37.5);
    }

    #[test]
    fn trace_and_best_track_observations() {
        let mut obj = QuadraticObjective::new(vec![0.5, 0.5], 0.0, 1);
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10));
        b.try_eval(&[0.9, 0.9]).unwrap();
        b.try_eval(&[0.5, 0.5]).unwrap();
        b.try_eval(&[0.8, 0.8]).unwrap();
        let (bt, bf) = b.best().unwrap();
        assert_eq!(bt, &[0.5, 0.5]);
        assert!((bf - 1.0).abs() < 1e-9, "noise-free minimum is 1.0");
        assert_eq!(b.trace().len(), 3);
        assert_eq!(b.trace()[2].obs, 3);
        assert!(!b.trace()[2].cached);
    }

    #[test]
    fn objective_facade_passes_through_unlimited() {
        // Through the Objective facade with cache off, the broker is a
        // transparent proxy: same values, same counter.
        let thetas: Vec<Vec<f64>> = vec![vec![0.2, 0.2], vec![0.7, 0.1], vec![0.5, 0.9]];
        let mut plain = quad();
        let want = plain.eval_batch(&thetas);
        let mut wrapped_inner = quad();
        let mut b = EvalBroker::new(&mut wrapped_inner, Budget::unlimited());
        let got = Objective::eval_batch(&mut b, &thetas);
        assert_eq!(got, want);
        assert_eq!(Objective::evals(&b), 3);
    }

    #[test]
    #[should_panic(expected = "budget exhausted")]
    fn objective_facade_panics_when_overdrawn() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(1));
        Objective::eval(&mut b, &[0.5, 0.5]);
        Objective::eval(&mut b, &[0.6, 0.6]); // caller bug: no remaining() check
    }

    // -----------------------------------------------------------------
    // wall-clock cost model
    // -----------------------------------------------------------------

    /// Noise-free quadratic: f is deterministic and the broker's duration
    /// fallback uses f itself, so wave costs are exactly computable.
    fn quiet() -> QuadraticObjective {
        QuadraticObjective::new(vec![0.0, 0.0], 0.0, 1)
    }

    #[test]
    fn batch_cost_is_max_of_member_durations_plus_overhead() {
        let mut obj = quiet();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10)).with_dispatch_overhead(7.0);
        // f(θ) = 1 + θ·θ (noise-free): durations 1.25, 2.0, 1.08
        let pts = vec![vec![0.5, 0.0], vec![1.0, 0.0], vec![0.2, 0.2]];
        let fs = b.try_eval_batch(&pts);
        assert_eq!(fs.len(), 3);
        let want = 2.0 + 7.0; // max, NOT sum (1.25 + 2.0 + 1.08), + overhead
        assert!((b.elapsed_model_time() - want).abs() < 1e-12, "{}", b.elapsed_model_time());
        assert_eq!(b.max_batch_cost(), b.elapsed_model_time());
        // a second wave accumulates
        b.try_eval(&[0.5, 0.0]).unwrap();
        assert!((b.elapsed_model_time() - (want + 1.25 + 7.0)).abs() < 1e-12);
    }

    #[test]
    fn cache_hits_cost_no_model_time() {
        let mut obj = quiet();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10))
            .with_cache(CachePolicy::Quantized)
            .with_dispatch_overhead(5.0);
        b.try_eval(&[0.5, 0.5]).unwrap();
        let after_first = b.elapsed_model_time();
        assert!(after_first > 0.0);
        b.try_eval(&[0.5, 0.5]).unwrap(); // memo hit: free in obs AND time
        assert_eq!(b.elapsed_model_time(), after_first);
        assert_eq!(b.trace()[1].model_time, after_first, "hit repeats the elapsed time");
    }

    #[test]
    fn model_time_axis_truncates_gracefully_with_bounded_overshoot() {
        let mut obj = quiet();
        // each 1-point wave costs f + 5 ≥ 6: three waves cross a 14 s cap
        let mut b = EvalBroker::new(&mut obj, Budget::obs(1000).with_model_time(14.0))
            .with_dispatch_overhead(5.0);
        let mut waves = 0;
        while b.try_eval(&[0.1, 0.1]).is_some() {
            waves += 1;
            assert!(waves < 100, "time axis never exhausted");
        }
        assert_eq!(b.stop_reason(), Some(BudgetAxis::ModelTime));
        assert!(b.exhausted());
        assert_eq!(b.remaining(), 0);
        assert!(b.elapsed_model_time() >= 14.0);
        assert!(
            b.elapsed_model_time() <= 14.0 + b.max_batch_cost(),
            "overshoot {} exceeds one batch cost {}",
            b.elapsed_model_time() - 14.0,
            b.max_batch_cost()
        );
        // graceful: best-so-far survives, batch requests serve nothing
        assert!(b.best().is_some());
        assert!(b.try_eval_batch(&[vec![0.2, 0.2]]).is_empty());
    }

    #[test]
    fn stop_reason_axis_order_is_deterministic_and_documented() {
        // Exactly-simultaneous exhaustion of the observation and batch
        // axes: one 2-obs wave spends Budget{max_obs: 2, max_batches: 1}
        // to the observation AND the round. The documented check order
        // (observations, then batches, then model time) must report
        // Observations — pinned here so the axis precedence can never
        // silently flip.
        let mut obj = quiet();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(2).with_batches(1));
        let fs = b.try_eval_batch(&[vec![0.1, 0.1], vec![0.2, 0.2]]);
        assert_eq!(fs.len(), 2);
        assert!(b.exhausted());
        assert_eq!(b.stop_reason(), Some(BudgetAxis::Observations));

        // batches exhaust alone → Batches
        let mut obj2 = quiet();
        let mut b2 = EvalBroker::new(&mut obj2, Budget::obs(10).with_batches(1));
        b2.try_eval(&[0.1, 0.1]).unwrap();
        assert_eq!(b2.stop_reason(), Some(BudgetAxis::Batches));

        // all three spent at once still reports Observations first
        let mut obj3 = quiet();
        let mut b3 =
            EvalBroker::new(&mut obj3, Budget::obs(1).with_batches(1).with_model_time(1.0));
        b3.try_eval(&[0.0, 0.0]).unwrap();
        assert_eq!(b3.stop_reason(), Some(BudgetAxis::Observations));
    }

    #[test]
    fn slot_contention_charges_sub_wave_sums() {
        // f(θ) = 1 + θ·θ noise-free ⇒ durations are the values themselves.
        // 5 probes on a 2-slot cluster: sub-waves [2.0, 1.25], [1.08, 1.5],
        // [1.0] → max 2.0 + max 1.5 + max 1.0 = 4.5, plus one overhead.
        let pts = vec![
            vec![1.0, 0.0], // 2.0
            vec![0.5, 0.0], // 1.25
            vec![0.2, 0.2], // 1.08
            vec![0.5, 0.5], // 1.5
            vec![0.0, 0.0], // 1.0
        ];
        let mut obj = quiet();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10))
            .with_dispatch_overhead(7.0)
            .with_slots(2);
        let fs = b.try_eval_batch(&pts);
        assert_eq!(fs.len(), 5);
        assert!((b.elapsed_model_time() - (4.5 + 7.0)).abs() < 1e-12, "{}", b.elapsed_model_time());
        // the contended charge is ≥ the flat max and ≤ the sequential sum
        let mut flat_obj = quiet();
        let mut flat = EvalBroker::new(&mut flat_obj, Budget::obs(10)).with_dispatch_overhead(7.0);
        let flat_fs = flat.try_eval_batch(&pts);
        assert_eq!(fs, flat_fs, "contention must not perturb observed values");
        assert!(b.elapsed_model_time() >= flat.elapsed_model_time());
        let sum: f64 = fs.iter().sum();
        assert!(b.elapsed_model_time() < sum + 7.0);
        // m ≥ k and slots == 0 both degenerate to the flat charge
        for m in [0, 5, 64] {
            let mut o = quiet();
            let mut wide = EvalBroker::new(&mut o, Budget::obs(10))
                .with_dispatch_overhead(7.0)
                .with_slots(m);
            wide.try_eval_batch(&pts);
            assert_eq!(wide.elapsed_model_time(), flat.elapsed_model_time(), "slots {m}");
        }
        assert_eq!(b.slots(), 2);
    }

    #[test]
    fn prior_spend_continues_budget_and_trace_stamps() {
        // A resumed broker preloaded with the interrupted segment's spend
        // must meter exactly like the uninterrupted broker's continuation.
        let mut full_obj = quiet();
        let mut full = EvalBroker::new(&mut full_obj, Budget::obs(5)).with_dispatch_overhead(5.0);
        full.try_eval(&[0.5, 0.0]).unwrap();
        full.try_eval(&[0.2, 0.2]).unwrap();
        let (obs, batches, elapsed) =
            (full.evals_used(), full.batches_used(), full.elapsed_model_time());
        full.try_eval(&[0.5, 0.5]).unwrap();

        let mut res_obj = quiet();
        assert!(res_obj.advance_evals(2), "quadratic supports skipping");
        let mut resumed = EvalBroker::new(&mut res_obj, Budget::obs(5))
            .with_dispatch_overhead(5.0)
            .with_prior_spend(obs, batches, elapsed);
        assert_eq!(resumed.remaining(), 3, "prior spend counts against the budget");
        let f = resumed.try_eval(&[0.5, 0.5]).unwrap();
        assert_eq!(f, full.trace()[2].f);
        assert_eq!(resumed.evals_used(), full.evals_used());
        assert_eq!(resumed.trace()[0].obs, full.trace()[2].obs, "obs stamp continues");
        assert_eq!(
            resumed.trace()[0].model_time,
            full.trace()[2].model_time,
            "model-time stamp continues"
        );
        // exhausting the rest hits the same ceiling as the straight run
        resumed.try_eval(&[0.1, 0.1]).unwrap();
        resumed.try_eval(&[0.3, 0.3]).unwrap();
        assert!(resumed.exhausted());
        assert_eq!(resumed.stop_reason(), Some(BudgetAxis::Observations));
    }

    #[test]
    fn records_carry_post_wave_model_time() {
        let mut obj = quiet();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10)).with_dispatch_overhead(5.0);
        b.try_eval_batch(&[vec![0.5, 0.0], vec![1.0, 0.0]]);
        let t1 = b.elapsed_model_time();
        // both members of the wave finish when the wave finishes
        assert_eq!(b.trace()[0].model_time, t1);
        assert_eq!(b.trace()[1].model_time, t1);
        b.try_eval(&[0.2, 0.2]).unwrap();
        assert!(b.trace()[2].model_time > t1);
        assert_eq!(b.trace()[2].model_time, b.elapsed_model_time());
    }

    #[test]
    fn metering_does_not_perturb_values_or_seeds() {
        // The acceptance contract: the cost model meters, it must not
        // change what is dispatched — a time-capped run reproduces the
        // uncapped run's observations bit-exactly up to truncation.
        let thetas: Vec<Vec<f64>> = (0..6).map(|i| vec![0.1 * i as f64, 0.3]).collect();
        let mut obj_a = quad();
        let mut a = EvalBroker::new(&mut obj_a, Budget::obs(100));
        let want: Vec<f64> = thetas.iter().filter_map(|t| a.try_eval(t)).collect();
        let mut obj_b = quad();
        let mut b = EvalBroker::new(&mut obj_b, Budget::obs(100).with_model_time(1e9));
        let got: Vec<f64> = thetas.iter().filter_map(|t| b.try_eval(t)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn budget_unlimited_predicate() {
        assert!(Budget::unlimited().is_unlimited());
        assert!(!Budget::obs(10).is_unlimited());
        assert!(!Budget::unlimited().with_batches(5).is_unlimited());
        assert!(!Budget::unlimited().with_model_time(1e6).is_unlimited());
    }

    #[test]
    fn store_tier_hits_are_free_flagged_and_coarse() {
        let mut obj = quad();
        // store cell 0.1 wide: 0.33 and 0.37 land in different cells,
        // 0.33 and 0.31 in the same one
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10))
            .with_cache(CachePolicy::Quantized)
            .with_store_tier(0.1, &[(vec![0.33, 0.7], 42.0)]);
        let f = b.try_eval(&[0.31, 0.71]).unwrap();
        assert_eq!(f, 42.0, "same store cell serves the frozen value");
        assert_eq!(b.evals_used(), 0, "store hits are free in observations");
        assert_eq!(b.elapsed_model_time(), 0.0, "…and in model time");
        assert_eq!(b.store_hits(), 1);
        assert_eq!(b.cache_hits(), 0, "memo and store metering are disjoint");
        let r = &b.trace()[0];
        assert!(r.cached && r.source == ObsSource::Store);
        // a θ outside every stored cell dispatches live
        let live = b.try_eval(&[0.9, 0.1]).unwrap();
        assert_ne!(live, 42.0);
        assert_eq!(b.evals_used(), 1);
        assert_eq!(b.trace()[1].source, ObsSource::Live);
    }

    #[test]
    fn store_tier_is_inert_under_cache_policy_off() {
        // SPSA-family contract: with CachePolicy::Off every observation
        // reaches the objective — the store tier must not intercept.
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10))
            .with_store_tier(0.1, &[(vec![0.33, 0.7], 42.0)]);
        let f = b.try_eval(&[0.33, 0.7]).unwrap();
        assert_ne!(f, 42.0, "Off-policy eval must dispatch live");
        assert_eq!(b.evals_used(), 1);
        assert_eq!(b.store_hits(), 0);
    }

    #[test]
    fn ingest_seeds_best_without_spending_and_flags_noise_frozen() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10));
        b.ingest(&[0.3, 0.7], 1.0);
        assert_eq!(b.evals_used(), 0);
        assert_eq!(b.store_hits(), 1);
        assert_eq!(b.best().map(|(_, f)| f), Some(1.0));
        assert!(b.best_noise_frozen(), "store-served incumbent best is frozen");
        assert!(b.best_live().is_none(), "nothing live-verified yet");
        let r = &b.trace()[0];
        assert!(r.cached && r.source == ObsSource::Store && r.obs == 0);
        // a live observation that beats the incumbent un-freezes the best
        let f = b.try_eval(&[0.3, 0.7]).unwrap();
        if f < 1.0 {
            assert!(!b.best_noise_frozen());
        }
        assert_eq!(b.best_live().map(|(_, f)| f), Some(f));
    }

    #[test]
    fn ingest_nan_does_not_poison_best() {
        let mut obj = quad();
        let mut b = EvalBroker::new(&mut obj, Budget::obs(10));
        b.ingest(&[0.5, 0.5], f64::NAN);
        assert!(b.best().is_none(), "NaN seed must not become the best");
        let f = b.try_eval(&[0.3, 0.7]).unwrap();
        assert_eq!(b.best().map(|(_, bf)| bf), Some(f));
        assert!(!b.best_noise_frozen());
    }

    #[test]
    fn live_best_skips_noise_frozen_records() {
        let rec = |obs: u64, f: f64, source: ObsSource| EvalRecord {
            obs,
            model_time: obs as f64,
            theta: vec![0.5],
            f,
            cached: source != ObsSource::Live,
            source,
        };
        // a frozen store record at obs 0 holds the global best; the live
        // best is worse and lands later — the regression shape of the
        // "time-to-best 0.0 for a best never measured live" bug
        let trace = vec![
            rec(0, 5.0, ObsSource::Store),
            rec(1, f64::NAN, ObsSource::Live),
            rec(2, 9.0, ObsSource::Live),
            rec(2, 6.0, ObsSource::Memo),
            rec(3, 7.0, ObsSource::Live),
        ];
        let lb = live_best(&trace).expect("has live records");
        assert_eq!((lb.obs, lb.f), (2, 9.0), "first live-verified best");
        assert!(live_best(&[rec(0, 5.0, ObsSource::Store)]).is_none());
        assert!(live_best(&[]).is_none());
    }
}
