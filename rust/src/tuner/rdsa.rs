//! RDSA — random-direction stochastic approximation, the sibling
//! noisy-gradient method the paper's §7 proposes as future work (citing
//! Prashanth et al. [26]): instead of SPSA's Bernoulli ±1 simultaneous
//! perturbation, one iteration probes along a single *gaussian direction*
//! d and estimates ĝ(i) = d(i)·(f(θ+cd) − f(θ))/c.
//!
//! Everything else is deliberately shared with SPSA — the gain schedule
//! (constant α, per-coordinate perturbation scales c(i), the `max_step`
//! stability clip), the projection Γ onto [0,1]^n, and the per-iteration
//! seed-stream derivation — so RDSA-vs-SPSA comparisons isolate exactly
//! the perturbation distribution. The estimator itself lives in
//! [`SpsaVariant::Rdsa`] inside the shared iteration loop; this file is
//! the first-class registry face, forcing the estimator and delegating
//! budget planning and broker-paced iteration (one iteration per
//! `run_state` call through the pause path) to the shared SPSA tuner —
//! one copy of the planning rule for the whole noisy-gradient family.
//!
//! Determinism: the iteration loop draws each round's Bernoulli signs
//! *and* gaussian direction from an RNG seeded per iteration index, and
//! dispatches all probes of an iteration as one ordered `eval_batch` —
//! trajectories therefore reproduce bit-exactly across pause/resume, a
//! metered broker vs a direct run, and any worker count (tested below).

use crate::config::ParameterSpace;

use super::broker::{CachePolicy, EvalBroker};
use super::registry::{decode_checkpoint, encode_checkpoint, SpsaTuner, TuneOutcome, Tuner};
use super::spsa::{SpsaConfig, SpsaVariant};

/// RDSA behind the [`Tuner`] interface: SPSA's machinery with the
/// gaussian-direction gradient estimator.
pub struct RdsaTuner {
    /// Shared gain schedule (α, `max_step`, `grad_avg`, termination); the
    /// variant is forced to [`SpsaVariant::Rdsa`] at run time.
    pub config: SpsaConfig,
}

impl RdsaTuner {
    /// The paper's SPSA hyper-parameters with the §7 estimator swapped in.
    pub fn paper() -> RdsaTuner {
        RdsaTuner { config: SpsaConfig { variant: SpsaVariant::Rdsa, ..SpsaConfig::default() } }
    }
}

impl Tuner for RdsaTuner {
    fn name(&self) -> &'static str {
        "rdsa"
    }

    fn cache_policy(&self) -> CachePolicy {
        // like the rest of the SPSA family: a memo hit would skip the
        // objective's next seed and break bit-exact trajectory replay
        CachePolicy::Off
    }

    fn tune(&self, broker: &mut EvalBroker, space: &ParameterSpace, seed: u64) -> TuneOutcome {
        // Delegate to the SPSA tuner with the estimator forced: the
        // budget-to-whole-iterations planning rule and the result mapping
        // live in ONE place, so the two noisy-gradient family members can
        // never silently diverge.
        let forced = SpsaConfig { variant: SpsaVariant::Rdsa, ..self.config.clone() };
        SpsaTuner { config: forced }.tune(broker, space, seed)
    }

    fn checkpointable(&self) -> bool {
        true
    }

    fn tune_resumable(
        &self,
        broker: &mut EvalBroker,
        space: &ParameterSpace,
        seed: u64,
        resume: Option<&[u8]>,
    ) -> (TuneOutcome, Option<Vec<u8>>) {
        // Same delegation as `tune`, but the checkpoint envelope carries
        // THIS tuner's tag: an rdsa blob must not resume an spsa run (the
        // state format is shared, the estimator is not).
        let forced = SpsaConfig { variant: SpsaVariant::Rdsa, ..self.config.clone() };
        let inner = SpsaTuner { config: forced };
        let translated = resume.map(|bytes| {
            let st = decode_checkpoint(self.name(), bytes)
                .unwrap_or_else(|e| panic!("{}: bad checkpoint: {e}", self.name()));
            encode_checkpoint(inner.name(), st)
        });
        let (out, ck) = inner.tune_resumable(broker, space, seed, translated.as_deref());
        let ck = ck.map(|bytes| {
            let st = decode_checkpoint(inner.name(), &bytes)
                .expect("inner spsa checkpoint must round-trip");
            encode_checkpoint(self.name(), st)
        });
        (out, ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::tuner::broker::{Budget, EvalBroker};
    use crate::tuner::objective::{QuadraticObjective, SimObjective};
    use crate::util::rng::Rng;
    use crate::workloads::Benchmark;

    #[test]
    fn rdsa_tuner_descends_and_plans_whole_iterations() {
        let space = ParameterSpace::v1();
        let tuner = RdsaTuner::paper(); // grad_avg 2 → 3 obs/iter
        let mut obj = QuadraticObjective::new(vec![0.4; space.dim()], 0.01, 5);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(31));
        let out = tuner.tune(&mut broker, &space, 5);
        assert!(broker.evals_used() <= 30, "3-obs iterations can't spend 31");
        assert_eq!(broker.evals_used() % 3, 0);
        assert!(out.best_f.is_finite());
        assert_eq!(out.best_theta.len(), space.dim());
        assert!(!out.history.is_empty(), "RDSA must report its iteration history");
    }

    /// The satellite contract: with the same seed and the same gain
    /// schedule, both noisy-gradient tuners reproduce their trajectories
    /// bit-exactly across 1 vs N workers — every probe batch of an
    /// iteration goes through the broker's ordered dispatch (the pause
    /// path runs one iteration per `run_state` call), so pre-assigned
    /// observation seeds make thread count invisible.
    #[test]
    fn rdsa_and_spsa_trajectories_bit_exact_across_worker_counts() {
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut prof_rng = Rng::seeded(14);
        let w = Benchmark::Terasort.profile_scaled(200_000, 1 << 30, &mut prof_rng);
        let shared = SpsaConfig { grad_avg: 3, seed: 6, ..SpsaConfig::default() };

        let mut finals = Vec::new();
        for variant in [SpsaVariant::OneSided, SpsaVariant::Rdsa] {
            let cfg = SpsaConfig { variant, ..shared.clone() };
            let tuner: Box<dyn Tuner> = match variant {
                SpsaVariant::Rdsa => Box::new(RdsaTuner { config: cfg }),
                _ => Box::new(SpsaTuner { config: cfg }),
            };
            let run_with = |workers: usize| {
                let mut obj =
                    SimObjective::new(space.clone(), cluster.clone(), w.clone(), 17)
                        .with_workers(workers);
                let mut broker = EvalBroker::new(&mut obj, Budget::obs(20))
                    .with_cache(tuner.cache_policy());
                tuner.tune(&mut broker, &space, 6)
            };
            let seq = run_with(1);
            let par = run_with(4);
            assert_eq!(seq.history.len(), par.history.len(), "{variant:?}");
            for (a, b) in seq.history.iter().zip(&par.history) {
                assert_eq!(a.f_theta, b.f_theta, "{variant:?}");
                assert_eq!(a.grad_norm, b.grad_norm, "{variant:?}");
                assert_eq!(a.theta, b.theta, "{variant:?}");
            }
            assert_eq!(seq.best_theta, par.best_theta, "{variant:?}");
            finals.push(seq);
        }
        // same seed, same schedule — but a different perturbation
        // distribution must produce a different trajectory
        assert_ne!(
            finals[0].history.last().unwrap().theta,
            finals[1].history.last().unwrap().theta,
            "RDSA replayed SPSA's trajectory exactly — estimator not in effect"
        );
    }

    #[test]
    fn rdsa_variant_is_forced_even_if_config_disagrees() {
        // A caller constructing RdsaTuner around a OneSided config still
        // gets RDSA: the registry name must never lie about the estimator.
        let space = ParameterSpace::v1();
        let mis = RdsaTuner { config: SpsaConfig::default() }; // OneSided inside
        let forced = RdsaTuner::paper();
        let run = |t: &RdsaTuner| {
            let mut obj = QuadraticObjective::new(vec![0.3; space.dim()], 0.0, 9);
            let mut broker = EvalBroker::new(&mut obj, Budget::obs(12));
            t.tune(&mut broker, &space, 3).best_theta
        };
        assert_eq!(run(&mis), run(&forced));
    }
}
