//! Objective abstraction: `f(θ_A)` — one *noisy* observation of system
//! performance per call (paper Fig. 3's system-in-the-loop box).

use crate::cluster::ClusterSpec;
use crate::config::ParameterSpace;
use crate::sim::{simulate, SimOptions};
use crate::workloads::WorkloadProfile;

/// A tunable system observed through its scalar performance.
pub trait Objective {
    fn dim(&self) -> usize;
    /// One observation of f at θ_A ∈ [0,1]^n. Observations are noisy; the
    /// same θ may return different values (run-to-run variance).
    fn eval(&mut self, theta: &[f64]) -> f64;
    /// Observe f at a batch of points. The contract: element `i` of the
    /// result equals what `eval(&thetas[i])` would have returned had the
    /// points been evaluated one by one, in order — per-observation seed
    /// derivation included. The default implementation *is* that
    /// sequential loop; implementations may parallelize (SPSA's
    /// perturbation probes are independent jobs) as long as the results
    /// stay element-for-element identical.
    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        thetas.iter().map(|t| self.eval(t)).collect()
    }
    /// Total observations made so far (the paper's cost metric: 2/iter).
    fn evals(&self) -> u64;
}

/// Which job statistic the tuner minimizes. The paper's experiments use
/// execution time, and §4.2 notes "we can also have other performance
/// metrics — like number of records spilled to disk, memory and heap
/// usage or number of failed jobs".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Wall-clock job execution time in seconds (the paper's metric).
    ExecTime,
    /// Records written to map-side spill files ("Spilled Records").
    SpilledRecords,
    /// Bytes moved map→reduce over the network.
    ShuffledBytes,
    /// Reduce-side bytes hitting disk before the reduce function.
    ReduceSpill,
}

impl Metric {
    pub fn from_name(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "exectime" | "time" => Some(Metric::ExecTime),
            "spilledrecords" | "spills" => Some(Metric::SpilledRecords),
            "shuffledbytes" | "shuffle" => Some(Metric::ShuffledBytes),
            "reducespill" => Some(Metric::ReduceSpill),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Metric::ExecTime => "execution time (s)",
            Metric::SpilledRecords => "spilled records",
            Metric::ShuffledBytes => "shuffled bytes",
            Metric::ReduceSpill => "reduce-side spilled bytes",
        }
    }

    /// Extract the metric from a run result. A +1 offset keeps byte/record
    /// metrics strictly positive for relative normalization.
    pub fn extract(&self, r: &crate::sim::JobRunResult) -> f64 {
        match self {
            Metric::ExecTime => r.exec_time_s,
            Metric::SpilledRecords => r.counters.spilled_records as f64 + 1.0,
            Metric::ShuffledBytes => r.counters.shuffled_bytes as f64 + 1.0,
            Metric::ReduceSpill => r.counters.reduce_spilled_bytes as f64 + 1.0,
        }
    }
}

/// The real objective: a job statistic of the workload on the simulated
/// cluster, exactly as the SPSA process on the NameNode observes it
/// (paper §6: job execution time is the default performance metric).
pub struct SimObjective {
    pub space: ParameterSpace,
    pub cluster: ClusterSpec,
    pub workload: WorkloadProfile,
    /// Base seed: each observation derives an independent run seed, so
    /// repeated evaluations at the same θ differ — as on a real cluster.
    pub base_seed: u64,
    /// Noise on/off (off only for landscape dumps / tests).
    pub noise: bool,
    /// Statistic to minimize.
    pub metric: Metric,
    /// Worker threads for `eval_batch` (None → `HSPSA_WORKERS` env var,
    /// else all-but-one core). 1 = sequential.
    workers: Option<usize>,
    evals: u64,
}

impl SimObjective {
    pub fn new(
        space: ParameterSpace,
        cluster: ClusterSpec,
        workload: WorkloadProfile,
        base_seed: u64,
    ) -> Self {
        SimObjective {
            space,
            cluster,
            workload,
            base_seed,
            noise: true,
            metric: Metric::ExecTime,
            workers: None,
            evals: 0,
        }
    }

    pub fn noise_free(mut self) -> Self {
        self.noise = false;
        self
    }

    pub fn with_metric(mut self, metric: Metric) -> Self {
        self.metric = metric;
        self
    }

    /// Pin the `eval_batch` worker count (1 = always sequential). Without
    /// this, `HSPSA_WORKERS` / core count decide.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    /// Seed of observation number `k` (1-based): the same derivation
    /// `eval` uses, split out so batched dispatch can assign every
    /// observation its seed *before* the jobs fan out across threads.
    fn obs_seed(&self, k: u64) -> u64 {
        self.base_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(k)
    }
}

impl Objective for SimObjective {
    fn dim(&self) -> usize {
        self.space.dim()
    }

    fn eval(&mut self, theta: &[f64]) -> f64 {
        self.evals += 1;
        let config = self.space.materialize(theta);
        let opts = SimOptions { seed: self.obs_seed(self.evals), noise: self.noise };
        self.metric
            .extract(&simulate(&self.cluster, &config, &self.workload, &opts))
    }

    /// Parallel override: one simulation per observation, fanned across
    /// the coordinator pool. Seeds are derived from the observation index
    /// *before* dispatch, so the result vector is bit-identical to the
    /// sequential `eval` loop for every worker count and independent of
    /// thread scheduling. Nested inside a campaign pool worker this
    /// degrades to sequential automatically (see `coordinator::pool`).
    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        let workers = crate::coordinator::pool::resolve_workers(self.workers);
        if workers <= 1 || thetas.len() <= 1 {
            return thetas.iter().map(|t| self.eval(t)).collect();
        }
        let jobs: Vec<crate::sim::SimJob> = thetas
            .iter()
            .map(|t| {
                self.evals += 1;
                crate::sim::SimJob {
                    config: self.space.materialize(t),
                    opts: SimOptions { seed: self.obs_seed(self.evals), noise: self.noise },
                }
            })
            .collect();
        crate::sim::simulate_batch(&self.cluster, jobs, &self.workload, workers)
            .iter()
            .map(|r| self.metric.extract(r))
            .collect()
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

/// Noisy quadratic test objective: f(θ) = Σ wᵢ (θᵢ − θ*ᵢ)² + noise.
/// Used by the convergence tests (the landscape SPSA provably descends).
pub struct QuadraticObjective {
    pub target: Vec<f64>,
    pub weights: Vec<f64>,
    pub noise_sigma: f64,
    rng: crate::util::rng::Rng,
    evals: u64,
}

impl QuadraticObjective {
    pub fn new(target: Vec<f64>, noise_sigma: f64, seed: u64) -> Self {
        let weights = vec![1.0; target.len()];
        QuadraticObjective {
            target,
            weights,
            noise_sigma,
            rng: crate::util::rng::Rng::seeded(seed),
            evals: 0,
        }
    }
}

impl Objective for QuadraticObjective {
    fn dim(&self) -> usize {
        self.target.len()
    }

    fn eval(&mut self, theta: &[f64]) -> f64 {
        self.evals += 1;
        let f: f64 = theta
            .iter()
            .zip(&self.target)
            .zip(&self.weights)
            .map(|((t, s), w)| w * (t - s) * (t - s))
            .sum();
        // offset keeps f positive so relative normalization is stable
        1.0 + f + self.noise_sigma * self.rng.gaussian()
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Benchmark;

    fn objective() -> SimObjective {
        let mut rng = crate::util::rng::Rng::seeded(1);
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut rng);
        SimObjective::new(ParameterSpace::v1(), ClusterSpec::paper_cluster(), w, 42)
    }

    #[test]
    fn sim_objective_observes_noisy_f() {
        let mut o = objective();
        let theta = o.space.default_theta();
        let a = o.eval(&theta);
        let b = o.eval(&theta);
        assert!(a > 0.0 && b > 0.0);
        assert_ne!(a, b, "repeated observations must differ (noise)");
        assert!((a / b - 1.0).abs() < 0.8);
        assert_eq!(o.evals(), 2);
    }

    #[test]
    fn noise_free_is_repeatable_per_eval_index() {
        // noise-free still advances the eval counter/seed but the sim noise
        // is off, so values at identical theta coincide.
        let mut o = objective().noise_free();
        let theta = o.space.default_theta();
        let a = o.eval(&theta);
        let b = o.eval(&theta);
        assert_eq!(a, b);
    }

    #[test]
    fn alternative_metrics_differ_from_time() {
        let base = objective();
        let theta = base.space.default_theta();
        let mut time_obj = objective();
        let mut spill_obj = objective().with_metric(Metric::SpilledRecords);
        let t = time_obj.eval(&theta);
        let s = spill_obj.eval(&theta);
        assert!(t > 0.0 && s > 0.0);
        assert_ne!(t, s, "metrics should measure different things");
    }

    #[test]
    fn tuning_spilled_records_reduces_spills() {
        use crate::tuner::{Spsa, SpsaConfig};
        let mut obj = objective().with_metric(Metric::SpilledRecords);
        let theta0 = obj.space.default_theta();
        let f0 = obj.eval(&theta0);
        let space = obj.space.clone();
        let spsa = Spsa::for_space(
            SpsaConfig { max_iters: 15, ..Default::default() },
            &space,
        );
        let res = spsa.run(&mut obj, theta0);
        assert!(
            res.best_f <= f0,
            "spill-metric tuning got worse: {f0} -> {}",
            res.best_f
        );
    }

    fn probe_thetas(n: usize) -> Vec<Vec<f64>> {
        let mut rng = crate::util::rng::Rng::seeded(77);
        (0..n).map(|_| (0..11).map(|_| rng.f64()).collect()).collect()
    }

    #[test]
    fn eval_batch_matches_sequential_eval_loop() {
        // the batched path must preserve per-observation seed derivation
        // exactly: element-for-element bit-identical with the plain loop
        let thetas = probe_thetas(7);
        let mut batched = objective();
        let got = batched.eval_batch(&thetas);
        let mut looped = objective();
        let want: Vec<f64> = thetas.iter().map(|t| looped.eval(t)).collect();
        assert_eq!(got, want);
        assert_eq!(batched.evals(), looped.evals());
        assert_eq!(batched.evals(), 7);
    }

    #[test]
    fn eval_batch_one_worker_equals_many_workers() {
        let thetas = probe_thetas(6);
        let mut one = objective().with_workers(1);
        let mut many = objective().with_workers(4);
        assert_eq!(one.eval_batch(&thetas), many.eval_batch(&thetas));
    }

    #[test]
    fn eval_batch_continues_the_seed_sequence() {
        // interleaving single evals and batches must not fork the seed
        // stream: (eval, eval, batch) == four sequential evals
        let thetas = probe_thetas(4);
        let mut mixed = objective().with_workers(4);
        let a = mixed.eval(&thetas[0]);
        let b = mixed.eval(&thetas[1]);
        let tail = mixed.eval_batch(&thetas[2..]);
        let mut seq = objective().with_workers(1);
        let want: Vec<f64> = thetas.iter().map(|t| seq.eval(t)).collect();
        assert_eq!(vec![a, b, tail[0], tail[1]], want);
    }

    #[test]
    fn metric_parsing() {
        assert_eq!(Metric::from_name("time"), Some(Metric::ExecTime));
        assert_eq!(Metric::from_name("spilled-records"), Some(Metric::SpilledRecords));
        assert_eq!(Metric::from_name("shuffle"), Some(Metric::ShuffledBytes));
        assert_eq!(Metric::from_name("bogus"), None);
    }

    #[test]
    fn quadratic_minimum_at_target() {
        let mut o = QuadraticObjective::new(vec![0.3, 0.7], 0.0, 1);
        let at_target = o.eval(&[0.3, 0.7]);
        let away = o.eval(&[0.9, 0.1]);
        assert!(at_target < away);
        assert!((at_target - 1.0).abs() < 1e-12);
    }
}
