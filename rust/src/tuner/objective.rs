//! Objective abstraction: `f(θ_A)` — one *noisy* observation of system
//! performance per call (paper Fig. 3's system-in-the-loop box).

use crate::cluster::ClusterSpec;
use crate::config::ParameterSpace;
use crate::sim::{simulate, SimOptions};
use crate::workloads::WorkloadProfile;

/// A tunable system observed through its scalar performance.
pub trait Objective {
    fn dim(&self) -> usize;
    /// One observation of f at θ_A ∈ [0,1]^n. Observations are noisy; the
    /// same θ may return different values (run-to-run variance).
    fn eval(&mut self, theta: &[f64]) -> f64;
    /// Total observations made so far (the paper's cost metric: 2/iter).
    fn evals(&self) -> u64;
}

/// Which job statistic the tuner minimizes. The paper's experiments use
/// execution time, and §4.2 notes "we can also have other performance
/// metrics — like number of records spilled to disk, memory and heap
/// usage or number of failed jobs".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Wall-clock job execution time in seconds (the paper's metric).
    ExecTime,
    /// Records written to map-side spill files ("Spilled Records").
    SpilledRecords,
    /// Bytes moved map→reduce over the network.
    ShuffledBytes,
    /// Reduce-side bytes hitting disk before the reduce function.
    ReduceSpill,
}

impl Metric {
    pub fn from_name(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "exectime" | "time" => Some(Metric::ExecTime),
            "spilledrecords" | "spills" => Some(Metric::SpilledRecords),
            "shuffledbytes" | "shuffle" => Some(Metric::ShuffledBytes),
            "reducespill" => Some(Metric::ReduceSpill),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Metric::ExecTime => "execution time (s)",
            Metric::SpilledRecords => "spilled records",
            Metric::ShuffledBytes => "shuffled bytes",
            Metric::ReduceSpill => "reduce-side spilled bytes",
        }
    }

    /// Extract the metric from a run result. A +1 offset keeps byte/record
    /// metrics strictly positive for relative normalization.
    pub fn extract(&self, r: &crate::sim::JobRunResult) -> f64 {
        match self {
            Metric::ExecTime => r.exec_time_s,
            Metric::SpilledRecords => r.counters.spilled_records as f64 + 1.0,
            Metric::ShuffledBytes => r.counters.shuffled_bytes as f64 + 1.0,
            Metric::ReduceSpill => r.counters.reduce_spilled_bytes as f64 + 1.0,
        }
    }
}

/// The real objective: a job statistic of the workload on the simulated
/// cluster, exactly as the SPSA process on the NameNode observes it
/// (paper §6: job execution time is the default performance metric).
pub struct SimObjective {
    pub space: ParameterSpace,
    pub cluster: ClusterSpec,
    pub workload: WorkloadProfile,
    /// Base seed: each observation derives an independent run seed, so
    /// repeated evaluations at the same θ differ — as on a real cluster.
    pub base_seed: u64,
    /// Noise on/off (off only for landscape dumps / tests).
    pub noise: bool,
    /// Statistic to minimize.
    pub metric: Metric,
    evals: u64,
}

impl SimObjective {
    pub fn new(
        space: ParameterSpace,
        cluster: ClusterSpec,
        workload: WorkloadProfile,
        base_seed: u64,
    ) -> Self {
        SimObjective {
            space,
            cluster,
            workload,
            base_seed,
            noise: true,
            metric: Metric::ExecTime,
            evals: 0,
        }
    }

    pub fn noise_free(mut self) -> Self {
        self.noise = false;
        self
    }

    pub fn with_metric(mut self, metric: Metric) -> Self {
        self.metric = metric;
        self
    }
}

impl Objective for SimObjective {
    fn dim(&self) -> usize {
        self.space.dim()
    }

    fn eval(&mut self, theta: &[f64]) -> f64 {
        self.evals += 1;
        let config = self.space.materialize(theta);
        let seed = self
            .base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(self.evals);
        let opts = SimOptions { seed, noise: self.noise };
        self.metric
            .extract(&simulate(&self.cluster, &config, &self.workload, &opts))
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

/// Noisy quadratic test objective: f(θ) = Σ wᵢ (θᵢ − θ*ᵢ)² + noise.
/// Used by the convergence tests (the landscape SPSA provably descends).
pub struct QuadraticObjective {
    pub target: Vec<f64>,
    pub weights: Vec<f64>,
    pub noise_sigma: f64,
    rng: crate::util::rng::Rng,
    evals: u64,
}

impl QuadraticObjective {
    pub fn new(target: Vec<f64>, noise_sigma: f64, seed: u64) -> Self {
        let weights = vec![1.0; target.len()];
        QuadraticObjective {
            target,
            weights,
            noise_sigma,
            rng: crate::util::rng::Rng::seeded(seed),
            evals: 0,
        }
    }
}

impl Objective for QuadraticObjective {
    fn dim(&self) -> usize {
        self.target.len()
    }

    fn eval(&mut self, theta: &[f64]) -> f64 {
        self.evals += 1;
        let f: f64 = theta
            .iter()
            .zip(&self.target)
            .zip(&self.weights)
            .map(|((t, s), w)| w * (t - s) * (t - s))
            .sum();
        // offset keeps f positive so relative normalization is stable
        1.0 + f + self.noise_sigma * self.rng.gaussian()
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Benchmark;

    fn objective() -> SimObjective {
        let mut rng = crate::util::rng::Rng::seeded(1);
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut rng);
        SimObjective::new(ParameterSpace::v1(), ClusterSpec::paper_cluster(), w, 42)
    }

    #[test]
    fn sim_objective_observes_noisy_f() {
        let mut o = objective();
        let theta = o.space.default_theta();
        let a = o.eval(&theta);
        let b = o.eval(&theta);
        assert!(a > 0.0 && b > 0.0);
        assert_ne!(a, b, "repeated observations must differ (noise)");
        assert!((a / b - 1.0).abs() < 0.8);
        assert_eq!(o.evals(), 2);
    }

    #[test]
    fn noise_free_is_repeatable_per_eval_index() {
        // noise-free still advances the eval counter/seed but the sim noise
        // is off, so values at identical theta coincide.
        let mut o = objective().noise_free();
        let theta = o.space.default_theta();
        let a = o.eval(&theta);
        let b = o.eval(&theta);
        assert_eq!(a, b);
    }

    #[test]
    fn alternative_metrics_differ_from_time() {
        let base = objective();
        let theta = base.space.default_theta();
        let mut time_obj = objective();
        let mut spill_obj = objective().with_metric(Metric::SpilledRecords);
        let t = time_obj.eval(&theta);
        let s = spill_obj.eval(&theta);
        assert!(t > 0.0 && s > 0.0);
        assert_ne!(t, s, "metrics should measure different things");
    }

    #[test]
    fn tuning_spilled_records_reduces_spills() {
        use crate::tuner::{Spsa, SpsaConfig};
        let mut obj = objective().with_metric(Metric::SpilledRecords);
        let theta0 = obj.space.default_theta();
        let f0 = obj.eval(&theta0);
        let space = obj.space.clone();
        let spsa = Spsa::for_space(
            SpsaConfig { max_iters: 15, ..Default::default() },
            &space,
        );
        let res = spsa.run(&mut obj, theta0);
        assert!(
            res.best_f <= f0,
            "spill-metric tuning got worse: {f0} -> {}",
            res.best_f
        );
    }

    #[test]
    fn metric_parsing() {
        assert_eq!(Metric::from_name("time"), Some(Metric::ExecTime));
        assert_eq!(Metric::from_name("spilled-records"), Some(Metric::SpilledRecords));
        assert_eq!(Metric::from_name("shuffle"), Some(Metric::ShuffledBytes));
        assert_eq!(Metric::from_name("bogus"), None);
    }

    #[test]
    fn quadratic_minimum_at_target() {
        let mut o = QuadraticObjective::new(vec![0.3, 0.7], 0.0, 1);
        let at_target = o.eval(&[0.3, 0.7]);
        let away = o.eval(&[0.9, 0.1]);
        assert!(at_target < away);
        assert!((at_target - 1.0).abs() < 1e-12);
    }
}
