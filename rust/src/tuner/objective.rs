//! Objective abstraction: `f(θ_A)` — one *noisy* observation of system
//! performance per call (paper Fig. 3's system-in-the-loop box).

use crate::cluster::ClusterSpec;
use crate::config::ParameterSpace;
use crate::sim::constants::FAILED_JOB_PENALTY;
use crate::sim::{simulate_with_buffers, JobRunResult, ScenarioSpec, SimBuffers, SimOptions};
use crate::util::stats::percentile;
use crate::workloads::WorkloadProfile;

/// A tunable system observed through its scalar performance.
pub trait Objective {
    fn dim(&self) -> usize;
    /// One observation of f at θ_A ∈ [0,1]^n. Observations are noisy; the
    /// same θ may return different values (run-to-run variance).
    fn eval(&mut self, theta: &[f64]) -> f64;
    /// Observe f at a batch of points. The contract: element `i` of the
    /// result equals what `eval(&thetas[i])` would have returned had the
    /// points been evaluated one by one, in order — per-observation seed
    /// derivation included. The default implementation *is* that
    /// sequential loop; implementations may parallelize (SPSA's
    /// perturbation probes are independent jobs) as long as the results
    /// stay element-for-element identical.
    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        thetas.iter().map(|t| self.eval(t)).collect()
    }
    /// Total observations made so far (the paper's cost metric: 2/iter).
    fn evals(&self) -> u64;

    /// Modeled wall-clock durations, in simulated seconds, of the
    /// observations served by the most recent `eval`/`eval_batch` call —
    /// one entry per observation, in call order. `None` (the default)
    /// tells the metering layer ([`EvalBroker`]) to fall back to the
    /// observation values themselves: exact for `ExecTime`-metric
    /// objectives (the observation IS the job's seconds), a documented
    /// proxy for synthetic test objectives.
    ///
    /// [`EvalBroker`]: crate::tuner::broker::EvalBroker
    fn last_durations(&self) -> Option<Vec<f64>> {
        None
    }

    /// Fast-forward the observation stream by `n` observations without
    /// running them, as if `n` evals had happened. A checkpoint-resumed
    /// tuner uses this to realign a *fresh* objective with the position an
    /// interrupted run had reached, so the next observation draws the same
    /// seed it would have in the uninterrupted run. Returns `false` (the
    /// default) when the objective cannot skip — resuming on it would fork
    /// the noise stream, so callers must treat `false` as "not resumable".
    fn advance_evals(&mut self, _n: u64) -> bool {
        false
    }
}

/// Which job statistic the tuner minimizes. The paper's experiments use
/// execution time, and §4.2 notes "we can also have other performance
/// metrics — like number of records spilled to disk, memory and heap
/// usage or number of failed jobs".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Wall-clock job execution time in seconds (the paper's metric).
    ExecTime,
    /// Records written to map-side spill files ("Spilled Records").
    SpilledRecords,
    /// Bytes moved map→reduce over the network.
    ShuffledBytes,
    /// Reduce-side bytes hitting disk before the reduce function.
    ReduceSpill,
}

impl Metric {
    pub fn from_name(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "exectime" | "time" => Some(Metric::ExecTime),
            "spilledrecords" | "spills" => Some(Metric::SpilledRecords),
            "shuffledbytes" | "shuffle" => Some(Metric::ShuffledBytes),
            "reducespill" => Some(Metric::ReduceSpill),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Metric::ExecTime => "execution time (s)",
            Metric::SpilledRecords => "spilled records",
            Metric::ShuffledBytes => "shuffled bytes",
            Metric::ReduceSpill => "reduce-side spilled bytes",
        }
    }

    /// Extract the metric from a run result. A +1 offset keeps byte/record
    /// metrics strictly positive for relative normalization.
    pub fn extract(&self, r: &crate::sim::JobRunResult) -> f64 {
        match self {
            Metric::ExecTime => r.exec_time_s,
            Metric::SpilledRecords => r.counters.spilled_records as f64 + 1.0,
            Metric::ShuffledBytes => r.counters.shuffled_bytes as f64 + 1.0,
            Metric::ReduceSpill => r.counters.reduce_spilled_bytes as f64 + 1.0,
        }
    }

    /// Objective-facing score: the raw metric for a completed run; for a
    /// failed job, a value guaranteed to exceed any completed run's.
    ///
    /// Execution time of an aborted run scales with how far the job got,
    /// so dividing by [`crate::sim::JobRunResult::progress`] reconstructs
    /// a full-job estimate before the [`FAILED_JOB_PENALTY`] multiplier —
    /// even an abort seconds into a multi-hour job scores worse than
    /// completing. Byte/record counters commit on success only and shrink
    /// toward zero as the abort gets earlier, so no extrapolation can
    /// recover their scale; those metrics score a graded sentinel instead
    /// (nearly-finishing configurations still compare better than
    /// instantly-dying ones).
    pub fn score(&self, r: &crate::sim::JobRunResult) -> f64 {
        let v = self.extract(r);
        if !r.job_failed {
            return v;
        }
        match self {
            Metric::ExecTime => v / r.progress() * FAILED_JOB_PENALTY,
            Metric::SpilledRecords | Metric::ShuffledBytes | Metric::ReduceSpill => {
                crate::sim::constants::FAILED_METRIC_SENTINEL * (2.0 - r.progress())
            }
        }
    }
}

/// How one `eval` call aggregates simulated runs into a scalar observation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ObsAgg {
    /// One simulated run per observation — the paper's setting.
    Single,
    /// `repeats` runs per observation, reduced at the `q`-th percentile.
    /// Tail-aware tuning: under fault injection the mean hides re-execution
    /// tails, so optimize p95 instead (`SimObjective::tail_p95`). The
    /// repeated runs are independent jobs and fan across the worker pool.
    Percentile { repeats: u64, q: f64 },
}

/// The real objective: a job statistic of the workload on the simulated
/// cluster, exactly as the SPSA process on the NameNode observes it
/// (paper §6: job execution time is the default performance metric).
pub struct SimObjective {
    pub space: ParameterSpace,
    pub cluster: ClusterSpec,
    pub workload: WorkloadProfile,
    /// Base seed: each observation derives an independent run seed, so
    /// repeated evaluations at the same θ differ — as on a real cluster.
    pub base_seed: u64,
    /// Noise on/off (off only for landscape dumps / tests).
    pub noise: bool,
    /// Statistic to minimize.
    pub metric: Metric,
    /// Execution-substrate regime the observed cluster runs under
    /// (failures, crashes, heterogeneity, speculation). Benign by default.
    pub scenario: ScenarioSpec,
    /// Runs-per-observation aggregation (`Single` = the paper's setting).
    pub agg: ObsAgg,
    /// Worker threads for `eval_batch` (None → `HSPSA_WORKERS` env var,
    /// else all-but-one core). 1 = sequential.
    workers: Option<usize>,
    evals: u64,
    /// Reused simulator buffer pool for the sequential eval paths
    /// (`Single` evals and 1-worker/percentile batches): thousands of
    /// SPSA observations share one arena/queue allocation, and the warm
    /// cost cache (`sim::cost`) carries across repeated observations of
    /// the same (config, workload) — percentile waves and re-probed θ
    /// points pay the cost model once, not per run.
    bufs: SimBuffers,
    /// Simulated seconds of each observation in the most recent
    /// `eval`/`eval_batch` call (see [`Objective::last_durations`]): the
    /// run's real elapsed time — retries and aborts included — which for
    /// a failed job is *not* the penalized score the tuner sees.
    last_durs: Vec<f64>,
}

impl SimObjective {
    pub fn new(
        space: ParameterSpace,
        cluster: ClusterSpec,
        workload: WorkloadProfile,
        base_seed: u64,
    ) -> Self {
        SimObjective {
            space,
            cluster,
            workload,
            base_seed,
            noise: true,
            metric: Metric::ExecTime,
            scenario: ScenarioSpec::default(),
            agg: ObsAgg::Single,
            workers: None,
            evals: 0,
            bufs: SimBuffers::new(),
            last_durs: Vec::new(),
        }
    }

    pub fn noise_free(mut self) -> Self {
        self.noise = false;
        self
    }

    pub fn with_metric(mut self, metric: Metric) -> Self {
        self.metric = metric;
        self
    }

    /// Observe the system under a fault/heterogeneity scenario instead of
    /// the benign cluster.
    pub fn with_scenario(mut self, scenario: ScenarioSpec) -> Self {
        self.scenario = scenario;
        self
    }

    /// Set the runs-per-observation aggregation.
    pub fn with_aggregate(mut self, agg: ObsAgg) -> Self {
        self.agg = agg;
        self
    }

    /// Tail-aware objective: each observation is the p95 of `repeats`
    /// independent runs (re-execution tails dominate under failures).
    pub fn tail_p95(self, repeats: u64) -> Self {
        self.with_aggregate(ObsAgg::Percentile { repeats: repeats.max(1), q: 95.0 })
    }

    /// Pin the `eval_batch` worker count (1 = always sequential). Without
    /// this, `HSPSA_WORKERS` / core count decide.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    /// Seed of observation number `k` (1-based): the same derivation
    /// `eval` uses, split out so batched dispatch can assign every
    /// observation its seed *before* the jobs fan out across threads.
    fn obs_seed(&self, k: u64) -> u64 {
        self.base_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(k)
    }

    /// Options of the next simulated run, consuming one eval-counter slot.
    fn next_opts(&mut self) -> SimOptions {
        self.evals += 1;
        SimOptions {
            seed: self.obs_seed(self.evals),
            noise: self.noise,
            scenario: self.scenario.clone(),
        }
    }

    /// Metric value of one run, with the failed-job penalty applied: a run
    /// that exhausted `max.attempts` (or lost its cluster) must look far
    /// worse to the tuner than any completed run (see [`Metric::score`]).
    fn score(&self, r: &JobRunResult) -> f64 {
        self.metric.score(r)
    }

    /// Number of simulated runs one observation consumes.
    fn runs_per_obs(&self) -> u64 {
        match self.agg {
            ObsAgg::Single => 1,
            ObsAgg::Percentile { repeats, .. } => repeats.max(1),
        }
    }

    /// Reduce the scores of one observation's runs to the scalar f(θ).
    fn aggregate(&self, scores: &[f64]) -> f64 {
        match self.agg {
            ObsAgg::Single => scores[0],
            ObsAgg::Percentile { q, .. } => percentile(scores, q),
        }
    }
}

impl Objective for SimObjective {
    fn dim(&self) -> usize {
        self.space.dim()
    }

    fn eval(&mut self, theta: &[f64]) -> f64 {
        let config = self.space.materialize(theta);
        match self.agg {
            ObsAgg::Single => {
                let opts = self.next_opts();
                let r = simulate_with_buffers(
                    &self.cluster,
                    &config,
                    &self.workload,
                    &opts,
                    &mut self.bufs,
                );
                // the run's real simulated seconds (an aborted run costs
                // its time-to-abort, not the penalized score)
                self.last_durs = vec![r.exec_time_s];
                self.score(&r)
            }
            ObsAgg::Percentile { .. } => {
                // the repeated runs of one observation are independent jobs
                // and fan across the pool like any other batch; the
                // sequential path threads the owned buffer pool through,
                // so the wave's runs 2.. hit the warm cost cache
                let jobs: Vec<crate::sim::SimJob> = (0..self.runs_per_obs())
                    .map(|_| crate::sim::SimJob { config: config.clone(), opts: self.next_opts() })
                    .collect();
                let workers = crate::coordinator::pool::resolve_workers(self.workers);
                let runs = crate::sim::simulate_batch_with_buffers(
                    &self.cluster,
                    jobs,
                    &self.workload,
                    workers,
                    &mut self.bufs,
                );
                let scores: Vec<f64> = runs.iter().map(|r| self.score(r)).collect();
                // the repeats run as one parallel wave: the observation
                // takes as long as its slowest run
                self.last_durs =
                    vec![runs.iter().map(|r| r.exec_time_s).fold(0.0_f64, f64::max)];
                self.aggregate(&scores)
            }
        }
    }

    /// Parallel override: one simulation per run, fanned across the
    /// coordinator pool. Seeds are derived from the observation index
    /// *before* dispatch, so the result vector is bit-identical to the
    /// sequential `eval` loop for every worker count and independent of
    /// thread scheduling. Nested inside a campaign pool worker this
    /// degrades to sequential automatically (see `coordinator::pool`).
    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        let workers = crate::coordinator::pool::resolve_workers(self.workers);
        if workers <= 1 || thetas.len() <= 1 {
            let mut durs = Vec::with_capacity(thetas.len());
            let out = thetas
                .iter()
                .map(|t| {
                    let f = self.eval(t);
                    durs.push(self.last_durs[0]);
                    f
                })
                .collect();
            self.last_durs = durs;
            return out;
        }
        let per_obs = self.runs_per_obs() as usize;
        let jobs: Vec<crate::sim::SimJob> = thetas
            .iter()
            .flat_map(|t| {
                let config = self.space.materialize(t);
                (0..per_obs)
                    .map(|_| crate::sim::SimJob { config: config.clone(), opts: self.next_opts() })
                    .collect::<Vec<_>>()
            })
            .collect();
        let runs = crate::sim::simulate_batch_with_buffers(
            &self.cluster,
            jobs,
            &self.workload,
            workers,
            &mut self.bufs,
        );
        let (mut out, mut durs) =
            (Vec::with_capacity(thetas.len()), Vec::with_capacity(thetas.len()));
        for chunk in runs.chunks(per_obs) {
            let scores: Vec<f64> = chunk.iter().map(|r| self.score(r)).collect();
            out.push(self.aggregate(&scores));
            durs.push(chunk.iter().map(|r| r.exec_time_s).fold(0.0_f64, f64::max));
        }
        self.last_durs = durs;
        out
    }

    fn evals(&self) -> u64 {
        self.evals
    }

    fn last_durations(&self) -> Option<Vec<f64>> {
        Some(self.last_durs.clone())
    }

    /// Seeds are positional (`obs_seed(k)`), so skipping is exact: bump
    /// the counter and observation k+n draws precisely the seed it would
    /// have drawn had the first k+n observations actually run.
    fn advance_evals(&mut self, n: u64) -> bool {
        self.evals += n;
        true
    }
}

/// Noisy quadratic test objective: f(θ) = Σ wᵢ (θᵢ − θ*ᵢ)² + noise.
/// Used by the convergence tests (the landscape SPSA provably descends).
pub struct QuadraticObjective {
    pub target: Vec<f64>,
    pub weights: Vec<f64>,
    pub noise_sigma: f64,
    rng: crate::util::rng::Rng,
    evals: u64,
}

impl QuadraticObjective {
    pub fn new(target: Vec<f64>, noise_sigma: f64, seed: u64) -> Self {
        let weights = vec![1.0; target.len()];
        QuadraticObjective {
            target,
            weights,
            noise_sigma,
            rng: crate::util::rng::Rng::seeded(seed),
            evals: 0,
        }
    }
}

impl Objective for QuadraticObjective {
    fn dim(&self) -> usize {
        self.target.len()
    }

    fn eval(&mut self, theta: &[f64]) -> f64 {
        self.evals += 1;
        let f: f64 = theta
            .iter()
            .zip(&self.target)
            .zip(&self.weights)
            .map(|((t, s), w)| w * (t - s) * (t - s))
            .sum();
        // offset keeps f positive so relative normalization is stable
        1.0 + f + self.noise_sigma * self.rng.gaussian()
    }

    fn evals(&self) -> u64 {
        self.evals
    }

    /// The quadratic draws exactly one gaussian per eval, so skipping n
    /// observations means burning n gaussians from the same stream.
    fn advance_evals(&mut self, n: u64) -> bool {
        for _ in 0..n {
            self.rng.gaussian();
        }
        self.evals += n;
        true
    }
}

/// Dimension-pruning adapter (Tuneful §3): presents a reduced search
/// space to the tuner while evaluating on the full one. Frozen
/// coordinates are pinned to a full-dimensional `template` (typically the
/// space defaults); the tuner proposes reduced θs over the free
/// coordinates only, and this wrapper expands each proposal to the full
/// vector before delegating. Seed derivation is untouched — the inner
/// objective sees exactly as many observations, in the same order, as it
/// would for natively full-dimensional proposals.
pub struct FrozenObjective<'a> {
    inner: &'a mut dyn Objective,
    /// Full-dimensional vector supplying the frozen coordinates' values.
    template: Vec<f64>,
    /// Indices (into `template`) of the free coordinates, ascending.
    free: Vec<usize>,
}

impl<'a> FrozenObjective<'a> {
    /// Wrap `inner`, freezing every coordinate where `frozen[i]` is true
    /// at `template[i]`. At least one coordinate must stay free.
    pub fn new(inner: &'a mut dyn Objective, template: Vec<f64>, frozen: &[bool]) -> Self {
        assert_eq!(template.len(), frozen.len(), "template/frozen length mismatch");
        assert_eq!(template.len(), inner.dim(), "template must be full-dimensional");
        let free: Vec<usize> =
            (0..frozen.len()).filter(|&i| !frozen[i]).collect();
        assert!(!free.is_empty(), "cannot freeze every dimension");
        FrozenObjective { inner, template, free }
    }

    /// Expand a reduced θ (one entry per free coordinate, in index order)
    /// to the full-dimensional vector the inner objective evaluates.
    pub fn expand(&self, reduced: &[f64]) -> Vec<f64> {
        debug_assert_eq!(reduced.len(), self.free.len());
        let mut full = self.template.clone();
        for (slot, &v) in self.free.iter().zip(reduced) {
            full[*slot] = v;
        }
        full
    }
}

impl Objective for FrozenObjective<'_> {
    fn dim(&self) -> usize {
        self.free.len()
    }

    fn eval(&mut self, theta: &[f64]) -> f64 {
        self.inner.eval(&self.expand(theta))
    }

    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        let full: Vec<Vec<f64>> = thetas.iter().map(|t| self.expand(t)).collect();
        self.inner.eval_batch(&full)
    }

    fn evals(&self) -> u64 {
        self.inner.evals()
    }

    fn last_durations(&self) -> Option<Vec<f64>> {
        self.inner.last_durations()
    }

    fn advance_evals(&mut self, n: u64) -> bool {
        self.inner.advance_evals(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Benchmark;

    fn objective() -> SimObjective {
        let mut rng = crate::util::rng::Rng::seeded(1);
        let w = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut rng);
        SimObjective::new(ParameterSpace::v1(), ClusterSpec::paper_cluster(), w, 42)
    }

    #[test]
    fn sim_objective_observes_noisy_f() {
        let mut o = objective();
        let theta = o.space.default_theta();
        let a = o.eval(&theta);
        let b = o.eval(&theta);
        assert!(a > 0.0 && b > 0.0);
        assert_ne!(a, b, "repeated observations must differ (noise)");
        assert!((a / b - 1.0).abs() < 0.8);
        assert_eq!(o.evals(), 2);
    }

    #[test]
    fn noise_free_is_repeatable_per_eval_index() {
        // noise-free still advances the eval counter/seed but the sim noise
        // is off, so values at identical theta coincide.
        let mut o = objective().noise_free();
        let theta = o.space.default_theta();
        let a = o.eval(&theta);
        let b = o.eval(&theta);
        assert_eq!(a, b);
    }

    #[test]
    fn alternative_metrics_differ_from_time() {
        let base = objective();
        let theta = base.space.default_theta();
        let mut time_obj = objective();
        let mut spill_obj = objective().with_metric(Metric::SpilledRecords);
        let t = time_obj.eval(&theta);
        let s = spill_obj.eval(&theta);
        assert!(t > 0.0 && s > 0.0);
        assert_ne!(t, s, "metrics should measure different things");
    }

    #[test]
    fn tuning_spilled_records_reduces_spills() {
        use crate::tuner::{Spsa, SpsaConfig};
        let mut obj = objective().with_metric(Metric::SpilledRecords);
        let theta0 = obj.space.default_theta();
        let f0 = obj.eval(&theta0);
        let space = obj.space.clone();
        let spsa = Spsa::for_space(
            SpsaConfig { max_iters: 15, ..Default::default() },
            &space,
        );
        let res = spsa.run(&mut obj, theta0);
        assert!(
            res.best_f <= f0,
            "spill-metric tuning got worse: {f0} -> {}",
            res.best_f
        );
    }

    fn probe_thetas(n: usize) -> Vec<Vec<f64>> {
        let mut rng = crate::util::rng::Rng::seeded(77);
        (0..n).map(|_| (0..11).map(|_| rng.f64()).collect()).collect()
    }

    #[test]
    fn eval_batch_matches_sequential_eval_loop() {
        // the batched path must preserve per-observation seed derivation
        // exactly: element-for-element bit-identical with the plain loop
        let thetas = probe_thetas(7);
        let mut batched = objective();
        let got = batched.eval_batch(&thetas);
        let mut looped = objective();
        let want: Vec<f64> = thetas.iter().map(|t| looped.eval(t)).collect();
        assert_eq!(got, want);
        assert_eq!(batched.evals(), looped.evals());
        assert_eq!(batched.evals(), 7);
    }

    #[test]
    fn eval_batch_one_worker_equals_many_workers() {
        let thetas = probe_thetas(6);
        let mut one = objective().with_workers(1);
        let mut many = objective().with_workers(4);
        assert_eq!(one.eval_batch(&thetas), many.eval_batch(&thetas));
    }

    #[test]
    fn durations_track_each_observation_at_any_worker_count() {
        let thetas = probe_thetas(5);
        let mut one = objective().with_workers(1);
        one.eval_batch(&thetas);
        let d1 = one.last_durations().expect("SimObjective reports durations");
        let mut many = objective().with_workers(4);
        many.eval_batch(&thetas);
        assert_eq!(d1.len(), 5, "one duration per observation");
        assert_eq!(d1, many.last_durations().unwrap());
        assert!(d1.iter().all(|d| *d > 0.0 && d.is_finite()));
    }

    #[test]
    fn benign_exectime_duration_equals_the_observation() {
        // under ExecTime with no failure penalty, the observation IS the
        // run's simulated seconds — the broker's fallback and the real
        // duration coincide exactly
        let mut o = objective();
        let theta = o.space.default_theta();
        let f = o.eval(&theta);
        assert_eq!(o.last_durations().unwrap(), vec![f]);
    }

    #[test]
    fn counter_metric_duration_is_still_seconds() {
        // minimizing spilled records: the observation is a record count,
        // but the wall-clock model must still be charged in seconds
        let mut o = objective().with_metric(Metric::SpilledRecords);
        let theta = o.space.default_theta();
        let f = o.eval(&theta);
        let d = o.last_durations().unwrap()[0];
        assert_ne!(d, f, "duration must not be the counter value");
        assert!(d > 0.0 && d < 1e7, "implausible run duration {d}");
    }

    #[test]
    fn tail_aggregate_reports_one_duration_per_observation() {
        let thetas = probe_thetas(3);
        let mut o = objective().tail_p95(4).with_workers(1);
        o.eval_batch(&thetas);
        let d = o.last_durations().unwrap();
        assert_eq!(d.len(), 3, "repeats fold into their observation's duration");
        assert!(d.iter().all(|x| *x > 0.0));
    }

    #[test]
    fn eval_batch_continues_the_seed_sequence() {
        // interleaving single evals and batches must not fork the seed
        // stream: (eval, eval, batch) == four sequential evals
        let thetas = probe_thetas(4);
        let mut mixed = objective().with_workers(4);
        let a = mixed.eval(&thetas[0]);
        let b = mixed.eval(&thetas[1]);
        let tail = mixed.eval_batch(&thetas[2..]);
        let mut seq = objective().with_workers(1);
        let want: Vec<f64> = thetas.iter().map(|t| seq.eval(t)).collect();
        assert_eq!(vec![a, b, tail[0], tail[1]], want);
    }

    #[test]
    fn advance_evals_realigns_the_observation_stream() {
        // skipping k observations on a fresh objective must reproduce the
        // continuation of a run that actually made those k observations
        let thetas = probe_thetas(6);
        let mut full = objective();
        let want: Vec<f64> = thetas.iter().map(|t| full.eval(t)).collect();
        let mut skipped = objective();
        assert!(skipped.advance_evals(3));
        let got: Vec<f64> = thetas[3..].iter().map(|t| skipped.eval(t)).collect();
        assert_eq!(got, want[3..].to_vec());
        assert_eq!(skipped.evals(), full.evals());
        // and the quadratic burns its gaussian stream the same way
        let mut qa = QuadraticObjective::new(vec![0.4, 0.6], 0.3, 9);
        let qwant: Vec<f64> = (0..5).map(|_| qa.eval(&[0.5, 0.5])).collect();
        let mut qb = QuadraticObjective::new(vec![0.4, 0.6], 0.3, 9);
        assert!(qb.advance_evals(2));
        let qgot: Vec<f64> = (0..3).map(|_| qb.eval(&[0.5, 0.5])).collect();
        assert_eq!(qgot, qwant[2..].to_vec());
    }

    #[test]
    fn metric_parsing() {
        assert_eq!(Metric::from_name("time"), Some(Metric::ExecTime));
        assert_eq!(Metric::from_name("spilled-records"), Some(Metric::SpilledRecords));
        assert_eq!(Metric::from_name("shuffle"), Some(Metric::ShuffledBytes));
        assert_eq!(Metric::from_name("bogus"), None);
    }

    #[test]
    fn scenario_objective_stays_deterministic_and_batched() {
        // Observations under a fault scenario keep the eval_batch contract:
        // element-for-element identical to the sequential loop, any workers.
        let scenario = crate::sim::ScenarioSpec::default()
            .with_failures(0.2)
            .with_max_attempts(10)
            .with_slow_node(2, 0.5)
            .with_speculation(true);
        let thetas = probe_thetas(5);
        let mut batched = objective().with_scenario(scenario.clone()).with_workers(4);
        let got = batched.eval_batch(&thetas);
        let mut looped = objective().with_scenario(scenario).with_workers(1);
        let want: Vec<f64> = thetas.iter().map(|t| looped.eval(t)).collect();
        assert_eq!(got, want);
        assert!(got.iter().all(|f| f.is_finite() && *f > 0.0));
    }

    #[test]
    fn scenario_observations_cost_more_time() {
        // Re-execution and retries make the observed objective worse than
        // the benign cluster's at the same θ and seed.
        let theta = objective().space.default_theta();
        let mut benign = objective().noise_free();
        let mut faulty = objective().noise_free().with_scenario(
            crate::sim::ScenarioSpec::default().with_failures(0.3).with_max_attempts(12),
        );
        let fb = benign.eval(&theta);
        let ff = faulty.eval(&theta);
        assert!(ff >= fb * 0.95, "faulty {ff} vs benign {fb}");
    }

    #[test]
    fn failed_jobs_are_penalized() {
        // p=1.0 with max_attempts=2 kills every job: the tuner must see a
        // value far above the benign one.
        let theta = objective().space.default_theta();
        let mut benign = objective().noise_free();
        let mut doomed = objective().noise_free().with_scenario(
            crate::sim::ScenarioSpec::default().with_failures(1.0).with_max_attempts(2),
        );
        let fb = benign.eval(&theta);
        let fd = doomed.eval(&theta);
        assert!(fd > fb, "failed job not penalized: {fd} vs {fb}");
    }

    #[test]
    fn failed_jobs_dominate_for_counter_metrics_too() {
        // Byte/record counters commit on success only, so an aborting run
        // reports ~zero shuffled bytes — the sentinel must keep it scoring
        // far above any completed run's real counter value.
        let theta = objective().space.default_theta();
        let mut completed = objective().noise_free().with_metric(Metric::ShuffledBytes);
        let mut doomed = objective()
            .noise_free()
            .with_metric(Metric::ShuffledBytes)
            .with_scenario(
                crate::sim::ScenarioSpec::default().with_failures(1.0).with_max_attempts(2),
            );
        let fc = completed.eval(&theta);
        let fd = doomed.eval(&theta);
        assert!(fd > fc, "aborting config undercuts completed run: {fd} vs {fc}");
    }

    #[test]
    fn early_abort_scores_worse_than_any_completed_run() {
        // The sharp case: a multi-hour job that aborts seconds in. The raw
        // abort-time makespan times the penalty constant could undercut a
        // completed run; the progress extrapolation in Metric::score must
        // keep the failed configuration strictly worse.
        let mut rng = crate::util::rng::Rng::seeded(5);
        let w = Benchmark::Terasort.profile_scaled(200_000, 30 << 30, &mut rng);
        let make = || {
            SimObjective::new(
                ParameterSpace::v1(),
                ClusterSpec::paper_cluster(),
                w.clone(),
                42,
            )
            .noise_free()
        };
        let theta = make().space.default_theta();
        let completed = make().eval(&theta);
        let aborted = make()
            .with_scenario(
                crate::sim::ScenarioSpec::default().with_failures(1.0).with_max_attempts(2),
            )
            .eval(&theta);
        assert!(
            aborted > completed,
            "early abort ({aborted}) undercuts the completed run ({completed})"
        );
    }

    #[test]
    fn tail_p95_matches_manual_percentile_and_batches() {
        use crate::util::stats::percentile;
        let theta = objective().space.default_theta();
        // manual: 9 single observations with the same seed stream
        let mut single = objective();
        let runs: Vec<f64> = (0..9).map(|_| single.eval(&theta)).collect();
        let want = percentile(&runs, 95.0);
        // one tail-aware observation consumes the same 9 runs
        let mut tail = objective().tail_p95(9);
        let got = tail.eval(&theta);
        assert_eq!(got, want);
        assert_eq!(tail.evals(), 9, "tail objective must account all runs");
        // and the batched path agrees at any worker count
        let thetas = probe_thetas(3);
        let mut seq = objective().tail_p95(4).with_workers(1);
        let mut par = objective().tail_p95(4).with_workers(4);
        let a: Vec<f64> = thetas.iter().map(|t| seq.eval(t)).collect();
        let b = par.eval_batch(&thetas);
        assert_eq!(a, b);
        assert_eq!(seq.evals(), par.evals());
    }

    #[test]
    fn warm_cost_cache_never_changes_percentile_observations() {
        // workers=1 threads the objective's one buffer pool — and its
        // warm cost cache — through every percentile repeat; workers=4
        // gives each chunk a fresh (cold) pool. The observations must be
        // bit-identical either way: warm reuse is an allocation/CPU
        // optimization, never a physics input.
        let thetas = probe_thetas(4);
        let mut warm = objective().tail_p95(6).with_workers(1);
        let mut cold = objective().tail_p95(6).with_workers(4);
        assert_eq!(warm.eval_batch(&thetas), cold.eval_batch(&thetas));
        // and interleaved single evals keep sharing the same warm pool
        let f1 = warm.eval(&thetas[0]);
        let f2 = cold.eval(&thetas[0]);
        assert_eq!(f1, f2);
    }

    #[test]
    fn tail_p95_sits_in_the_right_tail() {
        let theta = objective().space.default_theta();
        let mut mean_like = objective();
        let runs: Vec<f64> = (0..15).map(|_| mean_like.eval(&theta)).collect();
        let mean = runs.iter().sum::<f64>() / runs.len() as f64;
        let mut tail = objective().tail_p95(15);
        let p95 = tail.eval(&theta);
        assert!(p95 >= mean, "p95 {p95} below mean {mean}");
    }

    #[test]
    fn quadratic_minimum_at_target() {
        let mut o = QuadraticObjective::new(vec![0.3, 0.7], 0.0, 1);
        let at_target = o.eval(&[0.3, 0.7]);
        let away = o.eval(&[0.9, 0.1]);
        assert!(at_target < away);
        assert!((at_target - 1.0).abs() < 1e-12);
    }

    #[test]
    fn frozen_objective_matches_hand_expanded_evals() {
        // freezing dims 0 and 2 of a 3-dim quadratic at the template:
        // the reduced view must replay the exact observation stream of
        // hand-expanded full-dim proposals (same rng draws, same order)
        let template = vec![0.1, 0.5, 0.9];
        let frozen = [true, false, true];
        let mut a = QuadraticObjective::new(vec![0.3, 0.7, 0.2], 0.05, 7);
        let mut b = QuadraticObjective::new(vec![0.3, 0.7, 0.2], 0.05, 7);
        let mut fo = FrozenObjective::new(&mut a, template.clone(), &frozen);
        assert_eq!(fo.dim(), 1);
        assert_eq!(fo.expand(&[0.4]), vec![0.1, 0.4, 0.9]);
        let got = fo.eval_batch(&[vec![0.4], vec![0.6]]);
        let want = b.eval_batch(&[vec![0.1, 0.4, 0.9], vec![0.1, 0.6, 0.9]]);
        assert_eq!(got, want);
        assert_eq!(fo.evals(), b.evals());
    }
}
