//! The paper's contribution: SPSA-based Hadoop parameter tuning
//! (Algorithm 1 + the §5 adaptations), with a pluggable noisy objective.

pub mod objective;
pub mod spsa;

pub use objective::{Metric, Objective, ObsAgg, QuadraticObjective, SimObjective};
pub use spsa::{
    IterRecord, Spsa, SpsaConfig, SpsaState, SpsaVariant, StopReason, TuningResult,
};
