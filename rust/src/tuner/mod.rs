//! The paper's contribution: SPSA-based Hadoop parameter tuning
//! (Algorithm 1 + the §5 adaptations), with a pluggable noisy objective —
//! plus the unified tuner interface every comparison algorithm runs
//! behind: the budget-metered, memoizing [`EvalBroker`] and the
//! [`Tuner`] trait + registry.

pub mod broker;
pub mod nelder_mead;
pub mod objective;
pub mod rdsa;
pub mod registry;
pub mod spsa;
pub mod tpe;

pub use broker::{
    live_best, Budget, BudgetAxis, CachePolicy, EvalBroker, EvalRecord, ObsSource,
    DEFAULT_DISPATCH_OVERHEAD_S,
};
pub use nelder_mead::{NelderMeadConfig, NelderMeadTuner};
pub use objective::{
    FrozenObjective, Metric, Objective, ObsAgg, QuadraticObjective, SimObjective,
};
pub use rdsa::RdsaTuner;
pub use registry::{Tuner, TuneOutcome, TunerContext, TunerEntry, PROFILE_NOISE_SIGMA, TUNERS};
pub use spsa::{
    IterRecord, Spsa, SpsaConfig, SpsaState, SpsaVariant, StopReason, TuningResult,
};
pub use tpe::{TpeConfig, TpeTuner};
