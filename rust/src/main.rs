//! `repro` — CLI for the hadoop-spsa reproduction.
//!
//! Subcommands:
//!   run         simulate one job and print the trace
//!   scenario    simulate a job under fault injection / heterogeneity and
//!               compare against the benign cluster
//!   tune        run a tuning algorithm on a benchmark
//!   experiment  regenerate a paper table/figure (table1 | fig6 | fig7 |
//!               fig8 | fig9 | table2 | robustness | walltime | tenancy |
//!               headline | all)
//!   serve       replay a multi-tenant request stream through the tuning
//!               service (cross-campaign observation store, warm starts)
//!               and print the deterministic result JSON
//!   whatif      evaluate a configuration on the analytic model /
//!               AOT artifact and compare with the simulator
//!   lint        run the in-repo determinism & metering lints over
//!               rust/src and diff against the committed baseline
//!   bench       measure the simulator hot path (median ns/event,
//!               events/sec, allocation metering) and diff against the
//!               committed BENCH_sim.json perf baseline
//!   list        show benchmarks, parameters and algorithms

// the CLI's error/usage surface: stderr is the right channel here
#![allow(clippy::print_stderr)]

use hadoop_spsa::cluster::ClusterSpec;
use hadoop_spsa::config::{HadoopVersion, ParameterSpace};
use hadoop_spsa::coordinator::{profile_for, run_trial, Algo, ResultsDir, TrialSpec};
use hadoop_spsa::experiments::{self, ExpOptions};
use hadoop_spsa::runtime::{ArtifactWhatIf, Runtime};
use hadoop_spsa::sim::{simulate, ScenarioSpec, SimOptions};
use hadoop_spsa::tuner::Budget;
use hadoop_spsa::util::cli::Args;
use hadoop_spsa::util::table::Table;
use hadoop_spsa::util::units::fmt_secs;
use hadoop_spsa::whatif::{cost_for_theta, ClusterFeatures};
use hadoop_spsa::workloads::Benchmark;

/// Meter allocation traffic for `repro bench`. Binary-only: the library
/// and test targets keep the system allocator (see `util::alloc`).
#[global_allocator]
static ALLOC: hadoop_spsa::util::alloc::CountingAlloc = hadoop_spsa::util::alloc::CountingAlloc;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let cmd = argv.get(1).map(|s| s.as_str()).unwrap_or("help");
    let rc = match cmd {
        "run" => cmd_run(),
        "scenario" => cmd_scenario(),
        "tune" => cmd_tune(),
        "experiment" => cmd_experiment(),
        "serve" => cmd_serve(),
        "whatif" => cmd_whatif(),
        "lint" => cmd_lint(),
        "bench" => cmd_bench(),
        "list" => cmd_list(),
        _ => {
            println!(
                "repro — Performance Tuning of Hadoop MapReduce: A Noisy Gradient Approach\n\n\
                 USAGE: repro <run|scenario|tune|experiment|serve|whatif|lint|bench|list> [flags]\n\
                 Run `repro <cmd> --help` for per-command flags."
            );
            0
        }
    };
    std::process::exit(rc);
}

fn parse_version(s: &str) -> HadoopVersion {
    if s.contains('2') {
        HadoopVersion::V2
    } else {
        HadoopVersion::V1
    }
}

fn parse_benchmark(s: &str) -> Benchmark {
    Benchmark::from_name(s).unwrap_or_else(|| {
        eprintln!("unknown benchmark '{s}' (see `repro list`)");
        std::process::exit(2);
    })
}

fn cmd_run() -> i32 {
    let parsed = Args::new("repro run", "simulate one job and print its trace")
        .flag("benchmark", Some("terasort"), "benchmark name")
        .flag("version", Some("v1"), "hadoop version (v1|v2)")
        .flag("seed", Some("1"), "simulation seed")
        .switch("no-noise", "disable stochastic task noise")
        .parse_env(2);
    let p = match parsed {
        Ok(p) => p,
        Err(u) => {
            println!("{u}");
            return 2;
        }
    };
    let bench = parse_benchmark(&p.get_str("benchmark"));
    let version = parse_version(&p.get_str("version"));
    let space = ParameterSpace::for_version(version);
    let w = profile_for(bench, 1000);
    let r = simulate(
        &ClusterSpec::paper_cluster(),
        &space.default_config(),
        &w,
        &SimOptions {
            seed: p.get_u64("seed").unwrap_or(1),
            noise: !p.get_bool("no-noise"),
            ..Default::default()
        },
    );
    println!(
        "benchmark: {bench} ({} input)",
        hadoop_spsa::util::units::fmt_bytes(w.input_bytes)
    );
    print!("{}", r.report());
    0
}

/// Parse a crash schedule `"t:node[,t:node...]"` (seconds:worker).
fn parse_crashes(s: &str) -> Result<Vec<(f64, u32)>, String> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (t, node) = part
            .split_once(':')
            .ok_or_else(|| format!("bad crash entry '{part}' (want seconds:node)"))?;
        let t: f64 = t.trim().parse().map_err(|e| format!("crash time '{t}': {e}"))?;
        let node: u32 = node.trim().parse().map_err(|e| format!("crash node '{node}': {e}"))?;
        out.push((t, node));
    }
    Ok(out)
}

/// Parse a heterogeneity list `"node:speed[,node:speed...]"`.
fn parse_slow_nodes(s: &str) -> Result<Vec<(u32, f64)>, String> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (node, speed) = part
            .split_once(':')
            .ok_or_else(|| format!("bad slow-node entry '{part}' (want node:speed)"))?;
        let node: u32 = node.trim().parse().map_err(|e| format!("slow node '{node}': {e}"))?;
        let speed: f64 =
            speed.trim().parse().map_err(|e| format!("slow-node speed '{speed}': {e}"))?;
        out.push((node, speed));
    }
    Ok(out)
}

fn cmd_scenario() -> i32 {
    let parsed = Args::new(
        "repro scenario",
        "simulate a job under fault injection / heterogeneity and compare with the benign cluster",
    )
    .flag("benchmark", Some("terasort"), "benchmark name")
    .flag("version", Some("v1"), "hadoop version (v1|v2)")
    .flag("seed", Some("1"), "simulation seed")
    .flag("runs", Some("5"), "noisy runs per summary line")
    .flag("failure-p", Some("0.05"), "per-attempt task failure probability")
    .flag("max-attempts", Some("4"), "failed attempts per task before the job is killed")
    .flag("crash", Some(""), "node-crash schedule 'seconds:node[,seconds:node...]'")
    .flag("slow", Some(""), "heterogeneous nodes 'node:speed[,...]' (speed 1.0 = nominal)")
    .switch("speculative", "enable speculative execution (map + reduce)")
    .parse_env(2);
    let p = match parsed {
        Ok(p) => p,
        Err(u) => {
            println!("{u}");
            return 2;
        }
    };
    let bench = parse_benchmark(&p.get_str("benchmark"));
    let version = parse_version(&p.get_str("version"));
    let space = ParameterSpace::for_version(version);
    // A typo in a numeric flag must abort, not silently simulate a
    // different scenario than the one the user asked for.
    let numbers = (|| -> Result<(u64, u64, f64, u64), String> {
        Ok((
            p.get_u64("seed")?,
            p.get_u64("runs")?,
            p.get_f64("failure-p")?,
            p.get_u64("max-attempts")?,
        ))
    })();
    let (seed, runs, failure_p, max_attempts) = match numbers {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let runs = runs.max(1);

    let mut scenario =
        ScenarioSpec::default().with_failures(failure_p).with_max_attempts(max_attempts);
    match parse_crashes(&p.get_str("crash")) {
        Ok(crashes) => {
            for (t, node) in crashes {
                scenario = scenario.with_crash(t, node);
            }
        }
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    }
    match parse_slow_nodes(&p.get_str("slow")) {
        Ok(slow) => {
            for (node, speed) in slow {
                scenario = scenario.with_slow_node(node, speed);
            }
        }
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    }
    if p.get_bool("speculative") {
        scenario = scenario.with_speculation(true);
    }

    let cluster = ClusterSpec::paper_cluster();
    let w = profile_for(bench, 1000);
    let cfg = space.default_config();
    println!(
        "benchmark: {bench} ({} input)   scenario: p_fail={} max_attempts={} \
         crashes={} slow_nodes={} speculation={}\n",
        hadoop_spsa::util::units::fmt_bytes(w.input_bytes),
        scenario.task_failure_p,
        scenario.max_attempts,
        scenario.node_crashes.len(),
        scenario.slow_nodes.len(),
        scenario.speculative_maps,
    );

    // one detailed trace under the scenario ...
    let r = simulate(
        &cluster,
        &cfg,
        &w,
        &SimOptions { seed, noise: true, scenario: scenario.clone() },
    );
    print!("{}", r.report());

    // ... and a mean/p95 summary against the benign cluster
    let collect = |scn: &ScenarioSpec| -> Vec<f64> {
        (0..runs)
            .map(|i| {
                simulate(
                    &cluster,
                    &cfg,
                    &w,
                    &SimOptions { seed: seed ^ (i + 1), noise: true, scenario: scn.clone() },
                )
                .exec_time_s
            })
            .collect()
    };
    use hadoop_spsa::util::stats::{mean, percentile};
    let faulty = collect(&scenario);
    let benign = collect(&ScenarioSpec::default());
    println!(
        "\nover {runs} runs   scenario: mean {} p95 {}   benign: mean {} p95 {}",
        fmt_secs(mean(&faulty)),
        fmt_secs(percentile(&faulty, 95.0)),
        fmt_secs(mean(&benign)),
        fmt_secs(percentile(&benign, 95.0)),
    );
    0
}

fn cmd_tune() -> i32 {
    let parsed = Args::new("repro tune", "tune a benchmark with one registry tuner")
        .flag("benchmark", Some("terasort"), "benchmark name")
        .flag("version", Some("v1"), "hadoop version (v1|v2)")
        .flag("tuner", Some("spsa"), "registry tuner name (see `repro list`)")
        .flag("budget", Some("90"), "live-observation budget (all tuners share this currency)")
        .flag(
            "max-batches",
            Some("0"),
            "dispatch-round cap, 0 = uncapped (one round ≈ one parallel wave)",
        )
        .flag(
            "max-time",
            Some("0"),
            "modeled wall-clock cap in simulated seconds, 0 = uncapped",
        )
        .flag("seed", Some("7"), "tuner seed")
        .flag("metric", Some("time"), "objective: time|spills|shuffle|reduce-spill (spsa only)")
        .flag(
            "policy",
            Some("single"),
            "single (one tuner), or a scheduler interleaving many tuners on ONE shared \
             modeled clock: equal|halving|hyperband|bandit",
        )
        .flag(
            "tuners",
            Some(""),
            "comma-separated registry tuners for scheduler policies (default: whole registry)",
        )
        .flag(
            "total-time",
            Some("6000"),
            "shared modeled clock for scheduler policies (simulated seconds)",
        )
        .flag("rungs-out", None, "write the scheduler's allocation audit trail to this TSV file")
        .flag(
            "checkpoint-out",
            None,
            "write a resume envelope (JSON) if the budget pauses the tuner before it terminates",
        )
        .flag("resume", None, "resume from an envelope written by --checkpoint-out")
        .flag("out", None, "write the run's deterministic outcome JSON to this file")
        .parse_env(2);
    let p = match parsed {
        Ok(p) => p,
        Err(u) => {
            println!("{u}");
            return 2;
        }
    };
    let policy = p.get_str("policy");
    if policy != "single" {
        return tune_scheduled(&p, &policy);
    }
    let algo = Algo::from_name(&p.get_str("tuner")).unwrap_or_else(|| {
        eprintln!("unknown tuner '{}' (see `repro list`)", p.get_str("tuner"));
        std::process::exit(2);
    });
    let budget = match (|| -> Result<Budget, String> {
        let mut b = Budget::obs(p.get_u64("budget")?);
        let max_batches = p.get_u64("max-batches")?;
        if max_batches > 0 {
            b = b.with_batches(max_batches);
        }
        let max_time = p.get_f64("max-time")?;
        if max_time > 0.0 {
            b = b.with_model_time(max_time);
        }
        Ok(b)
    })() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if p.get("checkpoint-out").is_some() || p.get("resume").is_some() || p.get("out").is_some() {
        return tune_checkpointed(&p, algo, budget);
    }
    let spec = TrialSpec::new(
        parse_benchmark(&p.get_str("benchmark")),
        parse_version(&p.get_str("version")),
        algo,
        p.get_u64("seed").unwrap_or(7),
    )
    .with_budget(budget);

    // alternative objective metrics (paper §4.2) — SPSA path only, still
    // through the registry tuner + metered broker
    let metric = hadoop_spsa::tuner::Metric::from_name(&p.get_str("metric"))
        .unwrap_or(hadoop_spsa::tuner::Metric::ExecTime);
    if metric != hadoop_spsa::tuner::Metric::ExecTime {
        use hadoop_spsa::tuner::registry::SpsaTuner;
        use hadoop_spsa::tuner::{EvalBroker, SimObjective, Tuner};
        let space = ParameterSpace::for_version(spec.version);
        let w = profile_for(spec.benchmark, 1000);
        let cluster = ClusterSpec::paper_cluster();
        let mut obj = SimObjective::new(space.clone(), cluster, w, spec.seed)
            .with_metric(metric);
        let f0 = {
            use hadoop_spsa::tuner::Objective;
            obj.eval(&space.default_theta())
        };
        let mut broker = EvalBroker::new(&mut obj, spec.budget);
        let out = SpsaTuner::paper().tune(&mut broker, &space, spec.seed);
        println!(
            "SPSA minimizing {}: default {:.3e} → best {:.3e} ({} observations of {} budgeted)",
            metric.label(),
            f0,
            out.best_f,
            broker.evals_used(),
            spec.budget.max_obs
        );
        return 0;
    }

    let o = run_trial(&spec);
    println!(
        "{} on {} ({}): default {} → tuned {} (±{:.0}s)  [{:.0}% decrease]",
        algo.label(),
        spec.benchmark,
        spec.version,
        fmt_secs(o.default_mean_s),
        fmt_secs(o.tuned_mean_s),
        o.tuned_std_s,
        o.pct_decrease()
    );
    println!(
        "observations: {}/{}   model wall-clock: {}{}   model evals: {}   profiling: {}   \
         tuner wall: {:.0} ms",
        o.observations,
        o.spec.budget.max_obs,
        fmt_secs(o.elapsed_model_s),
        if o.spec.budget.max_model_time.is_finite() {
            format!(" of {}", fmt_secs(o.spec.budget.max_model_time))
        } else {
            String::new()
        },
        o.model_evals,
        if o.profiling_overhead_s > 0.0 {
            fmt_secs(o.profiling_overhead_s)
        } else {
            "none".into()
        },
        o.tuning_wall_ms
    );
    let space = ParameterSpace::for_version(spec.version);
    let vals = space.to_hadoop_values(&o.tuned_theta);
    let mut t = Table::new("tuned configuration").header(vec!["parameter", "default", "tuned"]);
    for (i, param) in space.params().iter().enumerate() {
        t.row(vec![
            param.name.to_string(),
            param.default_value().display(),
            vals[i].display(),
        ]);
    }
    print!("{}", t.to_ascii());
    0
}

/// `repro tune --policy equal|halving|hyperband|bandit`: run a
/// [`CampaignScheduler`] campaign — many tuners interleaved on one shared
/// modeled clock with slot-contention charging — and optionally dump the
/// allocation audit trail (the `scheduler-gauntlet` CI fixture) as TSV.
fn tune_scheduled(p: &hadoop_spsa::util::cli::Parsed, policy: &str) -> i32 {
    use hadoop_spsa::coordinator::{CampaignScheduler, RungEvent, SchedulerPolicy};

    let Some(pol) = SchedulerPolicy::from_name(policy) else {
        eprintln!("unknown policy '{policy}' (want single|equal|halving|hyperband|bandit)");
        return 2;
    };
    let bench = parse_benchmark(&p.get_str("benchmark"));
    let version = parse_version(&p.get_str("version"));
    let numbers = (|| -> Result<(u64, f64), String> {
        Ok((p.get_u64("seed")?, p.get_f64("total-time")?))
    })();
    let (seed, total) = match numbers {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if !(total > 0.0) {
        eprintln!("--total-time must be positive (modeled seconds shared by all tuners)");
        return 2;
    }
    let mut sched = CampaignScheduler::new(bench, version, seed, total).with_policy(pol);
    let csv = p.get_str("tuners");
    if !csv.trim().is_empty() {
        let mut algos = Vec::new();
        for name in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            match Algo::from_name(name) {
                Some(a) => algos.push(a),
                None => {
                    eprintln!("unknown tuner '{name}' (see `repro list`)");
                    return 2;
                }
            }
        }
        sched = sched.with_algos(algos);
    }
    let (outs, events) = sched.run_with_events();

    let mut t = Table::new(&format!(
        "{} on {bench} ({version}) — shared clock {total:.0} s",
        pol.name()
    ))
    .header(vec![
        "Tuner",
        "Allocated (s)",
        "Charged (s)",
        "Obs",
        "Culled at rung",
        "Best observed f (s)",
    ]);
    for o in &outs {
        t.row(vec![
            o.algo.label().to_string(),
            format!("{:.0}", o.allocated_s),
            format!("{:.0}", o.charged_s),
            o.observations.to_string(),
            o.culled_at_rung.map(|r| r.to_string()).unwrap_or_else(|| "-".into()),
            if o.best_f.is_finite() { format!("{:.1}", o.best_f) } else { "-".into() },
        ]);
    }
    print!("{}", t.to_ascii());
    println!("{} allocation event(s)", events.len());
    if let Some(path) = p.get("rungs-out") {
        let mut s = String::from(RungEvent::tsv_header());
        s.push('\n');
        for e in &events {
            s.push_str(&e.tsv_row());
            s.push('\n');
        }
        if let Err(e) = std::fs::write(path, s) {
            eprintln!("repro tune: writing {path}: {e}");
            return 2;
        }
        println!("allocation audit written to {path}");
    }
    0
}

/// `repro tune --checkpoint-out/--resume/--out`: one checkpointable tuner,
/// run through its resume channel. A run split across two invocations at a
/// budget boundary must produce an `--out` JSON byte-identical to the
/// uninterrupted run at the larger budget — the `resume-equivalence` CI
/// gate `cmp`s exactly that, at `HSPSA_WORKERS=1` and `4`.
fn tune_checkpointed(p: &hadoop_spsa::util::cli::Parsed, algo: Algo, budget: Budget) -> i32 {
    use hadoop_spsa::tuner::registry::{self, TunerContext};
    use hadoop_spsa::tuner::{CachePolicy, EvalBroker, Objective, SimObjective};
    use hadoop_spsa::util::json::Json;

    let bench = parse_benchmark(&p.get_str("benchmark"));
    let version = parse_version(&p.get_str("version"));
    let seed = p.get_u64("seed").unwrap_or(7);
    let space = ParameterSpace::for_version(version);
    let cluster = ClusterSpec::paper_cluster();
    let w = profile_for(bench, 1000);
    let ctx = TunerContext { version, cluster: cluster.clone(), workload: w.clone() };
    let tuner = registry::create(algo.name(), &ctx).expect("Algo maps to a registry entry");
    if !tuner.checkpointable() {
        eprintln!(
            "repro tune: '{}' has no checkpoint channel — checkpointable tuners: {}",
            algo.name(),
            registry::names()
                .into_iter()
                .filter(|n| registry::create(n, &ctx).is_some_and(|t| t.checkpointable()))
                .collect::<Vec<_>>()
                .join(", ")
        );
        return 2;
    }

    // the prior segment's meters + tuner state, if resuming
    let prior = match p.get("resume") {
        None => None,
        Some(path) => {
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("repro tune: reading {path}: {e}");
                    return 2;
                }
            };
            let parse = || -> Result<(String, u64, u64, f64, String), String> {
                let doc = Json::parse(&text)?;
                let field = |k: &str| doc.get(k).ok_or_else(|| format!("missing '{k}'"));
                let num =
                    |k: &str| -> Result<f64, String> { field(k)?.as_f64().ok_or(format!("'{k}' not a number")) };
                Ok((
                    field("tuner")?.as_str().ok_or("'tuner' not a string")?.to_string(),
                    num("obs")? as u64,
                    num("batches")? as u64,
                    num("elapsed_s")?,
                    field("state")?.as_str().ok_or("'state' not a string")?.to_string(),
                ))
            };
            match parse() {
                Ok(v) => Some(v),
                Err(e) => {
                    eprintln!("repro tune: {path}: {e}");
                    return 2;
                }
            }
        }
    };
    if let Some((name, ..)) = &prior {
        if name != algo.name() {
            eprintln!("repro tune: checkpoint is for '{name}', not '{}'", algo.name());
            return 2;
        }
    }

    // The memo cache is broker-local and cannot survive a segment
    // boundary, so checkpointed runs always disable it — straight runs on
    // this path too, keeping the two invocations' traces comparable.
    let mut obj = SimObjective::new(space.clone(), cluster, w, seed);
    let (out, ck, obs, batches, elapsed) = match &prior {
        Some((_, p_obs, p_batches, p_elapsed, state)) => {
            if !obj.advance_evals(*p_obs) {
                eprintln!(
                    "repro tune: checkpoint claims {p_obs} observations but the objective \
                     stream refused to fast-forward"
                );
                return 2;
            }
            let mut broker = EvalBroker::new(&mut obj, budget)
                .with_cache(CachePolicy::Off)
                .with_prior_spend(*p_obs, *p_batches, *p_elapsed);
            let (out, ck) = tuner.tune_resumable(&mut broker, &space, seed, Some(state.as_bytes()));
            (out, ck, broker.evals_used(), broker.batches_used(), broker.elapsed_model_time())
        }
        None => {
            let mut broker = EvalBroker::new(&mut obj, budget).with_cache(CachePolicy::Off);
            let (out, ck) = tuner.tune_resumable(&mut broker, &space, seed, None);
            (out, ck, broker.evals_used(), broker.batches_used(), broker.elapsed_model_time())
        }
    };
    println!(
        "{} on {bench} ({version}): {obs} observation(s) in {batches} wave(s), {} modeled — \
         best f {:.3} [{}]",
        algo.label(),
        fmt_secs(elapsed),
        out.best_f,
        if ck.is_some() { "paused, resumable" } else { "terminal" }
    );

    if let Some(path) = p.get("checkpoint-out") {
        match &ck {
            Some(bytes) => {
                let state =
                    String::from_utf8(bytes.clone()).expect("checkpoint envelopes are JSON text");
                let mut env = Json::obj();
                env.set("tuner", Json::Str(algo.name().to_string()))
                    .set("obs", Json::Num(obs as f64))
                    .set("batches", Json::Num(batches as f64))
                    .set("elapsed_s", Json::Num(elapsed))
                    .set("state", Json::Str(state));
                if let Err(e) = std::fs::write(path, env.to_pretty()) {
                    eprintln!("repro tune: writing {path}: {e}");
                    return 2;
                }
                println!("checkpoint written to {path}");
            }
            None => eprintln!(
                "repro tune: run reached a terminal stop — nothing to checkpoint, {path} not written"
            ),
        }
    }
    if let Some(path) = p.get("out") {
        let mut doc = Json::obj();
        doc.set("tuner", Json::Str(algo.name().to_string()))
            .set("benchmark", Json::Str(bench.to_string()))
            .set("version", Json::Str(version.to_string()))
            .set("seed", Json::Num(seed as f64))
            .set("observations", Json::Num(obs as f64))
            .set("waves", Json::Num(batches as f64))
            .set("elapsed_model_s", Json::Num(elapsed))
            .set("best_f", Json::Num(out.best_f))
            .set("best_theta", Json::from_f64_slice(&out.best_theta))
            .set("terminal", Json::Bool(ck.is_none()));
        if let Err(e) = std::fs::write(path, doc.to_pretty()) {
            eprintln!("repro tune: writing {path}: {e}");
            return 2;
        }
        println!("outcome written to {path}");
    }
    0
}

fn cmd_experiment() -> i32 {
    let parsed = Args::new(
        "repro experiment",
        "regenerate a paper table/figure (positional: table1 fig6 fig7 fig8 fig9 table2 robustness walltime tenancy headline ablation holistic all)",
    )
    .switch("quick", "reduced seeds/iterations")
    .flag("out", Some("results"), "output directory for md/csv")
    .parse_env(2);
    let p = match parsed {
        Ok(p) => p,
        Err(u) => {
            println!("{u}");
            return 2;
        }
    };
    let which = p.positional().first().map(|s| s.as_str()).unwrap_or("all").to_string();
    let out = ResultsDir::new(p.get_str("out")).expect("results dir");
    let opts = ExpOptions { quick: p.get_bool("quick"), out: Some(out) };

    let mut ran = false;
    let sel = |name: &str| which == name || which == "all";
    if sel("table1") {
        println!("{}", experiments::table1::run(&opts));
        ran = true;
    }
    if sel("fig6") {
        println!("{}", experiments::convergence::run(HadoopVersion::V1, &opts));
        ran = true;
    }
    if sel("fig7") {
        println!("{}", experiments::convergence::run(HadoopVersion::V2, &opts));
        ran = true;
    }
    if sel("fig8") {
        println!("{}", experiments::comparison::run(HadoopVersion::V1, &opts));
        ran = true;
    }
    if sel("fig9") {
        println!("{}", experiments::comparison::run(HadoopVersion::V2, &opts));
        ran = true;
    }
    if sel("table2") {
        println!("{}", experiments::table2::run(&opts));
        ran = true;
    }
    if sel("robustness") {
        println!("{}", experiments::robustness::run(&opts));
        ran = true;
    }
    if sel("walltime") {
        println!("{}", experiments::walltime::run(&opts));
        ran = true;
    }
    if sel("tenancy") {
        println!("{}", experiments::tenancy::run(&opts));
        ran = true;
    }
    if sel("holistic") {
        println!("{}", experiments::holistic::run(&opts));
        ran = true;
    }
    if sel("ablation") {
        println!("{}", experiments::ablation::run(&opts));
        ran = true;
    }
    if sel("headline") {
        let (_, report) = experiments::headline::compute(&opts);
        println!("{report}");
        ran = true;
    }
    if !ran {
        eprintln!("unknown experiment '{which}'");
        return 2;
    }
    0
}

fn cmd_serve() -> i32 {
    use hadoop_spsa::coordinator::{parse_script, stream_json, TuningService};

    let parsed = Args::new(
        "repro serve",
        "replay a multi-tenant request stream through the tuning service and print the \
         deterministic result JSON (byte-identical across replays at any worker count)",
    )
    .flag(
        "script",
        Some("rust/tests/fixtures/service/requests.tsv"),
        "request script: one 'tenant benchmark version tuner seed budget' line per request",
    )
    .flag("out", None, "also write the result JSON to this file")
    .parse_env(2);
    let p = match parsed {
        Ok(p) => p,
        Err(u) => {
            println!("{u}");
            return 2;
        }
    };
    let script_path = p.get_str("script");
    let text = match std::fs::read_to_string(&script_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("repro serve: reading {script_path}: {e}");
            return 2;
        }
    };
    let reqs = match parse_script(&text) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("repro serve: {script_path}: {e}");
            return 2;
        }
    };
    let mut svc = TuningService::new();
    let outcomes = svc.run_stream(&reqs);
    let json = stream_json(&outcomes, svc.store()).to_pretty();
    if let Some(out) = p.get("out") {
        if let Err(e) = std::fs::write(out, &json) {
            eprintln!("repro serve: writing {out}: {e}");
            return 2;
        }
        let warm = outcomes.iter().filter(|o| o.warm_started).count();
        println!(
            "{} request(s) served ({} warm-started), result written to {out}",
            outcomes.len(),
            warm
        );
    } else {
        println!("{json}");
    }
    0
}

fn cmd_whatif() -> i32 {
    let parsed = Args::new(
        "repro whatif",
        "evaluate a θ on the analytic model, the AOT artifact and the simulator",
    )
    .flag("benchmark", Some("terasort"), "benchmark name")
    .flag("version", Some("v1"), "hadoop version")
    .flag("theta", None, "comma-separated θ_A in [0,1]^11 (default: defaults)")
    .parse_env(2);
    let p = match parsed {
        Ok(p) => p,
        Err(u) => {
            println!("{u}");
            return 2;
        }
    };
    let bench = parse_benchmark(&p.get_str("benchmark"));
    let version = parse_version(&p.get_str("version"));
    let space = ParameterSpace::for_version(version);
    let theta: Vec<f64> = match p.get("theta") {
        Some(s) => s.split(',').filter_map(|x| x.trim().parse().ok()).collect(),
        None => space.default_theta(),
    };
    if theta.len() != space.dim() {
        eprintln!("theta needs {} coordinates", space.dim());
        return 2;
    }
    let w = profile_for(bench, 1000);
    let cluster = ClusterSpec::paper_cluster();
    let features = ClusterFeatures::from_spec(&cluster, version);

    let model = cost_for_theta(&space, &theta, &w, &features);
    let sim = simulate(
        &cluster,
        &space.materialize(&theta),
        &w,
        &SimOptions { seed: 1, noise: false, ..Default::default() },
    )
    .exec_time_s;
    println!("rust what-if model  : {}", fmt_secs(model));
    println!("simulator (no noise): {}", fmt_secs(sim));

    if Runtime::artifacts_present("artifacts") {
        let rt = Runtime::default_dir().expect("PJRT client");
        let mut art = ArtifactWhatIf::new(&rt, space.clone(), &w, &features).expect("artifact");
        use hadoop_spsa::baselines::CostEvaluator;
        let got = art.eval_batch(std::slice::from_ref(&theta));
        println!("AOT artifact (PJRT) : {}", fmt_secs(got[0]));
    } else {
        println!("AOT artifact        : skipped (run `make artifacts`)");
    }
    0
}

fn cmd_lint() -> i32 {
    use hadoop_spsa::analysis::{self, baseline::Baseline, report, rules};

    let parsed = Args::new(
        "repro lint",
        "static determinism/metering lints over rust/src, diffed against the committed baseline",
    )
    .flag("root", Some("rust/src"), "source tree to lint")
    .flag("format", Some("table"), "output format (table|json)")
    .flag(
        "baseline",
        Some("rust/tests/fixtures/lint/baseline.json"),
        "baseline findings ledger to diff against",
    )
    .switch("update-baseline", "rewrite the baseline to accept exactly the current findings")
    .switch("no-baseline", "ignore the baseline: any finding at all fails")
    .switch("rules", "list the registered rules and exit")
    .parse_env(2);
    let p = match parsed {
        Ok(p) => p,
        Err(u) => {
            println!("{u}");
            return 2;
        }
    };
    if p.get_bool("rules") {
        let mut t = Table::new("repro lint rules").header(vec!["rule", "summary"]);
        for r in rules::all() {
            t.row(vec![r.name, r.summary]);
        }
        print!("{}", t.to_ascii());
        return 0;
    }

    let root = std::path::PathBuf::from(p.get_str("root"));
    let lint_report = match analysis::lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("repro lint: {e}");
            return 2;
        }
    };
    let baseline_path = p.get_str("baseline");

    if p.get_bool("update-baseline") {
        let prev = std::fs::read_to_string(&baseline_path)
            .ok()
            .and_then(|s| Baseline::parse(&s).ok());
        let next = Baseline::from_findings(&lint_report.findings, prev.as_ref());
        // to_pretty already ends with the file's single trailing newline
        let body = next.to_json().to_pretty();
        if let Err(e) = std::fs::write(&baseline_path, body) {
            eprintln!("repro lint: writing {baseline_path}: {e}");
            return 2;
        }
        println!(
            "wrote {} entr{} to {baseline_path} ({} finding(s) accepted)",
            next.entries.len(),
            if next.entries.len() == 1 { "y" } else { "ies" },
            lint_report.findings.len(),
        );
        return 0;
    }

    let baseline = if p.get_bool("no-baseline") {
        None
    } else {
        match std::fs::read_to_string(&baseline_path) {
            Ok(s) => match Baseline::parse(&s) {
                Ok(b) => Some(b),
                Err(e) => {
                    eprintln!("repro lint: {baseline_path}: {e}");
                    return 2;
                }
            },
            Err(e) => {
                eprintln!(
                    "repro lint: reading {baseline_path}: {e}\n\
                     (run `repro lint --update-baseline` to create it, or pass --no-baseline)"
                );
                return 2;
            }
        }
    };
    let diff = baseline.as_ref().map(|b| b.diff(&lint_report));

    match p.get_str("format").as_str() {
        "json" => println!("{}", report::to_json(&lint_report, diff.as_ref()).to_pretty()),
        "table" => print!("{}", report::to_table(&lint_report, diff.as_ref())),
        other => {
            eprintln!("unknown format '{other}' (want table|json)");
            return 2;
        }
    }
    let clean = match &diff {
        Some(d) => d.clean(),
        None => lint_report.findings.is_empty(),
    };
    if clean {
        0
    } else {
        1
    }
}

fn cmd_bench() -> i32 {
    use hadoop_spsa::experiments::perf;
    use hadoop_spsa::util::json::Json;

    let parsed = Args::new(
        "repro bench",
        "measure the simulator hot path and diff against the committed perf baseline",
    )
    .flag("baseline", Some("BENCH_sim.json"), "committed perf baseline to compare against")
    .flag("out", None, "also write the fresh results to this JSON file")
    .switch("quick", "short CI-friendly measurement (noisier medians)")
    .switch("check", "exit 1 when a case regresses past the generous gate")
    .switch("update-baseline", "rewrite the baseline file with the fresh results")
    .parse_env(2);
    let p = match parsed {
        Ok(p) => p,
        Err(u) => {
            println!("{u}");
            return 2;
        }
    };
    let quick = p.get_bool("quick");
    let results = perf::run_all(quick);
    let doc = perf::to_json(&results, quick);
    println!("\n{} case(s) measured", results.len());

    let baseline_path = p.get_str("baseline");
    if p.get_bool("update-baseline") {
        if let Err(e) = std::fs::write(&baseline_path, doc.to_pretty()) {
            eprintln!("repro bench: writing {baseline_path}: {e}");
            return 2;
        }
        println!("wrote {} case(s) to {baseline_path}", results.len());
        return 0;
    }
    if let Some(out) = p.get("out") {
        if let Err(e) = std::fs::write(out, doc.to_pretty()) {
            eprintln!("repro bench: writing {out}: {e}");
            return 2;
        }
        println!("results written to {out}");
    }

    let baseline = match std::fs::read_to_string(&baseline_path) {
        Ok(s) => match Json::parse(&s) {
            Ok(j) => perf::parse_cases(&j),
            Err(e) => {
                eprintln!("repro bench: {baseline_path}: {e}");
                return 2;
            }
        },
        Err(e) => {
            eprintln!(
                "repro bench: reading {baseline_path}: {e}\n\
                 (run `repro bench --update-baseline` to create it)"
            );
            return 2;
        }
    };
    if baseline.is_empty() {
        println!(
            "baseline {baseline_path} has no cases yet — advisory run \
             (regenerate with `repro bench --update-baseline` on the CI runner class)"
        );
        return 0;
    }
    // Stale baseline entries (cases renamed or removed since the baseline
    // was sealed) are flagged but never affect the exit code.
    for name in perf::stale_cases(&results, &baseline) {
        println!(
            "STALE baseline case '{name}' is no longer measured — advisory; \
             reseal with `repro bench --update-baseline`"
        );
    }
    let violations = perf::check(&results, &baseline);
    if violations.is_empty() {
        println!("all {} case(s) within the regression gate", results.len());
        return 0;
    }
    for v in &violations {
        println!("REGRESSION {v}");
    }
    if p.get_bool("check") {
        1
    } else {
        println!("(advisory: pass --check to fail on regressions)");
        0
    }
}

fn cmd_list() -> i32 {
    let parsed = Args::new("repro list", "show benchmarks, parameters and tuners")
        .switch(
            "names",
            "print only the canonical registry tuner names, one per line (CI diffs this \
             against rust/tests/fixtures/registry_names.txt)",
        )
        .parse_env(2);
    let p = match parsed {
        Ok(p) => p,
        Err(u) => {
            println!("{u}");
            return 2;
        }
    };
    if p.get_bool("names") {
        for name in hadoop_spsa::tuner::registry::names() {
            println!("{name}");
        }
        return 0;
    }
    println!("benchmarks:");
    for b in Benchmark::all() {
        println!(
            "  {:<20} partial workload {}",
            b.label(),
            hadoop_spsa::util::units::fmt_bytes(b.paper_partial_bytes())
        );
    }
    println!(
        "\ntuners (registry, {} entries; all metered by one observation budget):",
        hadoop_spsa::tuner::TUNERS.len()
    );
    for e in hadoop_spsa::tuner::TUNERS {
        let aliases = if e.aliases.is_empty() {
            String::new()
        } else {
            format!("  (aliases: {})", e.aliases.join(", "))
        };
        println!("  {:<16} {}{}", e.name, e.summary, aliases);
    }
    for version in [HadoopVersion::V1, HadoopVersion::V2] {
        let space = ParameterSpace::for_version(version);
        let mut t = Table::new(&format!("parameters (Hadoop {version})")).header(vec![
            "name", "kind", "min", "max", "default", "doc",
        ]);
        for p in space.params() {
            t.row(vec![
                p.name.to_string(),
                format!("{:?}", p.kind),
                format!("{}", p.min),
                format!("{}", p.max),
                format!("{}", p.default),
                p.doc.to_string(),
            ]);
        }
        print!("\n{}", t.to_ascii());
    }
    0
}
