//! Deterministic pseudo-random number generation.
//!
//! The offline build environment ships no `rand` crate, so this module
//! provides the small set of distributions the project needs on top of a
//! [xoshiro256++][xo] generator seeded through SplitMix64. Determinism per
//! seed is a hard requirement: every experiment records its seed and every
//! simulated job run must be exactly reproducible.
//!
//! [xo]: https://prng.di.unimi.it/

/// SplitMix64 step used to expand a single `u64` seed into generator state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Small, fast, and with a 2^256-1 period — more than
/// enough for millions of simulated task durations.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child generator (used to give each simulated
    /// task / tuning run its own stream without correlation).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mixed = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::seeded(mixed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased enough
    /// for simulation purposes; n is always far below 2^32 here).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // widening multiply rejection-free variant; bias < 2^-64 * n.
        let x = self.next_u64();
        ((x as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Fair coin.
    #[inline]
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Rademacher variable: ±1 with probability ½ each — the SPSA
    /// perturbation distribution of the paper's Example 2.
    #[inline]
    pub fn rademacher(&mut self) -> f64 {
        if self.bool() {
            1.0
        } else {
            -1.0
        }
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Log-normal multiplicative noise with mean ≈ 1 and the given sigma of
    /// the underlying normal. Used for task-duration jitter (heavy right
    /// tail, like real stragglers).
    pub fn lognormal_unit_mean(&mut self, sigma: f64) -> f64 {
        // E[exp(N(mu, sigma^2))] = exp(mu + sigma^2/2) == 1  ⇒  mu = -sigma^2/2
        let mu = -0.5 * sigma * sigma;
        (mu + sigma * self.gaussian()).exp()
    }

    /// Sample a Zipf(s)-distributed rank in [1, n]. Used by the synthetic
    /// corpus generator to reproduce natural-language key skew.
    /// Rejection-inversion sampler (Hörmann & Derflinger).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        if n == 1 {
            return 1;
        }
        // For s == 1 the H function degenerates; nudge slightly.
        let s = if (s - 1.0).abs() < 1e-9 { 1.0 + 1e-9 } else { s };
        let h = |x: f64| -> f64 { ((x + 0.5).powf(1.0 - s) - 1.0) / (1.0 - s) };
        let h_inv = |x: f64| -> f64 { (1.0 + x * (1.0 - s)).powf(1.0 / (1.0 - s)) - 0.5 };
        let hx0 = h(0.5) - 1.0; // h(x0) - f(1)
        let hn = h(n as f64 + 0.5);
        loop {
            let u = hx0 + self.f64() * (hn - hx0);
            let x = h_inv(u);
            let k = (x + 0.5).floor().max(1.0).min(n as f64) as u64;
            // accept
            let fk = (k as f64).powf(-s);
            if u >= h(k as f64 + 0.5) - fk {
                return k;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Snapshot the full generator state (xoshiro words + the cached
    /// Box–Muller spare) for checkpoint serialization. Restoring via
    /// [`Rng::from_state`] continues the stream bit-exactly.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Rng {
        Rng { s, gauss_spare }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::seeded(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::seeded(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10) as usize;
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn rademacher_is_pm_one_and_balanced() {
        let mut r = Rng::seeded(5);
        let n = 100_000;
        let sum: f64 = (0..n)
            .map(|_| {
                let v = r.rademacher();
                assert!(v == 1.0 || v == -1.0);
                v
            })
            .sum();
        assert!(sum.abs() / (n as f64) < 0.01);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seeded(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn lognormal_unit_mean_is_unit_mean() {
        let mut r = Rng::seeded(13);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.lognormal_unit_mean(0.2)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::seeded(17);
        let n = 50_000;
        let mut count_1 = 0u64;
        let mut count_tail = 0u64;
        for _ in 0..n {
            let k = r.zipf(1000, 1.1);
            assert!((1..=1000).contains(&k));
            if k == 1 {
                count_1 += 1;
            }
            if k > 500 {
                count_tail += 1;
            }
        }
        // rank 1 must dominate any individual deep-tail rank.
        assert!(count_1 > n / 50, "rank-1 count {count_1}");
        assert!(count_1 > count_tail / 10);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn state_snapshot_resumes_the_stream_bit_exactly() {
        // The checkpoint contract: capture mid-stream — including with a
        // Box–Muller spare pending — and the restored generator must
        // produce the identical remaining stream.
        let mut r = Rng::seeded(31);
        for _ in 0..7 {
            r.gaussian(); // odd count ⇒ gauss_spare is Some(..)
        }
        let (s, spare) = r.state();
        assert!(spare.is_some(), "odd gaussian count must leave a spare");
        let mut resumed = Rng::from_state(s, spare);
        for _ in 0..100 {
            assert_eq!(r.gaussian().to_bits(), resumed.gaussian().to_bits());
            assert_eq!(r.next_u64(), resumed.next_u64());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::seeded(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
