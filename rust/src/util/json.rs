//! Minimal JSON value model, writer and parser.
//!
//! serde is not available in the offline build (DESIGN.md §7); the project
//! only needs JSON for tuner checkpoints (pause/resume, paper §6.8) and
//! experiment result files, so a compact hand-rolled implementation is
//! sufficient. Numbers are stored as f64; objects preserve insertion order.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps output deterministic (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).collect())
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    x.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; encode as null like most tolerant writers.
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::Str("terasort".into()))
            .set("iters", Json::Num(30.0))
            .set("alpha", Json::Num(0.01))
            .set("done", Json::Bool(false))
            .set("theta", Json::from_f64_slice(&[0.1, 0.5, 0.99]));
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn roundtrip_pretty() {
        let mut j = Json::obj();
        j.set("a", Json::Arr(vec![Json::Num(1.0), Json::Null]));
        let back = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#"{"s": "a\"b\\c\ndA"}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str().unwrap(), "a\"b\\c\nd\u{41}");
    }

    #[test]
    fn parses_numbers() {
        let j = Json::parse("[1, -2.5, 3e2, 0.001]").unwrap();
        assert_eq!(j.to_f64_vec().unwrap(), vec![1.0, -2.5, 300.0, 0.001]);
    }

    #[test]
    fn integer_formatting_has_no_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn nan_encodes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn unicode_roundtrip() {
        let j = Json::Str("héllo ✓ 日本".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
