//! Micro-bench harness used by the `harness = false` bench targets
//! (criterion is unavailable offline, DESIGN.md §7). Measures wall-clock
//! over warmup + timed iterations and prints mean ± stddev and throughput.

use std::time::Instant;

use super::stats::OnlineStats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    /// Median of the per-iteration samples — the robust central figure
    /// `repro bench` baselines on (a page fault or scheduler hiccup moves
    /// the mean, not the median).
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }

    pub fn summary(&self) -> String {
        let (val, unit) = humanize_ns(self.mean_ns);
        format!(
            "{:<40} {:>10.3} {}  (±{:.1}%, {} iters, {:.1}/s)",
            self.name,
            val,
            unit,
            100.0 * self.stddev_ns / self.mean_ns.max(1e-12),
            self.iters,
            self.per_sec()
        )
    }
}

fn humanize_ns(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "µs")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s")
    }
}

/// Run `f` repeatedly: `warmup` untimed iterations, then timed iterations
/// until `min_time_s` of cumulative measurement (at least `min_iters`).
pub fn bench<F: FnMut()>(name: &str, warmup: u64, min_iters: u64, min_time_s: f64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = OnlineStats::new();
    let mut samples: Vec<f64> = Vec::new();
    let mut total = 0.0;
    let mut iters = 0u64;
    while iters < min_iters || total < min_time_s {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        stats.push(dt * 1e9);
        samples.push(dt * 1e9);
        total += dt;
        iters += 1;
        if iters > 10_000_000 {
            break; // safety valve
        }
    }
    samples.sort_by(f64::total_cmp);
    let median_ns = if samples.is_empty() { 0.0 } else { samples[samples.len() / 2] };
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: stats.mean(),
        median_ns,
        stddev_ns: stats.stddev(),
        iters,
    };
    println!("{}", r.summary());
    r
}

/// Convenience harness: standard settings for project benches.
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 2, 5, 0.5, f)
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0u64;
        let r = bench("noop", 1, 10, 0.0, || {
            n += 1;
            black_box(n);
        });
        assert!(r.iters >= 10);
        assert!(n >= r.iters);
        assert!(r.mean_ns >= 0.0);
        assert!(r.median_ns >= 0.0);
    }

    #[test]
    fn humanize_units() {
        assert_eq!(humanize_ns(500.0).1, "ns");
        assert_eq!(humanize_ns(5_000.0).1, "µs");
        assert_eq!(humanize_ns(5_000_000.0).1, "ms");
        assert_eq!(humanize_ns(5e9).1, "s");
    }
}
