//! Tiny declarative command-line flag parser (clap is unavailable offline,
//! DESIGN.md §7). Supports `--flag value`, `--flag=value`, boolean `--flag`,
//! positional arguments, and auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative argument parser.
#[derive(Clone, Debug)]
pub struct Args {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            flags: Vec::new(),
            values: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Declare a value flag with an optional default.
    pub fn flag(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(|s| s.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a boolean switch (defaults to false).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} [FLAGS]\n\nFLAGS:\n", self.program, self.about, self.program);
        for f in &self.flags {
            let d = match (&f.default, f.is_bool) {
                (Some(d), _) => format!(" (default: {d})"),
                (None, true) => " (switch)".to_string(),
                (None, false) => String::new(),
            };
            s.push_str(&format!("  --{:<24} {}{}\n", f.name, f.help, d));
        }
        s
    }

    /// Parse a raw argument list. Returns Err with a usage string on bad
    /// input or `--help`.
    pub fn parse(mut self, argv: &[String]) -> Result<Parsed, String> {
        for f in &self.flags {
            if let Some(d) = &f.default {
                self.values.insert(f.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline_val) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?
                    .clone();
                let val = if spec.is_bool {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| format!("flag --{name} expects a value"))?
                };
                self.values.insert(name, val);
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Parsed { values: self.values, positional: self.positional })
    }

    /// Parse from `std::env::args()`, skipping the binary name (and an
    /// optional subcommand that the caller has already consumed).
    pub fn parse_env(self, skip: usize) -> Result<Parsed, String> {
        let argv: Vec<String> = std::env::args().skip(skip).collect();
        self.parse(&argv)
    }
}

/// Result of parsing.
#[derive(Clone, Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str) -> String {
        self.get(name).unwrap_or("").to_string()
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn spec() -> Args {
        Args::new("t", "test")
            .flag("benchmark", Some("grep"), "which benchmark")
            .flag("iters", Some("30"), "iterations")
            .switch("verbose", "chatty")
    }

    #[test]
    fn defaults_apply() {
        let p = spec().parse(&argv(&[])).unwrap();
        assert_eq!(p.get_str("benchmark"), "grep");
        assert_eq!(p.get_u64("iters").unwrap(), 30);
        assert!(!p.get_bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let p = spec().parse(&argv(&["--benchmark", "terasort", "--iters=5"])).unwrap();
        assert_eq!(p.get_str("benchmark"), "terasort");
        assert_eq!(p.get_u64("iters").unwrap(), 5);
    }

    #[test]
    fn switch_sets_true() {
        let p = spec().parse(&argv(&["--verbose"])).unwrap();
        assert!(p.get_bool("verbose"));
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(spec().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(spec().parse(&argv(&["--iters"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let p = spec().parse(&argv(&["pos1", "--verbose", "pos2"])).unwrap();
        assert_eq!(p.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn help_returns_usage() {
        let e = spec().parse(&argv(&["--help"])).unwrap_err();
        assert!(e.contains("USAGE"));
        assert!(e.contains("--benchmark"));
    }
}
