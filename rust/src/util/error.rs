//! Minimal error type standing in for the `anyhow` crate (unavailable in
//! the offline build, DESIGN.md §7): a string-message error, a `Result`
//! alias, and a `Context` extension trait for `Result`/`Option` mirroring
//! the `anyhow::Context` API surface this project uses.

use std::fmt;

/// A boxed string error with optional context chain.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::msg(s)
    }
}

/// Project-wide result alias (the `anyhow::Result` stand-in).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context(self, msg: impl Into<String>) -> Result<T>;
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", msg.into())))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.into()))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_wraps_message() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.context("opening file").unwrap_err();
        let s = format!("{e}");
        assert!(s.contains("opening file") && s.contains("gone"), "{s}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn question_mark_on_io_error() {
        fn inner() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(s)
        }
        assert!(inner().is_err());
    }
}
