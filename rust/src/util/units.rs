//! Byte / time unit helpers: parsing ("30GB", "128MB") and humanized
//! formatting for tables and logs.

pub const KB: u64 = 1 << 10;
pub const MB: u64 = 1 << 20;
pub const GB: u64 = 1 << 30;
pub const TB: u64 = 1 << 40;

/// Parse a size string: bare bytes, or suffixed with KB/MB/GB/TB (case
/// insensitive, optional 'B', decimal values allowed: "1.5GB").
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let t = s.trim().to_ascii_uppercase();
    let (num, mult) = if let Some(p) = t.strip_suffix("TB") {
        (p, TB)
    } else if let Some(p) = t.strip_suffix("GB") {
        (p, GB)
    } else if let Some(p) = t.strip_suffix("MB") {
        (p, MB)
    } else if let Some(p) = t.strip_suffix("KB") {
        (p, KB)
    } else if let Some(p) = t.strip_suffix('T') {
        (p, TB)
    } else if let Some(p) = t.strip_suffix('G') {
        (p, GB)
    } else if let Some(p) = t.strip_suffix('M') {
        (p, MB)
    } else if let Some(p) = t.strip_suffix('K') {
        (p, KB)
    } else if let Some(p) = t.strip_suffix('B') {
        (p, 1)
    } else {
        (t.as_str(), 1)
    };
    let v: f64 = num.trim().parse().map_err(|e| format!("bad size '{s}': {e}"))?;
    if v < 0.0 {
        return Err(format!("negative size '{s}'"));
    }
    Ok((v * mult as f64).round() as u64)
}

/// Humanize a byte count ("1.5 GB").
pub fn fmt_bytes(b: u64) -> String {
    let bf = b as f64;
    if b >= TB {
        format!("{:.2} TB", bf / TB as f64)
    } else if b >= GB {
        format!("{:.2} GB", bf / GB as f64)
    } else if b >= MB {
        format!("{:.1} MB", bf / MB as f64)
    } else if b >= KB {
        format!("{:.1} KB", bf / KB as f64)
    } else {
        format!("{b} B")
    }
}

/// Humanize a duration in seconds ("2m 13s", "1h 02m").
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "∞".to_string();
    }
    if s < 1.0 {
        format!("{:.0} ms", s * 1e3)
    } else if s < 60.0 {
        format!("{s:.1} s")
    } else if s < 3600.0 {
        format!("{}m {:02.0}s", (s / 60.0) as u64, s % 60.0)
    } else {
        format!("{}h {:02}m", (s / 3600.0) as u64, ((s % 3600.0) / 60.0) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_suffixes() {
        assert_eq!(parse_bytes("128MB").unwrap(), 128 * MB);
        assert_eq!(parse_bytes("30gb").unwrap(), 30 * GB);
        assert_eq!(parse_bytes("1.5GB").unwrap(), (1.5 * GB as f64) as u64);
        assert_eq!(parse_bytes("200 MB").unwrap(), 200 * MB);
        assert_eq!(parse_bytes("1024").unwrap(), 1024);
        assert_eq!(parse_bytes("64K").unwrap(), 64 * KB);
        assert_eq!(parse_bytes("512B").unwrap(), 512);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_bytes("abc").is_err());
        assert!(parse_bytes("-5MB").is_err());
    }

    #[test]
    fn fmt_roundtrip_scales() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * MB), "2.0 MB");
        assert!(fmt_bytes(3 * GB).contains("GB"));
    }

    #[test]
    fn fmt_secs_scales() {
        assert_eq!(fmt_secs(0.5), "500 ms");
        assert_eq!(fmt_secs(12.34), "12.3 s");
        assert!(fmt_secs(130.0).starts_with("2m"));
        assert!(fmt_secs(3725.0).starts_with("1h"));
    }
}
