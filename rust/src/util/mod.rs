//! Shared infrastructure: RNG, statistics, JSON, tables, CLI parsing, the
//! micro-bench harness, and the mini property-testing framework.
//!
//! These exist because the offline build has no access to `rand`, `serde`,
//! `clap`, `criterion` or `proptest` (see DESIGN.md §7); each submodule is a
//! small, tested stand-in scoped to exactly what this project needs.

pub mod alloc;
pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
