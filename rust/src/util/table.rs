//! ASCII / markdown table rendering for the experiment harnesses — every
//! paper table and figure is regenerated as rows printed through this module.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table { title: title.to_string(), header: Vec::new(), rows: Vec::new() }
    }

    pub fn header<S: Into<String>>(mut self, cols: Vec<S>) -> Self {
        self.header = cols.into_iter().map(Into::into).collect();
        self
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        self.rows.push(cells.into_iter().map(Into::into).collect());
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let ncol = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn to_ascii(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, width) in w.iter().enumerate() {
                let cell = cells.get(i).map(|s| s.as_str()).unwrap_or("");
                line.push_str(&format!("{:<width$}  ", cell, width = width));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * w.len()));
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavoured markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        if !self.header.is_empty() {
            out.push_str(&format!("| {} |\n", self.header.join(" | ")));
            out.push_str(&format!(
                "|{}|\n",
                self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
            ));
        }
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// Render as CSV (for plotting outside the repo).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        if !self.header.is_empty() {
            out.push_str(&self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&r.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Render a simple horizontal-bar chart into text — used by the fig6–fig9
/// harnesses so the "figure" is visible directly in terminal output.
pub fn bar_chart(title: &str, entries: &[(String, f64)], width: usize) -> String {
    let max = entries.iter().map(|(_, v)| *v).fold(0.0_f64, f64::max);
    let label_w = entries.iter().map(|(l, _)| l.chars().count()).max().unwrap_or(0);
    let mut out = format!("== {title} ==\n");
    for (label, v) in entries {
        let n = if max > 0.0 { ((v / max) * width as f64).round() as usize } else { 0 };
        out.push_str(&format!(
            "{:<label_w$}  {:>10.1}  {}\n",
            label,
            v,
            "#".repeat(n),
            label_w = label_w
        ));
    }
    out
}

/// Render a convergence curve (iteration → value) as a text sparkline block.
pub fn curve(title: &str, values: &[f64], height: usize) -> String {
    if values.is_empty() {
        return format!("== {title} == (empty)\n");
    }
    let min = values.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (max - min).max(1e-12);
    let mut rows = vec![String::new(); height];
    for &v in values {
        let level = (((v - min) / span) * (height - 1) as f64).round() as usize;
        for (h, row) in rows.iter_mut().enumerate() {
            // rows[0] is the top of the chart
            let y = height - 1 - h;
            row.push(if y == level { '*' } else if y < level { ' ' } else { ' ' });
        }
    }
    let mut out = format!("== {title} ==  (min {min:.1}, max {max:.1})\n");
    for row in rows {
        out.push_str(&format!("|{row}\n"));
    }
    out.push_str(&format!("+{}\n", "-".repeat(values.len())));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo").header(vec!["a", "bb", "ccc"]);
        t.row(vec!["1", "2", "3"]);
        t.row(vec!["10", "20", "30"]);
        t
    }

    #[test]
    fn ascii_alignment() {
        let s = sample().to_ascii();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header, rule, two rows, plus the title line
        assert_eq!(lines.len(), 5);
        assert!(lines[1].starts_with("a "));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("| a | bb | ccc |"));
        assert!(md.contains("|---|---|---|"));
        assert!(md.contains("| 10 | 20 | 30 |"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("").header(vec!["x"]);
        t.row(vec!["a,b"]);
        t.row(vec!["q\"q"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart("b", &[("x".into(), 10.0), ("y".into(), 5.0)], 20);
        let x_bars = s.lines().find(|l| l.starts_with('x')).unwrap().matches('#').count();
        let y_bars = s.lines().find(|l| l.starts_with('y')).unwrap().matches('#').count();
        assert_eq!(x_bars, 20);
        assert_eq!(y_bars, 10);
    }

    #[test]
    fn curve_renders() {
        let s = curve("c", &[3.0, 2.0, 1.0, 1.0], 3);
        assert!(s.contains("== c =="));
        assert_eq!(s.lines().filter(|l| l.starts_with('|')).count(), 3);
    }
}
