//! Small statistics helpers shared by the simulator, tuners and the bench
//! harness: online mean/variance, percentiles, and simple vector summaries.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Arithmetic mean of a slice (NaN on empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation of a slice.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation; `q` is clamped into [0, 100]
/// (out-of-range and NaN `q` used to index out of bounds).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let q = if q.is_nan() { 50.0 } else { q.clamp(0.0, 100.0) };
    let mut v: Vec<f64> = xs.to_vec();
    // total order: a NaN sample (e.g. a poisoned observation) sorts to the
    // high end instead of panicking mid-rank
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Geometric mean (for speedup aggregation across benchmarks).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Relative improvement `(base - new) / base` in percent — the paper's
/// "% decrease in execution time" metric.
pub fn pct_decrease(base: f64, new: f64) -> f64 {
    100.0 * (base - new) / base
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn merge_matches_single_pass() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        let mut full = OnlineStats::new();
        for &x in &xs {
            full.push(x);
        }
        assert!((a.mean() - full.mean()).abs() < 1e-9);
        assert!((a.variance() - full.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_clamps_out_of_range_q() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        // q > 100 used to panic with an index out of bounds
        assert_eq!(percentile(&xs, 101.0), 4.0);
        assert_eq!(percentile(&xs, 1e9), 4.0);
        assert_eq!(percentile(&xs, -5.0), 1.0);
        assert_eq!(percentile(&xs, f64::NEG_INFINITY), 1.0);
        assert_eq!(percentile(&xs, f64::INFINITY), 4.0);
        assert!(percentile(&xs, f64::NAN).is_finite());
        assert_eq!(percentile(&[7.5], 250.0), 7.5);
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // a NaN sample used to panic `partial_cmp().unwrap()` mid-sort;
        // under total_cmp it sorts above +inf and low percentiles stay
        // meaningful
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn geomean_of_constants() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pct_decrease_basic() {
        assert!((pct_decrease(100.0, 34.0) - 66.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_nan() {
        assert!(mean(&[]).is_nan());
        assert!(percentile(&[], 50.0).is_nan());
        assert!(geomean(&[]).is_nan());
    }
}
