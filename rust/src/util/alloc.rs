//! Counting global allocator for perf metering (`repro bench`).
//!
//! [`CountingAlloc`] wraps the system allocator and keeps three relaxed
//! atomic tallies: total allocation calls, live bytes, and the peak of
//! live bytes. The binary installs it via `#[global_allocator]` in
//! `main.rs`; the library and test targets keep the plain system
//! allocator, so the counters are a strictly opt-in measurement surface —
//! physics and tests never see them. Relaxed ordering is fine: the bench
//! harness reads the counters from the same thread that just ran the
//! workload, and cross-thread skew only blurs metering, never physics.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static TOTAL_ALLOCS: AtomicU64 = AtomicU64::new(0);
static LIVE_BYTES: AtomicU64 = AtomicU64::new(0);
static PEAK_LIVE_BYTES: AtomicU64 = AtomicU64::new(0);

/// System-allocator wrapper that meters allocation traffic.
pub struct CountingAlloc;

fn on_alloc(size: usize) {
    TOTAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
    let live = LIVE_BYTES.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
    PEAK_LIVE_BYTES.fetch_max(live, Ordering::Relaxed);
}

fn on_dealloc(size: usize) {
    // saturating: a free observed before its (relaxed) alloc tally must
    // not wrap the gauge
    let _ = LIVE_BYTES.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
        Some(v.saturating_sub(size as u64))
    });
}

// SAFETY: defers every allocation verbatim to `System`; the bookkeeping
// touches only atomics and never the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        on_dealloc(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            on_dealloc(layout.size());
            on_alloc(new_size);
        }
        p
    }
}

/// Allocation calls since process start.
pub fn total_allocs() -> u64 {
    TOTAL_ALLOCS.load(Ordering::Relaxed)
}

/// High-water mark of live heap bytes since process start.
pub fn peak_live_bytes() -> u64 {
    PEAK_LIVE_BYTES.load(Ordering::Relaxed)
}

/// True when a [`CountingAlloc`] is actually installed as the global
/// allocator in this process (the counters have seen traffic). Library
/// consumers and test binaries run on the system allocator, where every
/// counter stays zero.
pub fn metering_available() -> bool {
    total_allocs() > 0
}

/// Snapshot of the allocation counters, for before/after deltas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocSnapshot {
    pub total_allocs: u64,
    pub peak_live_bytes: u64,
}

/// Take a counter snapshot (all zeros when metering is unavailable).
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot { total_allocs: total_allocs(), peak_live_bytes: peak_live_bytes() }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: nothing here may call `on_alloc`/`on_dealloc` — the counters
    // are process-global statics shared with every other test in this
    // binary, and `metering_available()` must stay false wherever the
    // allocator isn't installed (other tests assert exactly that).
    #[test]
    fn counters_stay_inert_without_installation() {
        // The test binary does NOT install CountingAlloc, so the global
        // hooks never fire — exactly the `metering_available` contract.
        let a = snapshot();
        let _v: Vec<u64> = (0..1024).collect();
        assert_eq!(snapshot(), a);
        assert!(!metering_available());
        assert_eq!(a, AllocSnapshot::default());
        // dealloc under-run clamps at zero instead of wrapping the gauge
        // (same arithmetic `on_dealloc` applies to LIVE_BYTES)
        assert_eq!(0u64.saturating_sub(1 << 20), 0);
    }
}
