//! Mini property-based testing framework (proptest is unavailable offline,
//! DESIGN.md §7). Provides seeded generators, a `forall` runner with
//! counterexample reporting, and bounded shrinking for scalar inputs.
//!
//! Usage:
//! ```ignore
//! prop::forall("mu stays in range", 500, |g| {
//!     let x = g.f64_in(0.0, 1.0);
//!     let v = mu(x);
//!     prop::assert_that(v >= min && v <= max, format!("v={v}"))
//! });
//! ```

use super::rng::Rng;

/// Outcome of a single property check.
pub type PropResult = Result<(), String>;

/// Assertion helper producing a `PropResult`.
pub fn assert_that(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality assertion.
pub fn assert_close(a: f64, b: f64, tol: f64) -> PropResult {
    let scale = a.abs().max(b.abs()).max(1.0);
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

/// Generator handle passed to each property trial.
pub struct Gen {
    rng: Rng,
    /// Log of generated scalars this trial, for the failure report.
    trace: Vec<(String, f64)>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::seeded(seed), trace: Vec::new() }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// f64 uniform in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.range_f64(lo, hi);
        self.trace.push(("f64".into(), v));
        v
    }

    /// u64 uniform in [lo, hi] inclusive.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        let v = self.rng.range_u64(lo, hi);
        self.trace.push(("u64".into(), v as f64));
        v
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_in(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.bool();
        self.trace.push(("bool".into(), v as u64 as f64));
        v
    }

    /// A unit-hypercube point of dimension n (the SPSA θ_A domain).
    pub fn unit_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(0.0, 1.0)).collect()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize_in(0, xs.len() - 1);
        &xs[i]
    }
}

/// Run `trials` checks of `property`, each with a distinct deterministic
/// seed. Panics with a replayable report on the first failure.
///
/// Set `PROP_SEED` in the environment to replay one specific trial.
pub fn forall<F>(name: &str, trials: u64, mut property: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let base = fnv1a(name.as_bytes());
    if let Ok(s) = std::env::var("PROP_SEED") {
        let seed: u64 = s.parse().expect("PROP_SEED must be u64");
        let mut g = Gen::new(seed);
        if let Err(msg) = property(&mut g) {
            panic!("property '{name}' failed under PROP_SEED={seed}: {msg}\ninputs: {:?}", g.trace);
        }
        return;
    }
    for t in 0..trials {
        let seed = base.wrapping_add(t.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = property(&mut g) {
            panic!(
                "property '{name}' failed at trial {t}/{trials}: {msg}\n\
                 inputs: {:?}\nreplay with PROP_SEED={seed}",
                g.trace
            );
        }
    }
}

/// Shrink a failing f64 input toward `anchor` while the predicate keeps
/// failing; returns the smallest failing value found. Used by tests that
/// want a minimal counterexample for a scalar property.
pub fn shrink_f64<F>(mut failing: f64, anchor: f64, mut still_fails: F) -> f64
where
    F: FnMut(f64) -> bool,
{
    for _ in 0..64 {
        let candidate = anchor + (failing - anchor) / 2.0;
        if (candidate - failing).abs() < 1e-12 {
            break;
        }
        if still_fails(candidate) {
            failing = candidate;
        } else {
            break;
        }
    }
    failing
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum commutative", 200, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            assert_close(a + b, b + a, 1e-12)
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_report() {
        forall("always fails", 5, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert_that(false, format!("x={x}"))
        });
    }

    #[test]
    fn shrink_converges() {
        // failing iff x > 3.0; shrink from 100 toward 0 should approach 3.
        let min = shrink_f64(100.0, 0.0, |x| x > 3.0);
        assert!(min > 3.0 && min < 3.2, "min {min}");
    }

    #[test]
    fn gen_ranges_respected() {
        forall("gen ranges", 200, |g| {
            let f = g.f64_in(2.0, 5.0);
            let u = g.u64_in(3, 9);
            assert_that((2.0..5.0).contains(&f) && (3..=9).contains(&u), "range")
        });
    }

    #[test]
    fn trials_are_deterministic() {
        let mut first: Vec<f64> = Vec::new();
        forall("det", 10, |g| {
            first.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        let mut second: Vec<f64> = Vec::new();
        forall("det", 10, |g| {
            second.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
