//! Parameter specifications: one [`ParamSpec`] per Hadoop knob, with the
//! min / max / default triple the paper's §5.1 mapping μ is built on.

/// How a Hadoop parameter value is typed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    /// Integer-valued: μ floors the affine map (paper §5.1).
    Int,
    /// Real-valued: μ is the plain affine map.
    Real,
    /// Boolean: thresholded at 0.5 in algorithm space.
    Bool,
}

/// A concrete Hadoop parameter value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ParamValue {
    Int(i64),
    Real(f64),
    Bool(bool),
}

impl ParamValue {
    pub fn as_f64(&self) -> f64 {
        match self {
            ParamValue::Int(v) => *v as f64,
            ParamValue::Real(v) => *v,
            ParamValue::Bool(b) => {
                if *b {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    pub fn as_i64(&self) -> i64 {
        match self {
            ParamValue::Int(v) => *v,
            ParamValue::Real(v) => *v as i64,
            ParamValue::Bool(b) => *b as i64,
        }
    }

    pub fn as_bool(&self) -> bool {
        match self {
            ParamValue::Bool(b) => *b,
            ParamValue::Int(v) => *v != 0,
            ParamValue::Real(v) => *v >= 0.5,
        }
    }

    /// Table-friendly rendering (matches the paper's Table 1 style).
    pub fn display(&self) -> String {
        match self {
            ParamValue::Int(v) => format!("{v}"),
            ParamValue::Real(v) => format!("{v:.2}"),
            ParamValue::Bool(b) => format!("{b}"),
        }
    }
}

/// Specification of one tunable Hadoop parameter.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    /// Short name as used in the paper's Table 1 (e.g. "io.sort.mb").
    pub name: &'static str,
    pub kind: ParamKind,
    /// Minimum of the Hadoop-value range S_i.
    pub min: f64,
    /// Maximum of the Hadoop-value range S_i.
    pub max: f64,
    /// Hadoop's default value θ_H^d(i).
    pub default: f64,
    /// One-line description for --help / docs.
    pub doc: &'static str,
}

impl ParamSpec {
    pub const fn new(
        name: &'static str,
        kind: ParamKind,
        min: f64,
        max: f64,
        default: f64,
        doc: &'static str,
    ) -> Self {
        ParamSpec { name, kind, min, max, default, doc }
    }

    /// Width of the Hadoop range (max − min); the paper's perturbation and
    /// minimum-useful-step scale is 1/width.
    pub fn width(&self) -> f64 {
        self.max - self.min
    }

    /// The paper's μ for this coordinate: affine map from algorithm space
    /// [0,1] into the Hadoop range, floored for integers, thresholded for
    /// booleans.
    pub fn to_hadoop(&self, theta_a: f64) -> ParamValue {
        let t = theta_a.clamp(0.0, 1.0);
        match self.kind {
            ParamKind::Int => {
                let v = (self.width() * t + self.min).floor();
                ParamValue::Int(v.clamp(self.min, self.max) as i64)
            }
            ParamKind::Real => ParamValue::Real(self.width() * t + self.min),
            ParamKind::Bool => ParamValue::Bool(t >= 0.5),
        }
    }

    /// Inverse of μ (used to seed SPSA at the default configuration):
    /// maps a Hadoop value back into [0,1].
    pub fn to_algo(&self, hadoop_value: f64) -> f64 {
        match self.kind {
            ParamKind::Bool => {
                if hadoop_value >= 0.5 {
                    0.75
                } else {
                    0.25
                }
            }
            _ => {
                if self.width() <= 0.0 {
                    0.0
                } else {
                    ((hadoop_value - self.min) / self.width()).clamp(0.0, 1.0)
                }
            }
        }
    }

    /// Default position in algorithm space.
    pub fn default_algo(&self) -> f64 {
        self.to_algo(self.default)
    }

    pub fn default_value(&self) -> ParamValue {
        match self.kind {
            ParamKind::Int => ParamValue::Int(self.default as i64),
            ParamKind::Real => ParamValue::Real(self.default),
            ParamKind::Bool => ParamValue::Bool(self.default >= 0.5),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn int_spec() -> ParamSpec {
        ParamSpec::new("io.sort.mb", ParamKind::Int, 50.0, 2000.0, 100.0, "")
    }

    fn real_spec() -> ParamSpec {
        ParamSpec::new("spill", ParamKind::Real, 0.05, 0.95, 0.8, "")
    }

    fn bool_spec() -> ParamSpec {
        ParamSpec::new("compress", ParamKind::Bool, 0.0, 1.0, 0.0, "")
    }

    #[test]
    fn mu_endpoints_int() {
        let s = int_spec();
        assert_eq!(s.to_hadoop(0.0), ParamValue::Int(50));
        assert_eq!(s.to_hadoop(1.0), ParamValue::Int(2000));
    }

    #[test]
    fn mu_floors_int() {
        let s = ParamSpec::new("x", ParamKind::Int, 0.0, 10.0, 0.0, "");
        assert_eq!(s.to_hadoop(0.55), ParamValue::Int(5)); // floor(5.5)
    }

    #[test]
    fn mu_real_affine() {
        let s = real_spec();
        let v = s.to_hadoop(0.5);
        assert!((v.as_f64() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn mu_clamps_out_of_range() {
        let s = real_spec();
        assert!((s.to_hadoop(-0.5).as_f64() - 0.05).abs() < 1e-12);
        assert!((s.to_hadoop(1.5).as_f64() - 0.95).abs() < 1e-12);
    }

    #[test]
    fn bool_threshold() {
        let s = bool_spec();
        assert!(!s.to_hadoop(0.49).as_bool());
        assert!(s.to_hadoop(0.5).as_bool());
    }

    #[test]
    fn inverse_roundtrip_real() {
        let s = real_spec();
        for t in [0.0, 0.3, 0.77, 1.0] {
            let h = s.to_hadoop(t).as_f64();
            assert!((s.to_algo(h) - t).abs() < 1e-9);
        }
    }

    #[test]
    fn default_algo_maps_back_to_default() {
        let s = int_spec();
        let v = s.to_hadoop(s.default_algo());
        assert_eq!(v, ParamValue::Int(100));
    }
}
