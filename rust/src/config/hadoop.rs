//! Typed Hadoop job configuration — the θ_H the simulator consumes.
//!
//! `HadoopConfig` carries the 11 tuned knobs (per version) plus the fixed
//! framework constants the paper does not tune (JVM heap sizes, replication)
//! so the simulator reads everything from one place.

use super::param::ParamValue;
use super::space::*;

/// Which MapReduce architecture is simulated (paper §2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum HadoopVersion {
    /// MapReduce v1: JobTracker/TaskTracker, fixed map/reduce slots.
    V1,
    /// YARN: ResourceManager/NodeManager, containers, slowstart/JVM reuse.
    V2,
}

impl HadoopVersion {
    pub fn label(&self) -> &'static str {
        match self {
            HadoopVersion::V1 => "v1.0.3",
            HadoopVersion::V2 => "v2.6.3",
        }
    }
}

impl std::fmt::Display for HadoopVersion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// OS-layer tunables (paper §7 future work: "the SPSA algorithm based
/// tuning can include parameters from other layers such OS, System,
/// Hardware" — the *holistic* space). Defaults are stock Linux values;
/// the extended parameter space (`ParameterSpace::extended`) exposes them
/// to the tuner. The what-if cost model deliberately cannot see them —
/// model-based tuners don't cross the OS boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct OsTuning {
    /// Block-device readahead in KB (`blockdev --setra`); helps sequential
    /// reads up to a point, thrashes the page cache when oversized under
    /// concurrency.
    pub readahead_kb: u64,
    /// TCP receive buffer ceiling in KB (`net.core.rmem_max`); caps the
    /// effective per-flow bandwidth at window/RTT.
    pub net_rmem_kb: u64,
    /// `vm.dirty_ratio`-style writeback threshold in (0,1); absorbs small
    /// spill bursts but causes writeback storms when too high.
    pub dirty_ratio: f64,
}

impl Default for OsTuning {
    fn default() -> Self {
        OsTuning { readahead_kb: 128, net_rmem_kb: 208, dirty_ratio: 0.2 }
    }
}

impl OsTuning {
    /// Sequential-read throughput multiplier from readahead (≥ 1, saturates
    /// ~1.3× at 4 MB, degrades slightly beyond from cache pressure).
    pub fn readahead_boost(&self) -> f64 {
        let steps = (self.readahead_kb.max(128) as f64 / 128.0).log2();
        let boost = 1.0 + 0.06 * steps.min(5.0);
        if self.readahead_kb > 4096 {
            boost - 0.04 * ((self.readahead_kb as f64 / 4096.0).log2())
        } else {
            boost
        }
        .max(1.0)
    }

    /// Per-flow bandwidth ceiling from the TCP window (bytes/s, 2 ms RTT).
    pub fn net_window_bw(&self) -> f64 {
        (self.net_rmem_kb as f64 * 1024.0) / 0.002
    }

    /// Multiplier on the per-spill-file constant cost: page-cache
    /// absorption vs writeback storms — optimum near dirty_ratio ≈ 0.6.
    pub fn spill_overhead_factor(&self) -> f64 {
        let d = self.dirty_ratio.clamp(0.0, 1.0);
        (1.0 - 0.6 * d + 0.5 * d * d).max(0.2)
    }
}

/// A fully-materialized Hadoop configuration (θ_H plus fixed constants).
#[derive(Clone, Debug, PartialEq)]
pub struct HadoopConfig {
    pub version: HadoopVersion,

    // -- tuned, common to both versions ------------------------------------
    /// io.sort.mb — map-side sort buffer (MB).
    pub io_sort_mb: u64,
    /// io.sort.spill.percent — buffer fraction triggering a spill.
    pub spill_percent: f64,
    /// io.sort.factor — streams merged per merge round.
    pub sort_factor: u64,
    /// shuffle.input.buffer.percent — reducer heap fraction for shuffle.
    pub shuffle_input_buffer_percent: f64,
    /// shuffle.merge.percent — shuffle buffer fill fraction forcing merge.
    pub shuffle_merge_percent: f64,
    /// inmem.merge.threshold — in-memory segment count forcing merge.
    pub inmem_merge_threshold: u64,
    /// reduce.input.buffer.percent — heap fraction retaining map output
    /// during the reduce function itself.
    pub reduce_input_buffer_percent: f64,
    /// mapred.reduce.tasks — number of reducers.
    pub reduce_tasks: u64,

    // -- tuned, v1 only -----------------------------------------------------
    /// io.sort.record.percent — metadata share of the sort buffer.
    pub sort_record_percent: f64,
    /// mapred.compress.map.output.
    pub compress_map_output: bool,
    /// mapred.output.compress.
    pub output_compress: bool,

    // -- tuned, v2 only -----------------------------------------------------
    /// reduce.slowstart.completedmaps.
    pub slowstart: f64,
    /// mapreduce.job.jvm.numtasks (JVM reuse).
    pub jvm_numtasks: u64,
    /// mapreduce.job.maps (map-count hint).
    pub job_maps: u64,

    // -- fixed framework constants (not tuned; paper §6.2 cluster) ----------
    /// HDFS block size in bytes (128 MB).
    pub dfs_block_size: u64,
    /// Reducer task heap in bytes (1 GB) — basis of the *.percent knobs.
    pub reduce_task_heap: u64,
    /// HDFS replication factor (paper: 2).
    pub dfs_replication: u64,

    /// OS-layer tunables (defaults unless the extended space is used).
    pub os: OsTuning,
}

impl HadoopConfig {
    /// Assemble from the ordered value vector produced by
    /// [`ParameterSpace::to_hadoop_values`].
    pub fn from_values(version: HadoopVersion, vals: &[ParamValue]) -> Self {
        assert_eq!(vals.len(), N_PARAMS);
        let mut c = HadoopConfig {
            version,
            io_sort_mb: vals[P_IO_SORT_MB].as_i64() as u64,
            spill_percent: vals[P_SPILL_PERCENT].as_f64(),
            sort_factor: vals[P_SORT_FACTOR].as_i64().max(2) as u64,
            shuffle_input_buffer_percent: vals[P_SHUFFLE_INPUT_BUFFER].as_f64(),
            shuffle_merge_percent: vals[P_SHUFFLE_MERGE_PERCENT].as_f64(),
            inmem_merge_threshold: vals[P_INMEM_MERGE_THRESHOLD].as_i64().max(2) as u64,
            reduce_input_buffer_percent: vals[P_REDUCE_INPUT_BUFFER].as_f64(),
            reduce_tasks: vals[P_REDUCE_TASKS].as_i64().max(1) as u64,
            // version-specific tails filled below
            sort_record_percent: 0.05,
            compress_map_output: false,
            output_compress: false,
            slowstart: 0.05,
            jvm_numtasks: 1,
            job_maps: 2,
            dfs_block_size: 128 << 20,
            reduce_task_heap: 1 << 30,
            dfs_replication: 2,
            os: OsTuning::default(),
        };
        match version {
            HadoopVersion::V1 => {
                c.sort_record_percent = vals[P_SORT_RECORD_PERCENT].as_f64();
                c.compress_map_output = vals[P_COMPRESS_MAP_OUTPUT].as_bool();
                c.output_compress = vals[P_OUTPUT_COMPRESS].as_bool();
            }
            HadoopVersion::V2 => {
                c.slowstart = vals[P_SLOWSTART].as_f64();
                c.jvm_numtasks = vals[P_JVM_NUMTASKS].as_i64().max(1) as u64;
                c.job_maps = vals[P_JOB_MAPS].as_i64().max(1) as u64;
            }
        }
        c
    }

    /// Map-side sort buffer in bytes.
    pub fn sort_buffer_bytes(&self) -> u64 {
        self.io_sort_mb << 20
    }

    /// Bytes of the sort buffer available for record *data* (v1 splits the
    /// buffer into data + record-metadata regions via io.sort.record.percent;
    /// v2 accounts metadata inline, modelled as a fixed 5 % overhead).
    pub fn sort_buffer_data_bytes(&self) -> u64 {
        let frac = match self.version {
            HadoopVersion::V1 => 1.0 - self.sort_record_percent,
            HadoopVersion::V2 => 0.95,
        };
        (self.sort_buffer_bytes() as f64 * frac) as u64
    }

    /// Record-metadata capacity of the sort buffer, in records. Each record
    /// costs 16 bytes of accounting space in v1.
    pub fn sort_buffer_record_capacity(&self) -> u64 {
        match self.version {
            HadoopVersion::V1 => {
                ((self.sort_buffer_bytes() as f64 * self.sort_record_percent) / 16.0) as u64
            }
            // v2: accounting space is carved per-record from the same
            // buffer; effectively bounded by data capacity / 16.
            HadoopVersion::V2 => self.sort_buffer_bytes() / 16,
        }
        .max(1)
    }

    /// Shuffle buffer capacity in bytes on a reducer.
    pub fn shuffle_buffer_bytes(&self) -> u64 {
        (self.reduce_task_heap as f64 * self.shuffle_input_buffer_percent) as u64
    }

    /// Effective slowstart fraction (v1 has the fixed Hadoop default 0.05).
    pub fn effective_slowstart(&self) -> f64 {
        match self.version {
            HadoopVersion::V1 => 0.05,
            HadoopVersion::V2 => self.slowstart,
        }
    }

    /// Effective JVM-reuse count (v1: one task per JVM).
    pub fn effective_jvm_reuse(&self) -> u64 {
        match self.version {
            HadoopVersion::V1 => 1,
            HadoopVersion::V2 => self.jvm_numtasks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::ParameterSpace;

    #[test]
    fn default_v1_config_fields() {
        let c = ParameterSpace::v1().default_config();
        assert_eq!(c.version, HadoopVersion::V1);
        assert_eq!(c.io_sort_mb, 100);
        assert_eq!(c.reduce_tasks, 1);
        assert!((c.sort_record_percent - 0.05).abs() < 1e-9);
        assert_eq!(c.effective_jvm_reuse(), 1);
        assert!((c.effective_slowstart() - 0.05).abs() < 1e-9);
    }

    #[test]
    fn default_v2_config_fields() {
        let c = ParameterSpace::v2().default_config();
        assert_eq!(c.version, HadoopVersion::V2);
        assert_eq!(c.jvm_numtasks, 1);
        assert_eq!(c.job_maps, 2);
        assert!((c.slowstart - 0.05).abs() < 1e-9);
    }

    #[test]
    fn buffer_byte_math() {
        let mut c = ParameterSpace::v1().default_config();
        c.io_sort_mb = 100;
        c.sort_record_percent = 0.05;
        assert_eq!(c.sort_buffer_bytes(), 100 << 20);
        let data = c.sort_buffer_data_bytes();
        assert!(data < c.sort_buffer_bytes());
        assert!((data as f64 / c.sort_buffer_bytes() as f64 - 0.95).abs() < 1e-6);
        // 5 MB of accounting space at 16 B/record
        assert_eq!(c.sort_buffer_record_capacity(), (5 << 20) / 16);
    }

    #[test]
    fn shuffle_buffer_follows_percent() {
        let mut c = ParameterSpace::v2().default_config();
        c.shuffle_input_buffer_percent = 0.5;
        assert_eq!(c.shuffle_buffer_bytes(), (1u64 << 30) / 2);
    }

    #[test]
    fn guards_against_degenerate_values() {
        // Even if the raw vector carries zeros, the config clamps to sane
        // minima (merge factor ≥ 2, ≥ 1 reducer).
        let vals = vec![
            ParamValue::Int(50),
            ParamValue::Real(0.05),
            ParamValue::Int(0),
            ParamValue::Real(0.1),
            ParamValue::Real(0.1),
            ParamValue::Int(0),
            ParamValue::Real(0.0),
            ParamValue::Int(0),
            ParamValue::Real(0.01),
            ParamValue::Bool(false),
            ParamValue::Bool(false),
        ];
        let c = HadoopConfig::from_values(HadoopVersion::V1, &vals);
        assert!(c.sort_factor >= 2);
        assert!(c.inmem_merge_threshold >= 2);
        assert!(c.reduce_tasks >= 1);
    }
}
