//! Hadoop parameter model: specs (§5.1), the tunable spaces of the paper's
//! Table 1 for Hadoop v1 and v2, and the typed configuration the simulator
//! consumes.

pub mod hadoop;
pub mod param;
pub mod space;

pub use hadoop::{HadoopConfig, HadoopVersion};
pub use param::{ParamKind, ParamSpec, ParamValue};
pub use space::{ParameterSpace, N_PARAMS};
