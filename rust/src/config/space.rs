//! The tunable parameter spaces: 11 parameters for Hadoop v1 and 11 for
//! Hadoop v2, exactly the sets of the paper's Table 1 (the v2 space swaps
//! the three v1-only knobs for `reduce.slowstart.completedmaps`,
//! `jvm.numtasks` and `job.maps`).
//!
//! The space owns the paper's §5.1 mapping μ : [0,1]^n → S₁ × … × Sₙ and the
//! §5.2 perturbation scaling δΔ(i) = ±1/(θ_H^max(i) − θ_H^min(i)).

use super::hadoop::{HadoopConfig, HadoopVersion};
use super::param::{ParamKind, ParamSpec, ParamValue};
use crate::util::rng::Rng;

/// Parameter indices shared by both versions (first 8 coordinates).
pub const P_IO_SORT_MB: usize = 0;
pub const P_SPILL_PERCENT: usize = 1;
pub const P_SORT_FACTOR: usize = 2;
pub const P_SHUFFLE_INPUT_BUFFER: usize = 3;
pub const P_SHUFFLE_MERGE_PERCENT: usize = 4;
pub const P_INMEM_MERGE_THRESHOLD: usize = 5;
pub const P_REDUCE_INPUT_BUFFER: usize = 6;
pub const P_REDUCE_TASKS: usize = 7;
/// v1-only tail.
pub const P_SORT_RECORD_PERCENT: usize = 8;
pub const P_COMPRESS_MAP_OUTPUT: usize = 9;
pub const P_OUTPUT_COMPRESS: usize = 10;
/// v2-only tail.
pub const P_SLOWSTART: usize = 8;
pub const P_JVM_NUMTASKS: usize = 9;
pub const P_JOB_MAPS: usize = 10;

/// Number of tuned parameters (both versions).
pub const N_PARAMS: usize = 11;

fn common_params() -> Vec<ParamSpec> {
    vec![
        ParamSpec::new(
            "io.sort.mb",
            ParamKind::Int,
            50.0,
            2000.0,
            100.0,
            "map-side circular sort buffer size (MB)",
        ),
        ParamSpec::new(
            "io.sort.spill.percent",
            ParamKind::Real,
            0.05,
            0.95,
            0.08,
            "buffer fill fraction that triggers a spill (paper Table 1 default)",
        ),
        ParamSpec::new(
            "io.sort.factor",
            ParamKind::Int,
            5.0,
            500.0,
            10.0,
            "number of streams merged at once during sorts",
        ),
        ParamSpec::new(
            "shuffle.input.buffer.percent",
            ParamKind::Real,
            0.1,
            0.95,
            0.7,
            "fraction of reducer heap for holding fetched map outputs",
        ),
        ParamSpec::new(
            "shuffle.merge.percent",
            ParamKind::Real,
            0.1,
            0.95,
            0.66,
            "shuffle-buffer fill fraction that triggers in-memory merge",
        ),
        ParamSpec::new(
            "inmem.merge.threshold",
            ParamKind::Int,
            10.0,
            10000.0,
            1000.0,
            "number of in-memory map outputs that triggers merge",
        ),
        ParamSpec::new(
            "reduce.input.buffer.percent",
            ParamKind::Real,
            0.0,
            0.8,
            0.0,
            "fraction of heap to retain map outputs during reduce",
        ),
        ParamSpec::new(
            "mapred.reduce.tasks",
            ParamKind::Int,
            1.0,
            100.0,
            1.0,
            "number of reduce tasks for the job",
        ),
    ]
}

fn v1_params() -> Vec<ParamSpec> {
    let mut p = common_params();
    p.push(ParamSpec::new(
        "io.sort.record.percent",
        ParamKind::Real,
        0.01,
        0.5,
        0.05,
        "fraction of sort buffer reserved for record metadata (v1)",
    ));
    p.push(ParamSpec::new(
        "mapred.compress.map.output",
        ParamKind::Bool,
        0.0,
        1.0,
        0.0,
        "compress intermediate map output",
    ));
    p.push(ParamSpec::new(
        "mapred.output.compress",
        ParamKind::Bool,
        0.0,
        1.0,
        0.0,
        "compress final job output",
    ));
    p
}

fn v2_params() -> Vec<ParamSpec> {
    let mut p = common_params();
    p.push(ParamSpec::new(
        "reduce.slowstart.completedmaps",
        ParamKind::Real,
        0.0,
        1.0,
        0.05,
        "map-completion fraction before reducers may start (v2)",
    ));
    p.push(ParamSpec::new(
        "mapreduce.job.jvm.numtasks",
        ParamKind::Int,
        1.0,
        30.0,
        1.0,
        "tasks per JVM before it is recycled (v2)",
    ));
    p.push(ParamSpec::new(
        "mapreduce.job.maps",
        ParamKind::Int,
        2.0,
        50.0,
        2.0,
        "hint for the number of map tasks (v2)",
    ));
    p
}

/// OS-layer extension parameters (paper §7 future work; appended after the
/// 11 Hadoop knobs when [`ParameterSpace::extended`] is used).
fn os_params() -> Vec<ParamSpec> {
    vec![
        ParamSpec::new(
            "os.readahead.kb",
            ParamKind::Int,
            128.0,
            8192.0,
            128.0,
            "block-device readahead (blockdev --setra), KB",
        ),
        ParamSpec::new(
            "os.net.rmem.kb",
            ParamKind::Int,
            64.0,
            16384.0,
            208.0,
            "TCP receive buffer ceiling (net.core.rmem_max), KB",
        ),
        ParamSpec::new(
            "os.dirty.ratio",
            ParamKind::Real,
            0.05,
            0.9,
            0.2,
            "writeback threshold (vm.dirty_ratio analogue)",
        ),
    ]
}

/// Number of OS-extension parameters.
pub const N_OS_PARAMS: usize = 3;

/// A full tunable space: the ordered parameter list for one Hadoop version,
/// optionally extended with the OS layer (paper §7's holistic tuning).
#[derive(Clone, Debug)]
pub struct ParameterSpace {
    pub version: HadoopVersion,
    /// True when the 3 OS-layer knobs are appended (dim 14 instead of 11).
    pub extended: bool,
    params: Vec<ParamSpec>,
}

impl ParameterSpace {
    pub fn for_version(version: HadoopVersion) -> Self {
        let params = match version {
            HadoopVersion::V1 => v1_params(),
            HadoopVersion::V2 => v2_params(),
        };
        debug_assert_eq!(params.len(), N_PARAMS);
        ParameterSpace { version, extended: false, params }
    }

    /// The holistic space: Hadoop + OS layers (14 parameters).
    pub fn extended(version: HadoopVersion) -> Self {
        let mut s = Self::for_version(version);
        s.params.extend(os_params());
        s.extended = true;
        s
    }

    pub fn v1() -> Self {
        Self::for_version(HadoopVersion::V1)
    }

    pub fn v2() -> Self {
        Self::for_version(HadoopVersion::V2)
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    pub fn params(&self) -> &[ParamSpec] {
        &self.params
    }

    pub fn spec(&self, i: usize) -> &ParamSpec {
        &self.params[i]
    }

    /// The search-space view after dimension pruning (Tuneful §3): keep
    /// only the parameters where `keep[i]` is true, in order. The result
    /// drives a tuner's *proposals* (defaults, perturbations, projection)
    /// over the free coordinates; it is NOT materializable — expanding a
    /// reduced θ back to the full space (frozen coordinates pinned to
    /// defaults) is [`FrozenObjective`]'s job before any simulation runs.
    /// At least one parameter must be kept.
    ///
    /// [`FrozenObjective`]: crate::tuner::objective::FrozenObjective
    pub fn subspace(&self, keep: &[bool]) -> ParameterSpace {
        assert_eq!(keep.len(), self.dim(), "keep-mask dimension mismatch");
        let params: Vec<ParamSpec> = self
            .params
            .iter()
            .zip(keep)
            .filter(|(_, &k)| k)
            .map(|(p, _)| p.clone())
            .collect();
        assert!(!params.is_empty(), "cannot prune every parameter");
        ParameterSpace { version: self.version, extended: self.extended, params }
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.params.iter().map(|p| p.name).collect()
    }

    /// θ_A for Hadoop's default configuration — SPSA's starting point
    /// (paper §6.5: "we use the default configuration as the initial point").
    pub fn default_theta(&self) -> Vec<f64> {
        self.params.iter().map(|p| p.default_algo()).collect()
    }

    /// μ(θ_A): materialize an algorithm-space point into Hadoop values.
    pub fn to_hadoop_values(&self, theta: &[f64]) -> Vec<ParamValue> {
        assert_eq!(theta.len(), self.dim(), "theta dimension mismatch");
        self.params
            .iter()
            .zip(theta)
            .map(|(p, &t)| p.to_hadoop(t))
            .collect()
    }

    /// μ(θ_A) into the typed config consumed by the simulator. For the
    /// extended space the tail values populate [`crate::config::hadoop::OsTuning`].
    pub fn materialize(&self, theta: &[f64]) -> HadoopConfig {
        let vals = self.to_hadoop_values(theta);
        let mut cfg = HadoopConfig::from_values(self.version, &vals[..N_PARAMS]);
        if self.extended {
            cfg.os.readahead_kb = vals[N_PARAMS].as_i64().max(128) as u64;
            cfg.os.net_rmem_kb = vals[N_PARAMS + 1].as_i64().max(64) as u64;
            cfg.os.dirty_ratio = vals[N_PARAMS + 2].as_f64();
        }
        cfg
    }

    /// The default Hadoop configuration.
    pub fn default_config(&self) -> HadoopConfig {
        self.materialize(&self.default_theta())
    }

    /// Paper §5.2 perturbation: δΔ with δΔ(i) = ±1/(max−min), p = ½ each —
    /// the magnitude guarantees integer parameters move by ≥ 1.
    pub fn sample_perturbation(&self, rng: &mut Rng) -> Vec<f64> {
        self.params
            .iter()
            .map(|p| {
                let scale = 1.0 / p.width().max(1.0);
                rng.rademacher() * scale
            })
            .collect()
    }

    /// Rademacher signs only (Δ without the δ scaling); used where the
    /// gradient estimator divides by δΔ(i) explicitly.
    pub fn sample_signs(&self, rng: &mut Rng) -> Vec<f64> {
        self.params.iter().map(|_| rng.rademacher()).collect()
    }

    /// Per-coordinate δ scale 1/(max−min).
    pub fn delta_scales(&self) -> Vec<f64> {
        self.params.iter().map(|p| 1.0 / p.width().max(1.0)).collect()
    }

    /// Projection Γ: clip every coordinate into [0,1] (paper Algorithm 1).
    pub fn project(&self, theta: &mut [f64]) {
        for t in theta.iter_mut() {
            *t = t.clamp(0.0, 1.0);
        }
    }

    /// Uniform random point in the space (baselines: random search / RRS).
    pub fn sample_uniform(&self, rng: &mut Rng) -> Vec<f64> {
        (0..self.dim()).map(|_| rng.f64()).collect()
    }

    /// The feature-vector layout consumed by the AOT cost-model artifact:
    /// the 11 Hadoop-space values, fixed order, booleans encoded 0/1. The
    /// OS-extension tail is intentionally dropped — the what-if model
    /// cannot see below the framework boundary (paper §7).
    pub fn to_feature_row(&self, theta: &[f64]) -> Vec<f32> {
        self.to_hadoop_values(theta)
            .iter()
            .take(N_PARAMS)
            .map(|v| v.as_f64() as f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subspace_keeps_order_defaults_and_metadata() {
        let full = ParameterSpace::v1();
        let mut keep = vec![false; full.dim()];
        keep[1] = true;
        keep[4] = true;
        let sub = full.subspace(&keep);
        assert_eq!(sub.dim(), 2);
        assert_eq!(sub.version, full.version);
        assert_eq!(sub.spec(0).name, full.spec(1).name);
        assert_eq!(sub.spec(1).name, full.spec(4).name);
        assert_eq!(
            sub.default_theta(),
            vec![full.default_theta()[1], full.default_theta()[4]]
        );
    }

    #[test]
    #[should_panic(expected = "cannot prune every parameter")]
    fn subspace_rejects_empty_mask() {
        let full = ParameterSpace::v1();
        let keep = vec![false; full.dim()];
        let _ = full.subspace(&keep);
    }

    #[test]
    fn both_spaces_have_11_params() {
        assert_eq!(ParameterSpace::v1().dim(), 11);
        assert_eq!(ParameterSpace::v2().dim(), 11);
    }

    #[test]
    fn v1_v2_share_first_eight() {
        let a = ParameterSpace::v1();
        let b = ParameterSpace::v2();
        for i in 0..8 {
            assert_eq!(a.spec(i).name, b.spec(i).name);
        }
        assert_ne!(a.spec(8).name, b.spec(8).name);
    }

    #[test]
    fn default_theta_materializes_to_defaults() {
        for space in [ParameterSpace::v1(), ParameterSpace::v2()] {
            let vals = space.to_hadoop_values(&space.default_theta());
            for (v, p) in vals.iter().zip(space.params()) {
                match p.kind {
                    ParamKind::Int => assert_eq!(v.as_i64(), p.default as i64, "{}", p.name),
                    ParamKind::Real => {
                        assert!((v.as_f64() - p.default).abs() < 1e-9, "{}", p.name)
                    }
                    ParamKind::Bool => assert_eq!(v.as_bool(), p.default >= 0.5, "{}", p.name),
                }
            }
        }
    }

    #[test]
    fn perturbation_moves_integer_params() {
        // Paper §5.2: the ±1/(max−min) magnitude must change integer params
        // by at least 1 when applied from a mid-range point.
        let space = ParameterSpace::v1();
        let mut rng = Rng::seeded(1);
        let theta: Vec<f64> = vec![0.5; space.dim()];
        let delta = space.sample_perturbation(&mut rng);
        let base = space.to_hadoop_values(&theta);
        let pert: Vec<f64> = theta.iter().zip(&delta).map(|(t, d)| t + d).collect();
        let moved = space.to_hadoop_values(&pert);
        for (i, p) in space.params().iter().enumerate() {
            if p.kind == ParamKind::Int {
                assert_ne!(
                    base[i].as_i64(),
                    moved[i].as_i64(),
                    "integer param {} did not move",
                    p.name
                );
            }
        }
    }

    #[test]
    fn projection_clips() {
        let space = ParameterSpace::v1();
        let mut theta = vec![-0.2, 1.4, 0.5, 0.0, 1.0, 2.0, -1.0, 0.3, 0.9, 0.1, 0.7];
        space.project(&mut theta);
        assert!(theta.iter().all(|t| (0.0..=1.0).contains(t)));
        assert_eq!(theta[2], 0.5);
    }

    #[test]
    fn default_config_matches_paper_defaults() {
        let c = ParameterSpace::v1().default_config();
        assert_eq!(c.io_sort_mb, 100);
        assert!((c.spill_percent - 0.08).abs() < 1e-9);
        assert_eq!(c.sort_factor, 10);
        assert_eq!(c.reduce_tasks, 1);
        assert!(!c.compress_map_output);
    }

    #[test]
    fn feature_row_has_dim_entries() {
        let space = ParameterSpace::v2();
        let row = space.to_feature_row(&space.default_theta());
        assert_eq!(row.len(), space.dim());
    }
}
