//! Small k-means (k-means++ seeding, Lloyd iterations) for PPABS's job
//! signature clustering (paper §3: "the jobs are clustered (using variants
//! of k-means) according to their respective signatures").

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct KmeansResult {
    pub centroids: Vec<Vec<f64>>,
    pub assignment: Vec<usize>,
    pub inertia: f64,
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Cluster `points` into `k` groups. Deterministic per seed.
pub fn kmeans(points: &[Vec<f64>], k: usize, iters: u64, seed: u64) -> KmeansResult {
    assert!(!points.is_empty());
    let k = k.min(points.len()).max(1);
    let dim = points[0].len();
    let mut rng = Rng::seeded(seed);

    // k-means++ seeding
    let mut centroids: Vec<Vec<f64>> = Vec::with_capacity(k);
    centroids.push(points[rng.below(points.len() as u64) as usize].clone());
    while centroids.len() < k {
        let d2: Vec<f64> = points
            .iter()
            .map(|p| {
                centroids
                    .iter()
                    .map(|c| dist2(p, c))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let total: f64 = d2.iter().sum();
        if total <= 0.0 {
            centroids.push(points[rng.below(points.len() as u64) as usize].clone());
            continue;
        }
        let mut pick = rng.f64() * total;
        let mut chosen = 0;
        for (i, d) in d2.iter().enumerate() {
            pick -= d;
            if pick <= 0.0 {
                chosen = i;
                break;
            }
        }
        centroids.push(points[chosen].clone());
    }

    // Lloyd iterations
    let mut assignment = vec![0usize; points.len()];
    for _ in 0..iters {
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let best = (0..centroids.len())
                .min_by(|&a, &b| dist2(p, &centroids[a]).total_cmp(&dist2(p, &centroids[b])))
                .unwrap_or(0);
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        // recompute centroids
        let mut sums = vec![vec![0.0; dim]; centroids.len()];
        let mut counts = vec![0usize; centroids.len()];
        for (p, &a) in points.iter().zip(&assignment) {
            counts[a] += 1;
            for (s, x) in sums[a].iter_mut().zip(p) {
                *s += x;
            }
        }
        for (c, (s, n)) in centroids.iter_mut().zip(sums.iter().zip(&counts)) {
            if *n > 0 {
                *c = s.iter().map(|x| x / *n as f64).collect();
            }
        }
        if !changed {
            break;
        }
    }

    let inertia = points
        .iter()
        .zip(&assignment)
        .map(|(p, &a)| dist2(p, &centroids[a]))
        .sum();
    KmeansResult { centroids, assignment, inertia }
}

/// Index of the centroid nearest to `point`.
pub fn nearest(centroids: &[Vec<f64>], point: &[f64]) -> usize {
    (0..centroids.len())
        .min_by(|&a, &b| dist2(point, &centroids[a]).total_cmp(&dist2(point, &centroids[b])))
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs() -> Vec<Vec<f64>> {
        let mut pts = Vec::new();
        let mut rng = Rng::seeded(3);
        for c in [[0.1, 0.1], [0.9, 0.9], [0.1, 0.9]] {
            for _ in 0..20 {
                pts.push(vec![
                    c[0] + rng.range_f64(-0.05, 0.05),
                    c[1] + rng.range_f64(-0.05, 0.05),
                ]);
            }
        }
        pts
    }

    #[test]
    fn separates_blobs() {
        let pts = blobs();
        let res = kmeans(&pts, 3, 50, 1);
        assert_eq!(res.centroids.len(), 3);
        // points within a blob share an assignment
        for blob in 0..3 {
            let first = res.assignment[blob * 20];
            for i in 0..20 {
                assert_eq!(res.assignment[blob * 20 + i], first, "blob {blob}");
            }
        }
        assert!(res.inertia < 1.0);
    }

    #[test]
    fn nearest_assigns_to_own_centroid() {
        let pts = blobs();
        let res = kmeans(&pts, 3, 50, 2);
        for (p, &a) in pts.iter().zip(&res.assignment) {
            assert_eq!(nearest(&res.centroids, p), a);
        }
    }

    #[test]
    fn k_clamped_to_points() {
        let pts = vec![vec![0.0, 0.0], vec![1.0, 1.0]];
        let res = kmeans(&pts, 10, 10, 1);
        assert_eq!(res.centroids.len(), 2);
    }

    #[test]
    fn deterministic_per_seed() {
        let pts = blobs();
        let a = kmeans(&pts, 3, 50, 7);
        let b = kmeans(&pts, 3, 50, 7);
        assert_eq!(a.assignment, b.assignment);
    }
}
