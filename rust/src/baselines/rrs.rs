//! Recursive Random Search — the global optimizer Starfish's cost-based
//! optimizer runs over its what-if model (paper §3: "recursive random
//! search (RSS) for tuning the parameters").
//!
//! Explore: sample the full space uniformly, keep the best point.
//! Exploit: shrink a box around the incumbent and re-sample inside it;
//! re-center on improvement, shrink on stagnation; restart exploration
//! when the box gets tiny.

use crate::util::rng::Rng;

use super::evaluator::CostEvaluator;

#[derive(Clone, Debug)]
pub struct RrsConfig {
    /// Total model-evaluation budget.
    pub budget: u64,
    /// Samples per explore round.
    pub explore_samples: u64,
    /// Samples per exploit round.
    pub exploit_samples: u64,
    /// Box shrink factor on stagnation.
    pub shrink: f64,
    /// Restart exploration when the box radius falls below this.
    pub min_radius: f64,
    pub seed: u64,
}

impl Default for RrsConfig {
    fn default() -> Self {
        RrsConfig {
            budget: 400,
            explore_samples: 60,
            exploit_samples: 20,
            shrink: 0.55,
            min_radius: 0.01,
            seed: 11,
        }
    }
}

/// Outcome: best point found and its model cost.
#[derive(Clone, Debug)]
pub struct RrsResult {
    pub best_theta: Vec<f64>,
    pub best_cost: f64,
    pub evals: u64,
}

pub fn rrs(evaluator: &mut dyn CostEvaluator, cfg: &RrsConfig) -> RrsResult {
    let n = evaluator.dim();
    let mut rng = Rng::seeded(cfg.seed);
    let mut best_theta = vec![0.5; n];
    let mut best_cost = f64::INFINITY;
    let mut used = 0u64;

    while used < cfg.budget {
        // ---- explore ---------------------------------------------------
        let k = cfg.explore_samples.min(cfg.budget - used);
        let pts: Vec<Vec<f64>> = (0..k).map(|_| (0..n).map(|_| rng.f64()).collect()).collect();
        // lint:allow(unmetered-eval): CostEvaluator is the analytic what-if model — model-side evals, no live observation spent
        let costs = evaluator.eval_batch(&pts);
        used += k;
        let mut center = best_theta.clone();
        let mut center_cost = best_cost;
        for (p, c) in pts.iter().zip(&costs) {
            if *c < center_cost {
                center_cost = *c;
                center = p.clone();
            }
        }
        if center_cost < best_cost {
            best_cost = center_cost;
            best_theta = center.clone();
        }

        // ---- exploit ---------------------------------------------------
        let mut radius = 0.25;
        while radius > cfg.min_radius && used < cfg.budget {
            let k = cfg.exploit_samples.min(cfg.budget - used);
            let pts: Vec<Vec<f64>> = (0..k)
                .map(|_| {
                    center
                        .iter()
                        .map(|&c| (c + rng.range_f64(-radius, radius)).clamp(0.0, 1.0))
                        .collect()
                })
                .collect();
            // lint:allow(unmetered-eval): CostEvaluator is the analytic what-if model — model-side evals, no live observation spent
            let costs = evaluator.eval_batch(&pts);
            used += k;
            let (mut improved, mut round_best, mut round_theta) =
                (false, center_cost, center.clone());
            for (p, c) in pts.iter().zip(&costs) {
                if *c < round_best {
                    round_best = *c;
                    round_theta = p.clone();
                    improved = true;
                }
            }
            if improved {
                center = round_theta;
                center_cost = round_best;
            } else {
                radius *= cfg.shrink;
            }
        }
        if center_cost < best_cost {
            best_cost = center_cost;
            best_theta = center;
        }
    }

    RrsResult { best_theta, best_cost, evals: used }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic convex test surface.
    struct Sphere {
        target: Vec<f64>,
        evals: u64,
    }

    impl CostEvaluator for Sphere {
        fn dim(&self) -> usize {
            self.target.len()
        }

        fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
            self.evals += thetas.len() as u64;
            thetas
                .iter()
                .map(|t| {
                    t.iter().zip(&self.target).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
                })
                .collect()
        }

        fn model_evals(&self) -> u64 {
            self.evals
        }
    }

    #[test]
    fn finds_sphere_minimum() {
        let mut s = Sphere { target: vec![0.3, 0.8, 0.1, 0.6, 0.5], evals: 0 };
        let res = rrs(&mut s, &RrsConfig::default());
        for (a, b) in res.best_theta.iter().zip(&s.target.clone()) {
            assert!((a - b).abs() < 0.08, "{:?}", res.best_theta);
        }
        assert!(res.best_cost < 0.01);
    }

    #[test]
    fn respects_budget() {
        let mut s = Sphere { target: vec![0.5; 3], evals: 0 };
        let cfg = RrsConfig { budget: 500, ..Default::default() };
        let res = rrs(&mut s, &cfg);
        assert!(res.evals <= 500);
        assert_eq!(s.model_evals(), res.evals);
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            let mut s = Sphere { target: vec![0.4, 0.7], evals: 0 };
            rrs(&mut s, &RrsConfig { seed, budget: 300, ..Default::default() }).best_theta
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }
}
