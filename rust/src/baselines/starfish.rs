//! Starfish-style baseline (Herodotou et al., CIDR'11), as described in the
//! paper's §3: *profile → what-if engine → cost-based optimizer (RRS)*.
//!
//! * **Profiler** — runs the job once with the default configuration on the
//!   live (simulated) cluster, instrumented; this is the expensive
//!   profiling pass the paper's §6.8(6) measures in hours. The run goes
//!   through the [`EvalBroker`] like every other live observation, so it is
//!   metered against the same budget the other tuners spend, and its
//!   observed execution time is charged as `profiling_overhead_s`.
//! * **What-if engine** — the analytic cost model (rust or the AOT
//!   JAX/Pallas artifact through PJRT), supplied by the caller — typically
//!   built from a *noisy single-shot profile* of the workload.
//! * **CBO** — recursive random search over the what-if surface.
//!
//! The deliberate model-vs-system gap means Starfish's chosen configuration
//! is good but not optimal on the real system — the structural reason SPSA
//! wins in Fig. 8 (see DESIGN.md §1).

use crate::config::ParameterSpace;
use crate::tuner::broker::EvalBroker;

use super::evaluator::CostEvaluator;
use super::rrs::{rrs, RrsConfig, RrsResult};

/// Result of a Starfish-style tuning pass.
#[derive(Clone, Debug)]
pub struct StarfishResult {
    pub best_theta: Vec<f64>,
    /// Model-predicted cost at the chosen configuration.
    pub model_cost: f64,
    /// Profiling cost: the objective score of the one default-config run
    /// (0 when the broker's budget could not afford even the profile).
    /// Under the benign `ExecTime` objective this is the run's simulated
    /// seconds; under a fault scenario a *failed* profile run scores its
    /// extrapolated full-job estimate × the failed-job penalty — the
    /// broker's uniform currency deliberately surfaces how expensive
    /// profiling on a flaky cluster is, rather than the raw abort time.
    pub profiling_overhead_s: f64,
    /// What-if model evaluations consumed by the CBO.
    pub model_evals: u64,
}

/// Run the Starfish pipeline: one metered profiling run at the default
/// configuration, then RRS over the what-if surface.
pub fn starfish_tune(
    space: &ParameterSpace,
    broker: &mut EvalBroker,
    evaluator: &mut dyn CostEvaluator,
    rrs_cfg: &RrsConfig,
) -> StarfishResult {
    // 1. profile: one instrumented run at the default configuration (a
    //    live observation — under ExecTime its value IS the job's seconds)
    let profiling_overhead_s = broker.try_eval(&space.default_theta()).unwrap_or(0.0);

    // 2+3. what-if + CBO
    let RrsResult { best_theta, best_cost, evals } = rrs(evaluator, rrs_cfg);

    StarfishResult {
        best_theta,
        model_cost: best_cost,
        profiling_overhead_s,
        model_evals: evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::evaluator::RustWhatIf;
    use crate::cluster::ClusterSpec;
    use crate::config::HadoopVersion;
    use crate::sim::{simulate, SimOptions};
    use crate::tuner::broker::Budget;
    use crate::tuner::SimObjective;
    use crate::util::rng::Rng;
    use crate::whatif::ClusterFeatures;
    use crate::workloads::{Benchmark, WorkloadProfile};

    fn setup() -> (ParameterSpace, ClusterSpec, WorkloadProfile, RustWhatIf) {
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut rng = Rng::seeded(2);
        let w = Benchmark::Terasort.profile_scaled(100_000, 8 << 30, &mut rng);
        let eval = RustWhatIf::new(
            space.clone(),
            w.clone(),
            ClusterFeatures::from_spec(&cluster, HadoopVersion::V1),
        );
        (space, cluster, w, eval)
    }

    #[test]
    fn starfish_beats_default_on_live_system() {
        let (space, cluster, w, mut eval) = setup();
        let mut obj = SimObjective::new(space.clone(), cluster.clone(), w.clone(), 3);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(90));
        let res = starfish_tune(
            &space,
            &mut broker,
            &mut eval,
            &RrsConfig { seed: 3, ..Default::default() },
        );
        assert_eq!(broker.evals_used(), 1, "starfish profiles exactly once");
        let opts = SimOptions { seed: 77, noise: false, ..Default::default() };
        let f_default =
            simulate(&cluster, &space.default_config(), &w, &opts).exec_time_s;
        let f_starfish =
            simulate(&cluster, &space.materialize(&res.best_theta), &w, &opts).exec_time_s;
        assert!(
            f_starfish < f_default * 0.7,
            "starfish {f_starfish} default {f_default}"
        );
        assert!(res.profiling_overhead_s > 0.0);
        assert!(res.model_evals > 100);
    }

    #[test]
    fn exhausted_broker_still_returns_a_model_optimum() {
        // Budget 0: the profile is skipped (overhead 0) but the CBO still
        // searches the model — graceful partial result.
        let (space, _cluster, _w, mut eval) = setup();
        let mut obj = crate::tuner::QuadraticObjective::new(vec![0.5; 11], 0.0, 1);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(0));
        let res = starfish_tune(&space, &mut broker, &mut eval, &RrsConfig::default());
        assert_eq!(res.profiling_overhead_s, 0.0);
        assert_eq!(broker.evals_used(), 0);
        assert!(res.model_evals > 0);
        assert_eq!(res.best_theta.len(), space.dim());
    }
}
