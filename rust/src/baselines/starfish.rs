//! Starfish-style baseline (Herodotou et al., CIDR'11), as described in the
//! paper's §3: *profile → what-if engine → cost-based optimizer (RRS)*.
//!
//! * **Profiler** — runs the job once with the default configuration on the
//!   live (simulated) cluster, instrumented; this is the expensive
//!   profiling pass the paper's §6.8(6) measures in hours. We charge its
//!   wall-clock as `profiling_overhead_s`.
//! * **What-if engine** — the analytic cost model (rust or the AOT
//!   JAX/Pallas artifact through PJRT).
//! * **CBO** — recursive random search over the what-if surface.
//!
//! The deliberate model-vs-system gap means Starfish's chosen configuration
//! is good but not optimal on the real system — the structural reason SPSA
//! wins in Fig. 8 (see DESIGN.md §1).

use crate::cluster::ClusterSpec;
use crate::config::ParameterSpace;
use crate::sim::{simulate, SimOptions};
use crate::workloads::WorkloadProfile;

use super::evaluator::CostEvaluator;
use super::rrs::{rrs, RrsConfig, RrsResult};

/// Result of a Starfish-style tuning pass.
#[derive(Clone, Debug)]
pub struct StarfishResult {
    pub best_theta: Vec<f64>,
    /// Model-predicted cost at the chosen configuration.
    pub model_cost: f64,
    /// Simulated seconds spent profiling (one default-config run).
    pub profiling_overhead_s: f64,
    /// What-if model evaluations consumed by the CBO.
    pub model_evals: u64,
}

/// Run the Starfish pipeline. `evaluator` supplies the what-if engine
/// (rust model or PJRT artifact); the profiler runs on the DES.
pub fn starfish_tune(
    space: &ParameterSpace,
    cluster: &ClusterSpec,
    workload: &WorkloadProfile,
    evaluator: &mut dyn CostEvaluator,
    rrs_cfg: &RrsConfig,
    seed: u64,
) -> StarfishResult {
    // 1. profile: one instrumented run at the default configuration
    let default_cfg = space.default_config();
    let profile_run = simulate(
        cluster,
        &default_cfg,
        workload,
        &SimOptions { seed, noise: true, ..Default::default() },
    );

    // 2+3. what-if + CBO
    let RrsResult { best_theta, best_cost, evals } = rrs(evaluator, rrs_cfg);

    StarfishResult {
        best_theta,
        model_cost: best_cost,
        profiling_overhead_s: profile_run.exec_time_s,
        model_evals: evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::evaluator::RustWhatIf;
    use crate::config::HadoopVersion;
    use crate::util::rng::Rng;
    use crate::whatif::ClusterFeatures;
    use crate::workloads::Benchmark;

    fn setup() -> (ParameterSpace, ClusterSpec, WorkloadProfile, RustWhatIf) {
        let space = ParameterSpace::v1();
        let cluster = ClusterSpec::paper_cluster();
        let mut rng = Rng::seeded(2);
        let w = Benchmark::Terasort.profile_scaled(100_000, 8 << 30, &mut rng);
        let eval = RustWhatIf::new(
            space.clone(),
            w.clone(),
            ClusterFeatures::from_spec(&cluster, HadoopVersion::V1),
        );
        (space, cluster, w, eval)
    }

    #[test]
    fn starfish_beats_default_on_live_system() {
        let (space, cluster, w, mut eval) = setup();
        let res = starfish_tune(&space, &cluster, &w, &mut eval, &RrsConfig::default(), 3);
        let opts = SimOptions { seed: 77, noise: false, ..Default::default() };
        let f_default =
            simulate(&cluster, &space.default_config(), &w, &opts).exec_time_s;
        let f_starfish =
            simulate(&cluster, &space.materialize(&res.best_theta), &w, &opts).exec_time_s;
        assert!(
            f_starfish < f_default * 0.7,
            "starfish {f_starfish} default {f_default}"
        );
        assert!(res.profiling_overhead_s > 0.0);
        assert!(res.model_evals > 100);
    }
}
