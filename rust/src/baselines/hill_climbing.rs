//! MROnline-style hill climbing (Li et al., HPDC'14; paper §3): an online
//! tuner that moves one parameter at a time on the *live system*, keeping a
//! change only if the observed execution time improves.
//!
//! Contrast with SPSA: hill climbing needs O(n) observations to probe all
//! coordinates once, and its axis-aligned moves cannot follow
//! cross-parameter interactions — the paper's Table 2 "Parameter
//! Dependency" column.
//!
//! The observation budget lives in the [`EvalBroker`] (the one metered
//! evaluation path all live-system tuners share): `try_eval` returning
//! `None` is the graceful stop, and with [`CachePolicy::Quantized`]
//! revisited points — common when the shrinking step retraces its path —
//! cost nothing.
//!
//! [`CachePolicy::Quantized`]: crate::tuner::broker::CachePolicy::Quantized

use crate::tuner::broker::EvalBroker;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct HillClimbConfig {
    /// Step size per coordinate move (algorithm space).
    pub step: f64,
    /// Step shrink factor after a full unproductive sweep.
    pub shrink: f64,
    pub seed: u64,
}

impl Default for HillClimbConfig {
    fn default() -> Self {
        HillClimbConfig { step: 0.15, shrink: 0.6, seed: 17 }
    }
}

#[derive(Clone, Debug)]
pub struct HillClimbResult {
    pub best_theta: Vec<f64>,
    pub best_f: f64,
    /// Live observations consumed (cache hits are free).
    pub observations: u64,
}

pub fn hill_climb(
    broker: &mut EvalBroker,
    theta0: Vec<f64>,
    cfg: &HillClimbConfig,
) -> HillClimbResult {
    let n = broker.dim();
    let start_evals = broker.evals_used();
    let mut rng = Rng::seeded(cfg.seed);
    let mut theta = theta0;
    let Some(mut f_cur) = broker.try_eval(&theta) else {
        return HillClimbResult { best_theta: theta, best_f: f64::INFINITY, observations: 0 };
    };
    let mut step = cfg.step;

    'outer: loop {
        let mut improved_any = false;
        // visit coordinates in random order each sweep
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for &i in &order {
            for dir in [1.0, -1.0] {
                let mut cand = theta.clone();
                cand[i] = (cand[i] + dir * step).clamp(0.0, 1.0);
                if cand[i] == theta[i] {
                    continue;
                }
                let Some(f) = broker.try_eval(&cand) else {
                    break 'outer; // budget exhausted: keep best-so-far
                };
                if f < f_cur {
                    theta = cand;
                    f_cur = f;
                    improved_any = true;
                    break; // keep direction for next sweep via random order
                }
            }
        }
        if !improved_any {
            step *= cfg.shrink;
            if step < 0.01 {
                break;
            }
        }
    }

    // delta, not lifetime total: the broker may have metered earlier
    // phases (profile runs, a prior tuner) before this climb started
    HillClimbResult {
        best_theta: theta,
        best_f: f_cur,
        observations: broker.evals_used() - start_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::broker::{Budget, CachePolicy, EvalBroker};
    use crate::tuner::{Objective, QuadraticObjective};

    #[test]
    fn climbs_smooth_surface() {
        let mut obj = QuadraticObjective::new(vec![0.8, 0.2, 0.5], 0.0, 1);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(200));
        let res = hill_climb(&mut broker, vec![0.5; 3], &HillClimbConfig::default());
        for (a, b) in res.best_theta.iter().zip(&[0.8, 0.2, 0.5]) {
            assert!((a - b).abs() < 0.15, "{:?}", res.best_theta);
        }
    }

    #[test]
    fn respects_broker_budget() {
        let mut obj = QuadraticObjective::new(vec![0.5; 5], 0.1, 2);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(30));
        let res = hill_climb(&mut broker, vec![0.1; 5], &HillClimbConfig::default());
        assert!(res.observations <= 30);
        assert_eq!(obj.evals(), res.observations);
    }

    #[test]
    fn cached_revisits_stretch_the_budget() {
        // With the memo cache on, the climber's retraced points are free:
        // it must reach a (possibly cached) stop without ever overdrawing.
        let mut obj = QuadraticObjective::new(vec![0.6, 0.4], 0.0, 3);
        let mut broker =
            EvalBroker::new(&mut obj, Budget::obs(60)).with_cache(CachePolicy::Quantized);
        let res = hill_climb(&mut broker, vec![0.0, 1.0], &HillClimbConfig::default());
        assert!(res.observations <= 60);
        assert!(
            broker.cache_hits() > 0,
            "shrinking-step descent should revisit quantized points"
        );
        assert!((res.best_theta[0] - 0.6).abs() < 0.15, "{:?}", res.best_theta);
    }
}
