//! MROnline-style hill climbing (Li et al., HPDC'14; paper §3): an online
//! tuner that moves one parameter at a time on the *live system*, keeping a
//! change only if the observed execution time improves.
//!
//! Contrast with SPSA: hill climbing needs O(n) observations to probe all
//! coordinates once, and its axis-aligned moves cannot follow
//! cross-parameter interactions — the paper's Table 2 "Parameter
//! Dependency" column.

use crate::tuner::Objective;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct HillClimbConfig {
    /// Live-system observation budget (comparable to SPSA's 2 × iters).
    pub budget: u64,
    /// Step size per coordinate move (algorithm space).
    pub step: f64,
    /// Step shrink factor after a full unproductive sweep.
    pub shrink: f64,
    pub seed: u64,
}

impl Default for HillClimbConfig {
    fn default() -> Self {
        HillClimbConfig { budget: 60, step: 0.15, shrink: 0.6, seed: 17 }
    }
}

#[derive(Clone, Debug)]
pub struct HillClimbResult {
    pub best_theta: Vec<f64>,
    pub best_f: f64,
    pub observations: u64,
}

pub fn hill_climb(
    objective: &mut dyn Objective,
    theta0: Vec<f64>,
    cfg: &HillClimbConfig,
) -> HillClimbResult {
    let n = objective.dim();
    let mut rng = Rng::seeded(cfg.seed);
    let mut theta = theta0;
    let mut f_cur = objective.eval(&theta);
    let mut used = 1u64;
    let mut step = cfg.step;

    'outer: loop {
        let mut improved_any = false;
        // visit coordinates in random order each sweep
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for &i in &order {
            for dir in [1.0, -1.0] {
                if used >= cfg.budget {
                    break 'outer;
                }
                let mut cand = theta.clone();
                cand[i] = (cand[i] + dir * step).clamp(0.0, 1.0);
                if cand[i] == theta[i] {
                    continue;
                }
                let f = objective.eval(&cand);
                used += 1;
                if f < f_cur {
                    theta = cand;
                    f_cur = f;
                    improved_any = true;
                    break; // keep direction for next sweep via random order
                }
            }
        }
        if !improved_any {
            step *= cfg.shrink;
            if step < 0.01 {
                break;
            }
        }
    }

    HillClimbResult { best_theta: theta, best_f: f_cur, observations: used }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::QuadraticObjective;

    #[test]
    fn climbs_smooth_surface() {
        let mut obj = QuadraticObjective::new(vec![0.8, 0.2, 0.5], 0.0, 1);
        let cfg = HillClimbConfig { budget: 200, ..Default::default() };
        let res = hill_climb(&mut obj, vec![0.5; 3], &cfg);
        for (a, b) in res.best_theta.iter().zip(&[0.8, 0.2, 0.5]) {
            assert!((a - b).abs() < 0.15, "{:?}", res.best_theta);
        }
    }

    #[test]
    fn respects_budget() {
        let mut obj = QuadraticObjective::new(vec![0.5; 5], 0.1, 2);
        let cfg = HillClimbConfig { budget: 30, ..Default::default() };
        let res = hill_climb(&mut obj, vec![0.1; 5], &cfg);
        assert!(res.observations <= 30);
        assert_eq!(obj.evals(), res.observations);
    }
}
