//! Cost-evaluator abstraction for the model-based baselines: Starfish's
//! what-if engine evaluates *candidate configurations against a model*, not
//! the live system. Implementations: the rust analytic model (here) and the
//! AOT-compiled JAX/Pallas artifact via PJRT (`crate::runtime`).

use crate::config::ParameterSpace;
use crate::tuner::Objective;
use crate::whatif::{cost_for_theta, ClusterFeatures};
use crate::workloads::WorkloadProfile;

/// Batched what-if evaluation of θ_A points (algorithm space, [0,1]^n).
/// Same batch shape as [`Objective::eval_batch`] — [`CostObjective`]
/// bridges the two, so RRS, PPABS, Starfish *and* surrogate-SPSA all
/// drive their models through one batching interface.
pub trait CostEvaluator {
    fn dim(&self) -> usize;
    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64>;
    /// Number of model evaluations so far (profiling-overhead accounting).
    fn model_evals(&self) -> u64;
}

/// Adapter presenting any what-if [`CostEvaluator`] as a tuner
/// [`Objective`]: surrogate-SPSA runs against the model through the same
/// `eval_batch` the CBO baselines use (replacing the ad-hoc wrapper the
/// campaign layer used to carry). Model observations are deterministic —
/// the "noise" SPSA sees is only the perturbation geometry.
pub struct CostObjective<'a> {
    inner: &'a mut dyn CostEvaluator,
}

impl<'a> CostObjective<'a> {
    pub fn new(inner: &'a mut dyn CostEvaluator) -> Self {
        CostObjective { inner }
    }
}

impl Objective for CostObjective<'_> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval(&mut self, theta: &[f64]) -> f64 {
        self.inner.eval_batch(&[theta.to_vec()])[0]
    }

    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        self.inner.eval_batch(thetas)
    }

    fn evals(&self) -> u64 {
        self.inner.model_evals()
    }
}

/// The rust analytic what-if model.
pub struct RustWhatIf {
    pub space: ParameterSpace,
    pub workload: WorkloadProfile,
    pub cluster: ClusterFeatures,
    evals: u64,
}

impl RustWhatIf {
    pub fn new(space: ParameterSpace, workload: WorkloadProfile, cluster: ClusterFeatures) -> Self {
        RustWhatIf { space, workload, cluster, evals: 0 }
    }
}

impl CostEvaluator for RustWhatIf {
    fn dim(&self) -> usize {
        self.space.dim()
    }

    fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        self.evals += thetas.len() as u64;
        thetas
            .iter()
            .map(|t| cost_for_theta(&self.space, t, &self.workload, &self.cluster))
            .collect()
    }

    fn model_evals(&self) -> u64 {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::HadoopVersion;
    use crate::util::rng::Rng;
    use crate::workloads::Benchmark;

    pub fn test_whatif() -> RustWhatIf {
        let mut rng = Rng::seeded(4);
        let w = Benchmark::Terasort.profile_scaled(100_000, 8 << 30, &mut rng);
        RustWhatIf::new(
            ParameterSpace::v1(),
            w,
            ClusterFeatures::from_spec(&ClusterSpec::paper_cluster(), HadoopVersion::V1),
        )
    }

    #[test]
    fn batch_eval_counts() {
        let mut e = test_whatif();
        let pts = vec![vec![0.5; 11], vec![0.2; 11]];
        let costs = e.eval_batch(&pts);
        assert_eq!(costs.len(), 2);
        assert!(costs.iter().all(|c| c.is_finite() && *c > 0.0));
        assert_eq!(e.model_evals(), 2);
    }

    #[test]
    fn cost_objective_bridges_to_tuner() {
        let mut e = test_whatif();
        let direct = e.eval_batch(&[vec![0.5; 11]])[0];
        let mut obj = CostObjective::new(&mut e);
        assert_eq!(obj.dim(), 11);
        let single = obj.eval(&[0.5; 11]);
        let batch = obj.eval_batch(&[vec![0.5; 11], vec![0.2; 11]]);
        // the analytic model is deterministic: all routes agree
        assert_eq!(single, direct);
        assert_eq!(batch[0], direct);
        assert_eq!(obj.evals(), 4);
    }
}
