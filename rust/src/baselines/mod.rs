//! Baseline tuners the paper compares against (§3, §6.6): Starfish-style
//! profile + what-if + RRS, PPABS-style signature clustering + simulated
//! annealing on a reduced space, MROnline-style hill climbing, and pure
//! random search as the ablation anchor.

pub mod annealing;
pub mod evaluator;
pub mod hill_climbing;
pub mod kmeans;
pub mod ppabs;
pub mod random_search;
pub mod rrs;
pub mod starfish;

pub use annealing::{simulated_annealing, SaConfig, SaResult};
pub use evaluator::{CostEvaluator, CostObjective, RustWhatIf};
pub use hill_climbing::{hill_climb, HillClimbConfig, HillClimbResult};
pub use kmeans::{kmeans, nearest, KmeansResult};
pub use ppabs::{training_corpus, Ppabs};
pub use random_search::{
    random_search, random_search_resumable, RandomSearchResult, RandomSearchState,
};
pub use rrs::{rrs, RrsConfig, RrsResult};
pub use starfish::{starfish_tune, StarfishResult};
