//! Simulated annealing over the configuration space — the optimizer PPABS
//! runs per job-cluster (paper §3: "the optimal parameter configuration for
//! every cluster is obtained through simulated annealing, albeit for a
//! reduced parameter search space").

use crate::util::rng::Rng;

use super::evaluator::CostEvaluator;

#[derive(Clone, Debug)]
pub struct SaConfig {
    pub iters: u64,
    /// Initial temperature (relative to the initial cost scale).
    pub t0: f64,
    /// Geometric cooling rate per iteration.
    pub cooling: f64,
    /// Proposal step (gaussian sigma per coordinate).
    pub step: f64,
    /// Mask of coordinates SA may move (PPABS's reduced space); `None`
    /// moves all.
    pub active: Option<Vec<bool>>,
    pub seed: u64,
}

impl Default for SaConfig {
    fn default() -> Self {
        SaConfig { iters: 1500, t0: 0.3, cooling: 0.995, step: 0.08, active: None, seed: 13 }
    }
}

#[derive(Clone, Debug)]
pub struct SaResult {
    pub best_theta: Vec<f64>,
    pub best_cost: f64,
    pub evals: u64,
}

pub fn simulated_annealing(
    evaluator: &mut dyn CostEvaluator,
    start: Vec<f64>,
    cfg: &SaConfig,
) -> SaResult {
    let n = evaluator.dim();
    assert_eq!(start.len(), n);
    let mut rng = Rng::seeded(cfg.seed);
    let mut current = start;
    // lint:allow(unmetered-eval): CostEvaluator is the analytic what-if model — model-side evals, no live observation spent
    let mut current_cost = evaluator.eval_batch(std::slice::from_ref(&current))[0];
    let scale = current_cost.abs().max(1e-9);
    let mut best = current.clone();
    let mut best_cost = current_cost;
    let mut temp = cfg.t0;
    let mut evals = 1u64;

    for _ in 0..cfg.iters {
        let candidate: Vec<f64> = current
            .iter()
            .enumerate()
            .map(|(i, &x)| {
                let movable = cfg.active.as_ref().map(|m| m[i]).unwrap_or(true);
                if movable {
                    (x + cfg.step * rng.gaussian()).clamp(0.0, 1.0)
                } else {
                    x
                }
            })
            .collect();
        // lint:allow(unmetered-eval): CostEvaluator is the analytic what-if model — model-side evals, no live observation spent
        let cost = evaluator.eval_batch(std::slice::from_ref(&candidate))[0];
        evals += 1;
        let delta = (cost - current_cost) / scale;
        if delta < 0.0 || rng.f64() < (-delta / temp.max(1e-12)).exp() {
            current = candidate;
            current_cost = cost;
            if cost < best_cost {
                best_cost = cost;
                best = current.clone();
            }
        }
        temp *= cfg.cooling;
    }

    SaResult { best_theta: best, best_cost, evals }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Sphere {
        target: Vec<f64>,
        evals: u64,
    }

    impl CostEvaluator for Sphere {
        fn dim(&self) -> usize {
            self.target.len()
        }

        fn eval_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
            self.evals += thetas.len() as u64;
            thetas
                .iter()
                .map(|t| {
                    1.0 + t.iter().zip(&self.target).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
                })
                .collect()
        }

        fn model_evals(&self) -> u64 {
            self.evals
        }
    }

    #[test]
    fn anneals_to_minimum() {
        let mut s = Sphere { target: vec![0.2, 0.9, 0.5], evals: 0 };
        let res = simulated_annealing(&mut s, vec![0.5; 3], &SaConfig::default());
        for (a, b) in res.best_theta.iter().zip(&[0.2, 0.9, 0.5]) {
            assert!((a - b).abs() < 0.1, "{:?}", res.best_theta);
        }
    }

    #[test]
    fn frozen_coordinates_do_not_move() {
        let mut s = Sphere { target: vec![0.9, 0.9], evals: 0 };
        let cfg = SaConfig { active: Some(vec![true, false]), ..Default::default() };
        let res = simulated_annealing(&mut s, vec![0.1, 0.1], &cfg);
        assert!((res.best_theta[1] - 0.1).abs() < 1e-12);
        assert!((res.best_theta[0] - 0.9).abs() < 0.1);
    }

    #[test]
    fn eval_accounting() {
        let mut s = Sphere { target: vec![0.5], evals: 0 };
        let cfg = SaConfig { iters: 100, ..Default::default() };
        let res = simulated_annealing(&mut s, vec![0.0], &cfg);
        assert_eq!(res.evals, 101);
        assert_eq!(s.model_evals(), 101);
    }
}
