//! Pure random search on the live system — the weakest sensible baseline
//! and the ablation anchor: any tuner must beat it at equal observation
//! budget.
//!
//! The budget lives in the [`EvalBroker`]: the search spends *exactly* the
//! broker's remaining observations and keeps the best point seen.
//! Candidates are pre-drawn and dispatched in chunks through
//! `try_eval_batch`, so the independent probes fan across the worker pool
//! while the per-observation seed stream stays identical to the one-by-one
//! loop (the broker dispatches uncached points in order).

use crate::tuner::broker::EvalBroker;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Candidates per dispatch round (bounds memory for huge budgets while
/// keeping whole worker waves busy).
const CHUNK: u64 = 64;

/// Observations spent when the broker itself is unlimited: random search
/// has no intrinsic stopping rule, so an explicit fallback keeps the loop
/// finite instead of simulating forever.
const UNLIMITED_FALLBACK_OBS: u64 = 256;

#[derive(Clone, Debug)]
pub struct RandomSearchResult {
    pub best_theta: Vec<f64>,
    pub best_f: f64,
    pub observations: u64,
}

/// Evaluate the starting point, then uniform random points until the
/// broker's budget is spent; keep the best. An unlimited broker gets the
/// [`UNLIMITED_FALLBACK_OBS`] cap — the search would otherwise never stop.
pub fn random_search(
    broker: &mut EvalBroker,
    theta0: Vec<f64>,
    seed: u64,
) -> RandomSearchResult {
    let n = broker.dim();
    let start_evals = broker.evals_used();
    let mut rng = Rng::seeded(seed);
    // the fallback cap applies only when NO axis bounds the run: a budget
    // with unlimited observations but finite batches or model time is the
    // wall-clock-frame comparison (64-probe waves until time runs out) and
    // must spend it, not stop at an arbitrary observation count
    let mut cap = if broker.budget().is_unlimited() {
        UNLIMITED_FALLBACK_OBS
    } else {
        u64::MAX
    };
    let mut best_theta = theta0;
    let Some(mut best_f) = broker.try_eval(&best_theta) else {
        return RandomSearchResult { best_theta, best_f: f64::INFINITY, observations: 0 };
    };
    cap = cap.saturating_sub(1);
    loop {
        let k = broker.remaining().min(CHUNK).min(cap);
        if k == 0 {
            break;
        }
        cap -= k;
        let cands: Vec<Vec<f64>> =
            (0..k).map(|_| (0..n).map(|_| rng.f64()).collect()).collect();
        let fs = broker.try_eval_batch(&cands);
        // k never exceeds remaining(), so the broker serves whole chunks
        debug_assert_eq!(fs.len() as u64, k);
        for (cand, &f) in cands.iter().zip(&fs) {
            if f < best_f {
                best_f = f;
                best_theta = cand.clone();
            }
        }
    }
    // delta, not lifetime total: a reused broker may carry earlier spend
    RandomSearchResult {
        best_theta,
        best_f,
        observations: broker.evals_used() - start_evals,
    }
}

/// Serializable state of a checkpointed random search: how many random
/// candidates have been drawn (the candidate stream is positional in the
/// seed's RNG, so resuming fast-forwards `drawn × dim` draws), the
/// remaining intrinsic cap, and the incumbent.
#[derive(Clone, Debug)]
pub struct RandomSearchState {
    /// Whether θ₀ has been evaluated yet (it is the first observation of
    /// a fresh run; a zero-budget first segment may checkpoint before it).
    pub theta0_done: bool,
    pub drawn: u64,
    /// Remaining intrinsic candidate cap (`u64::MAX` = none — the broker
    /// is the only limit).
    pub cap: u64,
    pub best_theta: Vec<f64>,
    pub best_f: f64,
}

impl RandomSearchState {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        // u64s as strings: Json numbers are f64 and lossy above 2^53
        j.set("theta0_done", Json::Bool(self.theta0_done))
            .set("drawn", Json::Str(self.drawn.to_string()))
            .set("cap", Json::Str(self.cap.to_string()))
            .set("best_theta", Json::from_f64_slice(&self.best_theta))
            .set("best_f", Json::Num(self.best_f));
        j
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let u = |k: &str| -> Result<u64, String> {
            j.get(k)
                .and_then(|x| x.as_str())
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| format!("missing/invalid {k}"))
        };
        Ok(RandomSearchState {
            theta0_done: j
                .get("theta0_done")
                .and_then(|x| x.as_bool())
                .ok_or("missing theta0_done")?,
            drawn: u("drawn")?,
            cap: u("cap")?,
            best_theta: j
                .get("best_theta")
                .and_then(|x| x.to_f64_vec())
                .ok_or("missing best_theta")?,
            best_f: j.get("best_f").and_then(|x| x.as_f64()).unwrap_or(f64::INFINITY),
        })
    }
}

/// Checkpointable [`random_search`]: run until the broker cannot afford a
/// whole chunk, returning the state to continue from (`None` = the
/// intrinsic cap is spent — finished for good).
///
/// Unlike the plain search, this variant only dispatches **whole chunks**
/// (`CHUNK.min(cap)` candidates): a budget boundary mid-chunk stops the
/// segment *before* the partial wave, so a resumed run's wave grid — and
/// hence its modeled wall-clock charges — aligns exactly with an
/// uninterrupted run's. Resuming requires a broker carrying the prior
/// spend and an objective fast-forwarded past the prior observations; the
/// candidate stream itself is realigned here by burning `drawn × dim`
/// draws.
pub fn random_search_resumable(
    broker: &mut EvalBroker,
    theta0: Vec<f64>,
    seed: u64,
    resume: Option<RandomSearchState>,
) -> (RandomSearchResult, Option<RandomSearchState>) {
    let n = broker.dim();
    let start_evals = broker.evals_used();
    let mut rng = Rng::seeded(seed);
    let mut st = match resume {
        Some(st) => {
            for _ in 0..st.drawn.saturating_mul(n as u64) {
                rng.f64();
            }
            st
        }
        None => RandomSearchState {
            theta0_done: false,
            drawn: 0,
            cap: if broker.budget().is_unlimited() { UNLIMITED_FALLBACK_OBS } else { u64::MAX },
            best_theta: theta0,
            best_f: f64::INFINITY,
        },
    };
    if !st.theta0_done {
        let Some(f0) = broker.try_eval(&st.best_theta) else {
            // nothing affordable: checkpoint the virgin state
            let res = RandomSearchResult {
                best_theta: st.best_theta.clone(),
                best_f: st.best_f,
                observations: 0,
            };
            return (res, Some(st));
        };
        st.theta0_done = true;
        st.best_f = f0;
        st.cap = st.cap.saturating_sub(1);
    }
    let RandomSearchState { mut drawn, mut cap, mut best_theta, mut best_f, .. } = st;

    let done = loop {
        if cap == 0 {
            break true;
        }
        let k = CHUNK.min(cap);
        if broker.remaining() < k {
            // budget boundary: stop on the chunk grid (see the doc above)
            break false;
        }
        let cands: Vec<Vec<f64>> =
            (0..k).map(|_| (0..n).map(|_| rng.f64()).collect()).collect();
        let fs = broker.try_eval_batch(&cands);
        // remaining() ≥ k was checked pre-wave, so the chunk is whole
        debug_assert_eq!(fs.len() as u64, k);
        drawn += k;
        cap -= k;
        for (cand, &f) in cands.iter().zip(&fs) {
            if f < best_f {
                best_f = f;
                best_theta = cand.clone();
            }
        }
    };

    let result = RandomSearchResult {
        best_theta: best_theta.clone(),
        best_f,
        observations: broker.evals_used() - start_evals,
    };
    let state = if done {
        None
    } else {
        Some(RandomSearchState { theta0_done: true, drawn, cap, best_theta, best_f })
    };
    (result, state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::broker::{Budget, EvalBroker};
    use crate::tuner::QuadraticObjective;

    #[test]
    fn improves_over_bad_start_and_spends_exactly_the_budget() {
        let mut obj = QuadraticObjective::new(vec![0.5; 3], 0.0, 1);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(100));
        let res = random_search(&mut broker, vec![0.99; 3], 4);
        let start_f = 1.0 + 3.0 * (0.99 - 0.5) * (0.99 - 0.5);
        assert!(res.best_f < start_f);
        assert_eq!(res.observations, 100, "budget exhaustion must land exactly");
        assert!(broker.exhausted());
    }

    #[test]
    fn unlimited_broker_stops_at_the_fallback_cap() {
        let mut obj = QuadraticObjective::new(vec![0.5; 2], 0.0, 1);
        let mut broker = EvalBroker::new(&mut obj, Budget::unlimited());
        let res = random_search(&mut broker, vec![0.9, 0.9], 5);
        assert_eq!(res.observations, UNLIMITED_FALLBACK_OBS);
    }

    #[test]
    fn time_limited_budget_overrides_the_fallback_cap() {
        // The wall-clock comparison frame: unlimited observations, finite
        // model time. Random search's 64-probe waves cost barely more than
        // a single probe per wave (batch cost = max member duration +
        // overhead), so the time budget buys far more observations than
        // the old obs-only fallback allowed.
        let mut obj = QuadraticObjective::new(vec![0.5; 2], 0.0, 1);
        // noise-free f ≤ 1.5 on the unit box → each wave costs ≤ 6.5 s
        // (default 5 s dispatch overhead): a 40 s cap affords ~6 waves
        let mut broker =
            EvalBroker::new(&mut obj, Budget::unlimited().with_model_time(40.0));
        let res = random_search(&mut broker, vec![0.9, 0.9], 5);
        assert!(broker.exhausted(), "time axis must be what stops the search");
        assert!(
            res.observations > UNLIMITED_FALLBACK_OBS,
            "only {} obs — the fallback cap fired under a time budget",
            res.observations
        );
    }

    #[test]
    fn resumable_split_matches_straight_run_including_model_time() {
        // The checkpoint contract: seg1 at a smaller budget + resume at the
        // full budget ≡ one straight resumable run at the full budget —
        // same best, same observations, same wave grid (bit-equal elapsed
        // modeled time), with the second segment spending only the
        // increment.
        use crate::tuner::Objective;
        let mk = || QuadraticObjective::new(vec![0.4, 0.6, 0.2], 0.1, 8);

        let mut obj_s = mk();
        let mut straight = EvalBroker::new(&mut obj_s, Budget::obs(150));
        let (full, full_st) = random_search_resumable(&mut straight, vec![0.5; 3], 11, None);
        assert_eq!(full.observations, 129, "theta0 + two whole 64-chunks");
        assert!(full_st.is_some(), "obs budget left: still resumable");

        let mut obj_1 = mk();
        let mut seg1 = EvalBroker::new(&mut obj_1, Budget::obs(80));
        let (r1, st1) = random_search_resumable(&mut seg1, vec![0.5; 3], 11, None);
        assert_eq!(r1.observations, 65, "theta0 + one whole chunk");
        let st1 = st1.expect("resumable");
        let st1 = RandomSearchState::from_json(&st1.to_json()).unwrap();

        let mut obj_2 = mk();
        assert!(obj_2.advance_evals(seg1.evals_used()));
        let mut seg2 = EvalBroker::new(&mut obj_2, Budget::obs(150)).with_prior_spend(
            seg1.evals_used(),
            seg1.batches_used(),
            seg1.elapsed_model_time(),
        );
        let (r2, _) = random_search_resumable(&mut seg2, vec![0.5; 3], 11, Some(st1));
        assert_eq!(r2.observations, 64, "extension spends only the increment");
        assert_eq!(r2.best_theta, full.best_theta);
        assert_eq!(r2.best_f.to_bits(), full.best_f.to_bits());
        assert_eq!(seg2.evals_used(), straight.evals_used());
        assert_eq!(seg2.batches_used(), straight.batches_used());
        assert_eq!(
            seg2.elapsed_model_time().to_bits(),
            straight.elapsed_model_time().to_bits(),
            "wave grids must align: prior waves charged once, never replayed"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            let mut obj = QuadraticObjective::new(vec![0.5; 3], 0.0, 1);
            let mut broker = EvalBroker::new(&mut obj, Budget::obs(50));
            random_search(&mut broker, vec![0.0; 3], seed).best_theta
        };
        assert_eq!(run(9), run(9));
    }

    #[test]
    fn chunked_dispatch_matches_single_eval_loop() {
        // The chunked batches must see the same values a one-by-one loop
        // would: same candidate draws, same objective seed stream.
        let budget = 37; // not a multiple of CHUNK
        let mut obj_a = QuadraticObjective::new(vec![0.4, 0.6], 0.1, 2);
        let mut broker_a = EvalBroker::new(&mut obj_a, Budget::obs(budget));
        let batched = random_search(&mut broker_a, vec![0.5, 0.5], 11);

        // manual sequential replay: same rng, same eval order
        let mut obj_b = QuadraticObjective::new(vec![0.4, 0.6], 0.1, 2);
        let mut rng = Rng::seeded(11);
        use crate::tuner::Objective;
        let mut best_theta = vec![0.5, 0.5];
        let mut best_f = obj_b.eval(&best_theta);
        for _ in 1..budget {
            let cand: Vec<f64> = (0..2).map(|_| rng.f64()).collect();
            let f = obj_b.eval(&cand);
            if f < best_f {
                best_f = f;
                best_theta = cand;
            }
        }
        assert_eq!(batched.best_theta, best_theta);
        assert_eq!(batched.best_f, best_f);
        assert_eq!(batched.observations, budget);
    }
}
