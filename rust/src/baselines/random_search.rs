//! Pure random search on the live system — the weakest sensible baseline
//! and the ablation anchor: any tuner must beat it at equal observation
//! budget.
//!
//! The budget lives in the [`EvalBroker`]: the search spends *exactly* the
//! broker's remaining observations and keeps the best point seen.
//! Candidates are pre-drawn and dispatched in chunks through
//! `try_eval_batch`, so the independent probes fan across the worker pool
//! while the per-observation seed stream stays identical to the one-by-one
//! loop (the broker dispatches uncached points in order).

use crate::tuner::broker::EvalBroker;
use crate::util::rng::Rng;

/// Candidates per dispatch round (bounds memory for huge budgets while
/// keeping whole worker waves busy).
const CHUNK: u64 = 64;

/// Observations spent when the broker itself is unlimited: random search
/// has no intrinsic stopping rule, so an explicit fallback keeps the loop
/// finite instead of simulating forever.
const UNLIMITED_FALLBACK_OBS: u64 = 256;

#[derive(Clone, Debug)]
pub struct RandomSearchResult {
    pub best_theta: Vec<f64>,
    pub best_f: f64,
    pub observations: u64,
}

/// Evaluate the starting point, then uniform random points until the
/// broker's budget is spent; keep the best. An unlimited broker gets the
/// [`UNLIMITED_FALLBACK_OBS`] cap — the search would otherwise never stop.
pub fn random_search(
    broker: &mut EvalBroker,
    theta0: Vec<f64>,
    seed: u64,
) -> RandomSearchResult {
    let n = broker.dim();
    let start_evals = broker.evals_used();
    let mut rng = Rng::seeded(seed);
    // the fallback cap applies only when NO axis bounds the run: a budget
    // with unlimited observations but finite batches or model time is the
    // wall-clock-frame comparison (64-probe waves until time runs out) and
    // must spend it, not stop at an arbitrary observation count
    let mut cap = if broker.budget().is_unlimited() {
        UNLIMITED_FALLBACK_OBS
    } else {
        u64::MAX
    };
    let mut best_theta = theta0;
    let Some(mut best_f) = broker.try_eval(&best_theta) else {
        return RandomSearchResult { best_theta, best_f: f64::INFINITY, observations: 0 };
    };
    cap = cap.saturating_sub(1);
    loop {
        let k = broker.remaining().min(CHUNK).min(cap);
        if k == 0 {
            break;
        }
        cap -= k;
        let cands: Vec<Vec<f64>> =
            (0..k).map(|_| (0..n).map(|_| rng.f64()).collect()).collect();
        let fs = broker.try_eval_batch(&cands);
        // k never exceeds remaining(), so the broker serves whole chunks
        debug_assert_eq!(fs.len() as u64, k);
        for (cand, &f) in cands.iter().zip(&fs) {
            if f < best_f {
                best_f = f;
                best_theta = cand.clone();
            }
        }
    }
    // delta, not lifetime total: a reused broker may carry earlier spend
    RandomSearchResult {
        best_theta,
        best_f,
        observations: broker.evals_used() - start_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::broker::{Budget, EvalBroker};
    use crate::tuner::QuadraticObjective;

    #[test]
    fn improves_over_bad_start_and_spends_exactly_the_budget() {
        let mut obj = QuadraticObjective::new(vec![0.5; 3], 0.0, 1);
        let mut broker = EvalBroker::new(&mut obj, Budget::obs(100));
        let res = random_search(&mut broker, vec![0.99; 3], 4);
        let start_f = 1.0 + 3.0 * (0.99 - 0.5) * (0.99 - 0.5);
        assert!(res.best_f < start_f);
        assert_eq!(res.observations, 100, "budget exhaustion must land exactly");
        assert!(broker.exhausted());
    }

    #[test]
    fn unlimited_broker_stops_at_the_fallback_cap() {
        let mut obj = QuadraticObjective::new(vec![0.5; 2], 0.0, 1);
        let mut broker = EvalBroker::new(&mut obj, Budget::unlimited());
        let res = random_search(&mut broker, vec![0.9, 0.9], 5);
        assert_eq!(res.observations, UNLIMITED_FALLBACK_OBS);
    }

    #[test]
    fn time_limited_budget_overrides_the_fallback_cap() {
        // The wall-clock comparison frame: unlimited observations, finite
        // model time. Random search's 64-probe waves cost barely more than
        // a single probe per wave (batch cost = max member duration +
        // overhead), so the time budget buys far more observations than
        // the old obs-only fallback allowed.
        let mut obj = QuadraticObjective::new(vec![0.5; 2], 0.0, 1);
        // noise-free f ≤ 1.5 on the unit box → each wave costs ≤ 6.5 s
        // (default 5 s dispatch overhead): a 40 s cap affords ~6 waves
        let mut broker =
            EvalBroker::new(&mut obj, Budget::unlimited().with_model_time(40.0));
        let res = random_search(&mut broker, vec![0.9, 0.9], 5);
        assert!(broker.exhausted(), "time axis must be what stops the search");
        assert!(
            res.observations > UNLIMITED_FALLBACK_OBS,
            "only {} obs — the fallback cap fired under a time budget",
            res.observations
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            let mut obj = QuadraticObjective::new(vec![0.5; 3], 0.0, 1);
            let mut broker = EvalBroker::new(&mut obj, Budget::obs(50));
            random_search(&mut broker, vec![0.0; 3], seed).best_theta
        };
        assert_eq!(run(9), run(9));
    }

    #[test]
    fn chunked_dispatch_matches_single_eval_loop() {
        // The chunked batches must see the same values a one-by-one loop
        // would: same candidate draws, same objective seed stream.
        let budget = 37; // not a multiple of CHUNK
        let mut obj_a = QuadraticObjective::new(vec![0.4, 0.6], 0.1, 2);
        let mut broker_a = EvalBroker::new(&mut obj_a, Budget::obs(budget));
        let batched = random_search(&mut broker_a, vec![0.5, 0.5], 11);

        // manual sequential replay: same rng, same eval order
        let mut obj_b = QuadraticObjective::new(vec![0.4, 0.6], 0.1, 2);
        let mut rng = Rng::seeded(11);
        use crate::tuner::Objective;
        let mut best_theta = vec![0.5, 0.5];
        let mut best_f = obj_b.eval(&best_theta);
        for _ in 1..budget {
            let cand: Vec<f64> = (0..2).map(|_| rng.f64()).collect();
            let f = obj_b.eval(&cand);
            if f < best_f {
                best_f = f;
                best_theta = cand;
            }
        }
        assert_eq!(batched.best_theta, best_theta);
        assert_eq!(batched.best_f, best_f);
        assert_eq!(batched.observations, budget);
    }
}
