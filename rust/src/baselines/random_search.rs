//! Pure random search on the live system — the weakest sensible baseline
//! and the ablation anchor: any tuner must beat it at equal observation
//! budget.

use crate::tuner::Objective;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct RandomSearchResult {
    pub best_theta: Vec<f64>,
    pub best_f: f64,
    pub observations: u64,
}

/// Evaluate `budget` uniform random points (plus the starting point) and
/// keep the best.
pub fn random_search(
    objective: &mut dyn Objective,
    theta0: Vec<f64>,
    budget: u64,
    seed: u64,
) -> RandomSearchResult {
    let n = objective.dim();
    let mut rng = Rng::seeded(seed);
    let mut best_theta = theta0;
    let mut best_f = objective.eval(&best_theta);
    let mut used = 1u64;
    while used < budget {
        let cand: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let f = objective.eval(&cand);
        used += 1;
        if f < best_f {
            best_f = f;
            best_theta = cand;
        }
    }
    RandomSearchResult { best_theta, best_f, observations: used }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::QuadraticObjective;

    #[test]
    fn improves_over_bad_start() {
        let mut obj = QuadraticObjective::new(vec![0.5; 3], 0.0, 1);
        let res = random_search(&mut obj, vec![0.99; 3], 100, 4);
        let start_f = 1.0 + 3.0 * (0.99 - 0.5) * (0.99 - 0.5);
        assert!(res.best_f < start_f);
        assert_eq!(res.observations, 100);
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            let mut obj = QuadraticObjective::new(vec![0.5; 3], 0.0, 1);
            random_search(&mut obj, vec![0.0; 3], 50, seed).best_theta
        };
        assert_eq!(run(9), run(9));
    }
}
