//! PPABS-style baseline (Wu & Gokhale, HiPC'13), as described in the
//! paper's §3 and §6.6:
//!
//! * **Offline** — profile a corpus of jobs on the live (simulated) system
//!   to extract *signatures* (resource-utilization feature vectors),
//!   cluster them with k-means, then find one tuned configuration per
//!   cluster with simulated annealing over a *reduced* parameter space.
//! * **Online** — assign a new job to the nearest cluster and run it with
//!   that cluster's configuration.
//!
//! PPABS's two structural handicaps, which the paper's Fig. 9 exposes, are
//! faithfully reproduced: the parameter-space reduction and the fact that a
//! job gets its *cluster's* configuration, not its own optimum.

use crate::cluster::ClusterSpec;
use crate::config::ParameterSpace;
use crate::sim::{simulate, SimOptions};
use crate::whatif::ClusterFeatures;
use crate::workloads::WorkloadProfile;

use super::annealing::{simulated_annealing, SaConfig};
use super::evaluator::RustWhatIf;
use super::kmeans::{kmeans, nearest};

/// The reduced parameter space PPABS tunes: io.sort.mb, spill.percent,
/// sort.factor, shuffle.input.buffer.percent, inmem.merge.threshold and
/// mapred.reduce.tasks; everything else stays at the default.
pub fn reduced_mask(dim: usize) -> Vec<bool> {
    let mut m = vec![false; dim];
    for i in [0, 1, 2, 3, 5, 7] {
        if i < dim {
            m[i] = true;
        }
    }
    m
}

/// A job signature: scale-free data-flow + CPU features (what PPABS mines
/// from job history logs).
pub fn signature(w: &WorkloadProfile) -> Vec<f64> {
    vec![
        w.map_selectivity_bytes.min(4.0) / 4.0,
        (w.map_selectivity_records.min(16.0)) / 16.0,
        w.combiner_reduction,
        w.reduce_selectivity_bytes.min(2.0) / 2.0,
        w.compress_ratio,
        (w.map_cpu_ops_per_record.max(1.0).ln()) / 10.0,
        (w.reduce_cpu_ops_per_record.max(1.0).ln()) / 10.0,
        (w.partition_skew.min(5.0) - 1.0) / 4.0,
    ]
}

/// The trained PPABS system.
pub struct Ppabs {
    pub space: ParameterSpace,
    pub centroids: Vec<Vec<f64>>,
    /// Tuned θ_A per cluster.
    pub cluster_theta: Vec<Vec<f64>>,
    /// Simulated seconds spent profiling the training corpus.
    pub profiling_overhead_s: f64,
    pub model_evals: u64,
}

impl Ppabs {
    /// Offline phase: profile `corpus` jobs, cluster signatures, anneal one
    /// configuration per cluster.
    pub fn train(
        space: &ParameterSpace,
        cluster_spec: &ClusterSpec,
        corpus: &[WorkloadProfile],
        k: usize,
        seed: u64,
    ) -> Ppabs {
        assert!(!corpus.is_empty());
        let version = space.version;

        // 1. profile every corpus job once (live-system overhead)
        let mut profiling = 0.0;
        for (i, w) in corpus.iter().enumerate() {
            let run = simulate(
                cluster_spec,
                &space.default_config(),
                w,
                &SimOptions { seed: seed ^ (i as u64 + 1), noise: true, ..Default::default() },
            );
            profiling += run.exec_time_s;
        }

        // 2. cluster signatures
        let sigs: Vec<Vec<f64>> = corpus.iter().map(signature).collect();
        let km = kmeans(&sigs, k, 100, seed);

        // 3. per-cluster SA over the reduced space on a representative
        //    member (the job nearest the centroid)
        let mut cluster_theta = Vec::new();
        let mut model_evals = 0;
        for (ci, centroid) in km.centroids.iter().enumerate() {
            let rep = sigs
                .iter()
                .enumerate()
                .filter(|(i, _)| km.assignment[*i] == ci)
                .min_by(|(_, a), (_, b)| {
                    let da: f64 = a.iter().zip(centroid).map(|(x, y)| (x - y) * (x - y)).sum();
                    let db: f64 = b.iter().zip(centroid).map(|(x, y)| (x - y) * (x - y)).sum();
                    da.total_cmp(&db)
                })
                .map(|(i, _)| i)
                .unwrap_or(0);
            let mut evaluator = RustWhatIf::new(
                space.clone(),
                corpus[rep].clone(),
                ClusterFeatures::from_spec(cluster_spec, version),
            );
            let sa_cfg = SaConfig {
                active: Some(reduced_mask(space.dim())),
                seed: seed ^ (0xA5A5 + ci as u64),
                ..Default::default()
            };
            let res =
                simulated_annealing(&mut evaluator, space.default_theta(), &sa_cfg);
            model_evals += res.evals;
            cluster_theta.push(res.best_theta);
        }

        Ppabs {
            space: space.clone(),
            centroids: km.centroids,
            cluster_theta,
            profiling_overhead_s: profiling,
            model_evals,
        }
    }

    /// Online phase: configuration for a new job.
    pub fn configure(&self, w: &WorkloadProfile) -> Vec<f64> {
        let c = nearest(&self.centroids, &signature(w));
        self.cluster_theta[c].clone()
    }
}

/// Build the training corpus the paper's §6.6 describes ("we collect
/// datasets as described in [32]"): the five benchmarks at several scales,
/// profiled by really running them on sampled data.
pub fn training_corpus(seed: u64) -> Vec<WorkloadProfile> {
    use crate::workloads::Benchmark;
    let mut rng = crate::util::rng::Rng::seeded(seed);
    let mut corpus = Vec::new();
    for b in Benchmark::all() {
        for scale in [1u64, 4, 16] {
            let target = b.paper_partial_bytes() / 8 * scale;
            corpus.push(b.profile_scaled(512 << 10, target.max(64 << 20), &mut rng));
        }
    }
    corpus
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workloads::Benchmark;

    #[test]
    fn signatures_separate_benchmark_families() {
        let mut rng = Rng::seeded(5);
        let tera = signature(&Benchmark::Terasort.profile_scaled(100_000, 1 << 30, &mut rng));
        let grep = signature(&Benchmark::Grep.profile_scaled(100_000, 1 << 30, &mut rng));
        let d2: f64 = tera.iter().zip(&grep).map(|(a, b)| (a - b) * (a - b)).sum();
        assert!(d2 > 0.05, "terasort and grep signatures too close: {d2}");
    }

    #[test]
    fn reduced_mask_freezes_compression_flags() {
        let m = reduced_mask(11);
        assert!(m[0] && m[7]);
        assert!(!m[9] && !m[10]);
        assert_eq!(m.iter().filter(|&&b| b).count(), 6);
    }

    #[test]
    fn train_and_configure_beats_default() {
        let space = ParameterSpace::v2();
        let cluster = ClusterSpec::paper_cluster();
        let corpus = training_corpus(1);
        let ppabs = Ppabs::train(&space, &cluster, &corpus, 3, 11);
        assert_eq!(ppabs.cluster_theta.len(), ppabs.centroids.len());
        assert!(ppabs.profiling_overhead_s > 0.0);

        // a new terasort-like job
        let mut rng = Rng::seeded(9);
        let w = Benchmark::Terasort.profile_scaled(100_000, 8 << 30, &mut rng);
        let theta = ppabs.configure(&w);
        let opts = SimOptions { seed: 3, noise: false, ..Default::default() };
        let f_def = simulate(&cluster, &space.default_config(), &w, &opts).exec_time_s;
        let f_ppabs = simulate(&cluster, &space.materialize(&theta), &w, &opts).exec_time_s;
        assert!(f_ppabs < f_def, "ppabs {f_ppabs} default {f_def}");
    }
}
