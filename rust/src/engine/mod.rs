//! Mini MapReduce execution engine (substrate).
//!
//! Runs real map/combine/reduce programs over real byte data to (a) validate
//! benchmark semantics and (b) measure the [`stats::DataStats`] that
//! parameterize the discrete-event simulator in [`crate::sim`].

pub mod job;
pub mod stats;
pub mod types;

pub use job::{run_job, Emit, IdentityReducer, JobOutput, JobSpec, Mapper, Reducer, Split, SumReducer};
pub use stats::{compress_ratio, DataStats};
pub use types::{HashPartitioner, Partitioner, RangePartitioner, Rec};
