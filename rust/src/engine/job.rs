//! Job definition and the local MapReduce executor.
//!
//! This is a *real* (if miniature) MapReduce runtime: it splits input,
//! runs user map functions, partitions and sorts intermediate records,
//! applies the combiner, groups by key and runs user reduce functions.
//! The paper's five benchmarks run through it on real generated data; its
//! purpose in the reproduction is twofold:
//!
//! 1. prove the benchmarks are actual programs (Example 1 of the paper runs
//!    verbatim in the tests below), and
//! 2. *measure* [`DataStats`] that parameterize the discrete-event
//!    simulator, instead of hard-coding data-flow ratios.

use std::collections::BTreeMap;

use super::stats::{compress_ratio, DataStats};
use super::types::{HashPartitioner, Partitioner, Rec};

/// Emit-callback used by map / reduce / combine functions.
pub type Emit<'a> = &'a mut dyn FnMut(Rec);

/// User map function.
pub trait Mapper: Send + Sync {
    /// `key` is the record offset (like Hadoop's LongWritable byte offset);
    /// `value` the record payload.
    fn map(&self, key: u64, value: &[u8], emit: Emit);
}

/// User reduce function (also usable as a combiner).
pub trait Reducer: Send + Sync {
    fn reduce(&self, key: &[u8], values: &[Vec<u8>], emit: Emit);
}

/// A complete MapReduce job definition.
pub struct JobSpec {
    pub name: String,
    pub mapper: Box<dyn Mapper>,
    pub reducer: Box<dyn Reducer>,
    /// Optional combiner (paper §2.3.1: runs on map output before spill).
    pub combiner: Option<Box<dyn Reducer>>,
    pub partitioner: Box<dyn Partitioner>,
}

impl JobSpec {
    pub fn new(
        name: &str,
        mapper: Box<dyn Mapper>,
        reducer: Box<dyn Reducer>,
        combiner: Option<Box<dyn Reducer>>,
    ) -> Self {
        JobSpec {
            name: name.to_string(),
            mapper,
            reducer,
            combiner,
            partitioner: Box::new(HashPartitioner),
        }
    }

    pub fn with_partitioner(mut self, p: Box<dyn Partitioner>) -> Self {
        self.partitioner = p;
        self
    }
}

/// An input split: a byte buffer plus a record iterator strategy.
pub enum Split {
    /// Newline-delimited text records.
    Text(Vec<u8>),
    /// Fixed-size binary records (Terasort: 100-byte records).
    Fixed { data: Vec<u8>, record_len: usize },
}

impl Split {
    pub fn bytes(&self) -> u64 {
        match self {
            Split::Text(d) => d.len() as u64,
            Split::Fixed { data, .. } => data.len() as u64,
        }
    }

    /// Iterate records as (offset, payload) pairs.
    pub fn for_each_record(&self, mut f: impl FnMut(u64, &[u8])) {
        match self {
            Split::Text(data) => {
                let mut off = 0u64;
                for line in data.split(|&b| b == b'\n') {
                    if !line.is_empty() {
                        f(off, line);
                    }
                    off += line.len() as u64 + 1;
                }
            }
            Split::Fixed { data, record_len } => {
                let mut off = 0usize;
                while off + record_len <= data.len() {
                    f(off as u64, &data[off..off + record_len]);
                    off += record_len;
                }
            }
        }
    }

    pub fn record_count(&self) -> u64 {
        let mut n = 0;
        self.for_each_record(|_, _| n += 1);
        n
    }
}

/// Output of one full job execution.
pub struct JobOutput {
    /// Final reduce output, per partition, sorted by key within each.
    pub partitions: Vec<Vec<Rec>>,
    pub stats: DataStats,
}

impl JobOutput {
    /// Flatten all partitions (ordering: partition-major).
    pub fn all_records(&self) -> Vec<&Rec> {
        self.partitions.iter().flatten().collect()
    }

    pub fn find(&self, key: &[u8]) -> Option<&Rec> {
        self.partitions.iter().flatten().find(|r| r.key == key)
    }
}

/// Group sorted records by key and run a reduce-like function.
fn reduce_groups(sorted: &[Rec], f: &dyn Reducer, out: &mut Vec<Rec>) {
    let mut i = 0;
    while i < sorted.len() {
        let key = &sorted[i].key;
        let mut values: Vec<Vec<u8>> = Vec::new();
        let mut j = i;
        while j < sorted.len() && &sorted[j].key == key {
            values.push(sorted[j].value.clone());
            j += 1;
        }
        let mut emit = |r: Rec| out.push(r);
        f.reduce(key, &values, &mut emit);
        i = j;
    }
}

/// Execute a job locally over the given splits with `n_reducers`
/// partitions, measuring [`DataStats`] along the way.
pub fn run_job(job: &JobSpec, splits: &[Split], n_reducers: u32) -> JobOutput {
    assert!(n_reducers >= 1);
    let mut stats = DataStats::default();
    let mut intermediate: Vec<Vec<Rec>> = vec![Vec::new(); n_reducers as usize];

    // ---- map phase -------------------------------------------------------
    for split in splits {
        stats.input_bytes += split.bytes();
        split.for_each_record(|off, payload| {
            stats.input_records += 1;
            let mut emit = |r: Rec| {
                stats.map_output_records += 1;
                stats.map_output_bytes += r.bytes();
                let p = job.partitioner.partition(&r.key, n_reducers);
                intermediate[p as usize].push(r);
            };
            job.mapper.map(off, payload, &mut emit);
        });
    }

    // compressibility of a map-output sample (first ≤ 64 KiB, serialized)
    let mut sample: Vec<u8> = Vec::with_capacity(64 << 10);
    'outer: for part in &intermediate {
        for r in part {
            sample.extend_from_slice(&r.key);
            sample.extend_from_slice(&r.value);
            if sample.len() >= 64 << 10 {
                break 'outer;
            }
        }
    }
    stats.map_output_compress_ratio = compress_ratio(&sample);

    // ---- sort + combine (per partition, mirroring the spill path) --------
    let mut shuffled: Vec<Vec<Rec>> = Vec::with_capacity(n_reducers as usize);
    for part in intermediate {
        let mut part = part;
        part.sort();
        let combined = if let Some(comb) = &job.combiner {
            let mut out = Vec::new();
            reduce_groups(&part, comb.as_ref(), &mut out);
            out.sort();
            out
        } else {
            part
        };
        stats.combine_output_records += combined.len() as u64;
        stats.combine_output_bytes += combined.iter().map(|r| r.bytes()).sum::<u64>();
        stats.partition_bytes.push(combined.iter().map(|r| r.bytes()).sum::<u64>());
        shuffled.push(combined);
    }

    // distinct keys across all partitions
    let mut keys: BTreeMap<&[u8], ()> = BTreeMap::new();
    for part in &shuffled {
        for r in part {
            keys.insert(&r.key, ());
        }
    }
    stats.distinct_keys = keys.len() as u64;
    drop(keys);

    // ---- reduce phase ----------------------------------------------------
    let mut partitions: Vec<Vec<Rec>> = Vec::with_capacity(n_reducers as usize);
    for part in &shuffled {
        let mut out = Vec::new();
        reduce_groups(part, job.reducer.as_ref(), &mut out);
        stats.reduce_output_records += out.len() as u64;
        stats.reduce_output_bytes += out.iter().map(|r| r.bytes()).sum::<u64>();
        partitions.push(out);
    }

    JobOutput { partitions, stats }
}

// ---------------------------------------------------------------------------
// Reusable building-block map/reduce functions (the benchmarks compose these)
// ---------------------------------------------------------------------------

/// Sums integer-encoded values per key — WordCount/Grep/Bigram reducer.
pub struct SumReducer;

impl Reducer for SumReducer {
    fn reduce(&self, key: &[u8], values: &[Vec<u8>], emit: Emit) {
        let total: u64 = values
            .iter()
            .map(|v| String::from_utf8_lossy(v).parse::<u64>().unwrap_or(0))
            .sum();
        emit(Rec::new(key.to_vec(), total.to_string().into_bytes()));
    }
}

/// Identity reducer (Terasort).
pub struct IdentityReducer;

impl Reducer for IdentityReducer {
    fn reduce(&self, key: &[u8], values: &[Vec<u8>], emit: Emit) {
        for v in values {
            emit(Rec::new(key.to_vec(), v.clone()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Word-count mapper used by the engine tests (and the paper's
    /// Example 1).
    struct WordMapper;

    impl Mapper for WordMapper {
        fn map(&self, _k: u64, value: &[u8], emit: Emit) {
            let text = String::from_utf8_lossy(value);
            for w in text.split_whitespace() {
                let w = w.trim_matches(|c: char| !c.is_alphanumeric());
                if !w.is_empty() {
                    emit(Rec::from_str(w, "1"));
                }
            }
        }
    }

    fn wordcount() -> JobSpec {
        JobSpec::new(
            "wordcount",
            Box::new(WordMapper),
            Box::new(SumReducer),
            Some(Box::new(SumReducer)),
        )
    }

    #[test]
    fn paper_example_1_wordcount() {
        // "This is an apple. That is an apple" ⇒ counts {This:1, That:1,
        // is:2, an:2, apple:2} — Example 1 verbatim.
        let splits = vec![Split::Text(b"This is an apple. That is an apple".to_vec())];
        let out = run_job(&wordcount(), &splits, 2);
        let get = |k: &str| -> u64 {
            out.find(k.as_bytes())
                .map(|r| r.value_str().parse().unwrap())
                .unwrap_or(0)
        };
        assert_eq!(get("This"), 1);
        assert_eq!(get("That"), 1);
        assert_eq!(get("is"), 2);
        assert_eq!(get("an"), 2);
        assert_eq!(get("apple"), 2);
    }

    #[test]
    fn stats_are_measured() {
        let splits = vec![Split::Text(b"a a a b\nb c".to_vec())];
        let out = run_job(&wordcount(), &splits, 2);
        let s = &out.stats;
        assert_eq!(s.input_records, 2); // two lines
        assert_eq!(s.map_output_records, 6); // six words
        assert_eq!(s.distinct_keys, 3);
        // combiner collapses duplicate words within a partition
        assert!(s.combine_output_records <= s.map_output_records);
        assert_eq!(s.combine_output_records, 3);
        assert_eq!(s.partition_bytes.len(), 2);
        assert!(s.map_output_bytes > 0);
    }

    #[test]
    fn no_combiner_passthrough() {
        let job = JobSpec::new("wc", Box::new(WordMapper), Box::new(SumReducer), None);
        let splits = vec![Split::Text(b"x x x".to_vec())];
        let out = run_job(&job, &splits, 1);
        assert_eq!(out.stats.combine_output_records, 3);
        assert_eq!(out.find(b"x").unwrap().value_str(), "3");
    }

    #[test]
    fn fixed_split_record_iteration() {
        let data: Vec<u8> = (0..250u32).map(|i| (i % 256) as u8).collect();
        let s = Split::Fixed { data, record_len: 100 };
        assert_eq!(s.record_count(), 2); // trailing 50 bytes dropped
    }

    #[test]
    fn reduce_output_sorted_within_partition() {
        let splits = vec![Split::Text(b"pear kiwi apple kiwi fig".to_vec())];
        let out = run_job(&wordcount(), &splits, 1);
        let keys: Vec<_> = out.partitions[0].iter().map(|r| r.key.clone()).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn multiple_splits_accumulate() {
        let splits = vec![
            Split::Text(b"a b".to_vec()),
            Split::Text(b"a c".to_vec()),
        ];
        let out = run_job(&wordcount(), &splits, 4);
        assert_eq!(out.find(b"a").unwrap().value_str(), "2");
        assert_eq!(out.stats.input_bytes, 6);
    }
}
