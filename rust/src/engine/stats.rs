//! Data-flow statistics measured from a real execution of a MapReduce job —
//! the coupling between the execution engine and the discrete-event
//! simulator. A real Hadoop cluster derives its timing from these same
//! quantities; the DES consumes them via [`crate::workloads::WorkloadProfile`].

use flate2::write::ZlibEncoder;
use flate2::Compression;
use std::io::Write;

/// Everything the simulator needs to know about a job's data flow,
/// measured (not assumed) by running the job on sample data.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DataStats {
    pub input_bytes: u64,
    pub input_records: u64,
    pub map_output_records: u64,
    pub map_output_bytes: u64,
    /// Records surviving one combiner pass over a full map output
    /// (`map_output_records` if there is no combiner).
    pub combine_output_records: u64,
    pub combine_output_bytes: u64,
    pub distinct_keys: u64,
    /// Bytes per reduce partition (skew measurement).
    pub partition_bytes: Vec<u64>,
    pub reduce_output_records: u64,
    pub reduce_output_bytes: u64,
    /// Measured zlib ratio of map output (compressed / raw, in (0,1]).
    pub map_output_compress_ratio: f64,
}

impl DataStats {
    /// Map selectivity in bytes: map output bytes / input bytes.
    pub fn map_selectivity_bytes(&self) -> f64 {
        if self.input_bytes == 0 {
            return 0.0;
        }
        self.map_output_bytes as f64 / self.input_bytes as f64
    }

    /// Map selectivity in records.
    pub fn map_selectivity_records(&self) -> f64 {
        if self.input_records == 0 {
            return 0.0;
        }
        self.map_output_records as f64 / self.input_records as f64
    }

    /// Combiner record-reduction factor in (0,1]; 1.0 = no reduction.
    pub fn combiner_reduction(&self) -> f64 {
        if self.map_output_records == 0 {
            return 1.0;
        }
        (self.combine_output_records as f64 / self.map_output_records as f64).clamp(0.0, 1.0)
    }

    /// Reduce selectivity: output bytes per shuffled byte.
    pub fn reduce_selectivity_bytes(&self) -> f64 {
        let shuffled = self.combine_output_bytes.max(1);
        self.reduce_output_bytes as f64 / shuffled as f64
    }

    /// Average map-output record size in bytes.
    pub fn avg_map_record_bytes(&self) -> f64 {
        if self.map_output_records == 0 {
            return 0.0;
        }
        self.map_output_bytes as f64 / self.map_output_records as f64
    }

    /// Partition skew: max partition bytes / mean partition bytes (≥ 1).
    pub fn partition_skew(&self) -> f64 {
        if self.partition_bytes.is_empty() {
            return 1.0;
        }
        let max = *self.partition_bytes.iter().max().unwrap() as f64;
        let mean = self.partition_bytes.iter().sum::<u64>() as f64
            / self.partition_bytes.len() as f64;
        if mean <= 0.0 {
            1.0
        } else {
            (max / mean).max(1.0)
        }
    }
}

/// Measure the zlib compressibility of a byte sample: returns
/// compressed/raw in (0, 1]. Used to set the simulator's compression
/// ratio from *real* data rather than a guess.
pub fn compress_ratio(sample: &[u8]) -> f64 {
    if sample.is_empty() {
        return 1.0;
    }
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(sample).expect("in-memory compression cannot fail");
    let compressed = enc.finish().expect("in-memory compression cannot fail");
    (compressed.len() as f64 / sample.len() as f64).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selectivities() {
        let s = DataStats {
            input_bytes: 1000,
            input_records: 10,
            map_output_bytes: 500,
            map_output_records: 50,
            combine_output_records: 25,
            combine_output_bytes: 250,
            reduce_output_bytes: 100,
            ..Default::default()
        };
        assert!((s.map_selectivity_bytes() - 0.5).abs() < 1e-12);
        assert!((s.map_selectivity_records() - 5.0).abs() < 1e-12);
        assert!((s.combiner_reduction() - 0.5).abs() < 1e-12);
        assert!((s.reduce_selectivity_bytes() - 0.4).abs() < 1e-12);
        assert!((s.avg_map_record_bytes() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn skew_of_uniform_is_one() {
        let s = DataStats { partition_bytes: vec![100, 100, 100], ..Default::default() };
        assert!((s.partition_skew() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skew_detects_hot_partition() {
        let s = DataStats { partition_bytes: vec![300, 100, 100, 100], ..Default::default() };
        assert!(s.partition_skew() > 1.9);
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = DataStats::default();
        assert_eq!(s.map_selectivity_bytes(), 0.0);
        assert_eq!(s.combiner_reduction(), 1.0);
        assert_eq!(s.partition_skew(), 1.0);
    }

    #[test]
    fn text_compresses_well_random_does_not() {
        let text = "the quick brown fox jumps over the lazy dog ".repeat(200);
        let r_text = compress_ratio(text.as_bytes());
        assert!(r_text < 0.3, "text ratio {r_text}");

        // pseudo-random bytes barely compress
        let mut rng = crate::util::rng::Rng::seeded(1);
        let rand: Vec<u8> = (0..8192).map(|_| rng.next_u64() as u8).collect();
        let r_rand = compress_ratio(&rand);
        assert!(r_rand > 0.9, "random ratio {r_rand}");
    }
}
