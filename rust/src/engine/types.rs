//! Core record / partitioning types for the mini MapReduce execution engine.

/// A key-value record. Keys and values are byte strings (Terasort keys are
/// binary; text workloads use UTF-8).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Rec {
    pub key: Vec<u8>,
    pub value: Vec<u8>,
}

impl Rec {
    pub fn new(key: impl Into<Vec<u8>>, value: impl Into<Vec<u8>>) -> Self {
        Rec { key: key.into(), value: value.into() }
    }

    pub fn from_str(key: &str, value: &str) -> Self {
        Rec::new(key.as_bytes().to_vec(), value.as_bytes().to_vec())
    }

    /// Serialized size (key + value + framing), matching Hadoop's
    /// length-prefixed IFile layout (two varint-ish length fields ≈ 8 B).
    pub fn bytes(&self) -> u64 {
        self.key.len() as u64 + self.value.len() as u64 + 8
    }

    pub fn key_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.key)
    }

    pub fn value_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.value)
    }
}

/// Assigns a record key to one of `n` reduce partitions.
pub trait Partitioner: Send + Sync {
    fn partition(&self, key: &[u8], n: u32) -> u32;
}

/// Hadoop's default `HashPartitioner` (FNV-1a here; only the spread
/// matters, not the exact hash).
pub struct HashPartitioner;

impl Partitioner for HashPartitioner {
    fn partition(&self, key: &[u8], n: u32) -> u32 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &b in key {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        (h % n as u64) as u32
    }
}

/// Terasort's range partitioner over uniformly-distributed binary keys:
/// splits the key space into `n` equal ranges by the first bytes.
pub struct RangePartitioner;

impl Partitioner for RangePartitioner {
    fn partition(&self, key: &[u8], n: u32) -> u32 {
        let mut prefix = 0u64;
        for i in 0..4 {
            prefix = (prefix << 8) | *key.get(i).unwrap_or(&0) as u64;
        }
        // map [0, 2^32) onto [0, n)
        ((prefix * n as u64) >> 32).min(n as u64 - 1) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rec_bytes_counts_framing() {
        let r = Rec::from_str("ab", "cde");
        assert_eq!(r.bytes(), 2 + 3 + 8);
    }

    #[test]
    fn hash_partitioner_in_range_and_spread() {
        let p = HashPartitioner;
        let mut counts = vec![0u32; 8];
        for i in 0..8000 {
            let k = format!("key{i}");
            let part = p.partition(k.as_bytes(), 8);
            assert!(part < 8);
            counts[part as usize] += 1;
        }
        // roughly uniform: every partition sees 5%+ of keys
        assert!(counts.iter().all(|&c| c > 400), "{counts:?}");
    }

    #[test]
    fn hash_partitioner_deterministic() {
        let p = HashPartitioner;
        assert_eq!(p.partition(b"same", 16), p.partition(b"same", 16));
    }

    #[test]
    fn range_partitioner_ordered() {
        let p = RangePartitioner;
        assert_eq!(p.partition(&[0, 0, 0, 0], 4), 0);
        assert_eq!(p.partition(&[0xff, 0xff, 0xff, 0xff], 4), 3);
        let lo = p.partition(&[0x20, 0, 0, 0], 4);
        let hi = p.partition(&[0xe0, 0, 0, 0], 4);
        assert!(lo <= hi);
    }

    #[test]
    fn range_partitioner_short_keys() {
        let p = RangePartitioner;
        assert!(p.partition(b"", 4) < 4);
        assert!(p.partition(&[0x80], 4) >= 2);
    }
}
