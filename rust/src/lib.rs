//! # hadoop-spsa
//!
//! Production-style reproduction of *“Performance Tuning of Hadoop
//! MapReduce: A Noisy Gradient Approach”* (Kumar et al., 2016) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the SPSA tuner (paper Algorithm 1), the baseline
//!   tuners it is compared against (Starfish-style what-if optimizer,
//!   PPABS-style clustering + simulated annealing, hill climbing, random
//!   search) — all behind one `Tuner` trait and driven through the
//!   budget-metered, memoizing `EvalBroker` (`tuner::broker`), so
//!   cross-algorithm comparisons share one observation currency — and
//!   every substrate the evaluation needs: a 25-node cluster
//!   model, an HDFS block-placement model, a real mini-MapReduce execution
//!   engine running the five paper benchmarks on synthetic corpora, and a
//!   discrete-event simulator of the full MapReduce data path whose job
//!   execution time is the objective `f(θ)`.
//! * **L2/L1 (python/, build-time only)** — a differentiable analytic
//!   MapReduce cost model written in JAX with its batched hot loop as a
//!   Pallas kernel, AOT-lowered to HLO text and executed from rust through
//!   PJRT (`runtime`). It powers the Starfish-style what-if engine and the
//!   surrogate-SPSA extension; `whatif` holds the independent rust
//!   implementation used to cross-check artifact numerics.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

#![allow(clippy::too_many_arguments, clippy::type_complexity)]

pub mod analysis;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod runtime;
pub mod sim;
pub mod tuner;
pub mod util;
pub mod whatif;
pub mod workloads;
