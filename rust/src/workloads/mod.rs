//! Benchmark workloads: synthetic dataset generators (Wikipedia/PUMA and
//! TeraGen stand-ins), the paper's five benchmark MapReduce programs, and
//! the measured workload profiles that parameterize the simulator.

pub mod benchmarks;
pub mod corpus;
pub mod profile;

pub use benchmarks::Benchmark;
pub use profile::{WorkloadProfile, N_WORKLOAD_FEATURES};
