//! The paper's five benchmark applications (§6.3), implemented as real
//! MapReduce programs for the execution engine:
//!
//! * **Terasort** — sorts 100-byte TeraGen records (range partitioner,
//!   identity map/reduce). CPU *and* memory intensive.
//! * **Grep** — regex pattern search; tiny map output. CPU intensive.
//! * **Bigram** — counts consecutive word pairs. CPU intensive,
//!   reduce-heavy.
//! * **Inverted Index** — word → document-id postings. CPU+memory,
//!   reduce-heavy.
//! * **Word Co-occurrence** — window-2 co-occurrence matrix counts; the
//!   largest map output of the set.

use crate::engine::{
    Emit, IdentityReducer, JobSpec, Mapper, Rec, Reducer, Split, SumReducer,
};
use crate::engine::types::RangePartitioner;
use crate::util::rng::Rng;
use crate::util::units::{GB, MB};

use super::corpus::{
    generate_documents, generate_tera, generate_text, TextCorpusSpec, TERA_RECORD_LEN,
};
use super::profile::WorkloadProfile;

/// The five paper benchmarks. `Ord` follows declaration order and keys
/// the deterministic profile cache (`coordinator::profile_for`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Benchmark {
    Terasort,
    Grep,
    Bigram,
    InvertedIndex,
    WordCooccurrence,
}

impl Benchmark {
    pub fn all() -> [Benchmark; 5] {
        [
            Benchmark::Terasort,
            Benchmark::Grep,
            Benchmark::Bigram,
            Benchmark::InvertedIndex,
            Benchmark::WordCooccurrence,
        ]
    }

    pub fn label(&self) -> &'static str {
        match self {
            Benchmark::Terasort => "Terasort",
            Benchmark::Grep => "Grep",
            Benchmark::Bigram => "Bigram",
            Benchmark::InvertedIndex => "Inverted Index",
            Benchmark::WordCooccurrence => "Word Co-occurrence",
        }
    }

    /// Parse a benchmark name. Case-insensitive, tolerant of surrounding
    /// whitespace and of `-`/`_`/space separators, so the paper's display
    /// labels ("Inverted Index", "Word Co-occurrence") and every `label()`
    /// round-trip through CLI/experiment arguments.
    pub fn from_name(s: &str) -> Option<Benchmark> {
        match s.trim().to_ascii_lowercase().replace(['-', '_', ' '], "").as_str() {
            "terasort" => Some(Benchmark::Terasort),
            "grep" => Some(Benchmark::Grep),
            "bigram" => Some(Benchmark::Bigram),
            "invertedindex" | "invidx" => Some(Benchmark::InvertedIndex),
            "wordcooccurrence" | "cooccurrence" | "wordco" => Some(Benchmark::WordCooccurrence),
            _ => None,
        }
    }

    /// Partial (optimization-phase) workload sizes of the paper's §6.5:
    /// Terasort 30 GB, Grep 22 GB, Word Co-occurrence 85 GB, Inverted Index
    /// 1 GB, Bigram 200 MB.
    pub fn paper_partial_bytes(&self) -> u64 {
        match self {
            Benchmark::Terasort => 30 * GB,
            Benchmark::Grep => 22 * GB,
            Benchmark::Bigram => 200 * MB,
            Benchmark::InvertedIndex => GB,
            Benchmark::WordCooccurrence => 85 * GB,
        }
    }

    /// Per-record CPU weights (ops) for the map function, positioning each
    /// benchmark on the paper's CPU/IO spectrum (§6.3): Grep and Bigram are
    /// CPU intensive; Inverted Index and Terasort are CPU+memory intensive.
    pub fn map_cpu_ops_per_record(&self) -> f64 {
        match self {
            Benchmark::Terasort => 60.0,       // 100-byte records, pure data movement
            Benchmark::Grep => 2_600.0,        // regex scan per line
            Benchmark::Bigram => 1_500.0,      // tokenize + pair emit
            Benchmark::InvertedIndex => 1_900.0, // tokenize + dedup per doc
            Benchmark::WordCooccurrence => 2_400.0, // tokenize + window pairs
        }
    }

    /// Per-intermediate-record CPU weight (ops) in the reduce function.
    pub fn reduce_cpu_ops_per_record(&self) -> f64 {
        match self {
            Benchmark::Terasort => 50.0,
            Benchmark::Grep => 120.0,
            Benchmark::Bigram => 900.0,        // reduce-heavy (paper §6.5)
            Benchmark::InvertedIndex => 1_300.0, // postings-list building
            Benchmark::WordCooccurrence => 350.0,
        }
    }

    pub fn has_combiner(&self) -> bool {
        !matches!(self, Benchmark::Terasort | Benchmark::InvertedIndex)
    }

    /// Build the executable job definition.
    pub fn job(&self) -> JobSpec {
        match self {
            Benchmark::Terasort => JobSpec::new(
                "terasort",
                Box::new(TeraMapper),
                Box::new(IdentityReducer),
                None,
            )
            .with_partitioner(Box::new(RangePartitioner)),
            Benchmark::Grep => JobSpec::new(
                "grep",
                Box::new(GrepMapper::default_pattern()),
                Box::new(SumReducer),
                Some(Box::new(SumReducer)),
            ),
            Benchmark::Bigram => JobSpec::new(
                "bigram",
                Box::new(BigramMapper),
                Box::new(SumReducer),
                Some(Box::new(SumReducer)),
            ),
            Benchmark::InvertedIndex => JobSpec::new(
                "inverted_index",
                Box::new(InvertedIndexMapper),
                Box::new(PostingsReducer),
                None,
            ),
            Benchmark::WordCooccurrence => JobSpec::new(
                "word_cooccurrence",
                Box::new(CooccurrenceMapper { window: 2 }),
                Box::new(SumReducer),
                Some(Box::new(SumReducer)),
            ),
        }
    }

    /// Generate real input data of roughly `bytes`, chunked into splits of
    /// `split_bytes`.
    pub fn generate_input(&self, bytes: u64, split_bytes: u64, rng: &mut Rng) -> Vec<Split> {
        let mut splits = Vec::new();
        let mut remaining = bytes;
        while remaining > 0 {
            let chunk = remaining.min(split_bytes);
            let split = match self {
                Benchmark::Terasort => {
                    let n = (chunk / TERA_RECORD_LEN as u64).max(1);
                    Split::Fixed { data: generate_tera(n, rng), record_len: TERA_RECORD_LEN }
                }
                Benchmark::InvertedIndex => {
                    Split::Text(generate_documents(&TextCorpusSpec::default(), chunk, rng))
                }
                _ => Split::Text(generate_text(&TextCorpusSpec::default(), chunk, rng)),
            };
            remaining = remaining.saturating_sub(chunk);
            splits.push(split);
        }
        splits
    }

    /// Profile the benchmark by *really running it* on `sample_bytes` of
    /// generated data, then scale the measured ratios to `target_bytes`.
    pub fn profile_scaled(&self, sample_bytes: u64, target_bytes: u64, rng: &mut Rng) -> WorkloadProfile {
        let splits = self.generate_input(sample_bytes, sample_bytes.div_ceil(4).max(1), rng);
        let job = self.job();
        // Modest reducer count for profiling; ratios are insensitive to it.
        let out = crate::engine::run_job(&job, &splits, 8);
        WorkloadProfile::from_stats(
            self.label(),
            &out.stats,
            target_bytes,
            self.has_combiner(),
            self.map_cpu_ops_per_record(),
            self.reduce_cpu_ops_per_record(),
        )
    }

    /// Profile at the paper's partial-workload size with a small real sample.
    pub fn paper_profile(&self, rng: &mut Rng) -> WorkloadProfile {
        self.profile_scaled(2 * MB, self.paper_partial_bytes(), rng)
    }
}

impl std::fmt::Display for Benchmark {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

// ---------------------------------------------------------------------------
// Mappers / reducers
// ---------------------------------------------------------------------------

/// Terasort: emit (10-byte key, 90-byte payload).
struct TeraMapper;

impl Mapper for TeraMapper {
    fn map(&self, _k: u64, value: &[u8], emit: Emit) {
        if value.len() >= 10 {
            emit(Rec::new(value[..10].to_vec(), value[10..].to_vec()));
        }
    }
}

/// Word-level pattern for the Grep benchmark: matches maximal `\w+` runs
/// that contain any of a set of literal fragments — the offline stand-in
/// for `regex::bytes::Regex` (DESIGN.md §7). Covers the two shapes the
/// project uses: the default `\b\w*(aa|ee|..)\w*\b` alternation form and a
/// plain literal substring.
pub struct WordPattern {
    fragments: Vec<Vec<u8>>,
}

impl WordPattern {
    /// Parse a pattern. Accepted grammar: `\b\w*(F1|F2|..)\w*\b` (a word
    /// containing any literal fragment `Fi`) or a bare literal (a word
    /// containing that substring). Anything else is rejected.
    pub fn parse(pattern: &str) -> crate::util::error::Result<WordPattern> {
        let inner = pattern
            .strip_prefix(r"\b\w*(")
            .and_then(|r| r.strip_suffix(r")\w*\b"));
        let fragments: Vec<&str> = match inner {
            Some(alts) => alts.split('|').collect(),
            None => vec![pattern],
        };
        for f in &fragments {
            if f.is_empty() || !f.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_') {
                return Err(crate::util::error::Error::msg(format!(
                    "unsupported grep pattern {pattern:?}: fragments must be \
                     non-empty word literals (offline matcher, no full regex)"
                )));
            }
        }
        Ok(WordPattern {
            fragments: fragments.into_iter().map(|f| f.as_bytes().to_vec()).collect(),
        })
    }

    /// Does a word contain any fragment?
    fn matches(&self, word: &[u8]) -> bool {
        self.fragments
            .iter()
            .any(|f| word.windows(f.len()).any(|w| w == f.as_slice()))
    }
}

/// Grep: count pattern matches. The default pattern matches words with a
/// doubled vowel — selective but not empty on the Zipf corpus (the paper
/// notes Grep "produces very little map output").
pub struct GrepMapper {
    pattern: WordPattern,
}

impl GrepMapper {
    pub fn default_pattern() -> Self {
        GrepMapper { pattern: WordPattern::parse(r"\b\w*(aa|ee|ii|oo|uu)\w*\b").unwrap() }
    }

    pub fn with_pattern(pattern: &str) -> crate::util::error::Result<Self> {
        Ok(GrepMapper { pattern: WordPattern::parse(pattern)? })
    }
}

impl Mapper for GrepMapper {
    fn map(&self, _k: u64, value: &[u8], emit: Emit) {
        // \w+ word runs, like the regex's \b\w*..\w*\b match extent
        for word in value
            .split(|&b| !(b.is_ascii_alphanumeric() || b == b'_'))
            .filter(|w| !w.is_empty())
        {
            if self.pattern.matches(word) {
                emit(Rec::new(word.to_vec(), b"1".to_vec()));
            }
        }
    }
}

fn tokenize(value: &[u8]) -> impl Iterator<Item = &[u8]> {
    value
        .split(|&b| !(b.is_ascii_alphanumeric()))
        .filter(|w| !w.is_empty())
}

/// Bigram: emit ("w1 w2", 1) for consecutive word pairs.
struct BigramMapper;

impl Mapper for BigramMapper {
    fn map(&self, _k: u64, value: &[u8], emit: Emit) {
        let words: Vec<&[u8]> = tokenize(value).collect();
        for pair in words.windows(2) {
            let mut key = Vec::with_capacity(pair[0].len() + pair[1].len() + 1);
            key.extend_from_slice(pair[0]);
            key.push(b' ');
            key.extend_from_slice(pair[1]);
            emit(Rec::new(key, b"1".to_vec()));
        }
    }
}

/// Inverted index: line is `docid<TAB>text`; emit (word, docid) once per
/// distinct word per document.
struct InvertedIndexMapper;

impl Mapper for InvertedIndexMapper {
    fn map(&self, _k: u64, value: &[u8], emit: Emit) {
        let Some(tab) = value.iter().position(|&b| b == b'\t') else {
            return;
        };
        let (doc, text) = value.split_at(tab);
        let mut seen: std::collections::BTreeSet<&[u8]> = std::collections::BTreeSet::new();
        for w in tokenize(&text[1..]) {
            seen.insert(w);
        }
        for w in seen {
            emit(Rec::new(w.to_vec(), doc.to_vec()));
        }
    }
}

/// Inverted index reducer: build the sorted, deduplicated postings list.
struct PostingsReducer;

impl Reducer for PostingsReducer {
    fn reduce(&self, key: &[u8], values: &[Vec<u8>], emit: Emit) {
        let mut docs: Vec<&[u8]> = values.iter().map(|v| v.as_slice()).collect();
        docs.sort_unstable();
        docs.dedup();
        let mut postings = Vec::new();
        for (i, d) in docs.iter().enumerate() {
            if i > 0 {
                postings.push(b',');
            }
            postings.extend_from_slice(d);
        }
        emit(Rec::new(key.to_vec(), postings));
    }
}

/// Word co-occurrence: emit ("wi:wj", 1) for all ordered pairs within a
/// sliding window (the paper's NLP co-occurrence matrix).
struct CooccurrenceMapper {
    window: usize,
}

impl Mapper for CooccurrenceMapper {
    fn map(&self, _k: u64, value: &[u8], emit: Emit) {
        let words: Vec<&[u8]> = tokenize(value).collect();
        for i in 0..words.len() {
            let end = (i + 1 + self.window).min(words.len());
            for j in i + 1..end {
                let mut key = Vec::with_capacity(words[i].len() + words[j].len() + 1);
                key.extend_from_slice(words[i]);
                key.push(b':');
                key.extend_from_slice(words[j]);
                emit(Rec::new(key, b"1".to_vec()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run_job;

    fn text_split(s: &str) -> Vec<Split> {
        vec![Split::Text(s.as_bytes().to_vec())]
    }

    #[test]
    fn word_pattern_parses_alternation_and_literal() {
        let p = WordPattern::parse(r"\b\w*(aa|bb)\w*\b").unwrap();
        assert!(p.matches(b"baaz"));
        assert!(p.matches(b"abba"));
        assert!(!p.matches(b"abab"));
        let lit = WordPattern::parse("oo").unwrap();
        assert!(lit.matches(b"look"));
        assert!(!lit.matches(b"lok"));
        assert!(WordPattern::parse("").is_err());
        assert!(WordPattern::parse(r"a+b*").is_err());
    }

    #[test]
    fn grep_counts_matches() {
        let job = Benchmark::Grep.job();
        let out = run_job(&job, &text_split("the keen bee seen here\nkeen again"), 2);
        // doubled-vowel words: keen, bee, seen, keen
        assert_eq!(out.find(b"keen").unwrap().value_str(), "2");
        assert_eq!(out.find(b"bee").unwrap().value_str(), "1");
        assert_eq!(out.find(b"seen").unwrap().value_str(), "1");
        assert!(out.find(b"the").is_none());
    }

    #[test]
    fn bigram_counts_pairs() {
        let job = Benchmark::Bigram.job();
        let out = run_job(&job, &text_split("a b a b a"), 2);
        assert_eq!(out.find(b"a b").unwrap().value_str(), "2");
        assert_eq!(out.find(b"b a").unwrap().value_str(), "2");
    }

    #[test]
    fn inverted_index_builds_postings() {
        let job = Benchmark::InvertedIndex.job();
        let input = "doc1\tapple pear\ndoc2\tapple fig\n";
        let out = run_job(&job, &text_split(input), 2);
        let apple = out.find(b"apple").unwrap().value_str().to_string();
        assert_eq!(apple, "doc1,doc2");
        assert_eq!(out.find(b"fig").unwrap().value_str(), "doc2");
    }

    #[test]
    fn inverted_index_dedups_within_doc() {
        let job = Benchmark::InvertedIndex.job();
        let out = run_job(&job, &text_split("doc9\tword word word\n"), 1);
        assert_eq!(out.find(b"word").unwrap().value_str(), "doc9");
    }

    #[test]
    fn cooccurrence_window_two() {
        let job = Benchmark::WordCooccurrence.job();
        let out = run_job(&job, &text_split("x y z"), 1);
        // pairs: x:y, x:z, y:z
        assert_eq!(out.find(b"x:y").unwrap().value_str(), "1");
        assert_eq!(out.find(b"x:z").unwrap().value_str(), "1");
        assert_eq!(out.find(b"y:z").unwrap().value_str(), "1");
    }

    #[test]
    fn terasort_sorts_within_partitions() {
        let mut rng = Rng::seeded(8);
        let splits = Benchmark::Terasort.generate_input(10_000, 5_000, &mut rng);
        let job = Benchmark::Terasort.job();
        let out = run_job(&job, &splits, 4);
        // total records preserved
        let total: usize = out.partitions.iter().map(|p| p.len()).sum();
        assert_eq!(total, 100);
        // sorted within each partition, and partition ranges ordered
        let mut last_max: Vec<u8> = Vec::new();
        for part in &out.partitions {
            for w in part.windows(2) {
                assert!(w[0].key <= w[1].key);
            }
            if let (Some(first), Some(last)) = (part.first(), part.last()) {
                assert!(first.key >= last_max, "partition ranges out of order");
                last_max = last.key.clone();
            }
        }
    }

    #[test]
    fn profiles_reflect_benchmark_character() {
        let mut rng = Rng::seeded(21);
        let tera = Benchmark::Terasort.profile_scaled(200_000, 1 << 30, &mut rng);
        let grep = Benchmark::Grep.profile_scaled(200_000, 1 << 30, &mut rng);
        let cooc = Benchmark::WordCooccurrence.profile_scaled(200_000, 1 << 30, &mut rng);
        // Terasort: map output ≈ input (identity)
        assert!(
            tera.map_selectivity_bytes > 0.9 && tera.map_selectivity_bytes < 1.3,
            "tera selectivity {}",
            tera.map_selectivity_bytes
        );
        // Grep: tiny map output (the paper's observation)
        assert!(grep.map_selectivity_bytes < 0.25, "grep selectivity {}", grep.map_selectivity_bytes);
        // Co-occurrence: map output larger than input
        assert!(cooc.map_selectivity_bytes > 1.0, "cooc selectivity {}", cooc.map_selectivity_bytes);
        // combiner helps the skewed-text counts
        assert!(cooc.combiner_reduction < 0.9);
        // word-pair text compresses measurably
        assert!(cooc.compress_ratio < 0.7, "cooc ratio {}", cooc.compress_ratio);
    }

    #[test]
    fn from_name_parses_variants() {
        assert_eq!(Benchmark::from_name("TeraSort"), Some(Benchmark::Terasort));
        assert_eq!(Benchmark::from_name("inverted-index"), Some(Benchmark::InvertedIndex));
        assert_eq!(Benchmark::from_name("word co-occurrence"), Some(Benchmark::WordCooccurrence));
        assert_eq!(Benchmark::from_name("nope"), None);
    }

    #[test]
    fn every_label_round_trips_through_from_name() {
        // CLI/experiment args carry `label()` strings (the paper's display
        // names): parsing must invert labelling for every benchmark, in any
        // case, with stray whitespace.
        for b in Benchmark::all() {
            let label = b.label();
            assert_eq!(Benchmark::from_name(label), Some(b), "{label}");
            assert_eq!(Benchmark::from_name(&label.to_uppercase()), Some(b), "{label} upper");
            assert_eq!(Benchmark::from_name(&label.to_lowercase()), Some(b), "{label} lower");
            assert_eq!(Benchmark::from_name(&format!("  {label} ")), Some(b), "{label} padded");
            assert_eq!(Benchmark::from_name(&b.to_string()), Some(b), "{label} Display");
        }
        // the paper's exact table labels
        assert_eq!(Benchmark::from_name("Inverted Index"), Some(Benchmark::InvertedIndex));
        assert_eq!(Benchmark::from_name("Word Co-occurrence"), Some(Benchmark::WordCooccurrence));
    }

    #[test]
    fn paper_partial_sizes() {
        assert_eq!(Benchmark::Terasort.paper_partial_bytes(), 30 * GB);
        assert_eq!(Benchmark::Bigram.paper_partial_bytes(), 200 * MB);
    }
}
